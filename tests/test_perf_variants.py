"""Correctness of the §Perf optimization variants: every optimized path
must be numerically equivalent to its baseline."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L

_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run_forced(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = _SRC + os.pathsep + env.get("PYTHONPATH", "")
    prologue = (
        "import os\n"
        f"os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count={devices}'\n"
    )
    proc = subprocess.run([sys.executable, "-c", prologue + textwrap.dedent(code)],
                          capture_output=True, text=True, timeout=600, env=env)
    assert proc.returncode == 0, proc.stderr[-4000:]
    return proc.stdout


def test_chunked_attention_matches_naive():
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (2, 1024, 4, 32))
    k = jax.random.normal(ks[1], (2, 1024, 2, 32))
    v = jax.random.normal(ks[2], (2, 1024, 2, 32))
    naive = L.gqa_attention(q, k, v, causal=True)
    for blk in (128, 256, 512):
        chunk = L.chunked_attention(q, k, v, causal=True, block=blk)
        np.testing.assert_allclose(np.asarray(chunk), np.asarray(naive),
                                   rtol=2e-3, atol=2e-3)


def test_chunked_attention_grad_finite():
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, 512, 2, 16))
    k = jax.random.normal(ks[1], (1, 512, 2, 16))
    v = jax.random.normal(ks[2], (1, 512, 2, 16))
    g = jax.grad(lambda q: L.chunked_attention(q, k, v, True, 128).sum())(q)
    assert np.isfinite(np.asarray(g)).all()


def test_moe_ep_a2a_matches_dense_mixture():
    out = _run_forced("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import make_mesh
        from repro.models import layers as L, moe
        from repro.configs import REGISTRY, smoke_config
        mesh = make_mesh((2, 4), ("data", "model"))
        L.set_mesh(mesh)
        cfg = smoke_config(REGISTRY["qwen3-moe-30b-a3b"])
        p = jax.tree.map(lambda a: a[0], moe.init_moe_mlp(jax.random.PRNGKey(0), cfg, 1))
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 64), jnp.float32)
        def dense_ref(p, x):
            t = x.reshape(-1, 64)
            pr = jax.nn.softmax((t @ p["router"]).astype(jnp.float32), -1)
            topv, topi = jax.lax.top_k(pr, cfg.top_k)
            topv = topv / topv.sum(-1, keepdims=True)
            oe = jnp.stack([(jax.nn.silu(t@p["wg"][e]) * (t@p["wu"][e])) @ p["wd"][e]
                            for e in range(cfg.n_experts)], 1)
            w = jnp.zeros((t.shape[0], cfg.n_experts)).at[
                jnp.arange(t.shape[0])[:, None], topi].set(topv)
            return jnp.einsum("te,ted->td", w, oe).reshape(x.shape)
        want = dense_ref(p, x)
        moe.set_moe_impl("ep_a2a")
        got = jax.jit(lambda p, x: moe.moe_forward(p, x, cfg))(p, x)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-3, atol=2e-3)
        g = jax.grad(lambda x: jnp.sum(
            jax.jit(lambda p, x: moe.moe_forward(p, x, cfg))(p, x) ** 2))(x)
        assert np.isfinite(np.asarray(g)).all()
        print("EP_OK")
    """)
    assert "EP_OK" in out


def test_reduce_scatter_generation_matches_butterfly():
    out = _run_forced("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.graph.synthetic import powerlaw_graph, node_features, node_labels
        from repro.core.partition import partition_edges
        from repro.core.generation import make_distributed_generator
        from repro.launch.mesh import make_mesh
        W = 8
        mesh = make_mesh((W,), ("data",))
        g = powerlaw_graph(2000, avg_degree=8, n_hot=3, hot_degree=500, seed=0)
        part = partition_edges(g, W)
        X = node_features(2000, 16); Y = node_labels(2000, 7)
        seeds = np.arange(W * 16, dtype=np.int32).reshape(W, 16)
        gb, db = make_distributed_generator(mesh, part, X, Y, fanouts=(8, 4))
        gr, dr = make_distributed_generator(mesh, part, X, Y, fanouts=(8, 4),
                                            merge_mode="reduce_scatter")
        bb = jax.tree.map(np.asarray, gb(db, jnp.asarray(seeds), jax.random.PRNGKey(3)))
        br = jax.tree.map(np.asarray, gr(dr, jnp.asarray(seeds), jax.random.PRNGKey(3)))
        # identical candidate multisets -> identical min-k per frontier row
        np.testing.assert_array_equal(np.sort(bb.hop1, -1), np.sort(br.hop1, -1))
        np.testing.assert_array_equal(bb.mask1, br.mask1)
        adj = {v: set(g.indices[g.indptr[v]:g.indptr[v+1]]) for v in range(2000)}
        for i in range(br.hop1.shape[0]):
            for j in range(8):
                if br.mask1[i, j]:
                    assert br.hop1[i, j] in adj[br.seeds[i]]
        assert np.abs(br.x_hop1[br.mask1] - X[br.hop1[br.mask1]]).max() == 0
        print("RS_OK")
    """)
    assert "RS_OK" in out


def test_tree_reduce_scatter_segments():
    out = _run_forced("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.core.tree_reduce import tree_reduce_scatter
        from repro.launch.mesh import make_mesh
        W, F = 8, 32
        mesh = make_mesh((W,), ("data",))
        # per-worker data [F]: value = worker_id; merge = add
        x = jnp.tile(jnp.arange(W, dtype=jnp.float32)[:, None], (1, F))
        def body(v):
            return tree_reduce_scatter(
                v[0], lambda a, b: a + b, "data")
        out = shard_map(body, mesh=mesh, in_specs=P("data"),
                        out_specs=P("data"), check_rep=False)(x)
        # every row of every segment = sum over workers = 28
        np.testing.assert_array_equal(np.asarray(out),
                                      np.full((W * (F // W),), 28.0))
        print("SEG_OK")
    """)
    assert "SEG_OK" in out


def test_seq_parallel_matches_baseline():
    out = _run_forced("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import make_mesh
        from repro.models import layers as L, transformer
        from repro.configs import REGISTRY, smoke_config
        cfg = smoke_config(REGISTRY["smollm-135m"])
        params = transformer.init_lm(cfg, jax.random.PRNGKey(0))
        toks = jnp.asarray(np.random.default_rng(0).integers(
            0, cfg.vocab_size, (2, 16), dtype=np.int32))
        base = transformer.forward_train(cfg, params, toks)
        mesh = make_mesh((2, 4), ("data", "model"))
        L.set_mesh(mesh); L.set_seq_parallel(True)
        sp = jax.jit(lambda p, t: transformer.forward_train(cfg, p, t))(params, toks)
        L.set_mesh(None); L.set_seq_parallel(False)
        np.testing.assert_allclose(np.asarray(base), np.asarray(sp),
                                   rtol=2e-2, atol=2e-2)
        print("SP_OK")
    """)
    assert "SP_OK" in out


def test_compressed_training_still_learns():
    """int8 error-feedback compression must not break optimization."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.configs import REGISTRY, smoke_config
    from repro.core.config import TrainConfig
    from repro.models import zoo
    from repro.train.train_loop import init_state, make_train_step
    cfg = smoke_config(REGISTRY["smollm-135m"])
    api = zoo.build(cfg)
    tcfg = TrainConfig(learning_rate=3e-3, warmup_steps=0, compress_grads=True)
    state = init_state(api.init(jax.random.PRNGKey(0)), tcfg)
    assert state.error is not None
    step = jax.jit(make_train_step(api.loss, tcfg))
    rng = np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab_size, (4, 32), dtype=np.int32)
    batch = {"tokens": jnp.asarray(toks),
             "labels": jnp.asarray(np.roll(toks, -1, 1))}
    first = None
    for _ in range(25):
        state, m = step(state, batch)
        if first is None:
            first = float(m["loss"])
    assert float(m["loss"]) < first - 0.5
