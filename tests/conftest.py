"""Test-suite bootstrap.

``hypothesis`` is a dev-only dependency (see pyproject ``[dev]`` extra); when
absent, a deterministic stub stands in so the property-based modules still
collect and exercise their invariants on a fixed example budget.
"""
import os
import sys

sys.path.insert(0, os.path.dirname(__file__))

try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    import _hypothesis_stub

    sys.modules["hypothesis"] = _hypothesis_stub
    sys.modules["hypothesis.strategies"] = _hypothesis_stub.strategies
