"""graphlint gates, enforced in tier-1 so the hazard net cannot rot:

* every rule fires on a fixture encoding the historical bug pattern it
  was written for, and stays silent on the fixed form;
* suppression comments work (inline and own-line), require a
  justification, and reject unknown rule ids;
* the ``[tool.graphlint]`` config path (enable/disable/severity/
  exclude) and the 3.10 mini-TOML fallback parser behave;
* the GitHub-annotation formatter emits well-formed workflow commands;
* the real tree (``src/ benchmarks/ examples/``) lints clean inside the
  CI wall-clock budget — the zero-findings gate.

The test imports the tool from the repo checkout (same code CI runs),
so the gate cannot fork from the tool.
"""
import io
import os
import sys
import time

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
if REPO_ROOT not in sys.path:
    sys.path.insert(0, REPO_ROOT)

from tools import _report                              # noqa: E402
from tools.graphlint import Config, RULES, lint_paths, lint_source  # noqa: E402
from tools.graphlint.core import (                     # noqa: E402
    _parse_toml_minimal,
    mesh_axis_names,
    parse_suppressions,
)

_AXES = frozenset({"pod", "data", "model"})


def _rules_fired(source, config=None, axes=_AXES):
    findings = lint_source("fixture.py", source, config or Config(),
                           mesh_axes=axes)
    return [(f.rule, f.line) for f in findings]


def _assert_fires(rule, source):
    fired = _rules_fired(source)
    assert any(r == rule for r, _ in fired), (
        f"{rule} should fire on:\n{source}\nfired: {fired}")


def _assert_silent(source):
    fired = _rules_fired(source)
    assert not fired, f"expected clean, fired: {fired}\non:\n{source}"


# ---------------------------------------------------------------------------
# rule fixtures: each bug pattern fires, each fixed form is silent
# ---------------------------------------------------------------------------

def test_discarded_functional_update_fires_and_fixed_form_silent():
    """A bare ``x.at[i].set(v)`` statement is a silent no-op (JAX arrays
    are immutable) — the classic in-place-NumPy porting bug."""
    _assert_fires("discarded-functional-update", """
def admit(table, slot, row):
    table.at[slot].set(row)
    return table
""")
    _assert_silent("""
def admit(table, slot, row):
    table = table.at[slot].set(row)
    return table
""")


def test_tracer_branch_fires_on_jit_if_and_cast():
    """Python `if` and int() on a traced jit argument force
    concretization — ConcretizationTypeError or a traced-once branch."""
    _assert_fires("tracer-branch", """
import jax
@jax.jit
def relu(x):
    if x > 0:
        return x
    return 0.0
""")
    _assert_fires("tracer-branch", """
import jax
def count(x):
    return int(x.sum())
f = jax.jit(count)
""")


def test_tracer_branch_silent_on_static_idioms():
    """Shape introspection, `is None` tests, static_argnames params, and
    kernel keyword-only config params are static under tracing."""
    _assert_silent("""
import jax
@jax.jit
def f(x, mask=None):
    if mask is not None:
        x = x * mask
    if x.ndim == 2 and x.shape[0] > 1:
        x = x.sum(0)
    return x
""")
    _assert_silent("""
import functools
import jax
@functools.partial(jax.jit, static_argnames=("k",))
def topk(x, k):
    if k <= 0:
        return x
    return x[:k]
""")
    _assert_silent("""
import functools
from jax.experimental import pallas as pl
def _kernel(x_ref, o_ref, *, causal):
    if causal:
        o_ref[...] = x_ref[...]
def launch(x):
    return pl.pallas_call(functools.partial(_kernel, causal=True),
                          grid=(1,), out_specs=None)(x)
""")


def test_tracer_branch_fires_in_pallas_kernel_positional_ref():
    """A Python branch on a positional ref inside a pallas_call kernel is
    a real tracer leak (refs are never concrete)."""
    _assert_fires("tracer-branch", """
from jax.experimental import pallas as pl
def _kernel(x_ref, o_ref):
    if x_ref[0] > 0:
        o_ref[0] = x_ref[0]
def launch(x):
    return pl.pallas_call(_kernel, grid=(1,), out_specs=None)(x)
""")


def test_collective_axis_fires_on_undeclared_axis():
    """An axis_name string absent from launch/mesh.py's tuples hangs or
    mis-reduces the collective at runtime."""
    _assert_fires("collective-axis", """
import jax
def sync(x):
    return jax.lax.psum(x, "devices")
""")
    _assert_silent("""
import jax
def sync(x, axis_name):
    total = jax.lax.psum(x, "data")
    idx = jax.lax.axis_index(axis_name)
    return total, idx
""")


def test_collective_axis_fires_on_shard_map_without_out_specs():
    """shard_map without explicit out_specs silently replicates outputs —
    the historical memory blow-up."""
    _assert_fires("collective-axis", """
from jax.experimental.shard_map import shard_map
def wrap(f, mesh, specs):
    return shard_map(f, mesh=mesh, in_specs=specs)
""")
    _assert_silent("""
from jax.experimental.shard_map import shard_map
def wrap(f, mesh, specs):
    return shard_map(f, mesh=mesh, in_specs=specs, out_specs=specs)
""")


def test_cacheconfig_required_fires_without_cfg():
    """The PR 3 dead-config bug: probing a cache built with one geometry
    using a default-constructed CacheConfig."""
    _assert_fires("cacheconfig-required", """
def step(table, ids, cache):
    return fetch_rows(table, ids, "data", cache=cache)
""")
    _assert_fires("cacheconfig-required", """
def probe(cache, ids):
    return cache_probe(cache, ids)
""")
    _assert_silent("""
def step(table, ids, cache, cfg):
    rows = fetch_rows(table, ids, "data", cache=cache, cache_cfg=cfg)
    hits, vals = cache_probe(cache, ids, cfg=cfg)
    cache = cache_insert(cache, ids, rows, hits, cfg)
    return rows, vals, cache
""")


def test_pallas_blockspec_fires_on_floordiv_grid():
    """A `//`-built grid drops the partial final block when the axis
    stops dividing evenly; pl.cdiv covers it."""
    _assert_fires("pallas-blockspec", """
from jax.experimental import pallas as pl
def launch(x, kern, bq):
    grid = (x.shape[0] // bq,)
    return pl.pallas_call(kern, grid=grid, out_specs=None)(x)
""")
    _assert_silent("""
from jax.experimental import pallas as pl
def launch(x, kern, bq):
    grid = (pl.cdiv(x.shape[0], bq),)
    return pl.pallas_call(kern, grid=grid, out_specs=None)(x)
""")


def test_pallas_blockspec_fires_on_unguarded_shift_width():
    """``x >> (32 - k)`` is UB when k can be 0 — the PR 3 degenerate-hash
    bug (every id hashed to set 0 when n_sets == 1)."""
    _assert_fires("pallas-blockspec", """
import jax
import jax.numpy as jnp
def hash_slots(ids, n_sets):
    shift = 32 - (int(n_sets).bit_length() - 1)
    return jax.lax.shift_right_logical(ids, jnp.uint32(shift))
""")
    # the hash_slots guard idiom: early return before the shift
    _assert_silent("""
import jax
import jax.numpy as jnp
def hash_slots(ids, n_sets):
    if n_sets == 1:
        return jnp.zeros_like(ids)
    shift = 32 - (int(n_sets).bit_length() - 1)
    return jax.lax.shift_right_logical(ids, jnp.uint32(shift))
""")


def test_pallas_blockspec_fires_on_impure_index_map():
    """BlockSpec index maps must be pure index arithmetic — a call inside
    the lambda can capture traced state or allocate."""
    _assert_fires("pallas-blockspec", """
from jax.experimental import pallas as pl
def launch(x, kern, lookup):
    spec = pl.BlockSpec((1, 8), lambda i, j: (lookup(i), j))
    return pl.pallas_call(kern, grid=(1, 1), in_specs=[spec],
                          out_specs=None)(x)
""")
    _assert_silent("""
from jax.experimental import pallas as pl
def launch(x, kern):
    spec = pl.BlockSpec((1, 8), lambda i, j: (i, j))
    return pl.pallas_call(kern, grid=(1, 1), in_specs=[spec],
                          out_specs=None)(x)
""")


def test_host_transfer_fires_on_blocking_calls_in_traced_fns():
    """A device->host round-trip inside a jit/shard_map function either
    raises TracerArrayConversionError or silently bakes one step's data
    into the compiled program; block_until_ready under tracing is a
    silent no-op barrier."""
    _assert_fires("host-transfer", """
import jax
@jax.jit
def step(x):
    host = jax.device_get(x)
    return host.sum()
""")
    _assert_fires("host-transfer", """
import jax
import numpy as np
@jax.jit
def step(x):
    rows = np.asarray(x)
    return rows * 2
""")
    _assert_fires("host-transfer", """
import jax
def step(x):
    y = (x * 2).sum()
    y.block_until_ready()
    return y
f = jax.jit(step)
""")
    # taint propagates through assignment, as in tracer-branch
    _assert_fires("host-transfer", """
import jax
@jax.jit
def step(x):
    y = x + 1
    return jax.device_get(y)
""")


def test_host_transfer_silent_on_host_side_driver_code():
    """The same calls OUTSIDE traced functions are the legitimate idiom —
    host_store.py's _gather and the loops' block_until_ready timing
    fences must never fire, nor jnp.asarray (stays on device)."""
    _assert_silent("""
import jax
import numpy as np
def gather(table, ids):
    ids_np = np.asarray(ids)
    rows = table[ids_np]
    return jax.device_put(rows)
""")
    _assert_silent("""
import jax
def run(step, carry):
    carry = step(carry)
    jax.block_until_ready(carry)
    return carry
""")
    _assert_silent("""
import jax
import jax.numpy as jnp
@jax.jit
def step(x):
    return jnp.asarray(x) * 2
""")


def test_unseeded_rng_fires_on_global_state():
    """Global-RNG draws make benchmark runs non-replayable; the repo
    contract is an explicit np.random.default_rng(seed)."""
    _assert_fires("unseeded-rng", """
import numpy as np
def make_ids(n):
    return np.random.randint(0, 100, size=n)
""")
    _assert_fires("unseeded-rng", """
import numpy as np
def make_rng():
    return np.random.default_rng()
""")
    _assert_silent("""
import numpy as np
def make_ids(n, seed):
    rng = np.random.default_rng(seed)
    return rng.integers(0, 100, size=n)
""")


# ---------------------------------------------------------------------------
# suppression comments
# ---------------------------------------------------------------------------

_RNG_LINE = "import numpy as np\nv = np.random.rand(3)"


def test_suppression_inline_with_justification_silences():
    """``# graphlint: disable=<rule>  # why`` on the flagged line."""
    src = ("import numpy as np\n"
           "v = np.random.rand(3)"
           "  # graphlint: disable=unseeded-rng  # noise floor demo\n")
    assert _rules_fired(src) == []


def test_suppression_own_line_applies_to_next_line():
    """A comment-only suppression silences the following line, with the
    ``--`` justification spelling also accepted."""
    src = ("import numpy as np\n"
           "# graphlint: disable=unseeded-rng -- noise floor demo\n"
           "v = np.random.rand(3)\n")
    assert _rules_fired(src) == []
    # ...but it does NOT silence any later line
    src2 = ("import numpy as np\n"
            "# graphlint: disable=unseeded-rng -- noise floor demo\n"
            "a = 1\n"
            "v = np.random.rand(3)\n")
    assert ("unseeded-rng", 4) in _rules_fired(src2)


def test_suppression_without_justification_is_bad_suppression():
    """A bare suppression is rejected AND the original finding stays —
    silencing a rule requires saying why."""
    src = ("import numpy as np\n"
           "v = np.random.rand(3)  # graphlint: disable=unseeded-rng\n")
    fired = _rules_fired(src)
    assert ("bad-suppression", 2) in fired
    assert ("unseeded-rng", 2) in fired


def test_suppression_unknown_rule_is_bad_suppression():
    """Typo'd rule ids fail loudly instead of silently not suppressing."""
    src = ("import numpy as np\n"
           "v = np.random.rand(3)  # graphlint: disable=unseeded-rgn  # why\n")
    fired = _rules_fired(src)
    assert ("bad-suppression", 2) in fired
    assert ("unseeded-rng", 2) in fired


def test_parse_suppressions_multi_rule_list():
    """One comment can silence several rules on the same line."""
    sup, problems = parse_suppressions(
        ["x = 1  # graphlint: disable=unseeded-rng,tracer-branch  # demo"])
    assert problems == []
    assert sup[1] == {"unseeded-rng", "tracer-branch"}


# ---------------------------------------------------------------------------
# config: [tool.graphlint], severities, excludes, mini-TOML fallback
# ---------------------------------------------------------------------------

def test_config_disable_and_enable_lists():
    """disable= switches a rule off; a non-empty enable= runs only the
    listed rules."""
    src = "import numpy as np\nv = np.random.rand(3)\n"
    assert _rules_fired(src, Config(disable=("unseeded-rng",))) == []
    only = Config(enable=("discarded-functional-update",))
    assert _rules_fired(src, only) == []
    assert list(only.enabled_rules()) == ["discarded-functional-update"]


def test_config_severity_override_demotes_to_warning():
    """A [tool.graphlint.severity] override changes the reported severity
    (warnings print but do not fail the gate)."""
    cfg = Config(severity={"unseeded-rng": "warning"})
    findings = lint_source("f.py", "import numpy as np\nv = np.random.rand(3)\n",
                           cfg, mesh_axes=_AXES)
    assert [f.severity for f in findings] == ["warning"]


def test_config_from_dict_rejects_unknown_rule_and_bad_severity():
    """Config typos fail loudly instead of silently weakening the gate."""
    try:
        Config.from_dict({"disable": ["no-such-rule"]})
        raise AssertionError("unknown rule accepted")
    except ValueError:
        pass
    try:
        Config.from_dict({"severity": {"unseeded-rng": "fatal"}})
        raise AssertionError("bad severity accepted")
    except ValueError:
        pass


def test_config_exclude_globs():
    """exclude= patterns drop files from the walk (repo-relative)."""
    cfg = Config(exclude=("benchmarks/baselines/*",))
    assert cfg.is_excluded("benchmarks/baselines/gen.py")
    assert not cfg.is_excluded("benchmarks/run.py")


def test_mini_toml_parser_reads_graphlint_block():
    """The 3.10 fallback parser (no tomllib in the container) handles
    sections, string lists (incl. multi-line), severity tables, and
    comments — enough for pyproject.toml."""
    raw = _parse_toml_minimal("""
[project]
name = "x"                      # comment
dependencies = [
    "jax>=0.4.30",
    "numpy>=1.24",
]

[tool.graphlint]
exclude = ["benchmarks/baselines/*"]
collective-axes = []

[tool.graphlint.severity]
unseeded-rng = "warning"
""")
    assert raw["project"]["dependencies"] == ["jax>=0.4.30", "numpy>=1.24"]
    block = raw["tool"]["graphlint"]
    cfg = Config.from_dict(block)
    assert cfg.exclude == ("benchmarks/baselines/*",)
    assert cfg.severity_of("unseeded-rng") == "warning"


def test_repo_pyproject_config_loads():
    """The checked-in [tool.graphlint] block parses on this interpreter
    (3.10 fallback or 3.11 tomllib alike)."""
    cfg = Config.load(os.path.join(REPO_ROOT, "pyproject.toml"))
    assert cfg.is_excluded("benchmarks/baselines/anything.py")
    assert cfg.severity_of("unseeded-rng") == "error"


def test_mesh_axis_names_come_from_mesh_py():
    """The collective-axis allow-list is extracted from launch/mesh.py,
    so adding a mesh axis automatically teaches the rule."""
    axes = mesh_axis_names()
    assert {"pod", "data", "model"} <= axes


# ---------------------------------------------------------------------------
# shared report formats
# ---------------------------------------------------------------------------

def test_github_annotation_formatter():
    """Workflow commands carry file/line/title and escape newlines, so a
    CI failure annotates the offending line in the PR diff."""
    line = _report.format_github({
        "path": "src/x.py", "line": 7, "check": "unseeded-rng",
        "severity": "error", "message": "first\nsecond"})
    assert line == ("::error file=src/x.py,line=7,"
                    "title=unseeded-rng::first%0Asecond")
    warn = _report.format_github({
        "path": "a,b.py", "line": 1, "check": "c:d",
        "severity": "warning", "message": "m"})
    assert warn.startswith("::warning file=a%2Cb.py,line=1,title=c%3Ad::")


def test_json_report_shape():
    """--format=json emits one object with findings + severity counts."""
    import json
    buf = io.StringIO()
    _report.emit([{"path": "p", "line": 1, "check": "c",
                   "severity": "error", "message": "m"}],
                 fmt="json", stream=buf)
    data = json.loads(buf.getvalue())
    assert data["counts"] == {"error": 1, "warning": 0}
    assert data["findings"][0]["check"] == "c"


# ---------------------------------------------------------------------------
# the real-tree gate
# ---------------------------------------------------------------------------

def test_zero_findings_on_real_tree_within_budget():
    """`python -m tools.graphlint src benchmarks examples tests tools`
    exits 0 on the committed tree, inside the CI wall-clock budget — the
    same code path (including the project-wide dataflow rules) CI runs,
    so a new hazard or a slow rule fails here first."""
    t0 = time.monotonic()
    findings = lint_paths(["src", "benchmarks", "examples",
                           "tests", "tools"],
                          Config.load(), root=REPO_ROOT)
    elapsed = time.monotonic() - t0
    errors = [f for f in findings if f.severity == "error"]
    assert not errors, "\n".join(
        f"{f.path}:{f.line}: {f.rule}: {f.message}" for f in errors)
    assert elapsed < 10.0, f"graphlint took {elapsed:.2f}s (budget 10s)"


def test_rule_registry_covers_the_issue_hazard_classes():
    """All ten hazard classes stay registered — removing a rule without
    replacing its coverage fails the build."""
    from tools.graphlint.core import PROJECT_RULES
    assert {"discarded-functional-update", "tracer-branch",
            "collective-axis", "cacheconfig-required",
            "pallas-blockspec", "unseeded-rng",
            "host-transfer"} <= set(RULES)
    assert {"handle-lifecycle", "closure-capture",
            "carry-structure"} <= set(PROJECT_RULES)
