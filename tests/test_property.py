"""Property-based tests (hypothesis) on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.generation import Candidates, merge_topk
from repro.kernels import ref
from repro.launch.hlo_analysis import shape_bytes
from repro.models.ssm import ssd_chunked


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 6))
def test_merge_equals_global_topk(seed, k):
    """Merging per-worker candidate sets == global min-k over the union —
    the exact invariant the butterfly tree reduction relies on."""
    rng = np.random.default_rng(seed)
    parts = []
    for _ in range(4):
        parts.append(Candidates(
            ids=jnp.asarray(rng.integers(0, 1000, (3, k), dtype=np.int32)),
            keys=jnp.asarray(rng.uniform(0, 100, (3, k)).astype(np.float32)),
        ))
    merged = parts[0]
    for p in parts[1:]:
        merged = merge_topk(merged, p)
    all_keys = np.concatenate([np.asarray(p.keys) for p in parts], axis=1)
    want = np.sort(all_keys, axis=1)[:, :k]
    np.testing.assert_allclose(np.sort(np.asarray(merged.keys), axis=1), want,
                               rtol=1e-6)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_fanout_mean_bounds(seed):
    """Masked mean stays inside [min, max] of the contributing rows."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((5, 7, 9)).astype(np.float32))
    mask = jnp.asarray(rng.random((5, 7)) < 0.8)
    out = np.asarray(ref.fanout_mean_ref(x, mask))
    xm = np.asarray(x)
    for i in range(5):
        sel = np.asarray(mask)[i]
        if sel.any():
            lo = xm[i][sel].min(axis=0) - 1e-5
            hi = xm[i][sel].max(axis=0) + 1e-5
            assert (out[i] >= lo).all() and (out[i] <= hi).all()
        else:
            np.testing.assert_array_equal(out[i], 0)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1),
       st.sampled_from([4, 8, 16, 32]))
def test_ssd_chunk_size_invariance(seed, chunk):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((1, 32, 2, 4)).astype(np.float32))
    dt = jax.nn.softplus(jnp.asarray(rng.standard_normal((1, 32, 2)).astype(np.float32)))
    a = -jnp.exp(jnp.asarray(rng.standard_normal(2).astype(np.float32)))
    bm = jnp.asarray(rng.standard_normal((1, 32, 3)).astype(np.float32))
    cm = jnp.asarray(rng.standard_normal((1, 32, 3)).astype(np.float32))
    got = ssd_chunked(x, dt, a, bm, cm, chunk)
    want = ref.ssd_scan_ref(x, dt, a, bm, cm)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


@given(st.lists(st.integers(1, 64), min_size=1, max_size=4),
       st.sampled_from(["f32", "bf16", "s32", "u8", "pred"]))
def test_shape_bytes_parser(dims, dtype):
    nbytes = {"f32": 4, "bf16": 2, "s32": 4, "u8": 1, "pred": 1}[dtype]
    s = f"{dtype}[{','.join(map(str, dims))}]{{{','.join('0' * len(dims))}}}"
    want = nbytes * int(np.prod(dims))
    assert shape_bytes(s) == want


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_softmax_attention_rows_are_convex_combos(seed):
    """flash-attention output rows are convex combinations of V rows."""
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((1, 1, 4, 8)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((1, 1, 6, 8)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((1, 1, 6, 8)).astype(np.float32))
    out = np.asarray(ref.flash_attention_ref(q, k, v, causal=False))
    vm = np.asarray(v)[0, 0]
    lo, hi = vm.min(axis=0) - 1e-5, vm.max(axis=0) + 1e-5
    assert (out[0, 0] >= lo).all() and (out[0, 0] <= hi).all()
