"""Deterministic fallback for ``hypothesis`` when it is not installed.

The tier-1 container does not ship hypothesis; ``conftest.py`` registers this
module under ``sys.modules["hypothesis"]`` so the property-based test modules
still collect and run.  The stub draws a fixed number of pseudo-random
examples from a seed derived from the test name — deterministic across runs,
no shrinking, no database.  Install the real thing with ``pip install -e
'.[dev]'`` to get full property-based testing.
"""
from __future__ import annotations

import inspect
import random
import types
import zlib


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def draw(self, rng: random.Random):
        return self._draw(rng)


def _integers(min_value=0, max_value=2**31 - 1):
    return _Strategy(lambda rng: rng.randint(min_value, max_value))


def _sampled_from(options):
    opts = list(options)
    return _Strategy(lambda rng: opts[rng.randrange(len(opts))])


def _booleans():
    return _Strategy(lambda rng: bool(rng.getrandbits(1)))


def _floats(min_value=0.0, max_value=1.0, **_kw):
    return _Strategy(lambda rng: rng.uniform(min_value, max_value))


def _lists(elements, min_size=0, max_size=10):
    def draw(rng):
        n = rng.randint(min_size, max_size)
        return [elements.draw(rng) for _ in range(n)]

    return _Strategy(draw)


strategies = types.SimpleNamespace(
    integers=_integers,
    sampled_from=_sampled_from,
    booleans=_booleans,
    floats=_floats,
    lists=_lists,
)

_DEFAULT_MAX_EXAMPLES = 25


def settings(max_examples=_DEFAULT_MAX_EXAMPLES, deadline=None, **_kw):
    """Applied on top of ``given`` — records the example budget."""

    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn

    return deco


def given(*gargs, **gkwargs):
    """Run the test body over N deterministic examples.

    Mirrors hypothesis' parameter mapping: positional strategies fill the
    test function's RIGHTMOST parameters, keyword strategies fill their
    named parameters, and any leftover parameters stay visible through
    ``__signature__`` so pytest injects fixtures for them — same as the
    real library.
    """

    def deco(fn):
        params = list(inspect.signature(fn).parameters.values())
        if gargs:
            strat_names = [p.name for p in params[len(params) - len(gargs):]]
            fixture_params = params[:len(params) - len(gargs)]
        else:
            strat_names = []
            fixture_params = [p for p in params if p.name not in gkwargs]

        def runner(*args, **kwargs):
            n = getattr(runner, "_stub_max_examples", _DEFAULT_MAX_EXAMPLES)
            rng = random.Random(zlib.crc32(fn.__qualname__.encode()))
            for _ in range(n):
                kw = dict(zip(strat_names, (s.draw(rng) for s in gargs)))
                kw.update({k: s.draw(rng) for k, s in gkwargs.items()})
                fn(*args, **kwargs, **kw)

        runner.__name__ = fn.__name__
        runner.__doc__ = fn.__doc__
        runner.__module__ = fn.__module__
        runner.__signature__ = inspect.Signature(fixture_params)
        runner._stub_max_examples = _DEFAULT_MAX_EXAMPLES
        return runner

    return deco
