"""Parity canary for ``tests/_hypothesis_stub.py``.

Tier-1 containers run the property modules through the stub; dev machines
run them through real hypothesis.  The two environments only exercise the
SAME test cases if the stub's ``@given`` parameter mapping matches the
real library's documented contract:

  * positional strategies fill the test function's RIGHTMOST parameters,
  * keyword strategies fill their named parameters,
  * leftover (leading) parameters stay visible to pytest for fixtures /
    direct calls,
  * ``@settings(max_examples=N)`` bounds the number of drawn examples.

This module asserts that contract against WHICHEVER implementation is
loaded (``conftest.py`` installs the stub only when hypothesis is absent),
using pinned single-value strategies so the expected bindings are exact in
both environments.  A stub drift that remapped parameters would fail here
under the stub while real hypothesis keeps passing — precisely the
tier-1-vs-dev divergence this canary exists to catch.
"""
import inspect

from hypothesis import given, settings, strategies as st


def test_given_positional_strategies_fill_rightmost_params():
    calls = []

    @settings(max_examples=5, deadline=None)
    @given(st.integers(min_value=7, max_value=7), st.sampled_from(["z"]))
    def canary(lead, mid, tail):
        calls.append((lead, mid, tail))

    # `lead` is NOT covered by the two positional strategies, so it must
    # remain a caller-supplied (fixture-style) parameter; the strategies
    # bind right-aligned: mid <- integers, tail <- sampled_from
    canary("FIX")
    assert calls, "the wrapped test never ran its body"
    # pinned one-point strategies: hypothesis may deduplicate the single
    # distinct example, the stub replays it — both stay within the budget
    assert 1 <= len(calls) <= 5
    assert all(c == ("FIX", 7, "z") for c in calls), calls


def test_given_keyword_strategies_fill_named_params():
    calls = []

    @settings(max_examples=4, deadline=None)
    @given(b=st.integers(min_value=3, max_value=3))
    def canary(a, b):
        calls.append((a, b))

    canary("lead")
    assert calls and all(c == ("lead", 3) for c in calls), calls


def test_given_exposes_leftover_params_in_signature():
    """pytest decides fixture injection from the wrapper's signature: the
    strategy-bound parameters must be hidden, the leftovers visible."""

    @given(st.integers())
    def canary(fixture_param, drawn):
        pass

    visible = list(inspect.signature(canary).parameters)
    assert "fixture_param" in visible
    assert "drawn" not in visible


def test_given_all_params_covered_runs_standalone():
    """With every parameter strategy-bound, the wrapped test is callable
    with no arguments (how the property modules invoke their helpers)."""
    seen = []

    @settings(max_examples=3, deadline=None)
    @given(st.sampled_from([11]), st.sampled_from([22]))
    def canary(x, y):
        seen.append((x, y))

    canary()
    assert seen and all(s == (11, 22) for s in seen), seen
