"""Graph Partitioning (paper step 1): completeness and balance."""
import numpy as np
import pytest

from repro.core.partition import partition_edges
from repro.graph.csr import CSRGraph
from repro.graph.synthetic import powerlaw_graph


@pytest.mark.parametrize("strategy", ["by_edge_hash", "by_src_block"])
def test_partition_preserves_every_edge(strategy):
    g = powerlaw_graph(500, avg_degree=6, seed=1)
    part = partition_edges(g, 4, strategy=strategy)
    global_edges = sorted(zip(*g.edge_list()))
    local_edges = []
    for w in range(4):
        local = CSRGraph(part.indptr[w], part.indices[w][: part.n_local[w]])
        # indices were padded; rebuild edge list from local indptr
        src = np.repeat(np.arange(g.n_nodes, dtype=np.int32),
                        np.diff(part.indptr[w]))
        dst = part.indices[w][: len(src)]
        local_edges += list(zip(src.tolist(), dst.tolist()))
    assert sorted(local_edges) == global_edges


def test_edge_hash_splits_hot_nodes():
    """Edge-centric partitioning must spread a hot node's edges across
    workers — the property that parallelizes hot-node collection."""
    g = powerlaw_graph(300, avg_degree=4, n_hot=1, hot_degree=120, seed=0)
    part = partition_edges(g, 4, strategy="by_edge_hash")
    hot = int(np.argmax(g.degrees()))
    local_deg = [part.indptr[w][hot + 1] - part.indptr[w][hot] for w in range(4)]
    assert all(d > 0 for d in local_deg)           # every worker holds a share
    assert max(local_deg) < g.degrees()[hot]       # nobody holds it all


def test_edge_hash_balances_better_than_src_block():
    g = powerlaw_graph(2000, avg_degree=8, n_hot=5, hot_degree=400, seed=2)
    ph = partition_edges(g, 8, strategy="by_edge_hash")
    pb = partition_edges(g, 8, strategy="by_src_block")
    assert ph.edge_balance() <= pb.edge_balance()
    assert ph.edge_balance() < 1.05
