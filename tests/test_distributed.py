"""Multi-worker integration tests.

These run in SUBPROCESSES with ``--xla_force_host_platform_device_count=8``
so the main pytest process keeps its single real device (the dry-run-only
rule for forced device counts).
"""
import os
import subprocess
import sys
import textwrap

import pytest

_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_forced(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = _SRC + os.pathsep + env.get("PYTHONPATH", "")
    prologue = (
        "import os\n"
        f"os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count={devices}'\n"
    )
    proc = subprocess.run(
        [sys.executable, "-c", prologue + textwrap.dedent(code)],
        capture_output=True, text=True, timeout=600, env=env,
    )
    assert proc.returncode == 0, proc.stderr[-4000:]
    return proc.stdout


def test_distributed_generation_validity():
    out = run_forced("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.graph.synthetic import powerlaw_graph, node_features, node_labels
        from repro.core.partition import partition_edges
        from repro.core.balance import balance_table
        from repro.core.generation import make_distributed_generator
        from repro.launch.mesh import make_mesh

        W = 8
        mesh = make_mesh((W,), ("data",))
        g = powerlaw_graph(2000, avg_degree=8, n_hot=3, hot_degree=500, seed=0)
        part = partition_edges(g, W)
        X = node_features(2000, 32); Y = node_labels(2000, 7)
        table = balance_table(np.arange(2000), W, seed=0)
        seeds = table.per_worker[:, :16]
        gen, dev = make_distributed_generator(mesh, part, X, Y, fanouts=(8, 4))
        b = jax.tree.map(np.asarray, gen(dev, jnp.asarray(seeds), jax.random.PRNGKey(0)))
        adj = {v: set(g.indices[g.indptr[v]:g.indptr[v+1]]) for v in b.seeds}
        for i, s in enumerate(b.seeds):
            for j in range(8):
                if b.mask1[i, j]:
                    assert b.hop1[i, j] in adj[s], (i, j)
        assert np.abs(b.x_hop1[b.mask1] - X[b.hop1[b.mask1]]).max() == 0
        assert np.abs(b.x_seed - X[b.seeds]).max() == 0
        assert (b.labels == Y[b.seeds]).all()
        assert b.mask1.mean() == 1.0
        print("VALID")
    """)
    assert "VALID" in out


def test_hot_node_sampling_is_unbiased_across_partitions():
    """A hot node's edges live on all 8 workers; the tree-merged sample must
    draw from across the whole partition set, not just one worker."""
    out = run_forced("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.graph.csr import CSRGraph
        from repro.core.partition import partition_edges
        from repro.core.generation import make_distributed_generator
        from repro.launch.mesh import make_mesh

        W = 8
        # star graph: node 0 -> 1..800 (hot), everyone else isolated
        src = np.zeros(800, dtype=np.int32)
        dst = np.arange(1, 801, dtype=np.int32)
        g = CSRGraph.from_edges(src, dst, 801)
        part = partition_edges(g, W)   # edge-hash splits the hot edge list
        X = np.zeros((801, 4), np.float32); Y = np.zeros(801, np.int32)
        mesh = make_mesh((W,), ("data",))
        gen, dev = make_distributed_generator(mesh, part, X, Y, fanouts=(16, 2))
        seeds = np.zeros((W, 4), np.int32)   # every worker asks about node 0
        seen = set()
        for t in range(16):
            b = gen(dev, jnp.asarray(seeds), jax.random.PRNGKey(t))
            ids = np.asarray(b.hop1)[np.asarray(b.mask1)]
            # which worker-partition did each sampled edge come from?
            seen.update((int(i) % W) for i in ids)
        assert len(seen) == W, f"samples only from partitions {sorted(seen)}"
        print("UNBIASED", sorted(seen))
    """)
    assert "UNBIASED" in out


def test_tree_allreduce_matches_psum():
    out = run_forced("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.core.tree_reduce import tree_psum
        from repro.launch.mesh import make_mesh

        mesh = make_mesh((8,), ("data",))
        x = jnp.arange(8 * 5, dtype=jnp.float32).reshape(8, 5)
        tree = shard_map(lambda v: tree_psum(v, "data"), mesh=mesh,
                         in_specs=P("data"), out_specs=P("data"),
                         check_rep=False)(x)
        flat = shard_map(lambda v: jax.lax.psum(v, "data"), mesh=mesh,
                         in_specs=P("data"), out_specs=P("data"),
                         check_rep=False)(x)
        np.testing.assert_allclose(np.asarray(tree), np.asarray(flat))
        print("TREE_OK")
    """)
    assert "TREE_OK" in out


def test_fetch_rows_multiworker_routes_correctly():
    out = run_forced("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.core.generation import fetch_rows
        from repro.launch.mesh import make_mesh

        W, rows, d = 8, 16, 3
        mesh = make_mesh((W,), ("data",))
        table = np.arange(W * rows * d, dtype=np.float32).reshape(W * rows, d)
        rng = np.random.default_rng(0)
        ids = rng.integers(0, W * rows, size=64).astype(np.int32)
        out = shard_map(lambda t, i: fetch_rows(t, i, "data"),
                        mesh=mesh, in_specs=(P("data"), P()), out_specs=P(),
                        check_rep=False)(jnp.asarray(table), jnp.asarray(ids))
        np.testing.assert_array_equal(np.asarray(out), table[ids])
        print("FETCH_OK")
    """)
    assert "FETCH_OK" in out


def test_fetch_rows_skew_reports_drops_and_dedup_avoids_them():
    """Capacity overflow: a fully-skewed request pattern (every id owned by
    worker 0, heavily duplicated) must REPORT drops through FetchStats, not
    silently zero-fill; the dedup front end collapses the duplicates so at
    most n_unique ids cross the all_to_all and nothing drops at
    capacity == n_unique."""
    out = run_forced("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.core.generation import fetch_rows
        from repro.launch.mesh import make_mesh

        W, rows, d = 8, 16, 3
        mesh = make_mesh((W,), ("data",))
        table = np.arange(W * rows * d, dtype=np.float32).reshape(W * rows, d)
        rng = np.random.default_rng(0)
        # 256 requests over the 16 rows of worker 0 -> n_unique == 16
        ids = rng.integers(0, rows, size=256).astype(np.int32)

        def run(dedup, capacity):
            return shard_map(
                lambda t, i: fetch_rows(t, i, "data", dedup=dedup,
                                        capacity=capacity, return_stats=True),
                mesh=mesh, in_specs=(P("data"), P()), out_specs=P(),
                check_rep=False)(jnp.asarray(table), jnp.asarray(ids))

        n_unique = len(np.unique(ids))
        assert n_unique == 16
        # naive path at the dedup-sized capacity: massive drops, all counted
        out_n, st_n = run(False, n_unique)
        assert int(st_n.n_dropped) == 256 - n_unique, st_n
        # dedup path: every distinct id crosses once -> zero drops, and the
        # zero-filled naive result differs from the correct dedup result
        out_d, st_d = run(True, n_unique)
        assert int(st_d.n_unique) == n_unique
        assert int(st_d.n_dropped) == 0
        np.testing.assert_array_equal(np.asarray(out_d), table[ids])
        # naive path with the same wire budget lost rows
        assert np.abs(np.asarray(out_n) - table[ids]).max() > 0
        # under-capacity dedup: n_dropped counts zero-filled request SLOTS
        # (every duplicate of a dropped unique id), not wire slots
        out_p, st_p = run(True, 8)
        zero_filled = (np.asarray(out_p) != table[ids]).any(axis=1).sum()
        assert int(st_p.n_dropped) == zero_filled > 0, (st_p, zero_filled)
        print("DEDUP_OK")
    """)
    assert "DEDUP_OK" in out


def test_fetch_rows_shard_boundary_ids_route_correctly():
    """Ids sitting exactly on shard boundaries (first/last row of every
    worker's block), heavily duplicated, must route to the right owner and
    dedup to one wire slot each — the `owner = id // rows` bucketing at the
    edges is exactly where an off-by-one would hide."""
    out = run_forced("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.core.generation import fetch_rows
        from repro.launch.mesh import make_mesh

        W, rows, d = 8, 16, 3
        mesh = make_mesh((W,), ("data",))
        table = np.arange(W * rows * d, dtype=np.float32).reshape(W * rows, d)
        # first and last row of every shard, plus global extremes, duplicated
        edges = [k * rows for k in range(W)] + [k * rows + rows - 1 for k in range(W)]
        ids = np.asarray(edges * 3 + [0, W * rows - 1], dtype=np.int32)
        out, stats = shard_map(
            lambda t, i: fetch_rows(t, i, "data", return_stats=True),
            mesh=mesh, in_specs=(P("data"), P()), out_specs=P(),
            check_rep=False)(jnp.asarray(table), jnp.asarray(ids))
        np.testing.assert_array_equal(np.asarray(out), table[ids])
        assert int(stats.n_unique) == len(set(edges))
        assert int(stats.n_dropped) == 0
        print("BOUNDARY_OK")
    """)
    assert "BOUNDARY_OK" in out


#: the cross-mode differential matrix: every cache placement x every
#: associativity x every worker count x every probe wire format, each
#: cell checked bit-for-bit against the uncached oracle (the raw host
#: feature table) AND for training-loss equality — the single harness
#: that replaces the old scattered per-mode bit-identity tests
CACHE_MODES = ("none", "replicated", "sharded", "tiered")
CACHE_WIRES = ("dense", "compact")


@pytest.mark.parametrize("w", [1, 2, 4])
@pytest.mark.parametrize("assoc", [1, 2, 4])
@pytest.mark.parametrize("wire", CACHE_WIRES)
@pytest.mark.parametrize("mode", CACHE_MODES)
def test_cross_mode_differential_matrix(mode, wire, assoc, w):
    """THE cache contract, swept as one property over the whole design
    space: for every mode x assoc x W x wire cell, the generation
    engine's fetched feature rows are bit-identical to the uncached
    oracle (features gathered straight from the host table), padded
    slots are exactly zero, labels match, nothing drops, and the
    training loss computed from the generated batch equals the loss
    computed from the oracle batch bit-for-bit.  Recurring rngs warm the
    cache so every cached cell also proves hits appear without
    perturbing the rows; the compact cells run with a DELIBERATELY tiny
    hit_cap so demotion itself is inside the bit-identity sweep."""
    if wire == "compact" and (mode in ("none", "replicated") or w == 1):
        pytest.skip("no shard-probe round to compact in this cell")
    out = run_forced(f"""
        MODE, ASSOC, W, WIRE = {mode!r}, {assoc}, {w}, {wire!r}
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.graph.synthetic import powerlaw_graph, node_features, node_labels
        from repro.core.partition import partition_edges
        from repro.core.balance import balance_table
        from repro.core.feature_cache import CacheConfig
        from repro.core.generation import make_distributed_generator
        from repro.launch.mesh import make_mesh
        from repro.models import gcn as gcn_mod

        N, D, C = 600, 8, 7
        mesh = make_mesh((W,), ("data",))
        g = powerlaw_graph(N, avg_degree=8, n_hot=3, hot_degree=200, seed=0)
        part = partition_edges(g, W)
        X = node_features(N, D); Y = node_labels(N, C)
        table = balance_table(np.arange(N), W, seed=0)
        seeds = jnp.asarray(table.per_worker[:, :6])
        # compact cells pin hit_cap=4 — far below the warm hit count, so
        # holder-side demotion provably fires inside the identity sweep
        cc = None if MODE == "none" else CacheConfig(
            128, admit=1, assoc=ASSOC, mode=MODE,
            l1_rows=32 if MODE == "tiered" else 0, l1_promote=2,
            wire=WIRE, hit_cap=4 if WIRE == "compact" else 0)
        out = make_distributed_generator(mesh, part, X, Y, fanouts=(5, 3),
                                         cache_cfg=cc)
        gen, dev = out[0], out[1]
        cache = out[2] if cc is not None else None
        mcfg = dataclasses.replace(get_config("graphgen-gcn"), gcn_in_dim=D,
                                   gcn_hidden=16, n_classes=C, fanouts=(5, 3))
        params = gcn_mod.init_gcn(mcfg, jax.random.PRNGKey(1))
        loss_fn = jax.jit(gcn_mod.gcn_loss)
        hits = 0
        for t in range(3):
            rng = jax.random.PRNGKey(t % 2)   # recurring ids warm the cache
            if cache is None:
                b = gen(dev, seeds, rng)
            else:
                b, cache = gen(dev, seeds, rng, cache)
            b = jax.tree.map(np.asarray, b)
            assert b.n_dropped.sum() == 0, b.n_dropped
            # --- bit-identical rows vs the uncached oracle (the table) ---
            np.testing.assert_array_equal(b.x_seed, X[b.seeds])
            oracle_hops = []
            for h, m, x in zip(b.hops, b.masks, b.x_hops):
                want = X[h] * m[..., None]          # padded slots exactly 0
                np.testing.assert_array_equal(x, want)
                oracle_hops.append(want)
            assert (b.labels == Y[b.seeds]).all()
            # --- bit-identical training loss vs the oracle batch ---------
            oracle = b._replace(x_seed=X[b.seeds],
                                x_hops=tuple(oracle_hops))
            l_got = np.asarray(loss_fn(params, jax.tree.map(jnp.asarray, b)))
            l_want = np.asarray(loss_fn(params,
                                        jax.tree.map(jnp.asarray, oracle)))
            assert l_got.tobytes() == l_want.tobytes(), (l_got, l_want)
            assert np.isfinite(l_got)
            hits += int(b.n_cache_hits.sum())
        if cc is not None:
            assert hits > 0, "cache never warmed on recurring ids"
        else:
            assert hits == 0
        print("MATRIX_OK", MODE, ASSOC, W, WIRE, hits)
    """, devices=w)
    assert "MATRIX_OK" in out


#: the host-store (L3) extension of the matrix: the same bit-identity
#: and loss-equality contract, but with the feature table in host RAM —
#: the generation step emits staged misses, the HostFeatureStore gathers
#: them, and patch_batch must reconstruct the exact device-resident rows
HOST_MODES = ("none", "replicated", "sharded", "tiered")


@pytest.mark.parametrize("w", [1, 4])
@pytest.mark.parametrize("mode", HOST_MODES)
def test_host_store_differential_cells(mode, w):
    """Host-store cells of the differential matrix: for every cache mode
    x W, generation with ``feature_store="host"`` — after the L3 gather
    lands and ``patch_batch`` fills the holes — produces feature rows
    bit-identical to the uncached oracle (the raw table), padded slots
    exactly zero, labels equal, zero drops, and a training loss equal
    bit-for-bit to the oracle batch's.  Recurring rngs prove the
    deferred-admission round warms the cache (hits appear by step 3
    without perturbing a single bit); the store's byte telemetry must
    account for the staging rounds."""
    out = run_forced(f"""
        MODE, W = {mode!r}, {w}
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.graph.synthetic import powerlaw_graph, node_features, node_labels
        from repro.core.partition import partition_edges
        from repro.core.balance import balance_table
        from repro.core.feature_cache import CacheConfig
        from repro.core.generation import make_distributed_generator
        from repro.core.host_store import empty_admit, patch_batch
        from repro.launch.mesh import make_mesh
        from repro.models import gcn as gcn_mod

        N, D, C = 600, 8, 7
        mesh = make_mesh((W,), ("data",))
        g = powerlaw_graph(N, avg_degree=8, n_hot=3, hot_degree=200, seed=0)
        part = partition_edges(g, W)
        X = node_features(N, D); Y = node_labels(N, C)
        table = balance_table(np.arange(N), W, seed=0)
        seeds = jnp.asarray(table.per_worker[:, :6])
        cc = None if MODE == "none" else CacheConfig(
            128, admit=1, assoc=2, mode=MODE,
            l1_rows=32 if MODE == "tiered" else 0, l1_promote=2)
        out = make_distributed_generator(mesh, part, X, Y, fanouts=(5, 3),
                                         cache_cfg=cc, feature_store="host")
        if cc is None:
            gen, dev, store = out
            cache = None
        else:
            gen, dev, store, cache = out
        patch = jax.jit(patch_batch)
        mcfg = dataclasses.replace(get_config("graphgen-gcn"), gcn_in_dim=D,
                                   gcn_hidden=16, n_classes=C, fanouts=(5, 3))
        params = gcn_mod.init_gcn(mcfg, jax.random.PRNGKey(1))
        loss_fn = jax.jit(gcn_mod.gcn_loss)
        adm = empty_admit(W, D)
        hits = 0
        for t in range(3):
            rng = jax.random.PRNGKey(t % 2)   # recurring ids warm the cache
            if cache is None:
                b, req = gen(dev, seeds, rng)
            else:
                b, cache, req = gen(dev, seeds, rng, cache, *adm)
            landed = store.issue(req.ids).rows()
            adm = (req.ids, landed)           # next step's deferred admission
            b = jax.tree.map(np.asarray, patch(b, req, landed))
            assert b.n_dropped.sum() == 0, b.n_dropped
            # --- bit-identical rows vs the uncached oracle (the table) ---
            np.testing.assert_array_equal(b.x_seed, X[b.seeds])
            oracle_hops = []
            for h, m, x in zip(b.hops, b.masks, b.x_hops):
                want = X[h] * m[..., None]          # padded slots exactly 0
                np.testing.assert_array_equal(x, want)
                oracle_hops.append(want)
            assert (b.labels == Y[b.seeds]).all()
            # --- bit-identical training loss vs the oracle batch ---------
            oracle = b._replace(x_seed=X[b.seeds],
                                x_hops=tuple(oracle_hops))
            l_got = np.asarray(loss_fn(params, jax.tree.map(jnp.asarray, b)))
            l_want = np.asarray(loss_fn(params,
                                        jax.tree.map(jnp.asarray, oracle)))
            assert l_got.tobytes() == l_want.tobytes(), (l_got, l_want)
            assert np.isfinite(l_got)
            hits += int(b.n_cache_hits.sum())
        if cc is not None:
            assert hits > 0, "deferred admission never warmed the cache"
        else:
            assert hits == 0
        assert store.bytes_issued > 0
        print("HOST_MATRIX_OK", MODE, W, hits)
    """, devices=w)
    assert "HOST_MATRIX_OK" in out


def test_host_fetch_conservation_empty_and_all_miss():
    """The L3 conservation contract at the fetch level on a W=4 mesh, in
    the two corners that break sloppy accounting: an ALL-MISS cold batch
    (every distinct id must surface as an L3 staging hit, or — when the
    staging buffer is deliberately undersized — as a counted miss AND a
    counted drop) and an EMPTY batch (all counters zero, while a pending
    landed buffer still gets admitted).  Every cell checks
    ``l1 + local + shard + l3 + misses == distinct`` per worker."""
    out = run_forced("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.experimental.shard_map import shard_map
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.core.feature_cache import CacheConfig, init_cache_state
        from repro.core.generation import fetch_rows
        from repro.launch.mesh import make_mesh

        W, d, R = 4, 3, 24
        mesh = make_mesh((W,), ("data",))
        spec = NamedSharding(mesh, P("data"))

        def make_run(cfg, capacity, r):
            def worker(i, cc, aid, arows):
                cc = jax.tree.map(lambda a: a[0], cc)
                out, cc, fs, cs, req = fetch_rows(
                    None, i[0], "data", capacity=capacity, cache=cc,
                    cache_cfg=cfg, store="host", feat_dim=d,
                    host_admit=(aid[0], arows[0]))
                pack = lambda t: jax.tree.map(lambda a: a[None], t)
                return out[None], pack(cc), pack((fs, cs)), pack(req)
            return jax.jit(shard_map(
                worker, mesh=mesh,
                in_specs=(P("data"),) * 4, out_specs=(P("data"),) * 4,
                check_rep=False))

        for mode in ("replicated", "sharded", "tiered"):
            cfg = CacheConfig(32, admit=1, assoc=2, mode=mode,
                              l1_rows=16 if mode == "tiered" else 0,
                              l1_promote=2, store="host").validated()
            # distinct per-worker ids, cold cache: all-miss
            ids = np.stack([np.arange(R) + 100 * k for k in range(W)]
                           ).astype(np.int32)
            no_admit = (jnp.full((W, 1), -1, jnp.int32),
                        jnp.zeros((W, 1, d), jnp.float32))

            def conserve(cs, distinct):
                l1 = np.asarray(cs.n_l1_hits); loc = np.asarray(cs.n_local_hits)
                sh = np.asarray(cs.n_shard_hits); l3 = np.asarray(cs.n_l3_hits)
                ms = np.asarray(cs.n_misses)
                assert (l1 + loc + sh + l3 + ms == distinct).all(), \\
                    (mode, l1, loc, sh, l3, ms, distinct)
                return l3, ms

            # ample staging: every distinct id is an L3 hit, zero drops
            run = make_run(cfg, 2 * R, R)
            state = jax.device_put(init_cache_state(cfg, d, W), spec)
            out, state, (fs, cs), req = run(
                jnp.asarray(ids), state, *[jax.device_put(a, spec)
                                           for a in no_admit])
            l3, ms = conserve(cs, R)
            assert (l3 == R).all() and (ms == 0).all()
            assert int(np.asarray(fs.n_dropped).sum()) == 0
            assert (np.asarray(req.ids) >= 0).sum() == W * R
            assert int(np.asarray(fs.host_gather_bytes).sum()) > 0

            # undersized staging: the overflow is COUNTED miss + drop
            cap = 4
            run = make_run(cfg, cap, R)
            state = jax.device_put(init_cache_state(cfg, d, W), spec)
            out, state, (fs, cs), req = run(
                jnp.asarray(ids), state, *[jax.device_put(a, spec)
                                           for a in no_admit])
            l3, ms = conserve(cs, R)
            assert (l3 == cap).all() and (ms == R - cap).all()
            assert (np.asarray(fs.n_dropped) == R - cap).all()

            # empty batch: all counters zero, deferred admission still runs
            run = make_run(cfg, 4, 0)
            state = jax.device_put(init_cache_state(cfg, d, W), spec)
            admit = (jnp.asarray(np.stack(
                         [[7 + k, -1] for k in range(W)]).astype(np.int32)),
                     jnp.ones((W, 2, d), jnp.float32))
            out, state, (fs, cs), req = run(
                jnp.zeros((W, 0), jnp.int32), state,
                *[jax.device_put(a, spec) for a in admit])
            l3, ms = conserve(cs, 0)
            assert out.shape == (W, 0, d)
            assert int(np.asarray(fs.n_dropped).sum()) == 0
            assert int(np.asarray(cs.n_inserted).sum()) >= W, \\
                "pending landed rows were not admitted on the empty step"
        print("L3_CONSERVATION_OK")
    """, devices=4)
    assert "L3_CONSERVATION_OK" in out


def test_cached_fetch_all_modes_bit_identical_w4():
    """Fetch-level complement of the matrix on one W=4 mesh: random request
    mixes against every (mode, assoc) cell return rows bit-identical to
    the raw table with zero drops, the hit split stays consistent
    (l1 + local + shard == hits), and the conservation invariant
    l1 + local + shard + misses == distinct holds per worker."""
    out = run_forced("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.experimental.shard_map import shard_map
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.core.feature_cache import CacheConfig, init_cache_state
        from repro.core.generation import fetch_rows
        from repro.launch.mesh import make_mesh

        W, rows_pw, d = 4, 32, 3
        mesh = make_mesh((W,), ("data",))
        table = np.arange(W * rows_pw * d,
                          dtype=np.float32).reshape(W * rows_pw, d)
        spec = NamedSharding(mesh, P("data"))
        cells = [("replicated", 1), ("replicated", 4), ("sharded", 1),
                 ("sharded", 2), ("sharded", 4), ("tiered", 1),
                 ("tiered", 2), ("tiered", 4)]
        for trial, (mode, assoc) in enumerate(cells):
            cfg = CacheConfig(32, admit=1, assoc=assoc, mode=mode,
                              l1_rows=16 if mode == "tiered" else 0,
                              l1_promote=2).validated()

            def worker(t, i, cc):
                cc = jax.tree.map(lambda a: a[0], cc)
                out, cc, fs, cs = fetch_rows(t, i[0], "data", cache=cc,
                                             cache_cfg=cfg)
                return (out[None], jax.tree.map(lambda a: a[None], cc),
                        jax.tree.map(lambda a: a[None], (fs, cs)))

            run = jax.jit(shard_map(
                worker, mesh=mesh,
                in_specs=(P("data"), P("data"), P("data")),
                out_specs=(P("data"), P("data"), P("data")),
                check_rep=False))
            state = jax.device_put(init_cache_state(cfg, d, W), spec)
            rng = np.random.default_rng(trial)
            total_hits = total_l1 = 0
            for it in range(6):
                ids = rng.integers(0, W * rows_pw, (W, 48)).astype(np.int32)
                out, state, (fs, cs) = run(
                    jnp.asarray(table), jax.device_put(jnp.asarray(ids), spec),
                    state)
                np.testing.assert_array_equal(
                    np.asarray(out).reshape(W, 48, d),
                    table[ids])
                assert int(np.asarray(fs.n_dropped).sum()) == 0
                l1 = np.asarray(cs.n_l1_hits)
                loc = np.asarray(cs.n_local_hits)
                sh = np.asarray(cs.n_shard_hits)
                ms = np.asarray(cs.n_misses)
                assert (l1 + loc + sh == np.asarray(cs.n_hits)).all()
                distinct = np.asarray(
                    [len(np.unique(ids[k])) for k in range(W)])
                assert (l1 + loc + sh + ms == distinct).all(), (mode, assoc)
                if mode != "tiered":
                    assert (l1 == 0).all()
                total_hits += int(np.asarray(cs.n_hits).sum())
                total_l1 += int(l1.sum())
            assert total_hits > 0, (mode, assoc)
            if mode == "tiered":
                assert total_l1 > 0, "L1 never promoted"
        print("ALL_MODES_FETCH_OK")
    """, devices=4)
    assert "ALL_MODES_FETCH_OK" in out


def test_sharded_cache_beats_replicated_capacity():
    """The reason sharding exists: at equal per-worker cache_rows over a
    shared hot set larger than one replica, the W-sharded cache serves
    strictly more unique hits (effective capacity x W) AND a remote-shard
    hit population appears."""
    out = run_forced("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.experimental.shard_map import shard_map
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.core.feature_cache import CacheConfig, init_worker_caches
        from repro.core.generation import fetch_rows
        from repro.launch.mesh import make_mesh

        W, rows_pw, d, c = 4, 64, 2, 32
        mesh = make_mesh((W,), ("data",))
        table = np.arange(W * rows_pw * d,
                          dtype=np.float32).reshape(W * rows_pw, d)
        spec = NamedSharding(mesh, P("data"))
        rng = np.random.default_rng(0)
        # a hot set of ~3*c ids: one 32-row replica can never hold it, the
        # 4 x 32 sharded aggregate can
        hot = rng.choice(W * rows_pw, size=3 * c, replace=False)
        streams = [np.stack([rng.choice(hot, size=96) for _ in range(W)])
                   .astype(np.int32) for _ in range(10)]

        def run_mode(mode):
            cfg = CacheConfig(c, admit=1, assoc=2, mode=mode)

            def worker(t, i, cc):
                cc = jax.tree.map(lambda a: a[0], cc)
                out, cc, fs, cs = fetch_rows(t, i[0], "data", cache=cc,
                                             cache_cfg=cfg)
                return (out[None], jax.tree.map(lambda a: a[None], cc),
                        jax.tree.map(lambda a: a[None], (fs, cs)))

            run = jax.jit(shard_map(
                worker, mesh=mesh,
                in_specs=(P("data"), P("data"), P("data")),
                out_specs=(P("data"), P("data"), P("data")),
                check_rep=False))
            state = jax.device_put(init_worker_caches(c, d, W), spec)
            hits = shard_hits = 0
            for ids in streams:
                out, state, (fs, cs) = run(
                    jnp.asarray(table),
                    jax.device_put(jnp.asarray(ids), spec), state)
                np.testing.assert_array_equal(
                    np.asarray(out).reshape(W, 96, d), table[ids])
                hits += int(np.asarray(cs.n_hits).sum())
                shard_hits += int(np.asarray(cs.n_shard_hits).sum())
            return hits, shard_hits

        rep_hits, rep_shard = run_mode("replicated")
        sh_hits, sh_shard = run_mode("sharded")
        assert rep_shard == 0
        assert sh_shard > 0
        assert sh_hits > rep_hits, (sh_hits, rep_hits)
        print("SHARDED_CAPACITY_OK", rep_hits, sh_hits)
    """, devices=4)
    assert "SHARDED_CAPACITY_OK" in out


def test_tiered_cached_generation_multiworker_warms_l1():
    """End-to-end: the full generation engine with the TIERED cache on 8
    workers — the rows stay bit-identical to the uncached generator under
    the same rng (the matrix covers the sweep; this pins the 8-worker
    scale), the hit rate climbs on recurring ids, AND a promoted-L1 hit
    population appears, serving part of the stream with zero network."""
    out = run_forced("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.graph.synthetic import powerlaw_graph, node_features, node_labels
        from repro.core.partition import partition_edges
        from repro.core.balance import balance_table
        from repro.core.feature_cache import CacheConfig
        from repro.core.generation import make_distributed_generator
        from repro.launch.mesh import make_mesh

        W = 8
        mesh = make_mesh((W,), ("data",))
        g = powerlaw_graph(2000, avg_degree=8, n_hot=3, hot_degree=500, seed=0)
        part = partition_edges(g, W)
        X = node_features(2000, 16); Y = node_labels(2000, 7)
        table = balance_table(np.arange(2000), W, seed=0)
        seeds = jnp.asarray(table.per_worker[:, :16])
        gen_nc, dev_nc = make_distributed_generator(mesh, part, X, Y,
                                                    fanouts=(8, 4))
        gen_c, dev_c, cache = make_distributed_generator(
            mesh, part, X, Y, fanouts=(8, 4),
            cache_cfg=CacheConfig(256, admit=1, assoc=2, mode="tiered",
                                  l1_rows=64, l1_promote=2))
        hit_rates = []
        for t in range(5):
            rng = jax.random.PRNGKey(t % 2)   # recurring rngs -> recurring ids
            b_nc = gen_nc(dev_nc, seeds, rng)
            b_c, cache = gen_c(dev_c, seeds, rng, cache)
            np.testing.assert_array_equal(np.asarray(b_nc.x_seed),
                                          np.asarray(b_c.x_seed))
            for a, b in zip(b_nc.x_hops, b_c.x_hops):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
            assert (np.asarray(b_c.labels) == np.asarray(b_nc.labels)).all()
            assert np.asarray(b_c.n_dropped).sum() == 0
            hits = np.asarray(b_c.n_cache_hits).sum()
            total = hits + np.asarray(b_c.n_cache_misses).sum()
            hit_rates.append(hits / total)
        assert hit_rates[0] == 0.0                   # cold cache
        assert hit_rates[-1] > 0.5, hit_rates        # recurring ids now cached
        # the promoted head is resident in (at least one) L1 replica
        assert int(np.asarray(cache.l1.keys >= 0).sum()) > 0
        print("TIERED_GEN_OK", [round(h, 3) for h in hit_rates])
    """)
    assert "TIERED_GEN_OK" in out


def test_generation_three_hop_multiworker():
    """The depth-3 engine on 8 workers: chained masks, valid neighbors,
    correct features at every level."""
    out = run_forced("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.graph.synthetic import powerlaw_graph, node_features, node_labels
        from repro.core.partition import partition_edges
        from repro.core.balance import balance_table
        from repro.core.generation import make_distributed_generator
        from repro.launch.mesh import make_mesh

        W = 8
        mesh = make_mesh((W,), ("data",))
        g = powerlaw_graph(1500, avg_degree=8, n_hot=3, hot_degree=300, seed=1)
        part = partition_edges(g, W)
        X = node_features(1500, 8); Y = node_labels(1500, 5)
        table = balance_table(np.arange(1500), W, seed=0)
        seeds = table.per_worker[:, :8]
        gen, dev = make_distributed_generator(mesh, part, X, Y,
                                              fanouts=(5, 4, 3))
        b = jax.tree.map(np.asarray,
                         gen(dev, jnp.asarray(seeds), jax.random.PRNGKey(0)))
        assert [h.shape[1:] for h in b.hops] == [(5,), (5, 4), (5, 4, 3)]
        adj = {v: set(g.indices[g.indptr[v]:g.indptr[v+1]]) for v in range(1500)}
        for i, s in enumerate(b.seeds):
            for j in range(5):
                if b.masks[0][i, j]:
                    assert b.hops[0][i, j] in adj[s]
        for l in range(1, 3):
            assert not (b.masks[l] & ~b.masks[l-1][..., None]).any()
            ml = b.masks[l]
            if ml.any():
                assert np.abs(b.x_hops[l][ml] - X[b.hops[l][ml]]).max() == 0
            if (~ml).any():
                assert np.abs(b.x_hops[l][~ml]).max() == 0
        assert (b.labels == Y[b.seeds]).all()
        assert b.n_dropped.shape == (W,)
        print("THREE_HOP_OK")
    """)
    assert "THREE_HOP_OK" in out


def test_calibration_probes_cached_generator_cold():
    """The slack ladder probes the CONFIGURED (cached) generator with a
    cold cache per rung, and the chosen slack is drop-free from cold —
    a rung warmed by its predecessor would understate cold-start miss
    traffic and pick a slack that drops on the real run's first steps."""
    out = run_forced("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.graph.synthetic import powerlaw_graph, node_features, node_labels
        from repro.core.balance import balance_table
        from repro.core.feature_cache import CacheConfig, init_worker_caches
        from repro.core.generation import (make_distributed_generator,
                                           make_generator_fn)
        from repro.core.partition import partition_edges
        from repro.launch.mesh import make_mesh
        from repro.launch.train import calibrate_capacity_slack

        W, n, dim = 4, 2000, 8
        mesh = make_mesh((W,), ("data",))
        g = powerlaw_graph(n, avg_degree=8, n_hot=3, hot_degree=400, seed=0)
        part = partition_edges(g, W)
        X = node_features(n, dim); Y = node_labels(n, 5)
        table = balance_table(np.arange(n), W, seed=0)
        cfg = CacheConfig(256, admit=2, assoc=2, mode="sharded")
        _, dev = make_distributed_generator(mesh, part, X, Y, fanouts=(6, 4))
        probes = [(jnp.asarray(table.per_worker[:, t*8:(t+1)*8]),
                   jax.random.PRNGKey(t)) for t in range(2)]
        slack = calibrate_capacity_slack(mesh, dev, (6, 4), probes,
                                         cache_cfg=cfg)
        assert slack in (0.25, 0.5, 1.0, 1.5, 2.0), slack
        # the chosen slack must be drop-free from a COLD cache
        gen = jax.jit(make_generator_fn(mesh, fanouts=(6, 4),
                                        capacity_slack=slack, cache_cfg=cfg))
        cache = jax.device_put(init_worker_caches(256, dim, W),
                               NamedSharding(mesh, P("data")))
        for seeds, rng in probes:
            batch, cache = gen(dev, seeds, rng, cache)
            assert int(np.asarray(batch.n_dropped).sum()) == 0
        print("CALIBRATION_COLD_OK", slack)
    """, devices=4)
    assert "CALIBRATION_COLD_OK" in out


def test_hit_cap_calibration_ladder_and_dense_fallback():
    """The compact-wire calibration: the ladder returns a compact config
    whose hit_cap demotes nothing on the probes (re-checked from cold),
    and a ladder whose every rung demotes falls back to the dense wire —
    the rung that can never demote."""
    out = run_forced("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.graph.synthetic import powerlaw_graph, node_features, node_labels
        from repro.core.balance import balance_table
        from repro.core.feature_cache import CacheConfig, init_cache_state
        from repro.core.generation import (make_distributed_generator,
                                           make_generator_fn)
        from repro.core.partition import partition_edges
        from repro.launch.mesh import make_mesh
        from repro.launch.train import calibrate_probe_hit_cap

        W, n, dim = 4, 2000, 8
        mesh = make_mesh((W,), ("data",))
        g = powerlaw_graph(n, avg_degree=8, n_hot=3, hot_degree=400, seed=0)
        part = partition_edges(g, W)
        X = node_features(n, dim); Y = node_labels(n, 5)
        table = balance_table(np.arange(n), W, seed=0)
        cfg = CacheConfig(256, admit=1, assoc=2, mode="sharded",
                          wire="compact")
        _, dev = make_distributed_generator(mesh, part, X, Y, fanouts=(6, 4))
        # recurring seeds across probes: the cache warms and the probe
        # round produces real hits for the ladder to bound
        probes = [(jnp.asarray(table.per_worker[:, :8]),
                   jax.random.PRNGKey(0)) for _ in range(3)]
        cal = calibrate_probe_hit_cap(mesh, dev, (6, 4), probes, 2.0, cfg)
        assert cal.wire == "compact" and cal.hit_cap > 0, cal
        # the calibrated config demotes nothing from a cold start
        gen = jax.jit(make_generator_fn(mesh, fanouts=(6, 4),
                                        capacity_slack=2.0, cache_cfg=cal))
        cache = jax.device_put(init_cache_state(cal, dim, W),
                               NamedSharding(mesh, P("data")))
        for seeds, rng in probes:
            batch, cache = gen(dev, seeds, rng, cache)
            assert int(np.asarray(batch.n_probe_demoted).sum()) == 0
            assert int(np.asarray(batch.n_dropped).sum()) == 0
        # a ladder whose only rung is ~zero must demote and fall back
        dense = calibrate_probe_hit_cap(mesh, dev, (6, 4), probes, 2.0,
                                        cfg, ladder=(0.0001,))
        assert dense.wire == "dense" and dense.hit_cap == 0, dense
        print("HIT_CAP_CAL_OK", cal.hit_cap)
    """, devices=4)
    assert "HIT_CAP_CAL_OK" in out


def test_elastic_checkpoint_reshard():
    """Save on 4 workers, restore on 2 (node loss) — values identical."""
    out = run_forced("""
        import jax, jax.numpy as jnp, numpy as np, tempfile
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.mesh import make_mesh
        from repro.train import checkpoint as ckpt

        d = tempfile.mkdtemp()
        mesh4 = make_mesh((4,), ("data",))
        tree = {"w": jax.device_put(jnp.arange(64.).reshape(8, 8),
                                    NamedSharding(mesh4, P("data", None))),
                "b": jnp.ones((3,))}
        ckpt.save(d, 7, tree)
        mesh2 = make_mesh((2,), ("data",))
        shards = {"w": NamedSharding(mesh2, P("data", None)),
                  "b": NamedSharding(mesh2, P())}
        like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
        restored = ckpt.restore(d, 7, like, shardings=shards)
        np.testing.assert_array_equal(np.asarray(restored["w"]),
                                      np.arange(64.).reshape(8, 8))
        assert restored["w"].sharding.mesh.shape["data"] == 2
        print("ELASTIC_OK")
    """)
    assert "ELASTIC_OK" in out


def test_grad_sync_tree_equals_default():
    out = run_forced("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import make_mesh
        from repro.train.train_loop import make_shardmap_grad_sync

        mesh = make_mesh((8,), ("data",))
        grads = {"a": jnp.arange(24.).reshape(8, 3), "b": jnp.ones((8, 2))}
        sync = make_shardmap_grad_sync(mesh)
        out = sync(grads)
        # replicated input: sum of 8 copies / 8 == identity
        np.testing.assert_allclose(np.asarray(out["a"]), np.asarray(grads["a"]))
        np.testing.assert_allclose(np.asarray(out["b"]), np.asarray(grads["b"]))
        print("SYNC_OK")
    """)
    assert "SYNC_OK" in out


#: the serving extension of the matrix: the frozen (read-mostly) serve
#: generator — the forward-only form the GraphServer compiles — must
#: produce batches and GCN forward logits bit-identical to the uncached
#: oracle, while serving real hits from the state warmed by the mutable
#: generator
SERVE_MODES = ("replicated", "sharded", "tiered")


@pytest.mark.parametrize("w", [1, 4])
@pytest.mark.parametrize("mode", SERVE_MODES)
def test_serve_frozen_differential_cells(mode, w):
    """The serving contract, per mode x W cell: warm a cache with the
    mutable training generator, freeze it (serve_view), and check the
    forward-only serve generator's batch is bit-identical to the
    uncached oracle (rows from the raw table, padded slots exactly
    zero, labels match) AND the GCN forward logits — what serve()
    argmaxes — are bit-identical to the oracle batch's.  The frozen
    cells must also serve warm hits: a serve path that never hits
    would pass bit-identity trivially by fetching everything."""
    out = run_forced(f"""
        MODE, W = {mode!r}, {w}
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.graph.synthetic import powerlaw_graph, node_features, node_labels
        from repro.core.partition import partition_edges
        from repro.core.balance import balance_table
        from repro.core.feature_cache import CacheConfig
        from repro.core.generation import (make_distributed_generator,
                                           make_generator_fn)
        from repro.launch.mesh import make_mesh
        from repro.models import gcn as gcn_mod

        N, D, C = 600, 8, 7
        mesh = make_mesh((W,), ("data",))
        g = powerlaw_graph(N, avg_degree=8, n_hot=3, hot_degree=200, seed=0)
        part = partition_edges(g, W)
        X = node_features(N, D); Y = node_labels(N, C)
        table = balance_table(np.arange(N), W, seed=0)
        seeds = jnp.asarray(table.per_worker[:, :6])
        cc = CacheConfig(128, admit=1, assoc=2, mode=MODE,
                         l1_rows=32 if MODE == "tiered" else 0, l1_promote=2)
        gen_mut, dev, cache = make_distributed_generator(
            mesh, part, X, Y, fanouts=(5, 3), cache_cfg=cc)
        # warm on the ids the serve requests will replay
        for t in range(3):
            _, cache = gen_mut(dev, seeds, jax.random.PRNGKey(t % 2), cache)
        gen_frozen = jax.jit(make_generator_fn(
            mesh, fanouts=(5, 3), cache_cfg=cc.serve_view()))
        mcfg = dataclasses.replace(get_config("graphgen-gcn"), gcn_in_dim=D,
                                   gcn_hidden=16, n_classes=C, fanouts=(5, 3))
        params = gcn_mod.init_gcn(mcfg, jax.random.PRNGKey(1))
        fwd = jax.jit(gcn_mod.gcn_forward)
        hits = 0
        for t in range(3):
            rng = jax.random.PRNGKey(t % 2)   # replay the warmed ids
            b = jax.tree.map(np.asarray, gen_frozen(dev, seeds, rng, cache))
            assert b.n_dropped.sum() == 0, b.n_dropped
            np.testing.assert_array_equal(b.x_seed, X[b.seeds])
            oracle_hops = []
            for h, m, x in zip(b.hops, b.masks, b.x_hops):
                want = X[h] * m[..., None]        # padded slots exactly 0
                np.testing.assert_array_equal(x, want)
                oracle_hops.append(want)
            assert (b.labels == Y[b.seeds]).all()
            oracle = b._replace(x_seed=X[b.seeds], x_hops=tuple(oracle_hops))
            l_got = np.asarray(fwd(params, jax.tree.map(jnp.asarray, b)))
            l_want = np.asarray(fwd(params, jax.tree.map(jnp.asarray, oracle)))
            assert l_got.tobytes() == l_want.tobytes()
            hits += int(b.n_cache_hits.sum())
        assert hits > 0, "frozen serve cells must hit the warmed state"
        print("SERVE_OK", MODE, W, hits)
    """, devices=w)
    assert "SERVE_OK" in out
