"""Autotuner contract tests (``repro.launch.autotune``).

Three layers:

* pure-python trace/model contracts on SYNTHETIC traces built so
  ``Trace.violations()`` holds by construction (byte fields come from
  ``static_wire_bytes`` — the same formulas the live telemetry obeys):
  consistency checking, the cold-half/short-window edges, and the
  property-tested prediction contract (bytes monotone in capacity, step
  time positive/finite over the whole grid, bit-identical replay);

* the ``autotune_gcn`` fallback mapping with a monkeypatched
  instrumented run: corrupted trace -> rejected, short trace ->
  rejected, live validator drop -> rejected, clean run -> accepted;

* a W=4 differential subprocess: a REAL trace's warm telemetry must be
  reproduced exactly by the model's anchor prediction, and the
  predicted step time must hold against a live re-measure within the
  validator tolerance.  Plus the launcher degradation path: ``--autotune``
  with a too-short window warns and falls back to the ladders.

The property tests run under ``tests/_hypothesis_stub.py`` when
hypothesis is not installed.
"""
from __future__ import annotations

import math
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import repro.launch.autotune as at
from repro.core.config import TuneCandidate
from repro.core.feature_cache import CacheConfig

_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


# ---------------------------------------------------------------------------
# synthetic traces: consistent by construction
# ---------------------------------------------------------------------------

def _tc(mode="sharded", store="device", fanouts=(3, 2), w=4, b=8,
        rows=256, l1=32, assoc=2, hit_cap=9, slack=1.0,
        wire="compact") -> at.TracedConfig:
    cached = mode is not None
    return at.TracedConfig(
        fanouts=tuple(fanouts), n_workers=w, batch_per_worker=b,
        feat_dim=16, itemsize=4, mode=mode,
        cache_rows=rows if cached else 0,
        l1_rows=l1 if mode == "tiered" else 0,
        assoc=assoc if cached else 1, wire=wire,
        hit_cap=hit_cap if cached else 0,
        capacity_slack=slack, store=store)


def _cache_cfg(tc: at.TracedConfig):
    if tc.mode is None:
        return None
    return CacheConfig(n_rows=tc.cache_rows, mode=tc.mode, assoc=tc.assoc,
                       l1_rows=tc.l1_rows, wire=tc.wire,
                       hit_cap=tc.hit_cap, store=tc.store).validated()


def _trace(tc: at.TracedConfig, steps=8, hit_frac=0.5,
           wall=2e-3) -> at.Trace:
    """A synthetic instrumented window whose records satisfy every
    conservation identity: tier hits sum to hits, unique == routed or
    staged, and the byte fields are the static wire formulas verbatim
    (first step on the host-admit empty_admit prologue buffer)."""
    probe, gather, admit = at.static_wire_bytes(tc, tc.candidate())
    w, d, item = tc.n_workers, tc.feat_dim, tc.itemsize
    admit0 = w * 1 * (4 + d * item) if admit else 0
    r_all = w * at._requests_per_worker(tc.fanouts, tc.batch_per_worker)
    cached = tc.mode is not None and tc.cache_rows > 0
    recs = []
    for t in range(steps):
        distinct = max(r_all // 2, 1)
        hits = int(distinct * hit_frac) if cached else 0
        rest = distinct - hits
        l1 = hits // 3 if tc.mode == "tiered" else 0
        local = (hits - l1) // 2
        shard = hits - l1 - local
        l3, misses = (rest, 0) if tc.store == "host" else (0, rest)
        recs.append(at.TraceRecord(
            n_requests=r_all, n_unique=l3 if tc.store == "host" else misses,
            n_dropped=0,
            probe_round_bytes=w * (probe + (admit0 if t == 0 else admit)),
            host_gather_bytes=w * gather,
            n_hits=hits, n_misses=misses, n_l1_hits=l1, n_local_hits=local,
            n_shard_hits=shard, n_l3_hits=l3, n_probe_demoted=0,
            probe_hit_peak=max(hits // (w * w), 1) if hits else 0,
            # the cold half is visibly slower so the exclusion matters
            wall_time_s=wall * (3.0 if t < steps // 2 else 1.0)))
    return at.Trace(config=tc, records=tuple(recs))


@pytest.mark.parametrize("tc", [
    _tc(),                                             # sharded / device
    _tc(mode="tiered"),                                # tiered / device
    _tc(mode="replicated"),                            # no probe round
    _tc(mode="sharded", store="host"),                 # L3 admit pipeline
    _tc(mode=None, store="host"),                      # uncached host
    _tc(mode=None, w=1),                               # single worker
    _tc(wire="dense", hit_cap=0),                      # dense probe wire
], ids=["sharded", "tiered", "replicated", "host-cached", "host-uncached",
        "w1-uncached", "dense"])
def test_synthetic_trace_is_consistent(tc):
    tr = _trace(tc)
    assert tr.violations() == ()
    tr.validate()                                      # must not raise
    assert len(tr.warm_records()) == len(tr.records) // 2


def test_violations_catch_each_corruption_class():
    tr = _trace(_tc(store="host"))

    def corrupt(**kw):
        recs = (tr.records[0],) + (tr.records[1]._replace(**kw),) \
            + tr.records[2:]
        return at.Trace(config=tr.config, records=recs)

    r = tr.records[1]
    cases = {
        "negative": corrupt(n_hits=-1),
        "wall": corrupt(wall_time_s=0.0),
        "nan wall": corrupt(wall_time_s=float("nan")),
        "tier sum": corrupt(n_local_hits=r.n_local_hits + 1),
        "unique": corrupt(n_unique=r.n_unique + 1),
        "requests": corrupt(n_requests=r.n_requests + 1),
        "probe bytes": corrupt(probe_round_bytes=r.probe_round_bytes + 1),
        "gather bytes": corrupt(host_gather_bytes=r.host_gather_bytes + 1),
        "distinct": corrupt(n_l3_hits=r.n_requests + 5,
                            n_unique=r.n_requests + 5),
    }
    for name, bad in cases.items():
        assert bad.violations(), f"{name} corruption went undetected"
        with pytest.raises(at.TraceInconsistent):
            at.CostModel.fit(bad)
    # strict=False skips the consistency gate (count corruptions only)
    at.CostModel.fit(cases["probe bytes"], strict=False)


@pytest.mark.parametrize("steps", [0, 1, 3])
def test_fit_rejects_short_windows(steps):
    """Empty window, a window whose warm half is empty, and a window
    shorter than MIN_TRACE_STEPS (the cold burst would dominate) all
    refuse to fit — the launcher then degrades to the ladders."""
    tr = _trace(_tc(), steps=steps)
    assert len(tr.records) == steps
    with pytest.raises(at.TraceTooShort):
        at.CostModel.fit(tr)


def test_fit_accepts_minimum_window():
    model = at.CostModel.fit(_trace(_tc(), steps=at.MIN_TRACE_STEPS))
    assert model.steps == at.MIN_TRACE_STEPS // 2


# ---------------------------------------------------------------------------
# the prediction contract at the anchor
# ---------------------------------------------------------------------------

def test_anchor_prediction_is_exact():
    """Predicting the traced candidate reproduces the warm-window sums,
    the measured static bytes, and the traced mean step time EXACTLY —
    the differential-test contract, here on a synthetic trace."""
    tc = _tc(mode="tiered")
    tr = _trace(tc)
    model = at.CostModel.fit(tr)
    warm = tr.warm_records()
    p = model.predict(tc.candidate())
    assert p.n_hits == sum(r.n_hits for r in warm)
    assert p.n_l1_hits == sum(r.n_l1_hits for r in warm)
    assert p.n_misses == sum(r.n_misses for r in warm)
    assert p.n_distinct == sum(r.n_distinct() for r in warm)
    assert p.step_time_s == model.wall_mean_s
    probe, gather, _ = at.static_wire_bytes(tc, tc.candidate())
    assert p.probe_round_bytes == probe
    assert p.host_gather_bytes == gather
    # the cold half is excluded: the mean must be the warm 1x wall, not
    # the 3x cold wall the first half of the window carries
    assert model.wall_mean_s == pytest.approx(2e-3)


def test_host_trace_feeds_the_gather_term():
    """A host-store trace routes the miss residue to the L3 tier and its
    PCIe gather bytes enter the prediction (the roofline host term)."""
    tc = _tc(mode="sharded", store="host")
    tr = _trace(tc)
    assert all(r.host_gather_bytes > 0 for r in tr.records)
    model = at.CostModel.fit(tr)
    warm = tr.warm_records()
    p = model.predict(tc.candidate())
    assert p.host_gather_bytes > 0
    assert p.n_l3_hits == sum(r.n_l3_hits for r in warm)
    assert p.n_misses == 0.0
    from repro.core.config import PCIE_BW
    assert p.cost_s >= p.host_gather_bytes / PCIE_BW


# ---------------------------------------------------------------------------
# property tests: the model contract over the search space
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(st.sampled_from(["replicated", "sharded", "tiered"]),
       st.sampled_from([(2, 2), (3, 2), (4, 3)]),
       st.integers(5, 9),                   # log2 traced cache rows
       st.sampled_from([1, 2, 4]),
       st.integers(0, 24),                  # traced hit_cap (0 = auto)
       st.sampled_from([0.5, 1.0, 2.0]))
def test_predicted_bytes_monotone_in_capacity(mode, fanouts, log_rows,
                                              assoc, hit_cap, slack):
    """More cache capacity can never cost wire bytes: predicted misses,
    interconnect bytes, and step time are monotone non-increasing in
    cache_rows (and in l1_rows for the tiered mode) with every other
    knob held at the traced point."""
    tc = _tc(mode=mode, fanouts=fanouts, rows=2 ** log_rows,
             l1=max(2 ** (log_rows - 3), 1), assoc=assoc,
             hit_cap=hit_cap, slack=slack)
    model = at.CostModel.fit(_trace(tc))
    base = tc.candidate()
    preds = [model.predict(base._replace(cache_rows=2 ** k))
             for k in range(3, 13)]
    for a, b in zip(preds, preds[1:]):
        assert b.n_misses <= a.n_misses + 1e-9
        assert b.wire_bytes <= a.wire_bytes + 1e-9
        assert b.step_time_s <= a.step_time_s + 1e-9
    if mode == "tiered":
        preds = [model.predict(base._replace(l1_rows=2 ** k))
                 for k in range(0, 8)]
        for a, b in zip(preds, preds[1:]):
            assert b.wire_bytes <= a.wire_bytes + 1e-9


@settings(max_examples=8, deadline=None)
@given(st.sampled_from(["replicated", "sharded", "tiered", None]),
       st.integers(5, 8),                   # log2 traced cache rows
       st.sampled_from([0.5, 1.0, 2.0]))
def test_grid_predictions_positive_finite_and_replay_deterministic(
        mode, log_rows, slack):
    """Over the WHOLE candidate grid: every predicted step time is
    strictly positive and finite, and two independent fits of the same
    trace replay every candidate bit-identically (no wall clocks, no
    RNG inside the model)."""
    tc = _tc(mode=mode, rows=2 ** log_rows,
             l1=max(2 ** (log_rows - 3), 1), slack=slack)
    cfg = _cache_cfg(tc)
    grid = at.candidate_grid(tc, cfg)
    assert grid
    m1 = at.CostModel.fit(_trace(tc))
    m2 = at.CostModel.fit(_trace(tc))
    for cand in grid:
        p1, p2 = m1.predict(cand), m2.predict(cand)
        assert p1.step_time_s > 0.0 and math.isfinite(p1.step_time_s)
        assert p1.cost_s > 0.0 and math.isfinite(p1.cost_s)
        assert p1 == p2                     # bit-identical replay
    best1, ranked1 = at.search(m1, grid)
    best2, ranked2 = at.search(m2, grid)
    assert best1 == best2 and ranked1 == ranked2


def test_observed_floors_bound_the_grid():
    """Every compact-wire hit cap the floored grid offers carries the
    traced per-destination hit peak, SCALED by the candidate's
    effective-capacity growth (more cache rows -> more hits -> higher
    peaks); the never-demoting full-capacity cap always survives, so
    the floor can narrow the grid but never empty it."""
    from repro.core.generation import probe_round_capacity

    tc = _tc()
    tr = _trace(tc)
    floors = at.observed_floors(tr)
    assert floors["hit_peak"] > 0
    grid = at.candidate_grid(tc, _cache_cfg(tc), floors=floors)
    open_grid = at.candidate_grid(tc, _cache_cfg(tc))
    assert grid and len(grid) < len(open_grid)
    e0 = at._effective_capacity(tc, tc.cache_rows, tc.assoc)
    for cand in grid:
        cap = probe_round_capacity(
            at._requests_per_worker(cand.fanouts, tc.batch_per_worker),
            tc.n_workers, cand.capacity_slack)
        e = at._effective_capacity(tc, cand.cache_rows, cand.assoc)
        hp = min(math.ceil(floors["hit_peak"] * max(e / e0, 1.0)), cap)
        hc = cap // 2 if cand.hit_cap == 0 else min(cand.hit_cap, cap)
        assert hc >= hp, (cand, cap, hp)
    # an absurd traced peak still leaves the full-capacity caps standing
    tall = at.candidate_grid(tc, _cache_cfg(tc),
                             floors={"hit_peak": 10 ** 6})
    assert tall
    for cand in tall:
        cap = probe_round_capacity(
            at._requests_per_worker(cand.fanouts, tc.batch_per_worker),
            tc.n_workers, cand.capacity_slack)
        assert cand.hit_cap >= cap


# ---------------------------------------------------------------------------
# autotune_gcn fallback mapping (instrumented run monkeypatched out)
# ---------------------------------------------------------------------------

class _Mesh:
    shape = {"data": 4}


def _run_autotune(monkeypatch, traces, **kw):
    """Drive autotune_gcn against canned traces: the first feeds the
    fit; the rest play the live-validator windows of the ranked walk,
    repeating the last trace if the walk visits more picks."""
    queue = list(traces)
    monkeypatch.setattr(
        at, "_instrumented_run",
        lambda mesh, part, feats, labels, tc, cache_cfg, probes:
            queue.pop(0) if len(queue) > 1 else queue[0])
    tc = _tc()
    feats = np.zeros((64, tc.feat_dim), np.float32)
    return at.autotune_gcn(
        _Mesh(), None, feats, None, fanouts=tc.fanouts,
        cache_cfg=_cache_cfg(tc), feature_store=tc.store,
        batch_per_worker=tc.batch_per_worker,
        seeds_for=lambda t: None, rngs=[None] * 16,
        slack=tc.capacity_slack, **kw)


def test_corrupted_trace_is_rejected(monkeypatch):
    """A trace breaching the conservation identities must NOT become a
    confident model — the result demands the ladder fallback."""
    tr = _trace(_tc())
    bad = at.Trace(config=tr.config, records=(
        tr.records[0]._replace(probe_round_bytes=1),) + tr.records[1:])
    res = _run_autotune(monkeypatch, [bad])
    assert res.accepted is False
    assert "TraceInconsistent" in res.reason
    assert res.candidate is None


def test_short_trace_degrades_to_ladders(monkeypatch):
    res = _run_autotune(monkeypatch, [_trace(_tc(), steps=2)])
    assert res.accepted is False
    assert "TraceTooShort" in res.reason


def test_validator_rejects_a_dropping_pick(monkeypatch):
    """The model has no drop term; a pick that drops requests live is
    rolled back regardless of its predicted step time."""
    good = _trace(_tc())
    vt = _trace(_tc())
    vt = at.Trace(config=vt.config, records=(
        vt.records[0]._replace(n_dropped=3),) + vt.records[1:])
    res = _run_autotune(monkeypatch, [good, vt])
    assert res.accepted is False
    assert "validator rejected" in res.reason and "dropped=3" in res.reason
    assert res.candidate is not None        # there WAS a pick to reject


def test_validator_rejects_a_slow_pick(monkeypatch):
    """Measured step time beyond VALIDATOR_RATIO x max(predicted,
    traced) means the model mis-fit — reject, fall back."""
    res = _run_autotune(monkeypatch, [_trace(_tc()), _trace(_tc())],
                        validator_ratio=1e-9)
    assert res.accepted is False
    assert "validator rejected" in res.reason
    assert res.measured_step_s > 0.0


def test_clean_run_is_accepted(monkeypatch):
    res = _run_autotune(monkeypatch, [_trace(_tc()), _trace(_tc())])
    assert res.accepted is True and res.reason == "accepted"
    assert res.candidate == res.prediction.candidate
    assert res.measured_step_s == pytest.approx(2e-3)


# ---------------------------------------------------------------------------
# W=4 differential + launcher degradation (subprocess, forced devices)
# ---------------------------------------------------------------------------

def _run_forced(code: str, devices: int = 4) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = _SRC + os.pathsep + env.get("PYTHONPATH", "")
    prologue = (
        "import os\n"
        f"os.environ['XLA_FLAGS'] = "
        f"'--xla_force_host_platform_device_count={devices}'\n"
    )
    proc = subprocess.run(
        [sys.executable, "-c", prologue + textwrap.dedent(code)],
        capture_output=True, text=True, timeout=600, env=env,
    )
    assert proc.returncode == 0, proc.stderr[-4000:]
    return proc.stdout


def test_differential_replay_matches_live_telemetry():
    """The satellite-2 contract on the W=4 smoke shape: the anchor
    prediction reproduces the REAL trace's warm hit/miss counts and
    probe-round bytes exactly, and its step time holds against a live
    re-measure within the validator tolerance."""
    out = _run_forced("""
        import jax, jax.numpy as jnp, numpy as np
        import repro.launch.autotune as at
        from repro.graph.synthetic import powerlaw_graph, node_features, node_labels
        from repro.core.balance import balance_table
        from repro.core.feature_cache import CacheConfig
        from repro.core.partition import partition_edges
        from repro.launch.mesh import make_mesh

        w, b, dim = 4, 8, 16
        mesh = make_mesh((w,), ("data",))
        g = powerlaw_graph(2000, avg_degree=8, n_hot=3, hot_degree=400,
                           seed=0)
        part = partition_edges(g, w)
        X = node_features(2000, dim); Y = node_labels(2000, 5)
        table = balance_table(np.arange(2000), w, seed=0)
        cfg = CacheConfig(256, admit=1, assoc=2, mode="sharded",
                          wire="compact", hit_cap=0)
        tc = at._traced_config((3, 2), w, b, dim, cfg, 1.0, "device")
        rngs = jax.random.split(jax.random.PRNGKey(1), 8)
        def seeds_for(t):
            cols = (np.arange(b) + t * b) % table.per_worker.shape[1]
            return jnp.asarray(table.per_worker[:, cols])
        probes = [(seeds_for(t), rngs[t]) for t in range(8)]
        trace = at._instrumented_run(mesh, part, X, Y, tc, cfg, probes)
        assert len(trace.records) == 8, trace.records
        assert trace.violations() == (), trace.violations()
        model = at.CostModel.fit(trace)
        warm = trace.warm_records()
        p = model.predict(tc.candidate())
        # counts: the replay must equal the live CacheStats/FetchStats sums
        assert p.n_hits == sum(r.n_hits for r in warm), (p, warm)
        assert p.n_l1_hits == sum(r.n_l1_hits for r in warm)
        assert p.n_misses == sum(r.n_misses for r in warm)
        assert p.n_distinct == sum(r.n_distinct() for r in warm)
        # bytes: the static formula must equal every measured round
        for r in trace.records:
            assert r.probe_round_bytes == w * p.probe_round_bytes, r
            assert r.host_gather_bytes == 0
        # step time: exact at the anchor, and within the validator
        # tolerance of an independent live re-measure
        assert p.step_time_s == model.wall_mean_s
        t2 = at._instrumented_run(mesh, part, X, Y, tc, cfg, probes[:6])
        w2 = t2.warm_records()
        measured = sum(r.wall_time_s for r in w2) / len(w2)
        assert measured <= at.VALIDATOR_RATIO * max(p.step_time_s,
                                                    model.wall_mean_s), \\
            (measured, p.step_time_s, model.wall_mean_s)
        print("DIFFERENTIAL_OK", int(p.n_hits), int(p.n_misses))
    """)
    assert "DIFFERENTIAL_OK" in out


def test_train_autotune_short_window_warns_and_falls_back(tmp_path):
    """``--autotune`` with fewer than MIN_TRACE_STEPS instrumented steps
    must degrade to the calibration ladders with a warning — and still
    train to completion (satellite-3 coverage of the launcher seam)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = _SRC + os.pathsep + env.get("PYTHONPATH", "")
    env["REPRO_FORCE_DEVICES"] = "4"
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch",
         "graphgen-gcn", "--smoke", "--workers", "4", "--steps", "3",
         "--nodes", "2000", "--batch-per-worker", "8", "--autotune",
         "--autotune-steps", "2", "--log-every", "1",
         "--ckpt-dir", str(tmp_path)],
        capture_output=True, text=True, timeout=600, env=env)
    assert proc.returncode == 0, proc.stderr[-4000:]
    assert "falling back to the calibration ladders" in proc.stdout
    assert "TraceTooShort" in proc.stdout
    assert "trained 3 steps" in proc.stdout
