"""Serving-tier units: the bucket ladder, the GraphServer request path
(zero recompiles after warmup), the frozen-cache bit-stability contract,
the serving-checkpoint round trip, and the ``--prompt-len 0`` LM decode
regression.  Multi-worker serve cells (the frozen differential matrix)
run in test_distributed.py subprocesses."""
import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.feature_cache import CacheConfig, init_cache_state
from repro.core.generation import fetch_rows, make_distributed_generator
from repro.core.partition import partition_edges
from repro.graph.synthetic import node_features, node_labels, powerlaw_graph
from repro.launch.mesh import make_mesh
from repro.launch.serve import GraphServer, bucket_for, serve_lm
from repro.models import gcn as gcn_mod
from repro.train import checkpoint as ckpt


# ------------------------------------------------------------- bucket ladder

def test_bucket_for_picks_smallest_covering_bucket():
    """The ladder maps a request to the smallest bucket whose padded
    capacity (bucket x workers) holds it — minimal pad waste."""
    assert bucket_for(1, (8, 16, 32), 1) == 8
    assert bucket_for(8, (8, 16, 32), 1) == 8
    assert bucket_for(9, (8, 16, 32), 1) == 16
    assert bucket_for(32, (8, 16, 32), 1) == 32
    # capacity is per-worker slots x workers
    assert bucket_for(30, (8, 16, 32), 4) == 8
    assert bucket_for(33, (8, 16, 32), 4) == 16


def test_bucket_for_rejects_oversize_and_empty():
    """Oversized requests raise (split, never silently truncate); empty
    requests raise (nothing to predict)."""
    with pytest.raises(ValueError, match="exceeds"):
        bucket_for(33, (8, 16, 32), 1)
    with pytest.raises(ValueError, match="at least one seed"):
        bucket_for(0, (8, 16, 32), 1)


# -------------------------------------------------------- GraphServer (W=1)

def _tiny_serving_stack(cached: bool):
    """A W=1 serving stack on a small power-law graph: (server, n_nodes).
    ``cached=False`` keeps the single-device unit cheap; the cached cells
    run in the test_distributed.py matrix."""
    N, D, C = 200, 6, 5
    mesh = make_mesh((1,), ("data",))
    g = powerlaw_graph(N, avg_degree=6, n_hot=3, hot_degree=50, seed=0)
    part = partition_edges(g, 1)
    X, Y = node_features(N, D), node_labels(N, C)
    cc = CacheConfig(64, admit=1, assoc=2) if cached else None
    out = make_distributed_generator(mesh, part, X, Y, fanouts=(4, 3),
                                     cache_cfg=cc)
    mcfg = dataclasses.replace(get_config("graphgen-gcn"), gcn_in_dim=D,
                               gcn_hidden=8, n_classes=C, fanouts=(4, 3))
    params = gcn_mod.init_gcn(mcfg, jax.random.PRNGKey(1))
    server = GraphServer(out[0], out[1], params, None,
                         buckets=(4, 8), n_workers=1)
    return server, N


def test_graph_server_compiles_ladder_once_then_never_again():
    """THE serving invariant: warmup compiles exactly one program per
    bucket; every later request — any size the ladder covers — lands on
    a compiled program (compile count frozen)."""
    server, n_nodes = _tiny_serving_stack(cached=False)
    assert server.warmup() == len(server.buckets) == 2
    rng = np.random.default_rng(0)
    for size in (1, 3, 4, 5, 8):
        preds = server.serve(rng.integers(0, n_nodes, size))
        assert preds.shape == (size,)
        assert preds.dtype == np.int32
    assert server.compile_count() == len(server.buckets), \
        "a request traced a new program — the zero-recompile gate"


def test_graph_server_rejects_oversize_request():
    """A request beyond the ladder's capacity raises — it must be split
    by the caller, never padded to a shape that was never compiled."""
    server, _ = _tiny_serving_stack(cached=False)
    with pytest.raises(ValueError, match="exceeds"):
        server.serve(np.zeros(server.capacity + 1, np.int32))


def test_graph_server_is_deterministic_per_request_index():
    """Serving is reproducible: two fresh same-seed servers answer the
    same request stream with bit-identical predictions (the per-request
    rng is fold_in(seed rng, request index), never wall clock or global
    state).  The returned slice also never exposes pad-slot predictions."""
    server_a, n_nodes = _tiny_serving_stack(cached=False)
    server_b, _ = _tiny_serving_stack(cached=False)
    rng = np.random.default_rng(3)
    for size in (3, 8, 5):
        ids = rng.integers(0, n_nodes, size)
        pa, pb = server_a.serve(ids), server_b.serve(ids)
        np.testing.assert_array_equal(pa, pb)
        assert pa.shape == (size,)


# -------------------------------------------- frozen-cache read-only contract

@pytest.mark.parametrize("mode", ["replicated", "tiered"])
def test_frozen_fetch_cache_state_bit_stable(mode):
    """The read-mostly contract at the fetch level: a warmed state run
    under the frozen serve view returns (1) the exact table rows and
    (2) a cache state whose every leaf is BIT-identical to the input —
    no admission, no counter bumps, no L1 promotion — while still
    serving hits from the warm slots."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    rows_n, d = 64, 4
    mesh = make_mesh((1,), ("data",))
    cfg = CacheConfig(32, admit=1, assoc=2, mode=mode,
                      l1_rows=16 if mode == "tiered" else 0,
                      l1_promote=2).validated()
    table = jnp.asarray(
        np.random.default_rng(0).normal(size=(rows_n, d)).astype(np.float32))
    state = jax.tree.map(jnp.asarray, init_cache_state(cfg, d, 1))
    ids = jnp.asarray(
        np.random.default_rng(1).integers(0, rows_n, (1, 24)).astype(np.int32))

    def make_run(run_cfg):
        def worker(t, i, c):
            c = jax.tree.map(lambda a: a[0], c)
            out, c, fs, cs = fetch_rows(t, i[0], "data", cache=c,
                                        cache_cfg=run_cfg)
            return (out[None], jax.tree.map(lambda a: a[None], c),
                    jax.tree.map(lambda a: a[None], (fs, cs)))
        return jax.jit(shard_map(
            worker, mesh=mesh,
            in_specs=(P("data"), P("data"), P("data")),
            out_specs=(P("data"), P("data"), P("data")),
            check_rep=False))

    # warm under the MUTABLE config (repeat ids so admit=1 + promotion fire)
    run_mut = make_run(cfg)
    for _ in range(3):
        _, state, _ = run_mut(table, ids, state)

    run_frozen = make_run(cfg.serve_view())
    before = jax.tree.map(np.asarray, state)
    total_hits = 0
    for _ in range(3):
        out, state, (fs, cs) = run_frozen(table, ids, state)
        np.testing.assert_array_equal(np.asarray(out)[0],
                                      np.asarray(table)[np.asarray(ids)[0]])
        assert int(np.asarray(fs.n_dropped).sum()) == 0
        total_hits += int(np.asarray(cs.n_hits).sum())
    after = jax.tree.map(np.asarray, state)
    for x, y in zip(jax.tree.leaves(before), jax.tree.leaves(after)):
        assert x.tobytes() == y.tobytes(), \
            "a frozen fetch mutated the cache state"
    assert total_hits > 0, "frozen probes must serve the warm slots"


def test_serve_view_freezes_and_forces_device_store():
    """serve_view() keeps the slot layout (same probe addressing as the
    warmed state) but flips frozen=True and store='device'; a frozen
    config with a host store is rejected outright."""
    cfg = CacheConfig(128, admit=2, assoc=4, mode="tiered", l1_rows=32,
                      store="host").validated()
    sv = cfg.serve_view()
    assert sv.frozen and sv.store == "device"
    assert (sv.n_rows, sv.assoc, sv.mode, sv.l1_rows) == \
        (cfg.n_rows, cfg.assoc, cfg.mode, cfg.l1_rows)
    with pytest.raises(ValueError, match="frozen"):
        CacheConfig(128, frozen=True, store="host").validated()


# ------------------------------------------------------- serving checkpoints

def test_serving_checkpoint_round_trip_bit_exact(tmp_path):
    """save_serving_state/restore_serving_state round-trips params and
    the warm cache bit-exactly, and the latest step is selected."""
    cfg = CacheConfig(32, admit=1, assoc=2).validated()
    rng = np.random.default_rng(0)
    params = {"w1": rng.normal(size=(4, 3)).astype(np.float32),
              "b1": rng.normal(size=(3,)).astype(np.float32)}
    cache = init_cache_state(cfg, 3, 1)
    cache.keys[0, :5] = np.arange(5)            # a few warm slots
    cache.rows[0, :5] = rng.normal(size=(5, 3)).astype(np.float32)
    ckpt.save_serving_state(str(tmp_path), 7, params, cache, cache_cfg=cfg)
    p2, c2 = ckpt.restore_serving_state(
        str(tmp_path), jax.tree.map(jnp.asarray, params),
        jax.tree.map(jnp.asarray, cache), expect_cache_cfg=cfg.serve_view())
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        assert np.asarray(a).tobytes() == np.asarray(b).tobytes()
    for a, b in zip(jax.tree.leaves(cache), jax.tree.leaves(c2)):
        assert np.asarray(a).tobytes() == np.asarray(b).tobytes()


def test_serving_checkpoint_rejects_layout_mismatch(tmp_path):
    """A cache state only probes correctly under the layout it was warmed
    with — restoring under a different n_rows/assoc must raise, not
    silently probe cold."""
    cfg = CacheConfig(32, admit=1, assoc=2).validated()
    cache = init_cache_state(cfg, 3, 1)
    ckpt.save_serving_state(str(tmp_path), 1, {"w": np.zeros(2, np.float32)},
                            cache, cache_cfg=cfg)
    other = CacheConfig(64, admit=1, assoc=2).validated()
    with pytest.raises(ValueError, match="layout mismatch"):
        ckpt.restore_serving_state(
            str(tmp_path), {"w": np.zeros(2, np.float32)}, cache,
            expect_cache_cfg=other)
    with pytest.raises(FileNotFoundError):
        ckpt.restore_serving_state(
            str(tmp_path / "empty"), {"w": np.zeros(2, np.float32)}, cache)


# -------------------------------------------------------- LM decode driver

def _lm_args(**over):
    base = dict(arch="smollm-135m", smoke=True, seed=0, batch=2,
                prompt_len=4, gen_len=3)
    base.update(over)
    return argparse.Namespace(**base)


def test_serve_lm_prompt_len_zero_regression():
    """--prompt-len 0 must serve, not crash: the prefill loop is
    zero-trip, so there are no prompt logits — generation starts from
    the fixed BOS-like token (the old driver hit NameError: logits)."""
    rec = serve_lm(_lm_args(prompt_len=0))
    assert rec["tokens"].shape == (2, 3)
    assert rec["tok_s"] > 0


def test_serve_lm_returns_all_generated_tokens():
    """The timed loop accumulates device arrays (no per-token host sync)
    and still returns every generated token, in order, on host."""
    rec = serve_lm(_lm_args())
    assert rec["tokens"].shape == (2, 3)
    assert rec["tokens"].dtype == np.int32


def test_serve_lm_zero_gen_len_returns_empty():
    """gen-len 0: nothing generated, empty (batch, 0) token array, no
    division-by-zero or empty-concatenate crash."""
    rec = serve_lm(_lm_args(gen_len=0))
    assert rec["tokens"].shape == (2, 0)
