"""End-to-end behaviour: losses actually DECREASE when the data is
learnable, on both the paper's GCN pipeline and a zoo LM."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import REGISTRY, smoke_config
from repro.core.balance import balance_table
from repro.core.config import TrainConfig
from repro.core.generation import make_distributed_generator
from repro.core.partition import partition_edges
from repro.core.pipeline import make_pipelined_step
from repro.graph.synthetic import powerlaw_graph
from repro.models import gcn as gcn_mod
from repro.models import zoo
from repro.train.optimizer import adam_update, init_adam
from repro.train.train_loop import init_state, make_train_step
from jax.sharding import Mesh


def test_gcn_pipeline_learns_feature_rule():
    """Labels derived from node features -> pipelined GCN training must cut
    the loss well below chance."""
    n, dim, classes = 600, 16, 4
    g = powerlaw_graph(n, avg_degree=6, seed=1)
    rng = np.random.default_rng(0)
    feats = rng.standard_normal((n, dim)).astype(np.float32)
    w_true = rng.standard_normal((dim, classes))
    labels = np.argmax(feats @ w_true, axis=1).astype(np.int32)

    mesh = Mesh(np.asarray(jax.devices()[:1]), ("data",))
    part = partition_edges(g, 1)
    gen, dev = make_distributed_generator(mesh, part, feats, labels, fanouts=(4, 3))
    cfg = dataclasses.replace(
        smoke_config(REGISTRY["graphgen-gcn"]),
        gcn_in_dim=dim, n_classes=classes, gcn_hidden=32, fanouts=(4, 3),
    )
    params = gcn_mod.init_gcn(cfg, jax.random.PRNGKey(0))
    opt = init_adam(params)
    tcfg = TrainConfig(learning_rate=5e-3, total_steps=60, warmup_steps=0)

    def train_fn(params, opt, batch):
        loss, grads = jax.value_and_grad(gcn_mod.gcn_loss)(params, batch)
        params, opt, _ = adam_update(tcfg, params, grads, opt)
        return params, opt, loss

    table = balance_table(np.arange(n), 1, seed=0)
    step = jax.jit(make_pipelined_step(gen, train_fn))
    rngs = jax.random.split(jax.random.PRNGKey(7), 61)
    seeds = lambda t: jnp.asarray(
        table.per_worker[:, (t * 32) % (n - 32):(t * 32) % (n - 32) + 32])
    carry = (params, opt, gen(dev, seeds(0), rngs[0]))
    losses = []
    for t in range(60):
        carry, loss = step(carry, dev, seeds(t + 1), rngs[t + 1])
        losses.append(float(loss))
    assert np.mean(losses[:5]) > np.mean(losses[-5:]) + 0.3
    assert np.mean(losses[-5:]) < np.log(classes) * 0.8


def test_lm_overfits_single_batch():
    cfg = smoke_config(REGISTRY["smollm-135m"])
    api = zoo.build(cfg)
    tcfg = TrainConfig(learning_rate=3e-3, warmup_steps=0, total_steps=40)
    state = init_state(api.init(jax.random.PRNGKey(0)), tcfg)
    step = jax.jit(make_train_step(api.loss, tcfg))
    rng = np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab_size, (4, 32), dtype=np.int32)
    batch = {"tokens": jnp.asarray(toks),
             "labels": jnp.asarray(np.roll(toks, -1, 1))}
    first = None
    for _ in range(40):
        state, m = step(state, batch)
        if first is None:
            first = float(m["loss"])
    assert float(m["loss"]) < first - 1.0


def test_microbatch_accumulation_matches_full_batch():
    cfg = smoke_config(REGISTRY["smollm-135m"])
    api = zoo.build(cfg)
    rng = np.random.default_rng(1)
    toks = rng.integers(0, cfg.vocab_size, (8, 16), dtype=np.int32)
    batch = {"tokens": jnp.asarray(toks), "labels": jnp.asarray(np.roll(toks, -1, 1))}
    params = api.init(jax.random.PRNGKey(0))
    s1 = init_state(params, TrainConfig(microbatches=1))
    s4 = init_state(params, TrainConfig(microbatches=4))
    st1, m1 = jax.jit(make_train_step(api.loss, TrainConfig(microbatches=1)))(s1, batch)
    st4, m4 = jax.jit(make_train_step(api.loss, TrainConfig(microbatches=4)))(s4, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]), rtol=1e-4)
    l1 = jax.tree.leaves(st1.params)
    l4 = jax.tree.leaves(st4.params)
    for a, b in zip(l1, l4):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=2e-5)


def test_nan_guard_skips_bad_step():
    from repro.train.train_loop import TrainState, nan_guard
    from repro.train.optimizer import init_adam
    params = {"w": jnp.ones(3)}
    state = TrainState(params=params, opt=init_adam(params), error=None)
    bad = TrainState(params={"w": jnp.full(3, jnp.nan)}, opt=state.opt, error=None)
    out = nan_guard(state, bad, {"loss": jnp.float32(jnp.nan)})
    np.testing.assert_array_equal(np.asarray(out.params["w"]), np.ones(3))
    out2 = nan_guard(state, bad, {"loss": jnp.float32(1.0)})
    assert np.isnan(np.asarray(out2.params["w"])).all()
