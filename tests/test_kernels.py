"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.cache_gather import cache_probe_gather_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.gather_reduce import fanout_mean_pallas, gather_reduce_pallas
from repro.kernels.ssd_scan import ssd_scan_pallas


@pytest.mark.parametrize("m,k,d", [(8, 4, 16), (37, 9, 130), (128, 20, 128), (5, 40, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fanout_mean(m, k, d, dtype):
    x = jax.random.normal(jax.random.PRNGKey(0), (m, k, d)).astype(dtype)
    mask = jax.random.bernoulli(jax.random.PRNGKey(1), 0.7, (m, k))
    got = fanout_mean_pallas(x, mask)
    want = ref.fanout_mean_ref(x, mask)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=tol, atol=tol)


@pytest.mark.parametrize("n,d,m,k", [(100, 64, 13, 5), (64, 128, 32, 20), (257, 96, 8, 40)])
def test_gather_reduce(n, d, m, k):
    table = jax.random.normal(jax.random.PRNGKey(2), (n, d))
    idx = jax.random.randint(jax.random.PRNGKey(3), (m, k), 0, n)
    mask = jax.random.bernoulli(jax.random.PRNGKey(4), 0.8, (m, k))
    got = gather_reduce_pallas(table, idx, mask)
    want = ref.gather_reduce_ref(table, idx, mask)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("c,d,r", [(64, 32, 17), (256, 128, 300), (1024, 96, 64)])
@pytest.mark.parametrize("assoc", [1, 2, 4])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_cache_probe_gather(c, d, r, assoc, dtype):
    """Fused VMEM probe+gather vs the jnp oracle across associativities:
    identical hit vector and bit-identical rows (the cache tier must never
    perturb features)."""
    from repro.core.feature_cache import hash_slots

    rng = np.random.default_rng(0)
    # residents installed at their TRUE hash sets spread over the ways (as
    # cache_insert would), plus ~half the slots left empty
    n_sets = c // assoc
    pool = rng.choice(50 * c, size=c, replace=False).astype(np.int32)
    sets = np.asarray(hash_slots(jnp.asarray(pool), n_sets))
    keys = np.full(c, -1, np.int32)
    way_fill = np.zeros(n_sets, np.int64)
    for pid, s in zip(pool, sets):
        if way_fill[s] < assoc:
            keys[s * assoc + way_fill[s]] = pid
            way_fill[s] += 1
    keys[rng.random(c) < 0.5] = -1
    keys = jnp.asarray(keys)
    rows = jax.random.normal(jax.random.PRNGKey(1), (c, d)).astype(dtype)
    # probe a mix of resident ids (hits) and random ids (mostly misses)
    ids = np.where(rng.random(r) < 0.5, rng.choice(pool, size=r),
                   rng.integers(0, 50 * c, r)).astype(np.int32)
    ids = jnp.asarray(ids)
    got_hit, got_rows = cache_probe_gather_pallas(keys, rows, ids, assoc=assoc)
    want_hit, want_rows = ref.cache_probe_gather_ref(keys, rows, ids,
                                                     assoc=assoc)
    np.testing.assert_array_equal(np.asarray(got_hit), np.asarray(want_hit))
    np.testing.assert_array_equal(
        np.asarray(got_rows, np.float32), np.asarray(want_rows, np.float32))
    assert np.asarray(want_hit).any() and not np.asarray(want_hit).all()


@pytest.mark.parametrize("assoc", [1, 2])
def test_cache_probe_gather_matches_state_probe(assoc):
    """The kernel and feature_cache.cache_probe(impl=...) agree — same hash,
    same rows — so either implementation can serve the fetch front end."""
    from repro.core.feature_cache import (CacheConfig, cache_probe,
                                          init_cache, cache_insert)

    cfg = CacheConfig(128, admit=1, assoc=assoc)
    cache = init_cache(128, 16)
    rng = np.random.default_rng(3)
    ids = jnp.asarray(rng.integers(0, 400, 96, dtype=np.int32))
    rows = jax.random.normal(jax.random.PRNGKey(2), (96, 16))
    cache, _ = cache_insert(cache, ids, rows, jnp.ones(96, bool), cfg)
    probe = jnp.asarray(rng.integers(0, 400, 64, dtype=np.int32))
    hit_j, rows_j = cache_probe(cache, probe, cfg=cfg)
    hit_p, rows_p = cache_probe(cache, probe, cfg=cfg, impl="pallas")
    np.testing.assert_array_equal(np.asarray(hit_j), np.asarray(hit_p))
    np.testing.assert_array_equal(np.asarray(rows_j), np.asarray(rows_p))


def test_cache_probe_gather_degenerate_single_set():
    """c == assoc -> one set: the kernel takes the shift-guard branch
    (a literal 32-bit uint32 shift would be out of range)."""
    keys = jnp.asarray([11, 22, -1, 33], jnp.int32)
    rows = jnp.arange(8, dtype=jnp.float32).reshape(4, 2)
    ids = jnp.asarray([22, 5, 33, 11, -7], jnp.int32)
    got_hit, got_rows = cache_probe_gather_pallas(keys, rows, ids, assoc=4)
    want_hit, want_rows = ref.cache_probe_gather_ref(keys, rows, ids, assoc=4)
    np.testing.assert_array_equal(np.asarray(got_hit), np.asarray(want_hit))
    np.testing.assert_array_equal(np.asarray(got_rows), np.asarray(want_rows))
    np.testing.assert_array_equal(np.asarray(want_hit),
                                  [True, False, True, True, False])


@pytest.mark.parametrize("c,d,w,r", [(64, 16, 4, 33), (256, 96, 2, 300),
                                     (1024, 32, 3, 64)])
@pytest.mark.parametrize("assoc", [1, 2, 4])
@pytest.mark.parametrize("hit_cap", [1, 16, 4096])
def test_cache_probe_compact(c, d, w, r, assoc, hit_cap):
    """Fused probe+compact vs the jnp oracle across associativities, probe
    shapes, and payload bounds (1 = heavy demotion, 4096 = clamped to R =
    never demotes): identical bitmap words and bit-identical payload."""
    from repro.kernels.cache_gather import cache_probe_compact_pallas
    from repro.core.feature_cache import hash_slots

    rng = np.random.default_rng(c + r + assoc)
    n_sets = c // assoc
    pool = rng.choice(10 * c, size=c, replace=False).astype(np.int32)
    sets = np.asarray(hash_slots(jnp.asarray(pool), n_sets))
    keys = np.full(c, -1, np.int32)
    way_fill = np.zeros(n_sets, np.int64)
    for pid, s in zip(pool, sets):
        if way_fill[s] < assoc:
            keys[s * assoc + way_fill[s]] = pid
            way_fill[s] += 1
    keys = jnp.asarray(keys)
    rows = jax.random.normal(jax.random.PRNGKey(1), (c, d))
    # resident ids (hits), random ids (mostly misses), and the -1 empty-
    # probe-slot sentinel, which must never alias an empty cache slot
    ids = np.where(rng.random((w, r)) < 0.5, rng.choice(pool, size=(w, r)),
                   rng.integers(0, 10 * c, (w, r))).astype(np.int32)
    ids[rng.random((w, r)) < 0.15] = -1
    ids = jnp.asarray(ids)
    got_w, got_raw, got_p = cache_probe_compact_pallas(
        keys, rows, ids, assoc=assoc, hit_cap=hit_cap)
    want_w, want_raw, want_p = ref.cache_probe_compact_ref(
        keys, rows, ids, assoc=assoc, hit_cap=hit_cap)
    np.testing.assert_array_equal(np.asarray(got_w), np.asarray(want_w))
    np.testing.assert_array_equal(np.asarray(got_raw), np.asarray(want_raw))
    np.testing.assert_array_equal(np.asarray(got_p), np.asarray(want_p))
    assert got_w.shape == got_raw.shape == (w, -(-r // 32))
    assert got_p.shape == (w, min(hit_cap, r), d)


def test_cache_probe_compact_matches_dense_probe():
    """The compact encoding carries exactly the dense probe's hit rows
    (at a non-demoting hit_cap): unpacking the bitmap reproduces the
    dense hit vector and re-expanding the payload reproduces its rows —
    the wire format is pure transport, not a different probe."""
    from repro.core.feature_cache import (expand_hit_rows,
                                          unpack_hit_bitmap)
    from repro.kernels.cache_gather import cache_probe_compact_pallas

    rng = np.random.default_rng(9)
    c, d, r = 128, 12, 96
    keys = np.full(c, -1, np.int32)
    occ = rng.random(c) < 0.5
    keys[occ] = rng.integers(0, 4 * c, occ.sum())
    keys = jnp.asarray(keys)
    rows = jax.random.normal(jax.random.PRNGKey(4), (c, d))
    ids = jnp.asarray(rng.integers(0, 4 * c, (3, r)).astype(np.int32))
    words, raw_words, payload = cache_probe_compact_pallas(keys, rows, ids,
                                                           hit_cap=r)
    want_hit, want_rows = jax.vmap(
        lambda i: ref.cache_probe_gather_ref(keys, rows, i))(ids)
    np.testing.assert_array_equal(
        np.asarray(unpack_hit_bitmap(words, r)), np.asarray(want_hit))
    # at a non-demoting hit_cap the raw and wire bitmaps coincide
    np.testing.assert_array_equal(np.asarray(raw_words), np.asarray(words))
    np.testing.assert_array_equal(
        np.asarray(expand_hit_rows(unpack_hit_bitmap(words, r), payload)),
        np.asarray(want_rows))


def test_cache_probe_compact_degenerate_single_set():
    """c == assoc -> one set: the compact kernel takes the shift-guard
    branch (a literal 32-bit uint32 shift would be out of range)."""
    from repro.kernels.cache_gather import cache_probe_compact_pallas

    keys = jnp.asarray([11, 22, -1, 33], jnp.int32)
    rows = jnp.arange(8, dtype=jnp.float32).reshape(4, 2)
    ids = jnp.asarray([[22, 5, 33, 11, -7]], jnp.int32)
    got_w, got_raw, got_p = cache_probe_compact_pallas(keys, rows, ids,
                                                       assoc=4, hit_cap=2)
    want_w, want_raw, want_p = ref.cache_probe_compact_ref(
        keys, rows, ids, assoc=4, hit_cap=2)
    np.testing.assert_array_equal(np.asarray(got_w), np.asarray(want_w))
    np.testing.assert_array_equal(np.asarray(got_raw), np.asarray(want_raw))
    np.testing.assert_array_equal(np.asarray(got_p), np.asarray(want_p))
    # hits at slots 0 and 2 survive the 2-row bound; the slot-3 hit
    # demotes (cleared on the wire, still set in the raw telemetry)
    assert np.asarray(got_w).ravel().tolist() == [0b101]
    assert np.asarray(got_raw).ravel().tolist() == [0b1101]


@pytest.mark.parametrize("b,hq,hkv,lq,lk,dh", [
    (1, 2, 2, 128, 128, 32),     # MHA square
    (2, 4, 2, 128, 256, 64),     # GQA, decode-style longer k
    (1, 8, 1, 256, 256, 64),     # MQA
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention(b, hq, hkv, lq, lk, dh, causal):
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    q = jax.random.normal(ks[0], (b, hq, lq, dh))
    k = jax.random.normal(ks[1], (b, hkv, lk, dh))
    v = jax.random.normal(ks[2], (b, hkv, lk, dh))
    got = flash_attention_pallas(q, k, v, causal=causal, block_q=64, block_k=64)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-3, atol=2e-3)


def test_flash_attention_bf16():
    ks = jax.random.split(jax.random.PRNGKey(6), 3)
    q = jax.random.normal(ks[0], (1, 2, 128, 64)).astype(jnp.bfloat16)
    k = jax.random.normal(ks[1], (1, 2, 128, 64)).astype(jnp.bfloat16)
    v = jax.random.normal(ks[2], (1, 2, 128, 64)).astype(jnp.bfloat16)
    got = flash_attention_pallas(q, k, v, causal=True)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=5e-2, atol=5e-2)


@pytest.mark.parametrize("b,l,h,p,n,chunk", [
    (1, 32, 2, 8, 4, 8),
    (2, 64, 3, 16, 8, 16),
    (1, 128, 1, 32, 16, 128),    # single chunk == full quadratic path
])
def test_ssd_scan(b, l, h, p, n, chunk):
    ks = jax.random.split(jax.random.PRNGKey(7), 5)
    x = jax.random.normal(ks[0], (b, l, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)))
    bm = jax.random.normal(ks[3], (b, l, n))
    cm = jax.random.normal(ks[4], (b, l, n))
    got = ssd_scan_pallas(x, dt, a, bm, cm, chunk=chunk)
    want = ref.ssd_scan_ref(x, dt, a, bm, cm)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-3, atol=2e-3)


def test_ssd_chunk_invariance():
    """Output must not depend on the chunk size (the SSD identity)."""
    ks = jax.random.split(jax.random.PRNGKey(8), 5)
    x = jax.random.normal(ks[0], (1, 64, 2, 8))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (1, 64, 2)))
    a = -jnp.exp(jax.random.normal(ks[2], (2,)))
    bm = jax.random.normal(ks[3], (1, 64, 4))
    cm = jax.random.normal(ks[4], (1, 64, 4))
    outs = [np.asarray(ssd_scan_pallas(x, dt, a, bm, cm, chunk=c))
            for c in (8, 16, 32, 64)]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("c1,c2,r", [(16, 64, 33), (64, 256, 300),
                                     (32, 1024, 96)])
@pytest.mark.parametrize("l1_assoc,l2_assoc", [(1, 1), (2, 4), (2, 2)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_cache_probe_tiered(c1, c2, r, l1_assoc, l2_assoc, dtype):
    """Fused two-tier probe vs the jnp oracle: identical source vector
    (0 = miss, 1 = L1, 2 = L2 — the L1 wins a double residency) and
    bit-identical rows across tier sizes, associativities, and dtypes."""
    from repro.kernels.cache_gather import cache_probe_tiered_pallas

    d = 24
    rng = np.random.default_rng(c1 + c2 + r)
    ids = jnp.asarray(rng.integers(0, 4 * c2, r).astype(np.int32))

    def fill(c, frac):
        keys = np.full(c, -1, np.int32)
        occ = rng.random(c) < frac
        keys[occ] = rng.integers(0, 4 * c2, occ.sum())
        rows = rng.standard_normal((c, d)).astype(np.float32)
        return jnp.asarray(keys), jnp.asarray(rows, dtype)

    l1k, l1r = fill(c1, 0.6)
    l2k, l2r = fill(c2, 0.5)
    got_src, got_rows = cache_probe_tiered_pallas(
        l1k, l1r, l2k, l2r, ids, l1_assoc=l1_assoc, l2_assoc=l2_assoc)
    want_src, want_rows = ref.cache_probe_tiered_ref(
        l1k, l1r, l2k, l2r, ids, l1_assoc=l1_assoc, l2_assoc=l2_assoc)
    np.testing.assert_array_equal(np.asarray(got_src), np.asarray(want_src))
    np.testing.assert_array_equal(np.asarray(got_rows, np.float32),
                                  np.asarray(want_rows, np.float32))


def test_cache_probe_tiered_degenerate_single_set_l1():
    """A 1-row (single-set) L1 in front of a normal L2 exercises the
    32-bit-shift guard on the L1 side of the fused kernel."""
    from repro.kernels.cache_gather import cache_probe_tiered_pallas

    l1k = jnp.asarray([42], jnp.int32)
    l1r = jnp.asarray([[7.0, 8.0]])
    l2k = jnp.asarray([42, 9, -1, -1], jnp.int32)
    l2r = jnp.asarray(np.arange(8, dtype=np.float32).reshape(4, 2))
    ids = jnp.asarray([42, 9, 3], jnp.int32)
    got_src, got_rows = cache_probe_tiered_pallas(l1k, l1r, l2k, l2r, ids)
    want_src, want_rows = ref.cache_probe_tiered_ref(l1k, l1r, l2k, l2r, ids)
    np.testing.assert_array_equal(np.asarray(got_src), np.asarray(want_src))
    np.testing.assert_array_equal(np.asarray(got_rows), np.asarray(want_rows))
    assert int(got_src[0]) == 1          # resident in both tiers -> L1 wins


def test_cache_probe_tiered_matches_state_probe():
    """ops.cache_probe_tiered (kernel) and feature_cache.tiered_probe
    (production jnp path) agree on a populated TieredCache state."""
    from repro.core.feature_cache import (CacheConfig, TieredCache,
                                          cache_insert, init_cache,
                                          tiered_probe)

    cfg = CacheConfig(128, admit=1, assoc=4, mode="tiered", l1_rows=16,
                      l1_promote=1).validated()
    rng = np.random.default_rng(11)
    l1, l2 = init_cache(16, 8), init_cache(128, 8)
    ids1 = jnp.asarray(rng.integers(0, 500, 12).astype(np.int32))
    ids2 = jnp.asarray(rng.integers(0, 500, 96).astype(np.int32))
    l1, _ = cache_insert(l1, ids1, jax.random.normal(jax.random.PRNGKey(0), (12, 8)),
                         jnp.ones(12, bool), cfg.l1_config())
    l2, _ = cache_insert(l2, ids2, jax.random.normal(jax.random.PRNGKey(1), (96, 8)),
                         jnp.ones(96, bool), cfg.l2_config())
    state = TieredCache(l1=l1, l2=l2)
    probe = jnp.asarray(rng.integers(0, 500, 64).astype(np.int32))
    j1, j2, jr = tiered_probe(state, probe, cfg=cfg, impl="jnp")
    p1, p2, pr = tiered_probe(state, probe, cfg=cfg, impl="pallas")
    np.testing.assert_array_equal(np.asarray(j1), np.asarray(p1))
    np.testing.assert_array_equal(np.asarray(j2), np.asarray(p2))
    np.testing.assert_array_equal(np.asarray(jr), np.asarray(pr))
    assert bool(np.asarray(j1).any()) and bool(np.asarray(j2).any())
