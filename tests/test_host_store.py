"""L3 host-RAM feature store: unit tests and loop-level loss parity.

Multi-worker host-store cells (the differential matrix extension and
the conservation corners) live in ``tests/test_distributed.py`` under
the forced-device subprocess rule; everything here runs on the single
real device, where the host pipelined, host offline, device pipelined,
and device offline loops must all agree bit-for-bit.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.configs import REGISTRY, smoke_config
from repro.core.balance import balance_table
from repro.core.config import TrainConfig
from repro.core.feature_cache import CacheConfig
from repro.core.generation import make_distributed_generator
from repro.core.partition import partition_edges
from repro.core.host_store import HostFeatureStore, empty_admit
from repro.core.pipeline import (_load_roundtrip, _store_roundtrip,
                                 offline_loop, pipelined_loop)
from repro.graph.synthetic import node_features, node_labels, powerlaw_graph
from repro.models import gcn as gcn_mod
from repro.train.optimizer import adam_update, init_adam


def _setup(n=800, fanouts=(5, 3), dim=16, classes=5, cache_cfg=None,
           feature_store="host", depth=2):
    """One-worker generator + train_fn + schedule, either feature store."""
    mesh = Mesh(np.asarray(jax.devices()[:1]), ("data",))
    g = powerlaw_graph(n, avg_degree=6, seed=0)
    partition = partition_edges(g, 1)
    feats = node_features(n, dim)
    labels = node_labels(n, classes)
    out = make_distributed_generator(
        mesh, partition, feats, labels, fanouts=fanouts,
        cache_cfg=cache_cfg, feature_store=feature_store,
        host_gather_depth=depth)
    cfg = dataclasses.replace(
        smoke_config(REGISTRY["graphgen-gcn"]),
        gcn_in_dim=dim, n_classes=classes, fanouts=fanouts)
    params = gcn_mod.init_gcn(cfg, jax.random.PRNGKey(0))
    opt = init_adam(params)
    tcfg = TrainConfig(learning_rate=1e-3, total_steps=10)

    def train_fn(params, opt, batch):
        loss, grads = jax.value_and_grad(gcn_mod.gcn_loss)(params, batch)
        params, opt, _ = adam_update(tcfg, params, grads, opt)
        return params, opt, loss

    table = balance_table(np.arange(n), 1, seed=0)
    sched = np.stack([table.per_worker[:, i * 8:(i + 1) * 8]
                      for i in range(6)])
    return out, params, opt, train_fn, sched


def test_store_validation_errors():
    """A 1-D table and an unsupported gather depth must fail loudly at
    construction, not as a shape error mid-loop."""
    with pytest.raises(ValueError, match=r"\[N, D\]"):
        HostFeatureStore(np.zeros(8, np.float32))
    with pytest.raises(ValueError, match="host_gather_depth"):
        HostFeatureStore(np.zeros((8, 2), np.float32), depth=3)


def test_empty_admit_shapes_admit_nothing():
    """The prologue admission: all ids -1 (nothing admits), one staging
    slot to keep the shard_map specs rank-correct."""
    ids, rows = empty_admit(4, 16)
    assert ids.shape == (4, 1) and rows.shape == (4, 1, 16)
    assert (np.asarray(ids) == -1).all()
    assert np.abs(np.asarray(rows)).max() == 0


@pytest.mark.parametrize("depth", [1, 2])
def test_gather_matches_table_and_zero_fills_padding(depth):
    """Both gather depths land the exact table rows for valid ids,
    exact zeros for -1 staging padding, identical device and host
    views, and the byte telemetry accumulates per issue."""
    table = np.arange(40, dtype=np.float32).reshape(10, 4)
    store = HostFeatureStore(table, depth=depth)
    ids = jnp.asarray(np.array([[3, -1, 7], [-1, 0, 9]], np.int32))
    h = store.issue(ids)
    dev = np.asarray(h.rows())
    np.testing.assert_array_equal(dev, h.host_rows())
    np.testing.assert_array_equal(dev[0, 0], table[3])
    np.testing.assert_array_equal(dev[0, 2], table[7])
    np.testing.assert_array_equal(dev[1, 1], table[0])
    np.testing.assert_array_equal(dev[1, 2], table[9])
    assert np.abs(dev[0, 1]).max() == 0 and np.abs(dev[1, 0]).max() == 0
    first = store.bytes_issued
    assert first > 0
    store.issue(ids).rows()
    assert store.bytes_issued == 2 * first


@pytest.mark.parametrize("depth", [1, 2])
@pytest.mark.parametrize("cached", [False, True])
def test_host_pipelined_loss_parity_with_device_loops(cached, depth):
    """THE parity contract on one worker: the host-store pipelined loop
    (split dispatch, double-buffered gather, deferred admission) and the
    host offline loop produce per-step losses bit-identical to the
    device-resident pipelined and offline loops under the same schedule
    and rng split — the L3 tier changes where features live, never a
    single bit of what trains."""
    cc = (CacheConfig(128, admit=1, assoc=2, mode="replicated")
          if cached else None)
    out_d, params, opt, train_fn, sched = _setup(
        cache_cfg=cc, feature_store="device")
    out_h, _, _, _, _ = _setup(cache_cfg=cc, feature_store="host",
                               depth=depth)
    rng = jax.random.PRNGKey(42)
    if cached:
        gen_d, dev_d, cache_d = out_d
        gen_h, dev_h, store, cache_h = out_h
        *_, lp_d, _ = pipelined_loop(gen_d, train_fn, dev_d, sched, params,
                                     opt, rng, cache=cache_d)
        *_, lp_h, _ = pipelined_loop(gen_h, train_fn, dev_h, sched, params,
                                     opt, rng, cache=cache_h,
                                     host_store=store)
        _, _, lo_d, _, _ = offline_loop(gen_d, train_fn, dev_d, sched,
                                        params, opt, rng, cache=cache_d)
        _, _, lo_h, _, _ = offline_loop(gen_h, train_fn, dev_h, sched,
                                        params, opt, rng, cache=cache_h,
                                        host_store=store)
    else:
        gen_d, dev_d = out_d
        gen_h, dev_h, store = out_h
        *_, lp_d = pipelined_loop(gen_d, train_fn, dev_d, sched, params,
                                  opt, rng)
        *_, lp_h = pipelined_loop(gen_h, train_fn, dev_h, sched, params,
                                  opt, rng, host_store=store)
        _, _, lo_d, _ = offline_loop(gen_d, train_fn, dev_d, sched,
                                     params, opt, rng)
        _, _, lo_h, _ = offline_loop(gen_h, train_fn, dev_h, sched,
                                     params, opt, rng, host_store=store)
    lp_d, lp_h = np.asarray(lp_d), np.asarray(lp_h)
    lo_d, lo_h = np.asarray(lo_d), np.asarray(lo_h)
    assert np.isfinite(lp_h).all()
    assert lp_h.tobytes() == lp_d.tobytes(), (lp_h, lp_d)
    assert lo_h.tobytes() == lo_d.tobytes(), (lo_h, lo_d)
    assert lp_h.tobytes() == lo_h.tobytes(), (lp_h, lo_h)
    assert store.bytes_issued > 0


def test_store_roundtrip_serializes_buffers_out_of_band():
    """The offline storage path must hand array bodies back as pickle-5
    out-of-band buffers (zero extra memcpy), reconstruct bit-exactly,
    and keep the header free of the row payload."""
    payload = {"rows": np.arange(4096, dtype=np.float32).reshape(64, 64),
               "ids": np.arange(64, dtype=np.int32)}
    header, buffers = _store_roundtrip(payload)
    assert len(buffers) >= 2, "array bodies were inlined, not out-of-band"
    assert len(header) < payload["rows"].nbytes // 2
    back = _load_roundtrip((header, buffers))
    np.testing.assert_array_equal(np.asarray(back["rows"]),
                                  payload["rows"])
    np.testing.assert_array_equal(np.asarray(back["ids"]), payload["ids"])


def test_chunked_host_feature_table_is_bitwise_identical():
    """``features_on_host=True`` builds the table in bounded-memory
    chunks; every chunk size must consume the Generator stream exactly
    like the one-shot draw — bit-for-bit, including the non-chunk-aligned
    tail."""
    want = node_features(1000, 8, seed=3)
    for chunk in (64, 256, 1 << 16):
        got = node_features(1000, 8, seed=3, features_on_host=True,
                            chunk_rows=chunk)
        assert got.tobytes() == want.tobytes(), chunk
