"""Load-Balanced Subgraph Mapping (Algorithm 1 lines 4-13)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.balance import balance_table, load_skew, rebalance_on_failure


def test_round_robin_exact_shares():
    t = balance_table(np.arange(103), 4, seed=0)
    assert t.per_worker.shape == (4, 25)      # floor(103/4) = 25
    assert t.n_discarded == 3                 # 103 mod 4 discarded (Alg.1 l.6)


def test_assignment_is_round_robin_over_shuffle():
    t = balance_table(np.arange(40), 4, seed=1)
    # seed_order[i] must be assigned to worker i mod W (Alg.1 l.11)
    for i, s in enumerate(t.seed_order):
        w = i % 4
        assert s in t.per_worker[w]


def test_no_duplicates_no_invention():
    seeds = np.arange(1000, 1200)
    t = balance_table(seeds, 7, seed=3)
    flat = t.per_worker.reshape(-1)
    assert len(np.unique(flat)) == len(flat)
    assert set(flat).issubset(set(seeds.tolist()))


def test_shuffle_avoids_sequential_bias():
    t = balance_table(np.arange(64), 8, seed=0)
    assert not np.array_equal(t.per_worker[0], np.arange(0, 64, 8))


@settings(max_examples=50, deadline=None)
@given(n_seeds=st.integers(1, 500), n_workers=st.integers(1, 32),
       seed=st.integers(0, 10))
def test_balance_invariants(n_seeds, n_workers, seed):
    t = balance_table(np.arange(n_seeds), n_workers, seed=seed)
    per = n_seeds // n_workers
    assert t.per_worker.shape == (n_workers, per)
    assert t.n_discarded == n_seeds - per * n_workers
    assert load_skew(np.array([per] * n_workers)) == pytest.approx(1.0) or per == 0


def test_rebalance_on_failure_preserves_seed_pool():
    t = balance_table(np.arange(120), 6, seed=0)
    t2 = rebalance_on_failure(t, failed=[2, 4])
    assert t2.n_workers == 4
    # survivors re-deal the full original pool (minus new remainder)
    assert set(t2.per_worker.reshape(-1)).issubset(set(t.per_worker.reshape(-1)))
    assert t2.per_worker.shape == (4, 120 // 4)


def test_all_failed_raises():
    t = balance_table(np.arange(10), 2, seed=0)
    with pytest.raises(RuntimeError):
        rebalance_on_failure(t, failed=[0, 1])
