"""Optimizer, checkpointing, compression, fault handling."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.balance import balance_table
from repro.core.config import TrainConfig
from repro.train import checkpoint as ckpt
from repro.train import compression
from repro.train.fault import (FailureInjector, WorkerFailure,
                               recover_assignment, run_with_recovery)
from repro.train.optimizer import (adam_update, clip_by_global_norm,
                                   init_adam, lr_schedule)


# ------------------------------------------------- capacity calibration --
def test_warm_capacity_bounds():
    """Cache-aware capacity shrink: the warm bound follows the measured
    miss peak, never exceeds the per-worker row count, and keeps a margin
    for routing skew."""
    from repro.launch.train import warm_capacity

    # misses spread over 8 destinations with 2x skew allowance + margin
    assert warm_capacity(800, 8, 2.0, rows=10_000) == 208
    # clamped to the destination's row count
    assert warm_capacity(100_000, 2, 2.0, rows=512) == 512
    # the skew allowance floors at 2x even under a tighter calibrated
    # slack — warm miss peaks are spikier than the cold request mix
    assert warm_capacity(800, 8, 0.25, rows=10_000) == 208
    # a larger calibrated slack widens it further
    assert warm_capacity(800, 8, 4.0, rows=10_000) == 408
    # degenerate: tiny miss peaks still get a usable buffer
    assert warm_capacity(0, 8, 2.0, rows=64) == 8


# ------------------------------------------------------------- optimizer --
def test_adam_converges_on_quadratic():
    tcfg = TrainConfig(learning_rate=0.1, warmup_steps=0, total_steps=200,
                       weight_decay=0.0, grad_clip=1e9)
    target = jnp.array([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3)}
    state = init_adam(params)

    @jax.jit
    def step(params, state):
        loss, g = jax.value_and_grad(
            lambda p: jnp.sum((p["w"] - target) ** 2)
        )(params)
        params, state, _ = adam_update(tcfg, params, g, state)
        return params, state, loss

    for _ in range(200):
        params, state, loss = step(params, state)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=0.05)


def test_lr_schedule_warmup_and_decay():
    tcfg = TrainConfig(learning_rate=1.0, warmup_steps=10, total_steps=100)
    assert float(lr_schedule(tcfg, jnp.int32(0))) == 0.0
    assert float(lr_schedule(tcfg, jnp.int32(10))) == pytest.approx(1.0)
    assert float(lr_schedule(tcfg, jnp.int32(100))) == pytest.approx(0.1, rel=1e-3)


def test_grad_clip():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(20.0)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(clipped["a"])), 1.0, rtol=1e-5
    )


# ------------------------------------------------------------ checkpoint --
def test_checkpoint_roundtrip_and_retention(tmp_path):
    d = str(tmp_path)
    tree = {"w": jnp.arange(6.0).reshape(2, 3), "opt": {"m": jnp.ones(4)}}
    for s in (1, 2, 3, 4, 5):
        ckpt.save(d, s, tree, keep=2)
    assert ckpt.latest_step(d) == 5
    kept = sorted(x for x in os.listdir(d) if x.startswith("step_"))
    assert len(kept) == 2                      # keep-last-k enforced
    restored = ckpt.restore(d, 5, tree)
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(tree["w"]))


def test_checkpoint_atomic_no_tmp_left(tmp_path):
    d = str(tmp_path)
    ckpt.save(d, 1, {"x": jnp.zeros(2)})
    assert not any(f.startswith("tmp.") for f in os.listdir(d))


def test_checkpoint_restores_dtype(tmp_path):
    d = str(tmp_path)
    tree = {"x": jnp.ones(3, jnp.bfloat16)}
    ckpt.save(d, 1, tree)
    out = ckpt.restore(d, 1, tree)
    assert out["x"].dtype == jnp.bfloat16


# ----------------------------------------------------------- compression --
@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_quantize_error_bound(seed):
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.standard_normal(64).astype(np.float32) * 10)
    q, s = compression.quantize(g)
    err = np.abs(np.asarray(compression.dequantize(q, s)) - np.asarray(g))
    assert err.max() <= float(s) / 2 + 1e-6


def test_error_feedback_telescopes():
    """Sum of compressed grads ~= sum of true grads (bias telescopes)."""
    rng = np.random.default_rng(0)
    grads = [{"w": jnp.asarray(rng.standard_normal(32).astype(np.float32))}
             for _ in range(50)]
    err = compression.init_error(grads[0])
    total_c = np.zeros(32)
    for g in grads:
        packed, err = compression.compress_grads(g, err)
        total_c += np.asarray(compression.decompress_grads(packed)["w"])
    total = sum(np.asarray(g["w"]) for g in grads)
    resid = np.abs(total_c - total).max()
    # residual bounded by one quantization step, NOT growing with steps
    assert resid < 0.2, resid


# ----------------------------------------------------------------- fault --
def test_failure_injector_and_recovery_loop():
    table = balance_table(np.arange(96), 8, seed=0)
    injector = FailureInjector(fail_worker=3, fail_at_step=7)
    checkpoints = {"step": 0}

    def run_steps(start, end, tbl):
        for s in range(start, end):
            injector.check(s)
            if s % 5 == 0:
                checkpoints["step"] = s
        return end

    done, failures, final = run_with_recovery(
        run_steps, table, 20, restore_step=lambda: checkpoints["step"]
    )
    assert done == 20
    assert failures == 1
    assert final.n_workers == 7                      # rebuilt over survivors


def test_recover_assignment_equal_shares():
    table = balance_table(np.arange(100), 10, seed=1)
    t2 = recover_assignment(table, failed=[0, 9])
    assert t2.n_workers == 8
    assert t2.per_worker.shape[1] == 100 // 10 * 10 // 8  # pool re-dealt


def test_recovery_gives_up_after_max_failures():
    table = balance_table(np.arange(8), 4, seed=0)

    def always_fail(start, end, tbl):
        raise WorkerFailure(1, start)

    with pytest.raises(WorkerFailure):
        run_with_recovery(always_fail, table, 10,
                          restore_step=lambda: 0, max_failures=2)
