"""graphlint v2: the project-wide dataflow rules and the analysis engine.

Fixture pairs (bug fires / fixed version is silent) for the three
interprocedural rules — ``handle-lifecycle``, ``closure-capture``,
``carry-structure`` — plus property tests that pound the CFG builder
and the reaching-definitions fixpoint with generated structured control
flow.  The property tests run under ``tests/_hypothesis_stub.py`` when
hypothesis is not installed (deterministic examples, no shrinking).
"""
from __future__ import annotations

import ast
import io
import json
import random
import textwrap

from hypothesis import given, settings, strategies as st

from tools import _report
from tools.graphlint.analysis.cfg import ENTRY, EXIT, build_cfg
from tools.graphlint.analysis.defuse import ReachingDefs, assigned_names
from tools.graphlint.core import (Config, RunStats, changed_files,
                                  lint_source)

_AXES = frozenset({"pod", "data", "model"})


def _fired(source: str):
    src = textwrap.dedent(source)
    return {f.rule for f in lint_source("fixture.py", src, mesh_axes=_AXES)}


def _assert_fires(rule: str, source: str):
    fired = _fired(source)
    assert rule in fired, f"expected {rule!r} to fire, got {fired or '{}'}"


def _assert_silent(source: str):
    fired = _fired(source)
    assert not fired, f"expected no findings, got {fired}"


# ---------------------------------------------------------------------------
# handle-lifecycle
# ---------------------------------------------------------------------------

def test_lifecycle_leaked_executor_fires():
    _assert_fires("handle-lifecycle", """\
        from concurrent.futures import ThreadPoolExecutor

        def launch(work):
            pool = ThreadPoolExecutor(max_workers=2)
            pool.submit(work)
        """)


def test_lifecycle_shutdown_executor_silent():
    _assert_silent("""\
        from concurrent.futures import ThreadPoolExecutor

        def launch(work):
            pool = ThreadPoolExecutor(max_workers=2)
            pool.submit(work)
            pool.shutdown()
        """)


def test_lifecycle_context_managed_executor_silent():
    _assert_silent("""\
        from concurrent.futures import ThreadPoolExecutor

        def launch(work):
            with ThreadPoolExecutor(max_workers=2) as pool:
                pool.submit(work)
        """)


def test_lifecycle_undrained_gather_fires():
    """The PR 7 double-buffer hazard: an issued gather nobody collects."""
    _assert_fires("handle-lifecycle", """\
        def prologue(host_store, ids):
            pending = host_store.issue(ids)
            return 0
        """)


def test_lifecycle_drained_gather_silent():
    _assert_silent("""\
        def prologue(host_store, ids):
            pending = host_store.issue(ids)
            return pending.rows()
        """)


def test_lifecycle_branch_that_skips_drain_fires():
    """One CFG path drains, the other falls off the end — still a leak."""
    _assert_fires("handle-lifecycle", """\
        def maybe(host_store, ids, flag):
            pending = host_store.issue(ids)
            if flag:
                return pending.rows()
            return 0
        """)


def test_lifecycle_none_guard_drain_silent():
    """`if h is not None: h.rows()` is the canonical optional-handle
    drain; the live-handle path cannot take the guard's skip side."""
    _assert_silent("""\
        def run(host_store, ids, steps):
            pending = None
            if steps:
                pending = host_store.issue(ids)
            for t in range(steps):
                pass
            if pending is not None:
                pending.rows()
        """)


def test_lifecycle_clobbered_reissue_fires():
    """Overwriting an undrained handle loses the gather it held."""
    _assert_fires("handle-lifecycle", """\
        def reissue(host_store, a, b):
            pending = host_store.issue(a)
            pending = host_store.issue(b)
            return pending.rows()
        """)


def test_lifecycle_escaping_handle_silent():
    """A handle that escapes (returned, stored) is the caller's problem."""
    _assert_silent("""\
        def hand_off(host_store, ids, registry):
            pending = host_store.issue(ids)
            registry.append(pending)

        def forward(host_store, ids):
            pending = host_store.issue(ids)
            return pending
        """)


def test_lifecycle_trace_recorder_drain_shape_silent():
    """The autotune trace recorder's loop shape (PR 10): drain the
    previous gather before reissuing, break early on bad telemetry, and
    the post-loop None-guarded drain catches whatever is in flight on
    EVERY exit path — the fixture pins the shape
    ``repro.launch.autotune.record_trace`` relies on staying lint-clean."""
    _assert_silent("""\
        def record(host_store, probes, gen):
            pending = None
            records = []
            for seeds in probes:
                req = gen(seeds)
                if pending is not None:
                    pending.rows()
                pending = host_store.issue(req)
                records.append(req)
                if req < 0:
                    break
            if pending is not None:
                pending.rows()
            return records
        """)


def test_lifecycle_trace_recorder_early_return_fires():
    """The one-token mutation that breaks the recorder's contract: an
    early ``return`` inside the loop skips the post-loop drain and
    leaks the in-flight gather."""
    _assert_fires("handle-lifecycle", """\
        def record(host_store, probes, gen):
            pending = None
            records = []
            for seeds in probes:
                req = gen(seeds)
                if pending is not None:
                    pending.rows()
                pending = host_store.issue(req)
                records.append(req)
                if req < 0:
                    return records
            if pending is not None:
                pending.rows()
            return records
        """)


def test_lifecycle_unjoined_thread_fires_joined_silent():
    _assert_fires("handle-lifecycle", """\
        import threading

        def spawn(fn):
            t = threading.Thread(target=fn)
            t.start()
        """)
    _assert_silent("""\
        import threading

        def spawn(fn):
            t = threading.Thread(target=fn)
            t.start()
            t.join()
        """)


def test_lifecycle_unstopped_loader_fires_at_module_scope():
    """PR 1's leak class: a PrefetchLoader nobody stops — and module
    top-level scopes are analyzed too, not just functions."""
    _assert_fires("handle-lifecycle", """\
        loader = PrefetchLoader(batches, depth=2)
        first = next(iter(loader.queue))
        """)
    _assert_silent("""\
        loader = PrefetchLoader(batches, depth=2)
        first = next(iter(loader.queue))
        loader.stop()
        """)


def test_lifecycle_raise_path_is_not_a_leak():
    """Exception propagation is modelled as 'path vanishes', not a leak."""
    _assert_silent("""\
        def run(host_store, ids, ok):
            pending = host_store.issue(ids)
            if not ok:
                raise ValueError("bad ids")
            return pending.rows()
        """)


def test_lifecycle_suppression_works():
    _assert_silent("""\
        def launch(host_store, ids):
            # graphlint: disable=handle-lifecycle  # drained by the caller via the store registry
            pending = host_store.issue(ids)
            return 0
        """)


# ---------------------------------------------------------------------------
# closure-capture
# ---------------------------------------------------------------------------

def test_capture_mutated_module_list_fires():
    _assert_fires("closure-capture", """\
        import jax

        schedule = []

        def step(x):
            return x + len(schedule)

        step = jax.jit(step)

        def push(v):
            schedule.append(v)
        """)


def test_capture_immutable_tuple_silent():
    _assert_silent("""\
        import jax

        schedule = (1, 2, 3)

        def step(x):
            return x + len(schedule)

        step = jax.jit(step)
        """)


def test_capture_unmutated_list_silent():
    """A list nobody mutates is frozen in practice — no finding."""
    _assert_silent("""\
        import jax

        schedule = [1, 2, 3]

        def step(x):
            return x + len(schedule)

        step = jax.jit(step)
        """)


def test_capture_through_factory_fires():
    """The repo's make_*_fn idiom: jit(make_step(...)) traces the inner
    def, whose captures resolve through the enclosing scopes."""
    _assert_fires("closure-capture", """\
        import jax

        stats = {}

        def make_step(lr):
            def step(params, grads):
                return params - lr * grads * stats.get("scale", 1)
            return step

        step = jax.jit(make_step(0.1))

        def record(k, v):
            stats.update({k: v})
        """)


def test_capture_through_partial_and_decorator_fires():
    _assert_fires("closure-capture", """\
        import functools
        import jax
        import numpy as np

        buf = np.zeros((4,))

        @jax.jit
        def step(x):
            return x + buf

        def refill():
            buf[0] = 1.0
        """)


def test_capture_traced_method_reading_reassigned_attr_fires():
    _assert_fires("closure-capture", """\
        import jax

        class Runner:
            def __init__(self):
                self.scale = 1.0

            def recalibrate(self):
                self.scale = 2.0

            def step(self, x):
                return x * self.scale

        r = Runner()
        fast = jax.jit(r.step)
        """)


def test_capture_init_only_attr_silent():
    _assert_silent("""\
        import jax

        class Runner:
            def __init__(self):
                self.scale = 1.0

            def step(self, x):
                return x * self.scale

        r = Runner()
        fast = jax.jit(r.step)
        """)


def test_capture_suppression_works():
    _assert_silent("""\
        import jax

        table = []

        def step(x):
            # graphlint: disable=closure-capture  # table is sealed before the first trace
            return x + len(table)

        step = jax.jit(step)

        def seal(v):
            table.append(v)
        """)


# ---------------------------------------------------------------------------
# carry-structure
# ---------------------------------------------------------------------------

def test_carry_arity_drift_fires():
    """The pack site grew a slot the unpack site never learned about."""
    _assert_fires("carry-structure", """\
        def step(carry, x):
            params, opt = carry
            return (params, opt), x

        def loop(params, opt, batch, xs):
            carry = (params, opt, batch)
            for x in xs:
                out = step(carry, x)
            return out
        """)


def test_carry_matching_arity_silent():
    _assert_silent("""\
        def step(carry, x):
            params, opt, batch = carry
            return (params, opt, batch), x

        def loop(params, opt, batch, xs):
            carry = (params, opt, batch)
            for x in xs:
                out = step(carry, x)
            return out
        """)


def test_carry_transposed_elements_fire():
    _assert_fires("carry-structure", """\
        def step(carry):
            opt, params = carry
            return opt

        def loop(params, opt):
            carry = (params, opt)
            return step(carry)
        """)


def test_carry_variant_packs_skipped():
    """Cached/uncached variant carries (3- or 4-tuples depending on a
    flag) are ambiguous — the rule skips rather than guesses."""
    _assert_silent("""\
        def step(carry, x):
            params, opt, batch = carry
            return (params, opt, batch), x

        def loop(params, opt, batch, cache, cached, xs):
            if cached:
                carry = (params, opt, batch, cache)
            else:
                carry = (params, opt, batch)
            for x in xs:
                out = step(carry, x)
            return out
        """)


def test_carry_return_arity_drift_fires():
    _assert_fires("carry-structure", """\
        def make_outputs():
            return 1, 2, 3

        a, b = make_outputs()
        """)


def test_carry_jit_factory_resolution_fires():
    """Interprocedural resolution through jit + a factory return."""
    _assert_fires("carry-structure", """\
        import jax

        def make_step(train):
            def step(carry, x):
                params, opt = carry
                return (params, opt), train(x)
            return step

        def loop(params, opt, batch, train, x):
            step = jax.jit(make_step(train))
            out, loss = step((params, opt, batch), x)
            return out
        """)


def test_carry_loop_carried_redefinition_skipped():
    """`carry, loss = step(carry, ...)` makes the pack provenance
    ambiguous at the call (the loop-carried def reaches it too) — the
    rule skips instead of guessing, like the real pipelined_loop."""
    _assert_silent("""\
        import jax

        def make_step(train):
            def step(carry, x):
                params, opt = carry
                return (params, opt), train(x)
            return step

        def loop(params, opt, batch, train, xs):
            step = jax.jit(make_step(train))
            carry = (params, opt, batch)
            for x in xs:
                carry, loss = step(carry, x)
            return carry
        """)


def test_carry_subscript_out_of_range_fires():
    _assert_fires("carry-structure", """\
        def tail(params, opt, batch):
            carry = (params, opt, batch)
            return carry[3]
        """)


def test_carry_checkpoint_drift_fires():
    _assert_fires("carry-structure", """\
        from repro.train import checkpoint

        def run(d, params, opt, sched):
            checkpoint.save(d, 1, (params, opt, sched))
            params, opt = checkpoint.restore(d, 1, (params, opt))
            return params
        """)


def test_carry_checkpoint_matched_silent():
    _assert_silent("""\
        from repro.train import checkpoint

        def run(d, params, opt):
            checkpoint.save(d, 1, (params, opt))
            params, opt = checkpoint.restore(d, 1, (params, opt))
            return params
        """)


# ---------------------------------------------------------------------------
# CFG / reaching-defs property tests on generated control flow
# ---------------------------------------------------------------------------

_NAMES = ("a", "b", "c")


def _gen_block(rng: random.Random, depth: int, indent: int,
               terminators: bool, in_loop: bool, lines: list) -> None:
    """Append a random structured block at *indent* to *lines*."""
    pad = "    " * indent
    for _ in range(rng.randint(1, 3)):
        kinds = ["assign", "assign", "aug", "expr"]
        if depth > 0:
            kinds += ["if", "for", "while", "try", "with"]
        if terminators:
            kinds += ["return", "raise"]
            if in_loop:
                kinds += ["break", "continue"]
        kind = rng.choice(kinds)
        tgt, src = rng.choice(_NAMES), rng.choice(_NAMES)
        if kind == "assign":
            lines.append(f"{pad}{tgt} = {src} + 1")
        elif kind == "aug":
            lines.append(f"{pad}{tgt} += 1")
        elif kind == "expr":
            lines.append(f"{pad}print({src})")
        elif kind == "return":
            lines.append(f"{pad}return {src}")
        elif kind == "raise":
            lines.append(f"{pad}raise ValueError({src})")
        elif kind in ("break", "continue"):
            lines.append(f"{pad}{kind}")
        elif kind == "if":
            lines.append(f"{pad}if {src} > 0:")
            _gen_block(rng, depth - 1, indent + 1, terminators, in_loop,
                       lines)
            if rng.random() < 0.5:
                lines.append(f"{pad}else:")
                _gen_block(rng, depth - 1, indent + 1, terminators,
                           in_loop, lines)
        elif kind == "for":
            lines.append(f"{pad}for {tgt} in range(2):")
            _gen_block(rng, depth - 1, indent + 1, terminators, True,
                       lines)
        elif kind == "while":
            lines.append(f"{pad}while {src} < 3:")
            _gen_block(rng, depth - 1, indent + 1, terminators, True,
                       lines)
        elif kind == "try":
            lines.append(f"{pad}try:")
            _gen_block(rng, depth - 1, indent + 1, terminators, in_loop,
                       lines)
            lines.append(f"{pad}except ValueError:")
            _gen_block(rng, depth - 1, indent + 1, terminators, in_loop,
                       lines)
            if rng.random() < 0.3:
                lines.append(f"{pad}finally:")
                _gen_block(rng, depth - 1, indent + 1, False, in_loop,
                           lines)
        elif kind == "with":
            lines.append(f"{pad}with ctx() as {tgt}:")
            _gen_block(rng, depth - 1, indent + 1, terminators, in_loop,
                       lines)


def _generate_program(seed: int, terminators: bool) -> ast.Module:
    rng = random.Random(seed)
    lines: list = []
    _gen_block(rng, depth=3, indent=0, terminators=terminators,
               in_loop=False, lines=lines)
    return ast.parse("\n".join(lines))


def _check_wellformed(cfg) -> None:
    nodes = set(cfg.nodes())
    assert ENTRY in nodes and EXIT in nodes
    assert ENTRY not in cfg.stmts and EXIT not in cfg.stmts
    assert not cfg.succ[EXIT], "EXIT must have no successors"
    for src, dsts in cfg.succ.items():
        assert src in nodes
        for d in dsts:
            assert d in nodes, f"edge {src}->{d} dangles"
    for nid in cfg.stmts:
        assert nid in cfg.header_exprs


@settings(max_examples=40)
@given(st.integers(min_value=0, max_value=2**31 - 1))
def test_cfg_invariants_on_terminator_free_flow(seed):
    """Without return/raise/break, every statement gets exactly one
    reachable node and EXIT is reachable."""
    tree = _generate_program(seed, terminators=False)
    n_stmts = sum(1 for node in ast.walk(tree)
                  if isinstance(node, ast.stmt))
    cfg = build_cfg(tree.body)
    _check_wellformed(cfg)
    assert len(cfg.stmts) == n_stmts
    reachable = cfg.reachable(ENTRY)
    assert EXIT in reachable
    assert reachable == set(cfg.nodes()), "unreachable node in structured flow"


@settings(max_examples=40)
@given(st.integers(min_value=0, max_value=2**31 - 1))
def test_cfg_invariants_with_terminators(seed):
    """Return/raise/break/continue prune paths but never corrupt the
    graph: edges stay well-formed, raise never reaches EXIT directly,
    return reaches only EXIT."""
    tree = _generate_program(seed, terminators=True)
    n_stmts = sum(1 for node in ast.walk(tree)
                  if isinstance(node, ast.stmt))
    cfg = build_cfg(tree.body)
    _check_wellformed(cfg)
    assert len(cfg.stmts) <= n_stmts
    # inside a try body, ANY statement (return and raise included) may
    # jump to a handler entry — those are the only permitted extras
    stmt_nids = {id(s): nid for nid, s in cfg.stmts.items()}
    handler_entries = {
        stmt_nids[id(h.body[0])]
        for node in ast.walk(tree) if isinstance(node, ast.Try)
        for h in node.handlers if id(h.body[0]) in stmt_nids}
    for nid, stmt in cfg.stmts.items():
        if isinstance(stmt, ast.Return):
            assert cfg.succ[nid] <= {EXIT} | handler_entries
        elif isinstance(stmt, ast.Raise):
            assert cfg.succ[nid] <= handler_entries, \
                "raise must terminate its path (handlers aside)"


@settings(max_examples=40)
@given(st.integers(min_value=0, max_value=2**31 - 1),
       st.booleans())
def test_reaching_defs_sites_are_real_definitions(seed, terminators):
    """Every (name, site) the fixpoint reports either is the synthetic
    parameter def at ENTRY or names a node that really assigns it."""
    tree = _generate_program(seed, terminators=terminators)
    cfg = build_cfg(tree.body)
    rd = ReachingDefs(cfg, params={"p"})
    for nid in cfg.nodes():
        for name, site in rd.defs_in(nid):
            if site == ENTRY:
                assert name == "p"
                continue
            assert name in assigned_names(cfg.stmts[site],
                                          cfg.header_exprs[site])


@settings(max_examples=25)
@given(st.lists(st.sampled_from(_NAMES), min_size=1, max_size=8))
def test_reaching_defs_straightline_last_def_wins(names):
    """In straight-line code exactly the textually last definition of
    each name reaches EXIT."""
    src = "\n".join(f"{n} = {i}" for i, n in enumerate(names))
    tree = ast.parse(src)
    cfg = build_cfg(tree.body)
    rd = ReachingDefs(cfg)
    last_lineno = {n: i + 1 for i, n in enumerate(names)}
    by_lineno = {stmt.lineno: nid for nid, stmt in cfg.stmts.items()}
    for name, lineno in last_lineno.items():
        assert rd.reaching(EXIT, name) == frozenset({by_lineno[lineno]})


def test_reaching_defs_branch_merges_both_definitions():
    src = textwrap.dedent("""\
        if cond:
            x = 1
        else:
            x = 2
        use(x)
        """)
    tree = ast.parse(src)
    cfg = build_cfg(tree.body)
    rd = ReachingDefs(cfg)
    (use_nid,) = [nid for nid, s in cfg.stmts.items() if s.lineno == 5]
    sites = rd.reaching(use_nid, "x")
    assert len(sites) == 2, "both branch definitions must reach the use"


# ---------------------------------------------------------------------------
# runner surfaces: stats, changed-only plumbing, SARIF
# ---------------------------------------------------------------------------

def test_stats_table_reports_rules_and_total():
    stats = RunStats()
    lint_source_with_stats = textwrap.dedent("""\
        def f(store, ids):
            pending = store.issue(ids)
            return 0
        """)
    from tools.graphlint.core import build_entry, lint_entries
    findings = lint_entries([build_entry("fixture.py",
                                         lint_source_with_stats)],
                            Config(), mesh_axes=_AXES, stats=stats)
    assert any(f.rule == "handle-lifecycle" for f in findings)
    table = stats.table()
    assert "handle-lifecycle" in table and "TOTAL" in table
    assert stats.findings["handle-lifecycle"] == 1


def test_report_only_filters_findings_but_not_the_index(tmp_path):
    """--changed-only reports only changed files, yet project rules still
    see the whole tree (the index is unfiltered)."""
    from tools.graphlint.core import lint_paths
    bad = tmp_path / "bad.py"
    bad.write_text("def f(store, ids):\n"
                   "    pending = store.issue(ids)\n"
                   "    return 0\n")
    ok = tmp_path / "ok.py"
    ok.write_text("x = 1\n")
    everything = lint_paths([str(tmp_path)], Config(), root=str(tmp_path))
    assert {f.rule for f in everything} == {"handle-lifecycle"}
    filtered = lint_paths([str(tmp_path)], Config(), root=str(tmp_path),
                          report_only={"ok.py"})
    assert filtered == []


def test_changed_files_merge_base_plumbing():
    """Against HEAD the diff set is just the working-tree delta — a set;
    a bogus ref degrades to None (full lint), never an exception."""
    head = changed_files(base="HEAD")
    assert head is None or isinstance(head, set)
    assert changed_files(base="no-such-ref-anywhere") is None


def test_sarif_log_shape_and_emit():
    findings = [{"path": "src/x.py", "line": 3, "check": "handle-lifecycle",
                 "severity": "error", "message": "leaked"},
                {"path": "src/y.py", "line": 7, "check": "carry-structure",
                 "severity": "warning", "message": "drifted"}]
    log = _report.sarif_log(findings, tool_name="graphlint",
                            rule_docs={"closure-capture": "docs"})
    assert log["version"] == "2.1.0"
    run = log["runs"][0]
    assert run["tool"]["driver"]["name"] == "graphlint"
    rule_ids = [r["id"] for r in run["tool"]["driver"]["rules"]]
    assert rule_ids == sorted(rule_ids)
    assert {"handle-lifecycle", "carry-structure",
            "closure-capture"} <= set(rule_ids)
    res = run["results"]
    assert res[0]["ruleId"] == "handle-lifecycle"
    assert res[0]["level"] == "error" and res[1]["level"] == "warning"
    loc = res[0]["locations"][0]["physicalLocation"]
    assert loc["artifactLocation"]["uri"] == "src/x.py"
    assert loc["region"]["startLine"] == 3
    assert res[0]["ruleIndex"] == rule_ids.index("handle-lifecycle")

    buf = io.StringIO()
    _report.emit(findings, fmt="sarif", stream=buf, tool_name="graphlint")
    assert json.loads(buf.getvalue())["version"] == "2.1.0"


def test_sarif_out_writes_file(tmp_path):
    out = tmp_path / "lint.sarif"
    _report.write_sarif([], str(out), tool_name="graphlint")
    data = json.loads(out.read_text())
    assert data["runs"][0]["tool"]["driver"]["name"] == "graphlint"
    assert data["runs"][0]["results"] == []


def test_lifecycle_serve_producer_thread_join_in_finally():
    """The serving driver's request-queue worker shape (PR 9): a
    producer thread feeding a bounded queue is joined in ``finally`` —
    silent, because the join dominates every exit of the consumer loop
    — while the same driver without the ``finally`` leaks the thread on
    the break path and fires."""
    _assert_silent("""\
        import queue, threading

        def drive(stream, serve):
            q = queue.Queue(maxsize=4)

            def producer():
                for ids in stream:
                    q.put(ids)
                q.put(None)

            t = threading.Thread(target=producer)
            t.start()
            try:
                while True:
                    item = q.get()
                    if item is None:
                        break
                    serve(item)
            finally:
                t.join()
        """)
    _assert_fires("handle-lifecycle", """\
        import queue, threading

        def drive(stream, serve):
            q = queue.Queue(maxsize=4)
            t = threading.Thread(target=lambda: q.put(None))
            t.start()
            while True:
                item = q.get()
                if item is None:
                    return
                serve(item)
        """)
