"""Roofline extraction: trip-weighted FLOP/byte/collective accounting
validated against analytically-known programs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_analysis import (collective_bytes,
                                       computation_multipliers,
                                       shape_bytes, trip_weighted_cost,
                                       xla_cost)


def test_scan_flops_trip_weighted():
    """grad of a 30-layer linear scan wrt input = 30 dots of 128x256x256
    (fwd is DCE'd for a linear chain) — the while body must be counted 30x,
    not once (XLA's own cost_analysis counts it once; that's the bug this
    module exists to fix)."""
    def body(x, w):
        return x @ w, None

    def f(x, ws):
        y, _ = jax.lax.scan(body, x, ws)
        return jnp.sum(y)

    g = jax.grad(f)
    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    ws = jax.ShapeDtypeStruct((30, 256, 256), jnp.float32)
    compiled = jax.jit(g).lower(x, ws).compile()
    tw = trip_weighted_cost(compiled.as_text())
    per_dot = 2 * 128 * 256 * 256
    assert tw["flops"] == pytest.approx(30 * per_dot, rel=0.01)
    # XLA's counter really does undercount (regression guard for the
    # rationale; if XLA fixes this, we can drop trip weighting)
    xla = xla_cost(compiled).get("flops", 0.0)
    assert xla < tw["flops"] / 5


def test_nonlinear_scan_counts_fwd_and_bwd():
    def body(x, w):
        return jnp.tanh(x @ w), None

    def f(x, ws):
        y, _ = jax.lax.scan(body, x, ws)
        return jnp.sum(y * y)

    g = jax.grad(f)
    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((12, 128, 128), jnp.float32)
    tw = trip_weighted_cost(jax.jit(g).lower(x, ws).compile().as_text())
    per_dot = 2 * 64 * 128 * 128
    # grad is wrt x: fwd 12 dots (activations needed for tanh') + bwd dx 12
    assert tw["flops"] == pytest.approx(24 * per_dot, rel=0.05)


def test_unrolled_matches_scan_flops():
    """Trip weighting must make scan and unrolled versions agree."""
    def f_scan(x, ws):
        y, _ = jax.lax.scan(lambda c, w: (jnp.tanh(c @ w), None), x, ws)
        return y

    def f_unroll(x, ws):
        for i in range(8):
            x = jnp.tanh(x @ ws[i])
        return x

    x = jax.ShapeDtypeStruct((32, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((8, 64, 64), jnp.float32)
    tw_s = trip_weighted_cost(jax.jit(f_scan).lower(x, ws).compile().as_text())
    tw_u = trip_weighted_cost(jax.jit(f_unroll).lower(x, ws).compile().as_text())
    assert tw_s["flops"] == pytest.approx(tw_u["flops"], rel=0.01)


def test_shape_bytes_tuple():
    assert shape_bytes("(f32[4,4], bf16[8])") == 4 * 4 * 4 + 8 * 2
