"""Hot-node feature cache: state machine units (direct-mapped and
set-associative), the cache-aware fetch front end (bit-identical to the
uncached path), and the Zipf wire-slot reduction the subsystem exists
for.  The sharded-mode multiworker path runs in test_distributed.py
subprocesses (forced device counts)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.feature_cache import (CacheConfig, FeatureCache, TieredCache,
                                      cache_insert, cache_probe,
                                      compact_hit_rows, expand_hit_rows,
                                      hash_slots, hit_bitmap_words,
                                      init_cache, init_cache_state,
                                      init_worker_caches, pack_hit_bitmap,
                                      restore_worker_axis, shard_of,
                                      squeeze_worker_axis, tiered_probe,
                                      unpack_hit_bitmap)
from repro.core.generation import fetch_rows


# ---------------------------------------------------------------- state units

def test_empty_cache_never_hits():
    cache = init_cache(64, 8)
    ids = jnp.arange(100, dtype=jnp.int32)
    hit, rows = cache_probe(cache, ids, cfg=CacheConfig(64))
    assert not np.asarray(hit).any()
    assert np.abs(np.asarray(rows)).max() == 0


@pytest.mark.parametrize("assoc", [1, 2, 4])
def test_insert_then_probe_roundtrips_exact_rows(assoc):
    cfg = CacheConfig(128, admit=1, assoc=assoc)
    cache = init_cache(128, 4)
    ids = jnp.asarray([3, 17, 99, 1024], jnp.int32)
    rows = jax.random.normal(jax.random.PRNGKey(0), (4, 4))
    cache, n_ins = cache_insert(cache, ids, rows, jnp.ones(4, bool), cfg)
    assert int(n_ins) == 4
    hit, got = cache_probe(cache, ids, cfg=cfg)
    assert np.asarray(hit).all()
    np.testing.assert_array_equal(np.asarray(got), np.asarray(rows))  # bitwise
    # ids that were never inserted must miss
    hit2, _ = cache_probe(cache, jnp.asarray([5, 2048], jnp.int32), cfg=cfg)
    assert not np.asarray(hit2).any()


def test_should_mask_gates_insertion():
    """Capacity-dropped (unserved) rows must never enter the cache."""
    cfg = CacheConfig(64, admit=1)
    cache = init_cache(64, 2)
    ids = jnp.asarray([1, 2], jnp.int32)
    rows = jnp.ones((2, 2))
    cache, n_ins = cache_insert(cache, ids, rows,
                                jnp.asarray([True, False]), cfg)
    assert int(n_ins) == 1
    hit, _ = cache_probe(cache, ids, cfg=cfg)
    np.testing.assert_array_equal(np.asarray(hit), [True, False])


def test_frequency_admission_requires_repeat_offers():
    """admit=2: one-off ids never displace anything; the second offer of the
    same id at the same set installs it."""
    cfg = CacheConfig(64, admit=2)
    cache = init_cache(64, 2)
    ids = jnp.asarray([7], jnp.int32)
    rows = jnp.full((1, 2), 3.0)
    cache, n1 = cache_insert(cache, ids, rows, jnp.ones(1, bool), cfg)
    assert int(n1) == 0                       # first offer only tracks
    hit, _ = cache_probe(cache, ids, cfg=cfg)
    assert not np.asarray(hit).any()
    cache, n2 = cache_insert(cache, ids, rows, jnp.ones(1, bool), cfg)
    assert int(n2) == 1                       # second offer installs
    hit, got = cache_probe(cache, ids, cfg=cfg)
    assert np.asarray(hit).all()
    np.testing.assert_array_equal(np.asarray(got), np.asarray(rows))


def test_admission_counter_resets_on_different_candidate():
    """Alternating tail ids that collide on one set keep resetting each
    other's counters — the resident hot row survives."""
    c = 64
    cfg = CacheConfig(c, admit=2)
    cache = init_cache(c, 2)
    hot = jnp.asarray([5], jnp.int32)
    hot_row = jnp.full((1, 2), 1.0)
    for _ in range(2):
        cache, _ = cache_insert(cache, hot, hot_row, jnp.ones(1, bool), cfg)
    slot_of_hot = int(hash_slots(hot, c)[0])
    # find two distinct ids colliding with hot's slot
    pool = np.arange(10_000, dtype=np.int32)
    coll = pool[np.asarray(hash_slots(jnp.asarray(pool), c)) == slot_of_hot]
    coll = coll[coll != 5][:2]
    assert len(coll) == 2
    for _ in range(4):   # alternate the two colliders
        for cid in coll:
            cache, n = cache_insert(cache, jnp.asarray([cid]),
                                    jnp.zeros((1, 2)), jnp.ones(1, bool),
                                    cfg)
            assert int(n) == 0
    hit, got = cache_probe(cache, hot, cfg=cfg)
    assert np.asarray(hit).all()
    np.testing.assert_array_equal(np.asarray(got), np.asarray(hot_row))


def test_same_batch_slot_collision_installs_one_consistent_pair():
    """Distinct ids colliding on one direct-mapped slot within a single
    insert batch must resolve to ONE winner whose key and row agree —
    independent scatters with duplicate indices could otherwise pair id A
    with B's row and poison every later probe of A."""
    c = 64
    cfg = CacheConfig(c, admit=1)
    cache = init_cache(c, 2)
    pool = np.arange(20_000, dtype=np.int32)
    slots = np.asarray(hash_slots(jnp.asarray(pool), c))
    counts = np.bincount(slots, minlength=c)
    s = int(np.argmax(counts))
    trio = pool[slots == s][:3]
    assert len(trio) == 3
    ids = jnp.asarray(trio)
    rows = jnp.asarray(100.0 + np.arange(6, dtype=np.float32).reshape(3, 2))
    cache2, n_ins = cache_insert(cache, ids, rows, jnp.ones(3, bool), cfg)
    assert int(n_ins) == 1
    hit, got = cache_probe(cache2, ids, cfg=cfg)
    assert int(np.asarray(hit).sum()) == 1
    i = int(np.argmax(np.asarray(hit)))
    np.testing.assert_array_equal(np.asarray(got[i]), np.asarray(rows[i]))


# ------------------------------------------------------ set-associativity

def _set_colliders(n_sets: int, target_set: int, count: int,
                   exclude=()) -> np.ndarray:
    pool = np.arange(50_000, dtype=np.int32)
    sets = np.asarray(hash_slots(jnp.asarray(pool), n_sets))
    coll = pool[sets == target_set]
    coll = coll[~np.isin(coll, list(exclude))]
    assert len(coll) >= count
    return coll[:count]


def test_two_way_set_holds_two_colliding_ids():
    """The whole point of associativity: two hot ids whose hashes collide
    both stay resident in a 2-way set (direct mapping evicts one)."""
    c, a = 64, 2
    cfg = CacheConfig(c, admit=1, assoc=a)
    pair = _set_colliders(c // a, 7, 2)
    cache = init_cache(c, 2)
    rows = jnp.asarray([[1.0, 2.0], [3.0, 4.0]])
    cache, n = cache_insert(cache, jnp.asarray(pair), rows,
                            jnp.ones(2, bool), cfg)
    assert int(n) == 2       # same batch, same set -> both ways fill
    hit, got = cache_probe(cache, jnp.asarray(pair), cfg=cfg)
    assert np.asarray(hit).all()
    np.testing.assert_array_equal(np.asarray(got), np.asarray(rows))
    # the direct-mapped layout with the same state arrays keeps only one
    cfg1 = CacheConfig(c, admit=1, assoc=1)
    d_cache = init_cache(c, 2)
    d_pair = _set_colliders(c, 7, 2)
    d_cache, n1 = cache_insert(d_cache, jnp.asarray(d_pair),
                               rows, jnp.ones(2, bool), cfg1)
    assert int(n1) == 1
    d_hit, _ = cache_probe(d_cache, jnp.asarray(d_pair), cfg=cfg1)
    assert int(np.asarray(d_hit).sum()) == 1


def test_victim_selection_evicts_smallest_admission_counter():
    """4-way victim policy: the way whose candidate counter is smallest is
    the victim — a way whose resident keeps being re-offered (large
    counter) survives a new candidate's installation."""
    c, a = 64, 4
    cfg = CacheConfig(c, admit=1, assoc=a)
    n_sets = c // a
    ids = _set_colliders(n_sets, 3, 6)
    cache = init_cache(c, 2)
    # fill all 4 ways of set 3 (one batch -> ranks spread over ways)
    first4 = jnp.asarray(ids[:4])
    rows4 = jnp.asarray(np.arange(8, dtype=np.float32).reshape(4, 2))
    cache, n = cache_insert(cache, first4, rows4, jnp.ones(4, bool), cfg)
    assert int(n) == 4
    # pump one resident's counter by re-offering it as a candidate twice
    # (misses of an already-resident id cannot happen through fetch_rows,
    # so emulate contention by offering OTHER ids and re-offering one)
    keep = first4[:1]
    keep_row = rows4[:1]
    for _ in range(3):
        cache, _ = cache_insert(cache, keep, keep_row, jnp.ones(1, bool),
                                CacheConfig(c, admit=99, assoc=a))
    # now install a 5th collider: it must evict a LOW-counter way, never
    # the pumped way
    fifth = jnp.asarray(ids[4:5])
    cache, n5 = cache_insert(cache, fifth, jnp.full((1, 2), 9.0),
                             jnp.ones(1, bool), cfg)
    assert int(n5) == 1
    hit_keep, got_keep = cache_probe(cache, keep, cfg=cfg)
    assert np.asarray(hit_keep).all()
    np.testing.assert_array_equal(np.asarray(got_keep), np.asarray(keep_row))
    hit5, _ = cache_probe(cache, fifth, cfg=cfg)
    assert np.asarray(hit5).all()


def test_assoc_same_batch_set_overflow_keeps_consistent_pairs():
    """More same-set offers than ways in one batch: each installed way must
    hold a consistent (key, row) pair and the overflow is dropped."""
    c, a = 32, 2
    cfg = CacheConfig(c, admit=1, assoc=a)
    ids = _set_colliders(c // a, 5, 4)
    cache = init_cache(c, 2)
    rows = jnp.asarray(10.0 + np.arange(8, dtype=np.float32).reshape(4, 2))
    cache, n = cache_insert(cache, jnp.asarray(ids), rows,
                            jnp.ones(4, bool), cfg)
    assert int(n) == a       # one install per way, overflow dropped
    hit, got = cache_probe(cache, jnp.asarray(ids), cfg=cfg)
    assert int(np.asarray(hit).sum()) == a
    for i in np.flatnonzero(np.asarray(hit)):
        np.testing.assert_array_equal(np.asarray(got[i]), np.asarray(rows[i]))


@pytest.mark.parametrize("assoc", [2, 4])
@pytest.mark.parametrize("flip", [False, True])
def test_new_candidate_spares_inflight_candidate_way(assoc, flip):
    """A way whose candidate is mid-admission carries progress: a new
    same-set candidate must take a virgin way, not trample the in-flight
    tag (which would reset its counter with free ways available) — for
    either id ordering within the batch (the rank machinery must not route
    the new candidate onto the tagged way by off-by-one)."""
    c = 8 * assoc                 # keeps n_sets small so colliders abound
    cfg = CacheConfig(c, admit=2, assoc=assoc)
    ids = _set_colliders(c // assoc, 2, 2)
    x, y = int(ids[0]), int(ids[1])
    if flip:
        x, y = y, x
    cache = init_cache(c, 2)
    # offer X once: tagged somewhere, count 1, nothing installed
    cache, n0 = cache_insert(cache, jnp.asarray([x], jnp.int32),
                             jnp.ones((1, 2)), jnp.ones(1, bool), cfg)
    assert int(n0) == 0
    # offer X and Y together: X's second offer must install (progress
    # kept), Y must track in a DIFFERENT way
    batch = jnp.asarray([x, y], jnp.int32)
    cache, n1 = cache_insert(cache, batch, jnp.ones((2, 2)),
                             jnp.ones(2, bool), cfg)
    assert int(n1) == 1
    hit, _ = cache_probe(cache, jnp.asarray([x], jnp.int32), cfg=cfg)
    assert np.asarray(hit).all()
    assert int(np.asarray(cache.tags == y).sum()) == 1   # Y tracked too
    # Y's second offer now installs alongside X
    cache, n2 = cache_insert(cache, jnp.asarray([y], jnp.int32),
                             jnp.ones((1, 2)), jnp.ones(1, bool), cfg)
    assert int(n2) == 1
    hit2, _ = cache_probe(cache, batch, cfg=cfg)
    assert np.asarray(hit2).all()


def test_duplicate_id_offers_occupy_one_way():
    """Sharded admission hands the shard holder the SAME id from several
    source workers in one batch — it must land in exactly one way (and
    count one admission step), never clone itself across the set or evict
    unrelated residents from every way."""
    c, a = 32, 4
    cfg = CacheConfig(c, admit=1, assoc=a)
    cache = init_cache(c, 2)
    ids = jnp.asarray([77, 77, 77, 77], jnp.int32)   # 4 workers, same id
    rows = jnp.full((4, 2), 5.0)
    cache, n = cache_insert(cache, ids, rows, jnp.ones(4, bool), cfg)
    assert int(n) == 1
    assert int(np.asarray(cache.keys == 77).sum()) == 1
    hit, got = cache_probe(cache, ids[:1], cfg=cfg)
    assert np.asarray(hit).all()
    np.testing.assert_array_equal(np.asarray(got), np.asarray(rows[:1]))
    # duplicates + a distinct collider in one batch: the collider still
    # gets its own way
    sets = hash_slots(jnp.arange(50_000, dtype=jnp.int32), c // a)
    coll = np.arange(50_000)[np.asarray(sets)
                             == int(hash_slots(ids[:1], c // a)[0])]
    coll = coll[coll != 77][:1]
    batch = jnp.asarray([77, int(coll[0]), 77], jnp.int32)
    cache2, n2 = cache_insert(init_cache(c, 2), batch,
                              jnp.ones((3, 2)), jnp.ones(3, bool), cfg)
    assert int(n2) == 2
    hit2, _ = cache_probe(cache2, batch, cfg=cfg)
    assert np.asarray(hit2).all()
    # admit=2: duplicate offers in ONE batch are one tracking step, so the
    # candidate is not yet installed
    cfg2 = CacheConfig(c, admit=2, assoc=a)
    cache3, n3 = cache_insert(init_cache(c, 2), ids, rows,
                              jnp.ones(4, bool), cfg2)
    assert int(n3) == 0
    assert int(np.asarray(cache3.tags == 77).sum()) == 1


# ------------------------------------------------------------- hash guards

def test_hash_slots_rejects_non_power_of_two():
    with pytest.raises(ValueError):
        hash_slots(jnp.arange(4, dtype=jnp.int32), 100)


def test_hash_slots_degenerate_single_set():
    """n_sets == 1 would need a 32-bit shift (out of range on uint32) —
    the guard maps every id to set 0 instead of tracing UB."""
    slots = hash_slots(jnp.asarray([0, 1, 7, 2**30], jnp.int32), 1)
    np.testing.assert_array_equal(np.asarray(slots), 0)
    # a 1-row cache is usable end to end
    cfg = CacheConfig(1, admit=1)
    cache = init_cache(1, 2)
    cache, n = cache_insert(cache, jnp.asarray([42], jnp.int32),
                            jnp.ones((1, 2)), jnp.ones(1, bool), cfg)
    assert int(n) == 1
    hit, _ = cache_probe(cache, jnp.asarray([42], jnp.int32), cfg=cfg)
    assert np.asarray(hit).all()


def test_shard_of_is_balanced_and_differs_from_set_hash():
    """The shard router must spread ids over workers AND stay independent
    of the set hash — a shared mixer would collapse one shard's residents
    onto a fraction of its sets."""
    ids = jnp.arange(20_000, dtype=jnp.int32)
    for w in (2, 4, 7, 8):
        s = np.asarray(shard_of(ids, w))
        counts = np.bincount(s, minlength=w)
        assert counts.min() > 0.8 * len(ids) / w, (w, counts)
    # within one shard, the set indices still cover most sets
    n_sets = 64
    shard0 = np.asarray(ids)[np.asarray(shard_of(ids, 8)) == 0]
    sets = np.asarray(hash_slots(jnp.asarray(shard0), n_sets))
    assert len(np.unique(sets)) == n_sets


def test_probe_and_insert_reject_mismatched_layout():
    """The cfg must describe the POPULATED state: a different n_rows would
    silently probe/insert at wrong slots, so it raises instead."""
    cache = init_cache(64, 2)
    ids = jnp.asarray([1], jnp.int32)
    with pytest.raises(ValueError):
        cache_probe(cache, ids, cfg=CacheConfig(32))
    with pytest.raises(ValueError):
        cache_insert(cache, ids, jnp.ones((1, 2)), jnp.ones(1, bool),
                     CacheConfig(128))


def test_cache_config_validation():
    with pytest.raises(ValueError):
        CacheConfig(100).validated()            # not a power of two
    with pytest.raises(ValueError):
        CacheConfig(64, assoc=3).validated()    # unsupported ways
    with pytest.raises(ValueError):
        CacheConfig(64, mode="global").validated()
    assert CacheConfig(64, assoc=4, mode="sharded").validated().n_sets == 16


def test_model_config_rounds_cache_rows():
    """cache_rows validation happens at CONSTRUCTION, not trace time."""
    from repro.core.config import ModelConfig
    cfg = ModelConfig(name="t", family="gcn", cache_rows=1000)
    assert cfg.cache_rows == 1024
    cfg2 = ModelConfig(name="t", family="gcn", cache_rows=4096)
    assert cfg2.cache_rows == 4096
    with pytest.raises(ValueError):
        ModelConfig(name="t", family="gcn", cache_rows=-1)
    with pytest.raises(ValueError):
        ModelConfig(name="t", family="gcn", cache_assoc=3)
    with pytest.raises(ValueError):
        ModelConfig(name="t", family="gcn", cache_mode="bogus")
    c3 = CacheConfig.from_model(
        ModelConfig(name="t", family="gcn", cache_rows=512, cache_admit=3,
                    cache_assoc=2, cache_mode="sharded"))
    assert c3 == CacheConfig(512, 3, 2, "sharded")
    assert CacheConfig.from_model(
        ModelConfig(name="t", family="gcn", cache_rows=0)) is None


def test_worker_axis_roundtrip():
    stacked = init_worker_caches(32, 4, n_workers=1)
    c = squeeze_worker_axis(jax.tree.map(jnp.asarray, FeatureCache(*stacked)))
    assert c.keys.shape == (32,)
    r = restore_worker_axis(c)
    assert r.keys.shape == (1, 32) and r.rows.shape == (1, 32, 4)


def test_worker_axis_shape_contract_is_explicit():
    """Regression for the silent-acceptance bug: squeezing an
    already-squeezed cache used to index keys[0] — a SCALAR — and corrupt
    every downstream probe; restoring an already-stacked cache grew a
    bogus axis.  Both now raise, for the flat AND the tiered state."""
    stacked = jax.tree.map(jnp.asarray, init_worker_caches(32, 4, 1))
    per_worker = squeeze_worker_axis(stacked)
    with pytest.raises(ValueError, match="already squeezed"):
        squeeze_worker_axis(per_worker)
    with pytest.raises(ValueError, match="already\\s+stacked"):
        restore_worker_axis(stacked)
    # roundtrip identity both ways
    rt = squeeze_worker_axis(restore_worker_axis(per_worker))
    for a, b in zip(rt, per_worker):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # the worker axis must be the size-1 shard_map block, not a [W>1] stack
    with pytest.raises(ValueError, match="size 1"):
        squeeze_worker_axis(jax.tree.map(jnp.asarray,
                                         init_worker_caches(32, 4, 4)))
    # tiered state: same contract through the (l1, l2) pytree
    tcfg = CacheConfig(32, assoc=2, mode="tiered", l1_rows=8).validated()
    tstacked = jax.tree.map(jnp.asarray, init_cache_state(tcfg, 4, 1))
    tper = squeeze_worker_axis(tstacked)
    assert tper.l1.keys.shape == (8,) and tper.l2.keys.shape == (32,)
    with pytest.raises(ValueError, match="already squeezed"):
        squeeze_worker_axis(tper)
    with pytest.raises(ValueError, match="already\\s+stacked"):
        restore_worker_axis(tstacked)
    assert restore_worker_axis(tper).l1.keys.shape == (1, 8)


# ------------------------------------------------------------- tiered tier

def test_tiered_config_validation_and_tier_views():
    with pytest.raises(ValueError):
        CacheConfig(64, mode="tiered").validated()          # no L1
    with pytest.raises(ValueError):
        CacheConfig(64, mode="tiered", l1_rows=12).validated()  # not pow2
    with pytest.raises(ValueError):
        CacheConfig(64, mode="sharded", l1_rows=8).validated()  # wrong mode
    with pytest.raises(ValueError):
        CacheConfig(64, mode="tiered", l1_rows=8,
                    l1_promote=0).validated()
    cfg = CacheConfig(64, admit=2, assoc=4, mode="tiered", l1_rows=8,
                      l1_promote=3).validated()
    # tier views: L1 is a standalone replicated policy with the promotion
    # threshold as its admission knob and capped 2-way sets; L2 is the
    # pre-tiered sharded policy unchanged
    assert cfg.l1_assoc == 2
    assert cfg.l1_config() == CacheConfig(8, admit=3, assoc=2,
                                          mode="replicated")
    assert cfg.l2_config() == CacheConfig(64, admit=2, assoc=4,
                                          mode="sharded")
    assert CacheConfig(64, assoc=1, mode="tiered",
                       l1_rows=8).validated().l1_assoc == 1


def test_tiered_from_model_auto_sizes_l1():
    from repro.core.config import ModelConfig
    cfg = CacheConfig.from_model(ModelConfig(
        name="t", family="gcn", cache_rows=4096, cache_mode="tiered"))
    assert cfg.mode == "tiered" and cfg.l1_rows == 4096 // 8
    cfg2 = CacheConfig.from_model(ModelConfig(
        name="t", family="gcn", cache_rows=4096, cache_mode="tiered",
        cache_l1_rows=1000, cache_l1_promote=2))
    assert cfg2.l1_rows == 1024 and cfg2.l1_promote == 2   # rounded up
    # the auto floor respects the L1's way count: a tiny set-associative
    # tiered cache must still produce a VALID config
    tiny = CacheConfig.from_model(ModelConfig(
        name="t", family="gcn", cache_rows=8, cache_mode="tiered",
        cache_assoc=2))
    assert tiny.l1_rows == 2 and tiny.l1_assoc == 2
    # non-tiered modes IGNORE leftover L1 knobs instead of raising — the
    # launchers override cache_mode field-by-field on tiered arch configs
    # (e.g. --cache-mode sharded on graphgen-gcn-deep), so a cross-field
    # check at ModelConfig construction would break every such override
    sharded = CacheConfig.from_model(ModelConfig(
        name="t", family="gcn", cache_rows=64, cache_mode="sharded",
        cache_l1_rows=8))
    assert sharded.mode == "sharded" and sharded.l1_rows == 0
    with pytest.raises(ValueError):
        ModelConfig(name="t", family="gcn", cache_l1_promote=0)
    with pytest.raises(ValueError):
        ModelConfig(name="t", family="gcn", cache_l1_rows=-2)


def test_tiered_probe_l1_priority_and_bit_identity():
    """The fused local probe: an id resident in BOTH tiers is reported as
    an L1 hit (the cheaper tier wins), rows are verbatim copies from the
    serving tier, and the jnp and pallas paths agree bit-for-bit."""
    cfg = CacheConfig(32, admit=1, assoc=2, mode="tiered", l1_rows=8,
                      l1_promote=1).validated()
    state = TieredCache(l1=init_cache(8, 2), l2=init_cache(32, 2))
    both = jnp.asarray([3], jnp.int32)
    l2_only = jnp.asarray([100], jnp.int32)
    row_a, row_b = jnp.full((1, 2), 1.0), jnp.full((1, 2), 2.0)
    l1, _ = cache_insert(state.l1, both, row_a, jnp.ones(1, bool),
                         cfg.l1_config())
    l2, _ = cache_insert(state.l2, both, row_a, jnp.ones(1, bool),
                         cfg.l2_config())
    l2, _ = cache_insert(l2, l2_only, row_b, jnp.ones(1, bool),
                         cfg.l2_config())
    state = TieredCache(l1=l1, l2=l2)
    ids = jnp.asarray([3, 100, 999], jnp.int32)
    l1_hit, l2_hit, rows = tiered_probe(state, ids, cfg=cfg)
    np.testing.assert_array_equal(np.asarray(l1_hit), [True, False, False])
    np.testing.assert_array_equal(np.asarray(l2_hit), [False, True, False])
    np.testing.assert_array_equal(np.asarray(rows),
                                  np.asarray([[1., 1.], [2., 2.], [0., 0.]]))
    p1, p2, pr = tiered_probe(state, ids, cfg=cfg, impl="pallas")
    np.testing.assert_array_equal(np.asarray(p1), np.asarray(l1_hit))
    np.testing.assert_array_equal(np.asarray(p2), np.asarray(l2_hit))
    np.testing.assert_array_equal(np.asarray(pr), np.asarray(rows))
    # layout mismatch rejected, like the flat probe
    with pytest.raises(ValueError):
        tiered_probe(state, ids,
                     cfg=CacheConfig(32, mode="tiered", l1_rows=16))
    with pytest.raises(ValueError):
        tiered_probe(state, ids, cfg=CacheConfig(32))   # not tiered


def test_l1_promotion_requires_repeat_observations():
    """The L2 -> L1 migration gate: with l1_promote=2, one observation of
    an L2-served row only tracks it in the L1; the second installs it —
    after which the id is served with zero network (an L1 hit)."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.launch.mesh import make_local_mesh

    cfg = CacheConfig(64, admit=1, assoc=2, mode="tiered", l1_rows=16,
                      l1_promote=2).validated()
    n, d = 40, 3
    rng = np.random.default_rng(3)
    table = jnp.asarray(rng.standard_normal((n, d)).astype(np.float32))
    mesh = make_local_mesh(1, 1)

    def worker(t, i, c):
        out, c, fs, cs = fetch_rows(t, i, "data",
                                    cache=squeeze_worker_axis(c),
                                    cache_cfg=cfg)
        return (out, restore_worker_axis(c),
                jax.tree.map(lambda a: a[None], (fs, cs)))

    run = jax.jit(shard_map(
        worker, mesh=mesh, in_specs=(P(), P(), P("data")),
        out_specs=(P(), P("data"), P("data")), check_rep=False))
    state = jax.tree.map(jnp.asarray, init_cache_state(cfg, d, 1))
    ids = jnp.asarray(np.arange(10, dtype=np.int32))
    l1_hits = []
    for it in range(4):
        out, state, (fs, cs) = run(table, ids, state)
        np.testing.assert_array_equal(np.asarray(out),
                                      np.asarray(table)[:10])
        l1_hits.append(int(cs.n_l1_hits[0]))
    # it0: owner fetch (L2 admission).  it1: L2 serves -> first L1
    # observation, only tracked.  it2: probe still misses (the second
    # observation installs AFTER it2's probe).  it3: the L1 now serves
    # the stream network-free.
    assert l1_hits[0] == l1_hits[1] == l1_hits[2] == 0, l1_hits
    assert l1_hits[3] > 0, l1_hits


# --------------------------------------------------- conservation invariant

@pytest.mark.parametrize("mode", ["none", "replicated", "sharded", "tiered"])
def test_hit_conservation_invariant_adversarial_streams(mode):
    """For EVERY cache mode, ``n_l1_hits + n_local_hits + n_shard_hits +
    n_misses == n_distinct`` on each fetch — including the adversarial
    stream shapes where counter bookkeeping slips: all-duplicate,
    all-distinct, single-id, and the empty batch.  Each stream runs cold
    AND warm (the warm pass moves population between the categories; the
    sum must not move), and rows stay bit-identical throughout."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.launch.mesh import make_local_mesh

    n, d = 64, 3
    table = jnp.asarray(
        np.arange(n * d, dtype=np.float32).reshape(n, d))
    mesh = make_local_mesh(1, 1)
    cfg = None if mode == "none" else CacheConfig(
        16, admit=1, assoc=2, mode=mode,
        l1_rows=8 if mode == "tiered" else 0, l1_promote=1).validated()
    if cfg is None:
        run = jax.jit(shard_map(
            lambda t, i: fetch_rows(t, i, "data", return_stats=True),
            mesh=mesh, in_specs=(P(), P()), out_specs=P(), check_rep=False))
        state = None
    else:
        def worker(t, i, c):
            out, c, fs, cs = fetch_rows(t, i, "data",
                                        cache=squeeze_worker_axis(c),
                                        cache_cfg=cfg)
            return (out, restore_worker_axis(c),
                    jax.tree.map(lambda a: a[None], (fs, cs)))

        run = jax.jit(shard_map(
            worker, mesh=mesh, in_specs=(P(), P(), P("data")),
            out_specs=(P(), P("data"), P("data")), check_rep=False))
        state = jax.tree.map(jnp.asarray, init_cache_state(cfg, d, 1))
    streams = [
        np.full(64, 7, np.int32),          # all-duplicate
        np.arange(48, dtype=np.int32),     # all-distinct
        np.asarray([5], np.int32),         # single id
        np.zeros(0, np.int32),             # empty batch
    ]
    for ids_np in streams:
        distinct = len(np.unique(ids_np))
        for _ in range(2):                 # cold pass, then warm pass
            ids = jnp.asarray(ids_np)
            if cfg is None:
                out, fs = run(table, ids)
                np.testing.assert_array_equal(np.asarray(out),
                                              np.asarray(table)[ids_np])
                # no cache tier: everything distinct is a "miss"
                assert int(fs.n_unique) == distinct
                continue
            out, state, (fs, cs) = run(table, ids, state)
            np.testing.assert_array_equal(np.asarray(out),
                                          np.asarray(table)[ids_np])
            l1 = int(cs.n_l1_hits[0])
            loc = int(cs.n_local_hits[0])
            sh = int(cs.n_shard_hits[0])
            ms = int(cs.n_misses[0])
            assert l1 + loc + sh + ms == distinct, (
                mode, ids_np.shape, l1, loc, sh, ms, distinct)
            assert int(cs.n_hits[0]) == l1 + loc + sh
            assert l1 >= 0 and loc >= 0 and sh >= 0 and ms >= 0
            if mode != "tiered":
                assert l1 == 0
            # single worker owns every shard: nothing is remote
            assert sh == 0


# ------------------------------------------------- cache-aware fetch_rows

_FETCH_FNS = {}


def _fetch_fn(kind, admit=1, assoc=1, dedup=True):
    """Jitted single-worker fetch wrappers, cached so the hypothesis sweep
    and the 20-iteration Zipf run compile once per shape."""
    key = (kind, admit, assoc, dedup)
    if key in _FETCH_FNS:
        return _FETCH_FNS[key]
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.launch.mesh import make_local_mesh

    mesh = make_local_mesh(1, 1)
    if kind == "plain":
        fn = jax.jit(shard_map(
            lambda t, i: fetch_rows(t, i, "data", dedup=dedup),
            mesh=mesh, in_specs=(P(), P()), out_specs=P(), check_rep=False))
    else:
        def worker(t, i, c):
            cfg = CacheConfig(
                squeeze_worker_axis(c).n_rows, admit=admit, assoc=assoc)
            out, c, fs, cs = fetch_rows(t, i, "data",
                                        cache=squeeze_worker_axis(c),
                                        cache_cfg=cfg)
            return (out, restore_worker_axis(c),
                    jax.tree.map(lambda a: a[None], (fs, cs)))

        fn = jax.jit(shard_map(
            worker, mesh=mesh, in_specs=(P(), P(), P("data")),
            out_specs=(P(), P("data"), P("data")), check_rep=False))
    _FETCH_FNS[key] = fn
    return fn


def _run_fetch(table, ids, *, cache=None, admit=1, assoc=1, dedup=True):
    if cache is None:
        return _fetch_fn("plain", dedup=dedup)(table, ids)
    return _fetch_fn("cached", admit=admit, assoc=assoc)(table, ids, cache)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_cached_fetch_bit_identical_to_uncached(seed):
    """THE cache contract: across several iterations of a duplicated,
    recurring request stream, the cached path returns bit-identical rows to
    the uncached path (and to the table itself)."""
    rng = np.random.default_rng(seed)
    n, d = 40, 5
    table = jnp.asarray(rng.standard_normal((n, d)).astype(np.float32))
    cache = jax.tree.map(jnp.asarray, init_worker_caches(16, d, 1))
    for _ in range(4):
        ids = jnp.asarray(rng.integers(0, n, 50, dtype=np.int32))
        want = _run_fetch(table, ids)
        got, cache, (fs, cs) = _run_fetch(table, ids, cache=cache, admit=1)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        np.testing.assert_array_equal(np.asarray(got),
                                      np.asarray(table)[np.asarray(ids)])
        assert int(fs.n_dropped[0]) == 0


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1), st.sampled_from([2, 4]))
def test_cached_fetch_bit_identical_set_associative(seed, assoc):
    """The bit-identity contract holds for every associativity."""
    rng = np.random.default_rng(seed)
    n, d = 48, 3
    table = jnp.asarray(rng.standard_normal((n, d)).astype(np.float32))
    cache = jax.tree.map(jnp.asarray, init_worker_caches(16, d, 1))
    for _ in range(3):
        ids = jnp.asarray(rng.integers(0, n, 40, dtype=np.int32))
        got, cache, (fs, cs) = _run_fetch(table, ids, cache=cache,
                                          admit=1, assoc=assoc)
        np.testing.assert_array_equal(np.asarray(got),
                                      np.asarray(table)[np.asarray(ids)])
        assert int(fs.n_dropped[0]) == 0


def test_cached_fetch_hits_accumulate_and_route_count_drops():
    """Second identical request stream: hits appear, routed uniques fall,
    and n_requests/n_unique telemetry stays consistent."""
    rng = np.random.default_rng(0)
    n, d = 64, 3
    table = jnp.asarray(rng.standard_normal((n, d)).astype(np.float32))
    ids = jnp.asarray(rng.integers(0, n, 128, dtype=np.int32))
    n_uniq = len(np.unique(np.asarray(ids)))
    cache = jax.tree.map(jnp.asarray, init_worker_caches(256, d, 1))
    _, cache, (fs1, cs1) = _run_fetch(table, ids, cache=cache, admit=1)
    assert int(cs1.n_hits[0]) == 0
    assert int(fs1.n_unique[0]) == int(cs1.n_misses[0]) == n_uniq
    assert int(cs1.n_inserted[0]) == n_uniq
    got, cache, (fs2, cs2) = _run_fetch(table, ids, cache=cache, admit=1)
    assert int(cs2.n_hits[0]) > 0
    assert int(fs2.n_unique[0]) == n_uniq - int(cs2.n_hits[0])
    # replicated mode: every hit is local, bytes_saved counts all of them
    assert int(cs2.n_local_hits[0]) == int(cs2.n_hits[0])
    assert int(cs2.n_shard_hits[0]) == 0
    assert int(cs2.bytes_saved[0]) == int(cs2.n_hits[0]) * d * 4
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(table)[np.asarray(ids)])


def test_cache_requires_dedup():
    table = jnp.zeros((8, 2))
    cache = init_cache(8, 2)
    with pytest.raises(ValueError):
        # graphlint: disable=cacheconfig-required  # asserting this exact rejection path
        fetch_rows(table, jnp.zeros(4, jnp.int32), "data", dedup=False,
                   cache=cache)


def test_cache_requires_cfg():
    """A cache state without its policy object must be rejected — probing
    an assoc>1/sharded state under a guessed default layout would silently
    lose the residents instead of erroring."""
    table = jnp.zeros((8, 2))
    cache = init_cache(8, 2)
    with pytest.raises(ValueError):
        # graphlint: disable=cacheconfig-required  # the missing cfg IS what this test asserts
        fetch_rows(table, jnp.zeros(4, jnp.int32), "data", cache=cache)


def test_pallas_probe_impl_serves_cached_fetch():
    """set_probe_impl('pallas') routes the production fetch front end
    through the fused kernel — rows stay bit-identical to the table."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.core.feature_cache import set_probe_impl
    from repro.launch.mesh import make_local_mesh

    rng = np.random.default_rng(2)
    n, d = 64, 8
    table = jnp.asarray(rng.standard_normal((n, d)).astype(np.float32))
    ids = jnp.asarray(rng.integers(0, n, 96, dtype=np.int32))
    mesh = make_local_mesh(1, 1)

    def worker(t, i, c):
        out, c, fs, cs = fetch_rows(
            t, i, "data", cache=squeeze_worker_axis(c),
            cache_cfg=CacheConfig(32, admit=1, assoc=2))
        return (out, restore_worker_axis(c),
                jax.tree.map(lambda a: a[None], (fs, cs)))

    set_probe_impl("pallas")
    try:
        run = jax.jit(shard_map(
            worker, mesh=mesh, in_specs=(P(), P(), P("data")),
            out_specs=(P(), P("data"), P("data")), check_rep=False))
        cache = jax.tree.map(jnp.asarray, init_worker_caches(32, d, 1))
        _, cache, _ = run(table, ids, cache)
        got, cache, (fs, cs) = run(table, ids, cache)
    finally:
        set_probe_impl("jnp")
    assert int(cs.n_hits[0]) > 0
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(table)[np.asarray(ids)])
    with pytest.raises(ValueError):
        set_probe_impl("cuda")


# ------------------------------------------------- probe-round wire codec

@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_bitmap_pack_unpack_roundtrip(seed):
    """Property: pack then unpack reproduces ANY hit vector exactly, for
    slot counts on and off the 32-bit word boundary, and the packed form
    occupies exactly ceil(R/32) uint32 words."""
    rng = np.random.default_rng(seed)
    r = int(rng.integers(1, 130))
    b = int(rng.integers(1, 5))
    hit = jnp.asarray(rng.random((b, r)) < rng.random())
    words = pack_hit_bitmap(hit)
    assert words.dtype == jnp.uint32
    assert words.shape == (b, hit_bitmap_words(r)) == (b, -(-r // 32))
    np.testing.assert_array_equal(np.asarray(unpack_hit_bitmap(words, r)),
                                  np.asarray(hit))


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_compact_expand_roundtrip_property(seed):
    """Property: expand(compact(hit, rows)) reproduces the rows of every
    KEPT slot bit-for-bit and zeros everywhere else, where kept is hit
    truncated to the first hit_cap hits per destination."""
    rng = np.random.default_rng(seed)
    b, r, d = (int(rng.integers(1, 5)), int(rng.integers(1, 80)),
               int(rng.integers(1, 6)))
    hit_cap = int(rng.integers(0, r + 20))
    hit = jnp.asarray(rng.random((b, r)) < rng.random())
    rows = jnp.asarray(rng.standard_normal((b, r, d)).astype(np.float32))
    rows = jnp.where(hit[..., None], rows, 0)
    kept, payload = compact_hit_rows(hit, rows, hit_cap)
    assert payload.shape == (b, min(hit_cap, r), d)
    # kept truncates each destination's hits at hit_cap, in slot order
    want_kept = np.asarray(hit) & (np.cumsum(np.asarray(hit), axis=-1)
                                   <= hit_cap)
    np.testing.assert_array_equal(np.asarray(kept), want_kept)
    out = expand_hit_rows(kept, payload)
    np.testing.assert_array_equal(
        np.asarray(out), np.where(want_kept[..., None], np.asarray(rows), 0))


def test_compact_zero_hit_batch_ships_empty_payload():
    """All-miss destination: the bitmap is all-zero words and the payload
    carries nothing but zeros — the compact response of a cold cache."""
    hit = jnp.zeros((3, 40), jnp.bool_)
    rows = jnp.ones((3, 40, 4))
    kept, payload = compact_hit_rows(hit, rows, 8)
    assert not np.asarray(kept).any()
    assert np.abs(np.asarray(payload)).max() == 0
    words = pack_hit_bitmap(kept)
    assert np.asarray(words).sum() == 0
    assert np.abs(np.asarray(expand_hit_rows(kept, payload))).max() == 0


def test_compact_all_hit_batch_payload_equals_rows():
    """All-hit destination at hit_cap == R: nothing demotes and the
    payload IS the dense response, in slot order."""
    rng = np.random.default_rng(3)
    rows = jnp.asarray(rng.standard_normal((2, 24, 5)).astype(np.float32))
    hit = jnp.ones((2, 24), jnp.bool_)
    kept, payload = compact_hit_rows(hit, rows, 24)
    assert np.asarray(kept).all()
    np.testing.assert_array_equal(np.asarray(payload), np.asarray(rows))
    np.testing.assert_array_equal(
        np.asarray(expand_hit_rows(kept, payload)), np.asarray(rows))


def test_compact_overflow_demotes_in_slot_order():
    """hit_cap overflow: exactly the FIRST hit_cap hits (slot order)
    survive; demoted slots read back as misses after the roundtrip —
    the requester owner-fetches them, never sees wrong rows."""
    hit = jnp.asarray([[True, False, True, True, True, False, True, True]])
    rows = jnp.arange(8, dtype=jnp.float32).reshape(1, 8, 1) + 1.0
    kept, payload = compact_hit_rows(hit, rows, 3)
    np.testing.assert_array_equal(
        np.asarray(kept),
        [[True, False, True, True, False, False, False, False]])
    np.testing.assert_array_equal(np.asarray(payload).ravel(), [1., 3., 4.])
    out = expand_hit_rows(kept, payload)
    np.testing.assert_array_equal(np.asarray(out).ravel(),
                                  [1., 0., 3., 4., 0., 0., 0., 0.])


def test_unpack_rejects_mismatched_word_count():
    with pytest.raises(ValueError):
        unpack_hit_bitmap(jnp.zeros((2, 3), jnp.uint32), 32)


def test_wire_config_validation():
    """CacheConfig and ModelConfig both reject unknown wire formats and
    negative hit caps at construction, and thread valid ones through."""
    from repro.core.config import ModelConfig

    with pytest.raises(ValueError):
        CacheConfig(64, wire="zstd").validated()
    with pytest.raises(ValueError):
        CacheConfig(64, hit_cap=-1).validated()
    cfg = CacheConfig(64, mode="tiered", l1_rows=8, wire="compact",
                      hit_cap=40).validated()
    # the wire travels with the L2 tier view (whose probe round it is)
    assert cfg.l2_config().wire == "compact"
    assert cfg.l2_config().hit_cap == 40
    with pytest.raises(ValueError):
        ModelConfig(name="x", family="gcn", cache_wire="zstd")
    with pytest.raises(ValueError):
        ModelConfig(name="x", family="gcn", cache_hit_cap=-2)
    m = ModelConfig(name="x", family="gcn", cache_rows=64,
                    cache_mode="sharded", cache_wire="dense", cache_hit_cap=7)
    cc = CacheConfig.from_model(m)
    assert cc.wire == "dense" and cc.hit_cap == 7


def test_zipf_wire_slot_reduction_meets_criterion():
    """Acceptance anchor: Zipf(1.1) stream, cache_rows=4096, >= 20
    iterations -> >= 30% fewer routed unique requests than cache-off."""
    from benchmarks.feature_cache import zipf_requests

    rng = np.random.default_rng(1)
    n, d, r, iters = 20_000, 4, 4_096, 20
    table = jnp.asarray(rng.standard_normal((n, d)).astype(np.float32))
    streams = [jnp.asarray(zipf_requests(rng, n, r)) for _ in range(iters)]
    base = 0
    for ids in streams:
        base += len(np.unique(np.asarray(ids)))
    cache = jax.tree.map(jnp.asarray, init_worker_caches(4096, d, 1))
    routed = 0
    for ids in streams:
        got, cache, (fs, _) = _run_fetch(table, ids, cache=cache, admit=2)
        routed += int(fs.n_unique[0])
        np.testing.assert_array_equal(np.asarray(got),
                                      np.asarray(table)[np.asarray(ids)])
    assert routed < 0.7 * base, (routed, base)
