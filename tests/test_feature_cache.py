"""Hot-node feature cache: state machine units, the cache-aware fetch
front end (bit-identical to the uncached path), and the Zipf wire-slot
reduction the subsystem exists for."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.feature_cache import (FeatureCache, cache_insert, cache_probe,
                                      hash_slots, init_cache,
                                      init_worker_caches, restore_worker_axis,
                                      squeeze_worker_axis)
from repro.core.generation import fetch_rows


# ---------------------------------------------------------------- state units

def test_empty_cache_never_hits():
    cache = init_cache(64, 8)
    ids = jnp.arange(100, dtype=jnp.int32)
    hit, rows = cache_probe(cache, ids)
    assert not np.asarray(hit).any()
    assert np.abs(np.asarray(rows)).max() == 0


def test_insert_then_probe_roundtrips_exact_rows():
    cache = init_cache(128, 4)
    ids = jnp.asarray([3, 17, 99, 1024], jnp.int32)
    rows = jax.random.normal(jax.random.PRNGKey(0), (4, 4))
    cache, n_ins = cache_insert(cache, ids, rows, jnp.ones(4, bool), admit=1)
    assert int(n_ins) == 4
    hit, got = cache_probe(cache, ids)
    assert np.asarray(hit).all()
    np.testing.assert_array_equal(np.asarray(got), np.asarray(rows))  # bitwise
    # ids that were never inserted must miss
    hit2, _ = cache_probe(cache, jnp.asarray([5, 2048], jnp.int32))
    assert not np.asarray(hit2).any()


def test_should_mask_gates_insertion():
    """Capacity-dropped (unserved) rows must never enter the cache."""
    cache = init_cache(64, 2)
    ids = jnp.asarray([1, 2], jnp.int32)
    rows = jnp.ones((2, 2))
    cache, n_ins = cache_insert(cache, ids, rows,
                               jnp.asarray([True, False]), admit=1)
    assert int(n_ins) == 1
    hit, _ = cache_probe(cache, ids)
    np.testing.assert_array_equal(np.asarray(hit), [True, False])


def test_frequency_admission_requires_repeat_offers():
    """admit=2: one-off ids never displace anything; the second offer of the
    same id at the same slot installs it."""
    cache = init_cache(64, 2)
    ids = jnp.asarray([7], jnp.int32)
    rows = jnp.full((1, 2), 3.0)
    cache, n1 = cache_insert(cache, ids, rows, jnp.ones(1, bool), admit=2)
    assert int(n1) == 0                       # first offer only tracks
    hit, _ = cache_probe(cache, ids)
    assert not np.asarray(hit).any()
    cache, n2 = cache_insert(cache, ids, rows, jnp.ones(1, bool), admit=2)
    assert int(n2) == 1                       # second offer installs
    hit, got = cache_probe(cache, ids)
    assert np.asarray(hit).all()
    np.testing.assert_array_equal(np.asarray(got), np.asarray(rows))


def test_admission_counter_resets_on_different_candidate():
    """Alternating tail ids that collide on one slot keep resetting each
    other's counters — the resident hot row survives."""
    c = 64
    cache = init_cache(c, 2)
    hot = jnp.asarray([5], jnp.int32)
    hot_row = jnp.full((1, 2), 1.0)
    for _ in range(2):
        cache, _ = cache_insert(cache, hot, hot_row, jnp.ones(1, bool), admit=2)
    slot_of_hot = int(hash_slots(hot, c)[0])
    # find two distinct ids colliding with hot's slot
    pool = np.arange(10_000, dtype=np.int32)
    coll = pool[np.asarray(hash_slots(jnp.asarray(pool), c)) == slot_of_hot]
    coll = coll[coll != 5][:2]
    assert len(coll) == 2
    for _ in range(4):   # alternate the two colliders
        for cid in coll:
            cache, n = cache_insert(cache, jnp.asarray([cid]),
                                    jnp.zeros((1, 2)), jnp.ones(1, bool),
                                    admit=2)
            assert int(n) == 0
    hit, got = cache_probe(cache, hot)
    assert np.asarray(hit).all()
    np.testing.assert_array_equal(np.asarray(got), np.asarray(hot_row))


def test_same_batch_slot_collision_installs_one_consistent_pair():
    """Distinct ids colliding on one slot within a single insert batch must
    resolve to ONE winner whose key and row agree — independent scatters
    with duplicate indices could otherwise pair id A with B's row and
    poison every later probe of A."""
    c = 64
    cache = init_cache(c, 2)
    pool = np.arange(20_000, dtype=np.int32)
    slots = np.asarray(hash_slots(jnp.asarray(pool), c))
    counts = np.bincount(slots, minlength=c)
    s = int(np.argmax(counts))
    trio = pool[slots == s][:3]
    assert len(trio) == 3
    ids = jnp.asarray(trio)
    rows = jnp.asarray(100.0 + np.arange(6, dtype=np.float32).reshape(3, 2))
    cache2, n_ins = cache_insert(cache, ids, rows, jnp.ones(3, bool), admit=1)
    assert int(n_ins) == 1
    hit, got = cache_probe(cache2, ids)
    assert int(np.asarray(hit).sum()) == 1
    i = int(np.argmax(np.asarray(hit)))
    np.testing.assert_array_equal(np.asarray(got[i]), np.asarray(rows[i]))


def test_hash_slots_rejects_non_power_of_two():
    with pytest.raises(ValueError):
        hash_slots(jnp.arange(4, dtype=jnp.int32), 100)


def test_worker_axis_roundtrip():
    stacked = init_worker_caches(32, 4, n_workers=1)
    c = squeeze_worker_axis(jax.tree.map(jnp.asarray, FeatureCache(*stacked)))
    assert c.keys.shape == (32,)
    r = restore_worker_axis(c)
    assert r.keys.shape == (1, 32) and r.rows.shape == (1, 32, 4)


# ------------------------------------------------- cache-aware fetch_rows

_FETCH_FNS = {}


def _fetch_fn(kind, admit=1, dedup=True):
    """Jitted single-worker fetch wrappers, cached so the hypothesis sweep
    and the 20-iteration Zipf run compile once per shape."""
    key = (kind, admit, dedup)
    if key in _FETCH_FNS:
        return _FETCH_FNS[key]
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.launch.mesh import make_local_mesh

    mesh = make_local_mesh(1, 1)
    if kind == "plain":
        fn = jax.jit(shard_map(
            lambda t, i: fetch_rows(t, i, "data", dedup=dedup),
            mesh=mesh, in_specs=(P(), P()), out_specs=P(), check_rep=False))
    else:
        def worker(t, i, c):
            out, c, fs, cs = fetch_rows(t, i, "data",
                                        cache=squeeze_worker_axis(c),
                                        cache_admit=admit)
            return (out, restore_worker_axis(c),
                    jax.tree.map(lambda a: a[None], (fs, cs)))

        fn = jax.jit(shard_map(
            worker, mesh=mesh, in_specs=(P(), P(), P("data")),
            out_specs=(P(), P("data"), P("data")), check_rep=False))
    _FETCH_FNS[key] = fn
    return fn


def _run_fetch(table, ids, *, cache=None, admit=1, dedup=True):
    if cache is None:
        return _fetch_fn("plain", dedup=dedup)(table, ids)
    return _fetch_fn("cached", admit=admit)(table, ids, cache)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_cached_fetch_bit_identical_to_uncached(seed):
    """THE cache contract: across several iterations of a duplicated,
    recurring request stream, the cached path returns bit-identical rows to
    the uncached path (and to the table itself)."""
    rng = np.random.default_rng(seed)
    n, d = 40, 5
    table = jnp.asarray(rng.standard_normal((n, d)).astype(np.float32))
    cache = jax.tree.map(jnp.asarray, init_worker_caches(16, d, 1))
    for _ in range(4):
        ids = jnp.asarray(rng.integers(0, n, 50, dtype=np.int32))
        want = _run_fetch(table, ids)
        got, cache, (fs, cs) = _run_fetch(table, ids, cache=cache, admit=1)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        np.testing.assert_array_equal(np.asarray(got),
                                      np.asarray(table)[np.asarray(ids)])
        assert int(fs.n_dropped[0]) == 0


def test_cached_fetch_hits_accumulate_and_route_count_drops():
    """Second identical request stream: hits appear, routed uniques fall,
    and n_requests/n_unique telemetry stays consistent."""
    rng = np.random.default_rng(0)
    n, d = 64, 3
    table = jnp.asarray(rng.standard_normal((n, d)).astype(np.float32))
    ids = jnp.asarray(rng.integers(0, n, 128, dtype=np.int32))
    n_uniq = len(np.unique(np.asarray(ids)))
    cache = jax.tree.map(jnp.asarray, init_worker_caches(256, d, 1))
    _, cache, (fs1, cs1) = _run_fetch(table, ids, cache=cache, admit=1)
    assert int(cs1.n_hits[0]) == 0
    assert int(fs1.n_unique[0]) == int(cs1.n_misses[0]) == n_uniq
    assert int(cs1.n_inserted[0]) == n_uniq
    got, cache, (fs2, cs2) = _run_fetch(table, ids, cache=cache, admit=1)
    assert int(cs2.n_hits[0]) > 0
    assert int(fs2.n_unique[0]) == n_uniq - int(cs2.n_hits[0])
    assert int(cs2.bytes_saved[0]) == int(cs2.n_hits[0]) * d * 4
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(table)[np.asarray(ids)])


def test_cache_requires_dedup():
    table = jnp.zeros((8, 2))
    cache = init_cache(8, 2)
    with pytest.raises(ValueError):
        fetch_rows(table, jnp.zeros(4, jnp.int32), "data", dedup=False,
                   cache=cache)


def test_pallas_probe_impl_serves_cached_fetch():
    """set_probe_impl('pallas') routes the production fetch front end
    through the fused kernel — rows stay bit-identical to the table."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.core.feature_cache import set_probe_impl
    from repro.launch.mesh import make_local_mesh

    rng = np.random.default_rng(2)
    n, d = 64, 8
    table = jnp.asarray(rng.standard_normal((n, d)).astype(np.float32))
    ids = jnp.asarray(rng.integers(0, n, 96, dtype=np.int32))
    mesh = make_local_mesh(1, 1)

    def worker(t, i, c):
        out, c, fs, cs = fetch_rows(t, i, "data",
                                    cache=squeeze_worker_axis(c),
                                    cache_admit=1)
        return (out, restore_worker_axis(c),
                jax.tree.map(lambda a: a[None], (fs, cs)))

    set_probe_impl("pallas")
    try:
        run = jax.jit(shard_map(
            worker, mesh=mesh, in_specs=(P(), P(), P("data")),
            out_specs=(P(), P("data"), P("data")), check_rep=False))
        cache = jax.tree.map(jnp.asarray, init_worker_caches(32, d, 1))
        _, cache, _ = run(table, ids, cache)
        got, cache, (fs, cs) = run(table, ids, cache)
    finally:
        set_probe_impl("jnp")
    assert int(cs.n_hits[0]) > 0
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(table)[np.asarray(ids)])
    with pytest.raises(ValueError):
        set_probe_impl("cuda")


def test_zipf_wire_slot_reduction_meets_criterion():
    """Acceptance anchor: Zipf(1.1) stream, cache_rows=4096, >= 20
    iterations -> >= 30% fewer routed unique requests than cache-off."""
    from benchmarks.feature_cache import zipf_requests

    rng = np.random.default_rng(1)
    n, d, r, iters = 20_000, 4, 4_096, 20
    table = jnp.asarray(rng.standard_normal((n, d)).astype(np.float32))
    streams = [jnp.asarray(zipf_requests(rng, n, r)) for _ in range(iters)]
    base = 0
    for ids in streams:
        base += len(np.unique(np.asarray(ids)))
    cache = jax.tree.map(jnp.asarray, init_worker_caches(4096, d, 1))
    routed = 0
    for ids in streams:
        got, cache, (fs, _) = _run_fetch(table, ids, cache=cache, admit=2)
        routed += int(fs.n_unique[0])
        np.testing.assert_array_equal(np.asarray(got),
                                      np.asarray(table)[np.asarray(ids)])
    assert routed < 0.7 * base, (routed, base)
