"""Edge-centric generation primitives (single-worker units; the multi-worker
integration runs in test_distributed.py subprocesses)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.baselines import (edge_centric_sample, node_centric_sample,
                                  sql_like_sample)
from repro.core.generation import Candidates, fetch_rows, local_candidates, merge_topk
from repro.graph.synthetic import powerlaw_graph


@pytest.fixture(scope="module")
def graph():
    return powerlaw_graph(400, avg_degree=6, n_hot=2, hot_degree=80, seed=0)


def test_local_candidates_are_real_neighbors(graph):
    indptr = jnp.asarray(graph.indptr)
    indices = jnp.asarray(graph.indices)
    frontier = jnp.arange(50, dtype=jnp.int32)
    cand = local_candidates(indptr, indices, frontier, 8, jax.random.PRNGKey(0))
    ids, keys = np.asarray(cand.ids), np.asarray(cand.keys)
    for i in range(50):
        nbrs = set(graph.indices[graph.indptr[i]:graph.indptr[i + 1]].tolist())
        deg = len(graph.indices[graph.indptr[i]:graph.indptr[i + 1]])
        for k in range(8):
            if np.isfinite(keys[i, k]):
                assert ids[i, k] in nbrs
        assert np.isfinite(keys[i]).all() == (deg > 0)


def test_merge_topk_keeps_k_smallest():
    a = Candidates(ids=jnp.array([[1, 2, 3]]), keys=jnp.array([[0.5, 2.0, 9.0]]))
    b = Candidates(ids=jnp.array([[4, 5, 6]]), keys=jnp.array([[0.1, 3.0, jnp.inf]]))
    m = merge_topk(a, b)
    np.testing.assert_allclose(
        sorted(np.asarray(m.keys)[0].tolist()), [0.1, 0.5, 2.0], rtol=1e-6
    )
    assert set(np.asarray(m.ids)[0].tolist()) == {4, 1, 2}


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_merge_topk_associative(seed):
    """Associativity is what licenses the butterfly tree reduction."""
    rng = np.random.default_rng(seed)
    k = 4
    def rand_cand():
        return Candidates(
            ids=jnp.asarray(rng.integers(0, 100, (2, k), dtype=np.int32)),
            keys=jnp.asarray(rng.uniform(0, 10, (2, k)).astype(np.float32)),
        )
    a, b, c = rand_cand(), rand_cand(), rand_cand()
    left = merge_topk(merge_topk(a, b), c)
    right = merge_topk(a, merge_topk(b, c))
    np.testing.assert_allclose(
        np.sort(left.keys, axis=-1), np.sort(right.keys, axis=-1), rtol=1e-6
    )


def test_fetch_rows_single_worker_is_gather():
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.launch.mesh import make_local_mesh

    mesh = make_local_mesh(1, 1)
    table = jnp.arange(40, dtype=jnp.float32).reshape(20, 2)
    ids = jnp.array([3, 19, 0, 7], dtype=jnp.int32)
    out = shard_map(
        lambda t, i: fetch_rows(t, i, "data"),
        mesh=mesh, in_specs=(P(), P()), out_specs=P(), check_rep=False,
    )(table, ids)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(table)[np.asarray(ids)])


def test_baselines_agree_on_sampled_set_validity(graph):
    """All three strategies must return genuine neighbors — they differ in
    COST (the 27x), not in correctness."""
    indptr = jnp.asarray(graph.indptr)
    indices = jnp.asarray(graph.indices)
    src, dst = graph.edge_list()
    frontier = jnp.arange(20, dtype=jnp.int32)
    k = 5
    rng = jax.random.PRNGKey(1)
    adj = {v: set(graph.indices[graph.indptr[v]:graph.indptr[v+1]].tolist())
           for v in range(20)}
    for name, (ids, mask) in {
        "sql": sql_like_sample(jnp.asarray(src), jnp.asarray(dst), frontier, k, rng),
        "node": node_centric_sample(indptr, indices, frontier, k, rng,
                                    max_degree=int(graph.degrees().max())),
        "edge": edge_centric_sample(indptr, indices, frontier, k, rng),
    }.items():
        ids, mask = np.asarray(ids), np.asarray(mask)
        for i in range(20):
            got = set(ids[i][mask[i]].tolist())
            assert got.issubset(adj[i]), (name, i, got, adj[i])
            if adj[i]:
                assert mask[i].any(), (name, i)
