"""Edge-centric generation primitives (single-worker units; the multi-worker
integration runs in test_distributed.py subprocesses)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.baselines import (edge_centric_sample, node_centric_sample,
                                  sql_like_sample)
from repro.core.generation import (Candidates, dedup_requests, fetch_rows,
                                   local_candidates, merge_topk)
from repro.graph.synthetic import powerlaw_graph


@pytest.fixture(scope="module")
def graph():
    return powerlaw_graph(400, avg_degree=6, n_hot=2, hot_degree=80, seed=0)


def test_local_candidates_are_real_neighbors(graph):
    indptr = jnp.asarray(graph.indptr)
    indices = jnp.asarray(graph.indices)
    frontier = jnp.arange(50, dtype=jnp.int32)
    cand = local_candidates(indptr, indices, frontier, 8, jax.random.PRNGKey(0))
    ids, keys = np.asarray(cand.ids), np.asarray(cand.keys)
    for i in range(50):
        nbrs = set(graph.indices[graph.indptr[i]:graph.indptr[i + 1]].tolist())
        deg = len(graph.indices[graph.indptr[i]:graph.indptr[i + 1]])
        for k in range(8):
            if np.isfinite(keys[i, k]):
                assert ids[i, k] in nbrs
        assert np.isfinite(keys[i]).all() == (deg > 0)


def test_merge_topk_keeps_k_smallest():
    a = Candidates(ids=jnp.array([[1, 2, 3]]), keys=jnp.array([[0.5, 2.0, 9.0]]))
    b = Candidates(ids=jnp.array([[4, 5, 6]]), keys=jnp.array([[0.1, 3.0, jnp.inf]]))
    m = merge_topk(a, b)
    np.testing.assert_allclose(
        sorted(np.asarray(m.keys)[0].tolist()), [0.1, 0.5, 2.0], rtol=1e-6
    )
    assert set(np.asarray(m.ids)[0].tolist()) == {4, 1, 2}


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_merge_topk_associative(seed):
    """Associativity is what licenses the butterfly tree reduction."""
    rng = np.random.default_rng(seed)
    k = 4
    def rand_cand():
        return Candidates(
            ids=jnp.asarray(rng.integers(0, 100, (2, k), dtype=np.int32)),
            keys=jnp.asarray(rng.uniform(0, 10, (2, k)).astype(np.float32)),
        )
    a, b, c = rand_cand(), rand_cand(), rand_cand()
    left = merge_topk(merge_topk(a, b), c)
    right = merge_topk(a, merge_topk(b, c))
    np.testing.assert_allclose(
        np.sort(left.keys, axis=-1), np.sort(right.keys, axis=-1), rtol=1e-6
    )


def test_fetch_rows_single_worker_is_gather():
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.launch.mesh import make_local_mesh

    mesh = make_local_mesh(1, 1)
    table = jnp.arange(40, dtype=jnp.float32).reshape(20, 2)
    ids = jnp.array([3, 19, 0, 7], dtype=jnp.int32)
    out = shard_map(
        lambda t, i: fetch_rows(t, i, "data"),
        mesh=mesh, in_specs=(P(), P()), out_specs=P(), check_rep=False,
    )(table, ids)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(table)[np.asarray(ids)])


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_dedup_requests_invariants(seed):
    """The static-shape unique front end: each distinct id occupies exactly
    one wire slot (this is what bounds all_to_all traffic by n_unique
    instead of b*(1+k1+k1*k2))."""
    rng = np.random.default_rng(seed)
    r = int(rng.integers(1, 200))
    ids = jnp.asarray(rng.integers(0, 40, r, dtype=np.int32))
    uniq, inverse, valid, n_unique = jax.jit(dedup_requests)(ids)
    uniq, inverse, valid = np.asarray(uniq), np.asarray(inverse), np.asarray(valid)
    n_unique = int(n_unique)
    assert n_unique == len(np.unique(np.asarray(ids)))
    assert valid.sum() == n_unique          # wire slots == distinct ids
    np.testing.assert_array_equal(uniq[inverse], np.asarray(ids))
    assert inverse.max() < n_unique


@pytest.mark.parametrize("ids", [
    np.full(64, 7),                      # all-identical ids
    np.array([13]),                      # single-element input
    np.array([13, 13]),                  # smallest duplicated input
    np.arange(50),                       # already sorted, all distinct
    np.arange(50)[::-1].copy(),          # reverse-sorted, all distinct
    np.array([0, 159, 80, 0, 159, 42]),  # ids spanning the full shard range
    np.array([0]),                       # single id zero (sentinel-adjacent)
], ids=["all-identical", "singleton", "duplicated-pair", "sorted",
        "reverse-sorted", "shard-range", "zero"])
def test_dedup_requests_edge_cases(ids):
    """Boundary inputs for the static-shape unique front end."""
    ids_j = jnp.asarray(ids.astype(np.int32))
    uniq, inverse, valid, n_unique = jax.jit(dedup_requests)(ids_j)
    uniq, inverse, valid = np.asarray(uniq), np.asarray(inverse), np.asarray(valid)
    want = np.unique(ids)
    assert int(n_unique) == len(want)
    assert valid.sum() == len(want)
    np.testing.assert_array_equal(np.sort(uniq[: len(want)]), want)
    np.testing.assert_array_equal(uniq[inverse], ids)
    assert inverse.max() < int(n_unique)


def test_dedup_requests_full_shard_range_routing():
    """Full-table-range ids dedup and fetch correctly at W=1 (the local-
    gather path with dedup telemetry; the ROUTED owner-bucketing version of
    this runs on 8 workers in test_distributed.py)."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.launch.mesh import make_local_mesh

    w, rows = 1, 160
    mesh = make_local_mesh(w, 1)
    table = jnp.arange(160 * 2, dtype=jnp.float32).reshape(160, 2)
    ids = jnp.asarray([0, 159, 80, 0, 159, 42, 21, 21], jnp.int32)
    out, stats = shard_map(
        lambda t, i: fetch_rows(t, i, "data", return_stats=True),
        mesh=mesh, in_specs=(P(), P()), out_specs=P(), check_rep=False,
    )(table, ids)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(table)[np.asarray(ids)])
    assert int(stats.n_unique) == 5       # {0, 21, 42, 80, 159}
    assert int(stats.n_dropped) == 0


def test_fetch_rows_dedup_matches_naive_single_worker():
    """Shuffled duplicate ids must fetch identical rows via the dedup path
    and the naive path."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.launch.mesh import make_local_mesh

    mesh = make_local_mesh(1, 1)
    table = jnp.arange(60, dtype=jnp.float32).reshape(20, 3)
    rng = np.random.default_rng(4)
    ids = jnp.asarray(rng.integers(0, 20, 64, dtype=np.int32))  # duplicated

    def run(dedup):
        return shard_map(
            lambda t, i: fetch_rows(t, i, "data", dedup=dedup),
            mesh=mesh, in_specs=(P(), P()), out_specs=P(), check_rep=False,
        )(table, ids)

    np.testing.assert_array_equal(np.asarray(run(True)), np.asarray(run(False)))
    np.testing.assert_array_equal(
        np.asarray(run(True)), np.asarray(table)[np.asarray(ids)])


def test_two_hop_semantics_match_seed_layout():
    """Regression: the (40, 20) path through the L-hop engine must keep the
    seed repo's SubgraphBatch node/mask semantics — shapes [B,40]/[B,40,20],
    chained masks, features equal to the table rows wherever masked and
    zeroed wherever padded."""
    from jax.sharding import Mesh
    from repro.core.partition import partition_edges
    from repro.core.generation import make_distributed_generator
    from repro.graph.synthetic import node_features, node_labels

    n, dim, classes, b = 600, 8, 5, 16
    g = powerlaw_graph(n, avg_degree=5, n_hot=2, hot_degree=100, seed=2)
    mesh = Mesh(np.asarray(jax.devices()[:1]), ("data",))
    part = partition_edges(g, 1)
    X = node_features(n, dim)
    Y = node_labels(n, classes)
    gen, dev = make_distributed_generator(mesh, part, X, Y, fanouts=(40, 20))
    batch = jax.tree.map(
        np.asarray,
        gen(dev, jnp.arange(b, dtype=jnp.int32).reshape(1, b),
            jax.random.PRNGKey(0)))
    assert batch.depth == 2 and batch.fanouts == (40, 20)
    # 2-hop convenience views alias the per-hop lists
    assert batch.hop1.shape == (b, 40) and batch.hop2.shape == (b, 40, 20)
    assert batch.mask1.shape == (b, 40) and batch.mask2.shape == (b, 40, 20)
    assert batch.x_hop1.shape == (b, 40, dim)
    assert batch.x_hop2.shape == (b, 40, 20, dim)
    assert batch.nodes_per_iteration() == b * (1 + 40 + 40 * 20)
    # padded parents never spawn children (chained masks)
    assert not (batch.mask2 & ~batch.mask1[..., None]).any()
    # masked hop-1 ids are real neighbors of their seeds
    adj = {v: set(g.indices[g.indptr[v]:g.indptr[v + 1]].tolist())
           for v in batch.seeds}
    for i, s in enumerate(batch.seeds):
        for j in range(40):
            if batch.mask1[i, j]:
                assert batch.hop1[i, j] in adj[s]
    # features: table rows where masked, zeros where padded
    np.testing.assert_array_equal(batch.x_seed, X[batch.seeds])
    m1, m2 = batch.mask1, batch.mask2
    if m1.any():
        np.testing.assert_array_equal(batch.x_hop1[m1], X[batch.hop1[m1]])
    if (~m1).any():
        assert np.abs(batch.x_hop1[~m1]).max() == 0
    if m2.any():
        np.testing.assert_array_equal(batch.x_hop2[m2], X[batch.hop2[m2]])
    if (~m2).any():
        assert np.abs(batch.x_hop2[~m2]).max() == 0
    np.testing.assert_array_equal(batch.labels, Y[batch.seeds])
    assert batch.n_dropped.sum() == 0


def test_baselines_agree_on_sampled_set_validity(graph):
    """All three strategies must return genuine neighbors — they differ in
    COST (the 27x), not in correctness."""
    indptr = jnp.asarray(graph.indptr)
    indices = jnp.asarray(graph.indices)
    src, dst = graph.edge_list()
    frontier = jnp.arange(20, dtype=jnp.int32)
    k = 5
    rng = jax.random.PRNGKey(1)
    adj = {v: set(graph.indices[graph.indptr[v]:graph.indptr[v+1]].tolist())
           for v in range(20)}
    for name, (ids, mask) in {
        "sql": sql_like_sample(jnp.asarray(src), jnp.asarray(dst), frontier, k, rng),
        "node": node_centric_sample(indptr, indices, frontier, k, rng,
                                    max_degree=int(graph.degrees().max())),
        "edge": edge_centric_sample(indptr, indices, frontier, k, rng),
    }.items():
        ids, mask = np.asarray(ids), np.asarray(mask)
        for i in range(20):
            got = set(ids[i][mask[i]].tolist())
            assert got.issubset(adj[i]), (name, i, got, adj[i])
            if adj[i]:
                assert mask[i].any(), (name, i)
