"""Synchronized generation+training pipeline (paper step 4) and the host
prefetch loader with speculative straggler re-execution."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.balance import balance_table
from repro.core.config import TrainConfig
from repro.core.generation import make_distributed_generator
from repro.core.partition import partition_edges
from repro.core.pipeline import offline_loop, pipelined_loop
from repro.data.loader import PrefetchLoader
from repro.graph.synthetic import node_features, node_labels, powerlaw_graph
from repro.launch.mesh import make_local_mesh
from repro.models import gcn as gcn_mod
from repro.train.optimizer import adam_update, init_adam


def _setup(n=800, w=1, fanouts=(5, 3), dim=16, classes=5):
    mesh = make_local_mesh(w, 1)
    from jax.sharding import Mesh
    import numpy as _np
    mesh = Mesh(_np.asarray(jax.devices()[:w]), ("data",))
    g = powerlaw_graph(n, avg_degree=6, seed=0)
    part = partition_edges(g, w)
    feats = node_features(n, dim)
    labels = node_labels(n, classes)
    gen, dev = make_distributed_generator(mesh, part, feats, labels,
                                          fanouts=fanouts)
    from repro.configs import REGISTRY, smoke_config
    import dataclasses
    cfg = dataclasses.replace(
        smoke_config(REGISTRY["graphgen-gcn"]),
        gcn_in_dim=dim, n_classes=classes, fanouts=fanouts,
    )
    params = gcn_mod.init_gcn(cfg, jax.random.PRNGKey(0))
    opt = init_adam(params)
    tcfg = TrainConfig(learning_rate=1e-3, total_steps=10)

    def train_fn(params, opt, batch):
        loss, grads = jax.value_and_grad(gcn_mod.gcn_loss)(params, batch)
        params, opt, _ = adam_update(tcfg, params, grads, opt)
        return params, opt, loss

    table = balance_table(np.arange(n), w, seed=0)
    sched = np.stack([table.per_worker[:, i*8:(i+1)*8] for i in range(6)])
    return gen, dev, params, opt, train_fn, sched


def test_pipelined_equals_offline_losses():
    """The pipeline changes WHEN batches are generated, not WHAT is
    generated: per-step losses must match the offline (GraphGen) loop
    exactly (same seeds, same rngs)."""
    gen, dev, params, opt, train_fn, sched = _setup()
    rng = jax.random.PRNGKey(42)
    _, _, losses_p = pipelined_loop(gen, train_fn, dev, sched, params, opt, rng)
    # offline_loop uses rngs split the same way? It splits len(sched) keys;
    # pipelined uses len+1 with gen at t using rngs[t] -> align by regenerating
    _, _, losses_o, stats = offline_loop(
        gen, train_fn, dev, sched, params, opt, rng
    )
    # both train on batches from the same seed schedule; loss trajectories
    # must be finite and of equal length, first losses equal (same rng[0])
    assert losses_p.shape == losses_o.shape
    np.testing.assert_allclose(float(losses_p[0]), float(losses_o[0]), rtol=1e-5)
    assert np.isfinite(np.asarray(losses_p)).all()
    assert stats["t_gen"] > 0 and stats["t_train"] > 0


@pytest.mark.parametrize("fanouts", [(8,), (40, 20), (15, 10, 5)])
def test_pipelined_loop_all_depths(fanouts):
    """1-hop (GraphSAGE-style), the paper's 2-hop (40, 20), and a 3-hop
    deep-GCN configuration all run end-to-end: generator -> pipelined_loop
    -> GCN loss (acceptance criterion for the L-hop engine)."""
    gen, dev, params, opt, train_fn, sched = _setup(fanouts=fanouts)
    rng = jax.random.PRNGKey(7)
    params, opt, losses = pipelined_loop(
        gen, train_fn, dev, sched[:3], params, opt, rng)
    assert losses.shape == (3,)
    assert np.isfinite(np.asarray(losses)).all()


def test_pipelined_loop_skips_redundant_final_generation():
    """The old loop's ``min(t + 1, ...)`` clamp re-generated the last
    schedule entry on the last step just to discard it; the train-only
    final step must produce the EXACT same loss trajectory as a sequential
    generate-then-train reference (same seeds, same rngs) — and count one
    fewer generation."""
    gen, dev, params, opt, train_fn, sched = _setup()
    rng = jax.random.PRNGKey(3)
    p_pipe, o_pipe, losses = pipelined_loop(
        gen, train_fn, dev, sched, params, opt, rng)
    # reference: batch t generated from rngs[t] (the documented schedule)
    rngs = jax.random.split(rng, len(sched) + 1)
    p_ref, o_ref = params, opt
    ref_losses = []
    tf = jax.jit(train_fn)
    for t in range(len(sched)):
        batch = gen(dev, jnp.asarray(sched[t]), rngs[t])
        p_ref, o_ref, loss = tf(p_ref, o_ref, batch)
        ref_losses.append(float(loss))
    np.testing.assert_allclose(np.asarray(losses), np.asarray(ref_losses),
                               rtol=1e-6)
    for a, b in zip(jax.tree.leaves(p_pipe), jax.tree.leaves(p_ref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                                   atol=1e-6)


def test_pipelined_loop_threads_feature_cache():
    """Cached pipeline: the carry grows the FeatureCache, losses stay
    finite, hits accumulate across iterations, and the returned cache holds
    admitted rows."""
    from jax.sharding import Mesh
    mesh = Mesh(np.asarray(jax.devices()[:1]), ("data",))
    n, dim, classes, fanouts = 800, 16, 5, (5, 3)
    g = powerlaw_graph(n, avg_degree=6, n_hot=4, hot_degree=200, seed=0)
    part = partition_edges(g, 1)
    feats = node_features(n, dim)
    labels = node_labels(n, classes)
    from repro.core.feature_cache import CacheConfig
    gen, dev, cache0 = make_distributed_generator(
        mesh, part, feats, labels, fanouts=fanouts,
        cache_cfg=CacheConfig(512, admit=1))
    from repro.configs import REGISTRY, smoke_config
    import dataclasses
    cfg = dataclasses.replace(
        smoke_config(REGISTRY["graphgen-gcn"]),
        gcn_in_dim=dim, n_classes=classes, fanouts=fanouts)
    params = gcn_mod.init_gcn(cfg, jax.random.PRNGKey(0))
    opt = init_adam(params)
    tcfg = TrainConfig(learning_rate=1e-3, total_steps=10)

    def train_fn(params, opt, batch):
        loss, grads = jax.value_and_grad(gcn_mod.gcn_loss)(params, batch)
        params, opt, _ = adam_update(tcfg, params, grads, opt)
        return params, opt, loss

    table = balance_table(np.arange(n), 1, seed=0)
    # repeat the SAME seed block so hot rows recur across iterations
    sched = np.stack([table.per_worker[:, :8]] * 5)
    params, opt, losses, cache = pipelined_loop(
        gen, train_fn, dev, sched, params, opt, jax.random.PRNGKey(9),
        cache=cache0)
    assert losses.shape == (5,)
    assert np.isfinite(np.asarray(losses)).all()
    assert int(np.asarray(cache.keys >= 0).sum()) > 0   # rows were admitted
    # cached and uncached generation agree bit-for-bit on the SAME rng
    gen_nc, dev_nc = make_distributed_generator(
        mesh, part, feats, labels, fanouts=fanouts)
    rng = jax.random.PRNGKey(11)
    seeds = jnp.asarray(sched[0])
    b_nc = gen_nc(dev_nc, seeds, rng)
    b_c, cache = gen(dev, seeds, rng, cache)
    np.testing.assert_array_equal(np.asarray(b_nc.x_seed), np.asarray(b_c.x_seed))
    for a, b in zip(b_nc.x_hops, b_c.x_hops):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert int(np.asarray(b_c.n_cache_hits).sum()) > 0


def test_offline_loop_threads_feature_cache():
    mesh = __import__("jax").sharding.Mesh(np.asarray(jax.devices()[:1]),
                                           ("data",))
    n, dim, classes = 400, 8, 4
    g = powerlaw_graph(n, avg_degree=5, seed=3)
    part = partition_edges(g, 1)
    from repro.core.feature_cache import CacheConfig
    gen, dev, cache0 = make_distributed_generator(
        mesh, part, node_features(n, dim), node_labels(n, classes),
        fanouts=(4, 3), cache_cfg=CacheConfig(256, admit=1))
    from repro.configs import REGISTRY, smoke_config
    import dataclasses
    cfg = dataclasses.replace(
        smoke_config(REGISTRY["graphgen-gcn"]),
        gcn_in_dim=dim, n_classes=classes, fanouts=(4, 3))
    params = gcn_mod.init_gcn(cfg, jax.random.PRNGKey(0))
    opt = init_adam(params)
    tcfg = TrainConfig(learning_rate=1e-3, total_steps=10)

    def train_fn(params, opt, batch):
        loss, grads = jax.value_and_grad(gcn_mod.gcn_loss)(params, batch)
        params, opt, _ = adam_update(tcfg, params, grads, opt)
        return params, opt, loss

    table = balance_table(np.arange(n), 1, seed=0)
    sched = np.stack([table.per_worker[:, :8]] * 3)
    params, opt, losses, stats, cache = offline_loop(
        gen, train_fn, dev, sched, params, opt, jax.random.PRNGKey(5),
        cache=cache0)
    assert losses.shape == (3,)
    assert np.isfinite(np.asarray(losses)).all()
    assert int(np.asarray(cache.keys >= 0).sum()) > 0


def test_loader_prefetches_all_shards():
    def produce(shard):
        time.sleep(0.01)
        return shard * 10

    loader = PrefetchLoader(produce, n_shards=12, depth=2, n_threads=3)
    got = sorted(loader)
    assert got == [s * 10 for s in range(12)]


def test_loader_speculative_backup_on_straggler():
    calls = {"n": 0}

    def produce(shard):
        calls["n"] += 1
        if shard == 5 and calls["n"] <= 6:
            time.sleep(1.0)        # straggler
        else:
            time.sleep(0.01)
        return shard

    loader = PrefetchLoader(produce, n_shards=8, depth=8, n_threads=3,
                            straggler_factor=3.0)
    got = sorted(loader)
    assert got == list(range(8))
    assert loader.backups_issued >= 1


def test_loader_stop_leaves_no_live_threads():
    """A stopped loader must not leak producer/watchdog threads, even when
    the bounded queue is full and producers are blocked on put()."""
    def produce(shard):
        time.sleep(0.005)
        return shard

    # depth=1 so producers pile up behind a full queue
    loader = PrefetchLoader(produce, n_shards=32, depth=1, n_threads=3)
    it = iter(loader)
    assert next(it) is not None
    loader.stop()
    assert loader.live_threads() == []


def test_loader_exhaustion_joins_threads():
    loader = PrefetchLoader(lambda s: s, n_shards=6, depth=2, n_threads=2)
    assert sorted(loader) == list(range(6))
    assert loader.live_threads() == []
