"""Dry-run launcher integration: one fast LM cell + the paper's GCN cell
run end-to-end through the CLI in subprocesses (the CLI sets its own
512-device XLA flags; this process keeps 1 device)."""
import json
import os
import subprocess
import sys

import pytest

_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(args, timeout=900):
    env = dict(os.environ)
    env["PYTHONPATH"] = _SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun"] + args,
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    return json.loads(proc.stdout.strip().splitlines()[-1])


@pytest.mark.slow
def test_dryrun_lm_decode_cell():
    rec = _run(["--arch", "smollm-135m", "--shape", "decode_32k"])
    assert rec["status"] == "ok"
    assert rec["chips"] == 256
    assert rec["flops_per_device"] > 0
    assert rec["collective_bytes_per_device"]["total"] > 0
    assert rec["memory"]["temp_bytes"] > 0


@pytest.mark.slow
def test_dryrun_multipod_cell():
    rec = _run(["--arch", "smollm-135m", "--shape", "decode_32k", "--multi-pod"])
    assert rec["status"] == "ok"
    assert rec["chips"] == 512
    assert rec["mesh"] == "2x16x16"


@pytest.mark.slow
def test_dryrun_gcn_production_cell():
    rec = _run(["--arch", "graphgen-gcn", "--shape", "train_4k"])
    assert rec["status"] == "ok"
    # the paper's "1M nodes per iteration" claim: our cell compiles >1M
    assert rec["tokens"] > 1_000_000
    assert rec["collective_bytes_per_device"]["all-to-all"] > 0   # feature shuffle
    assert rec["collective_bytes_per_device"]["collective-permute"] > 0  # tree merge


@pytest.mark.slow
def test_dryrun_gcn_tiered_cell():
    """The deep-GCN config's TIERED cache (replicated L1 + sharded L2)
    must partition and compile at the production 16x16 mesh — the tiered
    state pytree rides the pipelined carry through shard_map."""
    rec = _run(["--arch", "graphgen-gcn-deep", "--shape", "train_4k"])
    assert rec["status"] == "ok"
    assert rec["cache_mode"] == "tiered"
    assert rec["cache_l1_rows"] == 512
    assert rec["collective_bytes_per_device"]["all-to-all"] > 0


def test_long500k_skip_policy():
    rec = _run(["--arch", "llama3-405b", "--shape", "long_500k"])
    assert rec["status"] == "skipped"
    assert "quadratic" in rec["reason"]
