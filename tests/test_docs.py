"""Docs-tier gates, enforced in tier-1 so regressions break the build:

* every relative markdown link in README.md + docs/ resolves (file AND
  heading anchor);
* every public symbol of the fetch-path API carries a real docstring
  (the ``interrogate --fail-under 100`` equivalent, dependency-free).

Both checks are the same code CI's docs step runs (tools/check_docs.py)
— the test imports it by path so the gate cannot fork from the tool.
"""
import importlib.util
import os
import sys

_TOOL = os.path.join(os.path.dirname(__file__), "..", "tools",
                     "check_docs.py")


def _load_tool():
    spec = importlib.util.spec_from_file_location("check_docs", _TOOL)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_markdown_links_resolve():
    """README.md + docs/*.md exist and every relative link/anchor in them
    points at something that exists — a moved file or renamed heading
    fails here, not in a reader's browser."""
    tool = _load_tool()
    readme = os.path.join(tool.REPO_ROOT, "README.md")
    docs = os.path.join(tool.REPO_ROOT, "docs")
    assert os.path.exists(readme), "README.md is the documented entry point"
    assert os.path.exists(os.path.join(docs, "ARCHITECTURE.md"))
    assert os.path.exists(os.path.join(docs, "BENCHMARKS.md"))
    problems = tool.check_markdown_links()
    assert not problems, "\n".join(
        f"{p['path']}:{p['line']}: {p['message']}" for p in problems)


def test_public_fetch_path_docstring_coverage():
    """100% docstring coverage over the public fetch-path API modules —
    a new public symbol without args/returns/shape contracts fails the
    build instead of silently eroding the docs tier."""
    tool = _load_tool()
    sys.path.insert(0, os.path.join(tool.REPO_ROOT, "src"))
    try:
        pct, missing = tool.check_docstrings()
    finally:
        sys.path.pop(0)
    assert pct == 100.0, "undocumented public symbols:\n" + "\n".join(
        f"{m['path']}:{m['line']}: {m['message']}" for m in missing)
