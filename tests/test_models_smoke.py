"""Per-architecture smoke tests (reduced configs) + train/decode parity.

Every assigned arch: one forward/train step on CPU asserting output shapes
and no NaNs, plus one decode step against its cache.  Parity tests check the
decode path (KV cache / recurrent state / absorbed MLA) reproduces the
full-sequence forward logits position by position.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, REGISTRY, smoke_config
from repro.core.config import TrainConfig
from repro.models import zoo
from repro.train.train_loop import init_state, make_train_step


def _batch(cfg, b=2, s=16, seed=0):
    rng = np.random.default_rng(seed)
    toks = rng.integers(0, cfg.vocab_size, (b, s), dtype=np.int32)
    batch = {"tokens": jnp.asarray(toks),
             "labels": jnp.asarray(np.roll(toks, -1, axis=1))}
    if cfg.family == "vlm":
        batch["vision"] = jnp.asarray(rng.standard_normal(
            (b, cfg.n_vision_tokens, cfg.d_vision), dtype=np.float32))
    if cfg.family == "audio":
        batch["frames"] = jnp.asarray(rng.standard_normal(
            (b, cfg.n_audio_frames, cfg.d_audio), dtype=np.float32))
    return batch


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_arch_train_step(arch):
    cfg = smoke_config(REGISTRY[arch])
    api = zoo.build(cfg)
    params = api.init(jax.random.PRNGKey(0))
    state = init_state(params, TrainConfig())
    step = jax.jit(make_train_step(api.loss, TrainConfig()))
    batch = _batch(cfg)
    state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["loss"]) > 0
    assert int(metrics["step"]) == 1
    for leaf in jax.tree.leaves(state.params):
        assert np.isfinite(np.asarray(leaf)).all()


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_arch_decode_step(arch):
    cfg = smoke_config(REGISTRY[arch])
    api = zoo.build(cfg)
    params = api.init(jax.random.PRNGKey(0))
    cache = api.init_cache(2, 24)
    logits, new_cache = api.decode(
        params, cache, jnp.zeros((2, 1), jnp.int32), jnp.int32(0)
    )
    from repro.models.layers import padded_vocab
    assert logits.shape == (2, padded_vocab(cfg))
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert jax.tree.structure(cache) == jax.tree.structure(new_cache)


@pytest.mark.parametrize("arch", [
    "smollm-135m", "qwen3-moe-30b-a3b", "deepseek-v2-236b",
    "mamba2-1.3b", "zamba2-1.2b", "whisper-small",
])
def test_decode_matches_train_forward(arch):
    """Step-by-step decode logits == full-sequence forward logits.

    Covers: GQA KV cache, MoE routing under decode, ABSORBED MLA decode,
    SSD recurrence vs chunked train path, hybrid shared-attn caches, and
    enc-dec cross attention."""
    cfg = smoke_config(REGISTRY[arch])
    api = zoo.build(cfg)
    params = api.init(jax.random.PRNGKey(1))
    b, s = 2, 8
    batch = _batch(cfg, b, s, seed=3)
    full = zoo.forward_logits(cfg, params, batch)          # [B, S, V]
    cache = api.init_cache(b, s)
    if cfg.family == "audio":
        from repro.models import whisper
        enc = whisper.encode(cfg, params, batch["frames"])
        cache["enc"] = enc.astype(cache["enc"].dtype)
    if cfg.family == "vlm":
        # precompute vision kv per site for the decode path
        from repro.models import layers as L
        sites = cfg.n_layers // cfg.cross_attn_every
        hd = cfg.resolved_head_dim
        vis = (batch["vision"].astype(L.COMPUTE_DTYPE)
               @ params["vproj"].astype(L.COMPUTE_DTYPE))
        vk, vv = [], []
        for i in range(sites):
            attn = jax.tree.map(lambda a: a[i], params["cross"]["attn"])
            vk.append((vis @ attn["wk"].astype(vis.dtype)))
            vv.append((vis @ attn["wv"].astype(vis.dtype)))
        cache["vis_k"] = jnp.stack(vk).astype(cache["vis_k"].dtype)
        cache["vis_v"] = jnp.stack(vv).astype(cache["vis_v"].dtype)
    decode = jax.jit(api.decode)
    errs = []
    for t in range(s):
        logits, cache = decode(params, cache, batch["tokens"][:, t:t+1],
                               jnp.int32(t))
        errs.append(np.abs(np.asarray(logits) - np.asarray(full[:, t])).max())
    assert max(errs) < 0.15, errs   # bf16 cache round-trip tolerance


def _random_subgraph_batch(fanouts, b, d, n_classes, seed=0):
    from repro.graph.subgraph import SubgraphBatch
    rng = np.random.default_rng(seed)
    shape = (b,)
    hops, masks, x_hops = [], [], []
    for k in fanouts:
        shape = shape + (k,)
        hops.append(jnp.asarray(rng.integers(0, 50, shape, dtype=np.int32)))
        m = rng.random(shape) < 0.9
        if masks:
            m = m & np.asarray(masks[-1])[..., None]   # chained masks
        masks.append(jnp.asarray(m))
        x_hops.append(jnp.asarray(
            rng.standard_normal(shape + (d,), dtype=np.float32)) * m[..., None])
    return SubgraphBatch(
        seeds=jnp.arange(b, dtype=jnp.int32),
        hops=tuple(hops),
        masks=tuple(masks),
        x_seed=jnp.asarray(rng.standard_normal((b, d), dtype=np.float32)),
        x_hops=tuple(x_hops),
        labels=jnp.asarray(rng.integers(0, n_classes, b, dtype=np.int32)),
        n_dropped=jnp.zeros((1,), jnp.int32),
    )


@pytest.mark.parametrize("arch", ["graphgen-sage", "graphgen-gcn",
                                  "graphgen-gcn-deep"])
def test_gcn_smoke(arch):
    from repro.models import gcn
    cfg = smoke_config(REGISTRY[arch])
    params = gcn.init_gcn(cfg, jax.random.PRNGKey(0))
    assert len(params.layers) == len(cfg.fanouts)
    b, d = 6, cfg.gcn_in_dim
    batch = _random_subgraph_batch(cfg.fanouts, b, d, cfg.n_classes)
    logits = gcn.gcn_forward(params, batch)
    assert logits.shape == (b, cfg.n_classes)
    loss = gcn.gcn_loss(params, batch)
    assert np.isfinite(float(loss))
    # kernel path must agree with reference path
    logits_k = gcn.gcn_forward(params, batch, use_kernel=True)
    np.testing.assert_allclose(np.asarray(logits_k), np.asarray(logits),
                               rtol=1e-4, atol=1e-4)


def test_gcn_seed_layer_keeps_neighbor_term():
    """Regression for the seed repo bug: the first conv at the SEED level
    ignored its hop-1 neighbors (x_seed @ w_self only).  With the second
    layer's neighbor path switched off, perturbing hop-1 features must
    still change the logits — it flows through layer 1's w_nbr at the seed
    level."""
    import dataclasses
    from repro.models import gcn
    from repro.models.gcn import GCNLayerParams, GCNParams
    cfg = dataclasses.replace(smoke_config(REGISTRY["graphgen-gcn"]),
                              fanouts=(3, 2))
    base = gcn.init_gcn(cfg, jax.random.PRNGKey(0))
    h = cfg.gcn_hidden
    # layer 2: identity-ish self path, ZERO neighbor path
    l2 = GCNLayerParams(w_self=jnp.eye(h), w_nbr=jnp.zeros((h, h)),
                        b=jnp.zeros((h,)))
    params = GCNParams(layers=(base.layers[0], l2), w_out=base.w_out,
                       b_out=base.b_out)
    batch = _random_subgraph_batch(cfg.fanouts, 4, cfg.gcn_in_dim,
                                   cfg.n_classes, seed=1)
    bumped = batch._replace(
        x_hops=(batch.x_hops[0] + batch.masks[0][..., None].astype(jnp.float32),
                batch.x_hops[1]))
    out0 = np.asarray(gcn.gcn_forward(params, batch))
    out1 = np.asarray(gcn.gcn_forward(params, bumped))
    assert np.abs(out1 - out0).max() > 1e-4, (
        "seed-level layer 1 dropped its neighbor aggregation term")


def test_gcn_depth1_matches_manual_formula():
    """Depth-1 forward is analytically checkable: one self+neighbor conv at
    the seed level, then the output head."""
    import dataclasses
    from repro.models import gcn
    cfg = dataclasses.replace(smoke_config(REGISTRY["graphgen-sage"]),
                              fanouts=(4,))
    params = gcn.init_gcn(cfg, jax.random.PRNGKey(2))
    batch = _random_subgraph_batch(cfg.fanouts, 5, cfg.gcn_in_dim,
                                   cfg.n_classes, seed=3)
    m = np.asarray(batch.masks[0]).astype(np.float32)
    agg = (np.asarray(batch.x_hops[0]) * m[..., None]).sum(1) / np.maximum(
        m.sum(1, keepdims=True), 1.0)
    lyr = params.layers[0]
    h = np.maximum(
        np.asarray(batch.x_seed) @ np.asarray(lyr.w_self)
        + agg @ np.asarray(lyr.w_nbr) + np.asarray(lyr.b), 0.0)
    want = h @ np.asarray(params.w_out) + np.asarray(params.b_out)
    got = np.asarray(gcn.gcn_forward(params, batch))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_param_counts_match_advertised_size():
    expect = {
        "smollm-135m": 0.135e9, "smollm-360m": 0.36e9, "stablelm-12b": 12e9,
        "llama3-405b": 405e9, "qwen3-moe-30b-a3b": 30e9,
        "deepseek-v2-236b": 236e9, "llama-3.2-vision-11b": 10e9,
        "mamba2-1.3b": 1.3e9, "zamba2-1.2b": 1.2e9,
    }
    for arch, want in expect.items():
        got = REGISTRY[arch].param_count()
        assert 0.7 * want < got < 1.35 * want, (arch, got, want)
