"""Per-architecture smoke tests (reduced configs) + train/decode parity.

Every assigned arch: one forward/train step on CPU asserting output shapes
and no NaNs, plus one decode step against its cache.  Parity tests check the
decode path (KV cache / recurrent state / absorbed MLA) reproduces the
full-sequence forward logits position by position.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, REGISTRY, smoke_config
from repro.core.config import TrainConfig
from repro.models import zoo
from repro.train.train_loop import init_state, make_train_step


def _batch(cfg, b=2, s=16, seed=0):
    rng = np.random.default_rng(seed)
    toks = rng.integers(0, cfg.vocab_size, (b, s), dtype=np.int32)
    batch = {"tokens": jnp.asarray(toks),
             "labels": jnp.asarray(np.roll(toks, -1, axis=1))}
    if cfg.family == "vlm":
        batch["vision"] = jnp.asarray(rng.standard_normal(
            (b, cfg.n_vision_tokens, cfg.d_vision), dtype=np.float32))
    if cfg.family == "audio":
        batch["frames"] = jnp.asarray(rng.standard_normal(
            (b, cfg.n_audio_frames, cfg.d_audio), dtype=np.float32))
    return batch


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_arch_train_step(arch):
    cfg = smoke_config(REGISTRY[arch])
    api = zoo.build(cfg)
    params = api.init(jax.random.PRNGKey(0))
    state = init_state(params, TrainConfig())
    step = jax.jit(make_train_step(api.loss, TrainConfig()))
    batch = _batch(cfg)
    state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["loss"]) > 0
    assert int(metrics["step"]) == 1
    for leaf in jax.tree.leaves(state.params):
        assert np.isfinite(np.asarray(leaf)).all()


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_arch_decode_step(arch):
    cfg = smoke_config(REGISTRY[arch])
    api = zoo.build(cfg)
    params = api.init(jax.random.PRNGKey(0))
    cache = api.init_cache(2, 24)
    logits, new_cache = api.decode(
        params, cache, jnp.zeros((2, 1), jnp.int32), jnp.int32(0)
    )
    from repro.models.layers import padded_vocab
    assert logits.shape == (2, padded_vocab(cfg))
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert jax.tree.structure(cache) == jax.tree.structure(new_cache)


@pytest.mark.parametrize("arch", [
    "smollm-135m", "qwen3-moe-30b-a3b", "deepseek-v2-236b",
    "mamba2-1.3b", "zamba2-1.2b", "whisper-small",
])
def test_decode_matches_train_forward(arch):
    """Step-by-step decode logits == full-sequence forward logits.

    Covers: GQA KV cache, MoE routing under decode, ABSORBED MLA decode,
    SSD recurrence vs chunked train path, hybrid shared-attn caches, and
    enc-dec cross attention."""
    cfg = smoke_config(REGISTRY[arch])
    api = zoo.build(cfg)
    params = api.init(jax.random.PRNGKey(1))
    b, s = 2, 8
    batch = _batch(cfg, b, s, seed=3)
    full = zoo.forward_logits(cfg, params, batch)          # [B, S, V]
    cache = api.init_cache(b, s)
    if cfg.family == "audio":
        from repro.models import whisper
        enc = whisper.encode(cfg, params, batch["frames"])
        cache["enc"] = enc.astype(cache["enc"].dtype)
    if cfg.family == "vlm":
        # precompute vision kv per site for the decode path
        from repro.models import layers as L
        sites = cfg.n_layers // cfg.cross_attn_every
        hd = cfg.resolved_head_dim
        vis = (batch["vision"].astype(L.COMPUTE_DTYPE)
               @ params["vproj"].astype(L.COMPUTE_DTYPE))
        vk, vv = [], []
        for i in range(sites):
            attn = jax.tree.map(lambda a: a[i], params["cross"]["attn"])
            vk.append((vis @ attn["wk"].astype(vis.dtype)))
            vv.append((vis @ attn["wv"].astype(vis.dtype)))
        cache["vis_k"] = jnp.stack(vk).astype(cache["vis_k"].dtype)
        cache["vis_v"] = jnp.stack(vv).astype(cache["vis_v"].dtype)
    decode = jax.jit(api.decode)
    errs = []
    for t in range(s):
        logits, cache = decode(params, cache, batch["tokens"][:, t:t+1],
                               jnp.int32(t))
        errs.append(np.abs(np.asarray(logits) - np.asarray(full[:, t])).max())
    assert max(errs) < 0.15, errs   # bf16 cache round-trip tolerance


def test_gcn_smoke():
    from repro.graph.subgraph import SubgraphBatch
    from repro.models import gcn
    cfg = smoke_config(REGISTRY["graphgen-gcn"])
    params = gcn.init_gcn(cfg, jax.random.PRNGKey(0))
    b, k1, k2, d = 6, *cfg.fanouts, cfg.gcn_in_dim
    rng = np.random.default_rng(0)
    batch = SubgraphBatch(
        seeds=jnp.arange(b, dtype=jnp.int32),
        hop1=jnp.asarray(rng.integers(0, 50, (b, k1), dtype=np.int32)),
        mask1=jnp.asarray(rng.random((b, k1)) < 0.9),
        hop2=jnp.asarray(rng.integers(0, 50, (b, k1, k2), dtype=np.int32)),
        mask2=jnp.asarray(rng.random((b, k1, k2)) < 0.9),
        x_seed=jnp.asarray(rng.standard_normal((b, d), dtype=np.float32)),
        x_hop1=jnp.asarray(rng.standard_normal((b, k1, d), dtype=np.float32)),
        x_hop2=jnp.asarray(rng.standard_normal((b, k1, k2, d), dtype=np.float32)),
        labels=jnp.asarray(rng.integers(0, cfg.n_classes, b, dtype=np.int32)),
    )
    logits = gcn.gcn_forward(params, batch)
    assert logits.shape == (b, cfg.n_classes)
    loss = gcn.gcn_loss(params, batch)
    assert np.isfinite(float(loss))
    # kernel path must agree with reference path
    logits_k = gcn.gcn_forward(params, batch, use_kernel=True)
    np.testing.assert_allclose(np.asarray(logits_k), np.asarray(logits),
                               rtol=1e-4, atol=1e-4)


def test_param_counts_match_advertised_size():
    expect = {
        "smollm-135m": 0.135e9, "smollm-360m": 0.36e9, "stablelm-12b": 12e9,
        "llama3-405b": 405e9, "qwen3-moe-30b-a3b": 30e9,
        "deepseek-v2-236b": 236e9, "llama-3.2-vision-11b": 10e9,
        "mamba2-1.3b": 1.3e9, "zamba2-1.2b": 1.2e9,
    }
    for arch, want in expect.items():
        got = REGISTRY[arch].param_count()
        assert 0.7 * want < got < 1.35 * want, (arch, got, want)
