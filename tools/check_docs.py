#!/usr/bin/env python
"""Docs-tier lint: markdown link check + public docstring coverage gate.

Two checks, both run by ``tests/test_docs.py`` (tier-1) and by the CI
docs step, so a moved file, renamed flag, or undocumented public symbol
breaks the build — not the reader:

1. **Markdown link check** over ``README.md`` and ``docs/*.md``: every
   relative link target must exist on disk, and every ``#anchor`` (in-page
   or cross-file) must match a heading in the target file under GitHub's
   slug rules.  External (``http``/``https``/``mailto``) links are not
   fetched.

2. **Docstring coverage** over the public fetch-path API
   (``PUBLIC_API_MODULES`` plus the individually-exported
   ``PUBLIC_API_SYMBOLS``): every public function, class, and public
   method defined there must carry a real docstring (not a placeholder).
   The gate is ``--fail-under`` percent (default 100 — the equivalent of
   ``interrogate --fail-under 100`` without adding a dependency the
   container lacks).

Findings are emitted through ``tools/_report.py`` — the same
``--format=human|json|github|sarif`` surface as ``tools/graphlint`` —
so CI failures annotate the offending file and line in the PR diff,
and ``--sarif-out FILE`` additionally writes a SARIF 2.1.0 log for the
code-scanning upload step.

Usage::

    PYTHONPATH=src python tools/check_docs.py [--fail-under 100] [--format github]
"""
from __future__ import annotations

import argparse
import importlib
import inspect
import os
import re
import sys

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, REPO_ROOT)

from tools import _report  # noqa: E402

#: modules whose PUBLIC surface is the documented fetch-path API —
#: fetch_rows and its config/state/stats types, the wire codec, the
#: kernel entry points, and the arch/shape/mesh/train config dataclasses
#: (docs/ARCHITECTURE.md is their narrative form)
PUBLIC_API_MODULES = (
    "repro.core.config",
    "repro.core.feature_cache",
    "repro.core.generation",
    "repro.graph.subgraph",
    "repro.kernels.cache_gather",
    "repro.kernels.ref",
    "repro.kernels.ops",
    "repro.launch.autotune",
    "repro.launch.serve",
    "repro.train.checkpoint",
)

#: individually-exported public symbols (``module:name``) from modules
#: whose remaining surface is launcher plumbing, not public API
PUBLIC_API_SYMBOLS = (
    "repro.launch.train:calibrate_capacity_slack",
    "repro.launch.train:calibrate_probe_hit_cap",
    "repro.launch.roofline:roofline_terms",
    "repro.launch.roofline:step_lower_bound",
)

#: a docstring shorter than this is a placeholder, not documentation
MIN_DOCSTRING = 20

_LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_EXTERNAL = ("http://", "https://", "mailto:")


def _slugify(heading: str) -> str:
    """GitHub anchor slug of a markdown heading."""
    s = heading.strip().lower()
    s = re.sub(r"[^\w\- ]", "", s)
    return s.replace(" ", "-")


def _anchors_of(md_path: str) -> set:
    anchors = set()
    with open(md_path, encoding="utf-8") as f:
        in_code = False
        for line in f:
            if line.lstrip().startswith("```"):
                in_code = not in_code
                continue
            if not in_code and line.startswith("#"):
                anchors.add(_slugify(line.lstrip("#")))
    return anchors


def _link_problem(rel, lineno, message):
    return {"path": rel, "line": lineno, "check": "markdown-link",
            "severity": "error", "message": message}


def check_markdown_links(files=None) -> list:
    """Return finding dicts (path/line/check/severity/message) for every
    broken relative link or missing anchor in the given markdown files
    (default: README.md + docs/*.md)."""
    if files is None:
        files = [os.path.join(REPO_ROOT, "README.md")]
        docs = os.path.join(REPO_ROOT, "docs")
        if os.path.isdir(docs):
            files += sorted(
                os.path.join(docs, f) for f in os.listdir(docs)
                if f.endswith(".md"))
    problems = []
    for path in files:
        rel = os.path.relpath(path, REPO_ROOT)
        if not os.path.exists(path):
            problems.append(_link_problem(rel, 1, "file missing"))
            continue
        base = os.path.dirname(path)
        in_code = False
        with open(path, encoding="utf-8") as f:
            for lineno, line in enumerate(f, start=1):
                if line.lstrip().startswith("```"):
                    in_code = not in_code
                    continue
                if in_code:
                    # links inside fenced code blocks are examples
                    continue
                for target in _LINK_RE.findall(line):
                    if target.startswith(_EXTERNAL):
                        continue
                    file_part, _, anchor = target.partition("#")
                    dest = (os.path.normpath(os.path.join(base, file_part))
                            if file_part else path)
                    if not os.path.exists(dest):
                        problems.append(_link_problem(
                            rel, lineno, f"broken link target {target!r}"))
                        continue
                    if anchor and dest.endswith(".md"):
                        if anchor not in _anchors_of(dest):
                            problems.append(_link_problem(
                                rel, lineno,
                                f"missing anchor {target!r} (no matching "
                                f"heading in "
                                f"{os.path.relpath(dest, REPO_ROOT)})"))
    return problems


def _public_symbols(module):
    """(qualname, obj) for the module's public functions/classes/methods."""
    for name, obj in sorted(vars(module).items()):
        if name.startswith("_"):
            continue
        if not (inspect.isfunction(obj) or inspect.isclass(obj)):
            continue
        if getattr(obj, "__module__", None) != module.__name__:
            continue    # re-export; documented where it is defined
        yield f"{module.__name__}.{name}", obj
        if inspect.isclass(obj):
            for mname, mobj in sorted(vars(obj).items()):
                if mname.startswith("_"):
                    continue
                if isinstance(mobj, property):
                    mobj = mobj.fget
                if isinstance(mobj, (staticmethod, classmethod)):
                    mobj = mobj.__func__
                if inspect.isfunction(mobj):
                    yield f"{module.__name__}.{name}.{mname}", mobj


def _location_of(obj) -> tuple:
    """Best-effort (repo-relative path, 1-based line) for *obj*."""
    try:
        src = inspect.getsourcefile(obj)
        line = inspect.getsourcelines(obj)[1]
    except (TypeError, OSError):
        return "<unknown>", 1
    rel = os.path.relpath(src, REPO_ROOT) if src else "<unknown>"
    return rel, line


def _missing_finding(qualname, obj) -> dict:
    path, line = _location_of(obj)
    return {"path": path, "line": line, "check": "docstring",
            "severity": "error",
            "message": f"{qualname} has no real docstring "
                       f"(>= {MIN_DOCSTRING} chars)"}


def check_docstrings() -> tuple:
    """Return ``(coverage_percent, missing)`` over the public API, where
    *missing* is a list of finding dicts locating each undocumented
    symbol."""
    covered, missing = 0, []
    total = 0
    for modname in PUBLIC_API_MODULES:
        module = importlib.import_module(modname)
        total += 1
        if not (module.__doc__ and len(module.__doc__) >= MIN_DOCSTRING):
            missing.append({
                "path": os.path.relpath(module.__file__, REPO_ROOT),
                "line": 1, "check": "docstring", "severity": "error",
                "message": f"{modname} has no module docstring"})
        else:
            covered += 1
        for qualname, obj in _public_symbols(module):
            total += 1
            doc = inspect.getdoc(obj)
            if doc and len(doc) >= MIN_DOCSTRING:
                covered += 1
            else:
                missing.append(_missing_finding(qualname, obj))
    for spec in PUBLIC_API_SYMBOLS:
        modname, _, symbol = spec.partition(":")
        obj = getattr(importlib.import_module(modname), symbol)
        total += 1
        doc = inspect.getdoc(obj)
        if doc and len(doc) >= MIN_DOCSTRING:
            covered += 1
        else:
            missing.append(_missing_finding(f"{modname}.{symbol}", obj))
    pct = 100.0 * covered / max(total, 1)
    return pct, missing


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--fail-under", type=float, default=100.0,
                    help="minimum docstring coverage percent (default 100)")
    ap.add_argument("--format", choices=_report.FORMATS, default="human",
                    help="finding output format (default: human)")
    ap.add_argument("--sarif-out", metavar="FILE", default=None,
                    help="also write findings as SARIF 2.1.0 to FILE "
                         "(for github/codeql-action/upload-sarif)")
    args = ap.parse_args()
    sys.path.insert(0, os.path.join(REPO_ROOT, "src"))
    problems = check_markdown_links()
    pct, missing = check_docstrings()
    _report.emit(problems + missing, fmt=args.format,
                 stream=sys.stderr if args.format == "human" else sys.stdout,
                 tool_name="check_docs")
    if args.sarif_out:
        _report.write_sarif(
            problems + missing, args.sarif_out, tool_name="check_docs",
            rule_docs={"markdown-link": "relative link/anchor must resolve",
                       "docstring": "public API symbol lacks a docstring"})
    failed = bool(problems)
    if args.format == "human":
        print(f"docstring coverage: {pct:.1f}% "
              f"({len(missing)} public symbols undocumented)")
    if pct < args.fail_under:
        print(f"FAIL: coverage {pct:.1f}% < --fail-under "
              f"{args.fail_under:.1f}%", file=sys.stderr)
        failed = True
    if not problems and args.format == "human":
        print("markdown links: OK")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
