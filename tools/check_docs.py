#!/usr/bin/env python
"""Docs-tier lint: markdown link check + public docstring coverage gate.

Two checks, both run by ``tests/test_docs.py`` (tier-1) and by the CI
docs step, so a moved file, renamed flag, or undocumented public symbol
breaks the build — not the reader:

1. **Markdown link check** over ``README.md`` and ``docs/*.md``: every
   relative link target must exist on disk, and every ``#anchor`` (in-page
   or cross-file) must match a heading in the target file under GitHub's
   slug rules.  External (``http``/``https``/``mailto``) links are not
   fetched.

2. **Docstring coverage** over the public fetch-path API
   (``PUBLIC_API_MODULES``): every public function, class, and public
   method defined in those modules must carry a real docstring (not a
   placeholder).  The gate is ``--fail-under`` percent (default 100 — the
   equivalent of ``interrogate --fail-under 100`` without adding a
   dependency the container lacks).

Usage::

    PYTHONPATH=src python tools/check_docs.py [--fail-under 100]
"""
from __future__ import annotations

import argparse
import importlib
import inspect
import os
import re
import sys

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

#: modules whose PUBLIC surface is the documented fetch-path API —
#: fetch_rows and its config/state/stats types, the wire codec, and the
#: kernel entry points (docs/ARCHITECTURE.md is their narrative form)
PUBLIC_API_MODULES = (
    "repro.core.feature_cache",
    "repro.core.generation",
    "repro.graph.subgraph",
    "repro.kernels.cache_gather",
    "repro.kernels.ref",
    "repro.kernels.ops",
)

#: a docstring shorter than this is a placeholder, not documentation
MIN_DOCSTRING = 20

_LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_EXTERNAL = ("http://", "https://", "mailto:")


def _slugify(heading: str) -> str:
    """GitHub anchor slug of a markdown heading."""
    s = heading.strip().lower()
    s = re.sub(r"[^\w\- ]", "", s)
    return s.replace(" ", "-")


def _anchors_of(md_path: str) -> set:
    anchors = set()
    with open(md_path, encoding="utf-8") as f:
        in_code = False
        for line in f:
            if line.lstrip().startswith("```"):
                in_code = not in_code
                continue
            if not in_code and line.startswith("#"):
                anchors.add(_slugify(line.lstrip("#")))
    return anchors


def check_markdown_links(files=None) -> list:
    """Return a list of "<file>: <problem>" strings for broken links."""
    if files is None:
        files = [os.path.join(REPO_ROOT, "README.md")]
        docs = os.path.join(REPO_ROOT, "docs")
        if os.path.isdir(docs):
            files += sorted(
                os.path.join(docs, f) for f in os.listdir(docs)
                if f.endswith(".md"))
    problems = []
    for path in files:
        if not os.path.exists(path):
            problems.append(f"{path}: file missing")
            continue
        base = os.path.dirname(path)
        rel = os.path.relpath(path, REPO_ROOT)
        with open(path, encoding="utf-8") as f:
            text = f.read()
        # links inside fenced code blocks are examples, not navigation
        text = re.sub(r"```.*?```", "", text, flags=re.S)
        for target in _LINK_RE.findall(text):
            if target.startswith(_EXTERNAL):
                continue
            file_part, _, anchor = target.partition("#")
            dest = (os.path.normpath(os.path.join(base, file_part))
                    if file_part else path)
            if not os.path.exists(dest):
                problems.append(f"{rel}: broken link target {target!r}")
                continue
            if anchor and dest.endswith(".md"):
                if anchor not in _anchors_of(dest):
                    problems.append(
                        f"{rel}: missing anchor {target!r} "
                        f"(no matching heading in {os.path.relpath(dest, REPO_ROOT)})")
    return problems


def _public_symbols(module):
    """(qualname, obj) for the module's public functions/classes/methods."""
    for name, obj in sorted(vars(module).items()):
        if name.startswith("_"):
            continue
        if not (inspect.isfunction(obj) or inspect.isclass(obj)):
            continue
        if getattr(obj, "__module__", None) != module.__name__:
            continue    # re-export; documented where it is defined
        yield f"{module.__name__}.{name}", obj
        if inspect.isclass(obj):
            for mname, mobj in sorted(vars(obj).items()):
                if mname.startswith("_"):
                    continue
                if isinstance(mobj, property):
                    mobj = mobj.fget
                if isinstance(mobj, (staticmethod, classmethod)):
                    mobj = mobj.__func__
                if inspect.isfunction(mobj):
                    yield f"{module.__name__}.{name}.{mname}", mobj


def check_docstrings() -> tuple:
    """Return ``(coverage_percent, missing)`` over the public API."""
    covered, missing = 0, []
    total = 0
    for modname in PUBLIC_API_MODULES:
        module = importlib.import_module(modname)
        if not (module.__doc__ and len(module.__doc__) >= MIN_DOCSTRING):
            missing.append(modname + " (module docstring)")
            total += 1
        else:
            covered += 1
            total += 1
        for qualname, obj in _public_symbols(module):
            total += 1
            doc = inspect.getdoc(obj)
            if doc and len(doc) >= MIN_DOCSTRING:
                covered += 1
            else:
                missing.append(qualname)
    pct = 100.0 * covered / max(total, 1)
    return pct, missing


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--fail-under", type=float, default=100.0,
                    help="minimum docstring coverage percent (default 100)")
    args = ap.parse_args()
    sys.path.insert(0, os.path.join(REPO_ROOT, "src"))
    failed = False
    problems = check_markdown_links()
    for p in problems:
        print(f"LINK: {p}", file=sys.stderr)
        failed = True
    pct, missing = check_docstrings()
    for m in missing:
        print(f"DOCSTRING MISSING: {m}", file=sys.stderr)
    print(f"docstring coverage: {pct:.1f}% "
          f"({len(missing)} public symbols undocumented)")
    if pct < args.fail_under:
        print(f"FAIL: coverage {pct:.1f}% < --fail-under "
              f"{args.fail_under:.1f}%", file=sys.stderr)
        failed = True
    if not problems:
        print("markdown links: OK")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
