"""Repo-local gate tooling: docs lint (`check_docs`) and the JAX/Pallas
static-analysis pass (`graphlint`).  Nothing here is installed with the
package; the tools run from a checkout (`python -m tools.graphlint ...`).
"""
