"""Small AST helpers shared by the graphlint rules."""
from __future__ import annotations

import ast
from typing import Iterator, Optional


def call_tail(func: ast.expr) -> Optional[str]:
    """Last path segment of a call target: ``jax.lax.psum`` -> ``psum``,
    ``psum`` -> ``psum``, anything else -> None."""
    if isinstance(func, ast.Attribute):
        return func.attr
    if isinstance(func, ast.Name):
        return func.id
    return None


def dotted_name(node: ast.expr) -> Optional[str]:
    """Full dotted path of a Name/Attribute chain, else None."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if not isinstance(node, ast.Name):
        return None
    parts.append(node.id)
    return ".".join(reversed(parts))


def keyword_arg(call: ast.Call, name: str) -> Optional[ast.expr]:
    """The value of keyword *name* in *call*, else None."""
    for kw in call.keywords:
        if kw.arg == name:
            return kw.value
    return None


def has_double_star(call: ast.Call) -> bool:
    """True when the call forwards ``**kwargs`` (keywords are opaque)."""
    return any(kw.arg is None for kw in call.keywords)


def walk_shallow(node: ast.AST) -> Iterator[ast.AST]:
    """``ast.walk`` that does not descend into nested function/class
    definitions or lambdas (their scopes are analyzed separately)."""
    stack = list(ast.iter_child_nodes(node))
    while stack:
        child = stack.pop()
        yield child
        if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                              ast.Lambda, ast.ClassDef)):
            continue
        stack.extend(ast.iter_child_nodes(child))


def string_constants(node: ast.expr) -> Iterator[tuple]:
    """Yield ``(lineno, value)`` for string constants in *node*, looking
    through tuple/list literals one level deep."""
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        yield node.lineno, node.value
    elif isinstance(node, (ast.Tuple, ast.List)):
        for elt in node.elts:
            if isinstance(elt, ast.Constant) and isinstance(elt.value, str):
                yield elt.lineno, elt.value


def function_defs(tree: ast.AST) -> Iterator[ast.FunctionDef]:
    """Every (possibly nested) function definition in *tree*."""
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node
