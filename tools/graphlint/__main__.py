"""CLI entry point: ``python -m tools.graphlint src/ benchmarks/ examples/``.

Exit status is 1 when any error-severity finding survives suppression
filtering (warnings print but do not fail), or when ``--max-seconds`` is
exceeded — the CI gate asserts the pass stays off the critical path.

``--changed-only`` restricts *reporting* to files touched since
``git merge-base HEAD origin/main`` (override the base with
``--changed-base``) while still indexing the whole tree, so the
project-wide dataflow rules stay sound — the pre-commit recipe in
``docs/LINTING.md`` uses it.  ``--stats`` prints a per-rule wall-time
table; ``--sarif-out FILE`` additionally writes the findings as SARIF
2.1.0 for ``github/codeql-action/upload-sarif``.
"""
from __future__ import annotations

import argparse
import os
import sys
import time

# allow `python tools/graphlint/__main__.py` as well as `-m tools.graphlint`
if __package__ in (None, ""):  # pragma: no cover
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__)))))
    from tools.graphlint.core import (Config, RunStats, all_rules,
                                      changed_files, lint_paths)
else:
    from .core import Config, RunStats, all_rules, changed_files, lint_paths

_REPORT_DIR = os.path.dirname(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__))))
sys.path.insert(0, _REPORT_DIR)
from tools import _report  # noqa: E402


def main(argv=None) -> int:
    """Parse args, run the lint, emit findings, return the exit status."""
    ap = argparse.ArgumentParser(
        prog="python -m tools.graphlint",
        description=__doc__.splitlines()[0])
    ap.add_argument("paths", nargs="*", default=[],
                    help="files or directories to lint (repo-relative)")
    ap.add_argument("--format", choices=_report.FORMATS, default="human",
                    help="finding output format (default: human)")
    ap.add_argument("--config", default=None, metavar="PYPROJECT",
                    help="pyproject.toml to read [tool.graphlint] from "
                         "(default: the repo's own)")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalog and exit")
    ap.add_argument("--max-seconds", type=float, default=None,
                    help="fail if the lint run takes longer than this "
                         "(the CI wall-clock budget)")
    ap.add_argument("--stats", action="store_true",
                    help="print a per-rule wall-time table after linting")
    ap.add_argument("--changed-only", action="store_true",
                    help="report findings only in files changed vs the "
                         "merge base (the whole tree is still indexed)")
    ap.add_argument("--changed-base", default="origin/main", metavar="REF",
                    help="base ref for --changed-only (default: origin/main)")
    ap.add_argument("--sarif-out", default=None, metavar="FILE",
                    help="additionally write findings as SARIF 2.1.0 "
                         "to FILE (for github/codeql-action/upload-sarif)")
    args = ap.parse_args(argv)

    if args.list_rules:
        config = Config.load(args.config)
        rules = all_rules()
        for name in sorted(rules):
            fn = rules[name]
            doc = (fn.__doc__ or "").strip().split("\n")[0]
            print(f"{name} [{config.severity_of(name)}] {doc}")
        return 0
    if not args.paths:
        if args.changed_only:
            # bare `--changed-only` (the pre-commit recipe): lint the
            # default CI scope, report only what the diff touches
            args.paths = ["src", "benchmarks", "examples", "tests", "tools"]
        else:
            ap.error("no paths given (e.g. src/ benchmarks/ examples/)")

    report_only = None
    if args.changed_only:
        report_only = changed_files(args.changed_base)
        if report_only is None:
            print(f"graphlint: --changed-only: cannot resolve merge base "
                  f"vs {args.changed_base!r}; linting everything",
                  file=sys.stderr)

    t0 = time.monotonic()
    config = Config.load(args.config)
    stats = RunStats()
    findings = lint_paths(args.paths, config, stats=stats,
                          report_only=report_only)
    elapsed = time.monotonic() - t0

    dicts = [f.as_dict() for f in findings]
    _report.emit(dicts, fmt=args.format, tool_name="graphlint")
    if args.sarif_out:
        rule_docs = {name: (fn.__doc__ or name).strip().split("\n")[0]
                     for name, fn in all_rules().items()}
        _report.write_sarif(dicts, args.sarif_out, tool_name="graphlint",
                            rule_docs=rule_docs)
    n_err = sum(1 for f in findings if f.severity == "error")
    n_warn = len(findings) - n_err
    if args.stats:
        print(stats.table())
    if args.format == "human":
        print(f"graphlint: {n_err} error(s), {n_warn} warning(s) "
              f"in {elapsed:.2f}s")
    if args.max_seconds is not None and elapsed > args.max_seconds:
        print(f"graphlint: FAIL — took {elapsed:.2f}s, over the "
              f"--max-seconds {args.max_seconds:.1f}s budget",
              file=sys.stderr)
        return 1
    return 1 if n_err else 0


if __name__ == "__main__":
    sys.exit(main())
