"""CLI entry point: ``python -m tools.graphlint src/ benchmarks/ examples/``.

Exit status is 1 when any error-severity finding survives suppression
filtering (warnings print but do not fail), or when ``--max-seconds`` is
exceeded — the CI gate asserts the pass stays off the critical path.
"""
from __future__ import annotations

import argparse
import os
import sys
import time

# allow `python tools/graphlint/__main__.py` as well as `-m tools.graphlint`
if __package__ in (None, ""):  # pragma: no cover
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__)))))
    from tools.graphlint.core import Config, RULES, lint_paths
else:
    from .core import Config, RULES, lint_paths

_REPORT_DIR = os.path.dirname(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__))))
sys.path.insert(0, _REPORT_DIR)
from tools import _report  # noqa: E402


def main(argv=None) -> int:
    """Parse args, run the lint, emit findings, return the exit status."""
    ap = argparse.ArgumentParser(
        prog="python -m tools.graphlint",
        description=__doc__.splitlines()[0])
    ap.add_argument("paths", nargs="*", default=[],
                    help="files or directories to lint (repo-relative)")
    ap.add_argument("--format", choices=_report.FORMATS, default="human",
                    help="finding output format (default: human)")
    ap.add_argument("--config", default=None, metavar="PYPROJECT",
                    help="pyproject.toml to read [tool.graphlint] from "
                         "(default: the repo's own)")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalog and exit")
    ap.add_argument("--max-seconds", type=float, default=None,
                    help="fail if the lint run takes longer than this "
                         "(the CI wall-clock budget)")
    args = ap.parse_args(argv)

    if args.list_rules:
        config = Config.load(args.config)
        for name in sorted(RULES):
            fn = RULES[name]
            doc = (fn.__doc__ or "").strip().split("\n")[0]
            print(f"{name} [{config.severity_of(name)}] {doc}")
        return 0
    if not args.paths:
        ap.error("no paths given (e.g. src/ benchmarks/ examples/)")

    t0 = time.monotonic()
    config = Config.load(args.config)
    findings = lint_paths(args.paths, config)
    elapsed = time.monotonic() - t0

    _report.emit([f.as_dict() for f in findings], fmt=args.format)
    n_err = sum(1 for f in findings if f.severity == "error")
    n_warn = len(findings) - n_err
    if args.format == "human":
        print(f"graphlint: {n_err} error(s), {n_warn} warning(s) "
              f"in {elapsed:.2f}s")
    if args.max_seconds is not None and elapsed > args.max_seconds:
        print(f"graphlint: FAIL — took {elapsed:.2f}s, over the "
              f"--max-seconds {args.max_seconds:.1f}s budget",
              file=sys.stderr)
        return 1
    return 1 if n_err else 0


if __name__ == "__main__":
    sys.exit(main())
