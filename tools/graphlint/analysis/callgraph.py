"""Callable resolution + traced-function discovery over the index.

The cross-file question the dataflow rules ask constantly is "which
project function does this expression denote" — through a bare name, an
import, a local binding (``step = jax.jit(make_pipelined_step(...))``),
``functools.partial``, or a factory call whose *return value* is the
callable (the repo's ``make_*_fn`` idiom).  :meth:`CallGraph.resolve`
answers it syntactically and conservatively: it returns every candidate
it can prove, or an empty list when it cannot — rules skip what they
cannot resolve rather than guess.

On top of resolution the graph computes the **traced set**: every
function that flows into ``jax.jit`` / ``shard_map`` / ``pallas_call``
(directly, by name, decorated, via partial, or via a factory return),
closed transitively over calls — a helper called from a jitted function
executes under tracing too, so its closure captures are just as baked.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from ..astutil import call_tail
from .symbols import FunctionInfo, ModuleInfo, ProjectIndex

#: call targets whose first argument's function is traced
TRACE_SINKS = {"jit": "jit", "shard_map": "shard_map",
               "pallas_call": "pallas_call"}


def _shallow_nodes(body):
    """Every AST node in *body* without entering nested def/class scopes
    (lambdas stay in — they share the enclosing scope's names)."""
    stack = list(body)
    while stack:
        node = stack.pop()
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            stack.extend(node.decorator_list)
            continue
        yield node
        stack.extend(ast.iter_child_nodes(node))


def _is_jit_decorator(dec: ast.expr) -> Optional[str]:
    """The sink kind a decorator implies, else None."""
    tail = call_tail(dec)
    if tail in TRACE_SINKS:
        return TRACE_SINKS[tail]
    if isinstance(dec, ast.Call):
        inner = call_tail(dec.func)
        if inner in TRACE_SINKS:
            return TRACE_SINKS[inner]
        if inner == "partial" and dec.args:
            return _is_jit_decorator(dec.args[0])
    return None


class CallGraph:
    """Project call graph facets: resolution, traced set, call edges."""

    def __init__(self, index: ProjectIndex):
        self.index = index
        #: FunctionInfo -> how it is traced ("jit"/"shard_map"/"pallas_call")
        self.traced: Dict[FunctionInfo, str] = {}
        #: FunctionInfo -> project functions it calls (resolved)
        self.calls: Dict[FunctionInfo, Set[FunctionInfo]] = {}
        self._local_bindings: Dict[Tuple[str, str], Dict[str, ast.expr]] = {}
        self._build()

    # -- resolution ---------------------------------------------------------

    def _bindings(self, module: ModuleInfo,
                  fi: Optional[FunctionInfo]) -> Dict[str, ast.expr]:
        """name -> last syntactic ``name = expr`` in a scope body (the
        module top level when *fi* is None).  Nested defs are skipped —
        they are separate scopes."""
        key = (module.path, fi.qualname if fi else "<module>")
        if key in self._local_bindings:
            return self._local_bindings[key]
        out: Dict[str, ast.expr] = {}
        body = fi.node.body if fi else module.tree.body
        stack = list(body)
        while stack:
            stmt = stack.pop(0)
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
                continue
            if (isinstance(stmt, ast.Assign) and len(stmt.targets) == 1
                    and isinstance(stmt.targets[0], ast.Name)):
                out[stmt.targets[0].id] = stmt.value
            for field in ("body", "orelse", "finalbody"):
                stack.extend(getattr(stmt, field, []) or [])
            for handler in getattr(stmt, "handlers", []) or []:
                stack.extend(handler.body)
        self._local_bindings[key] = out
        return out

    def _receiver_class(self, recv_name: str, module: ModuleInfo,
                        fi: Optional[FunctionInfo]) -> Optional[str]:
        """The top-level class *recv_name* is an instance of, when its
        binding in the scope chain is syntactically ``ClassName(...)``."""
        scope = fi
        bound = None
        while scope is not None:
            bound = self._bindings(module, scope).get(recv_name)
            if bound is not None:
                break
            scope = scope.parent
        if bound is None:
            bound = self._bindings(module, None).get(recv_name)
        if isinstance(bound, ast.Call):
            tail = call_tail(bound.func)
            if tail in module.classes:
                return tail
        return None

    def _nested_defs(self, fi: FunctionInfo, name: str) -> List[FunctionInfo]:
        module = self.index.modules[fi.path]
        return [c for c in module.children.get(fi.qualname, [])
                if c.name == name]

    def resolve(self, expr: ast.expr, module: ModuleInfo,
                fi: Optional[FunctionInfo],
                _seen: Optional[Set[int]] = None) -> List[FunctionInfo]:
        """All project functions *expr* can denote in the given scope.

        Handles names (scope chain -> local binding -> top-level def ->
        import), dotted attributes, ``functools.partial(f, ...)``,
        ``jax.jit(f)`` (transparent — jit returns a wrapper around f),
        and factory calls (``make_x(...)``: resolves to the functions
        ``make_x`` returns).  Unresolvable expressions yield ``[]``.
        """
        seen = _seen if _seen is not None else set()
        if id(expr) in seen:
            return []
        seen.add(id(expr))

        if isinstance(expr, ast.Name):
            scope = fi
            while scope is not None:
                nested = self._nested_defs(scope, expr.id)
                if nested:
                    return nested
                bound = self._bindings(module, scope).get(expr.id)
                if bound is not None:
                    return self.resolve(bound, module, scope, seen)
                scope = scope.parent
            if expr.id in module.toplevel:
                return [module.toplevel[expr.id]]
            bound = self._bindings(module, None).get(expr.id)
            if bound is not None:
                return self.resolve(bound, module, None, seen)
            target = self.index.resolve_function(module, expr.id)
            return [target] if target is not None else []
        if isinstance(expr, ast.Attribute):
            if isinstance(expr.value, ast.Name):
                # alias.fn where alias imports an indexed module
                dotted = module.imports.get(expr.value.id)
                if dotted:
                    target_mod = self.index.resolve_module(dotted)
                    if target_mod and expr.attr in target_mod.toplevel:
                        return [target_mod.toplevel[expr.attr]]
                # obj.method where obj binds to ClassName(...) in scope
                cls = self._receiver_class(expr.value.id, module, fi)
                if cls is not None:
                    method = module.functions.get(f"{cls}.{expr.attr}")
                    if method is not None:
                        return [method]
            return []
        if isinstance(expr, ast.Call):
            tail = call_tail(expr.func)
            if tail == "partial" and expr.args:
                return self.resolve(expr.args[0], module, fi, seen)
            if tail in TRACE_SINKS and expr.args:
                return self.resolve(expr.args[0], module, fi, seen)
            factories = self.resolve(expr.func, module, fi, seen)
            out: List[FunctionInfo] = []
            for factory in factories:
                out.extend(self.returned_functions(factory, seen))
            return out
        return []

    def returned_functions(self, fi: FunctionInfo,
                           _seen: Optional[Set[int]] = None
                           ) -> List[FunctionInfo]:
        """Project functions *fi* can return (the factory idiom).

        Scans *fi*'s own return statements (not nested scopes'); tuple
        returns contribute each element, so
        ``return jax.jit(gen_fn), device_args`` resolves ``gen_fn``."""
        module = self.index.modules[fi.path]
        out: List[FunctionInfo] = []
        for stmt in fi.node.body:
            for ret in self._shallow_returns(stmt):
                if ret.value is None:
                    continue
                values = (ret.value.elts
                          if isinstance(ret.value, ast.Tuple)
                          else [ret.value])
                for value in values:
                    out.extend(self.resolve(value, module, fi, _seen))
        return out

    @staticmethod
    def _shallow_returns(stmt: ast.stmt):
        """Return statements in *stmt* without entering nested scopes."""
        stack = [stmt]
        while stack:
            node = stack.pop()
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.Lambda, ast.ClassDef)):
                continue
            if isinstance(node, ast.Return):
                yield node
            stack.extend(ast.iter_child_nodes(node))

    # -- traced set ---------------------------------------------------------

    def _mark(self, fis: List[FunctionInfo], how: str) -> None:
        for fi in fis:
            self.traced.setdefault(fi, how)

    def _build(self) -> None:
        # 1. decorator-traced functions
        for fi in self.index.iter_functions():
            for dec in fi.node.decorator_list:
                how = _is_jit_decorator(dec)
                if how is not None:
                    self.traced.setdefault(fi, how)
        # 2. sink call sites, resolved in their enclosing scope (shallow:
        #    nested defs are their own scopes and get their own pass)
        for module, fi, body in self.index.iter_scopes():
            for node in _shallow_nodes(body):
                if not isinstance(node, ast.Call):
                    continue
                tail = call_tail(node.func)
                if tail in TRACE_SINKS and node.args:
                    self._mark(self.resolve(node.args[0], module, fi),
                               TRACE_SINKS[tail])
        # 3. call edges between project functions (used for transitive
        #    tracing: helpers called from traced functions trace too)
        for module, fi, body in self.index.iter_scopes():
            if fi is None:
                continue
            callees: Set[FunctionInfo] = set()
            for node in _shallow_nodes(body):
                if isinstance(node, ast.Call):
                    tail = call_tail(node.func)
                    if tail in TRACE_SINKS:
                        continue          # sink edges handled above
                    for target in self.resolve(node.func, module, fi):
                        if target != fi:
                            callees.add(target)
            self.calls[fi] = callees
        # 4. transitive closure over call edges
        work = list(self.traced)
        while work:
            fi = work.pop()
            how = self.traced[fi]
            for callee in self.calls.get(fi, ()):
                if callee not in self.traced:
                    self.traced[callee] = how
                    work.append(callee)
