"""Statement-level control-flow graphs for graphlint's dataflow rules.

One node per *statement* (plus synthetic ``ENTRY``/``EXIT``), which is
the right granularity for the lint queries: "does every path from this
``store.issue()`` reach a ``rows()`` call", "which assignments reach
this call site".  Compound statements contribute ONE node holding only
their header expressions (an ``If``'s test, a ``For``'s iterator, a
``With``'s context items); their bodies become separate nodes wired
with the real branch/loop edges, so a rule scanning a node never sees
a nested body twice.

Exception edges are deliberately approximate: every statement inside a
``try`` body may jump to each handler, and a ``raise`` terminates its
path without reaching ``EXIT`` (propagating an exception is not the
leak class the lifecycle rule chases, and modelling it as a leak would
flag every error path that lacks a ``finally``).
"""
from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Optional, Set, Tuple

ENTRY = 0
EXIT = 1

#: statements that open a new scope — their bodies are separate CFGs
SCOPE_STMTS = (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)


class CFG:
    """A per-scope control-flow graph over statement nodes.

    ``stmts`` maps node id -> the owning :class:`ast.stmt`; the
    synthetic ``ENTRY``/``EXIT`` ids have no statement.  ``succ`` holds
    forward edges.  ``header_exprs`` maps a node to the expression
    subtrees evaluated *at* that node (for compound statements, only
    the header — never the nested body).
    """

    def __init__(self):
        self.succ: Dict[int, Set[int]] = {ENTRY: set(), EXIT: set()}
        self.stmts: Dict[int, ast.stmt] = {}
        self.header_exprs: Dict[int, List[ast.AST]] = {}

    def nodes(self) -> Iterable[int]:
        """All node ids, synthetic ones included."""
        return self.succ.keys()

    def preds(self) -> Dict[int, Set[int]]:
        """Reverse edge map (computed on demand)."""
        rev: Dict[int, Set[int]] = {n: set() for n in self.succ}
        for src, dsts in self.succ.items():
            for d in dsts:
                rev[d].add(src)
        return rev

    def reachable(self, start: int = ENTRY) -> Set[int]:
        """Node ids reachable from *start* (including it)."""
        seen = {start}
        stack = [start]
        while stack:
            for nxt in self.succ.get(stack.pop(), ()):
                if nxt not in seen:
                    seen.add(nxt)
                    stack.append(nxt)
        return seen


def _header_exprs(stmt: ast.stmt) -> List[ast.AST]:
    """The expressions a statement evaluates at its own CFG node."""
    if isinstance(stmt, (ast.If, ast.While)):
        return [stmt.test]
    if isinstance(stmt, (ast.For, ast.AsyncFor)):
        return [stmt.iter]
    if isinstance(stmt, (ast.With, ast.AsyncWith)):
        return [item.context_expr for item in stmt.items]
    if isinstance(stmt, ast.Try):
        return []
    if isinstance(stmt, SCOPE_STMTS):
        # decorators/defaults evaluate here; the body is its own scope
        out: List[ast.AST] = list(stmt.decorator_list)
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            out += [d for d in stmt.args.defaults]
            out += [d for d in stmt.args.kw_defaults if d is not None]
        return out
    return [stmt]


class _Builder:
    """Recursive-descent CFG construction over a statement list."""

    def __init__(self):
        self.cfg = CFG()
        self._next = EXIT + 1
        # (loop_header_id, break_frontier) innermost-last
        self._loops: List[Tuple[int, Set[int]]] = []

    def _node(self, stmt: ast.stmt) -> int:
        nid = self._next
        self._next += 1
        self.cfg.succ[nid] = set()
        self.cfg.stmts[nid] = stmt
        self.cfg.header_exprs[nid] = _header_exprs(stmt)
        return nid

    def _link(self, frontier: Set[int], nid: int) -> None:
        for src in frontier:
            self.cfg.succ[src].add(nid)

    def seq(self, stmts: List[ast.stmt], frontier: Set[int]) -> Set[int]:
        """Wire *stmts* sequentially; returns the fall-through frontier."""
        for stmt in stmts:
            if not frontier:
                break                    # unreachable tail (after return)
            frontier = self.one(stmt, frontier)
        return frontier

    def one(self, stmt: ast.stmt, frontier: Set[int]) -> Set[int]:
        nid = self._node(stmt)
        self._link(frontier, nid)

        if isinstance(stmt, (ast.Return, ast.Raise)):
            # Raise still terminates the path; only Return reaches EXIT
            # (exception propagation is modelled as "path vanishes")
            if isinstance(stmt, ast.Return):
                self.cfg.succ[nid].add(EXIT)
            return set()
        if isinstance(stmt, ast.Break):
            if self._loops:
                self._loops[-1][1].add(nid)
            return set()
        if isinstance(stmt, ast.Continue):
            if self._loops:
                self.cfg.succ[nid].add(self._loops[-1][0])
            return set()
        if isinstance(stmt, ast.If):
            body_f = self.seq(stmt.body, {nid})
            else_f = self.seq(stmt.orelse, {nid}) if stmt.orelse else {nid}
            return body_f | else_f
        if isinstance(stmt, (ast.While, ast.For, ast.AsyncFor)):
            self._loops.append((nid, set()))
            body_f = self.seq(stmt.body, {nid})
            self._link(body_f, nid)       # back edge
            _, breaks = self._loops.pop()
            infinite = (isinstance(stmt, ast.While)
                        and isinstance(stmt.test, ast.Constant)
                        and bool(stmt.test.value))
            out: Set[int] = set() if infinite else {nid}
            if stmt.orelse:
                out = self.seq(stmt.orelse, out)
            return out | breaks
        if isinstance(stmt, (ast.With, ast.AsyncWith)):
            return self.seq(stmt.body, {nid})
        if isinstance(stmt, ast.Try):
            before = self._next
            body_f = self.seq(stmt.body, {nid})
            body_nodes = set(range(before, self._next))
            out: Set[int] = set()
            for handler in stmt.handlers:
                # any statement in the body (or none) may raise into it
                out |= self.seq(handler.body, body_nodes | {nid})
            if stmt.orelse:
                body_f = self.seq(stmt.orelse, body_f)
            out |= body_f
            if stmt.finalbody:
                out = self.seq(stmt.finalbody, out)
            return out
        if isinstance(stmt, ast.Match):
            out = set()
            for case in stmt.cases:
                out |= self.seq(case.body, {nid})
            return out | {nid}           # no case may match
        # simple statements (incl. nested def/class headers) fall through
        return {nid}


def build_cfg(body: List[ast.stmt]) -> CFG:
    """Build the CFG of one scope from its statement list.

    Pass a function's ``node.body`` for function scopes, or a module's
    top-level statements for script scopes (``examples/`` launchers
    create handles at module level too)."""
    b = _Builder()
    frontier = b.seq(body, {ENTRY})
    for src in frontier:
        b.cfg.succ[src].add(EXIT)
    if not b.cfg.stmts:                   # empty body: entry falls out
        b.cfg.succ[ENTRY].add(EXIT)
    return b.cfg
