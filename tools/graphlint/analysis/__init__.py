"""Phase-1 analysis layer: the project-wide index the dataflow rules run on.

graphlint v2 splits a lint run into two phases.  Phase 1 parses every
file ONCE and builds this package's structures over the shared ASTs:

* :mod:`symbols`   — per-module symbol tables (imports, functions with
  qualified names and enclosing scopes) rolled up into a
  :class:`~tools.graphlint.analysis.symbols.ProjectIndex`;
* :mod:`callgraph` — callable resolution across modules (through
  ``functools.partial``, local bindings, and ``make_*`` factories) plus
  the set of functions that provably flow into ``jax.jit`` /
  ``shard_map`` / ``pallas_call``;
* :mod:`cfg`       — a statement-level control-flow graph per function
  (or module top level), the substrate for all-paths queries;
* :mod:`defuse`    — reaching definitions over a CFG, the substrate for
  "what was this name when the call happened" queries.

Phase 2 runs the per-file syntactic rules and the project-wide dataflow
rules (``handle-lifecycle``, ``closure-capture``, ``carry-structure``)
against the index — see ``tools/graphlint/core.py``.
"""
from .callgraph import CallGraph  # noqa: F401
from .cfg import CFG, build_cfg  # noqa: F401
from .defuse import ReachingDefs, assigned_names  # noqa: F401
from .symbols import FunctionInfo, ModuleInfo, ProjectIndex  # noqa: F401
