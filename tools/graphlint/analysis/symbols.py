"""Module symbol tables and the project-wide index (phase 1).

The index is built ONCE per lint run from the already-parsed ASTs
(``core.FileEntry`` — one ``ast.parse`` per file, shared by every
rule).  It answers the cross-file questions the dataflow rules ask:

* which module does this repo-relative path implement
  (``src/repro/core/pipeline.py`` -> ``repro.core.pipeline``);
* which function does ``from ..core.pipeline import pipelined_loop``
  resolve to;
* what is the enclosing scope chain of a nested ``def`` (closure
  analysis walks it outward);
* the (cached) CFG and reaching-defs of any function or module scope.
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Dict, List, Optional, Tuple

from .cfg import CFG, build_cfg
from .defuse import ReachingDefs


@dataclasses.dataclass
class FunctionInfo:
    """One function definition, qualified by file and lexical scope."""

    path: str                      #: repo-relative path of the file
    module: str                    #: dotted module name ("" for scripts)
    qualname: str                  #: e.g. ``make_pipelined_step.<locals>.step``
    name: str                      #: bare name
    node: ast.AST                  #: the FunctionDef/AsyncFunctionDef
    parent: Optional["FunctionInfo"]   #: enclosing function, if nested
    cls: Optional[str]             #: enclosing class name, if a method
    lineno: int

    def __hash__(self):            # identity keyed by definition site
        return hash((self.path, self.qualname, self.lineno))

    def __eq__(self, other):
        return (isinstance(other, FunctionInfo)
                and (self.path, self.qualname, self.lineno)
                == (other.path, other.qualname, other.lineno))

    def scope_chain(self) -> List["FunctionInfo"]:
        """This function, then each enclosing function outward."""
        chain, fi = [], self
        while fi is not None:
            chain.append(fi)
            fi = fi.parent
        return chain


@dataclasses.dataclass
class ModuleInfo:
    """Per-file symbol table over the shared AST."""

    path: str
    name: str                      #: dotted module name ("" if not importable)
    tree: ast.Module
    imports: Dict[str, str]        #: local alias -> dotted origin
    functions: Dict[str, FunctionInfo]       #: qualname -> info (all scopes)
    toplevel: Dict[str, FunctionInfo]        #: bare name -> top-level defs
    classes: Dict[str, ast.ClassDef]         #: top-level class defs
    children: Dict[Optional[str], List[FunctionInfo]]  #: parent qualname -> nested defs


def module_name_for(rel_path: str) -> str:
    """Dotted module name for a repo-relative path (import-compatible:
    ``src/`` is the package root; scripts keep their directory prefix
    so ``tools/check_docs.py`` -> ``tools.check_docs``)."""
    p = rel_path.replace("\\", "/")
    if p.startswith("src/"):
        p = p[len("src/"):]
    if not p.endswith(".py"):
        return ""
    p = p[:-3]
    if p.endswith("/__init__"):
        p = p[: -len("/__init__")]
    return p.replace("/", ".")


class _FunctionCollector(ast.NodeVisitor):
    def __init__(self, path: str, module: str):
        self.path = path
        self.module = module
        self.functions: Dict[str, FunctionInfo] = {}
        self.children: Dict[Optional[str], List[FunctionInfo]] = {}
        self._fn_stack: List[FunctionInfo] = []
        self._cls_stack: List[str] = []
        self._qual: List[str] = []

    def _add(self, node) -> FunctionInfo:
        qual = ".".join((*self._qual, node.name))
        fi = FunctionInfo(
            path=self.path, module=self.module, qualname=qual,
            name=node.name, node=node,
            parent=self._fn_stack[-1] if self._fn_stack else None,
            cls=self._cls_stack[-1] if self._cls_stack else None,
            lineno=node.lineno)
        self.functions[qual] = fi
        parent_key = fi.parent.qualname if fi.parent else None
        self.children.setdefault(parent_key, []).append(fi)
        return fi

    def visit_FunctionDef(self, node):
        fi = self._add(node)
        self._fn_stack.append(fi)
        self._qual += [node.name, "<locals>"]
        self.generic_visit(node)
        self._qual = self._qual[:-2]
        self._fn_stack.pop()

    visit_AsyncFunctionDef = visit_FunctionDef

    def visit_ClassDef(self, node):
        self._cls_stack.append(node.name)
        self._qual.append(node.name)
        self.generic_visit(node)
        self._qual.pop()
        self._cls_stack.pop()

    def visit_Lambda(self, node):
        pass                               # not tracked as named scopes


def build_module_info(path: str, tree: ast.Module) -> ModuleInfo:
    """Symbol-table one parsed file."""
    imports: Dict[str, str] = {}
    module = module_name_for(path)
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                imports[a.asname or a.name.split(".")[0]] = a.name
        elif isinstance(node, ast.ImportFrom):
            if node.module is None:
                continue
            base = node.module
            if node.level:                 # relative: resolve against module
                parts = module.split(".")
                anchor = parts[: len(parts) - node.level]
                base = ".".join((*anchor, node.module))
            for a in node.names:
                if a.name == "*":
                    continue
                imports[a.asname or a.name] = f"{base}.{a.name}"
    coll = _FunctionCollector(path, module)
    coll.visit(tree)
    toplevel = {fi.name: fi for fi in coll.children.get(None, [])
                if fi.cls is None}
    classes = {n.name: n for n in tree.body if isinstance(n, ast.ClassDef)}
    return ModuleInfo(path=path, name=module, tree=tree, imports=imports,
                      functions=coll.functions, toplevel=toplevel,
                      classes=classes, children=coll.children)


class ProjectIndex:
    """The shared phase-1 artifact: one entry per parsed file, plus the
    lazily-built call graph and per-function CFG/def-use caches.

    ``entries`` maps repo-relative path -> ``core.FileEntry`` (the
    single-parse cache); files that failed to parse are skipped here
    (they already carry a ``parse-error`` finding).
    """

    #: sentinel qualname for a module's top-level statement scope
    MODULE_SCOPE = "<module>"

    def __init__(self, entries: Dict[str, "object"]):
        self.entries = entries
        self.modules: Dict[str, ModuleInfo] = {}          # by path
        self.modules_by_name: Dict[str, ModuleInfo] = {}
        for path, entry in entries.items():
            if entry.tree is None:
                continue
            info = build_module_info(path, entry.tree)
            self.modules[path] = info
            if info.name:
                self.modules_by_name[info.name] = info
        self._cfgs: Dict[Tuple[str, str], CFG] = {}
        self._reaching: Dict[Tuple[str, str], ReachingDefs] = {}
        self._callgraph = None

    # -- lazy facets --------------------------------------------------------

    @property
    def callgraph(self):
        """The project call graph (built on first use)."""
        if self._callgraph is None:
            from .callgraph import CallGraph
            self._callgraph = CallGraph(self)
        return self._callgraph

    def iter_functions(self):
        """Every FunctionInfo in the project, grouped by module."""
        for info in self.modules.values():
            yield from info.functions.values()

    def iter_scopes(self):
        """(module, fi_or_None, body) for every function scope plus each
        module's top-level statement scope (fi None)."""
        for info in self.modules.values():
            yield info, None, [s for s in info.tree.body]
            for fi in info.functions.values():
                yield info, fi, fi.node.body

    def cfg_of(self, path: str, fi: Optional[FunctionInfo]) -> CFG:
        """CFG of a function scope (or the module scope when *fi* is
        None), cached per definition site."""
        key = (path, fi.qualname if fi else self.MODULE_SCOPE)
        if key not in self._cfgs:
            body = fi.node.body if fi else self.modules[path].tree.body
            self._cfgs[key] = build_cfg(body)
        return self._cfgs[key]

    def reaching_of(self, path: str,
                    fi: Optional[FunctionInfo]) -> ReachingDefs:
        """Reaching definitions for a scope, cached with its CFG."""
        key = (path, fi.qualname if fi else self.MODULE_SCOPE)
        if key not in self._reaching:
            params = set()
            if fi is not None:
                a = fi.node.args
                params = {p.arg for p in (*a.posonlyargs, *a.args,
                                          *a.kwonlyargs)}
                if a.vararg:
                    params.add(a.vararg.arg)
                if a.kwarg:
                    params.add(a.kwarg.arg)
            self._reaching[key] = ReachingDefs(self.cfg_of(path, fi),
                                               params=params)
        return self._reaching[key]

    # -- name resolution ----------------------------------------------------

    def resolve_module(self, dotted: str) -> Optional[ModuleInfo]:
        """ModuleInfo for a dotted module name, if it is in this index."""
        return self.modules_by_name.get(dotted)

    def resolve_function(self, module: ModuleInfo,
                         name: str) -> Optional[FunctionInfo]:
        """Resolve a bare *name* used in *module* to a project function:
        a top-level def, or an import chased into another indexed
        module (one hop — re-exports via ``__init__`` resolve because
        the ``from .x import y`` alias records the defining path)."""
        if name in module.toplevel:
            return module.toplevel[name]
        dotted = module.imports.get(name)
        seen = set()
        while dotted and dotted not in seen:
            seen.add(dotted)
            mod_name, _, attr = dotted.rpartition(".")
            target = self.modules_by_name.get(mod_name)
            if target is None:
                return None
            if attr in target.toplevel:
                return target.toplevel[attr]
            dotted = target.imports.get(attr)
        return None
