"""Reaching definitions over a :mod:`cfg` graph (def-use substrate).

A *definition* is "statement node N binds name X" — assignment targets,
``for`` targets, ``with ... as`` names, walrus expressions in the
statement's header, imports, and nested ``def``/``class`` statements.
Function parameters are modelled as definitions at ``ENTRY``, so a use
whose reaching defs include ``ENTRY`` is visibly "maybe the parameter"
rather than silently unbound.

The fixpoint is the textbook forward may-analysis: a definition of X
kills every other definition of X, and ``IN(n)`` is the union of the
predecessors' ``OUT``.  graphlint uses it to answer "which pack sites
can this carry variable come from at this call" (``carry-structure``)
and to keep the CFG property-tested from two independent directions.
"""
from __future__ import annotations

import ast
from typing import Dict, FrozenSet, List, Set, Tuple

from .cfg import CFG, ENTRY

#: one definition: (name, defining node id)
Def = Tuple[str, int]


def _target_names(target: ast.AST, out: Set[str]) -> None:
    if isinstance(target, ast.Name):
        out.add(target.id)
    elif isinstance(target, (ast.Tuple, ast.List)):
        for elt in target.elts:
            _target_names(elt, out)
    elif isinstance(target, ast.Starred):
        _target_names(target.value, out)
    # Attribute/Subscript stores mutate an object, they bind no name


def assigned_names(stmt: ast.stmt,
                   header_exprs: List[ast.AST]) -> Set[str]:
    """Names *stmt* binds at its own CFG node.

    ``header_exprs`` is the node's header list from the CFG (walrus
    expressions inside it count; nested bodies never reach here)."""
    names: Set[str] = set()
    if isinstance(stmt, ast.Assign):
        for tgt in stmt.targets:
            _target_names(tgt, names)
    elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
        if isinstance(stmt, ast.AnnAssign) and stmt.value is None:
            pass                          # bare annotation binds nothing
        else:
            _target_names(stmt.target, names)
    elif isinstance(stmt, (ast.For, ast.AsyncFor)):
        _target_names(stmt.target, names)
    elif isinstance(stmt, (ast.With, ast.AsyncWith)):
        for item in stmt.items:
            if item.optional_vars is not None:
                _target_names(item.optional_vars, names)
    elif isinstance(stmt, (ast.Import, ast.ImportFrom)):
        for alias in stmt.names:
            names.add((alias.asname or alias.name).split(".")[0])
    elif isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                           ast.ClassDef)):
        names.add(stmt.name)
    elif isinstance(stmt, ast.ExceptHandler):  # pragma: no cover
        if stmt.name:
            names.add(stmt.name)
    for expr in header_exprs:
        for node in ast.walk(expr):
            if isinstance(node, ast.NamedExpr):
                _target_names(node.target, names)
    return names


class ReachingDefs:
    """Reaching-definition sets per CFG node, computed to fixpoint."""

    def __init__(self, cfg: CFG, params: Set[str] = frozenset()):
        self.cfg = cfg
        self._gen: Dict[int, Set[str]] = {}
        for nid, stmt in cfg.stmts.items():
            self._gen[nid] = assigned_names(stmt, cfg.header_exprs[nid])
        self._in: Dict[int, Set[Def]] = {n: set() for n in cfg.nodes()}
        self._out: Dict[int, Set[Def]] = {n: set() for n in cfg.nodes()}
        self._out[ENTRY] = {(p, ENTRY) for p in params}
        self._solve()

    def _transfer(self, nid: int, reaching: Set[Def]) -> Set[Def]:
        gen = self._gen.get(nid)
        if not gen:
            return reaching
        return ({(name, site) for name, site in reaching
                 if name not in gen}
                | {(name, nid) for name in gen})

    def _solve(self) -> None:
        preds = self.cfg.preds()
        work = list(self.cfg.nodes())
        while work:
            nid = work.pop()
            if nid == ENTRY:
                continue
            new_in: Set[Def] = set()
            for p in preds[nid]:
                new_in |= self._out[p]
            new_out = self._transfer(nid, new_in)
            if new_in != self._in[nid] or new_out != self._out[nid]:
                self._in[nid] = new_in
                self._out[nid] = new_out
                work.extend(self.cfg.succ.get(nid, ()))

    def reaching(self, nid: int, name: str) -> FrozenSet[int]:
        """Node ids of the definitions of *name* that reach *nid*'s
        entry (``ENTRY`` means "the parameter / nothing local")."""
        return frozenset(site for n, site in self._in[nid] if n == name)

    def defs_in(self, nid: int) -> FrozenSet[Def]:
        """The full reaching-definition set at *nid*'s entry."""
        return frozenset(self._in[nid])
