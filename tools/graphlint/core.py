"""graphlint framework: registry, config, suppressions, and the runner.

The moving parts, in the order a lint run uses them:

* :func:`rule` — decorator that registers a rule function.  A rule takes
  ``(tree, ctx)`` — the parsed :class:`ast.Module` and a
  :class:`FileContext` — and yields ``(lineno, message)`` pairs.
* :class:`Config` — the ``[tool.graphlint]`` block of ``pyproject.toml``
  (enable/disable lists, per-rule severity, exclude globs, extra
  collective axis names).  Loads via :mod:`tomllib` on 3.11+, falling
  back to a minimal TOML-subset parser so the 3.10 container needs no
  new dependency.
* suppression comments — ``# graphlint: disable=<rule>[,rule]`` on (or
  on the line above) the flagged line.  A suppression **must** carry a
  trailing justification (``-- why`` or ``# why``); a bare or malformed
  suppression is itself reported as ``bad-suppression`` and cannot be
  suppressed.
* :func:`lint_source` / :func:`lint_paths` — run the enabled rules and
  return :class:`Finding` objects with config-resolved severities.
"""
from __future__ import annotations

import ast
import dataclasses
import fnmatch
import os
import re
from typing import Callable, Dict, Iterable, List, Optional, Tuple

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))))

SEVERITIES = ("error", "warning")

#: findings the runner itself emits; not suppressible, always errors
META_CHECKS = ("bad-suppression", "parse-error")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at a specific line of a specific file."""

    path: str       #: repo-relative posix path
    line: int       #: 1-based line number
    rule: str       #: rule id (kebab-case)
    severity: str   #: "error" | "warning"
    message: str    #: human-readable explanation

    def as_dict(self) -> dict:
        """The shared ``tools._report`` finding-dict shape."""
        return {"path": self.path, "line": self.line, "check": self.rule,
                "severity": self.severity, "message": self.message}


@dataclasses.dataclass
class FileContext:
    """Per-file inputs a rule may consult beyond the AST."""

    path: str                 #: repo-relative posix path
    source: str               #: full file text
    lines: List[str]          #: source split into lines
    config: "Config"          #: resolved run configuration
    mesh_axes: frozenset      #: axis names rules treat as legitimate


#: rule-id -> rule function; populated by the :func:`rule` decorator
RULES: Dict[str, Callable] = {}


def rule(name: str, default_severity: str = "error"):
    """Register a rule function under *name* with a default severity.

    The decorated function must accept ``(tree, ctx)`` and yield
    ``(lineno, message)`` tuples; its docstring becomes the catalog
    entry shown by ``--list-rules``.
    """
    if default_severity not in SEVERITIES:
        raise ValueError(f"bad severity {default_severity!r}")

    def deco(fn):
        fn.rule_name = name
        fn.default_severity = default_severity
        RULES[name] = fn
        return fn

    return deco


# ---------------------------------------------------------------------------
# configuration ([tool.graphlint] in pyproject.toml)
# ---------------------------------------------------------------------------

def _parse_toml_minimal(text: str) -> dict:
    """Parse the TOML subset graphlint's config needs (3.10 fallback).

    Supports ``[dotted.section]`` headers, ``key = "string"``,
    ``key = ["a", "b"]`` single-line string lists, integers, booleans,
    and ``#`` comments.  Anything fancier raises ``ValueError`` so a
    silently-misread config cannot weaken the gate.
    """
    root: dict = {}
    table = root
    pending = ""
    for raw in text.splitlines():
        line = _strip_toml_comment(raw).strip()
        if not line:
            continue
        if pending:
            pending += " " + line
            if pending.count("[") > pending.count("]"):
                continue
            line, pending = pending, ""
        elif (line.startswith("[") and line.endswith("]")
                and "=" not in line):
            table = root
            for part in line[1:-1].strip().split("."):
                part = part.strip().strip('"')
                table = table.setdefault(part, {})
            continue
        if "=" not in line:
            raise ValueError(f"unparseable TOML line: {raw!r}")
        key, _, value = line.partition("=")
        value = value.strip()
        if value.count("[") > value.count("]"):  # multi-line array
            pending = line
            continue
        table[key.strip().strip('"')] = _parse_toml_value(value)
    return root


def _strip_toml_comment(line: str) -> str:
    out, in_str = [], False
    for ch in line:
        if ch == '"':
            in_str = not in_str
        if ch == "#" and not in_str:
            break
        out.append(ch)
    return "".join(out)


def _parse_toml_value(value: str):
    if value.startswith("[") and value.endswith("]"):
        inner = value[1:-1].strip()
        if not inner:
            return []
        return [_parse_toml_value(v.strip())
                for v in inner.split(",") if v.strip()]
    if value.startswith('"') and value.endswith('"') and len(value) >= 2:
        return value[1:-1]
    if value in ("true", "false"):
        return value == "true"
    try:
        return int(value)
    except ValueError:
        raise ValueError(f"unparseable TOML value: {value!r}")


def _load_toml(path: str) -> dict:
    """``tomllib`` when available (3.11+), else the minimal parser."""
    with open(path, "rb") as f:
        data = f.read()
    try:
        import tomllib
    except ModuleNotFoundError:
        return _parse_toml_minimal(data.decode("utf-8"))
    return tomllib.loads(data.decode("utf-8"))


@dataclasses.dataclass
class Config:
    """Resolved ``[tool.graphlint]`` settings for one lint run."""

    enable: Tuple[str, ...] = ()        #: if non-empty, ONLY these rules run
    disable: Tuple[str, ...] = ()       #: rules switched off
    severity: Dict[str, str] = dataclasses.field(default_factory=dict)
    exclude: Tuple[str, ...] = ()       #: repo-relative glob patterns
    collective_axes: Tuple[str, ...] = ()  #: extra allowed axis names

    @classmethod
    def from_dict(cls, raw: dict) -> "Config":
        """Build a Config from a ``[tool.graphlint]`` mapping, validating
        rule ids and severity values so typos fail loudly."""
        known = set(RULES)
        cfg = cls(
            enable=tuple(raw.get("enable", ())),
            disable=tuple(raw.get("disable", ())),
            severity=dict(raw.get("severity", {})),
            exclude=tuple(raw.get("exclude", ())),
            collective_axes=tuple(raw.get("collective-axes",
                                          raw.get("collective_axes", ()))),
        )
        for name in (*cfg.enable, *cfg.disable, *cfg.severity):
            if name not in known:
                raise ValueError(f"[tool.graphlint] references unknown rule "
                                 f"{name!r} (known: {sorted(known)})")
        for name, sev in cfg.severity.items():
            if sev not in SEVERITIES:
                raise ValueError(f"[tool.graphlint] severity for {name!r} "
                                 f"must be one of {SEVERITIES}, got {sev!r}")
        return cfg

    @classmethod
    def load(cls, pyproject_path: Optional[str] = None) -> "Config":
        """Read ``[tool.graphlint]`` from *pyproject_path* (default: the
        repo's own ``pyproject.toml``); absent file/section -> defaults."""
        path = pyproject_path or os.path.join(REPO_ROOT, "pyproject.toml")
        if not os.path.exists(path):
            return cls()
        raw = _load_toml(path)
        return cls.from_dict(raw.get("tool", {}).get("graphlint", {}))

    def enabled_rules(self) -> Dict[str, Callable]:
        """The registry filtered by the enable/disable lists."""
        names = self.enable or tuple(RULES)
        return {n: RULES[n] for n in names if n not in self.disable}

    def severity_of(self, rule_name: str) -> str:
        """Config override, else the rule's registered default."""
        if rule_name in self.severity:
            return self.severity[rule_name]
        if rule_name in RULES:
            return RULES[rule_name].default_severity
        return "error"

    def is_excluded(self, rel_path: str) -> bool:
        """True when *rel_path* matches an exclude glob."""
        rel = rel_path.replace(os.sep, "/")
        return any(fnmatch.fnmatch(rel, pat) for pat in self.exclude)


def mesh_axis_names(mesh_py: Optional[str] = None) -> frozenset:
    """Axis names declared in ``src/repro/launch/mesh.py``.

    The collective-axis rule treats exactly these (plus any configured
    ``collective-axes`` additions) as legitimate ``axis_name`` string
    literals.  Extraction is syntactic — every string constant inside a
    tuple literal in ``mesh.py`` — so adding an axis to the mesh module
    automatically teaches the rule about it.
    """
    path = mesh_py or os.path.join(REPO_ROOT, "src", "repro", "launch",
                                   "mesh.py")
    if not os.path.exists(path):
        return frozenset()
    with open(path, encoding="utf-8") as f:
        tree = ast.parse(f.read())
    axes = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Tuple):
            for elt in node.elts:
                if isinstance(elt, ast.Constant) and isinstance(elt.value, str):
                    axes.add(elt.value)
    return frozenset(axes)


# ---------------------------------------------------------------------------
# suppression comments
# ---------------------------------------------------------------------------

_SUPPRESS_RE = re.compile(
    r"#\s*graphlint:\s*disable=(?P<rules>[A-Za-z0-9_\-]+(?:\s*,\s*[A-Za-z0-9_\-]+)*)"
    r"(?P<rest>.*)$")
_JUSTIFY_RE = re.compile(r"^\s*(?:--|#)\s*(?P<why>\S.*)$")


def parse_suppressions(lines: List[str]):
    """Scan *lines* for suppression comments.

    Returns ``(suppressed, problems)`` where *suppressed* maps a 1-based
    line number to the set of rule ids silenced **on that line** (an
    own-line comment silences the next line), and *problems* is a list
    of ``(lineno, message)`` for malformed suppressions: a missing
    justification or an unknown rule id.  Problems surface as
    ``bad-suppression`` findings, which are never suppressible.
    """
    suppressed: Dict[int, set] = {}
    problems: List[Tuple[int, str]] = []
    for idx, line in enumerate(lines, start=1):
        m = _SUPPRESS_RE.search(line)
        if not m:
            if re.search(r"#\s*graphlint:", line):
                problems.append(
                    (idx, "unparseable graphlint comment; expected "
                          "'# graphlint: disable=<rule>[,rule]  # justification'"))
            continue
        names = {n.strip() for n in m.group("rules").split(",")}
        unknown = sorted(n for n in names if n not in RULES)
        if unknown:
            problems.append(
                (idx, f"suppression names unknown rule(s) {unknown}; "
                      f"known rules: {sorted(RULES)}"))
            continue
        just = _JUSTIFY_RE.match(m.group("rest"))
        if not just:
            problems.append(
                (idx, "suppression lacks a justification; write "
                      "'# graphlint: disable=<rule>  # why it is safe'"))
            continue
        target = idx
        before = line[:m.start()].strip()
        if not before:           # comment-only line silences the next line
            target = idx + 1
        suppressed.setdefault(target, set()).update(names)
    return suppressed, problems


# ---------------------------------------------------------------------------
# runner
# ---------------------------------------------------------------------------

def lint_source(path: str, source: str, config: Optional[Config] = None,
                mesh_axes: Optional[frozenset] = None) -> List[Finding]:
    """Lint one file's *source*; *path* is used for reporting only."""
    config = config if config is not None else Config()
    axes = mesh_axes if mesh_axes is not None else mesh_axis_names()
    axes = frozenset(axes) | frozenset(config.collective_axes)
    lines = source.splitlines()
    ctx = FileContext(path=path, source=source, lines=lines,
                      config=config, mesh_axes=axes)
    findings: List[Finding] = []

    suppressed, problems = parse_suppressions(lines)
    for lineno, message in problems:
        findings.append(Finding(path=path, line=lineno,
                                rule="bad-suppression", severity="error",
                                message=message))
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as exc:
        findings.append(Finding(
            path=path, line=exc.lineno or 1, rule="parse-error",
            severity="error", message=f"file does not parse: {exc.msg}"))
        return findings

    for name, fn in config.enabled_rules().items():
        sev = config.severity_of(name)
        for lineno, message in fn(tree, ctx):
            if name in suppressed.get(lineno, ()):
                continue
            findings.append(Finding(path=path, line=lineno, rule=name,
                                    severity=sev, message=message))
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return findings


def iter_python_files(paths: Iterable[str], config: Config,
                      root: Optional[str] = None):
    """Yield ``(abs_path, rel_path)`` for every lintable ``.py`` file."""
    root = root or REPO_ROOT
    for p in paths:
        absolute = p if os.path.isabs(p) else os.path.join(root, p)
        if os.path.isfile(absolute):
            rel = os.path.relpath(absolute, root).replace(os.sep, "/")
            if not config.is_excluded(rel):
                yield absolute, rel
            continue
        for dirpath, dirnames, filenames in os.walk(absolute):
            dirnames[:] = [d for d in sorted(dirnames)
                           if d not in ("__pycache__", ".git")]
            for fname in sorted(filenames):
                if not fname.endswith(".py"):
                    continue
                full = os.path.join(dirpath, fname)
                rel = os.path.relpath(full, root).replace(os.sep, "/")
                if not config.is_excluded(rel):
                    yield full, rel


def lint_paths(paths: Iterable[str], config: Optional[Config] = None,
               root: Optional[str] = None) -> List[Finding]:
    """Lint every Python file under *paths* (files or directories)."""
    config = config if config is not None else Config.load()
    axes = mesh_axis_names() | frozenset(config.collective_axes)
    findings: List[Finding] = []
    for absolute, rel in iter_python_files(paths, config, root=root):
        with open(absolute, encoding="utf-8") as f:
            source = f.read()
        findings.extend(lint_source(rel, source, config, mesh_axes=axes))
    return findings
