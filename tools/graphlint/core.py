"""graphlint framework: registry, config, suppressions, and the runner.

Since v2 a lint run has two phases:

* **phase 1 — index.**  Every file is read and parsed exactly once into
  a :class:`FileEntry` (AST + suppression table); the entries roll up
  into a :class:`~tools.graphlint.analysis.symbols.ProjectIndex`
  (module symbol tables, call graph, per-function CFG/def-use caches —
  see ``tools/graphlint/analysis/``).
* **phase 2 — rules.**  Per-file syntactic rules (registered with
  :func:`rule`) run against each entry's shared AST; project-wide
  dataflow rules (registered with :func:`project_rule`) run once
  against the index and may report findings in any file.

The moving parts, in the order a lint run uses them:

* :func:`rule` — decorator registering a per-file rule.  A rule takes
  ``(tree, ctx)`` — the parsed :class:`ast.Module` and a
  :class:`FileContext` — and yields ``(lineno, message)`` pairs.
* :func:`project_rule` — decorator registering a project rule.  It
  takes the :class:`ProjectIndex` and yields ``(path, lineno,
  message)`` triples.
* :class:`Config` — the ``[tool.graphlint]`` block of ``pyproject.toml``
  (enable/disable lists, per-rule severity, exclude globs, extra
  collective axis names).  Loads via :mod:`tomllib` on 3.11+, falling
  back to a minimal TOML-subset parser so the 3.10 container needs no
  new dependency.
* suppression comments — ``# graphlint: disable=<rule>[,rule]`` on (or
  on the line above) the flagged line.  A suppression **must** carry a
  trailing justification (``-- why`` or ``# why``); a bare or malformed
  suppression is itself reported as ``bad-suppression`` and cannot be
  suppressed.  Project-rule findings obey the same per-file table.
* :func:`lint_source` / :func:`lint_paths` — run the enabled rules and
  return :class:`Finding` objects with config-resolved severities.
  ``lint_paths`` accepts ``stats=`` (per-rule wall time, the
  ``--stats`` surface) and ``report_only=`` (the ``--changed-only``
  filter: the index still spans every file so cross-file analyses stay
  sound, but only findings in the changed set are reported).
"""
from __future__ import annotations

import ast
import dataclasses
import fnmatch
import io
import os
import re
import subprocess
import time
import tokenize
from typing import Callable, Dict, Iterable, List, Optional, Set, Tuple

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))))

SEVERITIES = ("error", "warning")

#: findings the runner itself emits; not suppressible, always errors
META_CHECKS = ("bad-suppression", "parse-error")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at a specific line of a specific file."""

    path: str       #: repo-relative posix path
    line: int       #: 1-based line number
    rule: str       #: rule id (kebab-case)
    severity: str   #: "error" | "warning"
    message: str    #: human-readable explanation

    def as_dict(self) -> dict:
        """The shared ``tools._report`` finding-dict shape."""
        return {"path": self.path, "line": self.line, "check": self.rule,
                "severity": self.severity, "message": self.message}


@dataclasses.dataclass
class FileContext:
    """Per-file inputs a rule may consult beyond the AST."""

    path: str                 #: repo-relative posix path
    source: str               #: full file text
    lines: List[str]          #: source split into lines
    config: "Config"          #: resolved run configuration
    mesh_axes: frozenset      #: axis names rules treat as legitimate


@dataclasses.dataclass
class FileEntry:
    """The single-parse cache record for one file (phase 1).

    Every rule — and the project index — consumes this one parse;
    ``tree`` is None when the file does not parse (the runner then
    emits ``parse-error`` and the file is skipped by the index)."""

    path: str
    source: str
    lines: List[str]
    tree: Optional[ast.Module]
    parse_error: Optional[SyntaxError]
    suppressed: Dict[int, set]            #: lineno -> silenced rule ids
    problems: List[Tuple[int, str]]       #: malformed suppressions


def build_entry(path: str, source: str) -> FileEntry:
    """Parse *source* once into a :class:`FileEntry`."""
    lines = source.splitlines()
    suppressed, problems = parse_suppressions(lines)
    tree, err = None, None
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as exc:
        err = exc
    return FileEntry(path=path, source=source, lines=lines, tree=tree,
                     parse_error=err, suppressed=suppressed,
                     problems=problems)


#: rule-id -> per-file rule function; populated by :func:`rule`
RULES: Dict[str, Callable] = {}

#: rule-id -> project-wide rule function; populated by :func:`project_rule`
PROJECT_RULES: Dict[str, Callable] = {}


def all_rules() -> Dict[str, Callable]:
    """Both registries merged (rule ids are unique across them)."""
    return {**RULES, **PROJECT_RULES}


def _register(registry: Dict[str, Callable], name: str,
              default_severity: str):
    if default_severity not in SEVERITIES:
        raise ValueError(f"bad severity {default_severity!r}")
    if name in RULES or name in PROJECT_RULES:
        raise ValueError(f"duplicate rule id {name!r}")

    def deco(fn):
        fn.rule_name = name
        fn.default_severity = default_severity
        registry[name] = fn
        return fn

    return deco


def rule(name: str, default_severity: str = "error"):
    """Register a per-file rule under *name* with a default severity.

    The decorated function must accept ``(tree, ctx)`` and yield
    ``(lineno, message)`` tuples; its docstring becomes the catalog
    entry shown by ``--list-rules``.
    """
    return _register(RULES, name, default_severity)


def project_rule(name: str, default_severity: str = "error"):
    """Register a project-wide dataflow rule under *name*.

    The decorated function must accept the
    :class:`~tools.graphlint.analysis.symbols.ProjectIndex` and yield
    ``(path, lineno, message)`` triples — it sees every file at once,
    which is what lets it check relationships *between* functions
    (handle lifecycles, closure captures, carry structures).
    """
    return _register(PROJECT_RULES, name, default_severity)


# ---------------------------------------------------------------------------
# configuration ([tool.graphlint] in pyproject.toml)
# ---------------------------------------------------------------------------

def _parse_toml_minimal(text: str) -> dict:
    """Parse the TOML subset graphlint's config needs (3.10 fallback).

    Supports ``[dotted.section]`` headers, ``key = "string"``,
    ``key = ["a", "b"]`` single-line string lists, integers, booleans,
    and ``#`` comments.  Anything fancier raises ``ValueError`` so a
    silently-misread config cannot weaken the gate.
    """
    root: dict = {}
    table = root
    pending = ""
    for raw in text.splitlines():
        line = _strip_toml_comment(raw).strip()
        if not line:
            continue
        if pending:
            pending += " " + line
            if pending.count("[") > pending.count("]"):
                continue
            line, pending = pending, ""
        elif (line.startswith("[") and line.endswith("]")
                and "=" not in line):
            table = root
            for part in line[1:-1].strip().split("."):
                part = part.strip().strip('"')
                table = table.setdefault(part, {})
            continue
        if "=" not in line:
            raise ValueError(f"unparseable TOML line: {raw!r}")
        key, _, value = line.partition("=")
        value = value.strip()
        if value.count("[") > value.count("]"):  # multi-line array
            pending = line
            continue
        table[key.strip().strip('"')] = _parse_toml_value(value)
    return root


def _strip_toml_comment(line: str) -> str:
    out, in_str = [], False
    for ch in line:
        if ch == '"':
            in_str = not in_str
        if ch == "#" and not in_str:
            break
        out.append(ch)
    return "".join(out)


def _parse_toml_value(value: str):
    if value.startswith("[") and value.endswith("]"):
        inner = value[1:-1].strip()
        if not inner:
            return []
        return [_parse_toml_value(v.strip())
                for v in inner.split(",") if v.strip()]
    if value.startswith('"') and value.endswith('"') and len(value) >= 2:
        return value[1:-1]
    if value in ("true", "false"):
        return value == "true"
    try:
        return int(value)
    except ValueError:
        raise ValueError(f"unparseable TOML value: {value!r}")


def _load_toml(path: str) -> dict:
    """``tomllib`` when available (3.11+), else the minimal parser."""
    with open(path, "rb") as f:
        data = f.read()
    try:
        import tomllib
    except ModuleNotFoundError:
        return _parse_toml_minimal(data.decode("utf-8"))
    return tomllib.loads(data.decode("utf-8"))


@dataclasses.dataclass
class Config:
    """Resolved ``[tool.graphlint]`` settings for one lint run."""

    enable: Tuple[str, ...] = ()        #: if non-empty, ONLY these rules run
    disable: Tuple[str, ...] = ()       #: rules switched off
    severity: Dict[str, str] = dataclasses.field(default_factory=dict)
    exclude: Tuple[str, ...] = ()       #: repo-relative glob patterns
    collective_axes: Tuple[str, ...] = ()  #: extra allowed axis names

    @classmethod
    def from_dict(cls, raw: dict) -> "Config":
        """Build a Config from a ``[tool.graphlint]`` mapping, validating
        rule ids and severity values so typos fail loudly."""
        known = set(RULES) | set(PROJECT_RULES)
        cfg = cls(
            enable=tuple(raw.get("enable", ())),
            disable=tuple(raw.get("disable", ())),
            severity=dict(raw.get("severity", {})),
            exclude=tuple(raw.get("exclude", ())),
            collective_axes=tuple(raw.get("collective-axes",
                                          raw.get("collective_axes", ()))),
        )
        for name in (*cfg.enable, *cfg.disable, *cfg.severity):
            if name not in known:
                raise ValueError(f"[tool.graphlint] references unknown rule "
                                 f"{name!r} (known: {sorted(known)})")
        for name, sev in cfg.severity.items():
            if sev not in SEVERITIES:
                raise ValueError(f"[tool.graphlint] severity for {name!r} "
                                 f"must be one of {SEVERITIES}, got {sev!r}")
        return cfg

    @classmethod
    def load(cls, pyproject_path: Optional[str] = None) -> "Config":
        """Read ``[tool.graphlint]`` from *pyproject_path* (default: the
        repo's own ``pyproject.toml``); absent file/section -> defaults."""
        path = pyproject_path or os.path.join(REPO_ROOT, "pyproject.toml")
        if not os.path.exists(path):
            return cls()
        raw = _load_toml(path)
        return cls.from_dict(raw.get("tool", {}).get("graphlint", {}))

    def enabled_rules(self) -> Dict[str, Callable]:
        """The per-file registry filtered by the enable/disable lists."""
        names = self.enable or tuple(RULES)
        return {n: RULES[n] for n in names
                if n in RULES and n not in self.disable}

    def enabled_project_rules(self) -> Dict[str, Callable]:
        """The project registry filtered by the enable/disable lists."""
        names = self.enable or tuple(PROJECT_RULES)
        return {n: PROJECT_RULES[n] for n in names
                if n in PROJECT_RULES and n not in self.disable}

    def severity_of(self, rule_name: str) -> str:
        """Config override, else the rule's registered default."""
        if rule_name in self.severity:
            return self.severity[rule_name]
        fn = all_rules().get(rule_name)
        if fn is not None:
            return fn.default_severity
        return "error"

    def is_excluded(self, rel_path: str) -> bool:
        """True when *rel_path* matches an exclude glob."""
        rel = rel_path.replace(os.sep, "/")
        return any(fnmatch.fnmatch(rel, pat) for pat in self.exclude)


def mesh_axis_names(mesh_py: Optional[str] = None) -> frozenset:
    """Axis names declared in ``src/repro/launch/mesh.py``.

    The collective-axis rule treats exactly these (plus any configured
    ``collective-axes`` additions) as legitimate ``axis_name`` string
    literals.  Extraction is syntactic — every string constant inside a
    tuple literal in ``mesh.py`` — so adding an axis to the mesh module
    automatically teaches the rule about it.
    """
    path = mesh_py or os.path.join(REPO_ROOT, "src", "repro", "launch",
                                   "mesh.py")
    if not os.path.exists(path):
        return frozenset()
    with open(path, encoding="utf-8") as f:
        tree = ast.parse(f.read())
    axes = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Tuple):
            for elt in node.elts:
                if isinstance(elt, ast.Constant) and isinstance(elt.value, str):
                    axes.add(elt.value)
    return frozenset(axes)


# ---------------------------------------------------------------------------
# suppression comments
# ---------------------------------------------------------------------------

_SUPPRESS_RE = re.compile(
    r"#\s*graphlint:\s*disable=(?P<rules>[A-Za-z0-9_\-]+(?:\s*,\s*[A-Za-z0-9_\-]+)*)"
    r"(?P<rest>.*)$")
_JUSTIFY_RE = re.compile(r"^\s*(?:--|#)\s*(?P<why>\S.*)$")


def _comment_tokens(lines: List[str]):
    """Yield ``(lineno, col, text)`` for every real COMMENT token.

    Tokenizing (rather than regex-scanning raw lines) is what keeps a
    ``# graphlint:`` mention inside a string literal — a docstring, an
    error message, a lint-test fixture — from being parsed as a live
    suppression.  Sources that do not tokenize fall back to a naive
    first-``#`` scan so a broken file still gets its suppressions (and
    its malformed-suppression findings) reported."""
    src = "\n".join(lines) + ("\n" if lines else "")
    try:
        toks = list(tokenize.generate_tokens(io.StringIO(src).readline))
    except (tokenize.TokenError, IndentationError, SyntaxError):
        for idx, line in enumerate(lines, start=1):
            pos = line.find("#")
            if pos >= 0:
                yield idx, pos, line[pos:]
        return
    for tok in toks:
        if tok.type == tokenize.COMMENT:
            yield tok.start[0], tok.start[1], tok.string


def parse_suppressions(lines: List[str]):
    """Scan *lines* for suppression comments.

    Returns ``(suppressed, problems)`` where *suppressed* maps a 1-based
    line number to the set of rule ids silenced **on that line** (an
    own-line comment silences the next line), and *problems* is a list
    of ``(lineno, message)`` for malformed suppressions: a missing
    justification or an unknown rule id.  Problems surface as
    ``bad-suppression`` findings, which are never suppressible.
    """
    known = set(RULES) | set(PROJECT_RULES)
    suppressed: Dict[int, set] = {}
    problems: List[Tuple[int, str]] = []
    for idx, col, comment in _comment_tokens(lines):
        m = _SUPPRESS_RE.search(comment)
        if not m:
            if re.search(r"#\s*graphlint:", comment):
                problems.append(
                    (idx, "unparseable graphlint comment; expected "
                          "'# graphlint: disable=<rule>[,rule]  # justification'"))
            continue
        names = {n.strip() for n in m.group("rules").split(",")}
        unknown = sorted(n for n in names if n not in known)
        if unknown:
            problems.append(
                (idx, f"suppression names unknown rule(s) {unknown}; "
                      f"known rules: {sorted(known)}"))
            continue
        just = _JUSTIFY_RE.match(m.group("rest"))
        if not just:
            problems.append(
                (idx, "suppression lacks a justification; write "
                      "'# graphlint: disable=<rule>  # why it is safe'"))
            continue
        target = idx
        before = lines[idx - 1][:col].strip() if idx <= len(lines) else ""
        if not before:           # comment-only line silences the next line
            target = idx + 1
        suppressed.setdefault(target, set()).update(names)
    return suppressed, problems


# ---------------------------------------------------------------------------
# runner
# ---------------------------------------------------------------------------

class RunStats:
    """Per-rule wall-time and finding counters (the ``--stats`` table)."""

    def __init__(self):
        self.seconds: Dict[str, float] = {}
        self.findings: Dict[str, int] = {}
        self.n_files = 0
        self.parse_seconds = 0.0
        self.index_seconds = 0.0

    def add(self, rule_name: str, dt: float, n: int) -> None:
        """Accumulate one timed rule invocation."""
        self.seconds[rule_name] = self.seconds.get(rule_name, 0.0) + dt
        self.findings[rule_name] = self.findings.get(rule_name, 0) + n

    def table(self) -> str:
        """Human-readable per-rule timing table, slowest first."""
        rows = [f"{'rule':<32} {'seconds':>8} {'findings':>9}",
                f"{'parse+suppressions':<32} {self.parse_seconds:>8.3f} "
                f"{'-':>9}",
                f"{'project index':<32} {self.index_seconds:>8.3f} {'-':>9}"]
        for name in sorted(self.seconds, key=self.seconds.get,
                           reverse=True):
            rows.append(f"{name:<32} {self.seconds[name]:>8.3f} "
                        f"{self.findings[name]:>9d}")
        total = (sum(self.seconds.values()) + self.parse_seconds
                 + self.index_seconds)
        rows.append(f"{'TOTAL (' + str(self.n_files) + ' files)':<32} "
                    f"{total:>8.3f} {sum(self.findings.values()):>9d}")
        return "\n".join(rows)


def lint_entries(entries: List[FileEntry], config: Optional[Config] = None,
                 mesh_axes: Optional[frozenset] = None,
                 stats: Optional[RunStats] = None,
                 report_only: Optional[Set[str]] = None) -> List[Finding]:
    """Run both rule phases over pre-parsed *entries*.

    This is THE runner: ``lint_source`` and ``lint_paths`` are wrappers
    that build the entry list.  ``report_only`` (a set of repo-relative
    paths) filters which files may *report* findings; the project index
    always spans every entry so cross-file dataflow stays sound.
    """
    from .analysis import ProjectIndex

    config = config if config is not None else Config()
    axes = mesh_axes if mesh_axes is not None else mesh_axis_names()
    axes = frozenset(axes) | frozenset(config.collective_axes)
    stats = stats if stats is not None else RunStats()
    stats.n_files += len(entries)

    findings: List[Finding] = []
    reportable = (lambda p: True) if report_only is None else (
        lambda p: p in report_only)

    file_rules = config.enabled_rules()
    for entry in entries:
        if not reportable(entry.path):
            continue
        for lineno, message in entry.problems:
            findings.append(Finding(path=entry.path, line=lineno,
                                    rule="bad-suppression",
                                    severity="error", message=message))
        if entry.tree is None:
            exc = entry.parse_error
            findings.append(Finding(
                path=entry.path, line=(exc.lineno or 1) if exc else 1,
                rule="parse-error", severity="error",
                message=f"file does not parse: "
                        f"{exc.msg if exc else 'unknown error'}"))
            continue
        ctx = FileContext(path=entry.path, source=entry.source,
                          lines=entry.lines, config=config, mesh_axes=axes)
        for name, fn in file_rules.items():
            sev = config.severity_of(name)
            t0 = time.perf_counter()
            hits = [(lineno, message) for lineno, message
                    in fn(entry.tree, ctx)
                    if name not in entry.suppressed.get(lineno, ())]
            stats.add(name, time.perf_counter() - t0, len(hits))
            findings.extend(
                Finding(path=entry.path, line=lineno, rule=name,
                        severity=sev, message=message)
                for lineno, message in hits)

    project_rules = config.enabled_project_rules()
    if project_rules:
        t0 = time.perf_counter()
        index = ProjectIndex({e.path: e for e in entries})
        stats.index_seconds += time.perf_counter() - t0
        by_path = {e.path: e for e in entries}
        for name, fn in project_rules.items():
            sev = config.severity_of(name)
            t0 = time.perf_counter()
            hits = []
            for path, lineno, message in fn(index):
                entry = by_path.get(path)
                if entry is None or not reportable(path):
                    continue
                if name in entry.suppressed.get(lineno, ()):
                    continue
                hits.append((path, lineno, message))
            stats.add(name, time.perf_counter() - t0, len(hits))
            findings.extend(
                Finding(path=path, line=lineno, rule=name, severity=sev,
                        message=message)
                for path, lineno, message in hits)

    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return findings


def lint_source(path: str, source: str, config: Optional[Config] = None,
                mesh_axes: Optional[frozenset] = None) -> List[Finding]:
    """Lint one file's *source*; *path* is used for reporting only.

    Project rules run against a single-file index, which is exactly
    what the fixture tests want: an interprocedural hazard expressed in
    one file still fires."""
    return lint_entries([build_entry(path, source)], config,
                        mesh_axes=mesh_axes)


def iter_python_files(paths: Iterable[str], config: Config,
                      root: Optional[str] = None):
    """Yield ``(abs_path, rel_path)`` for every lintable ``.py`` file."""
    root = root or REPO_ROOT
    for p in paths:
        absolute = p if os.path.isabs(p) else os.path.join(root, p)
        if os.path.isfile(absolute):
            rel = os.path.relpath(absolute, root).replace(os.sep, "/")
            if not config.is_excluded(rel):
                yield absolute, rel
            continue
        for dirpath, dirnames, filenames in os.walk(absolute):
            dirnames[:] = [d for d in sorted(dirnames)
                           if d not in ("__pycache__", ".git")]
            for fname in sorted(filenames):
                if not fname.endswith(".py"):
                    continue
                full = os.path.join(dirpath, fname)
                rel = os.path.relpath(full, root).replace(os.sep, "/")
                if not config.is_excluded(rel):
                    yield full, rel


def changed_files(base: str = "origin/main",
                  root: Optional[str] = None) -> Optional[Set[str]]:
    """Repo-relative paths touched vs ``git merge-base HEAD <base>``.

    The set covers committed, staged, unstaged, AND untracked changes —
    everything a pre-commit run wants linted.  Returns None when the
    base ref does not exist (fresh clone without the remote): callers
    fall back to a full lint rather than silently linting nothing."""
    root = root or REPO_ROOT

    def _git(*args) -> Optional[str]:
        try:
            proc = subprocess.run(["git", *args], cwd=root,
                                  capture_output=True, text=True,
                                  timeout=30)
        except (OSError, subprocess.TimeoutExpired):
            return None
        return proc.stdout if proc.returncode == 0 else None

    merge_base = _git("merge-base", "HEAD", base)
    if merge_base is None:
        return None
    changed: Set[str] = set()
    diff = _git("diff", "--name-only", merge_base.strip())
    if diff is None:
        return None
    changed.update(line for line in diff.splitlines() if line)
    untracked = _git("ls-files", "--others", "--exclude-standard")
    if untracked:
        changed.update(line for line in untracked.splitlines() if line)
    return changed


def lint_paths(paths: Iterable[str], config: Optional[Config] = None,
               root: Optional[str] = None,
               stats: Optional[RunStats] = None,
               report_only: Optional[Set[str]] = None) -> List[Finding]:
    """Lint every Python file under *paths* (files or directories).

    Each file is read and parsed exactly once (phase 1); every rule —
    per-file and project-wide — consumes the shared entry."""
    config = config if config is not None else Config.load()
    axes = mesh_axis_names() | frozenset(config.collective_axes)
    stats = stats if stats is not None else RunStats()
    t0 = time.perf_counter()
    entries: List[FileEntry] = []
    for absolute, rel in iter_python_files(paths, config, root=root):
        with open(absolute, encoding="utf-8") as f:
            source = f.read()
        entries.append(build_entry(rel, source))
    stats.parse_seconds += time.perf_counter() - t0
    return lint_entries(entries, config, mesh_axes=axes, stats=stats,
                        report_only=report_only)
