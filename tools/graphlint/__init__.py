"""graphlint: repo-native static analysis for JAX/Pallas hazard classes.

Every rule encodes a bug class this repo has already paid for once (see
``docs/LINTING.md`` for the catalog and the motivating incidents).  The
framework is stdlib-``ast`` only — zero new dependencies — and runs as

    python -m tools.graphlint src/ benchmarks/ examples/

Rules register themselves in :mod:`tools.graphlint.core`; importing
:mod:`tools.graphlint.rules` populates the registry.
"""
from .core import (  # noqa: F401
    Config,
    Finding,
    PROJECT_RULES,
    RULES,
    lint_paths,
    lint_source,
    project_rule,
    rule,
)
from . import rules  # noqa: F401  (imports register the rule set)
