"""closure-capture: traced functions must not close over mutable host state.

``jax.jit``/``shard_map``/``pallas_call`` bake closure captures at
*trace* time: a captured Python list, dict, or numpy array is read once
during tracing and the compiled executable never sees later mutations —
the classic "I appended to the schedule but the jitted step kept the old
one" bug.  Worse, mutating captured state *inside* a traced function is
a silent trace-time side effect that runs once, not per step.

The rule uses the project call graph to find every function that flows
into a trace sink (decorated, ``jit(f)`` by name, through
``functools.partial``, or returned from a ``make_*_fn`` factory — and
transitively, helpers called from traced code).  For each, it resolves
free names through the enclosing scopes to their binding and flags the
capture when the binding is recognizably mutable (list/dict/set
literal or constructor, host ``np.*`` array) AND some statement in the
binding's scope actually mutates it.  Reads of ``self.X`` inside a
traced method are flagged when ``self.X`` is reassigned outside
``__init__`` — attribute state on a traced method is re-read only on
retrace.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from ..astutil import call_tail
from ..core import project_rule

#: builtin/collections constructors that produce mutable containers
_MUTABLE_CALLS = frozenset({"list", "dict", "set", "bytearray",
                            "defaultdict", "deque", "OrderedDict",
                            "Counter"})
#: numpy array constructors (host-mutable buffers)
_NP_CTORS = frozenset({"zeros", "ones", "empty", "full", "array",
                       "arange", "zeros_like", "ones_like", "empty_like"})
#: container methods that mutate the receiver in place
_MUTATORS = frozenset({"append", "extend", "insert", "pop", "remove",
                       "clear", "update", "setdefault", "add", "popitem",
                       "appendleft", "extendleft", "fill", "sort",
                       "reverse", "discard"})


def _is_numpy_alias(name: str, imports: Dict[str, str]) -> bool:
    return imports.get(name) == "numpy"


def _mutable_binding_kind(value: ast.expr,
                          imports: Dict[str, str]) -> Optional[str]:
    """A short description when *value* builds a mutable object, else None."""
    if isinstance(value, (ast.List, ast.ListComp)):
        return "list"
    if isinstance(value, (ast.Dict, ast.DictComp)):
        return "dict"
    if isinstance(value, (ast.Set, ast.SetComp)):
        return "set"
    if isinstance(value, ast.Call):
        tail = call_tail(value.func)
        if isinstance(value.func, ast.Name) and tail in _MUTABLE_CALLS:
            return tail
        if (isinstance(value.func, ast.Attribute)
                and isinstance(value.func.value, ast.Name)
                and _is_numpy_alias(value.func.value.id, imports)
                and value.func.attr in _NP_CTORS):
            return f"np.{value.func.attr} array"
    return None


def _scope_bindings(body: List[ast.stmt]) -> Dict[str, ast.expr]:
    """name -> last ``name = expr`` at any nesting of *body*, without
    entering nested function/class scopes."""
    out: Dict[str, ast.expr] = {}
    stack = list(body)
    while stack:
        stmt = stack.pop(0)
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            continue
        if (isinstance(stmt, ast.Assign) and len(stmt.targets) == 1
                and isinstance(stmt.targets[0], ast.Name)):
            out[stmt.targets[0].id] = stmt.value
        for field in ("body", "orelse", "finalbody"):
            stack.extend(getattr(stmt, field, []) or [])
        for handler in getattr(stmt, "handlers", []) or []:
            stack.extend(handler.body)
    return out


def _bound_in_function(fn: ast.AST) -> Set[str]:
    """Names the function scope binds: params, assignments, nested defs."""
    bound: Set[str] = set()
    a = fn.args
    for p in (*a.posonlyargs, *a.args, *a.kwonlyargs):
        bound.add(p.arg)
    if a.vararg:
        bound.add(a.vararg.arg)
    if a.kwarg:
        bound.add(a.kwarg.arg)
    stack = list(fn.body)
    while stack:
        node = stack.pop()
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            bound.add(node.name)
            continue                       # nested scope binds elsewhere
        if isinstance(node, ast.Name) and isinstance(node.ctx,
                                                     (ast.Store, ast.Del)):
            bound.add(node.id)
        if isinstance(node, ast.Global) or isinstance(node, ast.Nonlocal):
            bound.update(node.names)       # treated as bound: skip flagging
        stack.extend(ast.iter_child_nodes(node))
    return bound


def _free_reads(fn: ast.AST, bound: Set[str]) -> Dict[str, int]:
    """free name -> first read lineno inside *fn* (nested defs included:
    their captures are baked through the same trace)."""
    out: Dict[str, int] = {}
    for node in ast.walk(fn):
        if (isinstance(node, ast.Name) and isinstance(node.ctx, ast.Load)
                and node.id not in bound):
            out.setdefault(node.id, node.lineno)
    return out


def _mutations_of(scope_node: ast.AST, name: str,
                  binding_value: ast.expr) -> Optional[int]:
    """Lineno of a statement mutating *name* in *scope_node*'s subtree
    (rebinding via plain ``=`` is not a mutation), else None."""
    for node in ast.walk(scope_node):
        if (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and isinstance(node.func.value, ast.Name)
                and node.func.value.id == name
                and node.func.attr in _MUTATORS):
            return node.lineno
        if isinstance(node, (ast.Assign, ast.AugAssign)):
            targets = (node.targets if isinstance(node, ast.Assign)
                       else [node.target])
            for tgt in targets:
                if (isinstance(tgt, (ast.Subscript, ast.Attribute))
                        and isinstance(tgt.value, ast.Name)
                        and tgt.value.id == name):
                    return node.lineno
                if (isinstance(node, ast.AugAssign)
                        and isinstance(tgt, ast.Name) and tgt.id == name):
                    return node.lineno
        if isinstance(node, ast.Delete):
            for tgt in node.targets:
                if (isinstance(tgt, ast.Subscript)
                        and isinstance(tgt.value, ast.Name)
                        and tgt.value.id == name):
                    return node.lineno
    return None


def _resolve_capture(index, module, fi,
                     name: str) -> Optional[Tuple[ast.expr, ast.AST]]:
    """``(binding_value, defining_scope_node)`` for free *name* seen from
    *fi*: enclosing functions outward, then module globals."""
    scope = fi.parent
    while scope is not None:
        a = scope.node.args
        params = {p.arg for p in (*a.posonlyargs, *a.args, *a.kwonlyargs)}
        if a.vararg:
            params.add(a.vararg.arg)
        if a.kwarg:
            params.add(a.kwarg.arg)
        if name in params:
            return None                   # parameter: provenance unknown
        value = _scope_bindings(scope.node.body).get(name)
        if value is not None:
            return value, scope.node
        scope = scope.parent
    value = _scope_bindings(module.tree.body).get(name)
    if value is not None:
        return value, module.tree
    return None


def _self_attr_stores(cls_node: ast.ClassDef) -> Tuple[Set[str], Dict[str, int]]:
    """(attrs assigned in __init__, attrs assigned elsewhere -> lineno)."""
    init_attrs: Set[str] = set()
    other: Dict[str, int] = {}
    for item in cls_node.body:
        if not isinstance(item, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        for node in ast.walk(item):
            if (isinstance(node, ast.Attribute)
                    and isinstance(node.ctx, ast.Store)
                    and isinstance(node.value, ast.Name)
                    and node.value.id == "self"):
                if item.name == "__init__":
                    init_attrs.add(node.attr)
                else:
                    other.setdefault(node.attr, node.lineno)
    return init_attrs, other


@project_rule("closure-capture")
def closure_capture(index):
    """traced function closes over mutable host state that is mutated
    elsewhere; the compiled executable keeps the trace-time snapshot."""
    cg = index.callgraph
    for fi, how in sorted(cg.traced.items(),
                          key=lambda kv: (kv[0].path, kv[0].lineno)):
        module = index.modules[fi.path]
        bound = _bound_in_function(fi.node)
        for name, read_line in sorted(_free_reads(fi.node, bound).items()):
            resolved = _resolve_capture(index, module, fi, name)
            if resolved is None:
                continue
            value, scope_node = resolved
            kind = _mutable_binding_kind(value, module.imports)
            if kind is None:
                continue
            mut_line = _mutations_of(scope_node, name, value)
            if mut_line is None:
                continue
            yield (fi.path, read_line,
                   f"'{fi.name}' is traced (via {how}) but closes over "
                   f"mutable {kind} '{name}' (bound at line {value.lineno}, "
                   f"mutated at line {mut_line}); the trace bakes the "
                   f"capture — pass it as an argument or freeze it")

        # self.X reads in traced methods, where X is reassigned post-init
        if fi.cls is not None and fi.cls in module.classes:
            init_attrs, reassigned = _self_attr_stores(
                module.classes[fi.cls])
            del init_attrs  # reassignment outside __init__ is the hazard
            flagged: Set[str] = set()
            for node in ast.walk(fi.node):
                if (isinstance(node, ast.Attribute)
                        and isinstance(node.ctx, ast.Load)
                        and isinstance(node.value, ast.Name)
                        and node.value.id == "self"
                        and node.attr in reassigned
                        and node.attr not in flagged):
                    flagged.add(node.attr)
                    yield (fi.path, node.lineno,
                           f"traced method '{fi.cls}.{fi.name}' reads "
                           f"'self.{node.attr}', which is reassigned at "
                           f"line {reassigned[node.attr]}; traced code "
                           f"sees the trace-time value until a retrace")
