"""Rule modules; importing this package registers every rule with
:data:`tools.graphlint.core.RULES`.  One module per hazard class — see
``docs/LINTING.md`` for the catalog and the historical bug each rule
encodes.
"""
from . import (  # noqa: F401
    cacheconfig_required,
    collective_axis,
    discarded_update,
    host_transfer,
    pallas_blockspec,
    tracer_branch,
    unseeded_rng,
)
