"""Rule modules; importing this package registers every rule with
:data:`tools.graphlint.core.RULES` (per-file syntactic rules) or
:data:`tools.graphlint.core.PROJECT_RULES` (project-wide dataflow
rules over the phase-1 index).  One module per hazard class — see
``docs/LINTING.md`` for the catalog and the historical bug each rule
encodes.
"""
from . import (  # noqa: F401
    cacheconfig_required,
    carry_structure,
    closure_capture,
    collective_axis,
    discarded_update,
    handle_lifecycle,
    host_transfer,
    pallas_blockspec,
    tracer_branch,
    unseeded_rng,
)
