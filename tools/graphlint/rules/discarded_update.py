"""discarded-functional-update: a bare ``x.at[...].set(...)`` statement.

JAX arrays are immutable; ``x.at[i].set(v)`` *returns* the updated array
and leaves ``x`` untouched.  As an expression statement the update is a
silent no-op — the classic porting bug from the in-place NumPy idiom.
"""
from __future__ import annotations

import ast

from ..core import rule

#: the .at[...] update methods (jax.numpy ndarray.at documentation)
_UPDATE_METHODS = frozenset({
    "set", "add", "subtract", "multiply", "divide", "power",
    "min", "max", "apply", "get",
})


def _is_at_update(call: ast.Call) -> bool:
    func = call.func
    if not (isinstance(func, ast.Attribute) and func.attr in _UPDATE_METHODS):
        return False
    sub = func.value
    return (isinstance(sub, ast.Subscript)
            and isinstance(sub.value, ast.Attribute)
            and sub.value.attr == "at")


@rule("discarded-functional-update")
def check(tree, ctx):
    """Flag expression statements whose value is an ``.at[...].<op>(...)``
    call — the functional result is discarded, so the update never
    happens."""
    for node in ast.walk(tree):
        if (isinstance(node, ast.Expr) and isinstance(node.value, ast.Call)
                and _is_at_update(node.value)):
            yield (node.lineno,
                   "result of functional .at[...] update is discarded — "
                   "JAX arrays are immutable, so this statement is a no-op; "
                   "bind the result (x = x.at[i].set(v))")
