"""tracer-branch: Python control flow on traced values.

Inside a ``@jax.jit``/``shard_map``-wrapped function or a Pallas kernel,
the arguments are tracers (or refs): ``if x > 0:``, ``while n < k:``,
``int(x)`` and ``bool(x)`` force concretization — a
``ConcretizationTypeError`` at best, a silently traced-once constant
branch at worst.  Structured control flow (``jnp.where``, ``lax.cond``,
``lax.while_loop``, ``pl.when``) is the functional replacement.

Scope is deliberately conservative to stay false-positive-free:

* only functions that are *provably* traced are analyzed — decorated
  with ``jit``, passed by name to ``jax.jit(...)`` / ``shard_map(...)``,
  or used as a ``pl.pallas_call`` kernel (directly or via
  ``functools.partial``);
* only values derived from the function's parameters are tainted
  (``static_argnames``/``static_argnums`` params and, for kernels,
  keyword-only params — the static-configuration idiom — are exempt);
* shape/dtype introspection (``x.shape``, ``x.ndim``, ``len(x)``,
  ``isinstance``) and identity tests (``x is None``) are static under
  tracing and never flagged;
* nested function definitions are skipped (they are separate scopes,
  usually ``pl.when`` bodies or branch lambdas).
"""
from __future__ import annotations

import ast
from typing import List, Set, Tuple

from ..astutil import call_tail, function_defs, keyword_arg
from ..core import rule

#: attribute reads that are static under tracing (abstract-value metadata)
_STATIC_ATTRS = frozenset({
    "shape", "ndim", "dtype", "size", "aval", "sharding", "weak_type",
    "itemsize", "nbytes",
})

#: builtins whose result on a tracer is static (metadata, not the value)
_STATIC_CALLS = frozenset({
    "len", "isinstance", "issubclass", "type", "getattr", "hasattr",
    "callable", "repr",
})

_CAST_CALLS = frozenset({"int", "bool", "float"})

_SKIP_SCOPES = (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda,
                ast.ClassDef)


def _tainted_use(expr: ast.expr, tainted: Set[str]):
    """Line of the first non-static use of a tainted name in *expr*,
    else None.  Static contexts (shape/dtype reads, ``len``/``isinstance``
    calls, ``is``/``is not`` comparisons) are skipped subtree-wide."""
    def visit(node):
        if isinstance(node, ast.Attribute) and node.attr in _STATIC_ATTRS:
            return None
        if (isinstance(node, ast.Call) and isinstance(node.func, ast.Name)
                and node.func.id in _STATIC_CALLS):
            return None
        if (isinstance(node, ast.Compare)
                and all(isinstance(op, (ast.Is, ast.IsNot))
                        for op in node.ops)):
            return None
        if isinstance(node, _SKIP_SCOPES):
            return None
        if isinstance(node, ast.Name) and node.id in tainted:
            return node.lineno
        for child in ast.iter_child_nodes(node):
            hit = visit(child)
            if hit is not None:
                return hit
        return None

    return visit(expr)


def _static_spec(call: ast.Call) -> Tuple[Set[str], Set[int]]:
    """(static_argnames, static_argnums) declared on a jit call."""
    names: Set[str] = set()
    nums: Set[int] = set()
    val = keyword_arg(call, "static_argnames")
    if isinstance(val, ast.Constant) and isinstance(val.value, str):
        names.add(val.value)
    elif isinstance(val, (ast.Tuple, ast.List)):
        names.update(e.value for e in val.elts
                     if isinstance(e, ast.Constant)
                     and isinstance(e.value, str))
    val = keyword_arg(call, "static_argnums")
    if isinstance(val, ast.Constant) and isinstance(val.value, int):
        nums.add(val.value)
    elif isinstance(val, (ast.Tuple, ast.List)):
        nums.update(e.value for e in val.elts
                    if isinstance(e, ast.Constant)
                    and isinstance(e.value, int))
    return names, nums


def _is_jit_target(node: ast.expr) -> bool:
    return call_tail(node) == "jit"


def _jit_decorator(dec: ast.expr):
    """(static_argnames, static_argnums) when *dec* marks a jit'd
    function — ``@jax.jit``, ``@jit(...)``, ``@partial(jax.jit, ...)`` —
    else None."""
    if _is_jit_target(dec):
        return set(), set()
    if isinstance(dec, ast.Call):
        if _is_jit_target(dec.func):
            return _static_spec(dec)
        if (call_tail(dec.func) == "partial" and dec.args
                and _is_jit_target(dec.args[0])):
            return _static_spec(dec)
    return None


def _kernel_name(arg: ast.expr):
    """Kernel function name from a pallas_call first argument."""
    if isinstance(arg, ast.Name):
        return arg.id
    if (isinstance(arg, ast.Call) and call_tail(arg.func) == "partial"
            and arg.args and isinstance(arg.args[0], ast.Name)):
        return arg.args[0].id
    return None


def _collect_candidates(tree):
    """(fn_node, static_names, static_nums, is_kernel, how) tuples for
    every function the rule can prove is traced."""
    by_name = {}
    for fn in function_defs(tree):
        by_name.setdefault(fn.name, []).append(fn)
    out = []
    for fn in function_defs(tree):
        for dec in fn.decorator_list:
            spec = _jit_decorator(dec)
            if spec is not None:
                out.append((fn, spec[0], spec[1], False, "jit"))
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        tail = call_tail(node.func)
        if tail == "jit" and node.args and isinstance(node.args[0], ast.Name):
            names, nums = _static_spec(node)
            for fn in by_name.get(node.args[0].id, ()):
                out.append((fn, names, nums, False, "jit"))
        elif (tail == "shard_map" and node.args
                and isinstance(node.args[0], ast.Name)):
            for fn in by_name.get(node.args[0].id, ()):
                out.append((fn, set(), set(), False, "shard_map"))
        elif tail == "pallas_call" and node.args:
            kname = _kernel_name(node.args[0])
            if kname:
                for fn in by_name.get(kname, ()):
                    out.append((fn, set(), set(), True, "pallas_call"))
    return out


def _analyze(fn, static_names, static_nums, is_kernel, how,
             findings: List[Tuple[int, str]]):
    params = [a.arg for a in (*fn.args.posonlyargs, *fn.args.args)]
    if not is_kernel:
        # keyword-only params of kernels are the static-config idiom
        # (closed over by functools.partial); positional ones are refs
        params += [a.arg for a in fn.args.kwonlyargs]
    tainted = {p for i, p in enumerate(params)
               if p not in static_names and i not in static_nums
               and p != "self"}

    def check_casts(expr):
        stack = [expr]
        while stack:
            node = stack.pop()
            if isinstance(node, _SKIP_SCOPES):
                continue
            if (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Name)
                    and node.func.id in _CAST_CALLS and node.args
                    and any(_tainted_use(a, tainted) is not None
                            for a in node.args)):
                findings.append((
                    node.lineno,
                    f"{node.func.id}() on a traced value inside a {how} "
                    f"function '{fn.name}' forces concretization — "
                    f"compute with jnp/lax ops instead"))
            stack.extend(ast.iter_child_nodes(node))

    def visit(stmt):
        if isinstance(stmt, _SKIP_SCOPES):
            return
        if isinstance(stmt, ast.Assign):
            check_casts(stmt.value)
            is_tainted = _tainted_use(stmt.value, tainted) is not None
            for tgt in stmt.targets:
                for name in ast.walk(tgt):
                    if isinstance(name, ast.Name):
                        (tainted.add if is_tainted
                         else tainted.discard)(name.id)
        elif isinstance(stmt, ast.AugAssign):
            check_casts(stmt.value)
            if (isinstance(stmt.target, ast.Name)
                    and _tainted_use(stmt.value, tainted) is not None):
                tainted.add(stmt.target.id)
        elif isinstance(stmt, (ast.If, ast.While)):
            kind = "if" if isinstance(stmt, ast.If) else "while"
            hit = _tainted_use(stmt.test, tainted)
            if hit is not None:
                findings.append((
                    stmt.lineno,
                    f"Python `{kind}` on a traced value inside a {how} "
                    f"function '{fn.name}' — use jnp.where/lax.cond/"
                    f"lax.while_loop (or pl.when in kernels)"))
            check_casts(stmt.test)
            for s in (*stmt.body, *stmt.orelse):
                visit(s)
        elif isinstance(stmt, ast.For):
            check_casts(stmt.iter)
            for s in (*stmt.body, *stmt.orelse):
                visit(s)
        elif isinstance(stmt, ast.With):
            for s in stmt.body:
                visit(s)
        elif isinstance(stmt, ast.Try):
            for s in (*stmt.body, *stmt.orelse, *stmt.finalbody):
                visit(s)
        elif isinstance(stmt, (ast.Return, ast.Expr)) and stmt.value:
            check_casts(stmt.value)

    for stmt in fn.body:
        visit(stmt)


@rule("tracer-branch")
def check(tree, ctx):
    """Flag Python ``if``/``while``/``int()``/``bool()``/``float()`` on
    values derived from the parameters of provably-traced functions."""
    findings: List[Tuple[int, str]] = []
    seen = set()
    for fn, names, nums, is_kernel, how in _collect_candidates(tree):
        key = (id(fn), frozenset(names), frozenset(nums), is_kernel)
        if key in seen:
            continue
        seen.add(key)
        _analyze(fn, names, nums, is_kernel, how, findings)
    for item in sorted(set(findings)):
        yield item
