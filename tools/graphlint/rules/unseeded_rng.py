"""unseeded-rng: randomness must carry an explicit seed.

Benchmarks and differential tests in this repo are reproducible by
construction — every synthetic graph, id stream, and feature table comes
from ``np.random.default_rng(seed)``.  Global-state randomness
(``np.random.rand``, ``random.random``) silently breaks that: two runs
of the same benchmark stop being comparable, and a flaky differential
failure cannot be replayed.  This rule flags module-level RNG calls and
unseeded generator constructions; the fix is an explicit
``np.random.default_rng(seed)`` / ``random.Random(seed)`` object.
"""
from __future__ import annotations

import ast

from ..astutil import call_tail, dotted_name
from ..core import rule

#: numpy.random constructors that carry their seed explicitly
_SEEDED_CONSTRUCTORS = frozenset({
    "default_rng", "Generator", "SeedSequence", "PCG64", "Philox",
    "MT19937", "RandomState",
})

#: stdlib ``random`` module calls that are themselves the seeding step
_STDLIB_SEEDERS = frozenset({"Random", "SystemRandom", "seed"})


def _numpy_aliases(tree) -> set:
    """Local names bound to the numpy module (``import numpy as np``)."""
    aliases = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.name == "numpy":
                    aliases.add(a.asname or "numpy")
    return aliases


def _stdlib_random_imported(tree) -> bool:
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            if any(a.name == "random" for a in node.names):
                return True
    return False


@rule("unseeded-rng")
def check(tree, ctx):
    """Flag ``np.random.*`` / ``random.*`` calls that draw from global
    RNG state instead of an explicitly seeded generator."""
    np_names = _numpy_aliases(tree)
    has_stdlib_random = _stdlib_random_imported(tree)
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        dotted = dotted_name(node.func)
        if dotted is None:
            continue
        parts = dotted.split(".")
        # numpy: np.random.<fn>(...)
        if len(parts) == 3 and parts[0] in np_names and parts[1] == "random":
            fn = parts[2]
            if fn not in _SEEDED_CONSTRUCTORS:
                yield (node.lineno,
                       f"np.random.{fn}() draws from global RNG state — "
                       f"use an explicit np.random.default_rng(seed) "
                       f"generator so runs are reproducible")
            elif not node.args and not node.keywords:
                yield (node.lineno,
                       f"np.random.{fn}() without a seed — pass an "
                       f"explicit seed so runs are reproducible")
        # stdlib: random.<fn>(...)
        elif (len(parts) == 2 and parts[0] == "random"
                and has_stdlib_random):
            fn = parts[1]
            if fn not in _STDLIB_SEEDERS:
                yield (node.lineno,
                       f"random.{fn}() draws from global RNG state — "
                       f"use an explicit random.Random(seed) instance")
            elif fn in ("Random", "SystemRandom") and fn == "Random" \
                    and not node.args:
                yield (node.lineno,
                       "random.Random() without a seed — pass an explicit "
                       "seed so runs are reproducible")
