"""collective-axis: collective axis names must come from the mesh.

Collectives (``psum``/``all_to_all``/``axis_index``/...) silently hang
or mis-reduce when an ``axis_name`` string drifts from the mesh axes
declared in ``src/repro/launch/mesh.py`` (``pod``/``data``/``model``).
This rule checks every string-literal axis name at a collective call
site against that set (extendable via ``collective-axes`` in
``[tool.graphlint]``), and additionally requires ``shard_map`` calls to
pass ``out_specs`` explicitly — the historical out_specs-defaulting bug
produced replicated outputs that silently multiplied memory.
"""
from __future__ import annotations

import ast

from ..astutil import call_tail, has_double_star, string_constants
from ..core import rule

#: jax.lax / jax collective entry points that take axis names
_COLLECTIVES = frozenset({
    "psum", "pmean", "pmax", "pmin", "psum_scatter", "all_gather",
    "all_to_all", "ppermute", "pshuffle", "axis_index", "axis_size",
})

#: keywords at collective call sites that carry axis names
_AXIS_KEYWORDS = ("axis_name", "axis")


@rule("collective-axis")
def check(tree, ctx):
    """Flag string-literal axis names not declared in launch/mesh.py and
    shard_map calls that omit ``out_specs``."""
    allowed = ctx.mesh_axes
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        tail = call_tail(node.func)
        if tail == "shard_map":
            if (not has_double_star(node)
                    and not any(kw.arg == "out_specs"
                                for kw in node.keywords)):
                yield (node.lineno,
                       "shard_map call without an explicit out_specs= — "
                       "spell out the output shardings so a replicated "
                       "default cannot silently blow up memory")
            continue
        if tail not in _COLLECTIVES:
            continue
        axis_exprs = list(node.args)
        axis_exprs += [kw.value for kw in node.keywords
                       if kw.arg in _AXIS_KEYWORDS]
        for lineno, name in _axis_strings(axis_exprs):
            if name not in allowed:
                yield (lineno,
                       f"collective {tail}() uses axis name {name!r}, "
                       f"which is not declared in launch/mesh.py "
                       f"(allowed: {sorted(allowed)}); use the mesh "
                       f"constants or add it to [tool.graphlint] "
                       f"collective-axes")


def _axis_strings(exprs):
    for expr in exprs:
        yield from string_constants(expr)
