"""handle-lifecycle: async handles must be drained/joined on every path.

The repo's host-gather pipeline hands out *handles* whose work is only
made visible by a finalizer call: ``HostFeatureStore.issue()`` returns a
``HostGather`` that must be ``rows()``/``host_rows()``-drained (PR 7's
double buffer silently drops a round if the pending gather is never
collected), ``PrefetchLoader`` must be ``stop()``-ed (the PR 1 thread
leak kept a daemon thread spinning after the loader was garbage),
``ThreadPoolExecutor`` must be ``shutdown()`` and ``threading.Thread``
must be ``join()``-ed or the process exits with work in flight.

This is a path property, so the rule walks the CFG: from each handle
creation it searches for a path to scope exit on which the handle is
neither finalized nor escapes (returned, stored in a container, passed
to a call, aliased, iterated).  ``with``-managed handles are exempt —
the context manager is the finalizer.  A redefinition that clobbers an
undrained handle is reported at the clobbering line.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Optional, Set, Tuple

from ..astutil import call_tail, dotted_name
from ..core import project_rule
from ..analysis.cfg import ENTRY, EXIT
from ..analysis.defuse import assigned_names

#: constructor tail -> the finalizer methods that discharge the handle
_CREATORS: Dict[str, frozenset] = {
    "ThreadPoolExecutor": frozenset({"shutdown"}),
    "Thread": frozenset({"join"}),
    "PrefetchLoader": frozenset({"stop"}),
}

#: receivers whose ``.issue()`` returns a HostGather handle
_STORE_NAMES = frozenset({"store", "host_store", "feature_store", "l3",
                          "l3_store", "hfs"})
_GATHER_FINALIZERS = frozenset({"rows", "host_rows", "collect"})


def _store_names_in(body: List[ast.stmt]) -> Set[str]:
    """Names syntactically bound to ``HostFeatureStore(...)`` in *body*
    (any nesting level — a scope-wide approximation is fine here)."""
    out: Set[str] = set()
    for stmt in body:
        for node in ast.walk(stmt):
            if (isinstance(node, ast.Assign) and len(node.targets) == 1
                    and isinstance(node.targets[0], ast.Name)
                    and isinstance(node.value, ast.Call)
                    and call_tail(node.value.func) == "HostFeatureStore"):
                out.add(node.targets[0].id)
    return out


def _creator_finalizers(value: ast.expr,
                        store_names: Set[str]) -> Optional[Tuple[frozenset, str]]:
    """``(finalizers, description)`` when *value* constructs a tracked
    handle, else None."""
    if not isinstance(value, ast.Call):
        return None
    tail = call_tail(value.func)
    if tail in _CREATORS:
        return _CREATORS[tail], f"{tail}(...)"
    if tail == "issue" and isinstance(value.func, ast.Attribute):
        recv = value.func.value
        recv_name = None
        if isinstance(recv, ast.Name):
            recv_name = recv.id
        elif isinstance(recv, ast.Attribute):
            recv_name = recv.attr
        if recv_name is not None and (recv_name in _STORE_NAMES
                                      or recv_name in store_names):
            return _GATHER_FINALIZERS, f"{recv_name}.issue(...)"
    return None


def _walk_with_parent(expr: ast.AST) -> Iterator[Tuple[ast.AST, Optional[ast.AST]]]:
    stack: List[Tuple[ast.AST, Optional[ast.AST]]] = [(expr, None)]
    while stack:
        node, parent = stack.pop()
        yield node, parent
        for child in ast.iter_child_nodes(node):
            stack.append((child, node))


def _is_none_test(parent: Optional[ast.AST]) -> bool:
    """True when the name occurrence only compares against None."""
    return (isinstance(parent, ast.Compare)
            and all(isinstance(c, ast.Constant) and c.value is None
                    for c in parent.comparators))


def _classify_use(stmt: Optional[ast.stmt], exprs: List[ast.AST],
                  name: str, finalizers: frozenset) -> Optional[str]:
    """How a CFG node treats handle *name*: ``"consume"`` (a finalizer
    method is reached — dominates), ``"escape"`` (the bare name flows
    somewhere we cannot track: call argument, container, return, alias,
    iteration), or None (untouched / neutral method access).  Truthiness
    and ``is None`` tests inspect the handle without capturing it, so
    they stay neutral — the None-guard refinement below relies on it."""
    escaped = False
    for expr in exprs:
        for node, parent in _walk_with_parent(expr):
            if not (isinstance(node, ast.Name) and node.id == name):
                continue
            if isinstance(node.ctx, ast.Store):
                continue                  # a rebinding target is not a use
            if isinstance(parent, ast.Attribute) and parent.value is node:
                if parent.attr in finalizers:
                    return "consume"
                continue                  # h.start(), h.submit(...): neutral
            if _is_none_test(parent):
                continue
            if parent is None and isinstance(stmt, (ast.If, ast.While)):
                continue                  # `if h:` — a bare truthiness test
            escaped = True
    return "escape" if escaped else None


def _feasible_successors(cfg, nid: int, stmt: ast.stmt, name: str,
                         stmt_to_nid: Dict[int, int]) -> Set[int]:
    """Successors of *nid* a LIVE handle *name* can actually take.

    The canonical finalize-an-optional-handle idiom is a None guard
    (``if h is not None: h.rows()``).  On any path where ``h`` holds the
    tracked handle it is not None, so the guard's skip/else side is
    infeasible — without this refinement every guarded drain would be a
    false leak.  Applies only to tests that are exactly ``h``, ``not
    h``, ``h is None``, or ``h is not None``."""
    succ = cfg.succ.get(nid, set())
    if not isinstance(stmt, ast.If) or not stmt.body:
        return succ
    test, positive = stmt.test, None
    if isinstance(test, ast.Name) and test.id == name:
        positive = True
    elif (isinstance(test, ast.UnaryOp) and isinstance(test.op, ast.Not)
            and isinstance(test.operand, ast.Name)
            and test.operand.id == name):
        positive = False
    elif (isinstance(test, ast.Compare) and len(test.ops) == 1
            and isinstance(test.left, ast.Name) and test.left.id == name
            and _is_none_test(test)):
        positive = isinstance(test.ops[0], ast.IsNot)
    if positive is None:
        return succ
    body_entry = stmt_to_nid.get(id(stmt.body[0]))
    if body_entry is None:
        return succ
    return succ & {body_entry} if positive else succ - {body_entry}


@project_rule("handle-lifecycle")
def handle_lifecycle(index):
    """async handle (issue()/Thread/PrefetchLoader/executor) may leak: a
    CFG path reaches scope exit without draining or escaping it."""
    for module, fi, body in index.iter_scopes():
        store_names = _store_names_in(body)
        cfg = index.cfg_of(module.path, fi)
        for nid, stmt in cfg.stmts.items():
            if not (isinstance(stmt, ast.Assign) and len(stmt.targets) == 1
                    and isinstance(stmt.targets[0], ast.Name)):
                continue
            created = _creator_finalizers(stmt.value, store_names)
            if created is None:
                continue
            finalizers, desc = created
            name = stmt.targets[0].id
            leak = _find_leak(cfg, nid, name, finalizers)
            if leak is None:
                continue
            leak_nid, why = leak
            where = ("scope exit" if leak_nid == EXIT else
                     f"line {cfg.stmts[leak_nid].lineno}")
            fins = "/".join(f".{f}()" for f in sorted(finalizers))
            yield (module.path, stmt.lineno,
                   f"handle '{name}' from {desc} can reach {where} "
                   f"({why}) without {fins}; drain or join it on every "
                   f"path, or hand it off explicitly")


def _find_leak(cfg, def_nid: int, name: str,
               finalizers: frozenset) -> Optional[Tuple[int, str]]:
    """First CFG node proving a leaking path from *def_nid*, else None.

    BFS over successors; a consuming or escaping node satisfies its
    path (not expanded), EXIT or a clobbering redefinition without
    prior consumption is the leak witness."""
    stmt_to_nid = {id(s): n for n, s in cfg.stmts.items()}
    seen: Set[int] = set()
    work = list(cfg.succ.get(def_nid, ()))
    while work:
        nid = work.pop()
        if nid in seen:
            continue
        seen.add(nid)
        if nid == EXIT:
            return EXIT, "falls off the end"
        stmt = cfg.stmts.get(nid)
        if stmt is None:          # ENTRY cannot reappear; defensive
            continue
        use = _classify_use(stmt, cfg.header_exprs.get(nid, []), name,
                            finalizers)
        if use is not None:
            continue              # this path is satisfied
        if name in assigned_names(stmt, cfg.header_exprs.get(nid, [])):
            return nid, "is overwritten undrained"
        work.extend(_feasible_successors(cfg, nid, stmt, name, stmt_to_nid))
    return None
