"""host-transfer: blocking device->host round-trips on traced values.

The L3 host feature store (``core/host_store.py``) makes host transfers
a first-class, *deliberately placed* part of the fetch path: gathers are
issued outside the jitted step and overlap the next step's compute.
The hazard this rule encodes is the accidental version — a host
round-trip *inside* a ``jit``/``shard_map`` function or Pallas kernel:

* ``jax.device_get(x)`` / ``np.asarray(x)`` on a tracer raises a
  ``TracerArrayConversionError`` at best; on a concrete-but-traced
  value it silently bakes one step's data into the compiled program;
* ``x.block_until_ready()`` under tracing is a no-op on the tracer
  (nothing to wait for) that *reads* as a synchronization point — the
  barrier the author wanted never exists in the compiled program.

The fix is always the same: keep the value on device (``jnp`` ops) and
move the transfer/synchronization outside the traced function — the
issue/collect split in ``host_store.py`` is the worked example.

Scope mirrors ``tracer-branch``: only provably-traced functions are
analyzed, and only values derived from their (non-static) parameters
are tainted, so host-side driver code that legitimately calls
``np.asarray``/``block_until_ready`` (e.g. the store's ``_gather``)
never fires.
"""
from __future__ import annotations

import ast
from typing import List, Set, Tuple

from ..astutil import call_tail
from ..core import rule
from .tracer_branch import _SKIP_SCOPES, _collect_candidates, _tainted_use


def _numpy_aliases(tree) -> Set[str]:
    """Local names bound to the real numpy module (never ``jax.numpy``)."""
    aliases = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.name == "numpy":
                    aliases.add(a.asname or "numpy")
    return aliases


def _flag_call(node: ast.Call, tainted: Set[str], np_names: Set[str],
               fn_name: str, how: str):
    """Finding tuple when *node* is a blocking host transfer on a tainted
    value, else None."""
    tail = call_tail(node.func)
    if tail == "device_get" and any(
            _tainted_use(a, tainted) is not None for a in node.args):
        return (node.lineno,
                f"jax.device_get() on a traced value inside a {how} "
                f"function '{fn_name}' blocks on a device->host copy — "
                f"keep the value on device or move the transfer outside "
                f"the traced function (see core/host_store.py's "
                f"issue/collect split)")
    if (tail in ("asarray", "array")
            and isinstance(node.func, ast.Attribute)
            and isinstance(node.func.value, ast.Name)
            and node.func.value.id in np_names
            and any(_tainted_use(a, tainted) is not None
                    for a in node.args)):
        return (node.lineno,
                f"np.{tail}() on a traced value inside a {how} function "
                f"'{fn_name}' materializes it on the host — use "
                f"jnp.{tail} (stays on device) or hoist the conversion "
                f"out of the traced function")
    if tail == "block_until_ready":
        recv_tainted = (isinstance(node.func, ast.Attribute)
                        and _tainted_use(node.func.value, tainted)
                        is not None)
        if recv_tainted or any(_tainted_use(a, tainted) is not None
                               for a in node.args):
            return (node.lineno,
                    f"block_until_ready() on a traced value inside a "
                    f"{how} function '{fn_name}' is a silent no-op under "
                    f"tracing — the barrier never exists in the compiled "
                    f"program; synchronize outside the traced function")
    return None


def _analyze(fn, static_names, static_nums, is_kernel, how,
             np_names: Set[str], findings: List[Tuple[int, str]]):
    params = [a.arg for a in (*fn.args.posonlyargs, *fn.args.args)]
    if not is_kernel:
        params += [a.arg for a in fn.args.kwonlyargs]
    tainted = {p for i, p in enumerate(params)
               if p not in static_names and i not in static_nums
               and p != "self"}

    def check_calls(expr):
        stack = [expr]
        while stack:
            node = stack.pop()
            if isinstance(node, _SKIP_SCOPES):
                continue
            if isinstance(node, ast.Call):
                hit = _flag_call(node, tainted, np_names, fn.name, how)
                if hit is not None:
                    findings.append(hit)
            stack.extend(ast.iter_child_nodes(node))

    def visit(stmt):
        if isinstance(stmt, _SKIP_SCOPES):
            return
        if isinstance(stmt, ast.Assign):
            check_calls(stmt.value)
            is_tainted = _tainted_use(stmt.value, tainted) is not None
            for tgt in stmt.targets:
                for name in ast.walk(tgt):
                    if isinstance(name, ast.Name):
                        (tainted.add if is_tainted
                         else tainted.discard)(name.id)
        elif isinstance(stmt, ast.AugAssign):
            check_calls(stmt.value)
            if (isinstance(stmt.target, ast.Name)
                    and _tainted_use(stmt.value, tainted) is not None):
                tainted.add(stmt.target.id)
        elif isinstance(stmt, (ast.If, ast.While)):
            check_calls(stmt.test)
            for s in (*stmt.body, *stmt.orelse):
                visit(s)
        elif isinstance(stmt, ast.For):
            check_calls(stmt.iter)
            for s in (*stmt.body, *stmt.orelse):
                visit(s)
        elif isinstance(stmt, ast.With):
            for s in stmt.body:
                visit(s)
        elif isinstance(stmt, ast.Try):
            for s in (*stmt.body, *stmt.orelse, *stmt.finalbody):
                visit(s)
        elif isinstance(stmt, (ast.Return, ast.Expr)) and stmt.value:
            check_calls(stmt.value)

    for stmt in fn.body:
        visit(stmt)


@rule("host-transfer")
def check(tree, ctx):
    """Flag ``jax.device_get``/``np.asarray``/``.block_until_ready()`` on
    values derived from the parameters of provably-traced functions."""
    findings: List[Tuple[int, str]] = []
    np_names = _numpy_aliases(tree)
    seen = set()
    for fn, names, nums, is_kernel, how in _collect_candidates(tree):
        key = (id(fn), frozenset(names), frozenset(nums), is_kernel)
        if key in seen:
            continue
        seen.add(key)
        _analyze(fn, names, nums, is_kernel, how, np_names, findings)
    for item in sorted(set(findings)):
        yield item
