"""cacheconfig-required: fetch-path calls must thread the CacheConfig.

The PR 3 review hardening made the cache geometry (``CacheConfig``) an
explicit argument everywhere — the original bug was a call site that
built a cache with one config and probed it with a default-constructed
one, a shape-compatible but semantically dead configuration.  This rule
enforces the contract at every call site:

* ``fetch_rows(..., cache=...)`` must also pass ``cache_cfg=``;
* ``cache_probe(...)`` / ``tiered_probe(...)`` must pass the
  keyword-only ``cfg=``;
* ``cache_insert(...)`` must pass ``cfg`` (5th positional or keyword).

Calls forwarding ``**kwargs`` are skipped (the config may travel in the
dict); the runtime check inside ``fetch_rows`` still backstops those.
"""
from __future__ import annotations

import ast

from ..astutil import call_tail, has_double_star, keyword_arg
from ..core import rule


def _is_none(node) -> bool:
    return isinstance(node, ast.Constant) and node.value is None


@rule("cacheconfig-required")
def check(tree, ctx):
    """Flag fetch_rows/cache_probe/tiered_probe/cache_insert call sites
    that do not pass the CacheConfig."""
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        tail = call_tail(node.func)
        if tail is None or has_double_star(node):
            continue
        if tail == "fetch_rows":
            cache = keyword_arg(node, "cache")
            if cache is not None and not _is_none(cache):
                cfg = keyword_arg(node, "cache_cfg")
                if cfg is None or _is_none(cfg):
                    yield (node.lineno,
                           "fetch_rows(cache=...) without cache_cfg= — the "
                           "cache geometry must be threaded explicitly "
                           "(the PR 3 dead-config bug)")
        elif tail in ("cache_probe", "tiered_probe"):
            if keyword_arg(node, "cfg") is None:
                yield (node.lineno,
                       f"{tail}() without cfg= — CacheConfig is a required "
                       f"keyword; probing with an implicit default config "
                       f"is the dead-config bug class")
        elif tail == "cache_insert":
            if len(node.args) < 5 and keyword_arg(node, "cfg") is None:
                yield (node.lineno,
                       "cache_insert() without cfg — pass the CacheConfig "
                       "(5th positional or cfg=) so admission uses the "
                       "real geometry")
