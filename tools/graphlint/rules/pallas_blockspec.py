"""pallas-blockspec: Pallas launch-geometry and shift-width hygiene.

Three checks, all encoding bugs this repo has actually shipped:

1. **grid built with `//`** — a ``pl.pallas_call`` grid dimension
   computed with floor division silently drops the partial final block
   when the axis stops being an exact multiple; ``pl.cdiv`` covers it.
2. **impure BlockSpec index map** — a ``pl.BlockSpec`` whose lambda
   index map calls functions can capture traced state or allocate; index
   maps must be pure index arithmetic.
3. **shift width that can reach 32** — ``x >> (32 - k)`` (or
   ``shift_right_logical`` with such an amount) is undefined for
   ``k == 0`` on int32/uint32 lanes: shifting by 32 is UB and produced
   the PR 3 degenerate-hash bug (every id hashed to set 0 when
   ``n_sets == 1``).  A ``32 - <nonconstant>`` shift amount must sit
   behind an early-out guard (an ``if`` that returns/raises before the
   shift — the ``hash_slots`` idiom).
"""
from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Optional, Tuple

from ..astutil import call_tail, function_defs, keyword_arg
from ..core import rule

_SHIFT_CALLS = frozenset({
    "shift_right_logical", "shift_right_arithmetic", "shift_left",
    "right_shift", "left_shift",
})

#: single-argument wrappers to look through when resolving shift amounts
_CAST_WRAPPERS = frozenset({
    "uint32", "int32", "uint64", "int64", "asarray", "array", "int",
    "astype",
})


def _assign_env(scope: ast.AST) -> Dict[str, ast.expr]:
    """name -> value for simple ``name = expr`` assignments in *scope*
    (shallow: nested function bodies keep their own env)."""
    env: Dict[str, ast.expr] = {}
    body = scope.body if hasattr(scope, "body") else []
    stack: List[ast.stmt] = list(body)
    while stack:
        stmt = stack.pop()
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            continue
        if (isinstance(stmt, ast.Assign) and len(stmt.targets) == 1
                and isinstance(stmt.targets[0], ast.Name)):
            env[stmt.targets[0].id] = stmt.value
        for field in ("body", "orelse", "finalbody"):
            stack.extend(getattr(stmt, field, []))
    return env


def _resolve(expr: ast.expr, env: Dict[str, ast.expr],
             depth: int = 4) -> ast.expr:
    """Chase simple names and single-arg casts to the defining expr."""
    while depth > 0:
        depth -= 1
        if isinstance(expr, ast.Name) and expr.id in env:
            expr = env[expr.id]
        elif (isinstance(expr, ast.Call) and len(expr.args) == 1
                and call_tail(expr.func) in _CAST_WRAPPERS):
            expr = expr.args[0]
        else:
            break
    return expr


def _has_32_minus_dynamic(expr: ast.expr) -> bool:
    """True when *expr* contains ``32 - <non-constant>``."""
    for node in ast.walk(expr):
        if (isinstance(node, ast.BinOp) and isinstance(node.op, ast.Sub)
                and isinstance(node.left, ast.Constant)
                and node.left.value == 32
                and not isinstance(node.right, ast.Constant)):
            return True
    return False


def _shift_amounts(scope: ast.AST) -> Iterator[Tuple[int, ast.expr]]:
    """(lineno, amount-expr) of every shift operation in *scope*,
    excluding nested function bodies (handled by their own pass)."""
    stack = list(ast.iter_child_nodes(scope))
    while stack:
        node = stack.pop()
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        if (isinstance(node, ast.BinOp)
                and isinstance(node.op, (ast.LShift, ast.RShift))):
            yield node.lineno, node.right
        elif (isinstance(node, ast.Call)
                and call_tail(node.func) in _SHIFT_CALLS
                and len(node.args) >= 2):
            yield node.lineno, node.args[1]
        stack.extend(ast.iter_child_nodes(node))


def _guarded_before(scope: ast.AST, lineno: int) -> bool:
    """True when an ``if`` earlier in *scope* returns/raises — the
    degenerate case has an early out before the shift executes."""
    stack = list(ast.iter_child_nodes(scope))
    while stack:
        node = stack.pop()
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        if isinstance(node, ast.If) and node.lineno < lineno:
            for sub in ast.walk(node):
                if isinstance(sub, (ast.Return, ast.Raise)):
                    return True
        stack.extend(ast.iter_child_nodes(node))
    return False


def _scopes(tree: ast.Module):
    yield tree
    yield from function_defs(tree)


@rule("pallas-blockspec")
def check(tree, ctx):
    """Flag `//`-built grids, impure BlockSpec lambda index maps, and
    unguarded ``32 - k`` shift widths."""
    module_env = _assign_env(tree)

    # --- shift widths, per scope ------------------------------------
    for scope in _scopes(tree):
        env = dict(module_env)
        if scope is not tree:
            env.update(_assign_env(scope))
        for lineno, amount in _shift_amounts(scope):
            resolved = _resolve(amount, env)
            if (_has_32_minus_dynamic(resolved)
                    and not _guarded_before(scope, lineno)):
                yield (lineno,
                       "shift amount of the form `32 - k` can reach 32 "
                       "when k == 0 — undefined behaviour on int32/uint32 "
                       "(the degenerate-hash bug); guard the k == 0 case "
                       "with an early return before shifting")

    # --- pallas_call grids and BlockSpec index maps ------------------
    for scope in _scopes(tree):
        env = dict(module_env)
        if scope is not tree:
            env.update(_assign_env(scope))
        stack = list(ast.iter_child_nodes(scope))
        while stack:
            node = stack.pop()
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            stack.extend(ast.iter_child_nodes(node))
            if not isinstance(node, ast.Call):
                continue
            tail = call_tail(node.func)
            if tail == "pallas_call":
                grid = keyword_arg(node, "grid")
                if grid is not None:
                    resolved = _resolve(grid, env)
                    for lineno in _floordiv_lines(resolved):
                        yield (lineno,
                               "pallas_call grid dimension uses `//` — a "
                               "non-multiple axis silently drops its "
                               "partial final block; use pl.cdiv")
            elif tail == "BlockSpec":
                maps = [a for a in node.args if isinstance(a, ast.Lambda)]
                kw = keyword_arg(node, "index_map")
                if isinstance(kw, ast.Lambda):
                    maps.append(kw)
                for lam in maps:
                    for sub in ast.walk(lam.body):
                        if isinstance(sub, ast.Call):
                            yield (lam.lineno,
                                   "BlockSpec index map calls a function — "
                                   "index maps must be pure index "
                                   "arithmetic (block coords in, block "
                                   "coords out)")
                            break


def _floordiv_lines(expr: ast.expr):
    seen = set()
    for node in ast.walk(expr):
        if isinstance(node, ast.BinOp) and isinstance(node.op, ast.FloorDiv):
            if node.lineno not in seen:
                seen.add(node.lineno)
                yield node.lineno
