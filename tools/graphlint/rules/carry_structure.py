"""carry-structure: pack and unpack sites of carry tuples must agree.

The pipelined/offline loops thread a positional carry tuple
(``(params, opt_state, batch[, cache])``) through jitted step functions,
and checkpointing saves/restores the same tuple shape.  Nothing in
Python checks that the packer and the unpacker agree: add a cache slot
to the pack site and forget one unpack site, and the loop trains on a
transposed carry (the PR 3 "dead CacheConfig" incident was exactly a
pack/unpack drift that type-checked fine).

The rule checks, interprocedurally through the call graph (including
``functools.partial``, ``jax.jit(f)``, and ``make_*_fn`` factory
returns):

* a call passing a tuple (literal, or a variable whose reaching
  definitions are all tuple literals of one arity) to a parameter the
  callee tuple-unpacks with a different arity — or the same arity with
  the same element names in a different order (a transposition);
* ``a, b = f(...)`` where every return in every resolved callee is a
  tuple literal of a different arity;
* ``x[k]`` where every reaching definition of ``x`` is a tuple literal
  with fewer than ``k + 1`` elements;
* ``checkpoint.save(..., (…))`` vs ``restore(..., (…))`` arity drift
  within one module.

Anything ambiguous — multiple pack arities (the cached/uncached carry
variants), unresolvable callees, reaching defs that include a parameter
— is skipped, not guessed at.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from ..astutil import call_tail, dotted_name, keyword_arg
from ..core import project_rule
from ..analysis.cfg import ENTRY

#: dotted receivers that syntactically mark a checkpoint call site
_CKPT_RECEIVERS = frozenset({"checkpoint", "ckpt"})
_CKPT_MODULE = "repro.train.checkpoint"


def _tuple_literal(expr: ast.expr) -> Optional[Tuple[int, Optional[List[str]]]]:
    """``(arity, element names or None)`` for a tuple literal."""
    if not isinstance(expr, ast.Tuple):
        return None
    names = [e.id if isinstance(e, ast.Name) else None for e in expr.elts]
    return len(expr.elts), (names if all(n is not None for n in names)
                            else None)


def _pack_of(arg: ast.expr, nid: int, cfg,
             reaching) -> Optional[Tuple[int, Optional[List[str]], int]]:
    """``(arity, names, pack lineno)`` when *arg* is provably one tuple
    shape at this node: a literal, or a name whose reaching defs are all
    single-target tuple-literal assignments of one arity."""
    lit = _tuple_literal(arg)
    if lit is not None:
        return lit[0], lit[1], arg.lineno
    if not isinstance(arg, ast.Name):
        return None
    sites = reaching.reaching(nid, arg.id)
    if not sites or ENTRY in sites:
        return None
    packs = []
    for site in sites:
        stmt = cfg.stmts.get(site)
        if not (isinstance(stmt, ast.Assign) and len(stmt.targets) == 1
                and isinstance(stmt.targets[0], ast.Name)):
            return None
        site_lit = _tuple_literal(stmt.value)
        if site_lit is None:
            return None
        packs.append((site_lit[0], site_lit[1], stmt.lineno))
    arities = {p[0] for p in packs}
    if len(arities) != 1:
        return None                        # cached/uncached variant packs
    name_lists = {tuple(p[1]) for p in packs if p[1] is not None}
    names = list(name_lists.pop()) if len(name_lists) == 1 else None
    return packs[0][0], names, packs[0][2]


def _positional_params(fi) -> Optional[List[str]]:
    """Positional parameter names of a candidate, or None to skip it
    (methods and ``*args`` make positions unreliable)."""
    if fi.cls is not None:
        return None
    a = fi.node.args
    if a.vararg is not None:
        return None
    return [p.arg for p in (*a.posonlyargs, *a.args)]


def _unpack_of(fi, param: str) -> Optional[Tuple[int, Optional[List[str]]]]:
    """The tuple-unpack shape a callee applies to *param*, if exactly
    one ``a, b, ... = param`` exists in its body (own scope only)."""
    shapes = []
    stack = list(fi.node.body)
    while stack:
        node = stack.pop()
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef, ast.Lambda)):
            continue
        if (isinstance(node, ast.Assign) and len(node.targets) == 1
                and isinstance(node.targets[0], ast.Tuple)
                and isinstance(node.value, ast.Name)
                and node.value.id == param):
            tgt = node.targets[0]
            names = [e.id if isinstance(e, ast.Name) else None
                     for e in tgt.elts]
            shapes.append((len(tgt.elts),
                           names if all(n is not None for n in names)
                           else None))
        stack.extend(ast.iter_child_nodes(node))
    if len({s[0] for s in shapes}) != 1:
        return None
    names = shapes[0][1] if len(shapes) == 1 else None
    return shapes[0][0], names


def _return_arities(fi) -> Optional[Set[int]]:
    """Arity set when every return of *fi* is a tuple literal, else None."""
    arities: Set[int] = set()
    stack = list(fi.node.body)
    while stack:
        node = stack.pop()
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef, ast.Lambda)):
            continue
        if isinstance(node, ast.Return):
            if not isinstance(node.value, ast.Tuple):
                return None
            arities.add(len(node.value.elts))
        stack.extend(ast.iter_child_nodes(node))
    return arities or None


def _resolve_flow(func: ast.expr, nid: int, cfg, reaching, index,
                  module, fi, cg) -> List:
    """Resolve a call target like ``cg.resolve``, but flow-sensitively
    for bare names: only the definitions REACHING this node count, so a
    name rebound differently on two branches (``run = jit(a)`` /
    ``run = jit(b)``) resolves per-path instead of to whichever binding
    is syntactically last.  Unknown provenance resolves to ``[]``."""
    if not isinstance(func, ast.Name):
        return cg.resolve(func, module, fi)
    sites = reaching.reaching(nid, func.id)
    if not sites:
        return cg.resolve(func, module, fi)   # global/import/enclosing
    if ENTRY in sites:
        return []                             # maybe a parameter
    out = []
    for site in sites:
        stmt = cfg.stmts.get(site)
        if (isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef))
                and stmt.name == func.id):
            cand = [f for f in module.functions.values()
                    if f.node is stmt]
            if not cand:
                return []
            out.extend(cand)
            continue
        if not (isinstance(stmt, ast.Assign) and len(stmt.targets) == 1
                and isinstance(stmt.targets[0], ast.Name)):
            return []
        resolved = cg.resolve(stmt.value, module, fi)
        if not resolved:
            return []                         # one opaque path: give up
        out.extend(resolved)
    return out


def _ckpt_call_kind(call: ast.Call, module, fi, cg) -> Optional[str]:
    """"save"/"restore" when *call* targets the checkpoint module."""
    tail = call_tail(call.func)
    if tail not in ("save", "restore"):
        return None
    for cand in cg.resolve(call.func, module, fi):
        if cand.module == _CKPT_MODULE and cand.name == tail:
            return tail
    if isinstance(call.func, ast.Attribute):
        dotted = dotted_name(call.func.value)
        if dotted and dotted.split(".")[-1] in _CKPT_RECEIVERS:
            return tail
    return None


def _ckpt_tree_arg(call: ast.Call, kind: str) -> Optional[ast.expr]:
    """The saved/restored tree argument (positional 2, or tree=/like=)."""
    kw = keyword_arg(call, "tree" if kind == "save" else "like")
    if kw is not None:
        return kw
    if len(call.args) > 2 and not any(isinstance(a, ast.Starred)
                                      for a in call.args[:3]):
        return call.args[2]
    return None


@project_rule("carry-structure")
def carry_structure(index):
    """carry tuple pack/unpack sites disagree on arity or element order
    (step carries, factory returns, checkpoint save/restore trees)."""
    cg = index.callgraph
    ckpt: Dict[str, Dict[str, List[Tuple[int, Optional[List[str]], int]]]] = {}

    for module, fi, body in index.iter_scopes():
        cfg = index.cfg_of(module.path, fi)
        reaching = index.reaching_of(module.path, fi)
        for nid, stmt in cfg.stmts.items():
            exprs = cfg.header_exprs.get(nid, [])

            for expr in exprs:
                for node in ast.walk(expr):
                    if isinstance(node, ast.Call):
                        yield from _check_call(node, nid, cfg, reaching,
                                               index, module, fi, cg)
                        kind = _ckpt_call_kind(node, module, fi, cg)
                        if kind is not None:
                            tree = _ckpt_tree_arg(node, kind)
                            if tree is not None:
                                pack = _pack_of(tree, nid, cfg, reaching)
                                if pack is not None:
                                    ckpt.setdefault(module.path, {}) \
                                        .setdefault(kind, []).append(pack)
                    elif isinstance(node, ast.Subscript):
                        yield from _check_subscript(node, nid, cfg,
                                                    reaching, module.path)

            if (isinstance(stmt, ast.Assign) and len(stmt.targets) == 1
                    and isinstance(stmt.targets[0], ast.Tuple)
                    and isinstance(stmt.value, ast.Call)):
                yield from _check_return_unpack(stmt, nid, cfg, reaching,
                                                index, module, fi, cg)

    # checkpoint save/restore drift, paired per module
    for path, kinds in sorted(ckpt.items()):
        saves, restores = kinds.get("save", []), kinds.get("restore", [])
        save_arities = {p[0] for p in saves}
        restore_arities = {p[0] for p in restores}
        if len(save_arities) != 1 or not restores:
            continue                      # no pair, or ambiguous saves
        (s_arity,) = save_arities
        for r_arity, r_names, r_line in restores:
            if r_arity != s_arity:
                yield (path, r_line,
                       f"checkpoint restore expects a {r_arity}-tuple but "
                       f"save at line {saves[0][2]} writes a "
                       f"{s_arity}-tuple; the carry shapes drifted")
            elif (r_names is not None and saves[0][1] is not None
                  and set(r_names) == set(saves[0][1])
                  and r_names != saves[0][1]):
                yield (path, r_line,
                       f"checkpoint restore unpacks ({', '.join(r_names)}) "
                       f"but save at line {saves[0][2]} packs "
                       f"({', '.join(saves[0][1])}); element order drifted")


def _check_call(call: ast.Call, nid: int, cfg, reaching, index, module,
                fi, cg):
    """Tuple-shaped positional args vs the callee's unpack of that param."""
    if any(isinstance(a, ast.Starred) for a in call.args):
        return
    candidates = [c for c in _resolve_flow(call.func, nid, cfg, reaching,
                                           index, module, fi, cg)
                  if _positional_params(c) is not None]
    if not candidates:
        return
    for i, arg in enumerate(call.args):
        pack = _pack_of(arg, nid, cfg, reaching)
        if pack is None:
            continue
        arity, names, pack_line = pack
        unpacks = []
        for cand in candidates:
            params = _positional_params(cand)
            if i >= len(params):
                unpacks = []
                break
            shape = _unpack_of(cand, params[i])
            if shape is None:
                unpacks = []
                break
            unpacks.append((cand, shape))
        if not unpacks or len({u[1][0] for u in unpacks}) != 1:
            continue                      # unresolved or variant callees
        cand, (n, unames) = unpacks[0]
        if arity != n:
            yield (module.path, call.lineno,
                   f"call packs a {arity}-tuple (line {pack_line}) into "
                   f"'{cand.name}', which unpacks it as a {n}-tuple "
                   f"(line {cand.lineno}); the carry shapes drifted")
        elif (names is not None and unames is not None
              and set(names) == set(unames) and names != unames):
            yield (module.path, call.lineno,
                   f"call packs ({', '.join(names)}) but '{cand.name}' "
                   f"unpacks ({', '.join(unames)}); element order is "
                   f"transposed")


def _check_subscript(node: ast.Subscript, nid: int, cfg, reaching,
                     path: str):
    """Constant index beyond every reaching tuple-literal pack's arity."""
    if not (isinstance(node.value, ast.Name)
            and isinstance(node.slice, ast.Constant)
            and isinstance(node.slice.value, int)):
        return
    k = node.slice.value
    pack = _pack_of(node.value, nid, cfg, reaching)
    if pack is None:
        return
    arity, _, pack_line = pack
    if k >= arity or k < -arity:
        yield (path, node.lineno,
               f"'{node.value.id}[{k}]' indexes past the {arity}-tuple "
               f"packed at line {pack_line}")


def _check_return_unpack(stmt: ast.Assign, nid: int, cfg, reaching,
                         index, module, fi, cg):
    """``a, b = f(...)`` vs the tuple arity every callee returns."""
    tgt = stmt.targets[0]
    if not all(isinstance(e, ast.Name) for e in tgt.elts):
        return
    k = len(tgt.elts)
    candidates = [c for c in _resolve_flow(stmt.value.func, nid, cfg,
                                           reaching, index, module, fi, cg)
                  if c.cls is None]
    if not candidates:
        return
    arities: Set[int] = set()
    for cand in candidates:
        ret = _return_arities(cand)
        if ret is None:
            return
        arities |= ret
    if len(arities) == 1 and k not in arities:
        (n,) = arities
        yield (module.path, stmt.lineno,
               f"unpacks {k} values from '{call_tail(stmt.value.func)}', "
               f"whose returns are {n}-tuples; the shapes drifted")
