"""Shared finding-report formats for the repo gates.

Both ``tools/check_docs.py`` and ``tools/graphlint`` emit their findings
through this module so CI gets one consistent surface:

* ``human`` — ``path:line: [severity] check: message`` (terminal)
* ``json`` — a single ``{"findings": [...], "counts": {...}}`` object
* ``github`` — workflow commands (``::error file=...``) so a failing CI
  step annotates the offending line directly in the PR diff
* ``sarif`` — minimal SARIF 2.1.0, the interchange format GitHub code
  scanning ingests (``github/codeql-action/upload-sarif``), so lint
  findings show up as code-scanning alerts with history, not just as
  one-off step annotations

A *finding* is a plain dict with keys ``path`` (repo-relative), ``line``
(1-based int), ``check`` (rule / check id), ``severity`` (``"error"`` or
``"warning"``), and ``message``.
"""
from __future__ import annotations

import json
import sys

FORMATS = ("human", "json", "github", "sarif")

SARIF_SCHEMA = ("https://raw.githubusercontent.com/oasis-tcs/sarif-spec/"
                "master/Schemata/sarif-schema-2.1.0.json")


def _gh_escape(value: str) -> str:
    """Escape a workflow-command message per the Actions toolkit rules."""
    return (value.replace("%", "%25")
                 .replace("\r", "%0D")
                 .replace("\n", "%0A"))


def _gh_escape_property(value: str) -> str:
    """Escape a workflow-command property (file/title), which additionally
    reserves ``,`` and ``:``."""
    return (_gh_escape(value).replace(":", "%3A").replace(",", "%2C"))


def format_github(finding: dict) -> str:
    """One ``::error``/``::warning`` workflow command for *finding*."""
    level = "error" if finding.get("severity", "error") == "error" else "warning"
    return ("::{level} file={file},line={line},title={title}::{msg}".format(
        level=level,
        file=_gh_escape_property(str(finding["path"])),
        line=int(finding.get("line", 1)),
        title=_gh_escape_property(str(finding["check"])),
        msg=_gh_escape(str(finding["message"])),
    ))


def format_human(finding: dict) -> str:
    """``path:line: [severity] check: message`` for terminals."""
    return "{path}:{line}: [{sev}] {check}: {msg}".format(
        path=finding["path"], line=finding.get("line", 1),
        sev=finding.get("severity", "error"), check=finding["check"],
        msg=finding["message"])


def sarif_log(findings, tool_name: str, rule_docs=None) -> dict:
    """A minimal SARIF 2.1.0 log object for *findings*.

    *rule_docs* optionally maps rule id -> one-line description; rules
    referenced by findings always appear in the driver's rule table so
    code scanning can render them."""
    rule_docs = rule_docs or {}
    rule_ids = sorted({str(f["check"]) for f in findings} | set(rule_docs))
    rules = [{"id": rid,
              "shortDescription": {"text": rule_docs.get(rid, rid)}}
             for rid in rule_ids]
    rule_index = {rid: i for i, rid in enumerate(rule_ids)}
    results = []
    for f in findings:
        rid = str(f["check"])
        results.append({
            "ruleId": rid,
            "ruleIndex": rule_index[rid],
            "level": ("error" if f.get("severity", "error") == "error"
                      else "warning"),
            "message": {"text": str(f["message"])},
            "locations": [{
                "physicalLocation": {
                    "artifactLocation": {
                        "uri": str(f["path"]).replace("\\", "/"),
                        "uriBaseId": "ROOT",
                    },
                    "region": {"startLine": int(f.get("line", 1))},
                },
            }],
        })
    return {
        "$schema": SARIF_SCHEMA,
        "version": "2.1.0",
        "runs": [{
            "tool": {"driver": {"name": tool_name,
                                "informationUri":
                                    "https://example.invalid/repro-lint",
                                "rules": rules}},
            "originalUriBaseIds": {"ROOT": {"uri": "file:///"}},
            "results": results,
        }],
    }


def write_sarif(findings, path: str, tool_name: str,
                rule_docs=None) -> None:
    """Serialize :func:`sarif_log` to *path* (the ``--sarif-out`` flag)."""
    with open(path, "w", encoding="utf-8") as f:
        json.dump(sarif_log(findings, tool_name, rule_docs=rule_docs),
                  f, indent=2, sort_keys=True)
        f.write("\n")


def emit(findings, fmt: str = "human", stream=None,
         tool_name: str = "repro-lint") -> None:
    """Write *findings* (list of finding dicts) to *stream* in *fmt*."""
    stream = stream if stream is not None else sys.stdout
    if fmt not in FORMATS:
        raise ValueError(f"unknown format {fmt!r}; expected one of {FORMATS}")
    if fmt == "sarif":
        json.dump(sarif_log(findings, tool_name), stream, indent=2,
                  sort_keys=True)
        stream.write("\n")
        return
    if fmt == "json":
        counts = {"error": 0, "warning": 0}
        for f in findings:
            counts[f.get("severity", "error")] = (
                counts.get(f.get("severity", "error"), 0) + 1)
        json.dump({"findings": list(findings), "counts": counts},
                  stream, indent=2, sort_keys=True)
        stream.write("\n")
        return
    fmt_one = format_github if fmt == "github" else format_human
    for f in findings:
        stream.write(fmt_one(f) + "\n")
