"""Jit'd public wrappers for the Pallas kernels.

Each op dispatches kernel vs pure-jnp reference via ``use_kernel`` (models
pass their config's flag).  On non-TPU backends kernels run in
``interpret=True`` mode — the kernel body executes exactly, which is the
validation story on this CPU container; on TPU they compile natively.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .cache_gather import (cache_probe_compact_pallas,
                           cache_probe_gather_pallas,
                           cache_probe_tiered_pallas)
from .flash_attention import flash_attention_pallas
from .gather_reduce import fanout_mean_pallas, gather_reduce_pallas
from .ssd_scan import ssd_scan_pallas


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def fanout_mean(x: jax.Array, mask: jax.Array, use_kernel: bool = False) -> jax.Array:
    """Masked mean over the fanout axis: x [M, K, D], mask [M, K] -> [M, D]
    (the GCN aggregation step on a padded fanout tree)."""
    if use_kernel:
        return fanout_mean_pallas(x, mask, interpret=_interpret())
    return ref.fanout_mean_ref(x, mask)


def gather_reduce(
    table: jax.Array, idx: jax.Array, mask: jax.Array, use_kernel: bool = False
) -> jax.Array:
    """Fused gather + masked mean: table [N, D], idx/mask [M, K] -> [M, D]
    (the per-worker hot spot of edge-centric collection + aggregation)."""
    if use_kernel:
        return gather_reduce_pallas(table, idx, mask, interpret=_interpret())
    return ref.gather_reduce_ref(table, idx, mask)


def cache_probe_gather(
    keys: jax.Array, rows: jax.Array, ids: jax.Array,
    assoc: int = 1, use_kernel: bool = False,
):
    """Fused hot-node cache probe+gather: (hit [R], rows [R, D])."""
    if use_kernel:
        return cache_probe_gather_pallas(keys, rows, ids, assoc=assoc,
                                         interpret=_interpret())
    return ref.cache_probe_gather_ref(keys, rows, ids, assoc=assoc)


def cache_probe_compact(
    keys: jax.Array, rows: jax.Array, ids: jax.Array,
    assoc: int = 1, hit_cap: int = 1, use_kernel: bool = False,
):
    """Fused probe + compact-wire encode of a [W, R] probe block:
    ``(words [W, ceil(R/32)] uint32, raw_words [W, ceil(R/32)] uint32,
    payload [W, min(hit_cap, R), D])`` — the post-demotion wire bitmap,
    the pre-demotion telemetry bitmap, and the compacted hit rows.

    The holder side of the compact shard-probe response
    (``generation._shard_probe`` with ``CacheConfig.wire == "compact"``);
    hits beyond ``hit_cap`` per destination are demoted to misses."""
    if use_kernel:
        return cache_probe_compact_pallas(keys, rows, ids, assoc=assoc,
                                          hit_cap=hit_cap,
                                          interpret=_interpret())
    return ref.cache_probe_compact_ref(keys, rows, ids, assoc=assoc,
                                       hit_cap=hit_cap)


def cache_probe_tiered(
    l1_keys: jax.Array, l1_rows: jax.Array,
    l2_keys: jax.Array, l2_rows: jax.Array, ids: jax.Array,
    l1_assoc: int = 1, l2_assoc: int = 1, use_kernel: bool = False,
):
    """Fused hierarchical L1/L2 probe: (src [R] 0=miss/1=L1/2=L2, rows)."""
    if use_kernel:
        return cache_probe_tiered_pallas(
            l1_keys, l1_rows, l2_keys, l2_rows, ids,
            l1_assoc=l1_assoc, l2_assoc=l2_assoc, interpret=_interpret())
    return ref.cache_probe_tiered_ref(l1_keys, l1_rows, l2_keys, l2_rows,
                                      ids, l1_assoc=l1_assoc,
                                      l2_assoc=l2_assoc)


def flash_attention(
    q: jax.Array, k: jax.Array, v: jax.Array,
    causal: bool = True, use_kernel: bool = False,
    block_q: int = 128, block_k: int = 128,
) -> jax.Array:
    """Softmax attention with GQA head grouping: q [B, Hq, Lq, Dh],
    k/v [B, Hkv, Lk, Dh] -> [B, Hq, Lq, Dh] (online-softmax tiles when
    ``use_kernel``)."""
    if use_kernel:
        return flash_attention_pallas(
            q, k, v, causal=causal, block_q=block_q, block_k=block_k,
            interpret=_interpret(),
        )
    return ref.flash_attention_ref(q, k, v, causal=causal)


def ssd_scan(
    x: jax.Array, dt: jax.Array, a: jax.Array,
    b_mat: jax.Array, c_mat: jax.Array,
    use_kernel: bool = False, chunk: int = 128,
) -> jax.Array:
    """Mamba-2 SSD recurrence: x [B, L, H, P], dt [B, L, H], a [H],
    b/c [B, L, N] -> y [B, L, H, P] (chunked scan when ``use_kernel``)."""
    if use_kernel:
        return ssd_scan_pallas(x, dt, a, b_mat, c_mat, chunk=chunk,
                               interpret=_interpret())
    return ref.ssd_scan_ref(x, dt, a, b_mat, c_mat)
