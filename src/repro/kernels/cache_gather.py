"""Fused Pallas probe+gather for the hot-node feature cache.

``gather_reduce_pallas`` serves already-sampled rows straight from the HBM
feature table; this kernel is its cache-tier sibling: it serves *cache
hits* from VMEM-tiled blocks of the device-resident cache
(core/feature_cache.py).  One kernel fuses the three steps a jnp probe
lowers to separately —

  slot    = top-bits multiplicative hash of each id        (VPU)
  hit     = keys[slot] == id                               (VPU compare)
  row     = rows[slot] masked by hit                       (VMEM gather)

The cache is small by construction (``cache_rows`` is a few thousand), so
a whole [C, block_d] column block of the row table fits in VMEM alongside
the full [C] key vector — the gather never touches HBM, which is the point
of the cache tier.  Grid: (R blocks, D blocks); the hit vector is written
once per D block (identical values, same revisiting pattern the other
kernels in this package use).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# keep the hash bit-compatible with the jnp probe (core/feature_cache.py)
from ..core.feature_cache import _HASH_K


def _probe_gather_kernel(keys_ref, rows_ref, ids_ref, hit_ref, out_ref,
                         *, shift: int):
    ids = ids_ref[...]                              # [br] int32
    h = ids.astype(jnp.uint32) * jnp.uint32(_HASH_K)
    slot = jax.lax.shift_right_logical(h, jnp.uint32(shift)).astype(jnp.int32)
    hit = keys_ref[...][slot] == ids                # [br] bool
    rows = rows_ref[...][slot]                      # [br, bd] VMEM gather
    hit_ref[...] = hit
    out_ref[...] = jnp.where(hit[:, None], rows, 0).astype(out_ref.dtype)


def cache_probe_gather_pallas(
    keys: jax.Array,     # [C] int32 resident id per slot (-1 = empty)
    rows: jax.Array,     # [C, D] resident feature rows
    ids: jax.Array,      # [R] int32 probe ids
    *,
    block_r: int = 256,
    block_d: int = 128,
    interpret: bool = True,
):
    """Probe ``ids`` against a direct-mapped cache: ``(hit [R], out [R, D])``.

    ``out`` rows are the cached copies where hit, zeros where missed —
    bit-identical to ``feature_cache.cache_probe`` (the jnp oracle is
    ``ref.cache_probe_gather_ref``).
    """
    c = keys.shape[0]
    if c & (c - 1):
        raise ValueError(f"cache size must be a power of two, got {c}")
    r = ids.shape[0]
    d = rows.shape[1]
    br, bd = min(block_r, r), min(block_d, d)
    shift = 32 - int(c).bit_length() + 1
    grid = (pl.cdiv(r, br), pl.cdiv(d, bd))
    return pl.pallas_call(
        functools.partial(_probe_gather_kernel, shift=shift),
        grid=grid,
        in_specs=[
            pl.BlockSpec((c,), lambda i, j: (0,)),        # full key vector
            pl.BlockSpec((c, bd), lambda i, j: (0, j)),   # VMEM column block
            pl.BlockSpec((br,), lambda i, j: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((br,), lambda i, j: (i,)),
            pl.BlockSpec((br, bd), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((r,), jnp.bool_),
            jax.ShapeDtypeStruct((r, d), rows.dtype),
        ],
        interpret=interpret,
    )(keys, rows, ids)
