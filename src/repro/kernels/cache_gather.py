"""Fused Pallas probe+gather for the hot-node feature cache.

``gather_reduce_pallas`` serves already-sampled rows straight from the HBM
feature table; this kernel is its cache-tier sibling: it serves *cache
hits* from VMEM-tiled blocks of the device-resident cache
(core/feature_cache.py).  One kernel fuses the steps a jnp probe lowers to
separately —

  set     = top-bits multiplicative hash of each id        (VPU)
  ways    = static unrolled loop over the ``assoc`` slots of the set:
            hit_j = keys[set*assoc+j] == id                (VPU compare)
            row   = rows[set*assoc+j] masked by hit_j      (VMEM gather)

``assoc=1`` is the direct-mapped PR 2 kernel; 2/4-way sets probe their
ways in the same VMEM residency (the way loop is a compile-time constant,
so it unrolls — no dynamic control flow on the accelerator).

The cache is small by construction (``cache_rows`` is a few thousand), so
a whole [C, block_d] column block of the row table fits in VMEM alongside
the full [C] key vector — the gather never touches HBM, which is the point
of the cache tier.  Grid: (R blocks, D blocks); the hit vector is written
once per D block (identical values, same revisiting pattern the other
kernels in this package use).

``cache_probe_tiered_pallas`` is the hierarchical sibling: ONE kernel
probes the small replicated L1 and this worker's L2 block in the same
VMEM residency (tiered mode's single-worker degenerate and the shard
holder's local two-tier probe).  L1 takes priority; the source vector
reports which tier served each id (0 = miss, 1 = L1, 2 = L2) so the
caller can split the telemetry without a second pass.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# keep the hash bit-compatible with the jnp probe (core/feature_cache.py)
from ..core.feature_cache import (_HASH_K, VALID_ASSOC, WIRE_WORD_BITS,
                                  hit_bitmap_words)


def _shift_for(n_sets: int) -> int:
    """Hash shift for a power-of-two set count; 32 signals the degenerate
    single-set cache (a literal 32-bit shift would be out of range for
    uint32 — ``_sets_of`` short-circuits to set 0 instead, mirroring
    feature_cache.hash_slots).  Shared by both probe kernels so their
    hashes cannot silently diverge."""
    return 32 if n_sets == 1 else 32 - (int(n_sets).bit_length() - 1)


def _sets_of(ids, shift: int):
    """Set index of each id inside a kernel body (static ``shift``)."""
    if shift >= 32:
        return jnp.zeros(ids.shape, jnp.int32)
    h = ids.astype(jnp.uint32) * jnp.uint32(_HASH_K)
    return jax.lax.shift_right_logical(h, jnp.uint32(shift)).astype(jnp.int32)


def _probe_gather_kernel(keys_ref, rows_ref, ids_ref, hit_ref, out_ref,
                         *, shift: int, assoc: int):
    ids = ids_ref[...]                              # [br] int32
    sets = _sets_of(ids, shift)
    keys = keys_ref[...]
    rows = rows_ref[...]
    hit = jnp.zeros(ids.shape, jnp.bool_)
    out = jnp.zeros(ids.shape + (rows.shape[1],), out_ref.dtype)
    for j in range(assoc):                          # static unrolled ways
        slot = sets * assoc + j
        m = keys[slot] == ids                       # [br] bool
        out = jnp.where(m[:, None], rows[slot].astype(out_ref.dtype), out)
        hit = jnp.logical_or(hit, m)
    hit_ref[...] = hit
    out_ref[...] = out


def cache_probe_gather_pallas(
    keys: jax.Array,     # [C] int32 resident id per slot (-1 = empty)
    rows: jax.Array,     # [C, D] resident feature rows
    ids: jax.Array,      # [R] int32 probe ids
    *,
    assoc: int = 1,
    block_r: int = 256,
    block_d: int = 128,
    interpret: bool = True,
):
    """Probe ``ids`` against an ``assoc``-way cache: ``(hit [R], out [R, D])``.

    ``out`` rows are the cached copies where hit, zeros where missed —
    bit-identical to ``feature_cache.cache_probe`` (the jnp oracle is
    ``ref.cache_probe_gather_ref``).
    """
    c = keys.shape[0]
    if c & (c - 1):
        raise ValueError(f"cache size must be a power of two, got {c}")
    if assoc not in VALID_ASSOC or assoc > c:
        raise ValueError(f"assoc must be one of {VALID_ASSOC} and <= {c}, "
                         f"got {assoc}")
    n_sets = c // assoc
    r = ids.shape[0]
    d = rows.shape[1]
    br, bd = min(block_r, r), min(block_d, d)
    shift = _shift_for(n_sets)
    grid = (pl.cdiv(r, br), pl.cdiv(d, bd))
    return pl.pallas_call(
        functools.partial(_probe_gather_kernel, shift=shift, assoc=assoc),
        grid=grid,
        in_specs=[
            pl.BlockSpec((c,), lambda i, j: (0,)),        # full key vector
            pl.BlockSpec((c, bd), lambda i, j: (0, j)),   # VMEM column block
            pl.BlockSpec((br,), lambda i, j: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((br,), lambda i, j: (i,)),
            pl.BlockSpec((br, bd), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((r,), jnp.bool_),
            jax.ShapeDtypeStruct((r, d), rows.dtype),
        ],
        interpret=interpret,
    )(keys, rows, ids)


def _probe_compact_kernel(keys_ref, rows_ref, ids_ref, words_ref, raw_ref,
                          pay_ref, *, shift: int, assoc: int, hit_cap: int):
    ids = ids_ref[0, :]                             # [R] one destination
    sets = _sets_of(ids, shift)
    keys = keys_ref[...]
    rows = rows_ref[...]
    hit = jnp.zeros(ids.shape, jnp.bool_)
    way = jnp.zeros(ids.shape, jnp.int32)
    for j in range(assoc):                          # static unrolled ways
        m = jnp.logical_and(keys[sets * assoc + j] == ids, ~hit)
        way = jnp.where(m, jnp.int32(j), way)       # first-match way
        hit = jnp.logical_or(hit, m)
    # empty probe slots carry -1, which must not alias empty cache slots
    # (their resident key is also -1)
    hit = jnp.logical_and(hit, ids >= 0)
    # keep the first hit_cap hits in slot order; later hits are demoted
    cs = jnp.cumsum(hit.astype(jnp.int32))
    kept = jnp.logical_and(hit, cs <= hit_cap)
    # pack both vectors into bitmap words (bit s%32 of word s//32);
    # R is padded to a word multiple by the wrapper, so the reshape is
    # exact and pad slots (ids == -1) contribute zero bits.  ``kept`` is
    # the wire bitmap; ``hit`` (pre-demotion) stays on the holder as the
    # demotion/hit-peak telemetry — one probe serves both
    weight = jax.lax.shift_left(
        jnp.uint32(1), jnp.arange(WIRE_WORD_BITS, dtype=jnp.uint32))

    def pack(v):
        bits = v.reshape(-1, WIRE_WORD_BITS).astype(jnp.uint32)
        return jnp.sum(bits * weight, axis=-1, dtype=jnp.uint32)

    words_ref[0, :] = pack(kept)
    raw_ref[0, :] = pack(hit)
    # payload slot p <- the (p+1)-th hit's row: cs increments by 0/1, so
    # the first index with cs >= p+1 equals |{j : cs[j] <= p}| — a
    # comparison-matrix sum, no sort and no scatter on the accelerator
    p = jnp.arange(hit_cap, dtype=jnp.int32)
    sel = jnp.sum((cs[None, :] <= p[:, None]).astype(jnp.int32), axis=-1)
    sel = jnp.clip(sel, 0, ids.shape[0] - 1)
    pvalid = p < jnp.minimum(cs[-1], hit_cap)
    src = rows[sets[sel] * assoc + way[sel]].astype(pay_ref.dtype)
    pay_ref[0, :, :] = jnp.where(pvalid[:, None], src, 0)


def cache_probe_compact_pallas(
    keys: jax.Array,     # [C] int32 resident id per slot (-1 = empty)
    rows: jax.Array,     # [C, D] resident feature rows
    ids: jax.Array,      # [W, R] int32 probe ids, one row per destination
                         # (-1 = empty probe slot)
    *,
    assoc: int = 1,
    hit_cap: int = 1,
    block_d: int = 128,
    interpret: bool = True,
):
    """Fused probe + compact-wire encode for the shard-probe response.

    Probes every destination's [R] probe block against the ``assoc``-way
    cache and emits the compact wire format directly — ``(words
    [W, ceil(R/32)] uint32, raw_words [W, ceil(R/32)] uint32, payload
    [W, min(hit_cap, R), D])`` — without ever materializing the dense
    [W, R, D] response block (the point: the dense block is exactly what
    the compact wire exists to not ship).  ``words`` is the
    post-demotion bitmap that rides the wire; ``raw_words`` packs the
    PRE-demotion hits and stays on the holder (the
    ``n_probe_demoted``/``probe_hit_peak`` telemetry — emitting it from
    the same probe avoids a second keys pass).  Bit-identical to
    ``ref.cache_probe_compact_ref``; hits beyond ``hit_cap`` per
    destination are demoted (bit cleared, row dropped), matching the
    holder side of ``generation._shard_probe``.

    Grid: (W destinations, D blocks); the bitmap words are written once
    per D block (identical values — the same revisiting pattern the
    other kernels in this package use).  The [hit_cap, R] rank-selection
    compare lives in VMEM alongside the [C, block_d] row block; both are
    small by construction (``R`` is the probe capacity, a few thousand
    at most).
    """
    c = keys.shape[0]
    if c & (c - 1):
        raise ValueError(f"cache size must be a power of two, got {c}")
    if assoc not in VALID_ASSOC or assoc > c:
        raise ValueError(f"assoc must be one of {VALID_ASSOC} and <= {c}, "
                         f"got {assoc}")
    if ids.ndim != 2:
        raise ValueError(f"ids must be [W, R] (one row per destination), "
                         f"got shape {tuple(ids.shape)}")
    w, r = ids.shape
    if r < 1 or w < 1:
        raise ValueError(f"need at least one destination and one probe "
                         f"slot, got ids shape {tuple(ids.shape)}")
    hit_cap = min(hit_cap, r)
    if hit_cap < 1:
        raise ValueError("hit_cap must be >= 1 (a zero-row payload cannot "
                         "ship hits; use the dense wire to disable)")
    n_words = hit_bitmap_words(r)
    pad = n_words * WIRE_WORD_BITS - r
    if pad:
        # pad probe slots with the -1 sentinel so the in-kernel reshape
        # to [n_words, 32] is exact; pad bits can never hit
        ids = jnp.concatenate(
            [ids, jnp.full((w, pad), -1, ids.dtype)], axis=1)
    d = rows.shape[1]
    bd = min(block_d, d)
    shift = _shift_for(c // assoc)
    grid = (w, pl.cdiv(d, bd))
    return pl.pallas_call(
        functools.partial(_probe_compact_kernel, shift=shift, assoc=assoc,
                          hit_cap=hit_cap),
        grid=grid,
        in_specs=[
            pl.BlockSpec((c,), lambda i, j: (0,)),        # full key vector
            pl.BlockSpec((c, bd), lambda i, j: (0, j)),   # VMEM column block
            pl.BlockSpec((1, n_words * WIRE_WORD_BITS), lambda i, j: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, n_words), lambda i, j: (i, 0)),
            pl.BlockSpec((1, n_words), lambda i, j: (i, 0)),
            pl.BlockSpec((1, hit_cap, bd), lambda i, j: (i, 0, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((w, n_words), jnp.uint32),
            jax.ShapeDtypeStruct((w, n_words), jnp.uint32),
            jax.ShapeDtypeStruct((w, hit_cap, d), rows.dtype),
        ],
        interpret=interpret,
    )(keys, rows, ids)


def _probe_tiered_kernel(l1k_ref, l1r_ref, l2k_ref, l2r_ref, ids_ref,
                         src_ref, out_ref, *, shift1: int, shift2: int,
                         l1_assoc: int, l2_assoc: int):
    ids = ids_ref[...]                              # [br] int32
    sets1 = _sets_of(ids, shift1)
    sets2 = _sets_of(ids, shift2)
    src = jnp.zeros(ids.shape, jnp.int32)
    out = jnp.zeros(ids.shape + (l1r_ref.shape[1],), out_ref.dtype)
    # L2 first, then L1 overwrites — L1 takes priority on a double hit
    l2k = l2k_ref[...]
    l2r = l2r_ref[...]
    for j in range(l2_assoc):                       # static unrolled ways
        slot = sets2 * l2_assoc + j
        m = l2k[slot] == ids
        out = jnp.where(m[:, None], l2r[slot].astype(out_ref.dtype), out)
        src = jnp.where(m, jnp.int32(2), src)
    l1k = l1k_ref[...]
    l1r = l1r_ref[...]
    for j in range(l1_assoc):
        slot = sets1 * l1_assoc + j
        m = l1k[slot] == ids
        out = jnp.where(m[:, None], l1r[slot].astype(out_ref.dtype), out)
        src = jnp.where(m, jnp.int32(1), src)
    src_ref[...] = src
    out_ref[...] = out


def cache_probe_tiered_pallas(
    l1_keys: jax.Array,  # [C1] int32 L1 resident id per slot (-1 = empty)
    l1_rows: jax.Array,  # [C1, D] L1 resident feature rows
    l2_keys: jax.Array,  # [C2] int32 L2 resident id per slot
    l2_rows: jax.Array,  # [C2, D] L2 resident feature rows
    ids: jax.Array,      # [R] int32 probe ids
    *,
    l1_assoc: int = 1,
    l2_assoc: int = 1,
    block_r: int = 256,
    block_d: int = 128,
    interpret: bool = True,
):
    """Fused two-tier probe: ``(src [R] int32, out [R, D])``.

    ``src`` is 0 where both tiers miss, 1 where the L1 serves the id, 2
    where (only) the L2 does; ``out`` carries the serving tier's row copy,
    zeros on a miss.  Bit-identical to ``ref.cache_probe_tiered_ref`` and
    to ``feature_cache.tiered_probe``'s jnp path.
    """
    c1, c2 = l1_keys.shape[0], l2_keys.shape[0]
    for c, a, name in ((c1, l1_assoc, "l1"), (c2, l2_assoc, "l2")):
        if c & (c - 1):
            raise ValueError(f"{name} size must be a power of two, got {c}")
        if a not in VALID_ASSOC or a > c:
            raise ValueError(f"{name} assoc must be one of {VALID_ASSOC} "
                             f"and <= {c}, got {a}")
    if l1_rows.shape[1] != l2_rows.shape[1]:
        raise ValueError(f"tier row widths differ: {l1_rows.shape[1]} vs "
                         f"{l2_rows.shape[1]}")
    r = ids.shape[0]
    d = l2_rows.shape[1]
    br, bd = min(block_r, r), min(block_d, d)
    grid = (pl.cdiv(r, br), pl.cdiv(d, bd))
    return pl.pallas_call(
        functools.partial(_probe_tiered_kernel,
                          shift1=_shift_for(c1 // l1_assoc),
                          shift2=_shift_for(c2 // l2_assoc),
                          l1_assoc=l1_assoc, l2_assoc=l2_assoc),
        grid=grid,
        in_specs=[
            pl.BlockSpec((c1,), lambda i, j: (0,)),       # full L1 keys
            pl.BlockSpec((c1, bd), lambda i, j: (0, j)),  # L1 column block
            pl.BlockSpec((c2,), lambda i, j: (0,)),       # full L2 keys
            pl.BlockSpec((c2, bd), lambda i, j: (0, j)),  # L2 column block
            pl.BlockSpec((br,), lambda i, j: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((br,), lambda i, j: (i,)),
            pl.BlockSpec((br, bd), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((r,), jnp.int32),
            jax.ShapeDtypeStruct((r, d), l2_rows.dtype),
        ],
        interpret=interpret,
    )(l1_keys, l1_rows, l2_keys, l2_rows, ids)
