"""Fused Pallas probe+gather for the hot-node feature cache.

``gather_reduce_pallas`` serves already-sampled rows straight from the HBM
feature table; this kernel is its cache-tier sibling: it serves *cache
hits* from VMEM-tiled blocks of the device-resident cache
(core/feature_cache.py).  One kernel fuses the steps a jnp probe lowers to
separately —

  set     = top-bits multiplicative hash of each id        (VPU)
  ways    = static unrolled loop over the ``assoc`` slots of the set:
            hit_j = keys[set*assoc+j] == id                (VPU compare)
            row   = rows[set*assoc+j] masked by hit_j      (VMEM gather)

``assoc=1`` is the direct-mapped PR 2 kernel; 2/4-way sets probe their
ways in the same VMEM residency (the way loop is a compile-time constant,
so it unrolls — no dynamic control flow on the accelerator).

The cache is small by construction (``cache_rows`` is a few thousand), so
a whole [C, block_d] column block of the row table fits in VMEM alongside
the full [C] key vector — the gather never touches HBM, which is the point
of the cache tier.  Grid: (R blocks, D blocks); the hit vector is written
once per D block (identical values, same revisiting pattern the other
kernels in this package use).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# keep the hash bit-compatible with the jnp probe (core/feature_cache.py)
from ..core.feature_cache import _HASH_K, VALID_ASSOC


def _probe_gather_kernel(keys_ref, rows_ref, ids_ref, hit_ref, out_ref,
                         *, shift: int, assoc: int):
    ids = ids_ref[...]                              # [br] int32
    if shift >= 32:
        # single-set cache: a 32-bit shift on uint32 is out of range —
        # every id lives in set 0 (mirrors feature_cache.hash_slots)
        sets = jnp.zeros(ids.shape, jnp.int32)
    else:
        h = ids.astype(jnp.uint32) * jnp.uint32(_HASH_K)
        sets = jax.lax.shift_right_logical(
            h, jnp.uint32(shift)).astype(jnp.int32)
    keys = keys_ref[...]
    rows = rows_ref[...]
    hit = jnp.zeros(ids.shape, jnp.bool_)
    out = jnp.zeros(ids.shape + (rows.shape[1],), out_ref.dtype)
    for j in range(assoc):                          # static unrolled ways
        slot = sets * assoc + j
        m = keys[slot] == ids                       # [br] bool
        out = jnp.where(m[:, None], rows[slot].astype(out_ref.dtype), out)
        hit = jnp.logical_or(hit, m)
    hit_ref[...] = hit
    out_ref[...] = out


def cache_probe_gather_pallas(
    keys: jax.Array,     # [C] int32 resident id per slot (-1 = empty)
    rows: jax.Array,     # [C, D] resident feature rows
    ids: jax.Array,      # [R] int32 probe ids
    *,
    assoc: int = 1,
    block_r: int = 256,
    block_d: int = 128,
    interpret: bool = True,
):
    """Probe ``ids`` against an ``assoc``-way cache: ``(hit [R], out [R, D])``.

    ``out`` rows are the cached copies where hit, zeros where missed —
    bit-identical to ``feature_cache.cache_probe`` (the jnp oracle is
    ``ref.cache_probe_gather_ref``).
    """
    c = keys.shape[0]
    if c & (c - 1):
        raise ValueError(f"cache size must be a power of two, got {c}")
    if assoc not in VALID_ASSOC or assoc > c:
        raise ValueError(f"assoc must be one of {VALID_ASSOC} and <= {c}, "
                         f"got {assoc}")
    n_sets = c // assoc
    r = ids.shape[0]
    d = rows.shape[1]
    br, bd = min(block_r, r), min(block_d, d)
    # 32 signals the degenerate single-set cache to the kernel (a literal
    # 32-bit shift would be out of range for uint32)
    shift = 32 if n_sets == 1 else 32 - (int(n_sets).bit_length() - 1)
    grid = (pl.cdiv(r, br), pl.cdiv(d, bd))
    return pl.pallas_call(
        functools.partial(_probe_gather_kernel, shift=shift, assoc=assoc),
        grid=grid,
        in_specs=[
            pl.BlockSpec((c,), lambda i, j: (0,)),        # full key vector
            pl.BlockSpec((c, bd), lambda i, j: (0, j)),   # VMEM column block
            pl.BlockSpec((br,), lambda i, j: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((br,), lambda i, j: (i,)),
            pl.BlockSpec((br, bd), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((r,), jnp.bool_),
            jax.ShapeDtypeStruct((r, d), rows.dtype),
        ],
        interpret=interpret,
    )(keys, rows, ids)
