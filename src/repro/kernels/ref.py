"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` contract).

Each function here is the semantic ground truth; kernel tests sweep shapes
and dtypes and ``assert_allclose`` the Pallas output (interpret=True on this
CPU container; TPU is the compile target) against these.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def fanout_mean_ref(x: jax.Array, mask: jax.Array) -> jax.Array:
    """Masked mean over the fanout axis: x [M, K, D], mask [M, K] -> [M, D].

    The GCN aggregation step on a padded fanout tree (paper §3 model)."""
    m = mask.astype(x.dtype)
    num = jnp.einsum("mkd,mk->md", x, m)
    den = jnp.maximum(m.sum(axis=1, keepdims=True), 1.0)
    return num / den


def gather_reduce_ref(
    table: jax.Array, idx: jax.Array, mask: jax.Array
) -> jax.Array:
    """Gather rows then masked-mean: table [N, D], idx [M, K], mask [M, K]
    -> [M, D].  The fused per-worker hot spot of edge-centric collection +
    aggregation."""
    rows = table[jnp.clip(idx, 0, table.shape[0] - 1)]        # [M, K, D]
    return fanout_mean_ref(rows, mask)


def cache_probe_gather_ref(
    keys: jax.Array, rows: jax.Array, ids: jax.Array, assoc: int = 1
) -> tuple:
    """Set-associative cache probe: keys [C], rows [C, D], ids [R] ->
    (hit [R] bool, out [R, D]); out is the cached row where hit, zeros
    where missed.  Set ``s = hash(id) mod (C/assoc)`` owns the ``assoc``
    consecutive slots ``s*assoc + j``; ``assoc=1`` is the direct-mapped
    special case.  Semantic ground truth for the fused probe+gather kernel
    (and the shape the jnp probe in core/feature_cache.py takes)."""
    from ..core.feature_cache import hash_slots
    sets = hash_slots(ids, keys.shape[0] // assoc)
    slots = sets[:, None] * assoc + jnp.arange(assoc)[None, :]   # [R, A]
    match = keys[slots] == ids[:, None]
    hit = match.any(axis=-1)
    way = jnp.argmax(match, axis=-1)
    out = jnp.where(hit[:, None], rows[sets * assoc + way], 0)
    return hit, out


def cache_probe_compact_ref(
    keys: jax.Array, rows: jax.Array, ids: jax.Array,
    assoc: int = 1, hit_cap: int = 1,
) -> tuple:
    """Fused probe + compact-wire encode: keys [C], rows [C, D],
    ids [W, R] -> ``(words [W, ceil(R/32)] uint32, raw_words
    [W, ceil(R/32)] uint32, payload [W, hc, D])`` with
    ``hc = min(hit_cap, R)``.

    Per destination row ``w``: probe the ``assoc``-way cache exactly as
    ``cache_probe_gather_ref`` does (ids ``< 0`` never hit — they are the
    empty-probe-slot sentinel and must not alias empty cache slots, whose
    resident key is also -1), KEEP the first ``hit_cap`` hits in slot
    order (later hits are demoted to misses — their bits are cleared and
    their rows never enter the payload), pack the kept vector into uint32
    bitmap words (bit ``s % 32`` of word ``s // 32``), and gather the
    kept rows into the ``p``-th payload slot by hit rank, zeros beyond
    the kept count.  ``raw_words`` packs the PRE-demotion hit vector —
    the holder-side demotion/hit-peak telemetry.  Semantic ground truth
    for the fused probe+compact kernel (``cache_probe_compact_pallas``)
    and for the holder side of the compact shard-probe wire
    (``generation._shard_probe``)."""
    from ..core.feature_cache import compact_hit_rows, pack_hit_bitmap
    hit, out = jax.vmap(
        lambda i: cache_probe_gather_ref(keys, rows, i, assoc=assoc))(ids)
    hit = jnp.logical_and(hit, ids >= 0)
    out = jnp.where(hit[..., None], out, 0)
    kept, payload = compact_hit_rows(hit, out, hit_cap)
    return pack_hit_bitmap(kept), pack_hit_bitmap(hit), payload


def cache_probe_tiered_ref(
    l1_keys: jax.Array, l1_rows: jax.Array,
    l2_keys: jax.Array, l2_rows: jax.Array,
    ids: jax.Array, l1_assoc: int = 1, l2_assoc: int = 1,
) -> tuple:
    """Hierarchical two-tier cache probe: ``(src [R] int32, out [R, D])``.

    Probes the small replicated L1 and the local L2 block in one pass —
    the L1 takes priority on a double hit.  ``src`` reports the serving
    tier (0 = miss, 1 = L1, 2 = L2); ``out`` is the serving tier's row
    copy, zeros where both tiers miss.  Semantic ground truth for the
    fused tiered probe kernel (``cache_probe_tiered_pallas``) and the
    shape ``feature_cache.tiered_probe``'s jnp path takes."""
    l1_hit, l1_out = cache_probe_gather_ref(l1_keys, l1_rows, ids,
                                            assoc=l1_assoc)
    l2_hit, l2_out = cache_probe_gather_ref(l2_keys, l2_rows, ids,
                                            assoc=l2_assoc)
    src = jnp.where(l1_hit, 1, jnp.where(l2_hit, 2, 0)).astype(jnp.int32)
    out = jnp.where(l1_hit[:, None], l1_out,
                    jnp.where(l2_hit[:, None], l2_out, 0))
    return src, out


def flash_attention_ref(
    q: jax.Array,      # [B, Hq, Lq, Dh]
    k: jax.Array,      # [B, Hkv, Lk, Dh]
    v: jax.Array,      # [B, Hkv, Lk, Dh]
    causal: bool = True,
) -> jax.Array:
    """Exact softmax attention with GQA head grouping."""
    b, hq, lq, dh = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    qg = q.reshape(b, hkv, group, lq, dh)
    scale = 1.0 / jnp.sqrt(dh).astype(q.dtype)
    logits = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k) * scale
    if causal:
        lk = k.shape[2]
        qi = jnp.arange(lq)[:, None] + (lk - lq)   # align last q with last k
        ki = jnp.arange(lk)[None, :]
        logits = jnp.where(qi >= ki, logits, -jnp.inf)
    p = jax.nn.softmax(logits.astype(jnp.float32), axis=-1).astype(q.dtype)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", p, v)
    return out.reshape(b, hq, lq, dh)


def ssd_scan_ref(
    x: jax.Array,      # [B, L, H, P]
    dt: jax.Array,     # [B, L, H]        (post-softplus, > 0)
    a: jax.Array,      # [H]              (negative: decay log-rate)
    b_mat: jax.Array,  # [B, L, N]        (single group, broadcast over heads)
    c_mat: jax.Array,  # [B, L, N]
) -> jax.Array:
    """Mamba-2 SSD recurrence, exact sequential oracle:

        h_t = exp(a * dt_t) * h_{t-1} + dt_t * (b_t  outer  x_t)
        y_t = h_t @ c_t
    """
    bsz, l, h, p = x.shape
    n = b_mat.shape[-1]

    def step(h_state, inp):
        xt, dtt, bt, ct = inp                      # [B,H,P], [B,H], [B,N], [B,N]
        decay = jnp.exp(a[None, :] * dtt)          # [B, H]
        upd = dtt[..., None, None] * (
            xt[..., :, None] * bt[:, None, None, :]
        )                                           # [B, H, P, N]
        h_state = h_state * decay[..., None, None] + upd
        yt = jnp.einsum("bhpn,bn->bhp", h_state, ct)
        return h_state, yt

    h0 = jnp.zeros((bsz, h, p, n), x.dtype)
    xs = (
        jnp.moveaxis(x, 1, 0),
        jnp.moveaxis(dt, 1, 0),
        jnp.moveaxis(b_mat, 1, 0),
        jnp.moveaxis(c_mat, 1, 0),
    )
    _, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1)                  # [B, L, H, P]
