"""Pallas TPU kernels for the GCN aggregation hot spot.

Two kernels:

* ``fanout_mean``    — masked mean over the fanout axis of already-gathered
  features, x [M, K, D] -> [M, D].  Tiled (block_m x K x block_d) in VMEM;
  D blocks are 128-aligned for the VPU lanes.

* ``gather_reduce``  — fused gather + masked mean straight from the node
  feature table: table [N, D] stays in HBM (memory_space=ANY) and rows are
  pulled with per-row dynamic-slice DMAs — the TPU-native shape of the
  "collect edges for my seeds" inner loop (DESIGN.md §7).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU memory spaces; interpret mode tolerates their absence
    from jax.experimental.pallas import tpu as pltpu
    _ANY = pltpu.ANY
except Exception:  # pragma: no cover
    pltpu = None
    _ANY = None


def _fanout_mean_kernel(x_ref, mask_ref, o_ref):
    x = x_ref[...]                       # [bm, K, bd]
    m = mask_ref[...].astype(x.dtype)    # [bm, K]
    num = jnp.einsum("mkd,mk->md", x, m)
    den = jnp.maximum(m.sum(axis=1, keepdims=True), 1.0)
    o_ref[...] = num / den


def fanout_mean_pallas(
    x: jax.Array,
    mask: jax.Array,
    *,
    block_m: int = 128,
    block_d: int = 128,
    interpret: bool = True,
) -> jax.Array:
    m, k, d = x.shape
    bm, bd = min(block_m, m), min(block_d, d)
    grid = (pl.cdiv(m, bm), pl.cdiv(d, bd))
    return pl.pallas_call(
        _fanout_mean_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k, bd), lambda i, j: (i, 0, j)),
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bd), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, d), x.dtype),
        interpret=interpret,
    )(x, mask)


def _gather_reduce_kernel(table_ref, idx_ref, mask_ref, o_ref, *, k: int):
    """One (block_m, block_d) output tile; rows DMA'd from HBM one fanout
    slot at a time (k is small: the paper's fanouts are 40/20)."""
    idx = idx_ref[...]                    # [bm, k] int32
    msk = mask_ref[...]                   # [bm, k] bool
    bm = idx.shape[0]
    bd = o_ref.shape[1]
    jd = pl.program_id(1)

    def slot(kk, acc):
        def row(i, acc):
            r = idx[i, kk]
            vals = pl.load(
                table_ref, (pl.dslice(r, 1), pl.dslice(jd * bd, bd))
            )[0]                          # [bd] row DMA from HBM
            take = msk[i, kk].astype(vals.dtype)
            return acc.at[i].add(vals * take)
        return jax.lax.fori_loop(0, bm, row, acc)

    acc = jax.lax.fori_loop(0, k, slot, jnp.zeros(o_ref.shape, jnp.float32))
    den = jnp.maximum(msk.sum(axis=1, keepdims=True).astype(jnp.float32), 1.0)
    o_ref[...] = (acc / den).astype(o_ref.dtype)


def gather_reduce_pallas(
    table: jax.Array,
    idx: jax.Array,
    mask: jax.Array,
    *,
    block_m: int = 64,
    block_d: int = 128,
    interpret: bool = True,
) -> jax.Array:
    m, k = idx.shape
    n, d = table.shape
    bm, bd = min(block_m, m), min(block_d, d)
    grid = (pl.cdiv(m, bm), pl.cdiv(d, bd))
    table_spec = (
        pl.BlockSpec(memory_space=_ANY)
        if _ANY is not None
        else pl.BlockSpec((n, d), lambda i, j: (0, 0))
    )
    return pl.pallas_call(
        functools.partial(_gather_reduce_kernel, k=k),
        grid=grid,
        in_specs=[
            table_spec,
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bd), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, d), table.dtype),
        interpret=interpret,
    )(table, jnp.clip(idx, 0, n - 1), mask)
