"""Chunked Mamba-2 SSD scan as a Pallas TPU kernel.

State-space duality (arXiv:2405.21060) splits the recurrence into
(a) an intra-chunk quadratic part — dense (Q x Q) and (Q x P) matmuls that
feed the MXU, and (b) an inter-chunk state carry — a [P, N] VMEM scratch
passed along the sequential innermost grid dimension.  Chunk length 128
keeps every matmul MXU-shaped.

    y[i] = sum_{j<=i} (c_i . b_j) exp(cum[i]-cum[j]) dt[j] x[j]   (intra)
         + (c_i . state_prev) exp(cum[i])                         (inter)
    state' = state_prev * exp(cum[Q-1]) + sum_j exp(cum[Q-1]-cum[j]) dt[j] x[j] b_j^T
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
except Exception:  # pragma: no cover
    pltpu = None


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, o_ref, state_ref, *, q: int):
    c_idx = pl.program_id(2)

    @pl.when(c_idx == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, :, 0, :].astype(jnp.float32)       # [Q, P]
    dt = dt_ref[0, :, 0].astype(jnp.float32)        # [Q]
    a = a_ref[0].astype(jnp.float32)                # scalar
    b = b_ref[0].astype(jnp.float32)                # [Q, N]
    c = c_ref[0].astype(jnp.float32)                # [Q, N]

    adt = a * dt                                    # [Q] (negative)
    cum = jnp.cumsum(adt)                           # [Q] inclusive
    # intra-chunk: masked decay matrix L[i, j] = exp(cum[i]-cum[j]) dt[j], j <= i
    seg = cum[:, None] - cum[None, :]
    ii = jax.lax.iota(jnp.int32, q)
    tri = ii[:, None] >= ii[None, :]
    l_mat = jnp.where(tri, jnp.exp(seg) * dt[None, :], 0.0)
    scores = (c @ b.T) * l_mat                      # [Q, Q]
    y = scores @ x                                  # [Q, P]
    # inter-chunk contribution from carried state
    state = state_ref[...]                          # [P, N]
    y += (c * jnp.exp(cum)[:, None]) @ state.T      # [Q, P]
    # state update
    total = jnp.exp(cum[q - 1])
    w = dt * jnp.exp(cum[q - 1] - cum)              # [Q]
    state_ref[...] = state * total + (x * w[:, None]).T @ b
    o_ref[0, :, 0, :] = y.astype(o_ref.dtype)


def ssd_scan_pallas(
    x: jax.Array,      # [B, L, H, P]
    dt: jax.Array,     # [B, L, H]
    a: jax.Array,      # [H]
    b_mat: jax.Array,  # [B, L, N]
    c_mat: jax.Array,  # [B, L, N]
    *,
    chunk: int = 128,
    interpret: bool = True,
) -> jax.Array:
    bsz, l, h, p = x.shape
    n = b_mat.shape[-1]
    q = min(chunk, l)
    assert l % q == 0, "pad seq len to chunk multiple"
    grid = (bsz, h, pl.cdiv(l, q))
    kwargs = {}
    if pltpu is not None and not interpret:
        kwargs["compiler_params"] = pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        )
    return pl.pallas_call(
        functools.partial(_ssd_kernel, q=q),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, q, 1, p), lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, q, 1), lambda bi, hi, ci: (bi, ci, hi)),
            pl.BlockSpec((1,), lambda bi, hi, ci: (hi,)),
            pl.BlockSpec((1, q, n), lambda bi, hi, ci: (bi, ci, 0)),
            pl.BlockSpec((1, q, n), lambda bi, hi, ci: (bi, ci, 0)),
        ],
        out_specs=pl.BlockSpec((1, q, 1, p), lambda bi, hi, ci: (bi, ci, hi, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, l, h, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)] if pltpu else [None],
        interpret=interpret,
        **kwargs,
    )(x, dt, a, b_mat, c_mat)
