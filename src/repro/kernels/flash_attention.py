"""Blocked (flash) attention Pallas kernel for the LM-family architectures.

Streaming-softmax attention tiled for VMEM: q blocks (block_q x head_dim)
stay resident while k/v blocks (block_k x head_dim) stream through the
innermost sequential grid dimension, with running (max, denom, accum)
scratch carried across k blocks.  GQA is handled in the BlockSpec index
maps (query head h reads kv head h // group — no materialized repeat).
Causal q/k block pairs that are entirely masked are skipped with
``pl.when`` (no FLOPs, no DMA use).

MXU alignment: block_q = block_k = 128 by default; head_dim is the matmul
contraction and is 64/128 for every assigned arch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
except Exception:  # pragma: no cover
    pltpu = None

_NEG = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
    *, causal: bool, lq: int, lk: int, block_q: int, block_k: int, scale: float
):
    iq = pl.program_id(1)
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_rows = iq * block_q + jax.lax.iota(jnp.int32, block_q) + (lk - lq)
    k_cols = ik * block_k + jax.lax.iota(jnp.int32, block_k)
    # skip fully-masked causal blocks: first q row < first k col of block
    run = (not causal) or (iq * block_q + block_q - 1 + (lk - lq)) >= ik * block_k

    @pl.when(run)
    def _compute():
        q = q_ref[0].astype(jnp.float32)            # [bq, dh]
        k = k_ref[0].astype(jnp.float32)            # [bk, dh]
        v = v_ref[0].astype(jnp.float32)
        s = (q @ k.T) * scale                       # [bq, bk]
        if causal:
            mask = q_rows[:, None] >= k_cols[None, :]
            s = jnp.where(mask, s, _NEG)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        alpha = jnp.exp(m_prev - m_new)             # finite: m >= _NEG
        p = jnp.exp(s - m_new[:, None])
        l_ref[...] = alpha * l_ref[...] + p.sum(axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + p @ v
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        den = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0] = (acc_ref[...] / den).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array,   # [B, Hq, Lq, Dh]
    k: jax.Array,   # [B, Hkv, Lk, Dh]
    v: jax.Array,
    *,
    causal: bool = True,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jax.Array:
    b, hq, lq, dh = q.shape
    hkv, lk = k.shape[1], k.shape[2]
    group = hq // hkv
    bq, bk = min(block_q, lq), min(block_k, lk)
    assert lq % bq == 0 and lk % bk == 0, "pad seq lens to block multiples"
    qf = q.reshape(b * hq, lq, dh)
    kf = k.reshape(b * hkv, lk, dh)
    vf = v.reshape(b * hkv, lk, dh)
    grid = (b * hq, pl.cdiv(lq, bq), pl.cdiv(lk, bk))

    def kv_index(h, iq, ik):
        # query head h -> kv head (h % hq) // group within the same batch
        bi = h // hq
        return (bi * hkv + (h % hq) // group, ik, 0)

    kwargs = {}
    if pltpu is not None and not interpret:
        kwargs["compiler_params"] = pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        )
    out = pl.pallas_call(
        functools.partial(
            _flash_kernel,
            causal=causal, lq=lq, lk=lk,
            block_q=bq, block_k=bk, scale=1.0 / (dh ** 0.5),
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, dh), lambda h, iq, ik: (h, iq, 0)),
            pl.BlockSpec((1, bk, dh), kv_index),
            pl.BlockSpec((1, bk, dh), kv_index),
        ],
        out_specs=pl.BlockSpec((1, bq, dh), lambda h, iq, ik: (h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, lq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, dh), jnp.float32) if pltpu else None,
            pltpu.VMEM((bq,), jnp.float32) if pltpu else None,
            pltpu.VMEM((bq,), jnp.float32) if pltpu else None,
        ],
        interpret=interpret,
        **kwargs,
    )(qf, kf, vf)
    return out.reshape(b, hq, lq, dh)
