"""Baseline subgraph-generation strategies the paper compares against (§3).

1. ``sql_like_sample``   — the "traditional SQL-like" method: each hop is a
   relational JOIN of the frontier against the full edge table, with no
   adjacency index.  Cost O(F x E) per hop (a broadcast compare / one-hot
   contraction), which is why the paper reports a 27x win over it.

2. ``node_centric_sample`` — AGL's node-centric MapReduce paradigm: each
   frontier node's neighbor list is collected *serially* (a fori_loop over
   its full degree).  Hot nodes serialize — the exact bottleneck GraphGen+'s
   edge-centric scan removes.

3. The *offline GraphGen* baseline (precompute all subgraphs, round-trip
   them through storage, then train) is a driver pattern, not a sampler —
   see ``benchmarks/pipeline_overlap.py`` and ``core.pipeline.offline_loop``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def sql_like_sample(
    edge_src: jax.Array,   # [E]
    edge_dst: jax.Array,   # [E]
    frontier: jax.Array,   # [F]
    k: int,
    rng: jax.Array,
) -> tuple[jax.Array, jax.Array]:
    """JOIN frontier x edges with no index: for every (frontier, edge) pair
    test ``edge.src == frontier.node``; rank matches by random priority and
    keep k.  Returns (ids [F,k], mask [F,k])."""
    e = edge_src.shape[0]
    pri = jax.random.uniform(rng, (e,), minval=1e-6)

    def per_node(v):
        match = edge_src == v                       # full edge-table scan
        score = jnp.where(match, pri, -jnp.inf)
        top, idx = lax.top_k(score, k)              # O(E log k)
        return edge_dst[idx], jnp.isfinite(top)

    ids, mask = jax.vmap(per_node)(frontier)
    return ids.astype(jnp.int32), mask


def node_centric_sample(
    indptr: jax.Array,
    indices: jax.Array,
    frontier: jax.Array,
    k: int,
    rng: jax.Array,
    max_degree: int,
) -> tuple[jax.Array, jax.Array]:
    """AGL-style: every frontier node walks its neighbor list one edge at a
    time (serial reservoir sampling up to ``max_degree`` steps).  The loop
    bound is the *maximum* degree, so one hot node stalls the whole batch —
    the behaviour the paper attributes AGL's bottleneck to."""
    f = frontier.shape[0]
    node = jnp.clip(frontier, 0, indptr.shape[0] - 2)
    start = indptr[node]
    deg = indptr[node + 1] - start

    def per_node(s, d, key):
        def body(i, state):
            res, key = state
            key, sub = jax.random.split(key)
            nbr = indices[jnp.clip(s + i, 0, indices.shape[0] - 1)]
            active = i < d
            # serial reservoir: position i replaces slot j ~ U[0, i] if j < k
            j = jax.random.randint(sub, (), 0, jnp.maximum(i + 1, 1))
            take = jnp.logical_and(active, jnp.logical_or(i < k, j < k))
            slot = jnp.where(i < k, i, j)
            res = lax.cond(
                take, lambda r: r.at[slot].set(nbr), lambda r: r, res
            )
            return res, key
        res = jnp.zeros((k,), jnp.int32)
        res, _ = lax.fori_loop(0, max_degree, body, (res, key))
        valid = jnp.arange(k) < jnp.minimum(d, k)
        return res, valid

    keys = jax.random.split(rng, f)
    ids, mask = jax.vmap(per_node)(start, deg, keys)
    return ids, mask


def edge_centric_sample(
    indptr: jax.Array,
    indices: jax.Array,
    frontier: jax.Array,
    k: int,
    rng: jax.Array,
) -> tuple[jax.Array, jax.Array]:
    """GraphGen+'s sampler, single-partition form: a pure parallel gather
    over the edge array (all F x k draws independent)."""
    from .generation import local_candidates

    cand = local_candidates(indptr, indices, frontier, k, rng)
    return jnp.where(jnp.isfinite(cand.keys), cand.ids, 0), jnp.isfinite(cand.keys)
