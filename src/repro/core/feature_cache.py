"""Device-resident hot-node feature cache (beyond-paper scaling lever).

PR 1's request-deduplicated shuffle collapses duplicate ids *within* one
iteration; on power-law graphs the same hot nodes recur *across*
iterations, so their rows cross the interconnect every step anyway.
DistDGL's locality-aware node placement and GraphScale's feature-store
caching exploit exactly this recurrence — here it becomes an explicit,
static-shape cache that sits in front of the routed ``all_to_all`` feature
shuffle (``generation.fetch_rows``):

  probe  — direct-mapped by multiplicative hash: node ``i`` can only live
           in slot ``hash(i) mod C``, so a probe is one gather + compare
           (no associative search, XLA-friendly static shapes).
  route  — only cache *misses* enter the all_to_all; hits are served from
           the device-resident copy, bit-identical to the owner's row
           (rows are immutable node features).
  insert — frequency admission: a missed id must be seen ``admit`` times
           at its slot (tracked by a candidate tag + counter, TinyLFU
           style) before it evicts the resident — one-off tail ids from
           the Zipf tail never displace hot rows.

The cache is **per-worker replicated state**: every worker keeps its own
[C] keys + [C, D] rows, threaded *functionally* through the generation
step (shard_map worker takes and returns it), the pipelined step (the
carry becomes ``(params, opt_state, batch, cache)``) and the launchers.
No mutation, no host round-trip: the state lives in device memory across
iterations exactly like optimizer state.

Invariant the tests pin down: a cached fetch returns **bit-identical**
rows to an uncached fetch — cached rows are verbatim copies of previously
fetched table rows, and features are immutable during an epoch.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# Knuth multiplicative hash constant (2^32 / phi); with a power-of-two
# cache we keep the TOP log2(C) bits of id * K, which are the well-mixed
# ones for multiplicative hashing.
_HASH_K = np.uint32(2654435761)


class CacheConfig(NamedTuple):
    """Static (python-int) cache policy knobs, safe to close over in jit."""
    n_rows: int          # cache slots, power of two (0 disables)
    admit: int = 2       # misses at a slot before a candidate is installed


class FeatureCache(NamedTuple):
    """One worker's cache state — an explicit pytree, threaded functionally.

    keys    [C]     int32  resident node id per slot (-1 = empty)
    rows    [C, D]  float  resident feature rows (bit-exact table copies)
    tags    [C]     int32  candidate id awaiting admission (-1 = none)
    counts  [C]     int32  consecutive-miss count for the candidate
    """
    keys: jax.Array
    rows: jax.Array
    tags: jax.Array
    counts: jax.Array

    @property
    def n_rows(self) -> int:
        return self.keys.shape[-1]


class CacheStats(NamedTuple):
    """Telemetry from one cached fetch (per-worker scalars)."""
    n_hits: jax.Array        # unique probes served from the cache
    n_misses: jax.Array      # unique probes routed over the wire
    n_inserted: jax.Array    # rows admitted this fetch
    bytes_saved: jax.Array   # wire bytes the hits did not cross


def hash_slots(ids: jax.Array, n_rows: int) -> jax.Array:
    """Direct-mapped slot of each id: top bits of the multiplicative hash."""
    if n_rows & (n_rows - 1):
        raise ValueError(f"cache n_rows must be a power of two, got {n_rows}")
    shift = 32 - int(n_rows).bit_length() + 1      # keep log2(n_rows) bits
    h = ids.astype(jnp.uint32) * _HASH_K
    return jax.lax.shift_right_logical(h, jnp.uint32(shift)).astype(jnp.int32)


def init_cache(n_rows: int, dim: int, dtype=jnp.float32) -> FeatureCache:
    """Empty single-worker cache state."""
    return FeatureCache(
        keys=jnp.full((n_rows,), -1, jnp.int32),
        rows=jnp.zeros((n_rows, dim), dtype),
        tags=jnp.full((n_rows,), -1, jnp.int32),
        counts=jnp.zeros((n_rows,), jnp.int32),
    )


def init_worker_caches(n_rows: int, dim: int, n_workers: int,
                       dtype=np.float32) -> FeatureCache:
    """Host-side [W, ...] stack of empty per-worker caches (for device_put
    with a ``P(axis)`` sharding — each worker owns one replica)."""
    return FeatureCache(
        keys=np.full((n_workers, n_rows), -1, np.int32),
        rows=np.zeros((n_workers, n_rows, dim), dtype),
        tags=np.full((n_workers, n_rows), -1, np.int32),
        counts=np.zeros((n_workers, n_rows), np.int32),
    )


def cache_specs(n_rows: int, dim: int, n_workers: int = 1,
                dtype=jnp.float32) -> FeatureCache:
    """ShapeDtypeStruct stand-ins for a [W, ...] cache (dry-run input)."""
    s = jax.ShapeDtypeStruct
    return FeatureCache(
        keys=s((n_workers, n_rows), jnp.int32),
        rows=s((n_workers, n_rows, dim), dtype),
        tags=s((n_workers, n_rows), jnp.int32),
        counts=s((n_workers, n_rows), jnp.int32),
    )


#: probe implementation every cached fetch uses when the caller does not
#: pick one explicitly — "jnp" (gather+compare, the XLA path) or "pallas"
#: (the fused VMEM probe+gather kernel; native on TPU, interpreted here).
_PROBE_IMPL = "jnp"


def set_probe_impl(impl: str) -> None:
    """Select the probe implementation for cached fetches (launcher knob —
    e.g. ``train.py --cache-probe-impl pallas``).

    The setting is read at TRACE time: call it before the cached fetch is
    first jitted — already-compiled executables keep the probe they were
    traced with (the launchers set it before building any generator)."""
    global _PROBE_IMPL
    if impl not in ("jnp", "pallas"):
        raise ValueError(f"probe impl must be 'jnp' or 'pallas', got {impl!r}")
    _PROBE_IMPL = impl


def cache_probe(
    cache: FeatureCache,
    ids: jax.Array,
    valid: Optional[jax.Array] = None,
    impl: Optional[str] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Probe [R] ids: ``(hit [R] bool, rows [R, D])`` (zeros where missed).

    ``impl`` defaults to the module setting (``set_probe_impl``);
    ``"pallas"`` routes through the fused VMEM-tiled probe+gather kernel
    (kernels/cache_gather.py, platform-dispatched via kernels/ops.py); the
    ``"jnp"`` path lowers to the same gather+compare.
    """
    if (impl or _PROBE_IMPL) == "pallas":
        from ..kernels.ops import cache_probe_gather
        hit, rows = cache_probe_gather(cache.keys, cache.rows, ids,
                                       use_kernel=True)
    else:
        slot = hash_slots(ids, cache.n_rows)
        hit = cache.keys[slot] == ids
        rows = jnp.where(hit[:, None], cache.rows[slot], 0)
    if valid is not None:
        hit = jnp.logical_and(hit, valid)
        rows = jnp.where(hit[:, None], rows, 0)
    return hit, rows


def cache_insert(
    cache: FeatureCache,
    ids: jax.Array,
    rows: jax.Array,
    should: jax.Array,
    admit: int = 2,
) -> Tuple[FeatureCache, jax.Array]:
    """Offer [R] fetched rows to the cache; returns (new_cache, n_inserted).

    ``should`` masks the offers (missed AND actually served — a
    capacity-dropped zero row must never be cached).  Admission: a
    candidate id is installed once its per-slot counter reaches ``admit``
    (``admit <= 1`` degrades to always-insert).  Distinct ids colliding on
    one slot within a single batch are resolved to ONE winner (highest
    request index) *before* any scatter: the state is four arrays updated
    by four scatters, and duplicate scatter indices apply in unspecified
    order per scatter — without a pre-resolved winner, ``keys[s]`` could
    take id A while ``rows[s]`` takes B's row and every later probe of A
    would silently return B's features.
    """
    c = cache.n_rows
    r = ids.shape[0]
    slot = hash_slots(ids, c)
    # one deterministic winner per slot among the offers (max-combiner
    # scatter is order-independent); only the winner touches the slot
    idx = jnp.arange(r, dtype=jnp.int32)
    win = jnp.full((c,), -1, jnp.int32).at[
        jnp.where(should, slot, c)].max(idx, mode="drop")
    offer = jnp.logical_and(should, win[slot] == idx)
    same_cand = cache.tags[slot] == ids
    new_count = jnp.where(same_cand, cache.counts[slot] + 1, 1)
    install = jnp.logical_and(offer, new_count >= admit)
    # not-selected offers scatter OUT OF BOUNDS so mode="drop" discards them
    s_track = jnp.where(offer, slot, c)
    s_install = jnp.where(install, slot, c)
    new = FeatureCache(
        keys=cache.keys.at[s_install].set(ids, mode="drop"),
        rows=cache.rows.at[s_install].set(rows.astype(cache.rows.dtype),
                                          mode="drop"),
        tags=cache.tags.at[s_track].set(ids, mode="drop"),
        counts=cache.counts.at[s_track].set(new_count, mode="drop"),
    )
    return new, jnp.sum(install).astype(jnp.int32)


def squeeze_worker_axis(cache: FeatureCache) -> FeatureCache:
    """[1, ...] shard_map block -> per-worker [...] state."""
    return jax.tree.map(lambda a: a[0], cache)


def restore_worker_axis(cache: FeatureCache) -> FeatureCache:
    """Per-worker [...] state -> [1, ...] shard_map block."""
    return jax.tree.map(lambda a: a[None], cache)
