"""Device-resident hot-node feature cache (beyond-paper scaling lever).

PR 1's request-deduplicated shuffle collapses duplicate ids *within* one
iteration; on power-law graphs the same hot nodes recur *across*
iterations, so their rows cross the interconnect every step anyway.
DistDGL's locality-aware node placement and GraphScale's feature-store
caching exploit exactly this recurrence — here it becomes an explicit,
static-shape cache that sits in front of the routed ``all_to_all`` feature
shuffle (``generation.fetch_rows``):

  probe  — set-associative by multiplicative hash: node ``i`` can only
           live in set ``hash(i) mod S`` and one of its ``assoc`` ways, so
           a probe is ``assoc`` gathers + compares (no unbounded
           associative search, XLA-friendly static shapes).  ``assoc=1``
           is the direct-mapped PR 2 layout; 2/4-way sets recover the
           ~1/3 of hot ids that direct mapping loses to balls-in-bins
           slot collisions at load factor 1.
  route  — only cache *misses* enter the all_to_all; hits are served from
           the device-resident copy, bit-identical to the owner's row
           (rows are immutable node features).
  insert — frequency admission: a missed id must be seen ``admit`` times
           at its set (tracked by a candidate tag + counter, TinyLFU
           style) before it evicts a resident — one-off tail ids from
           the Zipf tail never displace hot rows.  With ``assoc > 1``
           the admission counter doubles as the victim policy: a new
           candidate lands in the way with the smallest counter (empty
           ways first), so the most-contended candidates keep their
           progress toward admission.

Three placement modes (``CacheConfig.mode``):

  "replicated" — the PR 2 behavior: every worker caches its OWN request
           stream; total distinct capacity stays ~C no matter how many
           workers join (all replicas converge on the same Zipf head).
  "sharded" — the cache id-space is partitioned across the worker axis:
           worker ``shard_of(id, W)`` is the authoritative shard for
           ``id``, so total capacity grows to W*C distinct rows.  The
           fetch front end gains a second routing stage (one all_to_all
           probe round to the shard holders) — see
           ``generation.fetch_rows``.  The shard hash uses a DIFFERENT
           multiplicative mixer than the set hash so shard routing and
           in-cache set indices stay independent (with a shared mixer,
           the ids landing on one shard would collapse onto a fraction
           of its sets).
  "tiered" — hierarchical composition of the two: a SMALL replicated L1
           (``l1_rows`` slots, direct-mapped or 2-way — the global Zipf
           head) sits in front of the sharded L2 (``n_rows`` slots per
           worker).  The L1 probe is local — a hit costs ZERO network,
           not even the shard-probe round a sharded hit pays — and only
           L1 misses enter the probe round, so the probe round's wire
           bytes shrink by the L1 hit fraction.  Rows migrate L2 -> L1
           by frequency: every row the L2 tier SERVES a worker is
           OFFERED to that worker's local L1 and installs only after
           ``l1_promote`` observations — the hottest rows therefore
           reach every worker's L1 without any broadcast, because every
           worker keeps observing them (owner-fetched rows are not
           offered: they missed both tiers, and the cold tail must not
           churn the small L1's admission tags).  The tiered state is
           the ``TieredCache`` pytree ``(l1, l2)`` of two
           ``FeatureCache``s.

Two probe-round wire formats (``CacheConfig.wire``, sharded/tiered at
W > 1): **dense** ships the full ``[W, cap, D]`` row block back from the
shard holders even though only hit slots carry data; **compact** (the
default) ships a packed hit bitmap plus a row payload bounded by
``hit_cap`` rows per destination — stage-1 bytes then scale with hits
instead of probe capacity.  The codec lives here
(``pack_hit_bitmap``/``unpack_hit_bitmap``,
``compact_hit_rows``/``expand_hit_rows``); the routing that uses it is
``generation._shard_probe``, and docs/ARCHITECTURE.md has the per-mode
byte table.

The cache is **per-worker state**: every worker keeps its own [C] keys +
[C, D] rows, threaded *functionally* through the generation step
(shard_map worker takes and returns it), the pipelined step (the carry
becomes ``(params, opt_state, batch, cache)``) and the launchers.  No
mutation, no host round-trip: the state lives in device memory across
iterations exactly like optimizer state.

Invariant the tests pin down: a cached fetch returns **bit-identical**
rows to an uncached fetch — cached rows are verbatim copies of previously
fetched table rows, and features are immutable during an epoch.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# Knuth multiplicative hash constant (2^32 / phi); with a power-of-two
# set count we keep the TOP log2(S) bits of id * K, which are the
# well-mixed ones for multiplicative hashing.
_HASH_K = np.uint32(2654435761)
# murmur3 fmix multiplier for the cache-SHARD routing hash — deliberately
# a different mixer than ``_HASH_K`` so a shard's resident ids still
# spread over all of its sets (see module docstring).
_SHARD_K = np.uint32(0x85EBCA6B)

# single source of truth for the allowed policy values lives in the
# jax-free config module (ModelConfig validates against the same tuples);
# re-exported here under the names the kernels import
from .config import (VALID_CACHE_ASSOC as VALID_ASSOC,
                     VALID_CACHE_MODES as VALID_MODES,
                     VALID_CACHE_WIRES as VALID_WIRES,
                     VALID_FEATURE_STORES as VALID_STORES)


class CacheConfig(NamedTuple):
    """Static (python-int/str) cache policy knobs, safe to close over in
    jit — THE single source of cache policy, built once from
    ``ModelConfig`` (``CacheConfig.from_model``) and threaded through
    ``fetch_rows`` / ``_worker_generate`` / the launchers."""
    n_rows: int          # main-tier cache slots (the L2 in tiered mode),
                         # power of two (0 disables)
    admit: int = 2       # misses at a set before a candidate is installed
    assoc: int = 1       # ways per set (1 = direct-mapped), in VALID_ASSOC
    mode: str = "replicated"   # "replicated" | "sharded" | "tiered"
                               # (see module doc)
    l1_rows: int = 0     # tiered mode only: replicated L1 slots per
                         # worker, power of two (the global Zipf head —
                         # total device rows become l1_rows + n_rows)
    l1_promote: int = 3  # tiered mode only: observations of a row before
                         # it is promoted into this worker's L1
    wire: str = "compact"      # shard-probe response wire format,
                               # "dense" | "compact" (see module doc; only
                               # meaningful where a probe round runs —
                               # sharded/tiered modes at W > 1)
    hit_cap: int = 0     # compact wire only: per-destination row-payload
                         # slots of the probe response (0 = auto: half the
                         # probe capacity).  Hits beyond the bound are
                         # DEMOTED to misses by the shard holder — they
                         # fall through to the owner fetch, a lost hit
                         # opportunity but never a correctness loss.
    store: str = "device"      # where cache MISSES resolve: "device" pays
                               # the routed owner fetch against the
                               # device-resident table; "host" stages them
                               # for the L3 host-RAM store's async gather
                               # (core/host_store.py) — the step's output
                               # then carries a HostMissRequest and the
                               # rows land one step later
    frozen: bool = False       # read-mostly SERVE view: probes serve hits
                               # as usual but the admit stage is the
                               # identity — no admission, no L1 promotion,
                               # no tag/counter churn — so a pre-warmed
                               # cache state is bit-stable across requests
                               # and the admission collectives vanish from
                               # the request path.  Built via serve_view().

    @property
    def n_sets(self) -> int:
        """Hash sets of the main tier: ``n_rows // assoc`` (set ``s``
        owns the ``assoc`` consecutive slots starting at ``s * assoc``)."""
        return self.n_rows // self.assoc

    @property
    def l1_assoc(self) -> int:
        """L1 ways per set: direct-mapped, or 2-way when the L2 is
        set-associative (a tiny head cache gains nothing from 4 ways —
        it holds far fewer distinct ids than its set count collides)."""
        return 1 if self.assoc == 1 else 2

    def l1_config(self) -> "CacheConfig":
        """The L1 tier as a standalone replicated policy: the probe/insert
        state machine is tier-agnostic, so the L1 reuses it verbatim with
        ``l1_promote`` as the admission threshold (promotion IS frequency
        admission — a row installs after ``l1_promote`` observations)."""
        return CacheConfig(n_rows=self.l1_rows, admit=self.l1_promote,
                           assoc=self.l1_assoc, mode="replicated",
                           frozen=self.frozen)

    def l2_config(self) -> "CacheConfig":
        """The L2 tier as a standalone sharded policy (the pre-tiered
        sharded cache, unchanged); the wire format travels with it —
        the L2's probe round is the one the codec compacts."""
        return CacheConfig(n_rows=self.n_rows, admit=self.admit,
                           assoc=self.assoc, mode="sharded",
                           wire=self.wire, hit_cap=self.hit_cap,
                           store=self.store, frozen=self.frozen)

    def serve_view(self) -> "CacheConfig":
        """The read-mostly serve view of this policy: same slot layout
        (so a cache state warmed under ``self`` probes correctly), but
        ``frozen=True`` — the admit stage becomes the identity, and
        misses resolve against the device table (``store="device"``;
        serving never defers rows through the L3 staging path).  This is
        the config the serving tier compiles its bucket ladder under."""
        return self._replace(frozen=True, store="device").validated()

    def validated(self) -> "CacheConfig":
        """Self after strict cross-field validation (raises ``ValueError``
        on any inconsistent policy — e.g. a non-power-of-two tier size,
        an L1 knob outside tiered mode, or an unknown wire format).
        Call it wherever a ``CacheConfig`` is final; ``from_model``
        already does."""
        if self.n_rows <= 0:
            raise ValueError(f"cache n_rows must be > 0, got {self.n_rows}")
        if self.n_rows & (self.n_rows - 1):
            raise ValueError(
                f"cache n_rows must be a power of two, got {self.n_rows}")
        if self.assoc not in VALID_ASSOC:
            raise ValueError(
                f"cache assoc must be one of {VALID_ASSOC}, got {self.assoc}")
        if self.assoc > self.n_rows:
            raise ValueError(
                f"cache assoc {self.assoc} exceeds n_rows {self.n_rows}")
        if self.mode not in VALID_MODES:
            raise ValueError(
                f"cache mode must be one of {VALID_MODES}, got {self.mode!r}")
        if self.mode == "tiered":
            if self.l1_rows <= 0:
                raise ValueError("tiered mode requires l1_rows > 0 "
                                 f"(got {self.l1_rows})")
            if self.l1_rows & (self.l1_rows - 1):
                raise ValueError(f"l1_rows must be a power of two, "
                                 f"got {self.l1_rows}")
            if self.l1_rows > self.n_rows:
                raise ValueError(
                    f"l1_rows {self.l1_rows} exceeds the L2's n_rows "
                    f"{self.n_rows} — the L1 is the SMALL head tier")
            if self.l1_assoc > self.l1_rows:
                raise ValueError(
                    f"l1_rows {self.l1_rows} cannot hold {self.l1_assoc} ways")
            if self.l1_promote < 1:
                raise ValueError(
                    f"l1_promote must be >= 1, got {self.l1_promote}")
        elif self.l1_rows:
            raise ValueError(
                f"l1_rows is a tiered-mode knob; mode is {self.mode!r}")
        if self.wire not in VALID_WIRES:
            raise ValueError(
                f"cache wire must be one of {VALID_WIRES}, got {self.wire!r}")
        if self.hit_cap < 0:
            raise ValueError(
                f"hit_cap must be >= 0 (0 = auto), got {self.hit_cap}")
        if self.store not in VALID_STORES:
            raise ValueError(
                f"cache store must be one of {VALID_STORES}, "
                f"got {self.store!r}")
        if self.frozen and self.store != "device":
            raise ValueError(
                'a frozen (read-mostly serve) cache requires store='
                '"device" — serving resolves misses against the device '
                'table, never the L3 staging path (use serve_view())')
        return self

    @classmethod
    def from_model(cls, cfg) -> Optional["CacheConfig"]:
        """Policy from a ``ModelConfig`` (None when the cache is disabled).

        In tiered mode ``cache_l1_rows == 0`` auto-sizes the L1 to
        ``cache_rows // 8`` — the "small replicated head" default (floored
        at the L1's way count so a tiny auto-sized L1 still validates);
        outside tiered mode the L1 knobs are ignored entirely."""
        if cfg.cache_rows <= 0:
            return None
        l1 = 0
        if cfg.cache_mode == "tiered":
            l1_assoc = 1 if cfg.cache_assoc == 1 else 2
            l1 = cfg.cache_l1_rows or max(cfg.cache_rows // 8, l1_assoc)
        return cls(n_rows=cfg.cache_rows, admit=cfg.cache_admit,
                   assoc=cfg.cache_assoc, mode=cfg.cache_mode,
                   l1_rows=l1, l1_promote=cfg.cache_l1_promote,
                   wire=cfg.cache_wire,
                   hit_cap=cfg.cache_hit_cap,
                   store=cfg.feature_store).validated()


class FeatureCache(NamedTuple):
    """One worker's cache state — an explicit pytree, threaded functionally.

    The flat [C] layout is associativity-agnostic: set ``s`` owns slots
    ``s*assoc .. s*assoc + assoc - 1`` (the ``CacheConfig`` decides how the
    slots are grouped; the state arrays never change shape).

    keys    [C]     int32  resident node id per slot (-1 = empty)
    rows    [C, D]  float  resident feature rows (bit-exact table copies)
    tags    [C]     int32  candidate id awaiting admission (-1 = none)
    counts  [C]     int32  admission-progress count for the candidate
    """
    keys: jax.Array
    rows: jax.Array
    tags: jax.Array
    counts: jax.Array

    @property
    def n_rows(self) -> int:
        """Slot count ``C`` of this cache state (``keys.shape[-1]``)."""
        return self.keys.shape[-1]


class TieredCache(NamedTuple):
    """Tiered-mode per-worker state: the ``(l1, l2)`` pytree.

    ``l1`` is the small replicated head cache (``CacheConfig.l1_rows``
    slots, layout ``l1_config()``); ``l2`` is the authoritative sharded
    tier (``n_rows`` slots, layout ``l2_config()``).  Both are plain
    ``FeatureCache`` states, so every probe/insert primitive applies
    per tier unchanged."""
    l1: FeatureCache
    l2: FeatureCache


class CacheStats(NamedTuple):
    """Telemetry from one cached fetch (per-worker scalars).

    The hit population splits three ways, disjointly:

      ``n_l1_hits``    — served by the local replicated L1 (tiered mode):
                         ZERO network, not even a probe round.
      ``n_local_hits`` — served by THIS worker's main-tier cache (the
                         requester's own shard, or any hit in replicated
                         mode): no wire crossing.
      ``n_shard_hits`` — served by a REMOTE cache shard: the row crosses
                         the wire from the shard holder instead of the
                         owner (capacity multiplies by W but wire bytes
                         do not shrink).

    ``n_hits == n_l1_hits + n_local_hits + n_shard_hits``, and with
    ``n_misses`` (unique probes routed to their owner) plus ``n_l3_hits``
    (unique probes staged for the host-RAM L3 store — always 0 with the
    device-resident store) the conservation invariant
    ``n_l1_hits + n_local_hits + n_shard_hits + n_l3_hits + n_misses ==
    n_unique`` holds for every mode and both feature stores.  With
    ``store="host"`` the L3 serves every cache-tier miss that fits the
    staging capacity, so ``n_misses`` there counts only staging-overflow
    ids nobody will serve (they surface as drops too).  ``bytes_saved``
    counts only the network-free populations (L1 + local).

    The last two fields are HOLDER-side probe-round telemetry (this
    worker acting as a shard holder, not as a requester):
    ``n_probe_demoted`` counts hits the compact wire's ``hit_cap`` bound
    demoted to misses this round (they fall through to the requester's
    owner fetch — sum over workers for the global count; always 0 on the
    dense wire), and ``probe_hit_peak`` is the largest per-destination
    hit count this holder produced BEFORE demotion (max — not sum — over
    workers bounds the ``hit_cap`` a compact probe response needs; the
    hit-cap calibration reads it off a dense measurement pass)."""
    n_hits: jax.Array        # unique probes served from the cache tier
    n_misses: jax.Array      # unique probes routed to their owner
    n_inserted: jax.Array    # rows admitted into THIS worker's tiers
    bytes_saved: jax.Array   # wire bytes the network-free hits did not cross
    n_local_hits: jax.Array  # main-tier hits served without crossing the wire
    n_shard_hits: jax.Array  # hits served by a remote cache shard
    n_l1_hits: jax.Array     # hits served by the replicated L1 (no probe
                             # round either; 0 outside tiered mode)
    n_probe_demoted: jax.Array
                             # holder-side: probe hits demoted to misses
                             # by the compact wire's hit_cap bound
    probe_hit_peak: jax.Array
                             # holder-side: max per-destination probe hits
                             # before demotion (0 when no probe round ran)
    n_l3_hits: jax.Array
                             # unique probes staged for the host-RAM L3
                             # store (store="host" only, else 0; the
                             # async gather lands their rows a step later)

    @classmethod
    def zero(cls) -> "CacheStats":
        """An all-zero ``CacheStats`` (python ints — combines with either
        host-side window accumulators or device scalars)."""
        return cls(*(0,) * len(cls._fields))

    def combine(self, other: "CacheStats") -> "CacheStats":
        """Merge two windows' telemetry into one window's.

        Every counter is additive EXCEPT ``probe_hit_peak``, which is a
        per-round maximum — summing it across a window would report a
        peak no single probe round ever produced, and the hit-cap
        calibration (and the autotuner's demotion term) would then bound
        a payload that does not exist.  This is the per-window
        stat-splitting primitive: the trace recorder keeps per-step
        records and folds a window (cold half, warm half, whole run)
        with ``combine`` instead of re-measuring it."""
        vals = [a + b for a, b in zip(self[:-2], other[:-2])]
        peak = (jnp.maximum(self.probe_hit_peak, other.probe_hit_peak)
                if isinstance(self.probe_hit_peak, jax.Array)
                or isinstance(other.probe_hit_peak, jax.Array)
                else max(self.probe_hit_peak, other.probe_hit_peak))
        return CacheStats(*vals, peak, self.n_l3_hits + other.n_l3_hits)


def hash_slots(ids: jax.Array, n_sets: int) -> jax.Array:
    """Set index of each id: top bits of the multiplicative hash.

    For a direct-mapped cache (``assoc == 1``) the set IS the slot.  The
    degenerate single-set cache (``n_sets == 1``) would need a 32-bit
    logical shift — out of range for uint32 — so it short-circuits to
    set 0 for every id instead of tracing an undefined shift."""
    if n_sets <= 0 or n_sets & (n_sets - 1):
        raise ValueError(f"cache set count must be a power of two, "
                         f"got {n_sets}")
    if n_sets == 1:
        return jnp.zeros(ids.shape, jnp.int32)
    shift = 32 - (int(n_sets).bit_length() - 1)    # keep log2(n_sets) bits
    h = ids.astype(jnp.uint32) * _HASH_K
    return jax.lax.shift_right_logical(h, jnp.uint32(shift)).astype(jnp.int32)


def shard_of(ids: jax.Array, n_workers: int) -> jax.Array:
    """Cache-shard owner of each id: worker ``mix(id) mod W``.

    This is the SECOND routing function of the sharded mode — independent
    of both the row-ownership map (``id // rows``) and the in-cache set
    hash (different multiplier, see ``_SHARD_K``)."""
    if n_workers <= 1:
        return jnp.zeros(ids.shape, jnp.int32)
    h = ids.astype(jnp.uint32) * _SHARD_K
    h = jax.lax.shift_right_logical(h, jnp.uint32(16))
    return (h % np.uint32(n_workers)).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Probe-round wire codec (``CacheConfig.wire == "compact"``)
#
# The dense shard-probe response ships a full [cap, D] row block per
# destination even though only the hit slots carry data.  The compact
# format ships (a) a PACKED hit bitmap — one bit per probe slot, 32 slots
# per uint32 word — and (b) a row payload holding only the hit rows, in
# slot order, bounded by ``hit_cap``.  The holder compacts (prefix-sum
# gather), the requester re-expands (prefix-sum scatter-free gather), and
# the rows are bit-identical to the dense response for every slot whose
# bit survives.  Hits beyond ``hit_cap`` are DEMOTED: the holder clears
# their bit, so the requester treats them as misses and owner-fetches —
# a lost hit opportunity, never a correctness loss (the same contract as
# probe-capacity overflow).
# ---------------------------------------------------------------------------

#: probe slots per packed bitmap word (the bitmap dtype is uint32)
WIRE_WORD_BITS = 32


def hit_bitmap_words(n_slots: int) -> int:
    """uint32 words a packed bitmap of ``n_slots`` probe slots occupies."""
    if n_slots < 0:
        raise ValueError(f"n_slots must be >= 0, got {n_slots}")
    return -(-n_slots // WIRE_WORD_BITS)


def pack_hit_bitmap(hit: jax.Array) -> jax.Array:
    """Pack a hit vector into bitmap words: [..., R] bool -> [..., W] uint32.

    Slot ``s`` maps to bit ``s % 32`` of word ``s // 32``
    (``W == hit_bitmap_words(R)``); pad bits beyond ``R`` are zero.
    Inverse of ``unpack_hit_bitmap``."""
    r = hit.shape[-1]
    words = hit_bitmap_words(r)
    pad = words * WIRE_WORD_BITS - r
    if pad:
        hit = jnp.concatenate(
            [hit, jnp.zeros(hit.shape[:-1] + (pad,), jnp.bool_)], axis=-1)
    bits = hit.reshape(hit.shape[:-1] + (words, WIRE_WORD_BITS))
    weight = jnp.left_shift(
        jnp.uint32(1), jnp.arange(WIRE_WORD_BITS, dtype=jnp.uint32))
    return jnp.sum(bits.astype(jnp.uint32) * weight, axis=-1,
                   dtype=jnp.uint32)


def unpack_hit_bitmap(words: jax.Array, n_slots: int) -> jax.Array:
    """Unpack bitmap words back to a hit vector:
    [..., W] uint32 -> [..., n_slots] bool (pad bits discarded).
    Inverse of ``pack_hit_bitmap``."""
    if hit_bitmap_words(n_slots) != words.shape[-1]:
        raise ValueError(
            f"{words.shape[-1]} bitmap words cannot encode {n_slots} slots "
            f"(expected {hit_bitmap_words(n_slots)})")
    shift = jnp.arange(WIRE_WORD_BITS, dtype=jnp.uint32)
    bits = jnp.bitwise_and(
        jnp.right_shift(words[..., :, None], shift), jnp.uint32(1))
    flat = bits.reshape(words.shape[:-1]
                        + (words.shape[-1] * WIRE_WORD_BITS,))
    return flat[..., :n_slots].astype(jnp.bool_)


def compact_hit_rows(
    hit: jax.Array, rows: jax.Array, hit_cap: int
) -> Tuple[jax.Array, jax.Array]:
    """Holder-side payload compaction (per destination).

    ``hit`` [..., R] bool, ``rows`` [..., R, D] -> ``(kept [..., R] bool,
    payload [..., hit_cap, D])``: ``kept`` marks the first ``hit_cap``
    hits per destination (later hits are demoted — their rows are NOT in
    the payload, so the bitmap shipped over the wire must be ``kept``,
    never the raw ``hit``); ``payload[..., p, :]`` is the row of the
    ``p``-th kept slot in slot order, zeros beyond the kept count.

    ``hit_cap`` is clamped to the slot count ``R`` (a payload bound wider
    than the probe block cannot ship more rows than the dense response —
    at ``hit_cap >= R`` nothing is ever demoted)."""
    if hit_cap < 0:
        raise ValueError(f"hit_cap must be >= 0, got {hit_cap}")
    hit_cap = min(hit_cap, hit.shape[-1])
    cs = jnp.cumsum(hit.astype(jnp.int32), axis=-1)        # inclusive
    kept = jnp.logical_and(hit, cs <= hit_cap)
    # slot indices of the hits, first, in slot order (stable sort keeps
    # ascending slot order inside the hit group)
    order = jnp.argsort(~hit, axis=-1, stable=True)
    sel = order[..., :hit_cap]                             # [..., hit_cap]
    n_kept = jnp.minimum(cs[..., -1:], hit_cap)            # [..., 1]
    pvalid = jnp.arange(hit_cap, dtype=jnp.int32) < n_kept
    payload = jnp.take_along_axis(rows, sel[..., None], axis=-2)
    return kept, jnp.where(pvalid[..., None], payload, 0)


def expand_hit_rows(kept: jax.Array, payload: jax.Array) -> jax.Array:
    """Requester-side payload re-expansion (per holder).

    Inverse of ``compact_hit_rows``: ``kept`` [..., R] bool (the unpacked
    wire bitmap), ``payload`` [..., hit_cap, D] -> ``rows`` [..., R, D]
    with the ``p``-th kept slot carrying ``payload[..., p, :]`` and zeros
    everywhere else — bit-identical to the dense response on kept slots."""
    hit_cap = payload.shape[-2]
    if hit_cap == 0:
        return jnp.zeros(kept.shape + (payload.shape[-1],), payload.dtype)
    pos = jnp.cumsum(kept.astype(jnp.int32), axis=-1) - 1  # exclusive rank
    idx = jnp.clip(pos, 0, hit_cap - 1)
    rows = jnp.take_along_axis(payload, idx[..., None], axis=-2)
    return jnp.where(kept[..., None], rows, 0)


def init_cache(n_rows: int, dim: int, dtype=jnp.float32) -> FeatureCache:
    """Empty single-worker cache state."""
    return FeatureCache(
        keys=jnp.full((n_rows,), -1, jnp.int32),
        rows=jnp.zeros((n_rows, dim), dtype),
        tags=jnp.full((n_rows,), -1, jnp.int32),
        counts=jnp.zeros((n_rows,), jnp.int32),
    )


def init_worker_caches(n_rows: int, dim: int, n_workers: int,
                       dtype=np.float32) -> FeatureCache:
    """Host-side [W, ...] stack of empty per-worker caches (for device_put
    with a ``P(axis)`` sharding — each worker owns one replica/shard)."""
    return FeatureCache(
        keys=np.full((n_workers, n_rows), -1, np.int32),
        rows=np.zeros((n_workers, n_rows, dim), dtype),
        tags=np.full((n_workers, n_rows), -1, np.int32),
        counts=np.zeros((n_workers, n_rows), np.int32),
    )


def cache_specs(n_rows: int, dim: int, n_workers: int = 1,
                dtype=jnp.float32) -> FeatureCache:
    """ShapeDtypeStruct stand-ins for a [W, ...] cache (dry-run input)."""
    s = jax.ShapeDtypeStruct
    return FeatureCache(
        keys=s((n_workers, n_rows), jnp.int32),
        rows=s((n_workers, n_rows, dim), dtype),
        tags=s((n_workers, n_rows), jnp.int32),
        counts=s((n_workers, n_rows), jnp.int32),
    )


def init_cache_state(cfg: CacheConfig, dim: int, n_workers: int,
                     dtype=np.float32):
    """Mode-polymorphic [W, ...] initial cache state for a ``CacheConfig``.

    THE constructor every component should use: replicated/sharded modes
    get the flat ``FeatureCache`` stack, tiered mode gets the
    ``TieredCache`` pytree ``(l1, l2)`` — callers never branch on the
    mode themselves."""
    if cfg.mode == "tiered":
        return TieredCache(
            l1=init_worker_caches(cfg.l1_rows, dim, n_workers, dtype),
            l2=init_worker_caches(cfg.n_rows, dim, n_workers, dtype))
    return init_worker_caches(cfg.n_rows, dim, n_workers, dtype)


def cache_state_specs(cfg: CacheConfig, dim: int, n_workers: int = 1,
                      dtype=jnp.float32):
    """Mode-polymorphic ShapeDtypeStruct stand-ins (dry-run input)."""
    if cfg.mode == "tiered":
        return TieredCache(
            l1=cache_specs(cfg.l1_rows, dim, n_workers, dtype),
            l2=cache_specs(cfg.n_rows, dim, n_workers, dtype))
    return cache_specs(cfg.n_rows, dim, n_workers, dtype)


#: probe implementation every cached fetch uses when the caller does not
#: pick one explicitly — "jnp" (gather+compare, the XLA path) or "pallas"
#: (the fused VMEM probe+gather kernel; native on TPU, interpreted here).
_PROBE_IMPL = "jnp"


def set_probe_impl(impl: str) -> None:
    """Select the probe implementation for cached fetches (launcher knob —
    e.g. ``train.py --cache-probe-impl pallas``).

    The setting is read at TRACE time: call it before the cached fetch is
    first jitted — already-compiled executables keep the probe they were
    traced with (the launchers set it before building any generator)."""
    global _PROBE_IMPL
    if impl not in ("jnp", "pallas"):
        raise ValueError(f"probe impl must be 'jnp' or 'pallas', got {impl!r}")
    _PROBE_IMPL = impl


def get_probe_impl() -> str:
    """The module-level probe implementation (``"jnp"`` | ``"pallas"``)
    cached fetches trace with when the caller does not pick one
    explicitly — see ``set_probe_impl`` for the trace-time contract."""
    return _PROBE_IMPL


def cache_probe(
    cache: FeatureCache,
    ids: jax.Array,
    valid: Optional[jax.Array] = None,
    *,
    cfg: CacheConfig,
    impl: Optional[str] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Probe [R] ids: ``(hit [R] bool, rows [R, D])`` (zeros where missed).

    ``cfg`` is REQUIRED and must be the config the state was populated
    under — the slot layout is a property of the populated state, and a
    probe under a different associativity silently misses resident rows
    (never returns wrong ones: ``keys[slot] == id`` still gates the
    gather).  ``impl`` defaults to the module setting (``set_probe_impl``);
    ``"pallas"`` routes through the fused VMEM-tiled probe+gather kernel
    (kernels/cache_gather.py, platform-dispatched via kernels/ops.py); the
    ``"jnp"`` path lowers to the same gather+compare.
    """
    if cfg.n_rows != cache.n_rows:
        raise ValueError(f"cfg.n_rows {cfg.n_rows} != cache state rows "
                         f"{cache.n_rows}: probing under a mismatched "
                         f"layout silently loses residents")
    a = cfg.assoc
    if (impl or _PROBE_IMPL) == "pallas":
        from ..kernels.ops import cache_probe_gather
        hit, rows = cache_probe_gather(cache.keys, cache.rows, ids,
                                       assoc=a, use_kernel=True)
    else:
        sets = hash_slots(ids, cfg.n_sets)
        slots = sets[:, None] * a + jnp.arange(a, dtype=jnp.int32)[None, :]
        match = cache.keys[slots] == ids[:, None]           # [R, A]
        hit = match.any(axis=-1)
        way = jnp.argmax(match, axis=-1).astype(jnp.int32)  # first match
        rows = jnp.where(hit[:, None], cache.rows[sets * a + way], 0)
    if valid is not None:
        hit = jnp.logical_and(hit, valid)
        rows = jnp.where(hit[:, None], rows, 0)
    return hit, rows


def cache_insert(
    cache: FeatureCache,
    ids: jax.Array,
    rows: jax.Array,
    should: jax.Array,
    cfg: CacheConfig,
) -> Tuple[FeatureCache, jax.Array]:
    """Offer [R] fetched rows to the cache; returns (new_cache, n_inserted).

    ``cfg`` is REQUIRED and must match the config every probe of this
    state uses (the slot layout is a property of the populated state).
    ``should`` masks the offers (missed AND actually served — a
    capacity-dropped zero row must never be cached).  Admission: a
    candidate id is installed once its counter reaches ``cfg.admit``
    (``admit <= 1`` degrades to always-insert).  Way choice inside a set:
    an id already tracked as a candidate keeps its way; a new candidate
    takes the way with the smallest admission counter, empty ways first —
    the counter IS the victim policy, so contended candidates keep their
    progress.  Distinct ids colliding on one slot within a single batch
    are resolved to ONE winner (highest request index) *before* any
    scatter: the state is four arrays updated by four scatters, and
    duplicate scatter indices apply in unspecified order per scatter —
    without a pre-resolved winner, ``keys[s]`` could take id A while
    ``rows[s]`` takes B's row and every later probe of A would silently
    return B's features.
    """
    if cfg.n_rows != cache.n_rows:
        raise ValueError(f"cfg.n_rows {cfg.n_rows} != cache state rows "
                         f"{cache.n_rows}: inserting under a mismatched "
                         f"layout silently corrupts the placement")
    a, admit = cfg.assoc, cfg.admit
    c = cache.n_rows
    r = ids.shape[0]
    if r == 0:
        # empty offer batch: the rank machinery below concatenates a
        # length-1 group-start marker, which has no length-0 analogue
        return cache, jnp.int32(0)
    sets = hash_slots(ids, cfg.n_sets)
    slots = sets[:, None] * a + jnp.arange(a, dtype=jnp.int32)[None, :]
    keys_w = cache.keys[slots]                              # [R, A]
    tags_w = cache.tags[slots]
    counts_w = cache.counts[slots]
    tag_match = tags_w == ids[:, None]
    has_tag = tag_match.any(axis=-1)
    tag_way = jnp.argmax(tag_match, axis=-1).astype(jnp.int32)
    # victim policy: VIRGIN ways first (no resident AND no candidate in
    # flight — a way whose tag is mid-admission carries progress worth as
    # much as a resident's, so it scores by its counter like occupied
    # ways do), then smallest counter.  Ways claimed by a same-batch
    # TAGGED offer are excluded outright (huge score): the tagged offer
    # sits outside the preference order on its tag way, and a new
    # candidate routed onto it would trample its admission progress while
    # virgin ways sit free.
    claim_slot = sets * a + tag_way
    claimed = jnp.zeros((c,), jnp.bool_).at[
        jnp.where(jnp.logical_and(should, has_tag), claim_slot, c)
    ].set(True, mode="drop")
    victim_score = jnp.where(jnp.logical_and(keys_w < 0, tags_w < 0),
                             -1, counts_w)
    victim_score = jnp.where(claimed[slots], jnp.int32(2**30), victim_score)
    ways_pref = jnp.argsort(victim_score, axis=-1).astype(jnp.int32)  # [R, A]
    # Same-set offers within ONE batch must not all pick the same victim
    # way (the per-slot winner resolution below would then drop all but
    # one even with free ways left) — rank each NEW candidate within its
    # set and hand out ways in victim-preference order.  The rank counts
    # DISTINCT untagged ids only: duplicates of one id (several workers
    # offering the same hot row to its shard holder in one sharded
    # admission round) must share a way so the per-slot winner keeps
    # exactly one copy, and tagged offers consume no preference slot
    # (they keep their tag way).
    sets_eff = jnp.where(should, sets, cfg.n_sets)
    o1 = jnp.argsort(ids)
    order = o1[jnp.argsort(sets_eff[o1])]    # stable: (set, id) lexicographic
    s_sorted = sets_eff[order]
    i_sorted = ids[order]
    new_group = jnp.concatenate([
        jnp.ones((1,), jnp.bool_),
        jnp.logical_or(s_sorted[1:] != s_sorted[:-1],
                       i_sorted[1:] != i_sorted[:-1])])
    # cumulative count of NEW-CANDIDATE group starts: constant across a
    # group (increments only at group starts), so duplicates share a rank
    nontag_start = jnp.logical_and(new_group, ~has_tag[order])
    ng = jnp.cumsum(nontag_start).astype(jnp.int32)
    set_start = jnp.searchsorted(s_sorted, s_sorted, side="left")
    before_set = ng[set_start] - nontag_start[set_start].astype(jnp.int32)
    rank = jnp.zeros((r,), jnp.int32).at[order].set(ng - before_set - 1)
    victim_way = jnp.take_along_axis(ways_pref, (rank % a)[:, None],
                                     axis=-1)[:, 0]
    way = jnp.where(has_tag, tag_way, victim_way)
    slot = sets * a + way                                   # [R]
    prev = jnp.take_along_axis(counts_w, way[:, None], axis=-1)[:, 0]
    new_count = jnp.where(has_tag, prev + 1, 1)
    # one deterministic winner per slot among the offers (max-combiner
    # scatter is order-independent); only the winner touches the slot
    idx = jnp.arange(r, dtype=jnp.int32)
    win = jnp.full((c,), -1, jnp.int32).at[
        jnp.where(should, slot, c)].max(idx, mode="drop")
    offer = jnp.logical_and(should, win[slot] == idx)
    install = jnp.logical_and(offer, new_count >= admit)
    # not-selected offers scatter OUT OF BOUNDS so mode="drop" discards them
    s_track = jnp.where(offer, slot, c)
    s_install = jnp.where(install, slot, c)
    new = FeatureCache(
        keys=cache.keys.at[s_install].set(ids, mode="drop"),
        rows=cache.rows.at[s_install].set(rows.astype(cache.rows.dtype),
                                          mode="drop"),
        tags=cache.tags.at[s_track].set(ids, mode="drop"),
        counts=cache.counts.at[s_track].set(new_count, mode="drop"),
    )
    return new, jnp.sum(install).astype(jnp.int32)


def _keys_leaf(cache) -> jax.Array:
    """The representative keys array of either state form (tiered -> L1)."""
    return (cache.l1.keys if isinstance(cache, TieredCache) else cache.keys)


def squeeze_worker_axis(cache):
    """[1, ...] shard_map block -> per-worker [...] state.

    The shape contract is explicit: the input must be a STACKED block
    whose leading worker axis has size 1 (``keys`` is [1, C]).  An
    already-squeezed state used to be accepted silently — ``a[0]`` on a
    per-worker [C] keys array returns its first SCALAR, corrupting every
    downstream probe — so both violations now raise at trace time."""
    keys = _keys_leaf(cache)
    if keys.ndim != 2:
        raise ValueError(
            f"squeeze_worker_axis expects a [1, ...] stacked block "
            f"(keys ndim 2), got keys shape {tuple(keys.shape)} — "
            f"is this state already squeezed?")
    if keys.shape[0] != 1:
        raise ValueError(
            f"squeeze_worker_axis expects the shard_map block's worker "
            f"axis of size 1, got leading axis {keys.shape[0]}")
    return jax.tree.map(lambda a: a[0], cache)


def restore_worker_axis(cache):
    """Per-worker [...] state -> [1, ...] shard_map block.

    Inverse of ``squeeze_worker_axis`` and equally strict: the input
    must be the PER-WORKER form (``keys`` is [C]); restoring an already
    stacked state would silently grow a bogus axis."""
    keys = _keys_leaf(cache)
    if keys.ndim != 1:
        raise ValueError(
            f"restore_worker_axis expects per-worker state (keys ndim 1), "
            f"got keys shape {tuple(keys.shape)} — is this state already "
            f"stacked?")
    return jax.tree.map(lambda a: a[None], cache)


def tiered_probe(
    state: TieredCache,
    ids: jax.Array,
    valid: Optional[jax.Array] = None,
    *,
    cfg: CacheConfig,
    impl: Optional[str] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Local two-tier probe: ``(l1_hit [R], l2_hit [R], rows [R, D])``.

    Both tiers of THIS worker's state are probed in one pass — the
    single-worker degenerate of tiered mode (W == 1 owns every shard) and
    the building block the fused Pallas kernel implements.  ``l1_hit``
    and ``l2_hit`` are disjoint (L1 takes priority); ``rows`` carries the
    serving tier's copy, zeros where both miss."""
    if cfg.mode != "tiered":
        raise ValueError(f"tiered_probe requires mode='tiered', "
                         f"got {cfg.mode!r}")
    if cfg.l1_rows != state.l1.n_rows or cfg.n_rows != state.l2.n_rows:
        raise ValueError(
            f"cfg tiers ({cfg.l1_rows}, {cfg.n_rows}) != state tiers "
            f"({state.l1.n_rows}, {state.l2.n_rows}): probing under a "
            f"mismatched layout silently loses residents")
    if (impl or _PROBE_IMPL) == "pallas":
        from ..kernels.ops import cache_probe_tiered
        src, rows = cache_probe_tiered(
            state.l1.keys, state.l1.rows, state.l2.keys, state.l2.rows,
            ids, l1_assoc=cfg.l1_assoc, l2_assoc=cfg.assoc, use_kernel=True)
        l1_hit = src == 1
        l2_hit = src == 2
    else:
        l1_hit, r1 = cache_probe(state.l1, ids, cfg=cfg.l1_config())
        l2_raw, r2 = cache_probe(state.l2, ids, cfg=cfg.l2_config())
        l2_hit = jnp.logical_and(l2_raw, ~l1_hit)
        rows = jnp.where(l1_hit[:, None], r1,
                         jnp.where(l2_hit[:, None], r2, 0))
    if valid is not None:
        l1_hit = jnp.logical_and(l1_hit, valid)
        l2_hit = jnp.logical_and(l2_hit, valid)
        rows = jnp.where(jnp.logical_or(l1_hit, l2_hit)[:, None], rows, 0)
    return l1_hit, l2_hit, rows
