"""Load-Balanced Subgraph Mapping (paper §2 step 2, Algorithm 1 lines 4-13).

The coordinator builds a *balance table* mapping seed nodes to workers:
seeds are shuffled, assigned round-robin, and the remainder ``|S| mod |W|``
is **discarded** so every worker owns exactly ``floor(|S|/|W|)`` seeds.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class BalanceTable:
    """``assignment[i]`` is the worker owning shuffled seed i (discarded
    seeds excluded).  ``per_worker[w]`` is the [S/W] seed array of worker w —
    this stacked form is what shards over the mesh ``data`` axis."""

    per_worker: np.ndarray      # [n_workers, seeds_per_worker] int32
    n_discarded: int
    seed_order: np.ndarray      # the shuffled survivor seeds, round-robin order

    @property
    def n_workers(self) -> int:
        return self.per_worker.shape[0]

    @property
    def seeds_per_worker(self) -> int:
        return self.per_worker.shape[1]


def balance_table(seeds: np.ndarray, n_workers: int, seed: int = 0) -> BalanceTable:
    """Algorithm 1 lines 4-13, vectorized.

    Line 4:  shuffle S to avoid sequential bias.
    Line 6:  max_i = floor(|S|/|W|) * |W|   (remainder discarded).
    Line 11: M[s_i] = W[i mod |W|]          (round-robin).
    """
    if n_workers <= 0:
        raise ValueError("need at least one worker")
    rng = np.random.default_rng(seed)
    shuffled = rng.permutation(np.asarray(seeds, dtype=np.int32))
    per = len(shuffled) // n_workers
    max_i = per * n_workers
    kept = shuffled[:max_i]
    # i mod |W| assignment == reshape so column w holds worker w's seeds.
    per_worker = kept.reshape(per, n_workers).T.copy()
    return BalanceTable(
        per_worker=per_worker,
        n_discarded=len(shuffled) - max_i,
        seed_order=kept,
    )


def rebalance_on_failure(table: BalanceTable, failed: list[int], seed: int = 1) -> BalanceTable:
    """Fault tolerance: rebuild the balance table over surviving workers
    (Algorithm 1 re-run with |W| - |failed|).  The failed workers' seeds are
    pooled with everyone else's and re-dealt round-robin."""
    survivors = [w for w in range(table.n_workers) if w not in set(failed)]
    if not survivors:
        raise RuntimeError("all workers failed")
    all_seeds = table.per_worker.reshape(-1)
    return balance_table(all_seeds, len(survivors), seed=seed)


def load_skew(per_worker_work: np.ndarray) -> float:
    """max/mean worker load — the balance metric benchmarked in §3."""
    m = float(np.mean(per_worker_work))
    return float(np.max(per_worker_work)) / m if m > 0 else float("inf")
