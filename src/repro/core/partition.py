"""Graph Partitioning (paper §2 step 1).

The coordinator distributes the edge set across workers.  Each worker
receives a *local CSR* over the **global node-id space** (only its edge
partition's adjacency is populated), so any worker can be probed for any
frontier node — edges it does not own simply contribute degree 0.  This is
exactly the precondition for edge-centric generation: every worker scans its
own edges in parallel, and an edge (v1, v2) owned by worker w contributes to
*every* seed whose frontier reaches v1, regardless of which worker owns the
seed (paper: edges are *replicated* into all subgraphs that need them).

Partitioning strategies:
  * ``by_src_block``  — contiguous src ranges (locality, lowest shuffle cost)
  * ``by_edge_hash``  — edge-id striping (best balance for skewed graphs;
                        this is what splits a hot node's edge list across
                        workers and unlocks parallel hot-node collection)
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..graph.csr import CSRGraph


@dataclasses.dataclass
class PartitionedGraph:
    """Stacked per-worker local CSRs, padded to common sizes so the leading
    axis shards over the mesh ``data`` axis.

    indptr   [W, N+1] int32   local CSR offsets (global node-id space)
    indices  [W, E_pad] int32 local neighbor lists, padded with 0
    n_local  [W] int32        true local edge counts
    """

    indptr: np.ndarray
    indices: np.ndarray
    n_local: np.ndarray
    n_nodes: int

    @property
    def n_workers(self) -> int:
        return self.indptr.shape[0]

    def edge_balance(self) -> float:
        m = self.n_local.mean()
        return float(self.n_local.max() / m) if m > 0 else float("inf")


def partition_edges(
    graph: CSRGraph, n_workers: int, strategy: str = "by_edge_hash"
) -> PartitionedGraph:
    src, dst = graph.edge_list()
    n_edges = len(src)
    if strategy == "by_edge_hash":
        owner = (np.arange(n_edges) % n_workers).astype(np.int32)
    elif strategy == "by_src_block":
        block = -(-graph.n_nodes // n_workers)
        owner = np.minimum(src // block, n_workers - 1).astype(np.int32)
    else:
        raise ValueError(f"unknown strategy {strategy!r}")

    counts = np.bincount(owner, minlength=n_workers)
    e_pad = int(counts.max()) if n_edges else 1
    indptr = np.zeros((n_workers, graph.n_nodes + 1), dtype=np.int32)
    indices = np.zeros((n_workers, max(e_pad, 1)), dtype=np.int32)
    for w in range(n_workers):
        sel = owner == w
        local = CSRGraph.from_edges(src[sel], dst[sel], graph.n_nodes)
        indptr[w] = local.indptr.astype(np.int32)
        indices[w, : local.n_edges] = local.indices
    return PartitionedGraph(
        indptr=indptr,
        indices=indices,
        n_local=counts.astype(np.int32),
        n_nodes=graph.n_nodes,
    )


def cross_worker_fraction(graph: CSRGraph, n_workers: int, strategy: str) -> float:
    """Fraction of edges whose endpoints live in different src-blocks —
    the communication-minimization metric of §2 step 1."""
    src, dst = graph.edge_list()
    block = -(-graph.n_nodes // n_workers)
    if strategy == "by_src_block":
        return float(np.mean((src // block) != (dst // block)))
    return float(np.mean(np.arange(len(src)) % n_workers != (dst // block)))
