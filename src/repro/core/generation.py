"""Distributed Subgraph Generation (paper §2 step 3) — edge-centric, in JAX.

The paper's MapReduce formulation: every worker scans *its own edge
partition* against the current frontier in parallel (edge-centric — hot
nodes parallelize because their edge lists are split across partitions),
then partial per-seed subgraphs are aggregated through a **tree reduction**
to the seed's owner.

TPU-native mapping (DESIGN.md §2), generalized to arbitrary-depth fanout
trees driven by ``fanouts = (k_1, ..., k_L)``:

  1. frontier broadcast     — ``lax.all_gather`` of owned seeds; after each
                              hop the merged sample becomes the next global
                              frontier (every worker scans its local edges
                              against ALL frontier nodes — edge-centric).
  2. local edge scan        — each worker samples ``k_l`` candidate
                              neighbors per frontier node from its local CSR
                              (a pure gather over the local edge array:
                              fully parallel, no hot-node serialization).
                              Padded parents carry ``+inf`` keys, so they
                              never spawn children — masks chain down the
                              tree.
  3. tree aggregation       — candidates carry *weighted reservoir keys*
                              (exponential race, A-ES scheme): the merge
                              "keep the k smallest keys" is associative, so
                              the butterfly ``tree_allreduce`` (or the
                              recursive-halving ``tree_reduce_scatter``)
                              yields a weighted sample of the UNION of all
                              workers' local edges — i.e. a uniform fanout
                              sample of the global neighborhood.
  4. feature shuffle        — dense node features are fetched from their
                              owner workers with a routed ``all_to_all``
                              exchange (the MapReduce shuffle).  The tree
                              contains the same node id many times (hot
                              neighbors, with-replacement sampling), so the
                              shuffle is **request-deduplicated**: each
                              distinct id crosses the interconnect once and
                              the fetched row is scattered back to every
                              slot that asked for it.  Requests beyond the
                              per-destination capacity are *counted*
                              (``SubgraphBatch.n_dropped``), never silently
                              zero-filled.

Edges sampled for several seeds are *replicated* into each seed's subgraph
(paper step 3), which falls out of sampling per frontier slot.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from ..graph.subgraph import SubgraphBatch
from .partition import PartitionedGraph
from .tree_reduce import axis_size, tree_allreduce, tree_reduce_scatter


class Candidates(NamedTuple):
    ids: jax.Array    # [F, k] neighbor node ids
    keys: jax.Array   # [F, k] reservoir keys (+inf = invalid)


class FetchStats(NamedTuple):
    """Telemetry from one ``fetch_rows`` shuffle (per-worker scalars)."""
    n_requests: jax.Array   # request slots presented (incl. duplicates)
    n_unique: jax.Array     # distinct ids actually routed over the wire
    n_dropped: jax.Array    # request SLOTS zero-filled by the capacity
                            # bound (a dropped unique id counts once per
                            # duplicate slot it would have served)


def local_candidates(
    indptr: jax.Array,
    indices: jax.Array,
    frontier: jax.Array,
    k: int,
    rng: jax.Array,
) -> Candidates:
    """Sample ``k`` neighbors-with-replacement of each frontier node from a
    local CSR partition, tagged with weighted reservoir keys.

    Each draw represents ``deg_local / k`` edges, so its key is an
    Exponential(rate = deg_local / k) variate — the min-k merge over workers
    is then a weighted (≈ uniform-over-global-edges) sample of the union.
    """
    f = frontier.shape[0]
    node = jnp.clip(frontier, 0, indptr.shape[0] - 2)
    start = indptr[node]
    deg = (indptr[node + 1] - start).astype(jnp.int32)
    r_off, r_key = jax.random.split(rng)
    offs = jax.random.randint(r_off, (f, k), 0, jnp.iinfo(jnp.int32).max)
    offs = offs % jnp.maximum(deg, 1)[:, None]
    ids = indices[jnp.clip(start[:, None] + offs, 0, indices.shape[0] - 1)]
    u = jax.random.uniform(r_key, (f, k), minval=jnp.finfo(jnp.float32).tiny)
    weight = (deg.astype(jnp.float32) / k)[:, None]
    keys = -jnp.log(u) / jnp.maximum(weight, 1e-30)
    keys = jnp.where((deg > 0)[:, None], keys, jnp.inf)
    return Candidates(ids=ids.astype(jnp.int32), keys=keys)


def merge_topk(a: Candidates, b: Candidates) -> Candidates:
    """Associative merge: keep the k smallest keys of the union."""
    k = a.keys.shape[-1]
    keys = jnp.concatenate([a.keys, b.keys], axis=-1)
    ids = jnp.concatenate([a.ids, b.ids], axis=-1)
    neg, idx = lax.top_k(-keys, k)
    return Candidates(ids=jnp.take_along_axis(ids, idx, axis=-1), keys=-neg)


def dedup_requests(ids: jax.Array):
    """Static-shape sort+segment unique (``jnp.unique`` needs dynamic sizes).

    Returns ``(uniq, inverse, valid, n_unique)`` where ``uniq`` is a [R]
    array whose first ``n_unique`` slots hold the distinct ids (the tail is
    unspecified padding), ``inverse`` maps each original slot to its unique
    slot (``uniq[inverse] == ids``), and ``valid[i] = i < n_unique``.
    """
    r = ids.shape[0]
    order = jnp.argsort(ids)
    s = ids[order]
    is_first = jnp.concatenate(
        [jnp.ones((1,), jnp.bool_), s[1:] != s[:-1]])
    group = (jnp.cumsum(is_first) - 1).astype(jnp.int32)     # [R], sorted
    n_unique = group[-1] + 1
    uniq = jnp.zeros((r,), ids.dtype).at[group].set(s)
    inverse = jnp.zeros((r,), jnp.int32).at[order].set(group)
    valid = jnp.arange(r, dtype=jnp.int32) < n_unique
    return uniq, inverse, valid, n_unique


def _routed_fetch(
    table_local: jax.Array,
    ids: jax.Array,
    valid: jax.Array,
    axis_name: str,
    cap: int,
    w: int,
    rows: int,
):
    """One routed all_to_all round trip serving ``ids[valid]`` requests.

    Returns ``(rows [R, D], served [R])`` — invalid slots return zero rows
    with ``served=False``; valid slots beyond the per-destination capacity
    ``cap`` also return zero rows with ``served=False`` (the caller decides
    what counts as a drop).
    """
    r = ids.shape[0]
    owner = jnp.clip(ids // rows, 0, w - 1)
    # invalid slots route to a sentinel bucket past the last worker so they
    # neither consume capacity nor cross the interconnect
    owner = jnp.where(valid, owner, w)
    order = jnp.argsort(owner)
    sorted_owner = owner[order]
    first = jnp.searchsorted(sorted_owner, sorted_owner, side="left")
    slot = jnp.arange(r, dtype=jnp.int32) - first
    sorted_valid = sorted_owner < w
    ok = jnp.logical_and(slot < cap, sorted_valid)
    # overflow + sentinel requests go OUT OF BOUNDS so mode="drop" discards
    # them (clipping would overwrite the request already in the last slot)
    slot_c = jnp.where(ok, slot, cap)
    send = jnp.zeros((w, cap), dtype=jnp.int32)
    send = send.at[sorted_owner, slot_c].set(ids[order], mode="drop")
    recv = lax.all_to_all(send, axis_name, split_axis=0, concat_axis=0, tiled=True)
    me = lax.axis_index(axis_name)
    local = jnp.clip(recv - me * rows, 0, rows - 1)
    served = table_local[local]                      # [w, cap, D]
    resp = lax.all_to_all(served, axis_name, split_axis=0, concat_axis=0, tiled=True)
    got = resp[jnp.clip(sorted_owner, 0, w - 1), jnp.clip(slot_c, 0, cap - 1)]
    got = jnp.where(ok[:, None], got, 0)
    out = jnp.zeros((r, table_local.shape[1]), table_local.dtype)
    served = jnp.zeros((r,), jnp.bool_).at[order].set(ok)
    return out.at[order].set(got), served


def fetch_rows(
    table_local: jax.Array,
    ids: jax.Array,
    axis_name: str,
    capacity_slack: float = 2.0,
    dedup: bool = True,
    capacity: Optional[int] = None,
    return_stats: bool = False,
):
    """Routed remote row fetch (the MapReduce shuffle, as ``all_to_all``).

    ``table_local`` is this worker's [rows, D] block of a row-sharded table;
    global row ``i`` lives on worker ``i // rows``.  Every worker requests
    ``ids`` [R] and receives the corresponding rows [R, D].

    With ``dedup=True`` (default) duplicate ids are collapsed before
    routing: each distinct id occupies at most one wire slot and its row is
    scattered back to every requesting slot.  A fanout tree's request list
    is massively duplicated (hot neighbors, with-replacement sampling), so
    at a given per-destination capacity this slashes the drop rate — and
    because distinct requests per destination can never exceed the
    destination's ``rows``, the default capacity is clamped to ``rows``
    (shrinking the static exchange buffers).  Pass a smaller ``capacity``
    sized to the expected unique count to shrink wire traffic further.

    Per-destination capacity defaults to ``ceil(R/W) * slack`` (clamped as
    above when dedup is on); requests beyond it return zero rows and are
    counted per request slot — pass ``return_stats=True`` to receive
    ``(out, FetchStats)`` instead of silently zero-filled rows.  For W == 1
    this degenerates to a local gather (no routing, so ``n_unique`` is
    reported as ``R``).
    """
    w = axis_size(axis_name)
    rows = table_local.shape[0]
    r = ids.shape[0]
    if w == 1:
        out = table_local[jnp.clip(ids, 0, rows - 1)]
        if return_stats:
            return out, FetchStats(jnp.int32(r), jnp.int32(r), jnp.int32(0))
        return out
    cap = capacity
    if cap is None:
        cap = int(min(r, -(-r // w) * capacity_slack + 8))
        if dedup:
            cap = min(cap, rows)    # ≤ rows distinct ids per destination
    if dedup:
        uniq, inverse, valid, n_unique = dedup_requests(ids)
        rows_u, served_u = _routed_fetch(
            table_local, uniq, valid, axis_name, cap, w, rows)
        out = rows_u[inverse]
        # a dropped unique id zero-fills EVERY duplicate slot it backed —
        # count affected request slots, not wire slots
        dropped = jnp.sum(~served_u[inverse])
    else:
        valid = jnp.ones((r,), jnp.bool_)
        out, served = _routed_fetch(
            table_local, ids, valid, axis_name, cap, w, rows)
        dropped = jnp.sum(~served)
        n_unique = jnp.int32(r)
    if return_stats:
        return out, FetchStats(jnp.int32(r), n_unique,
                               dropped.astype(jnp.int32))
    return out


def _worker_generate(
    indptr: jax.Array,       # [N+1] local CSR
    indices: jax.Array,      # [E_pad]
    x_local: jax.Array,      # [rows, D] node features (row-sharded)
    y_local: jax.Array,      # [rows, 1] labels (row-sharded)
    seeds: jax.Array,        # [b] seeds owned by this worker (balance table row)
    rng: jax.Array,
    *,
    fanouts: Tuple[int, ...],
    axis_name: str,
    merge_mode: str = "butterfly",
) -> SubgraphBatch:
    """One worker's slice of an L-hop generation round (runs in shard_map).

    Per hop: broadcast frontier -> ``local_candidates`` scan -> tree merge
    (butterfly allreduce or recursive-halving reduce-scatter); the merged
    global sample becomes the next frontier.  Masks chain so a padded
    parent's subtree stays padded.  Then one deduplicated feature shuffle
    fetches every node's row.
    """
    b = seeds.shape[0]
    me = lax.axis_index(axis_name)
    rng = jax.random.fold_in(rng, me)
    hop_rngs = jax.random.split(rng, max(len(fanouts), 2))

    frontier = lax.all_gather(seeds, axis_name, tiled=True)   # [B] global
    parent_mask = jnp.ones(frontier.shape, jnp.bool_)
    hops, masks = [], []
    shape = (b,)                # local tree shape accumulator
    local_rows = b              # b * k_1 * ... * k_l (this worker's rows)
    for level, k in enumerate(fanouts):
        cand = local_candidates(indptr, indices, frontier, k, hop_rngs[level])
        # padding must not spawn children:
        cand = Candidates(
            ids=cand.ids,
            keys=jnp.where(parent_mask[:, None], cand.keys, jnp.inf),
        )
        if merge_mode == "reduce_scatter":
            # beyond-paper: recursive-halving merge — each worker
            # materializes only ITS segment of the frontier
            # (tree_reduce.py); ~4x less ICI traffic than the butterfly
            # at W=16.
            seg = tree_reduce_scatter(cand, merge_topk, axis_name)
            m = jnp.isfinite(seg.keys)                        # [rows_l, k]
            h = jnp.where(m, seg.ids, 0)
            # the next frontier must still be GLOBAL (edge-centric: every
            # worker scans its local edges against all hop-l nodes)
            h_all = lax.all_gather(h, axis_name, tiled=True)
            m_all = lax.all_gather(m, axis_name, tiled=True)
        else:
            merged = tree_allreduce(cand, merge_topk, axis_name)  # [F, k]
            m_all = jnp.isfinite(merged.keys)
            h_all = jnp.where(m_all, merged.ids, 0)
            h = lax.dynamic_slice_in_dim(h_all, me * local_rows, local_rows, 0)
            m = lax.dynamic_slice_in_dim(m_all, me * local_rows, local_rows, 0)
        shape = shape + (k,)
        hops.append(h.reshape(shape))
        masks.append(m.reshape(shape))
        frontier = h_all.reshape(-1)                          # [F * k]
        parent_mask = m_all.reshape(-1)
        local_rows *= k

    # chain masks explicitly (the +inf-key propagation already implies this;
    # keep the invariant structural, not sampler-dependent)
    for level in range(1, len(masks)):
        masks[level] = jnp.logical_and(masks[level], masks[level - 1][..., None])

    # --- feature shuffle: one deduplicated fetch for every node slot ---
    need = jnp.concatenate([seeds] + [h.reshape(-1) for h in hops])
    feats, fstats = fetch_rows(x_local, need, axis_name, return_stats=True)
    d = x_local.shape[1]
    x_seed = feats[:b]
    x_hops = []
    off = b
    n = b
    for level, k in enumerate(fanouts):
        n *= k
        x = feats[off:off + n].reshape(masks[level].shape + (d,))
        x_hops.append(x * masks[level][..., None])
        off += n
    # balance-table seeds are already distinct per worker — skip the dedup
    # front end for the label fetch
    ys, ystats = fetch_rows(y_local, seeds, axis_name, dedup=False,
                            return_stats=True)
    labels = ys[:, 0].astype(jnp.int32)

    return SubgraphBatch(
        seeds=seeds,
        hops=tuple(hops),
        masks=tuple(masks),
        x_seed=x_seed,
        x_hops=tuple(x_hops),
        labels=labels,
        n_dropped=(fstats.n_dropped + ystats.n_dropped)[None],
    )


def shard_rows(table: np.ndarray, n_workers: int) -> np.ndarray:
    """Pad a [N, D] host table to [W * rows, D] so it row-shards evenly."""
    n = table.shape[0]
    rows = -(-n // n_workers)
    pad = n_workers * rows - n
    if pad:
        table = np.concatenate([table, np.zeros((pad,) + table.shape[1:], table.dtype)])
    return table


def make_generator_fn(
    mesh: Mesh,
    *,
    fanouts: Tuple[int, ...] = (40, 20),
    axis_name: str = "data",
    merge_mode: str = "butterfly",
):
    """Pure generator function (no data placement — dry-run lowerable).

    ``gen_fn(device_args, seeds [W, b], rng) -> SubgraphBatch`` where
    ``device_args = (indptr [W,N+1], indices [W,E_pad], x [W*rows,D],
    y [W*rows,1])`` sharded on their leading axis."""
    if not fanouts:
        raise ValueError("fanouts must name at least one hop, got ()")
    graph_spec = P(axis_name)
    row_spec = P(axis_name)
    repl = P()

    def _squeeze_worker_axis(fn):
        # shard_map blocks keep the sharded leading axis of size 1 per worker;
        # wrap worker fn to drop/restore it.
        def wrapped(indptr, indices, xs, ys, seeds, rng):
            batch = fn(
                indptr[0], indices[0], xs, ys, seeds[0], rng
            )
            return batch
        return wrapped

    worker_fn = _squeeze_worker_axis(
        functools.partial(_worker_generate, fanouts=tuple(fanouts),
                          axis_name=axis_name, merge_mode=merge_mode)
    )

    def gen_fn(device_args, seeds, rng):
        indptr, indices, xs, ys = device_args
        return shard_map(
            worker_fn,
            mesh=mesh,
            in_specs=(graph_spec, graph_spec, row_spec, row_spec, graph_spec, repl),
            out_specs=P(axis_name),
            check_rep=False,
        )(indptr, indices, xs, ys, seeds, rng)

    return gen_fn


def make_distributed_generator(
    mesh: Mesh,
    part: PartitionedGraph,
    features: np.ndarray,
    labels: np.ndarray,
    *,
    fanouts: Tuple[int, ...] = (40, 20),
    axis_name: str = "data",
    merge_mode: str = "butterfly",
):
    """Build the jitted distributed generator with data placed on the mesh.

    Returns ``(gen_fn, device_args)``; every output leaf is sharded
    ``P(axis_name)`` on its leading (global-batch) axis."""
    w = mesh.shape[axis_name]
    assert part.n_workers == w, (part.n_workers, w)
    x = shard_rows(features.astype(np.float32), w)
    y = shard_rows(labels.reshape(-1, 1).astype(np.float32), w)
    gen_fn = make_generator_fn(mesh, fanouts=fanouts, axis_name=axis_name,
                               merge_mode=merge_mode)
    spec = NamedSharding(mesh, P(axis_name))
    device_args = (
        jax.device_put(part.indptr, spec),
        jax.device_put(part.indices, spec),
        jax.device_put(x, spec),
        jax.device_put(y, spec),
    )
    return jax.jit(gen_fn), device_args
