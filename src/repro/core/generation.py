"""Distributed Subgraph Generation (paper §2 step 3) — edge-centric, in JAX.

The paper's MapReduce formulation: every worker scans *its own edge
partition* against the current frontier in parallel (edge-centric — hot
nodes parallelize because their edge lists are split across partitions),
then partial per-seed subgraphs are aggregated through a **tree reduction**
to the seed's owner.

TPU-native mapping (DESIGN.md §2), generalized to arbitrary-depth fanout
trees driven by ``fanouts = (k_1, ..., k_L)``:

  1. frontier broadcast     — ``lax.all_gather`` of owned seeds; after each
                              hop the merged sample becomes the next global
                              frontier (every worker scans its local edges
                              against ALL frontier nodes — edge-centric).
  2. local edge scan        — each worker samples ``k_l`` candidate
                              neighbors per frontier node from its local CSR
                              (a pure gather over the local edge array:
                              fully parallel, no hot-node serialization).
                              Padded parents carry ``+inf`` keys, so they
                              never spawn children — masks chain down the
                              tree.
  3. tree aggregation       — candidates carry *weighted reservoir keys*
                              (exponential race, A-ES scheme): the merge
                              "keep the k smallest keys" is associative, so
                              the butterfly ``tree_allreduce`` (or the
                              recursive-halving ``tree_reduce_scatter``)
                              yields a weighted sample of the UNION of all
                              workers' local edges — i.e. a uniform fanout
                              sample of the global neighborhood.
  4. feature shuffle        — dense node features are fetched from their
                              owner workers with a routed ``all_to_all``
                              exchange (the MapReduce shuffle).  The tree
                              contains the same node id many times (hot
                              neighbors, with-replacement sampling), so the
                              shuffle is **request-deduplicated**: each
                              distinct id crosses the interconnect once and
                              the fetched row is scattered back to every
                              slot that asked for it.  In front of the
                              all_to_all sits an optional **device-resident
                              hot-node cache** (core/feature_cache.py):
                              distinct ids are first probed against the
                              cache tier and only the *misses* are routed —
                              hot rows that recur across iterations stop
                              being fetched from their owners, and served
                              misses are admitted back (frequency
                              admission) so the cache tracks the workload.
                              Requests beyond the per-destination capacity
                              are *counted* (``SubgraphBatch.n_dropped``),
                              never silently zero-filled, and cache
                              hits/misses surface as
                              ``SubgraphBatch.n_cache_hits/n_cache_misses``.

**Mode-polymorphic cache-aware routing** (``CacheConfig.mode``): the
replicated cache caps total distinct capacity at ~C no matter how many
workers join (every replica converges on the same Zipf head); sharded
mode partitions the id-space over the worker axis (capacity x W); tiered
mode composes both.  Each mode is a (probe, admit) strategy pair — the
fetch path itself never branches on the mode.  The full three-stage
tiered flow (the other modes run a subset of it):

  stage 0 (L1 probe)     — every deduplicated id is probed against the
           LOCAL replicated L1 (the global Zipf head, ``l1_rows`` slots).
           An L1 hit costs zero network — it skips the probe round AND
           the owner fetch.  [tiered only]
  stage 1 (shard probe)  — the remaining ids are routed to their
           *cache-shard* worker (``shard_of(id, W)``) with one
           ``all_to_all`` probe round; the shard holder probes its local
           tier and responds — DistDGL-style "ask the worker whose CACHE
           holds a hot row, not its owner".  The RESPONSE rides one of
           two wire formats (``CacheConfig.wire``): **dense** ships the
           full ``[W, cap, D]`` row block back even though only hit
           slots carry data, **compact** (the default) ships a packed
           hit bitmap plus a row payload compacted to ``hit_cap`` rows
           per destination — stage-1 bytes then scale with *hits*, not
           with the probe capacity.  In tiered mode the round carries
           only L1 *misses*, so its wire bytes shrink by the L1 hit
           fraction — and the compact payload compounds the saving
           (fewer probe hits support a tighter ``hit_cap``).
           [sharded + tiered]
  stage 2 (owner fetch)  — only shard-*misses* fall through to the routed
           owner fetch; the served rows then ride one more ``all_to_all``
           back to the shard holders (reusing the probe round's slot
           assignment) so admission updates the AUTHORITATIVE shard, not a
           local replica.  In tiered mode every row the L2 tier SERVED the
           requester this round is also OFFERED to its local L1, which
           installs it after ``l1_promote`` observations — the hottest
           rows migrate L2 -> L1 on every worker without any broadcast
           (owner-fetched rows are not offered: the cold tail must not
           churn the small L1's admission tags).  [all modes; replicated
           probes/admits locally]

A shard hit's row still crosses the wire (shard holder -> requester
instead of owner -> requester), so ``CacheStats`` splits the hits into
``n_l1_hits`` (zero network) / ``n_local_hits`` (own shard, no crossing) /
``n_shard_hits`` (remote shard) and ``bytes_saved`` counts only the first
two.  Cached fetches stay bit-identical to uncached fetches in every mode
— cached rows are verbatim table copies wherever they live.

Edges sampled for several seeds are *replicated* into each seed's subgraph
(paper step 3), which falls out of sampling per frontier slot.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from ..graph.subgraph import SubgraphBatch
from .feature_cache import (CacheConfig, CacheStats, FeatureCache,
                            TieredCache, cache_insert, cache_probe,
                            compact_hit_rows, expand_hit_rows,
                            get_probe_impl, hit_bitmap_words,
                            init_cache_state, pack_hit_bitmap,
                            restore_worker_axis, shard_of,
                            squeeze_worker_axis, tiered_probe,
                            unpack_hit_bitmap)
from .host_store import HostFeatureStore, HostMissRequest
from .partition import PartitionedGraph
from .tree_reduce import axis_size, tree_allreduce, tree_reduce_scatter


class Candidates(NamedTuple):
    ids: jax.Array    # [F, k] neighbor node ids
    keys: jax.Array   # [F, k] reservoir keys (+inf = invalid)


class FetchStats(NamedTuple):
    """Telemetry from one ``fetch_rows`` shuffle (per-worker scalars).

    ``probe_round_bytes`` is MEASURED, not estimated: it is the byte size
    of the buffers this worker actually ships on the stage-1 shard-probe
    round (ids up, plus the hit/row response down — dense or compact per
    ``CacheConfig.wire``), computed from the static exchange shapes the
    compiled program moves.  It is 0 whenever no probe round runs
    (uncached, replicated mode, or W == 1); summing it over workers and
    iterations gives the total probe-round wire volume a run paid.

    ``host_gather_bytes`` is the PCIe payload of the L3 staging round a
    ``store="host"`` fetch hands to the host store (staged miss ids up
    plus the landed feature rows back down, from the static staging
    shape) — 0 whenever the feature table is device-resident."""
    n_requests: jax.Array   # request slots presented (incl. duplicates)
    n_unique: jax.Array     # distinct ids actually routed over the wire
    n_dropped: jax.Array    # request SLOTS zero-filled by the capacity
                            # bound (a dropped unique id counts once per
                            # duplicate slot it would have served)
    probe_round_bytes: jax.Array
                            # bytes this worker shipped on the shard-probe
                            # all_to_all round (0 = no probe round ran)
    host_gather_bytes: jax.Array
                            # bytes of the host-store staging round trip
                            # (0 = device-resident feature table)

    @classmethod
    def zero(cls) -> "FetchStats":
        """An all-zero ``FetchStats`` (python ints — combines with either
        host-side window accumulators or device scalars)."""
        return cls(*(0,) * len(cls._fields))

    def combine(self, other: "FetchStats") -> "FetchStats":
        """Merge two windows' fetch telemetry into one window's.

        Every ``FetchStats`` field is additive (counts and byte totals),
        so a window's stats are the fold of its per-step records — the
        per-window stat-splitting primitive the trace recorder
        (``launch/autotune.py``) uses to separate the cold burst from
        the warm steady state without re-measuring either."""
        return FetchStats(*(a + b for a, b in zip(self, other)))


def local_candidates(
    indptr: jax.Array,
    indices: jax.Array,
    frontier: jax.Array,
    k: int,
    rng: jax.Array,
) -> Candidates:
    """Sample ``k`` neighbors-with-replacement of each frontier node from a
    local CSR partition, tagged with weighted reservoir keys.

    Each draw represents ``deg_local / k`` edges, so its key is an
    Exponential(rate = deg_local / k) variate — the min-k merge over workers
    is then a weighted (≈ uniform-over-global-edges) sample of the union.
    """
    f = frontier.shape[0]
    node = jnp.clip(frontier, 0, indptr.shape[0] - 2)
    start = indptr[node]
    deg = (indptr[node + 1] - start).astype(jnp.int32)
    r_off, r_key = jax.random.split(rng)
    offs = jax.random.randint(r_off, (f, k), 0, jnp.iinfo(jnp.int32).max)
    offs = offs % jnp.maximum(deg, 1)[:, None]
    ids = indices[jnp.clip(start[:, None] + offs, 0, indices.shape[0] - 1)]
    u = jax.random.uniform(r_key, (f, k), minval=jnp.finfo(jnp.float32).tiny)
    weight = (deg.astype(jnp.float32) / k)[:, None]
    keys = -jnp.log(u) / jnp.maximum(weight, 1e-30)
    keys = jnp.where((deg > 0)[:, None], keys, jnp.inf)
    return Candidates(ids=ids.astype(jnp.int32), keys=keys)


def merge_topk(a: Candidates, b: Candidates) -> Candidates:
    """Associative merge: keep the k smallest keys of the union."""
    k = a.keys.shape[-1]
    keys = jnp.concatenate([a.keys, b.keys], axis=-1)
    ids = jnp.concatenate([a.ids, b.ids], axis=-1)
    neg, idx = lax.top_k(-keys, k)
    return Candidates(ids=jnp.take_along_axis(ids, idx, axis=-1), keys=-neg)


def dedup_requests(ids: jax.Array):
    """Static-shape sort+segment unique (``jnp.unique`` needs dynamic sizes).

    Returns ``(uniq, inverse, valid, n_unique)`` where ``uniq`` is a [R]
    array whose first ``n_unique`` slots hold the distinct ids (the tail is
    unspecified padding), ``inverse`` maps each original slot to its unique
    slot (``uniq[inverse] == ids``), and ``valid[i] = i < n_unique``.
    """
    r = ids.shape[0]
    if r == 0:
        # the group-start marker below concatenates a length-1 sentinel,
        # which has no length-0 analogue — an empty batch has no uniques
        return (ids, jnp.zeros((0,), jnp.int32),
                jnp.zeros((0,), jnp.bool_), jnp.int32(0))
    order = jnp.argsort(ids)
    s = ids[order]
    is_first = jnp.concatenate(
        [jnp.ones((1,), jnp.bool_), s[1:] != s[:-1]])
    group = (jnp.cumsum(is_first) - 1).astype(jnp.int32)     # [R], sorted
    n_unique = group[-1] + 1
    uniq = jnp.zeros((r,), ids.dtype).at[group].set(s)
    inverse = jnp.zeros((r,), jnp.int32).at[order].set(group)
    valid = jnp.arange(r, dtype=jnp.int32) < n_unique
    return uniq, inverse, valid, n_unique


def probe_round_capacity(n_requests: int, n_workers: int,
                         capacity_slack: float = 2.0) -> int:
    """Per-destination slot count of the slack-sized exchange rounds.

    THE sizing formula ``fetch_rows`` uses for the owner exchange (before
    dedup clamping / explicit ``capacity``) and for the shard-probe round
    (always — the probe round carries ALL distinct ids, see
    ``fetch_rows``): ``min(R, ceil(R / W) * slack + 8)``.  Exposed so the
    launcher's hit-cap calibration derives its ladder rungs from the SAME
    capacity the compiled fetch will use — a reimplementation that
    drifted would calibrate a bound for buffers that do not exist."""
    return int(min(n_requests,
                   -(-n_requests // n_workers) * capacity_slack + 8))


class _RoutePlan(NamedTuple):
    """Per-destination slot assignment of one routed all_to_all round.

    The assignment is a pure function of ``(dest, cap)`` — the shard-probe
    and shard-admission rounds rely on this determinism to reuse ONE plan,
    so the rows a requester sends for admission land exactly on the recv
    slots whose ids the shard holder probed.
    """
    order: jax.Array        # [R] argsort of dest (requests in send order)
    sorted_dest: jax.Array  # [R] dest[order] (w = sentinel "nowhere")
    slot_c: jax.Array       # [R] per-destination slot, cap = overflow/drop
    ok: jax.Array           # [R] request got a wire slot (in sorted order)


def _route_plan(dest: jax.Array, cap: int, w: int) -> _RoutePlan:
    """Assign each request a (destination, slot) wire position.

    ``dest == w`` is the sentinel for requests that must not cross the
    interconnect; requests beyond ``cap`` per destination overflow to slot
    index ``cap`` so a ``mode="drop"`` scatter discards them (clipping
    would overwrite the request already in the last slot).
    """
    r = dest.shape[0]
    order = jnp.argsort(dest)
    sorted_dest = dest[order]
    first = jnp.searchsorted(sorted_dest, sorted_dest, side="left")
    slot = jnp.arange(r, dtype=jnp.int32) - first
    ok = jnp.logical_and(slot < cap, sorted_dest < w)
    slot_c = jnp.where(ok, slot, cap)
    return _RoutePlan(order, sorted_dest, slot_c, ok)


def _routed_fetch(
    table_local: jax.Array,
    ids: jax.Array,
    valid: jax.Array,
    axis_name: str,
    cap: int,
    w: int,
    rows: int,
):
    """One routed all_to_all round trip serving ``ids[valid]`` requests.

    Returns ``(rows [R, D], served [R])`` — invalid slots return zero rows
    with ``served=False``; valid slots beyond the per-destination capacity
    ``cap`` also return zero rows with ``served=False`` (the caller decides
    what counts as a drop).
    """
    r = ids.shape[0]
    owner = jnp.clip(ids // rows, 0, w - 1)
    # invalid slots route to a sentinel bucket past the last worker so they
    # neither consume capacity nor cross the interconnect
    owner = jnp.where(valid, owner, w)
    plan = _route_plan(owner, cap, w)
    send = jnp.zeros((w, cap), dtype=jnp.int32)
    send = send.at[plan.sorted_dest, plan.slot_c].set(ids[plan.order],
                                                      mode="drop")
    recv = lax.all_to_all(send, axis_name, split_axis=0, concat_axis=0, tiled=True)
    me = lax.axis_index(axis_name)
    local = jnp.clip(recv - me * rows, 0, rows - 1)
    served = table_local[local]                      # [w, cap, D]
    resp = lax.all_to_all(served, axis_name, split_axis=0, concat_axis=0, tiled=True)
    got = resp[jnp.clip(plan.sorted_dest, 0, w - 1),
               jnp.clip(plan.slot_c, 0, cap - 1)]
    got = jnp.where(plan.ok[:, None], got, 0)
    out = jnp.zeros((r, table_local.shape[1]), table_local.dtype)
    served = jnp.zeros((r,), jnp.bool_).at[plan.order].set(plan.ok)
    return out.at[plan.order].set(got), served


class _WireStats(NamedTuple):
    """Holder-side probe-round telemetry one ``_shard_probe`` produces.

    ``n_demoted``/``hit_peak`` are per-worker int32 scalars (see
    ``CacheStats``); ``probe_bytes`` is the MEASURED per-worker byte cost
    of the round — a static python int derived from the exchange buffer
    shapes the compiled program actually ships."""
    n_demoted: jax.Array    # hits the compact hit_cap bound demoted
    hit_peak: jax.Array     # max per-destination hits before demotion
    probe_bytes: int        # bytes this worker ships on the round


def probe_hit_cap(cfg: CacheConfig, cap: int) -> int:
    """Resolved compact-wire payload bound for a probe capacity ``cap``.

    ``CacheConfig.hit_cap == 0`` auto-sizes to half the probe capacity —
    a conservative 2x response-row saving that never demotes while fewer
    than half the probe slots hit; an explicit (calibrated) ``hit_cap``
    is clamped into ``[1, cap]``."""
    return max(min(cfg.hit_cap or max(cap // 2, 1), cap), 1)


def _shard_probe(
    cache: FeatureCache,
    cfg: CacheConfig,
    ids: jax.Array,
    valid: jax.Array,
    axis_name: str,
    cap: int,
    w: int,
):
    """Stage-1 routing: probe each id against its CACHE-SHARD worker.

    One all_to_all round trip — ids ride to their shard holders, every
    holder probes its local shard for everything it received, and the
    response rides back in the wire format ``cfg.wire`` selects:

      dense    — ``(hit [w, cap] bool, rows [w, cap, D])``: every probe
                 slot ships a row slot back, hit or not.
      compact  — ``(bitmap [w, words] uint32, payload [w, hit_cap, D])``:
                 one bit per probe slot plus only the hit rows, compacted
                 in slot order by the holder (``compact_hit_rows``) and
                 re-expanded by the requester via the bitmap's prefix
                 sums (``expand_hit_rows``) — bit-identical to the dense
                 response for every surviving hit.  Hits beyond
                 ``hit_cap`` per destination are DEMOTED to misses by
                 the holder (bit cleared), falling through to the owner
                 fetch exactly like probe-capacity overflow.

    Returns ``(hit [R], rows [R, D], plan, recv_ids [w, cap], wire)``
    where ``wire`` is the ``_WireStats`` telemetry; ids beyond the probe
    capacity simply miss (they fall through to the owner fetch — a lost
    hit opportunity, never a correctness loss).  ``plan``/``recv_ids``
    feed ``_shard_admit`` so the admission round reuses this round's
    slot assignment.
    """
    r = ids.shape[0]
    dest = jnp.where(valid, shard_of(ids, w), w)
    plan = _route_plan(dest, cap, w)
    # empty probe slots carry -1, which the probe masks out (node ids are
    # always >= 0, so -1 can never alias a resident key)
    send = jnp.full((w, cap), -1, jnp.int32)
    send = send.at[plan.sorted_dest, plan.slot_c].set(ids[plan.order],
                                                      mode="drop")
    recv = lax.all_to_all(send, axis_name, split_axis=0, concat_axis=0, tiled=True)
    flat = recv.reshape(-1)
    d = cache.rows.shape[-1]
    item = jnp.dtype(cache.rows.dtype).itemsize
    probe_bytes = w * cap * 4                    # ids up, int32
    if cfg.wire == "compact":
        hc = probe_hit_cap(cfg, cap)
        n_words = hit_bitmap_words(cap)
        if get_probe_impl() == "pallas":
            # fused probe+compact: never materializes the dense [w, cap, D]
            # response block the compact wire exists to not ship; the
            # raw (pre-demotion) bitmap rides along as a second kernel
            # output, so ONE probe serves both the wire and the
            # demotion/hit-peak telemetry
            from ..kernels.ops import cache_probe_compact
            words, raw_words, payload = cache_probe_compact(
                cache.keys, cache.rows, recv, assoc=cfg.assoc, hit_cap=hc,
                use_kernel=True)
            kept = unpack_hit_bitmap(words, cap)
            raw_hit = unpack_hit_bitmap(raw_words, cap)
        else:
            hit_f, rows_f = cache_probe(cache, flat, valid=flat >= 0,
                                        cfg=cfg)
            raw_hit = hit_f.reshape(w, cap)
            kept, payload = compact_hit_rows(raw_hit,
                                             rows_f.reshape(w, cap, d), hc)
            words = pack_hit_bitmap(kept)
        wire = _WireStats(
            n_demoted=jnp.sum(jnp.logical_and(raw_hit, ~kept))
            .astype(jnp.int32),
            hit_peak=jnp.max(jnp.sum(raw_hit, axis=1)).astype(jnp.int32),
            probe_bytes=probe_bytes + w * n_words * 4 + w * hc * d * item)
        words_b = lax.all_to_all(words, axis_name,
                                 split_axis=0, concat_axis=0, tiled=True)
        pay_b = lax.all_to_all(payload, axis_name,
                               split_axis=0, concat_axis=0, tiled=True)
        hit_b = unpack_hit_bitmap(words_b, cap)
        rows_b = expand_hit_rows(hit_b, pay_b)
        row_dtype = payload.dtype
    else:
        hit_f, rows_f = cache_probe(cache, flat, valid=flat >= 0, cfg=cfg)
        hit2 = hit_f.reshape(w, cap)
        wire = _WireStats(
            n_demoted=jnp.int32(0),
            hit_peak=jnp.max(jnp.sum(hit2, axis=1)).astype(jnp.int32),
            probe_bytes=probe_bytes + w * cap * 1 + w * cap * d * item)
        hit_b = lax.all_to_all(hit2, axis_name,
                               split_axis=0, concat_axis=0, tiled=True)
        rows_b = lax.all_to_all(rows_f.reshape(w, cap, d), axis_name,
                                split_axis=0, concat_axis=0, tiled=True)
        row_dtype = rows_f.dtype
    g = (jnp.clip(plan.sorted_dest, 0, w - 1), jnp.clip(plan.slot_c, 0, cap - 1))
    got_hit = jnp.logical_and(hit_b[g], plan.ok)
    got_rows = jnp.where(got_hit[:, None], rows_b[g], 0)
    hit = jnp.zeros((r,), jnp.bool_).at[plan.order].set(got_hit)
    hit_rows = jnp.zeros((r, d), row_dtype).at[plan.order].set(got_rows)
    return hit, hit_rows, plan, recv, wire


def _shard_admit(
    cache: FeatureCache,
    cfg: CacheConfig,
    plan: _RoutePlan,
    recv_ids: jax.Array,
    fetched: jax.Array,
    should: jax.Array,
    axis_name: str,
    w: int,
):
    """Stage-2 write-back: offer owner-fetched rows to their shard holders.

    Reuses the probe round's slot assignment, so the shard holder pairs
    each incoming row with the id it probed at that slot — admission
    updates the AUTHORITATIVE shard, not the requester's local state.
    Returns ``(new_cache, n_inserted)`` for THIS worker's shard.
    """
    cap = recv_ids.shape[1]
    d = fetched.shape[1]
    send_rows = jnp.zeros((w, cap, d), fetched.dtype)
    send_rows = send_rows.at[plan.sorted_dest, plan.slot_c].set(
        fetched[plan.order], mode="drop")
    send_should = jnp.zeros((w, cap), jnp.bool_)
    send_should = send_should.at[plan.sorted_dest, plan.slot_c].set(
        should[plan.order], mode="drop")
    recv_rows = lax.all_to_all(send_rows, axis_name,
                               split_axis=0, concat_axis=0, tiled=True)
    recv_should = lax.all_to_all(send_should, axis_name,
                                 split_axis=0, concat_axis=0, tiled=True)
    ids_f = recv_ids.reshape(-1)
    offer = jnp.logical_and(recv_should.reshape(-1), ids_f >= 0)
    return cache_insert(cache, ids_f, recv_rows.reshape(-1, d), offer, cfg)


class _TierProbe(NamedTuple):
    """What a cache-mode strategy's probe stage hands back to ``fetch_rows``.

    ``l1_hit``/``local``/(``hit`` minus both) are the disjoint hit
    populations ``CacheStats`` reports; ``wire`` is the probe round's
    ``_WireStats`` telemetry (zeros / 0 bytes when no probe round ran);
    ``ctx`` is mode-private state the matching admit stage consumes
    (e.g. the shard-probe ``_RoutePlan``)."""
    hit: jax.Array       # [R] served by ANY cache tier
    rows: jax.Array      # [R, D] the serving tier's row copies
    l1_hit: jax.Array    # [R] subset served by the replicated L1 (tiered)
    local: jax.Array     # [R] subset served by THIS worker's main tier
    wire: _WireStats     # probe-round wire telemetry (see _WireStats)
    ctx: tuple           # opaque probe context for the admit stage


def _zeros_like_hits(ids):
    return jnp.zeros(ids.shape, jnp.bool_)


def _no_wire() -> _WireStats:
    """Wire telemetry of a fetch with no probe round (local probes only)."""
    return _WireStats(jnp.int32(0), jnp.int32(0), 0)


class _ReplicatedTier:
    """mode="replicated": local probe, local admission."""

    @staticmethod
    def probe(cache, cfg, ids, valid, axis_name, cap, w):
        hit, rows = cache_probe(cache, ids, valid, cfg=cfg)
        return _TierProbe(hit, rows, _zeros_like_hits(ids), hit,
                          _no_wire(), ())

    @staticmethod
    def admit(cache, cfg, probe, ids, fetched, should, axis_name, w):
        return cache_insert(cache, ids, fetched, should, cfg)


class _ShardedTier:
    """mode="sharded": one probe round to the shard holders, admission
    routed back on the same plan.  W == 1 degenerates to the replicated
    behavior (the single worker owns every shard)."""

    @staticmethod
    def probe(cache, cfg, ids, valid, axis_name, cap, w):
        if w == 1:
            hit, rows = cache_probe(cache, ids, valid, cfg=cfg)
            return _TierProbe(hit, rows, _zeros_like_hits(ids), hit,
                              _no_wire(), ())
        hit, rows, plan, recv, wire = _shard_probe(cache, cfg, ids, valid,
                                                   axis_name, cap, w)
        local = jnp.logical_and(hit,
                                shard_of(ids, w) == lax.axis_index(axis_name))
        return _TierProbe(hit, rows, _zeros_like_hits(ids), local, wire,
                          (plan, recv))

    @staticmethod
    def admit(cache, cfg, probe, ids, fetched, should, axis_name, w):
        if w == 1:
            return cache_insert(cache, ids, fetched, should, cfg)
        plan, recv = probe.ctx
        return _shard_admit(cache, cfg, plan, recv, fetched, should,
                            axis_name, w)


class _TieredTier:
    """mode="tiered": the three-stage composition — local L1 probe, shard
    probe (L2) for the L1 misses, owner fetch for the rest; admission
    updates the authoritative L2 shard AND offers the L2-served rows to
    the requester's L1 (installed after ``l1_promote`` observations)."""

    @staticmethod
    def probe(cache, cfg, ids, valid, axis_name, cap, w):
        if w == 1:
            # single worker owns both tiers: the fused local probe (the
            # two-tier Pallas kernel when set_probe_impl('pallas'))
            l1_hit, l2_hit, rows = tiered_probe(cache, ids, valid, cfg=cfg)
            return _TierProbe(jnp.logical_or(l1_hit, l2_hit), rows,
                              l1_hit, l2_hit, _no_wire(),
                              (None, None, l2_hit))
        l1_hit, l1_rows = cache_probe(cache.l1, ids, valid,
                                      cfg=cfg.l1_config())
        # only L1 misses enter the probe round — the wire-byte win the
        # compact codec compounds (fewer probe hits -> a tighter hit_cap)
        l2_valid = jnp.logical_and(valid, ~l1_hit)
        l2_hit, l2_rows, plan, recv, wire = _shard_probe(
            cache.l2, cfg.l2_config(), ids, l2_valid, axis_name, cap, w)
        rows = jnp.where(l1_hit[:, None], l1_rows, l2_rows)
        local = jnp.logical_and(
            l2_hit, shard_of(ids, w) == lax.axis_index(axis_name))
        return _TierProbe(jnp.logical_or(l1_hit, l2_hit), rows, l1_hit,
                          local, wire, (plan, recv, l2_hit))

    @staticmethod
    def admit(cache, cfg, probe, ids, fetched, should, axis_name, w):
        plan, recv, l2_hit = probe.ctx
        if w == 1:
            new_l2, n_l2 = cache_insert(cache.l2, ids, fetched, should,
                                        cfg.l2_config())
        else:
            new_l2, n_l2 = _shard_admit(cache.l2, cfg.l2_config(), plan,
                                        recv, fetched, should, axis_name, w)
        # L1 promotion is strictly L2 -> L1: only rows the L2 tier SERVED
        # this round (verbatim table copies that already survived the L2's
        # frequency admission — the proven-hot population) are offered to
        # the local L1, installing after l1_promote observations.  Owner-
        # fetched rows are deliberately NOT offered: they missed both
        # tiers, so letting them compete would churn the small L1's
        # admission tags with exactly the cold tail the threshold exists
        # to keep out.
        new_l1, n_l1 = cache_insert(cache.l1, ids, probe.rows, l2_hit,
                                    cfg.l1_config())
        return TieredCache(l1=new_l1, l2=new_l2), n_l2 + n_l1


#: mode -> (probe, admit) strategy — the SINGLE dispatch point; components
#: downstream of it (stats, routing, admission plumbing) are mode-agnostic
_CACHE_TIERS = {
    "replicated": _ReplicatedTier,
    "sharded": _ShardedTier,
    "tiered": _TieredTier,
}


class _FrozenTier:
    """Read-mostly serve view of a base strategy (``cfg.frozen``): the
    probe stage delegates verbatim — hits are served from the warm state
    through the base mode's full flow, probe round included — but the
    admit stage is the IDENTITY.  No admission, no L1 promotion, no
    tag/counter churn, and the admission ``all_to_all`` round disappears
    from the compiled program entirely, so a pre-warmed cache state is
    bit-stable across requests (the serving tier's correctness contract)
    and the request path pays only the probe collectives."""

    def __init__(self, base):
        self._base = base

    def probe(self, cache, cfg, ids, valid, axis_name, cap, w):
        """Delegate to the base mode's probe stage unchanged."""
        return self._base.probe(cache, cfg, ids, valid, axis_name, cap, w)

    def admit(self, cache, cfg, probe, ids, fetched, should, axis_name, w):
        """Identity: the cache state passes through untouched."""
        return cache, jnp.int32(0)


def _cache_tier(cfg: CacheConfig):
    """The (probe, admit) strategy pair for *cfg* — the base mode's pair,
    wrapped read-mostly when ``cfg.frozen`` selects the serve view."""
    if cfg.mode not in _CACHE_TIERS:
        raise ValueError(f"unknown cache mode {cfg.mode!r}; "
                         f"expected one of {sorted(_CACHE_TIERS)}")
    base = _CACHE_TIERS[cfg.mode]
    return _FrozenTier(base) if cfg.frozen else base


def _host_admit(cache, cfg: CacheConfig, adm_ids: jax.Array,
                adm_rows: jax.Array, axis_name: str, w: int):
    """Deferred admission: offer the PREVIOUS step's landed L3 rows.

    With ``store="host"`` the owner fetch never runs, so the cache admits
    the rows the host gather landed one step later (``host_admit=``) —
    the same frequency-admission policy, shifted by the double buffer's
    one-step lag.  Sharded/tiered W > 1 route each row to its cache-shard
    holder first (one all_to_all round, same "admit the AUTHORITATIVE
    shard" rule as ``_shard_admit``); tiered admits into the L2 — the
    L1 sees rows only via the usual L2 -> L1 promotion at probe time.
    Returns ``(new_cache, n_inserted, admit_round_bytes)``.
    """
    s = adm_ids.shape[0]
    d = adm_rows.shape[1]
    if cfg.mode == "tiered":
        target, tcfg = cache.l2, cfg.l2_config()
    else:
        target, tcfg = cache, cfg
    if w == 1 or cfg.mode == "replicated":
        new, n_ins = cache_insert(target, adm_ids, adm_rows,
                                  adm_ids >= 0, tcfg)
        adm_bytes = 0
    else:
        dest = jnp.where(adm_ids >= 0, shard_of(adm_ids, w), w)
        plan = _route_plan(dest, s, w)   # cap = s: routing never overflows
        send_ids = jnp.full((w, s), -1, jnp.int32)
        send_ids = send_ids.at[plan.sorted_dest, plan.slot_c].set(
            adm_ids[plan.order], mode="drop")
        send_rows = jnp.zeros((w, s, d), adm_rows.dtype)
        send_rows = send_rows.at[plan.sorted_dest, plan.slot_c].set(
            adm_rows[plan.order], mode="drop")
        recv_ids = lax.all_to_all(send_ids, axis_name,
                                  split_axis=0, concat_axis=0, tiled=True)
        recv_rows = lax.all_to_all(send_rows, axis_name,
                                   split_axis=0, concat_axis=0, tiled=True)
        flat = recv_ids.reshape(-1)
        new, n_ins = cache_insert(target, flat, recv_rows.reshape(-1, d),
                                  flat >= 0, tcfg)
        adm_bytes = w * s * (4 + d * jnp.dtype(adm_rows.dtype).itemsize)
    if cfg.mode == "tiered":
        return TieredCache(l1=cache.l1, l2=new), n_ins, adm_bytes
    return new, n_ins, adm_bytes


def _host_fetch(ids, axis_name, capacity_slack, capacity, cache, cache_cfg,
                host_admit, d, dtype, w):
    """The ``store="host"`` fetch body: probe tiers, STAGE misses for L3.

    Instead of the routed owner fetch, cache-tier misses are compacted
    into a per-worker staging buffer of ids handed back to the caller as
    a ``HostMissRequest`` — the host store gathers them asynchronously
    and the NEXT step consumes the landed rows (``patch_batch`` fills the
    holes, ``_host_admit`` feeds the cache).  Hit slots are served now;
    staged slots return zero-filled holes flagged ``req.patch``; misses
    beyond the staging capacity are dropped (counted, never silent).
    """
    r = ids.shape[0]
    s = capacity if capacity is not None \
        else probe_round_capacity(r, 1, capacity_slack)
    s = max(int(s), 1)
    req_ids, inverse, req_valid, n_distinct = dedup_requests(ids)
    n_adm = jnp.int32(0)
    adm_bytes = 0
    if cache is not None and host_admit is not None:
        adm_ids, adm_rows = host_admit
        cache, n_adm, adm_bytes = _host_admit(cache, cache_cfg, adm_ids,
                                              adm_rows, axis_name, w)
    tier = _cache_tier(cache_cfg) if cache is not None else None
    if tier is not None:
        probe = tier.probe(cache, cache_cfg, req_ids, req_valid, axis_name,
                           probe_round_capacity(r, w, capacity_slack), w)
        hit = probe.hit
    else:
        probe = None
        hit = jnp.zeros((r,), jnp.bool_)
    # --- stage the misses: compact them into the [S] id buffer ----------
    miss = jnp.logical_and(req_valid, ~hit)
    cs = jnp.cumsum(miss.astype(jnp.int32))
    staged = jnp.logical_and(miss, cs <= s)
    slot_u = cs - 1                       # staging slot per unique slot
    miss_ids = jnp.full((s,), -1, jnp.int32)
    miss_ids = miss_ids.at[jnp.where(staged, slot_u, s)].set(
        req_ids, mode="drop")
    n_staged = jnp.sum(staged).astype(jnp.int32)
    n_overflow = jnp.sum(miss).astype(jnp.int32) - n_staged
    if tier is not None:
        out_u = jnp.where(hit[:, None], probe.rows, 0)
    else:
        out_u = jnp.zeros((r, d), dtype)
    served_u = jnp.logical_or(hit, staged)
    out = out_u[inverse]
    dropped = jnp.sum(~served_u[inverse]).astype(jnp.int32)
    req = HostMissRequest(ids=miss_ids,
                          slot=slot_u[inverse].astype(jnp.int32),
                          patch=staged[inverse])
    gather_bytes = s * (4 + d * jnp.dtype(dtype).itemsize)
    stats = FetchStats(
        jnp.int32(r), n_staged, dropped,
        jnp.int32((probe.wire.probe_bytes if tier is not None else 0)
                  + adm_bytes),
        jnp.int32(gather_bytes))
    if tier is None:
        return out, stats, req
    # tiered L1 promotion still happens at probe time (L2-served rows)
    new_cache = cache
    n_ins = n_adm
    if cache_cfg.mode == "tiered":
        l2_hit = probe.ctx[2]
        new_l1, n_l1_ins = cache_insert(cache.l1, req_ids, probe.rows,
                                        l2_hit, cache_cfg.l1_config())
        new_cache = TieredCache(l1=new_l1, l2=cache.l2)
        n_ins = n_ins + n_l1_ins
    n_hits = jnp.sum(probe.hit).astype(jnp.int32)
    n_l1 = jnp.sum(probe.l1_hit).astype(jnp.int32)
    n_local = jnp.sum(probe.local).astype(jnp.int32)
    row_bytes = d * jnp.dtype(dtype).itemsize
    cstats = CacheStats(
        n_hits=n_hits, n_misses=n_overflow, n_inserted=n_ins,
        bytes_saved=(n_l1 + n_local) * row_bytes, n_local_hits=n_local,
        n_shard_hits=n_hits - n_l1 - n_local, n_l1_hits=n_l1,
        n_probe_demoted=probe.wire.n_demoted,
        probe_hit_peak=probe.wire.hit_peak,
        n_l3_hits=n_staged)
    return out, new_cache, stats, cstats, req


def fetch_rows(
    table_local: jax.Array,
    ids: jax.Array,
    axis_name: str,
    capacity_slack: float = 2.0,
    dedup: bool = True,
    capacity: Optional[int] = None,
    return_stats: bool = False,
    cache: Optional[FeatureCache] = None,
    cache_cfg: Optional[CacheConfig] = None,
    store: Optional[str] = None,
    feat_dim: Optional[int] = None,
    host_admit=None,
):
    """Routed remote row fetch (the MapReduce shuffle, as ``all_to_all``).

    ``table_local`` is this worker's [rows, D] block of a row-sharded table;
    global row ``i`` lives on worker ``i // rows``.  Every worker requests
    ``ids`` [R] and receives the corresponding rows [R, D].

    ``store`` picks where MISSES resolve (default: ``cache_cfg.store``,
    else ``"device"``).  With ``store="host"`` the owner fetch is
    replaced by the L3 *issue/collect* split (``core/host_store.py``):
    cache-tier misses are STAGED into a ``HostMissRequest`` appended to
    the return value (``(out, new_cache, FetchStats, CacheStats, req)``
    cached, ``(out, stats, req)`` uncached) instead of fetched — their
    output rows are zero holes the caller patches one step later with
    the landed host gather (``patch_batch``), and ``host_admit=(ids
    [S], rows [S, D])`` feeds the PREVIOUS step's landed buffer back
    into the cache (deferred admission, ``_host_admit``).  The host path
    requires ``dedup=True``; ``table_local`` may be ``None`` (there is
    no device table) when ``feat_dim`` supplies the row width, and
    ``capacity`` sizes the staging buffer (default: the slack formula
    with W = 1 — staging is per-worker, not per-destination).

    With ``dedup=True`` (default) duplicate ids are collapsed before
    routing: each distinct id occupies at most one wire slot and its row is
    scattered back to every requesting slot.  A fanout tree's request list
    is massively duplicated (hot neighbors, with-replacement sampling), so
    at a given per-destination capacity this slashes the drop rate — and
    because distinct requests per destination can never exceed the
    destination's ``rows``, the default capacity is clamped to ``rows``
    (shrinking the static exchange buffers).

    With ``cache`` (a per-worker ``FeatureCache``/``TieredCache``; requires
    dedup AND ``cache_cfg`` — the ``CacheConfig`` the state was populated
    under, since the slot layout is a property of the state) the distinct
    ids are first probed against the device-resident hot-node cache tier,
    through the mode's (probe, admit) strategy pair (``_CACHE_TIERS``):
    **replicated** probes locally; **sharded** (W > 1) rides one all_to_all
    probe round to the cache-shard workers; **tiered** probes the local
    replicated L1 first (zero network) and sends only L1 misses on the
    probe round — the three-stage flow in the module docstring.  In every
    mode only the cache-tier **misses** enter the owner all_to_all, the
    returned rows are bit-identical to the uncached path (cached rows are
    verbatim table copies), the return value becomes
    ``(out, new_cache, FetchStats, CacheStats)``, and ``n_unique`` counts
    only the ids that went to their owner.

    With ``cache_cfg.frozen`` (the read-mostly serve view,
    ``CacheConfig.serve_view()``) the probe stage runs unchanged but the
    admit stage is the identity: ``new_cache`` is the input state
    bit-for-bit, nothing is admitted or promoted, and the admission
    collectives drop out of the compiled program — the serving tier's
    request-path form.

    The shard-probe round's RESPONSE rides the wire format
    ``cache_cfg.wire`` selects: ``"dense"`` ships a full ``[W, cap, D]``
    row block back (every probe slot pays a row slot, hit or not);
    ``"compact"`` ships a packed hit bitmap plus a row payload bounded by
    ``probe_hit_cap(cache_cfg, cap)`` rows per destination, so stage-1
    bytes scale with hits instead of capacity (see ``_shard_probe``).
    Hits beyond the bound are demoted to owner-fetched misses
    (``CacheStats.n_probe_demoted``) — never a correctness loss.
    ``FetchStats.probe_round_bytes`` reports the bytes the chosen format
    actually shipped, measured from the static exchange buffer shapes.

    Per-destination OWNER capacity defaults to ``ceil(R/W) * slack``
    (clamped as above when dedup is on); pass an explicit ``capacity`` —
    e.g. sized to the steady-state cache-miss count by the warm
    re-calibration hook in ``launch/train.py`` — to shrink the static
    owner-exchange buffers below their cache-unaware cold-start size.  The
    sharded probe round keeps the slack-based size regardless: it carries
    ALL distinct ids (not just misses), so shrinking it with the miss rate
    would spill probes to the owner path and undo the hit rate it was
    sized for.  Requests beyond capacity return zero rows and are counted
    per request slot — pass ``return_stats=True`` to receive
    ``(out, FetchStats)`` instead of silently zero-filled rows.  For
    W == 1 the fetch degenerates to a local gather (no routing; sharded
    mode degenerates to replicated — the single worker owns every shard —
    and ``n_unique`` still reports the would-route distinct/miss count so
    single-device runs measure the same wire-slot telemetry).
    """
    if cache is not None and not dedup:
        raise ValueError("the cache front end requires dedup=True")
    if cache is not None and cache_cfg is None:
        # the slot layout and placement are properties of the POPULATED
        # state; guessing a default here would silently probe an assoc>1
        # or sharded cache with the wrong layout (near-zero hit rate, no
        # error) — the policy object must travel with the state
        raise ValueError("fetch_rows(cache=...) requires cache_cfg "
                         "(the CacheConfig the state was populated under)")
    if store is None:
        store = cache_cfg.store if cache_cfg is not None else "device"
    host = store == "host"
    if host and not dedup:
        raise ValueError('fetch_rows(store="host") requires dedup=True')
    if host and cache_cfg is not None and cache_cfg.frozen:
        raise ValueError('a frozen (read-mostly serve) cache cannot ride '
                         'the L3 staging path — serve misses resolve '
                         'against the device table (see serve_view())')
    if host and table_local is None and feat_dim is None:
        raise ValueError('fetch_rows(store="host") without a device table '
                         'requires feat_dim (the feature row width)')
    if not host and table_local is None:
        raise ValueError('fetch_rows(store="device") requires table_local')
    if not host and host_admit is not None:
        raise ValueError('host_admit only applies to store="host"')
    w = axis_size(axis_name)
    d = table_local.shape[1] if table_local is not None else feat_dim
    dtype = table_local.dtype if table_local is not None else jnp.float32
    rows = table_local.shape[0] if table_local is not None else 0
    r = ids.shape[0]
    if r == 0:
        # empty request batch: nothing to route (uniform across workers —
        # the request shape is static — so skipping the collectives is
        # safe); counters are all zero by conservation
        out = jnp.zeros((0, d), dtype)
        stats = FetchStats(jnp.int32(0), jnp.int32(0), jnp.int32(0),
                           jnp.int32(0), jnp.int32(0))
        if host:
            # deferred admission still runs (a landed buffer may be
            # pending even when this step requests nothing)
            n_adm = jnp.int32(0)
            if cache is not None and host_admit is not None:
                cache, n_adm, _ = _host_admit(cache, cache_cfg,
                                              host_admit[0], host_admit[1],
                                              axis_name, w)
            s0 = max(int(capacity), 1) if capacity is not None else 1
            req = HostMissRequest(jnp.full((s0,), -1, jnp.int32),
                                  jnp.zeros((0,), jnp.int32),
                                  jnp.zeros((0,), jnp.bool_))
            if cache is not None:
                z = jnp.int32(0)
                return out, cache, stats, CacheStats(
                    z, z, n_adm, z, z, z, z, z, z, z), req
            return out, stats, req
        if cache is not None:
            z = jnp.int32(0)
            return out, cache, stats, CacheStats(z, z, z, z, z, z, z, z,
                                                 z, z)
        if return_stats:
            return out, stats
        return out
    if host:
        return _host_fetch(ids, axis_name, capacity_slack, capacity,
                           cache, cache_cfg, host_admit, d, dtype, w)
    if w == 1 and cache is None:
        out = table_local[jnp.clip(ids, 0, rows - 1)]
        if return_stats:
            if dedup:
                n_unique = dedup_requests(ids)[3].astype(jnp.int32)
            else:
                n_unique = jnp.int32(r)
            return out, FetchStats(jnp.int32(r), n_unique, jnp.int32(0),
                                   jnp.int32(0), jnp.int32(0))
        return out
    # the probe round carries ALL distinct ids, so it is sized from the
    # request count even when an explicit miss-sized `capacity` shrinks
    # the owner exchange (see docstring)
    slack_cap = probe_round_capacity(r, w, capacity_slack)
    cap = capacity
    if cap is None:
        cap = slack_cap
        if dedup:
            cap = min(cap, rows)    # ≤ rows distinct ids per destination
    if dedup:
        req_ids, inverse, req_valid, n_unique = dedup_requests(ids)
    else:
        req_ids, inverse = ids, None
        req_valid = jnp.ones((r,), jnp.bool_)
        n_unique = jnp.int32(r)
    # --- cache probe: hits never reach the owner fetch -------------------
    # the mode's (probe, admit) strategy pair is the only mode dispatch —
    # routing, admission plumbing, and stats below are mode-agnostic
    tier = None
    if cache is not None:
        tier = _cache_tier(cache_cfg)
    if tier is not None:
        probe = tier.probe(cache, cache_cfg, req_ids, req_valid,
                           axis_name, slack_cap, w)
        route_valid = jnp.logical_and(req_valid, ~probe.hit)
    else:
        probe = None
        route_valid = req_valid
    # --- route the (remaining) requests to their owners ------------------
    if w == 1:
        fetched = table_local[jnp.clip(req_ids, 0, rows - 1)]
        fetched = jnp.where(route_valid[:, None], fetched, 0)
        served_r = route_valid
    else:
        fetched, served_r = _routed_fetch(
            table_local, req_ids, route_valid, axis_name, cap, w, rows)
    n_routed = jnp.sum(route_valid).astype(jnp.int32)
    # --- merge hits back, offer served misses for admission --------------
    new_cache = None
    cstats = None
    if tier is not None:
        out_u = jnp.where(probe.hit[:, None], probe.rows, fetched)
        served_u = jnp.logical_or(probe.hit, served_r)
        should = jnp.logical_and(route_valid, served_r)
        new_cache, n_ins = tier.admit(cache, cache_cfg, probe, req_ids,
                                      fetched, should, axis_name, w)
        n_hits = jnp.sum(probe.hit).astype(jnp.int32)
        n_l1 = jnp.sum(probe.l1_hit).astype(jnp.int32)
        n_local = jnp.sum(probe.local).astype(jnp.int32)
        row_bytes = table_local.shape[1] * jnp.dtype(table_local.dtype).itemsize
        cstats = CacheStats(
            n_hits=n_hits, n_misses=n_routed, n_inserted=n_ins,
            bytes_saved=(n_l1 + n_local) * row_bytes, n_local_hits=n_local,
            n_shard_hits=n_hits - n_l1 - n_local, n_l1_hits=n_l1,
            n_probe_demoted=probe.wire.n_demoted,
            probe_hit_peak=probe.wire.hit_peak,
            n_l3_hits=jnp.int32(0))
        n_unique = n_routed          # ids that went to their owner
    else:
        out_u, served_u = fetched, served_r
    if dedup:
        out = out_u[inverse]
        # a dropped unique id zero-fills EVERY duplicate slot it backed —
        # count affected request slots, not wire slots
        dropped = jnp.sum(~served_u[inverse])
    else:
        out = out_u
        dropped = jnp.sum(~served_u)
    stats = FetchStats(jnp.int32(r), jnp.int32(n_unique),
                       dropped.astype(jnp.int32),
                       jnp.int32(probe.wire.probe_bytes if tier is not None
                                 else 0),
                       jnp.int32(0))
    if cache is not None:
        return out, new_cache, stats, cstats
    if return_stats:
        return out, stats
    return out


def _worker_generate(
    indptr: jax.Array,       # [N+1] local CSR
    indices: jax.Array,      # [E_pad]
    x_local: jax.Array,      # [rows, D] node features (row-sharded)
    y_local: jax.Array,      # [rows, 1] labels (row-sharded)
    seeds: jax.Array,        # [b] seeds owned by this worker (balance table row)
    rng: jax.Array,
    cache: Optional[FeatureCache] = None,   # per-worker hot-node cache state
    *,
    fanouts: Tuple[int, ...],
    axis_name: str,
    merge_mode: str = "butterfly",
    capacity_slack: float = 2.0,
    cache_cfg: Optional[CacheConfig] = None,
    fetch_capacity: Optional[int] = None,
    feature_store: str = "device",
    feat_dim: Optional[int] = None,
    host_admit=None,         # (ids [S], rows [S, D]) landed one step ago
    collect_stats: bool = False,
):
    """One worker's slice of an L-hop generation round (runs in shard_map).

    Per hop: broadcast frontier -> ``local_candidates`` scan -> tree merge
    (butterfly allreduce or recursive-halving reduce-scatter); the merged
    global sample becomes the next frontier.  Masks chain so a padded
    parent's subtree stays padded.  Then one deduplicated feature shuffle
    fetches every node's row, probing the hot-node cache tier first when
    one is threaded in — locally in replicated mode, via the two-stage
    shard routing in sharded mode (returns ``(SubgraphBatch, new_cache)``
    in either case).  ``cache_cfg`` is the single source of cache policy;
    ``fetch_capacity`` pins the owner-exchange buffer size (the warm
    re-calibration hook shrinks it to the steady-state miss count).

    With ``feature_store="host"`` the feature table lives in host RAM
    behind the L3 store: ``x_local`` is ``None`` (``feat_dim`` supplies
    the row width), the feature shuffle STAGES its cache misses instead
    of owner-fetching them, and the returns grow a ``HostMissRequest``
    tail — ``(batch, cache, req)`` cached / ``(batch, req)`` uncached.
    The batch's staged feature slots are zero holes until the caller
    patches them with the landed host gather (``patch_batch``); labels
    stay device-resident either way.

    With ``collect_stats=True`` (the autotuner's trace seam) the return
    grows a ``(FetchStats, CacheStats)`` tail: the feature shuffle's
    per-worker telemetry, normally folded into the few ``SubgraphBatch``
    counters, rides out whole so the trace recorder can keep per-step
    records.  Uncached runs ship a synthesized ``CacheStats`` whose only
    nonzero field is the conservation remainder (``n_misses`` for the
    device store, ``n_l3_hits`` for staged host fetches), so the
    invariant ``n_l1 + n_local + n_shard + n_l3 + n_misses ==
    n_distinct`` holds for every traced configuration.
    """
    b = seeds.shape[0]
    me = lax.axis_index(axis_name)
    rng = jax.random.fold_in(rng, me)
    hop_rngs = jax.random.split(rng, max(len(fanouts), 2))

    frontier = lax.all_gather(seeds, axis_name, tiled=True)   # [B] global
    parent_mask = jnp.ones(frontier.shape, jnp.bool_)
    hops, masks = [], []
    shape = (b,)                # local tree shape accumulator
    local_rows = b              # b * k_1 * ... * k_l (this worker's rows)
    for level, k in enumerate(fanouts):
        cand = local_candidates(indptr, indices, frontier, k, hop_rngs[level])
        # padding must not spawn children:
        cand = Candidates(
            ids=cand.ids,
            keys=jnp.where(parent_mask[:, None], cand.keys, jnp.inf),
        )
        if merge_mode == "reduce_scatter":
            # beyond-paper: recursive-halving merge — each worker
            # materializes only ITS segment of the frontier
            # (tree_reduce.py); ~4x less ICI traffic than the butterfly
            # at W=16.
            seg = tree_reduce_scatter(cand, merge_topk, axis_name)
            m = jnp.isfinite(seg.keys)                        # [rows_l, k]
            h = jnp.where(m, seg.ids, 0)
            # the next frontier must still be GLOBAL (edge-centric: every
            # worker scans its local edges against all hop-l nodes)
            h_all = lax.all_gather(h, axis_name, tiled=True)
            m_all = lax.all_gather(m, axis_name, tiled=True)
        else:
            merged = tree_allreduce(cand, merge_topk, axis_name)  # [F, k]
            m_all = jnp.isfinite(merged.keys)
            h_all = jnp.where(m_all, merged.ids, 0)
            h = lax.dynamic_slice_in_dim(h_all, me * local_rows, local_rows, 0)
            m = lax.dynamic_slice_in_dim(m_all, me * local_rows, local_rows, 0)
        shape = shape + (k,)
        hops.append(h.reshape(shape))
        masks.append(m.reshape(shape))
        frontier = h_all.reshape(-1)                          # [F * k]
        parent_mask = m_all.reshape(-1)
        local_rows *= k

    # chain masks explicitly (the +inf-key propagation already implies this;
    # keep the invariant structural, not sampler-dependent)
    for level in range(1, len(masks)):
        masks[level] = jnp.logical_and(masks[level], masks[level - 1][..., None])

    # --- feature shuffle: one deduplicated fetch for every node slot,
    # cache-probed first when a hot-node cache is threaded through ---
    need = jnp.concatenate([seeds] + [h.reshape(-1) for h in hops])
    host = feature_store == "host"
    req = None
    if cache is not None and host:
        feats, cache, fstats, cstats, req = fetch_rows(
            x_local, need, axis_name, capacity_slack=capacity_slack,
            capacity=fetch_capacity, cache=cache, cache_cfg=cache_cfg,
            store="host", feat_dim=feat_dim, host_admit=host_admit)
        n_hits, n_misses = cstats.n_hits, cstats.n_misses
        n_demoted = cstats.n_probe_demoted
    elif cache is not None:
        feats, cache, fstats, cstats = fetch_rows(
            x_local, need, axis_name, capacity_slack=capacity_slack,
            capacity=fetch_capacity, cache=cache, cache_cfg=cache_cfg,
            store="device")
        n_hits, n_misses = cstats.n_hits, cstats.n_misses
        n_demoted = cstats.n_probe_demoted
    elif host:
        feats, fstats, req = fetch_rows(
            x_local, need, axis_name, capacity_slack=capacity_slack,
            capacity=fetch_capacity, store="host", feat_dim=feat_dim)
        n_hits, n_misses = jnp.int32(0), fstats.n_unique
        n_demoted = jnp.int32(0)
    else:
        feats, fstats = fetch_rows(x_local, need, axis_name,
                                   capacity_slack=capacity_slack,
                                   capacity=fetch_capacity,
                                   return_stats=True)
        n_hits, n_misses = jnp.int32(0), fstats.n_unique
        n_demoted = jnp.int32(0)
    d = x_local.shape[1] if x_local is not None else feat_dim
    x_seed = feats[:b]
    x_hops = []
    off = b
    n = b
    for level, k in enumerate(fanouts):
        n *= k
        x = feats[off:off + n].reshape(masks[level].shape + (d,))
        x_hops.append(x * masks[level][..., None])
        off += n
    # balance-table seeds are already distinct per worker — skip the dedup
    # front end for the label fetch
    ys, ystats = fetch_rows(y_local, seeds, axis_name,
                            capacity_slack=capacity_slack, dedup=False,
                            return_stats=True)
    labels = ys[:, 0].astype(jnp.int32)

    batch = SubgraphBatch(
        seeds=seeds,
        hops=tuple(hops),
        masks=tuple(masks),
        x_seed=x_seed,
        x_hops=tuple(x_hops),
        labels=labels,
        n_dropped=(fstats.n_dropped + ystats.n_dropped)[None],
        n_cache_hits=n_hits[None],
        n_cache_misses=n_misses[None],
        n_probe_demoted=n_demoted[None],
    )
    if collect_stats:
        if cache is None:
            # synthesize the cache-tier view of an uncached fetch so the
            # trace's conservation check holds: every distinct id either
            # routed to its owner (device store -> n_misses) or staged
            # for the L3 gather (host store -> n_l3_hits)
            z = jnp.int32(0)
            cstats = CacheStats(
                n_hits=z, n_misses=z if host else fstats.n_unique,
                n_inserted=z, bytes_saved=z, n_local_hits=z,
                n_shard_hits=z, n_l1_hits=z, n_probe_demoted=z,
                probe_hit_peak=z,
                n_l3_hits=fstats.n_unique if host else z)
        stats = (fstats, cstats)
        if cache is not None and req is not None:
            return batch, cache, req, stats
        if cache is not None:
            return batch, cache, stats
        if req is not None:
            return batch, req, stats
        return batch, stats
    if cache is not None and req is not None:
        return batch, cache, req
    if cache is not None:
        return batch, cache
    if req is not None:
        return batch, req
    return batch


def shard_rows(table: np.ndarray, n_workers: int) -> np.ndarray:
    """Pad a [N, D] host table to [W * rows, D] so it row-shards evenly."""
    n = table.shape[0]
    rows = -(-n // n_workers)
    pad = n_workers * rows - n
    if pad:
        table = np.concatenate([table, np.zeros((pad,) + table.shape[1:], table.dtype)])
    return table


def make_generator_fn(
    mesh: Mesh,
    *,
    fanouts: Tuple[int, ...] = (40, 20),
    axis_name: str = "data",
    merge_mode: str = "butterfly",
    capacity_slack: float = 2.0,
    cache_cfg: Optional[CacheConfig] = None,
    fetch_capacity: Optional[int] = None,
    feature_store: str = "device",
    feat_dim: Optional[int] = None,
    collect_stats: bool = False,
):
    """Pure generator function (no data placement — dry-run lowerable).

    ``gen_fn(device_args, seeds [W, b], rng) -> SubgraphBatch`` where
    ``device_args = (indptr [W,N+1], indices [W,E_pad], x [W*rows,D],
    y [W*rows,1])`` sharded on their leading axis.

    With ``feature_store="host"`` (requires ``feat_dim``) the feature
    table never reaches the device: ``device_args`` shrinks to
    ``(indptr, indices, y)`` and every generation returns a stacked
    ``HostMissRequest`` tail for the L3 store —
    ``gen_fn(device_args, seeds, rng) -> (batch, req)`` uncached, or
    ``gen_fn(device_args, seeds, rng, cache, admit_ids [W, S], admit_rows
    [W, S, D]) -> (batch, cache, req)`` cached, where ``admit_*`` is the
    previous step's landed gather (``host_store.empty_admit`` for the
    prologue) consumed for deferred cache admission.

    With a ``cache_cfg`` (a ``CacheConfig`` with ``n_rows > 0``) the
    generator becomes stateful-by-threading:
    ``gen_fn(device_args, seeds, rng, cache) -> (SubgraphBatch, cache)``
    where ``cache`` is a [W, ...] cache-state pytree (``FeatureCache``,
    or ``TieredCache`` in tiered mode) sharded ``P(axis_name)`` on its
    leading axis — one replica per worker in replicated mode, one
    authoritative shard per worker in sharded mode, and both at once
    (L1 replica + L2 shard) in tiered mode.
    ``fetch_capacity`` (optional) pins the per-destination owner-exchange
    capacity; the warm re-calibration hook uses it to shrink the static
    all_to_all buffers to the steady-state cache-miss count.

    With a FROZEN ``cache_cfg`` (``CacheConfig.serve_view()``) the
    generator takes the forward-only serve form:
    ``gen_fn(device_args, seeds, rng, cache) -> SubgraphBatch`` — the
    cache is a read-only input (probed, never admitted into, and not
    returned: read-mostly state has no next version to thread), which is
    what lets the serving tier hold ONE warm state and replay it across
    every request without carry plumbing.

    With ``collect_stats=True`` every signature's return grows a stacked
    ``(FetchStats, CacheStats)`` tail (leaves ``[W]``-leading, sharded
    ``P(axis_name)``) — the instrumented form the autotuner's trace
    recorder compiles.  Not available on the frozen serve form (the
    request path ships answers, not telemetry)."""
    if not fanouts:
        raise ValueError("fanouts must name at least one hop, got ()")
    if feature_store not in ("device", "host"):
        raise ValueError(f"feature_store must be 'device' or 'host', "
                         f"got {feature_store!r}")
    host = feature_store == "host"
    if host and feat_dim is None:
        raise ValueError('make_generator_fn(feature_store="host") '
                         'requires feat_dim (no device table to read it '
                         'from)')
    graph_spec = P(axis_name)
    row_spec = P(axis_name)
    repl = P()
    cached = cache_cfg is not None and cache_cfg.n_rows > 0
    frozen = cached and cache_cfg.frozen
    if frozen and host:
        raise ValueError('a frozen (read-mostly serve) cache cannot ride '
                         'the L3 staging path — build the serve generator '
                         'with feature_store="device"')
    if frozen and collect_stats:
        raise ValueError('collect_stats instruments the training-path '
                         'generator; the frozen serve form ships answers, '
                         'not telemetry — trace before serve_view()')
    if cached:
        cache_cfg = cache_cfg.validated()
        if cache_cfg.store != feature_store:
            # the generator's feature_store is authoritative — normalize
            # the cfg instead of letting the two silently disagree
            cache_cfg = cache_cfg._replace(store=feature_store)

    worker_gen = functools.partial(
        _worker_generate, fanouts=tuple(fanouts), axis_name=axis_name,
        merge_mode=merge_mode, capacity_slack=capacity_slack,
        cache_cfg=cache_cfg if cached else None,
        fetch_capacity=fetch_capacity,
        feature_store=feature_store, feat_dim=feat_dim,
        collect_stats=collect_stats)

    # the instrumented (collect_stats) form appends the per-worker
    # (FetchStats, CacheStats) pytree, restored to a [W] leading axis
    # exactly like the cache state; each wrapper/spec grows the same tail
    def _stats_tail(stats):
        return jax.tree.map(lambda a: a[None], stats)

    def _specs(*base):
        return base + ((P(axis_name),) if collect_stats else ())

    # shard_map blocks keep the sharded leading axis of size 1 per worker;
    # the wrappers drop it on the way in and restore it on the way out.
    def worker_fn(indptr, indices, xs, ys, seeds, rng):
        out = worker_gen(indptr[0], indices[0], xs, ys, seeds[0], rng)
        if collect_stats:
            batch, stats = out
            return batch, _stats_tail(stats)
        return out

    def worker_fn_cached(indptr, indices, xs, ys, seeds, rng, cache):
        out = worker_gen(indptr[0], indices[0], xs, ys, seeds[0],
                         rng, squeeze_worker_axis(cache))
        if collect_stats:
            batch, cache, stats = out
            return batch, restore_worker_axis(cache), _stats_tail(stats)
        batch, cache = out
        return batch, restore_worker_axis(cache)

    # forward-only serve form: the frozen admit stage already returns the
    # state untouched, so there is no next cache version to ship out —
    # dropping it here removes the state round-trip from the request path
    def worker_fn_frozen(indptr, indices, xs, ys, seeds, rng, cache):
        batch, _ = worker_gen(indptr[0], indices[0], xs, ys, seeds[0],
                              rng, squeeze_worker_axis(cache))
        return batch

    # host-store variants: no device feature table; the HostMissRequest
    # comes back stacked [W, ...] (out_specs P(axis_name), leading axis
    # restored the same way as the cache state)
    def worker_fn_host(indptr, indices, ys, seeds, rng):
        out = worker_gen(indptr[0], indices[0], None, ys, seeds[0], rng)
        if collect_stats:
            batch, req, stats = out
            return (batch, jax.tree.map(lambda a: a[None], req),
                    _stats_tail(stats))
        batch, req = out
        return batch, jax.tree.map(lambda a: a[None], req)

    def worker_fn_host_cached(indptr, indices, ys, seeds, rng, cache,
                              adm_ids, adm_rows):
        out = worker_gen(
            indptr[0], indices[0], None, ys, seeds[0], rng,
            squeeze_worker_axis(cache),
            host_admit=(adm_ids[0], adm_rows[0]))
        if collect_stats:
            batch, cache, req, stats = out
            return (batch, restore_worker_axis(cache),
                    jax.tree.map(lambda a: a[None], req),
                    _stats_tail(stats))
        batch, cache, req = out
        return (batch, restore_worker_axis(cache),
                jax.tree.map(lambda a: a[None], req))

    if host and cached:
        def gen_fn(device_args, seeds, rng, cache, admit_ids, admit_rows):
            indptr, indices, ys = device_args
            return shard_map(
                worker_fn_host_cached,
                mesh=mesh,
                in_specs=(graph_spec, graph_spec, row_spec, graph_spec,
                          repl, P(axis_name), P(axis_name), P(axis_name)),
                out_specs=_specs(P(axis_name), P(axis_name), P(axis_name)),
                check_rep=False,
            )(indptr, indices, ys, seeds, rng, cache, admit_ids,
              admit_rows)
    elif host:
        def gen_fn(device_args, seeds, rng):
            indptr, indices, ys = device_args
            return shard_map(
                worker_fn_host,
                mesh=mesh,
                in_specs=(graph_spec, graph_spec, row_spec, graph_spec,
                          repl),
                out_specs=_specs(P(axis_name), P(axis_name)),
                check_rep=False,
            )(indptr, indices, ys, seeds, rng)
    elif cached and frozen:
        def gen_fn(device_args, seeds, rng, cache):
            indptr, indices, xs, ys = device_args
            return shard_map(
                worker_fn_frozen,
                mesh=mesh,
                in_specs=(graph_spec, graph_spec, row_spec, row_spec,
                          graph_spec, repl, P(axis_name)),
                out_specs=P(axis_name),
                check_rep=False,
            )(indptr, indices, xs, ys, seeds, rng, cache)
    elif cached:
        def gen_fn(device_args, seeds, rng, cache):
            indptr, indices, xs, ys = device_args
            return shard_map(
                worker_fn_cached,
                mesh=mesh,
                in_specs=(graph_spec, graph_spec, row_spec, row_spec,
                          graph_spec, repl, P(axis_name)),
                out_specs=_specs(P(axis_name), P(axis_name)),
                check_rep=False,
            )(indptr, indices, xs, ys, seeds, rng, cache)
    else:
        def gen_fn(device_args, seeds, rng):
            indptr, indices, xs, ys = device_args
            return shard_map(
                worker_fn,
                mesh=mesh,
                in_specs=(graph_spec, graph_spec, row_spec, row_spec,
                          graph_spec, repl),
                out_specs=(_specs(P(axis_name)) if collect_stats
                           else P(axis_name)),
                check_rep=False,
            )(indptr, indices, xs, ys, seeds, rng)

    return gen_fn


def make_distributed_generator(
    mesh: Mesh,
    part: PartitionedGraph,
    features: np.ndarray,
    labels: np.ndarray,
    *,
    fanouts: Tuple[int, ...] = (40, 20),
    axis_name: str = "data",
    merge_mode: str = "butterfly",
    capacity_slack: float = 2.0,
    cache_cfg: Optional[CacheConfig] = None,
    fetch_capacity: Optional[int] = None,
    feature_store: str = "device",
    host_gather_depth: int = 2,
    collect_stats: bool = False,
):
    """Build the jitted distributed generator with data placed on the mesh.

    Returns ``(gen_fn, device_args)``; every output leaf is sharded
    ``P(axis_name)`` on its leading (global-batch) axis.  With a
    ``cache_cfg`` an initial (empty) per-worker ``FeatureCache`` is
    also placed on the mesh and the return becomes
    ``(gen_fn, device_args, cache0)`` with
    ``gen_fn(device_args, seeds, rng, cache) -> (batch, cache)``.

    With ``feature_store="host"`` the feature table stays in host RAM —
    unsharded, unpadded — behind a ``HostFeatureStore`` (depth
    ``host_gather_depth``); only the CSR and labels are placed on the
    mesh and the returns become ``(gen_fn, device_args, store)`` /
    ``(gen_fn, device_args, store, cache0)`` (see ``make_generator_fn``
    for the host-mode ``gen_fn`` signature).

    ``collect_stats=True`` builds the instrumented (trace-recorder) form:
    ``gen_fn`` additionally returns a stacked per-worker
    ``(FetchStats, CacheStats)`` tail — see ``make_generator_fn``."""
    w = mesh.shape[axis_name]
    assert part.n_workers == w, (part.n_workers, w)
    host = feature_store == "host"
    y = shard_rows(labels.reshape(-1, 1).astype(np.float32), w)
    gen_fn = make_generator_fn(
        mesh, fanouts=fanouts, axis_name=axis_name, merge_mode=merge_mode,
        capacity_slack=capacity_slack, cache_cfg=cache_cfg,
        fetch_capacity=fetch_capacity, feature_store=feature_store,
        feat_dim=int(features.shape[1]) if host else None,
        collect_stats=collect_stats)
    spec = NamedSharding(mesh, P(axis_name))
    cached = cache_cfg is not None and cache_cfg.n_rows > 0
    if host:
        table = (features if features.dtype == np.float32
                 else features.astype(np.float32))
        store = HostFeatureStore(table, depth=host_gather_depth,
                                 sharding=spec)
        device_args = (
            jax.device_put(part.indptr, spec),
            jax.device_put(part.indices, spec),
            jax.device_put(y, spec),
        )
        if cached:
            cache0 = jax.device_put(
                init_cache_state(cache_cfg.validated(), table.shape[1], w),
                spec)
            return jax.jit(gen_fn), device_args, store, cache0
        return jax.jit(gen_fn), device_args, store
    x = shard_rows(features.astype(np.float32), w)
    device_args = (
        jax.device_put(part.indptr, spec),
        jax.device_put(part.indices, spec),
        jax.device_put(x, spec),
        jax.device_put(y, spec),
    )
    if cached:
        cache0 = jax.device_put(
            init_cache_state(cache_cfg.validated(), x.shape[1], w), spec)
        return jax.jit(gen_fn), device_args, cache0
    return jax.jit(gen_fn), device_args
