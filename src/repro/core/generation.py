"""Distributed Subgraph Generation (paper §2 step 3) — edge-centric, in JAX.

The paper's MapReduce formulation: every worker scans *its own edge
partition* against the current frontier in parallel (edge-centric — hot
nodes parallelize because their edge lists are split across partitions),
then partial per-seed subgraphs are aggregated through a **tree reduction**
to the seed's owner.

TPU-native mapping (DESIGN.md §2):

  1. frontier broadcast     — ``lax.all_gather`` of owned seeds.
  2. local edge scan        — each worker samples ``k`` candidate neighbors
                              per frontier node from its local CSR (a pure
                              gather over the local edge array: fully
                              parallel, no hot-node serialization).
  3. tree aggregation       — candidates carry *weighted reservoir keys*
                              (exponential race, A-ES scheme): the merge
                              "keep the k smallest keys" is associative, so
                              the butterfly ``tree_allreduce`` yields, at
                              every worker, a weighted sample of the UNION
                              of all workers' local edges — i.e. a uniform
                              fanout sample of the global neighborhood.
  4. feature shuffle        — dense node features are fetched from their
                              owner workers with a routed ``all_to_all``
                              exchange (the MapReduce shuffle).

Edges sampled for several seeds are *replicated* into each seed's subgraph
(paper step 3), which falls out of sampling per frontier slot.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from ..graph.subgraph import SubgraphBatch
from .partition import PartitionedGraph
from .tree_reduce import tree_allreduce, tree_reduce_scatter


class Candidates(NamedTuple):
    ids: jax.Array    # [F, k] neighbor node ids
    keys: jax.Array   # [F, k] reservoir keys (+inf = invalid)


def local_candidates(
    indptr: jax.Array,
    indices: jax.Array,
    frontier: jax.Array,
    k: int,
    rng: jax.Array,
) -> Candidates:
    """Sample ``k`` neighbors-with-replacement of each frontier node from a
    local CSR partition, tagged with weighted reservoir keys.

    Each draw represents ``deg_local / k`` edges, so its key is an
    Exponential(rate = deg_local / k) variate — the min-k merge over workers
    is then a weighted (≈ uniform-over-global-edges) sample of the union.
    """
    f = frontier.shape[0]
    node = jnp.clip(frontier, 0, indptr.shape[0] - 2)
    start = indptr[node]
    deg = (indptr[node + 1] - start).astype(jnp.int32)
    r_off, r_key = jax.random.split(rng)
    offs = jax.random.randint(r_off, (f, k), 0, jnp.iinfo(jnp.int32).max)
    offs = offs % jnp.maximum(deg, 1)[:, None]
    ids = indices[jnp.clip(start[:, None] + offs, 0, indices.shape[0] - 1)]
    u = jax.random.uniform(r_key, (f, k), minval=jnp.finfo(jnp.float32).tiny)
    weight = (deg.astype(jnp.float32) / k)[:, None]
    keys = -jnp.log(u) / jnp.maximum(weight, 1e-30)
    keys = jnp.where((deg > 0)[:, None], keys, jnp.inf)
    return Candidates(ids=ids.astype(jnp.int32), keys=keys)


def merge_topk(a: Candidates, b: Candidates) -> Candidates:
    """Associative merge: keep the k smallest keys of the union."""
    k = a.keys.shape[-1]
    keys = jnp.concatenate([a.keys, b.keys], axis=-1)
    ids = jnp.concatenate([a.ids, b.ids], axis=-1)
    neg, idx = lax.top_k(-keys, k)
    return Candidates(ids=jnp.take_along_axis(ids, idx, axis=-1), keys=-neg)


def fetch_rows(
    table_local: jax.Array,
    ids: jax.Array,
    axis_name: str,
    capacity_slack: float = 2.0,
) -> jax.Array:
    """Routed remote row fetch (the MapReduce shuffle, as ``all_to_all``).

    ``table_local`` is this worker's [rows, D] block of a row-sharded table;
    global row ``i`` lives on worker ``i // rows``.  Every worker requests
    ``ids`` [R] and receives the corresponding rows [R, D].

    Per-destination capacity is ``ceil(R/W) * slack``; with shuffled seeds
    the request load is near-multinomial so slack=2 virtually never drops —
    dropped requests (beyond capacity) return zeros and are counted in
    tests.  For W == 1 this degenerates to a local gather.
    """
    w = lax.axis_size(axis_name)
    rows = table_local.shape[0]
    r = ids.shape[0]
    if w == 1:
        return table_local[jnp.clip(ids, 0, rows - 1)]
    cap = int(min(r, -(-r // w) * capacity_slack + 8))
    owner = jnp.clip(ids // rows, 0, w - 1)
    order = jnp.argsort(owner)
    sorted_owner = owner[order]
    first = jnp.searchsorted(sorted_owner, sorted_owner, side="left")
    slot = jnp.arange(r, dtype=jnp.int32) - first
    ok = slot < cap
    # overflow requests go OUT OF BOUNDS so mode="drop" discards them
    # (clipping would overwrite the request already in the last slot)
    slot_c = jnp.where(ok, slot, cap)
    send = jnp.zeros((w, cap), dtype=jnp.int32)
    send = send.at[sorted_owner, slot_c].set(ids[order], mode="drop")
    recv = lax.all_to_all(send, axis_name, split_axis=0, concat_axis=0, tiled=True)
    me = lax.axis_index(axis_name)
    local = jnp.clip(recv - me * rows, 0, rows - 1)
    served = table_local[local]                      # [w, cap, D]
    resp = lax.all_to_all(served, axis_name, split_axis=0, concat_axis=0, tiled=True)
    got = resp[sorted_owner, jnp.clip(slot_c, 0, cap - 1)]   # [R, D] (sorted)
    got = jnp.where(ok[:, None], got, 0)
    out = jnp.zeros((r, table_local.shape[1]), table_local.dtype)
    return out.at[order].set(got)


def _worker_generate(
    indptr: jax.Array,       # [N+1] local CSR
    indices: jax.Array,      # [E_pad]
    x_local: jax.Array,      # [rows, D] node features (row-sharded)
    y_local: jax.Array,      # [rows, 1] labels (row-sharded)
    seeds: jax.Array,        # [b] seeds owned by this worker (balance table row)
    rng: jax.Array,
    *,
    k1: int,
    k2: int,
    axis_name: str,
    merge_mode: str = "butterfly",
) -> SubgraphBatch:
    b = seeds.shape[0]
    me = lax.axis_index(axis_name)
    rng = jax.random.fold_in(rng, me)
    r1, r2 = jax.random.split(rng)

    # --- hop 1: broadcast frontier, local edge scan, tree aggregation ---
    frontier1 = lax.all_gather(seeds, axis_name, tiled=True)          # [B]
    cand1 = local_candidates(indptr, indices, frontier1, k1, r1)
    if merge_mode == "reduce_scatter":
        # beyond-paper: recursive-halving merge — each worker materializes
        # only ITS segment of the frontier (tree_reduce.py); ~4x less ICI
        # traffic than the butterfly at W=16.
        seg1 = tree_reduce_scatter(cand1, merge_topk, axis_name)      # [b, k1]
        mask1 = jnp.isfinite(seg1.keys)
        hop1 = jnp.where(mask1, seg1.ids, 0)
        # hop-2 frontier must still be GLOBAL (edge-centric: every worker
        # scans its local edges against all hop-1 nodes)
        hop1_all = lax.all_gather(hop1, axis_name, tiled=True)        # [B, k1]
        mask1_all = lax.all_gather(mask1, axis_name, tiled=True)
    else:
        cand1 = tree_allreduce(cand1, merge_topk, axis_name)          # [B, k1]
        mask1_all = jnp.isfinite(cand1.keys)
        hop1_all = jnp.where(mask1_all, cand1.ids, 0)
        hop1 = lax.dynamic_slice_in_dim(hop1_all, me * b, b, 0)       # [b, k1]
        mask1 = lax.dynamic_slice_in_dim(mask1_all, me * b, b, 0)

    frontier2 = hop1_all.reshape(-1)                                  # [B*k1]
    cand2 = local_candidates(indptr, indices, frontier2, k2, r2)
    # hop-1 padding must not spawn hop-2 samples:
    cand2 = Candidates(
        ids=cand2.ids,
        keys=jnp.where(mask1_all.reshape(-1)[:, None], cand2.keys, jnp.inf),
    )
    if merge_mode == "reduce_scatter":
        seg2 = tree_reduce_scatter(cand2, merge_topk, axis_name)      # [b*k1, k2]
        mask2 = jnp.isfinite(seg2.keys).reshape(b, k1, k2)
        hop2 = jnp.where(jnp.isfinite(seg2.keys), seg2.ids, 0).reshape(b, k1, k2)
    else:
        cand2 = tree_allreduce(cand2, merge_topk, axis_name)          # [B*k1, k2]
        mask2_all = jnp.isfinite(cand2.keys)
        hop2_all = jnp.where(mask2_all, cand2.ids, 0)
        hop2 = lax.dynamic_slice_in_dim(hop2_all, me * b * k1, b * k1, 0)
        hop2 = hop2.reshape(b, k1, k2)
        mask2 = lax.dynamic_slice_in_dim(mask2_all, me * b * k1, b * k1, 0)
        mask2 = mask2.reshape(b, k1, k2)

    # --- feature shuffle: fetch rows for every node in my subgraphs ---
    need = jnp.concatenate([seeds, hop1.reshape(-1), hop2.reshape(-1)])
    feats = fetch_rows(x_local, need, axis_name)
    d = x_local.shape[1]
    x_seed = feats[:b]
    x_hop1 = feats[b : b + b * k1].reshape(b, k1, d)
    x_hop2 = feats[b + b * k1 :].reshape(b, k1, k2, d)
    labels = fetch_rows(y_local, seeds, axis_name)[:, 0].astype(jnp.int32)

    return SubgraphBatch(
        seeds=seeds,
        hop1=hop1,
        mask1=mask1,
        hop2=hop2,
        mask2=jnp.logical_and(mask2, mask1[..., None]),
        x_seed=x_seed,
        x_hop1=x_hop1 * mask1[..., None],
        x_hop2=x_hop2 * mask2[..., None] * mask1[..., None, None],
        labels=labels,
    )


def shard_rows(table: np.ndarray, n_workers: int) -> np.ndarray:
    """Pad a [N, D] host table to [W * rows, D] so it row-shards evenly."""
    n = table.shape[0]
    rows = -(-n // n_workers)
    pad = n_workers * rows - n
    if pad:
        table = np.concatenate([table, np.zeros((pad,) + table.shape[1:], table.dtype)])
    return table


def make_generator_fn(
    mesh: Mesh,
    *,
    k1: int = 40,
    k2: int = 20,
    axis_name: str = "data",
    merge_mode: str = "butterfly",
):
    """Pure generator function (no data placement — dry-run lowerable).

    ``gen_fn(device_args, seeds [W, b], rng) -> SubgraphBatch`` where
    ``device_args = (indptr [W,N+1], indices [W,E_pad], x [W*rows,D],
    y [W*rows,1])`` sharded on their leading axis."""
    graph_spec = P(axis_name)
    row_spec = P(axis_name)
    repl = P()

    def _squeeze_worker_axis(fn):
        # shard_map blocks keep the sharded leading axis of size 1 per worker;
        # wrap worker fn to drop/restore it.
        def wrapped(indptr, indices, xs, ys, seeds, rng):
            batch = fn(
                indptr[0], indices[0], xs, ys, seeds[0], rng
            )
            return batch
        return wrapped

    worker_fn = _squeeze_worker_axis(
        functools.partial(_worker_generate, k1=k1, k2=k2, axis_name=axis_name,
                          merge_mode=merge_mode)
    )

    def gen_fn(device_args, seeds, rng):
        indptr, indices, xs, ys = device_args
        return shard_map(
            worker_fn,
            mesh=mesh,
            in_specs=(graph_spec, graph_spec, row_spec, row_spec, graph_spec, repl),
            out_specs=P(axis_name),
            check_rep=False,
        )(indptr, indices, xs, ys, seeds, rng)

    return gen_fn


def make_distributed_generator(
    mesh: Mesh,
    part: PartitionedGraph,
    features: np.ndarray,
    labels: np.ndarray,
    *,
    k1: int = 40,
    k2: int = 20,
    axis_name: str = "data",
    merge_mode: str = "butterfly",
):
    """Build the jitted distributed generator with data placed on the mesh.

    Returns ``(gen_fn, device_args)``; every output leaf is sharded
    ``P(axis_name)`` on its leading (global-batch) axis."""
    w = mesh.shape[axis_name]
    assert part.n_workers == w, (part.n_workers, w)
    x = shard_rows(features.astype(np.float32), w)
    y = shard_rows(labels.reshape(-1, 1).astype(np.float32), w)
    gen_fn = make_generator_fn(mesh, k1=k1, k2=k2, axis_name=axis_name,
                               merge_mode=merge_mode)
    spec = NamedSharding(mesh, P(axis_name))
    device_args = (
        jax.device_put(part.indptr, spec),
        jax.device_put(part.indices, spec),
        jax.device_put(x, spec),
        jax.device_put(y, spec),
    )
    return jax.jit(gen_fn), device_args
