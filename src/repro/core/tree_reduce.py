"""Tree Reduction (paper §2 step 3).

GraphGen+ organizes workers into a hierarchy so hot-node aggregation is
performed in log(W) partial steps instead of a flat all-to-one.  On a TPU
mesh the natural realization is a **butterfly (recursive-halving) exchange**
built from ``lax.ppermute``: at stage s every worker exchanges its partial
aggregate with the partner ``rank XOR 2^s`` and merges.  After log2(W)
stages every worker holds the full reduction — i.e. tree *allreduce*
semantics, which is what both subgraph aggregation (step 3) and gradient
sync (step 4) need.

The merge operator is a parameter: ``add`` gives a gradient AllReduce;
``merge_topk_samples`` (generation.py) gives distributed reservoir-sample
merging for subgraph candidate sets.  Any associative+commutative op is
valid on a butterfly.

The paper's tree is rack-topology-aware; ICI on a TPU pod is symmetric per
axis, so stage order is the only placement decision (DESIGN.md §2).
"""
from __future__ import annotations

from typing import Callable, TypeVar

import jax
from jax import lax

T = TypeVar("T")


def axis_size(axis_name: str) -> int:
    """Static size of a mapped axis.

    ``lax.axis_size`` only exists in newer jax; ``lax.psum`` of a python
    literal is position-invariant, so jax returns it as a static int on
    every version this repo supports.
    """
    if hasattr(lax, "axis_size"):
        return lax.axis_size(axis_name)
    return lax.psum(1, axis_name)


def tree_allreduce(
    x: T,
    merge: Callable[[T, T], T],
    axis_name: str,
) -> T:
    """Butterfly allreduce of pytree ``x`` along ``axis_name`` (size must be
    a power of two — mesh axes here are 2/16) using ``merge`` at each stage."""
    size = axis_size(axis_name)
    if size & (size - 1):
        raise ValueError(f"butterfly needs power-of-two axis, got {size}")
    stage = 1
    while stage < size:
        perm = [(i, i ^ stage) for i in range(size)]
        partner = jax.tree.map(lambda a: lax.ppermute(a, axis_name, perm), x)
        x = merge(x, partner)
        stage <<= 1
    return x


def tree_psum(x: T, axis_name: str) -> T:
    """Gradient AllReduce via explicit tree reduction (``--grad-sync tree``)."""
    return tree_allreduce(x, lambda a, b: jax.tree.map(lax.add, a, b), axis_name)


def tree_reduce_scatter(
    x: T,
    merge: Callable[[T, T], T],
    axis_name: str,
) -> T:
    """Recursive-halving reduce-scatter along the leading (row) axis.

    Beyond-paper optimization of the subgraph-aggregation tree: the
    butterfly allreduce leaves EVERY worker with the merged result for the
    whole frontier (log2(W) full-width stages), but the balance table
    assigns each worker a contiguous 1/W row segment — only that segment is
    needed.  Recursive halving exchanges the half of the current segment
    the partner's group owns and merges the half it keeps, so per-worker
    traffic drops from log2(W) * F rows to (1 - 1/W) * F rows (~4x at
    W=16), and merge compute shrinks geometrically.

    Every leaf of ``x`` must have the same leading dimension F (divisible
    by the axis size); returns the fully-merged rows ``me*F/W : (me+1)*F/W``
    for each worker (big-endian rank-bit segment ordering).
    """
    size = axis_size(axis_name)
    if size & (size - 1):
        raise ValueError(f"recursive halving needs power-of-two axis, got {size}")
    me = lax.axis_index(axis_name)
    n_stages = size.bit_length() - 1
    seg = x
    for b in reversed(range(n_stages)):
        f = jax.tree.leaves(seg)[0].shape[0]
        half = f // 2
        mybit = (me >> b) & 1
        keep = jax.tree.map(
            lambda a: lax.dynamic_slice_in_dim(a, mybit * half, half, 0), seg
        )
        send = jax.tree.map(
            lambda a: lax.dynamic_slice_in_dim(a, (1 - mybit) * half, half, 0), seg
        )
        perm = [(i, i ^ (1 << b)) for i in range(size)]
        recv = jax.tree.map(lambda a: lax.ppermute(a, axis_name, perm), send)
        seg = merge(keep, recv)
    return seg
