"""Configuration dataclasses for the repro framework.

One ``ModelConfig`` describes any architecture in the zoo (the paper's GCN
plus the 10 assigned LM-family architectures).  ``ShapeConfig`` describes an
input-shape cell (train / prefill / decode / long-decode).  ``MeshConfig``
describes the device mesh.  All are plain frozen dataclasses — no jax state
is touched at import time.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Tuple

#: cache associativities the probe/insert paths implement, the cache
#: placement modes, and the shard-probe wire formats — defined HERE
#: (jax-free) so ModelConfig validation and core/feature_cache.py (which
#: imports jax) share one source of truth
VALID_CACHE_ASSOC = (1, 2, 4)
VALID_CACHE_MODES = ("replicated", "sharded", "tiered")
VALID_CACHE_WIRES = ("dense", "compact")
#: where the authoritative feature table lives: "device" row-shards it
#: over the workers (the owner fetch resolves misses on-device);
#: "host" keeps it in host RAM behind the L3 store (misses resolve via
#: an async double-buffered host gather — core/host_store.py)
VALID_FEATURE_STORES = ("device", "host")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | vlm | audio | ssm | hybrid | gcn
    n_layers: int = 0
    d_model: int = 0
    n_heads: int = 0
    n_kv_heads: int = 0
    d_ff: int = 0
    vocab_size: int = 0
    head_dim: int = 0          # 0 -> d_model // n_heads
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    d_ff_expert: int = 0       # per-expert FFN width (qwen3/deepseek style)
    first_dense_layers: int = 0  # deepseek: first k layers are dense
    # --- MLA (deepseek) ---
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    qk_rope_head_dim: int = 0
    qk_nope_head_dim: int = 0
    v_head_dim: int = 0
    # --- SSM (mamba2) ---
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_head_dim: int = 0
    ssm_expand: int = 2
    ssm_chunk: int = 128
    conv_width: int = 4
    # --- hybrid (zamba2): shared attention block every `attn_every` layers ---
    attn_every: int = 0
    # --- vlm: cross-attention every `cross_attn_every` layers ---
    cross_attn_every: int = 0
    n_vision_tokens: int = 0
    d_vision: int = 0
    # --- audio (whisper): encoder-decoder ---
    n_encoder_layers: int = 0
    n_audio_frames: int = 0
    d_audio: int = 0
    # --- gcn (the paper's model) ---
    gcn_hidden: int = 0
    gcn_in_dim: int = 0
    n_classes: int = 0
    fanouts: Tuple[int, ...] = ()
    # --- distributed feature-fetch policy (generation step 4) ---
    cache_rows: int = 0        # hot-node feature cache slots per worker
                               # (rounded UP to a power of two at
                               # construction; 0 disables the cache tier)
    cache_admit: int = 2       # misses before a candidate id is admitted
    cache_assoc: int = 1       # ways per cache set (1 = direct-mapped;
                               # 2/4-way recovers slot-collision losses)
    cache_mode: str = "replicated"
                               # "replicated": each worker caches its own
                               # stream (PR 2 behavior, the single-worker
                               # default); "sharded": the cache id-space
                               # partitions across workers and misses are
                               # first routed to their cache-shard holder
                               # (effective capacity x W); "tiered": a
                               # small replicated L1 (the global Zipf head,
                               # probed with zero network traffic) in front
                               # of the sharded L2 — L1 misses take the
                               # shard-probe round, shard misses fall
                               # through to the owner fetch
    cache_l1_rows: int = 0     # tiered mode: replicated L1 slots per worker
                               # (rounded UP to a power of two; 0 = auto,
                               # cache_rows // 8 — the "~1/8 head" default)
    cache_l1_promote: int = 3  # tiered mode: times the L2 tier must serve
                               # a row to this worker before the row is
                               # promoted into its L1 — the frequency
                               # threshold that migrates the hottest rows
                               # to every worker without a broadcast
    cache_wire: str = "compact"
                               # shard-probe response wire format (sharded/
                               # tiered modes, W > 1): "dense" ships the
                               # full [W, cap, D] row block back even
                               # though only hit slots carry data;
                               # "compact" ships a packed hit bitmap plus
                               # a row payload bounded by cache_hit_cap —
                               # stage-1 bytes then scale with hits, not
                               # with the probe capacity
    cache_hit_cap: int = 0     # compact wire: per-destination row-payload
                               # slots of the probe response; 0 = auto
                               # (half the probe capacity; launch/train.py
                               # calibrates a tighter bound from observed
                               # hit peaks, with a dense-fallback rung)
    capacity_slack: Optional[float] = None
                               # per-destination shuffle capacity slack;
                               # None = launcher auto-sizes from n_dropped
                               # (dryrun compiles at the 2.0 default)
    feature_store: str = "device"
                               # where the feature table lives: "device"
                               # row-shards it over the workers (misses
                               # pay the routed owner fetch); "host"
                               # keeps it in host RAM as the L3 tier —
                               # cache misses are staged and resolved by
                               # an async host gather double-buffered
                               # with the next step's compute
    host_gather_depth: int = 2 # host store pipeline depth: 2 issues the
                               # gather on a worker thread so the
                               # device_put overlaps the compute step;
                               # 1 gathers synchronously (overlap off —
                               # the benchmark's comparison column)
    # --- performance knobs (hillclimbed in §Perf) ---
    remat: str = "none"        # none | full | dots
    scan_layers: bool = True   # stack layer params and lax.scan over them
    use_flash_attention: bool = False
    fsdp_params: bool = True   # shard params over the data axis (ZeRO-3 style)

    def __post_init__(self):
        # validate the cache policy at CONSTRUCTION, not at trace time: a
        # non-power-of-two cache_rows used to surface as a ValueError deep
        # inside the jitted fetch (hash_slots), long after the config was
        # built.  Round up — the caller asked for at least that many slots.
        if self.cache_rows < 0:
            raise ValueError(f"cache_rows must be >= 0, got {self.cache_rows}")
        if self.cache_rows and self.cache_rows & (self.cache_rows - 1):
            object.__setattr__(self, "cache_rows",
                               1 << self.cache_rows.bit_length())
        if self.cache_assoc not in VALID_CACHE_ASSOC:
            raise ValueError(
                f"cache_assoc must be one of {VALID_CACHE_ASSOC}, "
                f"got {self.cache_assoc}")
        if self.cache_rows and self.cache_assoc > self.cache_rows:
            raise ValueError(
                f"cache_assoc {self.cache_assoc} exceeds cache_rows "
                f"{self.cache_rows}")
        if self.cache_mode not in VALID_CACHE_MODES:
            raise ValueError(
                f"cache_mode must be one of {VALID_CACHE_MODES}, "
                f"got {self.cache_mode!r}")
        if self.cache_l1_rows < 0:
            raise ValueError(
                f"cache_l1_rows must be >= 0, got {self.cache_l1_rows}")
        if self.cache_l1_rows and self.cache_l1_rows & (self.cache_l1_rows - 1):
            object.__setattr__(self, "cache_l1_rows",
                               1 << self.cache_l1_rows.bit_length())
        if self.cache_l1_promote < 1:
            raise ValueError(
                f"cache_l1_promote must be >= 1, got {self.cache_l1_promote}")
        if self.cache_wire not in VALID_CACHE_WIRES:
            raise ValueError(
                f"cache_wire must be one of {VALID_CACHE_WIRES}, "
                f"got {self.cache_wire!r}")
        if self.cache_hit_cap < 0:
            raise ValueError(
                f"cache_hit_cap must be >= 0 (0 = auto), "
                f"got {self.cache_hit_cap}")
        if self.feature_store not in VALID_FEATURE_STORES:
            raise ValueError(
                f"feature_store must be one of {VALID_FEATURE_STORES}, "
                f"got {self.feature_store!r}")
        if self.host_gather_depth not in (1, 2):
            raise ValueError(
                f"host_gather_depth must be 1 (synchronous) or 2 "
                f"(double-buffered), got {self.host_gather_depth}")
        # deliberately NO cross-field mode check here: launchers override
        # one field at a time with dataclasses.replace, so a tiered arch
        # config being switched to --cache-mode sharded must not trip over
        # its (now ignored) cache_l1_rows — CacheConfig.from_model simply
        # drops the L1 knobs outside tiered mode, and the strict check
        # lives in CacheConfig.validated() where the policy is final

    def with_candidate(self, cand: "TuneCandidate") -> "ModelConfig":
        """Self with an autotuner ``TuneCandidate`` applied — the config
        re-jit seam.

        Returns a new ``ModelConfig`` whose generation knobs (fanouts,
        cache sizes, associativity, hit cap, capacity slack) are replaced
        by the candidate's; everything else (model dims, placement mode,
        wire format, feature store) is untouched.  ``__post_init__``
        re-validates, so an infeasible candidate raises here — before
        anything is compiled against it.  The launcher rebuilds
        ``CacheConfig.from_model`` + the generator from the result;
        nothing downstream knows the config came from a search."""
        return dataclasses.replace(
            self, fanouts=tuple(cand.fanouts), cache_rows=cand.cache_rows,
            cache_l1_rows=cand.l1_rows, cache_assoc=cand.assoc,
            cache_hit_cap=cand.hit_cap, capacity_slack=cand.capacity_slack)

    @property
    def resolved_head_dim(self) -> int:
        """Per-head attention dim: ``head_dim`` when set explicitly,
        else ``d_model // n_heads``."""
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    def param_count(self) -> int:
        """Analytic parameter count (used for 6ND roofline, not allocation)."""
        if self.family == "gcn":
            d, h = self.gcn_in_dim, self.gcn_hidden
            depth = max(len(self.fanouts), 1)
            # per conv layer: self + neighbor transforms + bias
            total = 2 * d * h + h + (depth - 1) * (2 * h * h + h)
            return total + h * self.n_classes + self.n_classes
        hd = self.resolved_head_dim
        emb = self.vocab_size * self.d_model
        out = 0 if self.tie_embeddings else self.vocab_size * self.d_model
        if self.family == "ssm":
            per = _mamba2_layer_params(self)
            return emb + out + self.n_layers * per
        if self.family == "hybrid":
            n_attn = self.n_layers // max(self.attn_every, 1)
            per = _mamba2_layer_params(self)
            attn = _attn_params(self) + _mlp_params(self.d_model, self.d_ff)
            return emb + out + self.n_layers * per + attn + (n_attn - 1) * 0
        per = _attn_params(self)
        if self.family == "moe":
            dense_ff = _mlp_params(self.d_model, self.d_ff if self.d_ff else self.d_ff_expert)
            moe_ff = (
                self.n_experts * _mlp_params(self.d_model, self.d_ff_expert)
                + self.n_shared_experts * _mlp_params(self.d_model, self.d_ff_expert)
                + self.d_model * self.n_experts
            )
            n_moe = self.n_layers - self.first_dense_layers
            ff_total = self.first_dense_layers * dense_ff + n_moe * moe_ff
        else:
            ff_total = self.n_layers * _mlp_params(self.d_model, self.d_ff)
        total = emb + out + self.n_layers * per + ff_total
        if self.family == "audio":
            total += self.n_encoder_layers * (
                _attn_params(self) + _mlp_params(self.d_model, self.d_ff)
            )
            total += self.n_layers * _attn_params(self)  # decoder cross-attn
        if self.family == "vlm":
            n_cross = self.n_layers // max(self.cross_attn_every, 1)
            total += n_cross * _attn_params(self)
        return total

    def active_param_count(self) -> int:
        """Active params per token (== param_count for dense)."""
        if self.family != "moe":
            return self.param_count()
        per_attn = _attn_params(self)
        emb = self.vocab_size * self.d_model
        out = 0 if self.tie_embeddings else self.vocab_size * self.d_model
        active_ff = (self.top_k + self.n_shared_experts) * _mlp_params(
            self.d_model, self.d_ff_expert
        ) + self.d_model * self.n_experts
        dense_ff = _mlp_params(self.d_model, self.d_ff if self.d_ff else self.d_ff_expert)
        n_moe = self.n_layers - self.first_dense_layers
        return (
            emb + out + self.n_layers * per_attn
            + self.first_dense_layers * dense_ff + n_moe * active_ff
        )


def _attn_params(cfg: ModelConfig) -> int:
    hd = cfg.resolved_head_dim
    if cfg.kv_lora_rank:  # MLA
        q = cfg.d_model * cfg.n_heads * (cfg.qk_rope_head_dim + cfg.qk_nope_head_dim)
        kv = (
            cfg.d_model * (cfg.kv_lora_rank + cfg.qk_rope_head_dim)
            + cfg.kv_lora_rank * cfg.n_heads * (cfg.qk_nope_head_dim + cfg.v_head_dim)
        )
        o = cfg.n_heads * cfg.v_head_dim * cfg.d_model
        return q + kv + o
    q = cfg.d_model * cfg.n_heads * hd
    kv = 2 * cfg.d_model * cfg.n_kv_heads * hd
    o = cfg.n_heads * hd * cfg.d_model
    return q + kv + o


def _mlp_params(d_model: int, d_ff: int) -> int:
    return 3 * d_model * d_ff  # gate, up, down


def _mamba2_layer_params(cfg: ModelConfig) -> int:
    d_in = cfg.ssm_expand * cfg.d_model
    nh = cfg.ssm_heads or max(d_in // max(cfg.ssm_head_dim, 1), 1)
    in_proj = cfg.d_model * (2 * d_in + 2 * cfg.ssm_state + nh)
    conv = (d_in + 2 * cfg.ssm_state) * cfg.conv_width
    out_proj = d_in * cfg.d_model
    return in_proj + conv + out_proj + 2 * nh


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str            # train_4k | prefill_32k | decode_32k | long_500k
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524_288, 1),
}


@dataclasses.dataclass(frozen=True)
class MeshConfig:
    multi_pod: bool = False

    @property
    def shape(self) -> Tuple[int, ...]:
        """Device-grid shape: 16x16 per pod, with a leading pod axis of 2
        when ``multi_pod``."""
        return (2, 16, 16) if self.multi_pod else (16, 16)

    @property
    def axes(self) -> Tuple[str, ...]:
        """Mesh axis names, matching ``launch/mesh.py``'s production
        tuples — ``(pod,) + (data, model)``."""
        return ("pod", "data", "model") if self.multi_pod else ("data", "model")

    @property
    def n_devices(self) -> int:
        """Total devices in the mesh (product of ``shape``)."""
        n = 1
        for s in self.shape:
            n *= s
        return n


# TPU v5e hardware constants for the roofline model (target hardware).
PEAK_FLOPS_BF16 = 197e12        # per chip, FLOP/s
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link
PCIE_BW = 16e9                  # bytes/s host<->device (PCIe gen4 x16) —
                                # the L3 host-gather term of the roofline


class TuneCandidate(NamedTuple):
    """One point of the autotuner's joint search space (jax-free).

    The knobs the profile-driven autotuner (``launch/autotune.py``)
    searches jointly against its trace-fit cost model: the per-hop
    fanout shape, the cache-tier sizes, the set associativity, the
    compact-wire payload bound, and the exchange capacity slack.  A
    candidate is pure data — applying one to a ``ModelConfig``
    (``ModelConfig.with_candidate``) or a ``CacheConfig`` is THE re-jit
    seam: the launcher rebuilds the generator from the replaced config,
    nothing else changes.
    """
    fanouts: Tuple[int, ...]    # per-hop fanout shape (workload-defining:
                                # the default grid pins it to the config's)
    cache_rows: int             # main-tier (L2) cache slots per worker
    l1_rows: int                # tiered mode: replicated L1 slots (0 else)
    assoc: int                  # cache ways per set, in VALID_CACHE_ASSOC
    hit_cap: int                # compact-wire payload bound (0 = auto)
    capacity_slack: float       # exchange-capacity slack factor


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 1000
    microbatches: int = 1       # gradient accumulation
    grad_sync: str = "psum"     # psum | tree (explicit butterfly tree reduction)
    compress_grads: bool = False
    checkpoint_every: int = 100
    keep_checkpoints: int = 3
