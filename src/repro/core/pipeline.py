"""Synchronized subgraph generation + in-memory training (paper §2 step 4).

GraphGen+'s headline design: *"as new subgraphs are generated, they are
directly loaded into memory and used for training"* — no external storage.

Two realizations:

* ``pipelined_loop``  — GraphGen+: one jitted step trains on batch *t* while
  generating batch *t+1*.  The two computations share no data dependency,
  so XLA schedules them concurrently (compute/generation overlap); the
  batch never leaves device memory.

* ``offline_loop``    — the GraphGen baseline: ALL subgraphs are generated
  first, round-tripped through "storage" (device -> host numpy -> bytes ->
  device, physically paying serialization + I/O), then the trainer reads
  them back.  This is the 1.3x comparison target.
"""
from __future__ import annotations

import pickle
import time
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def make_pipelined_step(
    gen_fn: Callable[..., Any],
    train_fn: Callable[..., Tuple[Any, Any, jax.Array]],
):
    """Fuse generation(t+1) with training(t) into one step.

    carry = (params, opt_state, next_batch); the returned step consumes the
    pre-generated batch and produces the next one in the same XLA program.
    """

    def step(carry, device_args, seeds, rng):
        params, opt_state, batch = carry
        next_batch = gen_fn(device_args, seeds, rng)   # generation of t+1 ...
        params, opt_state, loss = train_fn(params, opt_state, batch)  # ... overlaps training of t
        return (params, opt_state, next_batch), loss

    return step


def pipelined_loop(
    gen_fn,
    train_fn,
    device_args,
    seed_schedule: np.ndarray,   # [steps, W, b] balance-table seeds per step
    params,
    opt_state,
    rng: jax.Array,
    step=None,                   # pass a pre-jitted step to amortize compile
):
    """Run the synchronized pipeline for ``steps`` iterations."""
    if step is None:
        step = jax.jit(make_pipelined_step(gen_fn, train_fn))
    rngs = jax.random.split(rng, len(seed_schedule) + 1)
    batch = gen_fn(device_args, jnp.asarray(seed_schedule[0]), rngs[0])
    carry = (params, opt_state, batch)
    losses = []
    for t in range(len(seed_schedule)):
        nxt = seed_schedule[min(t + 1, len(seed_schedule) - 1)]
        carry, loss = step(carry, device_args, jnp.asarray(nxt), rngs[t + 1])
        losses.append(loss)
    params, opt_state, _ = carry
    return params, opt_state, jnp.stack(losses)


def _store_roundtrip(batch) -> bytes:
    """GraphGen baseline storage: serialize the subgraph batch to bytes
    (device->host copy + pickle), as precomputed subgraphs would be written."""
    host = jax.tree.map(np.asarray, batch)
    return pickle.dumps(host)


def _load_roundtrip(blob: bytes):
    host = pickle.loads(blob)
    return jax.tree.map(jnp.asarray, host)


def offline_loop(
    gen_fn,
    train_fn,
    device_args,
    seed_schedule: np.ndarray,
    params,
    opt_state,
    rng: jax.Array,
    train_step=None,             # pass a pre-jitted step to amortize compile
):
    """GraphGen baseline: precompute-all -> store -> read -> train."""
    if train_step is None:
        train_step = jax.jit(train_fn)
    # split one extra key exactly like pipelined_loop so batch t is generated
    # from the SAME rngs[t] in both loops (split(k, n)[i] depends on n)
    rngs = jax.random.split(rng, len(seed_schedule) + 1)
    t0 = time.perf_counter()
    storage = []
    for t, seeds in enumerate(seed_schedule):
        batch = gen_fn(device_args, jnp.asarray(seeds), rngs[t])
        jax.block_until_ready(batch)
        storage.append(_store_roundtrip(batch))
    t_gen = time.perf_counter() - t0
    losses = []
    t0 = time.perf_counter()
    for blob in storage:
        batch = _load_roundtrip(blob)
        params, opt_state, loss = train_step(params, opt_state, batch)
        losses.append(loss)
    jax.block_until_ready(losses[-1])
    t_train = time.perf_counter() - t0
    return params, opt_state, jnp.stack(losses), {"t_gen": t_gen, "t_train": t_train}
