"""Synchronized subgraph generation + in-memory training (paper §2 step 4).

GraphGen+'s headline design: *"as new subgraphs are generated, they are
directly loaded into memory and used for training"* — no external storage.

Two realizations:

* ``pipelined_loop``  — GraphGen+: one jitted step trains on batch *t* while
  generating batch *t+1*.  The two computations share no data dependency,
  so XLA schedules them concurrently (compute/generation overlap); the
  batch never leaves device memory.

* ``offline_loop``    — the GraphGen baseline: ALL subgraphs are generated
  first, round-tripped through "storage" (device -> host numpy -> bytes ->
  device, physically paying serialization + I/O), then the trainer reads
  them back.  This is the 1.3x comparison target.
"""
from __future__ import annotations

import pickle
import time
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def make_pipelined_step(
    gen_fn: Callable[..., Any],
    train_fn: Callable[..., Tuple[Any, Any, jax.Array]],
    cached: bool = False,
):
    """Fuse generation(t+1) with training(t) into one step.

    carry = (params, opt_state, next_batch); the returned step consumes the
    pre-generated batch and produces the next one in the same XLA program.
    With ``cached=True`` the carry grows the hot-node feature-cache state —
    ``(params, opt_state, next_batch, cache)`` — and ``gen_fn`` must be the
    stateful form ``gen_fn(device_args, seeds, rng, cache) -> (batch,
    cache)``; the cache rides across iterations in device memory exactly
    like optimizer state.  The carry shape is identical for replicated and
    sharded/tiered cache placement (all are a [W, ...] cache-state pytree
    sharded on the worker axis — only the MEANING of worker ``i``'s block
    changes: its own replica vs the authoritative shard of
    ``shard_of(id, W) == i``), so the pipelined step needs no mode switch.
    """

    if cached:
        def step(carry, device_args, seeds, rng):
            params, opt_state, batch, cache = carry
            next_batch, cache = gen_fn(device_args, seeds, rng, cache)
            params, opt_state, loss = train_fn(params, opt_state, batch)
            return (params, opt_state, next_batch, cache), loss
    else:
        def step(carry, device_args, seeds, rng):
            params, opt_state, batch = carry
            next_batch = gen_fn(device_args, seeds, rng)   # generation of t+1 ...
            params, opt_state, loss = train_fn(params, opt_state, batch)  # ... overlaps training of t
            return (params, opt_state, next_batch), loss

    return step


def pipelined_loop(
    gen_fn,
    train_fn,
    device_args,
    seed_schedule: np.ndarray,   # [steps, W, b] balance-table seeds per step
    params,
    opt_state,
    rng: jax.Array,
    step=None,                   # pass a pre-jitted step to amortize compile
    cache=None,                  # FeatureCache pytree -> thread it through
    train_step=None,             # pre-jitted train_fn for the final step
):
    """Run the synchronized pipeline for ``steps`` iterations.

    The final iteration has no batch left to pre-generate, so it runs a
    train-only step (historically the loop re-generated the last schedule
    entry just to discard it — pure wasted generation work).  With
    ``cache`` given, the cache state is threaded through every generation
    and returned: ``(params, opt_state, losses, cache)``.
    """
    cached = cache is not None
    if step is None:
        step = jax.jit(make_pipelined_step(gen_fn, train_fn, cached=cached))
    if train_step is None:
        train_step = jax.jit(train_fn)
    # one key per schedule entry plus a tail key: batch t is generated from
    # rngs[t] (split(k, n)[i] depends on n, so the count must stay aligned
    # with offline_loop even though rngs[steps] is no longer consumed)
    rngs = jax.random.split(rng, len(seed_schedule) + 1)
    if cached:
        batch, cache = gen_fn(device_args, jnp.asarray(seed_schedule[0]),
                              rngs[0], cache)
        carry = (params, opt_state, batch, cache)
    else:
        batch = gen_fn(device_args, jnp.asarray(seed_schedule[0]), rngs[0])
        carry = (params, opt_state, batch)
    losses = []
    for t in range(len(seed_schedule) - 1):
        nxt = seed_schedule[t + 1]
        carry, loss = step(carry, device_args, jnp.asarray(nxt), rngs[t + 1])
        losses.append(loss)
    params, opt_state, batch = carry[0], carry[1], carry[2]
    params, opt_state, loss = train_step(params, opt_state, batch)
    losses.append(loss)
    if cached:
        return params, opt_state, jnp.stack(losses), carry[3]
    return params, opt_state, jnp.stack(losses)


def _store_roundtrip(batch) -> bytes:
    """GraphGen baseline storage: serialize the subgraph batch to bytes
    (device->host copy + pickle), as precomputed subgraphs would be written."""
    host = jax.tree.map(np.asarray, batch)
    return pickle.dumps(host)


def _load_roundtrip(blob: bytes):
    host = pickle.loads(blob)
    return jax.tree.map(jnp.asarray, host)


def offline_loop(
    gen_fn,
    train_fn,
    device_args,
    seed_schedule: np.ndarray,
    params,
    opt_state,
    rng: jax.Array,
    train_step=None,             # pass a pre-jitted step to amortize compile
    cache=None,                  # FeatureCache pytree -> thread it through
):
    """GraphGen baseline: precompute-all -> store -> read -> train.

    With ``cache`` given, the cache threads through the generation phase
    (the storage round trip carries batches only, never cache state) and
    the return grows a trailing cache element.
    """
    cached = cache is not None
    if train_step is None:
        train_step = jax.jit(train_fn)
    # split one extra key exactly like pipelined_loop so batch t is generated
    # from the SAME rngs[t] in both loops (split(k, n)[i] depends on n)
    rngs = jax.random.split(rng, len(seed_schedule) + 1)
    t0 = time.perf_counter()
    storage = []
    for t, seeds in enumerate(seed_schedule):
        if cached:
            batch, cache = gen_fn(device_args, jnp.asarray(seeds), rngs[t],
                                  cache)
        else:
            batch = gen_fn(device_args, jnp.asarray(seeds), rngs[t])
        jax.block_until_ready(batch)
        storage.append(_store_roundtrip(batch))
    t_gen = time.perf_counter() - t0
    losses = []
    t0 = time.perf_counter()
    for blob in storage:
        batch = _load_roundtrip(blob)
        params, opt_state, loss = train_step(params, opt_state, batch)
        losses.append(loss)
    jax.block_until_ready(losses[-1])
    t_train = time.perf_counter() - t0
    stats = {"t_gen": t_gen, "t_train": t_train}
    if cached:
        return params, opt_state, jnp.stack(losses), stats, cache
    return params, opt_state, jnp.stack(losses), stats
