"""Synchronized subgraph generation + in-memory training (paper §2 step 4).

GraphGen+'s headline design: *"as new subgraphs are generated, they are
directly loaded into memory and used for training"* — no external storage.

Two realizations:

* ``pipelined_loop``  — GraphGen+: one jitted step trains on batch *t* while
  generating batch *t+1*.  The two computations share no data dependency,
  so XLA schedules them concurrently (compute/generation overlap); the
  batch never leaves device memory.

* ``offline_loop``    — the GraphGen baseline: ALL subgraphs are generated
  first, round-tripped through "storage" (device -> host numpy -> bytes ->
  device, physically paying serialization + I/O), then the trainer reads
  them back.  This is the 1.3x comparison target.
"""
from __future__ import annotations

import pickle
import time
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .host_store import empty_admit, patch_batch


def make_pipelined_step(
    gen_fn: Callable[..., Any],
    train_fn: Callable[..., Tuple[Any, Any, jax.Array]],
    cached: bool = False,
):
    """Fuse generation(t+1) with training(t) into one step.

    carry = (params, opt_state, next_batch); the returned step consumes the
    pre-generated batch and produces the next one in the same XLA program.
    With ``cached=True`` the carry grows the hot-node feature-cache state —
    ``(params, opt_state, next_batch, cache)`` — and ``gen_fn`` must be the
    stateful form ``gen_fn(device_args, seeds, rng, cache) -> (batch,
    cache)``; the cache rides across iterations in device memory exactly
    like optimizer state.  The carry shape is identical for replicated and
    sharded/tiered cache placement (all are a [W, ...] cache-state pytree
    sharded on the worker axis — only the MEANING of worker ``i``'s block
    changes: its own replica vs the authoritative shard of
    ``shard_of(id, W) == i``), so the pipelined step needs no mode switch.

    Host mode (``feature_store="host"``) does NOT fuse: the L3 gather for
    step *t*'s misses reads gen *t*'s output and feeds gen *t+1*'s
    deferred admission, so inside one fused program it would sit squarely
    on the critical path with nothing to hide under.  The host loop
    instead dispatches generation and :func:`make_host_consume_step`
    as separate programs with the gather issued between them — see
    ``pipelined_loop``.
    """

    if cached:
        def step(carry, device_args, seeds, rng):
            params, opt_state, batch, cache = carry
            next_batch, cache = gen_fn(device_args, seeds, rng, cache)
            params, opt_state, loss = train_fn(params, opt_state, batch)
            return (params, opt_state, next_batch, cache), loss
    else:
        def step(carry, device_args, seeds, rng):
            params, opt_state, batch = carry
            next_batch = gen_fn(device_args, seeds, rng)   # generation of t+1 ...
            params, opt_state, loss = train_fn(params, opt_state, batch)  # ... overlaps training of t
            return (params, opt_state, next_batch), loss

    return step


def make_host_consume_step(train_fn):
    """The host-mode train program: patch batch *t*'s holes, train on it.

    One jitted ``consume(params, opt_state, batch, req, landed)`` fusing
    the ``patch_batch`` scatter with the train step.  In host mode this
    is deliberately a SEPARATE program from generation: the loop
    dispatches gen *t+1* first, issues the gather for its misses (whose
    host-side work waits on gen *t+1*'s ids), and only then dispatches
    this program — so the previous gather's host gather + transfer runs
    concurrently with this program's device compute instead of
    serializing between steps.  The final schedule entry reuses the same
    program as the drain: its landed buffer has no successor step, so
    the loop collects it synchronously and consumes it last."""
    def consume(params, opt_state, batch, req, landed):
        return train_fn(params, opt_state, patch_batch(batch, req, landed))
    return consume


def pipelined_loop(
    gen_fn,
    train_fn,
    device_args,
    seed_schedule: np.ndarray,   # [steps, W, b] balance-table seeds per step
    params,
    opt_state,
    rng: jax.Array,
    step=None,                   # pass a pre-jitted step to amortize compile
    cache=None,                  # FeatureCache pytree -> thread it through
    train_step=None,             # pre-jitted train_fn for the final step
    host_store=None,             # HostFeatureStore -> L3 issue/collect loop
    consume_step=None,           # pre-jitted host patch+train program
):
    """Run the synchronized pipeline for ``steps`` iterations.

    The final iteration has no batch left to pre-generate, so it runs a
    train-only step (historically the loop re-generated the last schedule
    entry just to discard it — pure wasted generation work).  With
    ``cache`` given, the cache state is threaded through every generation
    and returned: ``(params, opt_state, losses, cache)``.

    With a ``host_store`` (the generator built with
    ``feature_store="host"``) the loop runs the L3 issue/collect double
    buffer as a SPLIT dispatch — per iteration, in this order:

      1. collect the previous gather's landed rows (``pending.rows()``);
      2. dispatch gen *t* (deferred admission fed the landed rows);
      3. issue the gather for gen *t*'s staged misses — its host-side
         work waits on gen *t*'s ids, on the store's worker thread;
      4. dispatch the consume program (patch + train batch *t-1*).

    Gen *t* is queued on the device before the consume program, so the
    gather's host work (the blocking id read, the table gather, the
    device transfer) runs concurrently with batch *t-1*'s patch+train
    compute — that concurrency is the whole point of the split (a fused
    gen+train program would pin the gather between two steps with
    nothing to hide under; ``benchmarks/host_fetch.py`` measures the
    difference as its overlap gate).  The prologue generates batch 0
    synchronously (admission fed ``empty_admit`` — nothing has landed
    yet); the last landed buffer has no successor, so the epilogue
    collects it synchronously and consumes it last.  Loss parity with
    ``offline_loop(host_store=...)`` is bit-exact: both loops feed the
    identical admit schedule and rng split.
    """
    cached = cache is not None
    host = host_store is not None
    if step is None and not host:
        step = jax.jit(make_pipelined_step(gen_fn, train_fn, cached=cached))
    if train_step is None and not host:
        train_step = jax.jit(train_fn)
    if consume_step is None and host:
        consume_step = jax.jit(make_host_consume_step(train_fn))
    # one key per schedule entry plus a tail key: batch t is generated from
    # rngs[t] (split(k, n)[i] depends on n, so the count must stay aligned
    # with offline_loop even though rngs[steps] is no longer consumed)
    rngs = jax.random.split(rng, len(seed_schedule) + 1)
    if host:
        w = seed_schedule.shape[1]
        if cached:
            adm_ids, adm_rows = empty_admit(w, host_store.feat_dim)
            batch, cache, req = gen_fn(device_args,
                                       jnp.asarray(seed_schedule[0]),
                                       rngs[0], cache, adm_ids, adm_rows)
        else:
            batch, req = gen_fn(device_args, jnp.asarray(seed_schedule[0]),
                                rngs[0])
        pending = host_store.issue(req.ids)
        losses = []
        for t in range(1, len(seed_schedule)):
            landed = pending.rows()          # batch t-1's misses, landed
            prev_batch, prev_req = batch, req
            if cached:
                batch, cache, req = gen_fn(device_args,
                                           jnp.asarray(seed_schedule[t]),
                                           rngs[t], cache, prev_req.ids,
                                           landed)
            else:
                batch, req = gen_fn(device_args,
                                    jnp.asarray(seed_schedule[t]), rngs[t])
            pending = host_store.issue(req.ids)   # rides under consume
            params, opt_state, loss = consume_step(params, opt_state,
                                                   prev_batch, prev_req,
                                                   landed)
            losses.append(loss)
        params, opt_state, loss = consume_step(params, opt_state, batch,
                                               req, pending.rows())
        losses.append(loss)
        if cached:
            return params, opt_state, jnp.stack(losses), cache
        return params, opt_state, jnp.stack(losses)
    if cached:
        batch, cache = gen_fn(device_args, jnp.asarray(seed_schedule[0]),
                              rngs[0], cache)
        carry = (params, opt_state, batch, cache)
    else:
        batch = gen_fn(device_args, jnp.asarray(seed_schedule[0]), rngs[0])
        carry = (params, opt_state, batch)
    losses = []
    for t in range(len(seed_schedule) - 1):
        nxt = seed_schedule[t + 1]
        carry, loss = step(carry, device_args, jnp.asarray(nxt), rngs[t + 1])
        losses.append(loss)
    params, opt_state, batch = carry[0], carry[1], carry[2]
    params, opt_state, loss = train_step(params, opt_state, batch)
    losses.append(loss)
    if cached:
        return params, opt_state, jnp.stack(losses), carry[3]
    return params, opt_state, jnp.stack(losses)


def _store_roundtrip(payload):
    """GraphGen baseline storage: serialize a batch payload to bytes.

    One device->host copy (``np.asarray`` — a no-copy view for leaves
    already resident on the host, e.g. the L3 store's landed staging
    buffers), then pickle **protocol 5 with out-of-band buffers**: the
    array bodies are handed back as zero-copy ``PickleBuffer`` views
    instead of being memcpy'd into the byte stream a second time.
    Returns ``(header_bytes, buffers)``."""
    host = jax.tree.map(np.asarray, payload)
    buffers = []
    header = pickle.dumps(host, protocol=5,
                          buffer_callback=buffers.append)
    return header, buffers


def _load_roundtrip(blob):
    header, buffers = blob
    host = pickle.loads(header, buffers=buffers)
    return jax.tree.map(jnp.asarray, host)


def offline_loop(
    gen_fn,
    train_fn,
    device_args,
    seed_schedule: np.ndarray,
    params,
    opt_state,
    rng: jax.Array,
    train_step=None,             # pass a pre-jitted step to amortize compile
    cache=None,                  # FeatureCache pytree -> thread it through
    host_store=None,             # HostFeatureStore -> L3 generation path
):
    """GraphGen baseline: precompute-all -> store -> read -> train.

    With ``cache`` given, the cache threads through the generation phase
    (the storage round trip carries batches only, never cache state) and
    the return grows a trailing cache element.

    With a ``host_store`` the generation phase resolves misses against
    the L3 tier synchronously (the baseline is sequential anyway) using
    the SAME admit schedule and rng split as
    ``pipelined_loop(host_store=...)``, so the two loops' losses stay
    bit-exact.  Storage payloads are ``(batch, req, rows)`` where
    ``rows`` is the gather's already-landed host staging buffer
    (``HostGather.host_rows()``) — serialized without ever re-copying it
    off the device — and the train phase patches the holes on load.
    """
    cached = cache is not None
    host = host_store is not None
    if train_step is None:
        train_step = jax.jit(train_fn)
    patch_jit = jax.jit(patch_batch) if host else None
    # split one extra key exactly like pipelined_loop so batch t is generated
    # from the SAME rngs[t] in both loops (split(k, n)[i] depends on n)
    rngs = jax.random.split(rng, len(seed_schedule) + 1)
    t0 = time.perf_counter()
    storage = []
    if host:
        adm = (empty_admit(seed_schedule.shape[1], host_store.feat_dim)
               if cached else None)
        for t, seeds in enumerate(seed_schedule):
            if cached:
                batch, cache, req = gen_fn(device_args, jnp.asarray(seeds),
                                           rngs[t], cache, *adm)
            else:
                batch, req = gen_fn(device_args, jnp.asarray(seeds),
                                    rngs[t])
            pending = host_store.issue(req.ids)
            if cached:
                adm = (req.ids, pending.rows())
            jax.block_until_ready(batch)
            storage.append(_store_roundtrip((batch, req,
                                             pending.host_rows())))
    else:
        for t, seeds in enumerate(seed_schedule):
            if cached:
                batch, cache = gen_fn(device_args, jnp.asarray(seeds),
                                      rngs[t], cache)
            else:
                batch = gen_fn(device_args, jnp.asarray(seeds), rngs[t])
            jax.block_until_ready(batch)
            storage.append(_store_roundtrip(batch))
    t_gen = time.perf_counter() - t0
    losses = []
    t0 = time.perf_counter()
    for blob in storage:
        if host:
            batch, req, rows = _load_roundtrip(blob)
            batch = patch_jit(batch, req, rows)
        else:
            batch = _load_roundtrip(blob)
        params, opt_state, loss = train_step(params, opt_state, batch)
        losses.append(loss)
    jax.block_until_ready(losses[-1])
    t_train = time.perf_counter() - t0
    stats = {"t_gen": t_gen, "t_train": t_train}
    if cached:
        return params, opt_state, jnp.stack(losses), stats, cache
    return params, opt_state, jnp.stack(losses), stats
