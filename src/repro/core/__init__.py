from .config import (ModelConfig, ShapeConfig, MeshConfig, TrainConfig, SHAPES,
                     PEAK_FLOPS_BF16, HBM_BW, ICI_BW)
from .balance import balance_table, rebalance_on_failure, load_skew, BalanceTable
from .partition import partition_edges, PartitionedGraph
from .tree_reduce import tree_allreduce, tree_psum
