"""L3 host-RAM feature store: async double-buffered gathers behind the cache.

The device-resident feature table is the hard capacity wall for
industrial graphs — GraphScale scales past it by decoupling feature
storage from the compute workers.  This module is that tier: the
authoritative feature table stays in host RAM (a numpy array, possibly
memory-mapped), and cache-tier misses resolve against it through an
**asynchronous gather** instead of the routed owner ``all_to_all``.

The perf problem is that a host gather blocks on PCIe.  The fetch path
therefore splits the owner-fetch stage into *issue* and *collect*
(``generation.fetch_rows(store="host")``):

  issue    — the generation program for batch *t* emits a
             :class:`HostMissRequest` (the staged miss ids plus the
             scatter map back into the batch) instead of fetching; the
             loop hands the ids to :meth:`HostFeatureStore.issue`,
             which gathers on the host and starts an async
             ``jax.device_put``.
  collect  — one step later the landed ``[W, S, D]`` buffer is consumed
             by two programs: gen *t+1* admits the rows into the cache
             tiers (``fetch_rows``'s deferred-admission round, so the
             hot head stops missing) and the consume program
             (``pipeline.make_host_consume_step``) scatters them into
             batch *t*'s feature holes via :func:`patch_batch` right
             before training on it.

The overlap comes from dispatch ORDER, not fusion: the loop dispatches
gen *t*, then issues its gather (whose host-side work waits on gen
*t*'s ids), then dispatches batch *t-1*'s patch+train — so the gather
runs concurrently with that program's device compute.  Fusing gen and
train into one program would instead pin the gather between two steps
with nothing to hide under (its input is one program's output and its
output is the next program's input).  The double buffer costs one step
of cache-admission lag and zero correctness: landed rows are verbatim
table copies merged with ``jnp.where``.

``host_gather_depth`` picks the overlap mode: **2** (default) runs the
host-side ``np.asarray`` + gather on a worker thread so the main thread
keeps dispatching device work (the transfer overlaps compute); **1**
gathers synchronously at issue time and blocks until the buffer lands,
serializing gather and compute — the overlap-off baseline
``benchmarks/host_fetch.py`` compares against.
"""
from __future__ import annotations

from concurrent.futures import ThreadPoolExecutor
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class HostMissRequest(NamedTuple):
    """One step's staged cache misses, per worker (stacked ``[W, ...]``).

    Emitted by ``fetch_rows(store="host")`` as part of the generation
    step's output; consumed twice one step later — by
    :meth:`HostFeatureStore.issue` (the ``ids`` to gather) and by
    :func:`patch_batch` (the scatter map that fills the batch's feature
    holes with the landed rows).

    ids    [W, S]  int32  staged miss ids (-1 = empty staging slot)
    slot   [W, R]  int32  staging slot serving each request slot
                          (meaningful only where ``patch``)
    patch  [W, R]  bool   request slots whose row arrives via the L3
                          gather (their batch features are holes until
                          :func:`patch_batch` runs)
    """
    ids: jax.Array
    slot: jax.Array
    patch: jax.Array


def empty_admit(n_workers: int, dim: int,
                dtype=jnp.float32) -> Tuple[jax.Array, jax.Array]:
    """The prologue step's ``(admit_ids, admit_rows)`` — nothing landed yet.

    All-(-1) ids admit nothing; the single staging slot keeps the
    shapes rank-correct for the shard_map specs."""
    return (jnp.full((n_workers, 1), -1, jnp.int32),
            jnp.zeros((n_workers, 1, dim), dtype))


def patch_batch(batch, req: HostMissRequest, landed: jax.Array):
    """Fill batch feature holes with the landed L3 rows (pure jnp).

    ``landed`` is the ``[W, S, D]`` buffer :meth:`HostFeatureStore.issue`
    gathered for ``req.ids``; every request slot flagged ``req.patch``
    takes its staged row, every other slot keeps its existing value
    bit-for-bit (``jnp.where`` merge — never arithmetic), and hop levels
    re-apply their masks so padded slots stay exactly zero.  The result
    is bit-identical to the batch a device-resident fetch would have
    produced, which is what keeps the host-store cells of the
    differential matrix exact."""
    w, s, d = landed.shape
    wb = batch.x_seed.shape[0]
    b = wb // w

    def fill(slots, flag, x):
        idx = jnp.clip(slots, 0, s - 1)[..., None]
        rows = jnp.take_along_axis(landed, idx, axis=1)
        return jnp.where(flag[..., None], rows, x)

    x_seed = fill(req.slot[:, :b], req.patch[:, :b],
                  batch.x_seed.reshape(w, b, d)).reshape(wb, d)
    x_hops = []
    off = b
    for mask, x in zip(batch.masks, batch.x_hops):
        n = mask.size // w          # per-worker request slots at this level
        patched = fill(req.slot[:, off:off + n], req.patch[:, off:off + n],
                       x.reshape(w, n, d))
        patched = patched * mask.reshape(w, n, 1)
        x_hops.append(patched.reshape(x.shape))
        off += n
    return batch._replace(x_seed=x_seed, x_hops=tuple(x_hops))


class HostGather:
    """Handle on one in-flight host gather (the double buffer's slot).

    ``rows()`` returns the landed device buffer — with depth 2 it joins
    the worker thread first (the gather itself), but the device transfer
    stays asynchronous (``jax.device_put`` dispatch semantics), so the
    consuming step's compute still overlaps it.  ``host_rows()`` exposes
    the pre-transfer numpy buffer — the offline loop serializes storage
    payloads straight from it instead of round-tripping the rows
    device -> host a second time."""

    def __init__(self, result=None, future=None):
        self._result = result
        self._future = future

    def _get(self):
        if self._result is None:
            self._result = self._future.result()
        return self._result

    def rows(self) -> jax.Array:
        """The landed ``[W, S, D]`` device buffer (sharded per worker)."""
        return self._get()[0]

    def host_rows(self) -> np.ndarray:
        """The gathered rows as the host-side numpy staging buffer."""
        return self._get()[1]


class HostFeatureStore:
    """The host-RAM feature table plus its async gather machinery.

    ``table`` is the authoritative ``[N, D]`` feature array — host
    memory only, never placed on device (``graph/synthetic.py``'s
    ``features_on_host`` path can build it chunked or memory-mapped so
    sweeps exceed aggregate device capacity).  ``depth`` is the gather
    pipeline depth (see module docstring); ``sharding`` (e.g.
    ``NamedSharding(mesh, P("data"))``) places each landed buffer so
    worker ``w`` receives its own ``[S, D]`` slice.
    """

    def __init__(self, table: np.ndarray, *, depth: int = 2,
                 sharding=None):
        if table.ndim != 2:
            raise ValueError(f"host feature table must be [N, D], "
                             f"got shape {table.shape}")
        if depth not in (1, 2):
            raise ValueError(f"host_gather_depth must be 1 or 2, "
                             f"got {depth}")
        self.table = table
        self.depth = depth
        self.sharding = sharding
        self.bytes_issued = 0       # PCIe payload telemetry, summed
        self._pool = (ThreadPoolExecutor(max_workers=1, thread_name_prefix="l3")
                      if depth == 2 else None)

    @property
    def feat_dim(self) -> int:
        """Feature dimensionality ``D`` of the stored table."""
        return self.table.shape[1]

    def _gather(self, ids) -> Tuple[jax.Array, np.ndarray]:
        # np.asarray blocks until the producing step computed the ids —
        # with depth 2 that wait happens on the worker thread, so the
        # main thread keeps dispatching the overlapping compute
        ids_np = np.asarray(ids)
        # staging is sized for the worst-case miss burst, so most slots
        # are -1 padding in steady state: gather only the valid rows
        # into a zeroed buffer instead of gathering padding and zeroing
        # it back out (same bits, a fraction of the memcpy)
        rows = np.zeros(ids_np.shape + (self.table.shape[1],),
                        self.table.dtype)
        valid = ids_np >= 0
        rows[valid] = self.table[np.clip(ids_np[valid], 0,
                                         self.table.shape[0] - 1)]
        dev = jax.device_put(rows, self.sharding)
        return dev, rows

    def issue(self, ids) -> HostGather:
        """Start the gather for one step's staged miss ids ``[W, S]``.

        Returns the :class:`HostGather` handle whose ``rows()`` the
        *next* step consumes.  Depth 2 dispatches the host work to the
        store's worker thread and returns immediately; depth 1 gathers
        inline and blocks until the buffer is resident on device (the
        overlap-off mode)."""
        self.bytes_issued += (ids.size * 4
                              + ids.size * self.feat_dim
                              * self.table.dtype.itemsize)
        if self.depth == 2:
            return HostGather(future=self._pool.submit(self._gather, ids))
        dev, rows = self._gather(ids)
        jax.block_until_ready(dev)
        return HostGather(result=(dev, rows))
