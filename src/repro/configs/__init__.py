"""Config registry: ``get_config(arch_id)`` + reduced smoke variants."""
from __future__ import annotations

import dataclasses

from ..core.config import ModelConfig, SHAPES, ShapeConfig
from . import (
    deepseek_v2_236b,
    graphgen_gcn,
    graphgen_gcn_deep,
    graphgen_sage,
    llama32_vision_11b,
    llama3_405b,
    mamba2_1p3b,
    qwen3_moe_30b_a3b,
    smollm_135m,
    smollm_360m,
    stablelm_12b,
    whisper_small,
    zamba2_1p2b,
)

REGISTRY: dict[str, ModelConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (
        smollm_135m, smollm_360m, stablelm_12b, llama3_405b,
        qwen3_moe_30b_a3b, deepseek_v2_236b, llama32_vision_11b,
        whisper_small, mamba2_1p3b, zamba2_1p2b, graphgen_gcn,
        graphgen_sage, graphgen_gcn_deep,
    )
}

ASSIGNED_ARCHS = [n for n, c in REGISTRY.items() if c.family != "gcn"]

# archs whose attention is quadratic-only: long_500k is skipped for them
# (DESIGN.md §4); SSM/hybrid run it.
SUBQUADRATIC = {"mamba2-1.3b", "zamba2-1.2b"}


def get_config(name: str) -> ModelConfig:
    return REGISTRY[name]


def smoke_config(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    if cfg.family == "gcn":
        # shrink fanouts but keep the configured sampling depth; keep the
        # cache tier on (tiny) when the full config enables it
        depth = max(len(cfg.fanouts), 1)
        small = ((4, 3) + (2,) * depth)[:depth]
        return dataclasses.replace(cfg, gcn_in_dim=16, gcn_hidden=32, n_classes=5,
                                   fanouts=small,
                                   cache_rows=min(cfg.cache_rows, 256),
                                   cache_l1_rows=min(cfg.cache_l1_rows, 32))
    hd = 16
    heads = max(cfg.n_heads // 4, 2) if cfg.n_heads else 0
    kv = max(cfg.n_kv_heads // 4, 1) if cfg.n_kv_heads else 0
    kv = min(kv, heads) if heads else 0
    if heads and kv and heads % kv:
        kv = 1
    rep = {
        "n_layers": min(cfg.n_layers, 4),
        "d_model": 64,
        "n_heads": heads,
        "n_kv_heads": kv,
        "head_dim": hd if heads else 0,
        "d_ff": 128 if cfg.d_ff else 0,
        "vocab_size": 512,
        "remat": "none",
    }
    if cfg.family == "moe":
        rep.update(n_experts=8, top_k=2, d_ff_expert=32)
        if cfg.kv_lora_rank:
            rep.update(kv_lora_rank=24, q_lora_rank=32, qk_rope_head_dim=8,
                       qk_nope_head_dim=16, v_head_dim=16, first_dense_layers=1,
                       n_layers=3, n_shared_experts=1, d_ff=64)
    if cfg.family in ("ssm", "hybrid"):
        rep.update(ssm_state=16, ssm_head_dim=16, ssm_chunk=8)
    if cfg.family == "hybrid":
        rep.update(n_layers=5, attn_every=2)
    if cfg.family == "vlm":
        rep.update(n_layers=4, cross_attn_every=2, n_vision_tokens=8, d_vision=24)
    if cfg.family == "audio":
        rep.update(n_encoder_layers=2, n_layers=2, n_audio_frames=12, d_audio=24)
    return dataclasses.replace(cfg, **rep)


def smoke_shape(kind: str = "train") -> ShapeConfig:
    if kind == "train":
        return ShapeConfig("smoke_train", "train", 32, 4)
    return ShapeConfig("smoke_decode", "decode", 32, 4)
