"""1-hop GraphSAGE-style workload: single fanout (8,) — shallow sampling,
wide batches.  Exercises the depth-1 path of the L-hop generation engine."""
from ..core.config import ModelConfig

CONFIG = ModelConfig(
    name="graphgen-sage", family="gcn",
    gcn_in_dim=128, gcn_hidden=256, n_classes=64, fanouts=(8,),
    # shallow trees request far fewer rows per iteration -> smaller cache;
    # 2-way sets + sharded placement keep the small cache effective
    cache_rows=2048, cache_admit=2, cache_assoc=2, cache_mode="sharded",
)
