"""Whisper-small [arXiv:2212.04356] — enc-dec; conv frontend is a STUB
(input_specs supplies precomputed frame embeddings)."""
from ..core.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small", family="audio",
    n_layers=12, n_encoder_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
    d_ff=3072, vocab_size=51865, head_dim=64,
    n_audio_frames=1500, d_audio=768,
)
