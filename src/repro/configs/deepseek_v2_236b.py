"""DeepSeek-V2-236B [arXiv:2405.04434] — MLA (kv_lora=512) + MoE
(2 shared + 160 routed, top-6); first layer dense (d_ff=12288)."""
from ..core.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b", family="moe",
    n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128,
    d_ff=12288, d_ff_expert=1536, vocab_size=102400,
    n_experts=160, top_k=6, n_shared_experts=2, first_dense_layers=1,
    kv_lora_rank=512, q_lora_rank=1536,
    qk_rope_head_dim=64, qk_nope_head_dim=128, v_head_dim=128,
    remat="full",
)
