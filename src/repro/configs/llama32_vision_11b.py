"""Llama-3.2-11B-Vision [hf:meta-llama/Llama-3.2-11B-Vision] — text decoder
with gated cross-attn every 5th layer; patch-embedding frontend is a STUB."""
from ..core.config import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b", family="vlm",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab_size=128256, head_dim=128,
    rope_theta=500_000.0,
    cross_attn_every=5, n_vision_tokens=1600, d_vision=1280,
)
