"""Zamba2-1.2b [arXiv:2411.15242] — Mamba2 backbone + shared attn block."""
from ..core.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab_size=32000, head_dim=64,
    ssm_state=64, ssm_head_dim=64, ssm_expand=2, ssm_chunk=128, conv_width=4,
    attn_every=6,
)
