"""Mamba2-1.3b [arXiv:2405.21060] — SSD, attention-free."""
from ..core.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b", family="ssm",
    n_layers=48, d_model=2048, vocab_size=50280,
    ssm_state=128, ssm_head_dim=64, ssm_expand=2, ssm_chunk=128, conv_width=4,
)
