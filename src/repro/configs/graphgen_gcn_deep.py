"""3-hop deep-GCN workload: fanouts (15, 10, 5) — same padded-node budget
order as the paper's (40, 20) but one more level of receptive field.
Exercises the depth-3 path of the L-hop generation engine."""
from ..core.config import ModelConfig

CONFIG = ModelConfig(
    name="graphgen-gcn-deep", family="gcn",
    gcn_in_dim=128, gcn_hidden=256, n_classes=64, fanouts=(15, 10, 5),
    # deep trees revisit the hot head at EVERY level, so the global head
    # is the hottest of any workload here -> tiered cache: a 512-row
    # replicated L1 serves it with zero probe-round traffic in front of
    # the 4096-row sharded L2 (promotion after 3 observations)
    cache_rows=4096, cache_admit=2, cache_assoc=4, cache_mode="tiered",
    cache_l1_rows=512, cache_l1_promote=3,
    # the deep workload is the one that outgrows aggregate device memory
    # first (530M-node-paper-scale feature tables): flip feature_store to
    # "host" (or pass --feature-store host) to keep the table in host RAM
    # behind the double-buffered L3 gather; depth 2 hides the PCIe
    # transfer under the compute step
    feature_store="device", host_gather_depth=2,
)
