"""The paper's own model: mini-batch GCN on 2-hop (40, 20) subgraphs (§3).

The hot-node feature cache (4096 rows/worker, admit-after-2, 4-way sets)
serves the power-law head of the request stream across iterations —
DistDGL/GraphScale-style locality caching layered onto the paper's
deduplicated feature shuffle.  Sharded placement partitions the cache
id-space over the worker axis (effective capacity x W); on a single
worker it degenerates to the replicated behavior."""
from ..core.config import ModelConfig

CONFIG = ModelConfig(
    name="graphgen-gcn", family="gcn",
    gcn_in_dim=128, gcn_hidden=256, n_classes=64, fanouts=(40, 20),
    cache_rows=4096, cache_admit=2, cache_assoc=4, cache_mode="sharded",
)
