"""The paper's own model: mini-batch GCN on 2-hop (40, 20) subgraphs (§3)."""
from ..core.config import ModelConfig

CONFIG = ModelConfig(
    name="graphgen-gcn", family="gcn",
    gcn_in_dim=128, gcn_hidden=256, n_classes=64, fanouts=(40, 20),
)
