"""The paper's own model: mini-batch GCN on 2-hop (40, 20) subgraphs (§3).

The hot-node feature cache (4096 rows/worker, admit-after-2) serves the
power-law head of the request stream device-locally across iterations —
DistDGL/GraphScale-style locality caching layered onto the paper's
deduplicated feature shuffle."""
from ..core.config import ModelConfig

CONFIG = ModelConfig(
    name="graphgen-gcn", family="gcn",
    gcn_in_dim=128, gcn_hidden=256, n_classes=64, fanouts=(40, 20),
    cache_rows=4096, cache_admit=2,
)
