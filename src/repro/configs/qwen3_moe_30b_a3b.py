"""Qwen3-30B-A3B [hf:Qwen/Qwen3-30B-A3B] — 128 experts top-8 MoE.
The assignment's d_ff=768 is the per-expert (routed) FFN width."""
from ..core.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4,
    d_ff=0, d_ff_expert=768, vocab_size=151936, head_dim=128,
    n_experts=128, top_k=8,
)
