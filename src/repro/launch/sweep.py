"""Run the full dry-run sweep: every (arch x shape) cell on the single-pod
16x16 mesh AND the multi-pod 2x16x16 mesh, one fresh subprocess per cell
(compile caches don't accumulate; one bad cell can't kill the sweep).

    PYTHONPATH=src python -m repro.launch.sweep --out dryrun_results.jsonl
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time


def cells():
    from ..configs import ASSIGNED_ARCHS
    from ..core.config import SHAPES
    for arch in ASSIGNED_ARCHS:
        for shape in SHAPES:
            for multi_pod in (False, True):
                yield arch, shape, multi_pod
    # the paper's own workload (530M nodes / 5B edges GCN pipeline)
    yield "graphgen-gcn", "train_4k", False
    yield "graphgen-gcn", "train_4k", True


def run_cell(arch: str, shape: str, multi_pod: bool, out: str, timeout: int) -> dict:
    cmd = [
        sys.executable, "-m", "repro.launch.dryrun",
        "--arch", arch, "--shape", shape, "--out", out,
    ]
    if multi_pod:
        cmd.append("--multi-pod")
    env = dict(os.environ)
    t0 = time.time()
    try:
        proc = subprocess.run(
            cmd, capture_output=True, text=True, timeout=timeout, env=env
        )
        if proc.returncode != 0:
            rec = {
                "arch": arch, "shape": shape,
                "mesh": "2x16x16" if multi_pod else "16x16",
                "status": "error",
                "stderr_tail": proc.stderr[-2000:],
                "wall_s": round(time.time() - t0, 1),
            }
            with open(out, "a") as f:
                f.write(json.dumps(rec) + "\n")
            return rec
        return {"status": "ok", "wall_s": round(time.time() - t0, 1)}
    except subprocess.TimeoutExpired:
        rec = {
            "arch": arch, "shape": shape,
            "mesh": "2x16x16" if multi_pod else "16x16",
            "status": "timeout", "wall_s": timeout,
        }
        with open(out, "a") as f:
            f.write(json.dumps(rec) + "\n")
        return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="dryrun_results.jsonl")
    ap.add_argument("--timeout", type=int, default=1800)
    ap.add_argument("--only-missing", action="store_true")
    args = ap.parse_args()

    done = set()
    if args.only_missing and os.path.exists(args.out):
        with open(args.out) as f:
            for line in f:
                r = json.loads(line)
                if r.get("status") in ("ok", "skipped"):
                    done.add((r["arch"], r["shape"], r["mesh"]))

    todo = list(cells())
    for i, (arch, shape, multi_pod) in enumerate(todo):
        mesh = "2x16x16" if multi_pod else "16x16"
        if (arch, shape, mesh) in done:
            continue
        r = run_cell(arch, shape, multi_pod, args.out, args.timeout)
        print(f"[{i+1}/{len(todo)}] {arch} {shape} {mesh}: "
              f"{r['status']} ({r.get('wall_s', '?')}s)", flush=True)


if __name__ == "__main__":
    main()
