"""Static analysis of compiled HLO text: collective-traffic accounting.

``compiled.cost_analysis()`` reports FLOPs and HBM bytes but NOT collective
bytes, so the roofline's third term is recovered from ``compiled.as_text()``:
sum the output bytes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute, each weighted by how many times its
enclosing computation executes (scan-over-layers puts collectives inside
``while`` bodies — we recover trip counts from the loop-condition constants
and propagate multipliers over the call graph).
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1,
    "pred": 1, "c64": 8, "c128": 16,
}

COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
# computation header, e.g. ``%wide.region_0.2 (arg: (s32[], f32[8,4])) -> pred[] {``
# (params may contain nested parens; instruction lines are excluded by the
# `` = `` check in _split_computations)
_COMP_HDR = re.compile(r"^\s*(?:ENTRY\s+)?%?([\w\.\-]+)\s+\(.*\)\s*->\s*.+\{\s*$")
_ATTR_COMP = re.compile(
    r"(?:to_apply|condition|body|calls)=\{?%?([\w\.\-]+)\}?"
)
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")


def shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        b = _DTYPE_BYTES.get(dt)
        if b is None:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * b
    return total


def _split_computations(hlo: str) -> dict[str, list[str]]:
    comps: dict[str, list[str]] = {}
    cur = None
    for line in hlo.splitlines():
        m = None if " = " in line else _COMP_HDR.match(line)
        if m:
            cur = m.group(1)
            comps[cur] = []
            continue
        if cur is not None:
            if line.strip() == "}":
                cur = None
            else:
                comps[cur].append(line)
    return comps


def xla_cost(compiled) -> dict:
    """Normalize ``compiled.cost_analysis()`` across jax versions: newer
    versions return a dict, older ones a one-element list of dicts (or None
    on some backends)."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost or {}


def _entry_name(hlo: str) -> str | None:
    m = re.search(r"^ENTRY\s+%?([\w\.\-]+)", hlo, re.M)
    return m.group(1) if m else None


def _trip_count(cond_lines: list[str]) -> int:
    """Loop condition computations compare the induction variable against a
    constant; the largest integer constant is the trip count."""
    best = 1
    for line in cond_lines:
        for c in re.findall(r"constant\((\d+)\)", line):
            best = max(best, int(c))
    return best


def computation_multipliers(hlo: str) -> dict[str, float]:
    comps = _split_computations(hlo)
    entry = _entry_name(hlo)
    mult: dict[str, float] = defaultdict(float)
    if entry is None:
        for name in comps:
            mult[name] = 1.0
        return mult
    mult[entry] = 1.0
    # propagate in topological-ish order by repeated relaxation (call graph
    # of HLO is a DAG; a few passes converge)
    for _ in range(8):
        changed = False
        for name, lines in comps.items():
            m = mult.get(name, 0.0)
            if m == 0.0:
                continue
            for line in lines:
                is_while = " while(" in line
                trip = 1
                callees = _ATTR_COMP.findall(line)
                if is_while:
                    cm = re.search(r"condition=%?([\w\.\-]+)", line)
                    if cm and cm.group(1) in comps:
                        trip = _trip_count(comps[cm.group(1)])
                bm = _BRANCHES.search(line)
                if bm:
                    callees += [c.strip().lstrip("%") for c in bm.group(1).split(",")]
                for c in callees:
                    if c not in comps:
                        continue
                    contrib = m * (trip if is_while else 1)
                    if mult.get(c, 0.0) < contrib:
                        mult[c] = contrib
                        changed = True
        if not changed:
            break
    return dict(mult)


_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*((?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\]\S*))\s+([\w\-]+)\("
)
_FREE_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "iota", "partition-id", "replica-id",
}


def _symbols(lines: list[str]) -> dict[str, str]:
    """name -> shape-string for every instruction in a computation."""
    table = {}
    for line in lines:
        m = _INSTR_RE.match(line)
        if m:
            table[m.group(1)] = m.group(2)
    return table


def _dot_flops(line: str, table: dict[str, str], out_shape: str) -> float:
    """FLOPs of a dot: 2 * prod(output dims) * prod(lhs contracting dims)."""
    # operands may carry inline types: ``dot(f32[128,256]{1,0} %lhs, ...)``
    ops = re.search(
        r"dot\(\s*(?:[a-z0-9]+\[[0-9,]*\](?:\{[0-9,]*\})?\s+)?%?([\w\.\-]+)\s*,",
        line)
    cdims = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", line)
    out_elems = 1
    for dt, dims in _SHAPE_RE.findall(out_shape):
        for d in dims.split(","):
            if d:
                out_elems *= int(d)
    contract = 1
    if ops and cdims and ops.group(1) in table:
        lhs_dims_m = _SHAPE_RE.search(table[ops.group(1)])
        if lhs_dims_m:
            lhs_dims = [int(d) for d in lhs_dims_m.group(2).split(",") if d]
            for ci in cdims.group(1).split(","):
                if ci and int(ci) < len(lhs_dims):
                    contract *= lhs_dims[int(ci)]
    return 2.0 * out_elems * contract


def _execution_contexts(hlo: str) -> set[str]:
    """Computations whose instructions individually touch HBM: ENTRY, while
    bodies/conditions, and conditional branches.  Fusion bodies and
    reduction lambdas (referenced via ``calls=``/``to_apply=``) execute
    inside one kernel — counting their internals double-counts HBM traffic
    already accounted at the fusion call site."""
    ctx: set[str] = set()
    entry = _entry_name(hlo)
    if entry:
        ctx.add(entry)
    for m in re.finditer(r"condition=%?([\w\.\-]+), body=%?([\w\.\-]+)", hlo):
        ctx.update(m.groups())
    for m in _BRANCHES.finditer(hlo):
        ctx.update(c.strip().lstrip("%") for c in m.group(1).split(","))
    return ctx


def trip_weighted_cost(hlo: str) -> dict[str, float]:
    """Trip-count-weighted FLOPs and HBM-traffic estimate from compiled HLO.

    XLA's ``cost_analysis()`` counts each while body ONCE (verified on this
    backend), which undercounts scanned-layer models by ~n_layers x.  This
    walks computations with their execution multipliers and sums:
      * flops  — dot instructions in ALL computations (matmuls dominate
        every arch here);
      * bytes  — per-instruction output + resolvable operand bytes, but
        ONLY in execution contexts (ENTRY / loop bodies / branches): each
        fusion call site contributes its operands+output once, its internals
        never touch HBM.
    """
    comps = _split_computations(hlo)
    mult = computation_multipliers(hlo)
    exec_ctx = _execution_contexts(hlo)
    flops = 0.0
    bytes_ = 0.0
    for name, lines in comps.items():
        m = mult.get(name, 1.0)
        table = _symbols(lines)
        in_ctx = name in exec_ctx
        for line in lines:
            im = _INSTR_RE.match(line)
            if not im:
                continue
            _, out_shape, op = im.groups()
            if op in _FREE_OPS:
                continue
            if in_ctx:
                out_b = shape_bytes(out_shape)
                opnds = []
                args = re.search(rf"{op}\(([^)]*)\)", line)
                if args:
                    # operand lists may carry inline types whose dims contain
                    # commas — pull %names first, fall back to a bare split
                    names = re.findall(r"%([\w\.\-]+)", args.group(1))
                    if not names:
                        names = [a.strip() for a in args.group(1).split(",")]
                    for a in names:
                        if a in table:
                            opnds.append(shape_bytes(table[a]))
                if op in ("gather", "dynamic-slice"):
                    # sparse read: traffic ~ gathered rows + indices, not the
                    # whole table (operand 0)
                    instr_b = out_b + sum(opnds[1:])
                elif op in ("dynamic-update-slice", "scatter"):
                    # in-place update (XLA aliases the buffer): traffic ~ the
                    # update slice + indices, not a full-buffer copy
                    instr_b = 2 * sum(opnds[1:])
                else:
                    instr_b = out_b + sum(opnds)
                bytes_ += instr_b * m
            if op == "dot":
                flops += _dot_flops(line, table, out_shape) * m
    return {"flops": flops, "bytes": bytes_}


def collective_bytes(hlo: str) -> dict[str, float]:
    """Per-collective-type bytes moved per device per step (trip-weighted)."""
    comps = _split_computations(hlo)
    mult = computation_multipliers(hlo)
    out: dict[str, float] = {k: 0.0 for k in COLLECTIVES}
    counts: dict[str, int] = {k: 0 for k in COLLECTIVES}
    op_re = re.compile(
        r"=\s*(?P<shape>\([^)]*\)|[a-z0-9]+\[[0-9,]*\]\S*)\s+"
        r"(?P<op>" + "|".join(COLLECTIVES) + r")(?P<suffix>-start)?\("
    )
    for name, lines in comps.items():
        m = mult.get(name, 1.0)
        for line in lines:
            om = op_re.search(line)
            if not om:
                continue
            nbytes = shape_bytes(om.group("shape"))
            out[om.group("op")] += nbytes * m
            counts[om.group("op")] += 1
    out["total"] = sum(out[k] for k in COLLECTIVES)
    out["op_counts"] = counts  # type: ignore[assignment]
    return out
