"""End-to-end training driver.

Two modes, selected by --arch:

* ``graphgen-gcn`` (the paper): synthetic power-law graph -> coordinator
  partitioning -> balance table -> synchronized distributed subgraph
  generation + in-memory GCN training (the GraphGen+ pipeline), with
  checkpoint/restart and optional failure injection.

* any LM arch id: reduced-config training on synthetic token batches using
  the same substrate (AdamW, microbatching, checkpointing).

Examples:
    PYTHONPATH=src python -m repro.launch.train --arch graphgen-gcn --steps 50
    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m --steps 10 --smoke
    REPRO_FORCE_DEVICES=8 PYTHONPATH=src python -m repro.launch.train \
        --arch graphgen-gcn --steps 30 --workers 8
"""
import os
if os.environ.get("REPRO_FORCE_DEVICES"):
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={os.environ['REPRO_FORCE_DEVICES']} "
        + os.environ.get("XLA_FLAGS", "")
    )

import argparse        # noqa: E402
import time            # noqa: E402

import jax             # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np     # noqa: E402

from ..configs import get_config, smoke_config          # noqa: E402
from ..core.balance import balance_table                # noqa: E402
from ..core.config import TrainConfig                   # noqa: E402
from ..core.generation import make_distributed_generator  # noqa: E402
from ..core.partition import partition_edges            # noqa: E402
from ..core.pipeline import make_pipelined_step         # noqa: E402
from ..graph.synthetic import node_features, node_labels, powerlaw_graph  # noqa: E402
from ..models import gcn as gcn_mod                     # noqa: E402
from ..models import zoo                                # noqa: E402
from ..train import checkpoint as ckpt                  # noqa: E402
from ..train.optimizer import adam_update, init_adam    # noqa: E402
from ..train.train_loop import init_state, make_train_step  # noqa: E402
from .mesh import make_mesh                             # noqa: E402


#: ascending slack ladder probed by the drop-aware capacity calibration
SLACK_LADDER = (0.25, 0.5, 1.0, 1.5, 2.0)
#: calibration batches per rung — a single probe has no safety margin
#: against seed/rng draws with more uniques per destination
CALIBRATION_PROBES = 3
#: ascending hit-cap ladder (fractions of the probe-round capacity)
#: probed by the compact-wire calibration; a rung is accepted when no
#: probe demotes a hit, and a run that demotes on EVERY rung falls back
#: to the dense wire (the dense-fallback rung)
HIT_CAP_LADDER = (0.125, 0.25, 0.5)


def calibrate_capacity_slack(mesh, device_args, fanouts, probes,
                             ladder=SLACK_LADDER, cache_cfg=None) -> float:
    """Drop-aware capacity autotuning (ROADMAP item).

    ``probes`` is a list of ``(seeds, rng)`` calibration batches; the
    graph/table placement in ``device_args`` is shared across the whole
    ladder (slack only changes the compiled program, not the data).
    Returns the smallest slack whose ``SubgraphBatch.n_dropped`` is zero
    over EVERY probe — the all_to_all exchange buffers then carry no more
    static padding than the workload needs, with the multi-probe pass
    standing in for a worst-case bound.

    With ``cache_cfg`` the ladder probes the CACHED generator, and every
    rung starts from a freshly initialized (cold) cache: the heaviest
    owner-fetch traffic is the cold-start miss burst, and a cache warmed
    by a previous rung would understate it — the chosen slack would then
    drop requests on the real run's first iterations.  (Within a rung the
    cache threads across the probes, exactly as the real run warms up.)
    """
    from ..core.feature_cache import init_cache_state
    from ..core.generation import make_generator_fn
    from jax.sharding import NamedSharding, PartitionSpec as P

    w = mesh.shape["data"]
    feat_dim = device_args[2].shape[1]     # the placed [W*rows, D] table
    cached = cache_cfg is not None and cache_cfg.n_rows > 0
    for slack in ladder:
        gen_fn = jax.jit(make_generator_fn(
            mesh, fanouts=fanouts, capacity_slack=slack,
            cache_cfg=cache_cfg if cached else None))
        if cached:
            # COLD cache per rung (see docstring); init_cache_state is
            # mode-polymorphic (flat state or tiered (l1, l2) pytree)
            cache = jax.device_put(
                init_cache_state(cache_cfg, feat_dim, w),
                NamedSharding(mesh, P("data")))
        dropped = 0
        for seeds, rng in probes:
            if cached:
                batch, cache = gen_fn(device_args, seeds, rng, cache)
            else:
                batch = gen_fn(device_args, seeds, rng)
            dropped += int(np.asarray(batch.n_dropped).sum())
        if dropped == 0:
            return slack
        print(f"calibration: slack={slack} dropped {dropped} requests "
              f"over {len(probes)} probes")
    print(f"calibration: even slack={ladder[-1]} drops requests; keeping it")
    return ladder[-1]


def calibrate_probe_hit_cap(mesh, device_args, fanouts, probes, slack,
                            cache_cfg, ladder=HIT_CAP_LADDER):
    """Compact-wire hit-cap calibration (the probe-compaction ROADMAP item).

    Probes an ascending ladder of ``hit_cap`` rungs — fractions of the
    probe-round capacity the compiled fetch will actually use
    (``generation.probe_round_capacity``) — and returns the ``CacheConfig``
    of the smallest rung whose probes report ZERO demoted hits
    (``SubgraphBatch.n_probe_demoted``): the compact probe response then
    ships the fewest payload rows that still carry every hit the cache
    produced during calibration.  The cache warms WITHIN a rung (state
    threads across the probes), so later probes see warm-ish hit counts;
    steady-state hit excursions beyond the calibrated bound only demote
    (lost hit opportunity, logged by the training loop), never corrupt.

    If every rung demotes, the DENSE wire is the fallback rung: the hit
    population is too large for a payload bound to pay off, so the run
    keeps the format that can never demote.
    """
    from ..core.feature_cache import init_cache_state
    from ..core.generation import make_generator_fn, probe_round_capacity
    from ..graph.subgraph import slots_per_seed
    from jax.sharding import NamedSharding, PartitionSpec as P

    w = mesh.shape["data"]
    feat_dim = device_args[2].shape[1]
    b = probes[0][0].shape[1]              # seeds are [W, b]
    n_requests = b * slots_per_seed(fanouts)
    cap = probe_round_capacity(n_requests, w, slack)
    for frac in ladder:
        hc = max(int(cap * frac), 1)
        cfg = cache_cfg._replace(wire="compact", hit_cap=hc)
        gen_fn = jax.jit(make_generator_fn(
            mesh, fanouts=fanouts, capacity_slack=slack, cache_cfg=cfg))
        cache = jax.device_put(init_cache_state(cfg, feat_dim, w),
                               NamedSharding(mesh, P("data")))
        demoted = 0
        for seeds, rng in probes:
            batch, cache = gen_fn(device_args, seeds, rng, cache)
            demoted += int(np.asarray(batch.n_probe_demoted).sum())
        if demoted == 0:
            print(f"probe hit-cap auto-sized to {hc} rows/destination "
                  f"({frac:.0%} of the {cap}-slot probe round; override "
                  f"with --probe-hit-cap)")
            return cfg
        print(f"hit-cap calibration: hit_cap={hc} demoted {demoted} hits "
              f"over {len(probes)} probes")
    print(f"hit-cap calibration: even {ladder[-1]:.0%} of the probe round "
          f"demotes hits; falling back to the dense wire")
    return cache_cfg._replace(wire="dense", hit_cap=0)


def warm_capacity(miss_peak: int, w: int, slack: float, rows: int,
                  margin: int = 8) -> int:
    """Steady-state owner-exchange capacity from a warm miss measurement.

    ``miss_peak`` is the largest per-worker routed-miss count observed
    over the warm window; the per-destination capacity only needs to
    carry those misses (not the full pre-cache request count), spread
    over ``w`` destinations.  The skew allowance floors at 2x regardless
    of the calibrated ``slack``: steady-state miss counts are small, so
    their per-destination peaks are relatively spikier than the cold
    request mix the slack was calibrated on (and the training loop's
    drop-rollback still guards the residual risk).  Clamped to ``rows``
    (a destination can never serve more distinct ids than it owns)."""
    cap = int(-(-miss_peak // max(w, 1)) * max(slack, 2.0)) + margin
    return max(min(cap, rows), 1)


def train_gcn(args) -> dict:
    import dataclasses
    w = args.workers
    mesh = make_mesh((w,), ("data",))
    cfg = get_config(args.arch)
    if args.fanouts:
        try:
            fo = tuple(int(k) for k in args.fanouts.split(","))
        except ValueError:
            raise SystemExit(
                f"--fanouts expects comma-separated ints (e.g. 15,10,5), "
                f"got {args.fanouts!r}")
        if not fo or any(k < 1 for k in fo):
            raise SystemExit(f"--fanouts entries must be >= 1, got {fo}")
        cfg = dataclasses.replace(cfg, fanouts=fo)
    if args.cache_rows is not None:
        cfg = dataclasses.replace(cfg, cache_rows=args.cache_rows)
    if args.cache_admit is not None:
        cfg = dataclasses.replace(cfg, cache_admit=args.cache_admit)
    if args.cache_assoc is not None:
        cfg = dataclasses.replace(cfg, cache_assoc=args.cache_assoc)
    if args.cache_mode is not None:
        cfg = dataclasses.replace(cfg, cache_mode=args.cache_mode)
    if args.l1_rows is not None:
        cfg = dataclasses.replace(cfg, cache_l1_rows=args.l1_rows)
    if args.l1_promote is not None:
        cfg = dataclasses.replace(cfg, cache_l1_promote=args.l1_promote)
    if args.probe_wire is not None:
        cfg = dataclasses.replace(cfg, cache_wire=args.probe_wire)
    if args.probe_hit_cap is not None:
        cfg = dataclasses.replace(cfg, cache_hit_cap=args.probe_hit_cap)
    if args.feature_store is not None:
        cfg = dataclasses.replace(cfg, feature_store=args.feature_store)
    if args.host_gather_depth is not None:
        cfg = dataclasses.replace(cfg,
                                  host_gather_depth=args.host_gather_depth)
    if args.smoke:
        cfg = smoke_config(cfg)
    fanouts = cfg.fanouts
    host = cfg.feature_store == "host"
    if host and args.warm_recalibrate:
        raise SystemExit("--warm-recalibrate shrinks the owner-exchange "
                         "buffers, which --feature-store host replaces "
                         "with the L3 staging path — drop the flag")
    from ..core.feature_cache import CacheConfig
    cache_cfg = CacheConfig.from_model(cfg)
    cached = cache_cfg is not None

    graph = powerlaw_graph(args.nodes, avg_degree=args.avg_degree,
                           n_hot=max(args.nodes // 1000, 1), seed=args.seed)
    part = partition_edges(graph, w)                       # step 1
    feats = node_features(graph.n_nodes, cfg.gcn_in_dim, args.seed,
                          features_on_host=host)
    labels = node_labels(graph.n_nodes, cfg.n_classes, args.seed)
    table = balance_table(np.arange(graph.n_nodes), w, args.seed)  # step 2

    b = args.batch_per_worker
    rngs = jax.random.split(jax.random.PRNGKey(args.seed + 1), args.steps + 1)

    def seeds_for(t):
        sw = table.per_worker
        cols = (np.arange(b) + t * b) % sw.shape[1]
        return jnp.asarray(sw[:, cols])

    # --- profile-driven autotune: one trace + offline search replaces
    # the serial calibration ladders; the ladders survive below as the
    # fallback path the validator rolls back to on rejection ------------
    autotuned = None
    if args.autotune:
        from .autotune import autotune_gcn, candidate_cache_cfg
        at_rngs = jax.random.split(jax.random.PRNGKey(args.seed + 2),
                                   max(args.autotune_steps, 1))
        res = autotune_gcn(
            mesh, part, feats, labels, fanouts=fanouts,
            cache_cfg=cache_cfg, feature_store=cfg.feature_store,
            batch_per_worker=b, seeds_for=seeds_for, rngs=at_rngs,
            steps=args.autotune_steps,
            slack=(args.capacity_slack or cfg.capacity_slack or 2.0))
        if res.accepted:
            autotuned = res
            cand = res.candidate
            cfg = cfg.with_candidate(cand)
            fanouts = cfg.fanouts
            if cached:
                cache_cfg = candidate_cache_cfg(cache_cfg, cand)
            print(f"autotune: accepted (measured "
                  f"{res.measured_step_s * 1e3:.1f} ms/step warm)")
        else:
            print(f"autotune: WARNING — falling back to the calibration "
                  f"ladders ({res.reason})")

    need_slack_cal = (args.capacity_slack is None
                      and cfg.capacity_slack is None and w > 1
                      and not host and autotuned is None)
    # the compact probe wire needs a hit_cap; calibrate one unless the
    # config pins it or --probe-hit-cap was given (any explicit value —
    # including 0, which selects the uncalibrated half-capacity auto
    # bound — skips the ladder; replicated mode and W == 1 run no probe
    # round, so there is nothing to compact)
    need_hit_cap = (cached and w > 1 and cache_cfg.mode != "replicated"
                    and cache_cfg.wire == "compact"
                    and cache_cfg.hit_cap == 0
                    and args.probe_hit_cap is None
                    and not host and autotuned is None)
    cal_args = probes = None
    if need_slack_cal or need_hit_cap:
        # place the graph+tables once; every ladder rung (slack AND
        # hit-cap) only re-jits against the same placement
        _, cal_args = make_distributed_generator(
            mesh, part, feats, labels, fanouts=fanouts)
        probes = [(seeds_for(t), rngs[t]) for t in range(CALIBRATION_PROBES)]
    if args.capacity_slack is not None:
        slack = args.capacity_slack
    elif cfg.capacity_slack is not None:
        slack = cfg.capacity_slack       # config pins it: no calibration
    elif host:
        # host mode replaces the owner exchange with the L3 staging path,
        # whose default staging size never drops — the ladder would probe
        # a device-resident generator this run will not compile
        slack = 2.0
        if w > 1:
            print("capacity_slack fixed at 2.0 (--feature-store host "
                  "skips the drop-aware ladder: misses stage to the L3 "
                  "store instead of the owner exchange)")
    elif w == 1:
        slack = 2.0      # W=1 fetch is a local gather: capacity never binds
    else:
        # probing the CACHED generator (cold cache per rung) so the slack
        # covers the configured path's cold-start miss traffic
        slack = calibrate_capacity_slack(mesh, cal_args, fanouts, probes,
                                         cache_cfg=cache_cfg)
        print(f"capacity_slack auto-sized to {slack} "
              f"(override with --capacity-slack)")
    if need_hit_cap:
        cache_cfg = calibrate_probe_hit_cap(mesh, cal_args, fanouts, probes,
                                            slack, cache_cfg)
    del cal_args, probes

    gen_out = make_distributed_generator(                  # step 3
        mesh, part, feats, labels, fanouts=fanouts, capacity_slack=slack,
        cache_cfg=cache_cfg, feature_store=cfg.feature_store,
        host_gather_depth=cfg.host_gather_depth,
    )
    store = None
    cache = None
    if host and cached:
        gen_fn, device_args, store, cache = gen_out
    elif host:
        gen_fn, device_args, store = gen_out
    elif cached:
        gen_fn, device_args, cache = gen_out
    else:
        gen_fn, device_args = gen_out
    if host:
        print(f"L3 host feature store: {feats.shape[0]}x{feats.shape[1]} "
              f"f32 table ({feats.nbytes / 1e6:.1f} MB) in host RAM, "
              f"gather depth {cfg.host_gather_depth} "
              f"({'overlapped' if cfg.host_gather_depth == 2 else 'synchronous'})")
    if cached:
        line = (f"hot-node cache: {cache_cfg.n_rows} rows/worker "
                f"({cache_cfg.assoc}-way, {cache_cfg.mode}), "
                f"admit-after-{cache_cfg.admit}")
        if cache_cfg.mode == "tiered":
            line += (f" + {cache_cfg.l1_rows}-row replicated L1 "
                     f"(promote-after-{cache_cfg.l1_promote})")
        if cache_cfg.mode != "replicated" and w > 1:
            line += f", {cache_cfg.wire} probe wire"
            if cache_cfg.wire == "compact" and cache_cfg.hit_cap:
                line += f" (hit_cap {cache_cfg.hit_cap})"
        print(line)
    tcfg = TrainConfig(learning_rate=args.lr, total_steps=args.steps,
                       checkpoint_every=args.ckpt_every)
    params = gcn_mod.init_gcn(cfg, jax.random.PRNGKey(args.seed))
    opt = init_adam(params)

    def train_fn(params, opt, batch):                      # step 4
        loss, grads = jax.value_and_grad(gcn_mod.gcn_loss)(params, batch)
        params, opt, _ = adam_update(tcfg, params, grads, opt)
        return params, opt, loss

    start = 0
    if args.resume and ckpt.latest_step(args.ckpt_dir) is not None:
        start = ckpt.latest_step(args.ckpt_dir)
        params, opt = ckpt.restore(args.ckpt_dir, start, (params, opt))
        print(f"resumed from step {start}")

    step = None
    consume_step = None
    train_step = jax.jit(train_fn)
    pending = None
    if host:
        from ..core.host_store import empty_admit
        from ..core.pipeline import make_host_consume_step
        consume_step = jax.jit(make_host_consume_step(train_fn))
    else:
        step = jax.jit(make_pipelined_step(gen_fn, train_fn, cached=cached))
    # batch t comes from seeds_for(t)/rngs[t] — a resumed run must prime the
    # pipeline at `start`, not at 0.  Host mode keeps the cache OUT of the
    # carry: the split dispatch (gen / issue / consume) threads it through
    # the generation call directly.
    if host and cached:
        adm_ids, adm_rows = empty_admit(w, feats.shape[1])
        batch, cache, req = gen_fn(device_args, seeds_for(start),
                                   rngs[start], cache, adm_ids, adm_rows)
        carry = (params, opt, batch, req)
        pending = store.issue(req.ids)
    elif host:
        batch, req = gen_fn(device_args, seeds_for(start), rngs[start])
        carry = (params, opt, batch, req)
        pending = store.issue(req.ids)
    elif cached:
        batch, cache = gen_fn(device_args, seeds_for(start), rngs[start], cache)
        carry = (params, opt, batch, cache)
    else:
        batch = gen_fn(device_args, seeds_for(start), rngs[start])
        carry = (params, opt, batch)
    losses = []
    miss_peak = 0
    wide_step = None          # pre-recalibration step, kept for rollback
    # the first batches carry the cold-start miss burst the cache exists to
    # eliminate — measuring them would size the "warm" buffers to the cold
    # peak; only the second half of the warm window counts
    warm_from = start + max(args.warm_recalibrate // 2, 1)
    t0 = time.perf_counter()
    for t in range(start, args.steps):
        if cached and args.warm_recalibrate and t >= warm_from:
            miss_peak = max(miss_peak, int(np.asarray(
                carry[2].n_cache_misses).max()))
        # rollback check FIRST: when it fires, carry[2] was generated by
        # the SHRUNKEN generator (the recalibration below installs the
        # shrink only after this point, so a drop in a wide-generated
        # batch can never be misattributed to the shrink)
        if (wide_step is not None
                and int(np.asarray(carry[2].n_dropped).sum()) > 0):
            # the shrunken buffers dropped requests (a miss-rate excursion
            # beyond the warm sample) — zero-filled features must never
            # train, so regenerate THIS batch at the calibrated width and
            # roll the step back for good.  (The regeneration re-offers
            # the batch's served rows to the cache — a second admission
            # tick for those ids, harmless: admission is a heuristic and
            # rows stay verbatim table copies.)
            step = wide_step
            wide_step = None
            batch, cache_now = wide_gen(device_args, seeds_for(t), rngs[t],
                                        carry[3])
            carry = (carry[0], carry[1], batch, cache_now)
            print(f"step {t}: shrunken capacity dropped requests — "
                  f"regenerated the batch and rolled back to the "
                  f"calibrated width")
        if (args.warm_recalibrate and cached and w > 1
                and t == start + args.warm_recalibrate
                and t + 1 < args.steps):
            # cache-aware capacity shrink: by now the cache serves the hot
            # head, so the owner exchange only carries steady-state misses
            # — re-jit the generator with buffers sized to the warm peak
            # (the cold-start burst is behind us; the cache state carries
            # over, so the miss rate will not rebound)
            from ..core.generation import make_generator_fn
            rows_pw = device_args[2].shape[0] // w
            new_cap = warm_capacity(miss_peak, w, slack, rows_pw)
            wide_step, wide_gen = step, gen_fn
            gen_fn = jax.jit(make_generator_fn(
                mesh, fanouts=fanouts, capacity_slack=slack,
                cache_cfg=cache_cfg, fetch_capacity=new_cap))
            step = jax.jit(make_pipelined_step(gen_fn, train_fn,
                                               cached=True))
            print(f"warm re-calibration at step {t}: owner-exchange "
                  f"capacity -> {new_cap} slots/destination "
                  f"(peak warm per-worker misses {miss_peak})")
        if t + 1 < args.steps:
            if host:
                # split dispatch: collect batch t's landed gather, queue
                # gen t+1 (admitting the landed rows), issue ITS gather,
                # then dispatch patch+train of batch t — the gather's
                # host work overlaps the consume program's compute
                landed = pending.rows()
                if cached:
                    batch, cache, req = gen_fn(device_args,
                                               seeds_for(t + 1),
                                               rngs[t + 1], cache,
                                               carry[3].ids, landed)
                else:
                    batch, req = gen_fn(device_args, seeds_for(t + 1),
                                        rngs[t + 1])
                pending = store.issue(req.ids)
                p, o, loss = consume_step(carry[0], carry[1], carry[2],
                                          carry[3], landed)
                carry = (p, o, batch, req)
            else:
                carry, loss = step(carry, device_args, seeds_for(t + 1),
                                   rngs[t + 1])
        elif host:
            # drain: the last batch still has staged feature holes
            p, o, loss = consume_step(carry[0], carry[1], carry[2],
                                      carry[3], pending.rows())
            carry = (p, o) + carry[2:]
        else:
            # nothing left to pre-generate: train-only final step (the same
            # redundant-generation fix pipelined_loop carries)
            p, o, loss = train_step(carry[0], carry[1], carry[2])
            carry = (p, o) + carry[2:]
        losses.append(float(loss))
        if (t + 1) % args.ckpt_every == 0:
            ckpt.save(args.ckpt_dir, t + 1, (carry[0], carry[1]),
                      keep=tcfg.keep_checkpoints)
        if (t + 1) % args.log_every == 0:
            line = f"step {t+1}: loss={losses[-1]:.4f}"
            nb = carry[2]
            if cached:
                line += f" cache_hit_rate={nb.cache_hit_rate():.3f}"
            dropped = int(np.asarray(nb.n_dropped).sum())
            if dropped:
                line += f" DROPPED={dropped}"
            if cached and nb.n_probe_demoted is not None:
                demoted = int(np.asarray(nb.n_probe_demoted).sum())
                if demoted:
                    # a hit excursion beyond the calibrated hit_cap: those
                    # ids were owner-fetched instead (lost hit, not a bug)
                    line += f" demoted={demoted}"
            print(line)
    if pending is not None:
        # a zero-step run (resume landing exactly at args.steps) primes the
        # gather but never reaches the loop's drain; rows() memoizes, so on
        # every other path this hits the already-landed buffer for free
        pending.rows()
    if args.export_serve:
        if not cached:
            raise SystemExit("--export-serve checkpoints params + the warm "
                             "cache state; this run has no cache "
                             "(--cache-rows 0)")
        # device mode threads the cache through the pipelined carry; host
        # mode keeps it in the local variable (see the carry comment above)
        cache_final = carry[3] if not host else cache
        ckpt.save_serving_state(args.export_serve, args.steps, carry[0],
                                cache_final, cache_cfg=cache_cfg)
        print(f"exported serving state (params + warm cache) to "
              f"{args.export_serve}")
    jax.block_until_ready(carry[0])
    dt = time.perf_counter() - t0
    nodes_per_iter = batch.nodes_per_iteration()
    out = {"losses": losses, "nodes_per_iter": nodes_per_iter, "wall_s": dt,
           "capacity_slack": slack}
    if host:
        out["host_gather_mb"] = store.bytes_issued / 1e6
        print(f"L3 host gathers shipped {out['host_gather_mb']:.1f} MB "
              f"over PCIe")
    print(f"trained {args.steps - start} steps in {dt:.1f}s "
          f"({nodes_per_iter} padded nodes/iter, "
          f"{(args.steps - start) * nodes_per_iter / dt:,.0f} nodes/s)")
    if cached:
        out["cache_hit_rate"] = carry[2].cache_hit_rate()
        print(f"steady-state cache hit rate: {out['cache_hit_rate']:.3f}")
    return out


def train_lm(args) -> dict:
    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_config(cfg)
    api = zoo.build(cfg)
    tcfg = TrainConfig(learning_rate=args.lr, total_steps=args.steps,
                       microbatches=args.microbatches)
    params = api.init(jax.random.PRNGKey(args.seed))
    state = init_state(params, tcfg)
    step = jax.jit(make_train_step(api.loss, tcfg))

    start = 0
    if args.resume and ckpt.latest_step(args.ckpt_dir) is not None:
        start = ckpt.latest_step(args.ckpt_dir)
        state = ckpt.restore(args.ckpt_dir, start, state)
        print(f"resumed from step {start}")

    rng = np.random.default_rng(args.seed)
    b, s = args.lm_batch, args.lm_seq
    losses = []
    t0 = time.perf_counter()
    for t in range(start, args.steps):
        toks = rng.integers(0, cfg.vocab_size, (b, s), dtype=np.int32)
        batch = {"tokens": jnp.asarray(toks),
                 "labels": jnp.asarray(np.roll(toks, -1, axis=1))}
        if cfg.family == "vlm":
            batch["vision"] = jnp.asarray(
                rng.standard_normal((b, cfg.n_vision_tokens, cfg.d_vision),
                                    dtype=np.float32))
        if cfg.family == "audio":
            batch["frames"] = jnp.asarray(
                rng.standard_normal((b, cfg.n_audio_frames, cfg.d_audio),
                                    dtype=np.float32))
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
        if (t + 1) % args.ckpt_every == 0:
            ckpt.save(args.ckpt_dir, t + 1, state, keep=tcfg.keep_checkpoints)
        if (t + 1) % args.log_every == 0:
            print(f"step {t+1}: loss={losses[-1]:.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f}")
    dt = time.perf_counter() - t0
    print(f"trained {args.steps - start} steps in {dt:.1f}s")
    return {"losses": losses, "wall_s": dt}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="graphgen-gcn")
    ap.add_argument("--fanouts", default=None,
                    help="comma-separated per-hop fanouts override, e.g. 15,10,5")
    ap.add_argument("--capacity-slack", type=float, default=None,
                    help="feature-shuffle capacity slack; omit to auto-size "
                         "from a drop-aware calibration step")
    ap.add_argument("--cache-rows", type=int, default=None,
                    help="hot-node feature cache rows/worker (rounded UP "
                         "to a power of two; 0 disables; default from "
                         "config)")
    ap.add_argument("--cache-admit", type=int, default=None,
                    help="misses before a node id is admitted to the cache")
    ap.add_argument("--cache-assoc", type=int, default=None,
                    choices=[1, 2, 4],
                    help="cache ways per set (1 = direct-mapped)")
    ap.add_argument("--cache-mode", default=None,
                    choices=["replicated", "sharded", "tiered"],
                    help="cache placement: per-worker replicas, id-space "
                         "shards with cache-aware routing, or a "
                         "replicated L1 head in front of the sharded L2")
    ap.add_argument("--l1-rows", type=int, default=None,
                    help="tiered mode: replicated L1 rows/worker (rounded "
                         "UP to a power of two; 0 auto-sizes to "
                         "cache_rows/8)")
    ap.add_argument("--l1-promote", type=int, default=None,
                    help="tiered mode: observations of a row before it is "
                         "promoted into the local L1")
    ap.add_argument("--probe-wire", default=None,
                    choices=["dense", "compact"],
                    help="shard-probe response wire format: dense ships "
                         "the full [W, cap, D] row block, compact (the "
                         "config default) ships a hit bitmap + a row "
                         "payload bounded by the calibrated hit cap")
    ap.add_argument("--probe-hit-cap", type=int, default=None,
                    help="compact wire: pin the probe-response payload "
                         "rows per destination (skips the hit-cap "
                         "calibration ladder; 0 = auto, half the probe "
                         "capacity)")
    ap.add_argument("--feature-store", default=None,
                    choices=["device", "host"],
                    help="where the feature table lives: device row-shards "
                         "it over the workers, host keeps it in host RAM "
                         "behind the async L3 gather tier (for tables "
                         "beyond aggregate device memory)")
    ap.add_argument("--host-gather-depth", type=int, default=None,
                    choices=[1, 2],
                    help="host store gather pipeline depth: 2 overlaps the "
                         "gather with the compute step (default), 1 "
                         "gathers synchronously (the overlap-off baseline)")
    ap.add_argument("--autotune", action="store_true",
                    help="replace the serial calibration ladders with one "
                         "instrumented trace window + an offline cost-model "
                         "search over (fanouts, cache_rows, l1_rows, assoc, "
                         "hit_cap, capacity_slack); a live validator "
                         "accepts the pick or falls back to the ladders")
    ap.add_argument("--autotune-steps", type=int, default=8,
                    help="instrumented steps the autotune trace records "
                         "(the cold half is excluded from the fit; fewer "
                         "than 4 degrades to the calibration ladders)")
    ap.add_argument("--warm-recalibrate", type=int, default=0,
                    help="after N warm steps, shrink the owner-exchange "
                         "capacity to the observed steady-state cache-miss "
                         "peak (0 disables; needs the cache and W > 1)")
    ap.add_argument("--cache-probe-impl", default="jnp",
                    choices=["jnp", "pallas"],
                    help="cache probe implementation: XLA gather+compare or "
                         "the fused Pallas VMEM kernel (native on TPU)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--workers", type=int, default=1)
    ap.add_argument("--nodes", type=int, default=20_000)
    ap.add_argument("--avg-degree", type=float, default=10.0)
    ap.add_argument("--batch-per-worker", type=int, default=32)
    ap.add_argument("--lm-batch", type=int, default=4)
    ap.add_argument("--lm-seq", type=int, default=64)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=5)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--export-serve", default=None, metavar="DIR",
                    help="after training, checkpoint params + the warm "
                         "cache state for the serving tier "
                         "(repro.launch.serve --warm-from DIR)")
    args = ap.parse_args()
    if args.cache_probe_impl != "jnp":
        from ..core.feature_cache import set_probe_impl
        set_probe_impl(args.cache_probe_impl)
    if get_config(args.arch).family == "gcn":
        train_gcn(args)
    else:
        train_lm(args)


if __name__ == "__main__":
    main()
