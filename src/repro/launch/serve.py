"""Serving drivers: the graph-serving tier and the LM decode loop.

Graph serving (``--arch graphgen-gcn``) is the production half of
GraphGen+: a *frozen* model answering seed-node requests at low latency.
Requests flow through three stages:

1. **bounded request queue** — a producer thread enqueues seed-id
   batches; the server drains them (backpressure is the queue bound);
2. **bucket ladder** — each request's batch size is padded up to the
   smallest bucket in a small shape ladder, and the ladder is compiled
   once at startup, so a request NEVER lands on a re-JIT (the latency
   killer the JIT-compiled-inference paper names);
3. **read-mostly fetch** — subgraph generation + a forward-only GCN run
   against the tiered L1/L2 feature cache in its frozen serve view
   (``CacheConfig.serve_view()``): probes serve hits, the admit stage is
   the identity, and the warm state — restored from a training
   checkpoint (``--warm-from``, see ``train.checkpoint``) or built by a
   dedicated warmup sweep over the Zipf head — is bit-stable across
   requests.

LM serving (any zoo arch id) drives batched autoregressive decoding with
a KV/SSM cache, token-by-token.

Examples:
    PYTHONPATH=src python -m repro.launch.serve --arch graphgen-gcn \\
        --smoke --requests 64
    REPRO_FORCE_DEVICES=4 PYTHONPATH=src python -m repro.launch.serve \\
        --arch graphgen-gcn --smoke --workers 4 --buckets 8,16,32
    PYTHONPATH=src python -m repro.launch.serve --arch mamba2-1.3b --smoke \\
        --batch 4 --prompt-len 16 --gen-len 16
"""
import os
if os.environ.get("REPRO_FORCE_DEVICES"):
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={os.environ['REPRO_FORCE_DEVICES']} "
        + os.environ.get("XLA_FLAGS", "")
    )

import argparse        # noqa: E402
import queue           # noqa: E402
import threading       # noqa: E402
import time            # noqa: E402

import jax             # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np     # noqa: E402

from ..configs import get_config, smoke_config          # noqa: E402
from ..core.feature_cache import CacheConfig            # noqa: E402
from ..core.generation import (make_distributed_generator,  # noqa: E402
                               make_generator_fn)
from ..core.partition import partition_edges            # noqa: E402
from ..graph.synthetic import (node_features, node_labels,  # noqa: E402
                               powerlaw_graph)
from ..models import gcn as gcn_mod                     # noqa: E402
from ..models import zoo                                # noqa: E402
from ..train import checkpoint as ckpt                  # noqa: E402
from .mesh import make_mesh                             # noqa: E402

#: default request-shape ladder: per-worker seed slots per bucket.  Small
#: on purpose — each bucket is one compiled program resident for the
#: server's lifetime, and pad waste is bounded by the ladder's spacing.
DEFAULT_BUCKETS = (8, 16, 32)


def jit_compile_count(jitted) -> int:
    """Compiled-program count of a ``jax.jit``-wrapped callable — the
    zero-recompile probe the serving tier and ``benchmarks/serve_latency``
    assert with.  Reads the jit executable-cache size: one entry per
    traced input signature, so a request that lands on an un-compiled
    shape is visible as a count increase."""
    size = getattr(jitted, "_cache_size", None)
    if size is None:
        raise RuntimeError(
            "this jax build exposes no jit cache-size probe "
            "(jit_fn._cache_size) — the zero-recompile gate cannot run")
    return int(size())


def bucket_for(n: int, buckets, n_workers: int) -> int:
    """Smallest ladder bucket (per-worker seed slots) whose padded
    capacity ``bucket * n_workers`` holds an ``n``-seed request.  Raises
    on a request larger than the ladder's top bucket — an oversized
    request must be split by the caller, never silently truncated."""
    if n <= 0:
        raise ValueError(f"a request needs at least one seed, got {n}")
    for b in buckets:
        if b * n_workers >= n:
            return b
    raise ValueError(
        f"request of {n} seeds exceeds the bucket ladder's capacity "
        f"{buckets[-1] * n_workers} (buckets {tuple(buckets)} x "
        f"{n_workers} workers) — split the request or widen the ladder")


def warmup_sweep(gen_fn, device_args, cache, head_ids, *, n_workers: int,
                 bucket: int, sweeps: int, seed: int = 0):
    """Pre-warm a cache state for serving: run the MUTABLE generator over
    the Zipf head before any request arrives.

    ``head_ids`` is the hot node-id population, hottest first (e.g. ids
    in descending degree order); each sweep feeds the next
    ``bucket * n_workers`` of them (wrapping) through
    ``gen_fn(device_args, seeds, rng, cache) -> (batch, cache)``, so the
    head rows — and the hot neighbors their fanouts pull in — pass the
    frequency-admission threshold and are resident before the serve view
    freezes the state.  Returns the warmed cache."""
    head = np.asarray(head_ids, np.int32).reshape(-1)
    if head.size == 0:
        raise ValueError("warmup_sweep needs a non-empty head population")
    per = bucket * n_workers
    rng0 = jax.random.PRNGKey(seed)
    for t in range(sweeps):
        take = (np.arange(per) + t * per) % head.size
        seeds = jnp.asarray(head[take].reshape(n_workers, bucket))
        _, cache = gen_fn(device_args, seeds, jax.random.fold_in(rng0, t),
                          cache)
    return cache


class GraphServer:
    """Read-mostly graph-serving engine: frozen params + warm cache +
    a compiled bucket ladder.

    Holds ONE warm cache state and one parameter tree, both read-only,
    and answers ``serve(seed_ids) -> class predictions`` by padding the
    request to its ladder bucket and running the forward-only program
    (frozen-cache subgraph generation + GCN forward + argmax) compiled
    for that bucket.  Call :meth:`warmup` once at startup to compile
    every bucket; after that the request path never traces —
    :meth:`compile_count` is the probe that proves it."""

    def __init__(self, gen_fn, device_args, params, cache, *,
                 buckets=DEFAULT_BUCKETS, n_workers: int, seed: int = 0):
        self._buckets = tuple(sorted(set(int(b) for b in buckets)))
        if not self._buckets or self._buckets[0] <= 0:
            raise ValueError(f"bucket ladder must name positive sizes, "
                             f"got {buckets}")
        self._w = int(n_workers)
        self._device_args = device_args
        self._params = params
        self._cache = cache
        self._rng0 = jax.random.PRNGKey(seed)
        self._n_requests = 0
        cached = cache is not None

        def _step(device_args, seeds, rng, cache, params):
            if cached:
                batch = gen_fn(device_args, seeds, rng, cache)
            else:
                batch = gen_fn(device_args, seeds, rng)
            logits = gcn_mod.gcn_forward(params, batch)
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)

        self._step = jax.jit(_step)

    @property
    def buckets(self) -> tuple:
        """The ladder: per-worker seed slots per bucket, ascending."""
        return self._buckets

    @property
    def capacity(self) -> int:
        """Largest request (seed count) the ladder can hold."""
        return self._buckets[-1] * self._w

    def compile_count(self) -> int:
        """Programs compiled so far (one per traced bucket shape).  After
        :meth:`warmup` this equals ``len(buckets)`` and MUST NOT grow on
        the request path — the zero-recompile serving invariant."""
        return jit_compile_count(self._step)

    def warmup(self) -> int:
        """Compile the whole ladder by serving one synthetic request per
        bucket (startup cost, paid exactly once — never on the request
        path).  Returns the compiled-program count, the baseline the
        request loop's zero-recompile assertion compares against."""
        for b in self._buckets:
            self.serve(np.zeros(b * self._w, np.int32))
        return self.compile_count()

    def serve(self, seed_ids) -> np.ndarray:
        """Answer one request: ``int32`` class predictions, one per seed.

        The request is padded to its ladder bucket (repeating the last
        seed — any valid id; the padded slots' predictions are sliced
        off), spread row-major across the worker axis, and run through
        the bucket's already-compiled program.  Blocks until the
        predictions are on host — the caller's clock reads end-to-end
        request latency."""
        ids = np.asarray(seed_ids, np.int32).reshape(-1)
        n = ids.size
        b = bucket_for(n, self._buckets, self._w)
        padded = np.empty(b * self._w, np.int32)
        padded[:n] = ids
        padded[n:] = ids[n - 1]
        seeds = jnp.asarray(padded.reshape(self._w, b))
        rng = jax.random.fold_in(self._rng0, self._n_requests)
        self._n_requests += 1
        preds = self._step(self._device_args, seeds, rng, self._cache,
                           self._params)
        return np.asarray(preds)[:n]


def _zipf_request_stream(rng, n_requests, head_order, max_size):
    """Synthetic serve traffic: request sizes uniform in [1, max_size],
    seed ids Zipf-ranked over ``head_order`` (hot head requested most —
    the access pattern the warm cache exists for)."""
    n_nodes = head_order.size
    for _ in range(n_requests):
        size = int(rng.integers(1, max_size + 1))
        ranks = np.minimum(rng.zipf(1.5, size=size), n_nodes) - 1
        yield head_order[ranks]


def serve_gcn(args) -> dict:
    """Graph-serving driver: build the read-mostly server, then drain a
    bounded queue of synthetic seed-node requests through it.

    Setup mirrors the training driver (power-law graph, partitioning,
    feature/label tables), then: warm the cache (``--warm-from`` restores
    a training checkpoint's params + cache state; otherwise a
    ``--warmup-sweeps`` sweep over the degree-ranked Zipf head), compile
    the bucket ladder, and serve ``--requests`` requests from a
    ``--queue-depth``-bounded queue fed by a producer thread.  Reports
    p50/p99 end-to-end latency, sustained QPS, and the request-path
    compile count (which must be zero)."""
    w = args.workers
    mesh = make_mesh((w,), ("data",))
    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_config(cfg)
    cache_cfg = CacheConfig.from_model(cfg)
    buckets = tuple(int(b) for b in args.buckets.split(","))

    graph = powerlaw_graph(args.nodes, avg_degree=args.avg_degree,
                           n_hot=max(args.nodes // 1000, 1), seed=args.seed)
    part = partition_edges(graph, w)
    feats = node_features(graph.n_nodes, cfg.gcn_in_dim, args.seed)
    labels = node_labels(graph.n_nodes, cfg.n_classes, args.seed)
    params = gcn_mod.init_gcn(cfg, jax.random.PRNGKey(args.seed))
    # degree-ranked hot head: warmup population AND the synthetic request
    # stream's Zipf rank -> id mapping
    head_order = np.argsort(
        -np.diff(graph.indptr)).astype(np.int32)

    cache = None
    if cache_cfg is not None:
        gen_mut, device_args, cache0 = make_distributed_generator(
            mesh, part, feats, labels, fanouts=cfg.fanouts,
            cache_cfg=cache_cfg)
        serve_cfg = cache_cfg.serve_view()
        if args.warm_from:
            from jax.sharding import NamedSharding, PartitionSpec as P
            shardings = {
                "params": jax.tree.map(
                    lambda _: NamedSharding(mesh, P()), params),
                "cache": jax.tree.map(
                    lambda _: NamedSharding(mesh, P("data")), cache0),
            }
            params, cache = ckpt.restore_serving_state(
                args.warm_from, params, cache0, shardings=shardings,
                expect_cache_cfg=serve_cfg)
            print(f"restored serving state from {args.warm_from} "
                  f"(params + warm cache)")
        else:
            head = head_order[:max(buckets[-1] * w,
                                   args.warmup_head or cache_cfg.n_rows)]
            cache = warmup_sweep(gen_mut, device_args, cache0, head,
                                 n_workers=w, bucket=buckets[-1],
                                 sweeps=args.warmup_sweeps, seed=args.seed)
            print(f"warmup sweep: {args.warmup_sweeps} sweeps over the "
                  f"{head.size}-node Zipf head")
        # the serve generator: same mesh/placement, frozen serve view
        gen_serve = make_generator_fn(mesh, fanouts=cfg.fanouts,
                                      cache_cfg=serve_cfg)
    else:
        gen_serve, device_args = make_distributed_generator(
            mesh, part, feats, labels, fanouts=cfg.fanouts)

    server = GraphServer(gen_serve, device_args, params, cache,
                         buckets=buckets, n_workers=w, seed=args.seed)
    server.warmup()
    startup_compiles = server.compile_count()
    print(f"bucket ladder {server.buckets} compiled at startup "
          f"({startup_compiles} programs, capacity "
          f"{server.capacity} seeds/request)")

    req_q = queue.Queue(maxsize=args.queue_depth)
    rng = np.random.default_rng(args.seed + 7)

    def _producer():
        # enqueue the synthetic request stream; the bounded queue is the
        # backpressure (put blocks while the server is `queue-depth`
        # requests behind).  None is the drain sentinel.
        for ids in _zipf_request_stream(rng, args.requests, head_order,
                                        server.capacity):
            req_q.put((time.perf_counter(), ids))
        req_q.put(None)

    latencies = []
    producer = threading.Thread(target=_producer, name="serve-producer")
    producer.start()
    try:
        t0 = time.perf_counter()
        while True:
            item = req_q.get()
            if item is None:
                break
            t_enq, ids = item
            server.serve(ids)
            latencies.append(time.perf_counter() - t_enq)
        wall = time.perf_counter() - t0
    finally:
        producer.join()

    request_compiles = server.compile_count() - startup_compiles
    p50, p99 = (np.percentile(latencies, [50, 99]) * 1e3
                if latencies else (0.0, 0.0))
    qps = len(latencies) / wall if wall > 0 else 0.0
    print(f"served {len(latencies)} requests in {wall:.2f}s "
          f"({qps:.1f} req/s): p50 {p50:.2f}ms p99 {p99:.2f}ms, "
          f"{request_compiles} request-path compiles")
    if request_compiles:
        print("WARNING: requests landed on uncompiled shapes — the "
              "bucket ladder does not cover the request stream")
    return {"p50_ms": float(p50), "p99_ms": float(p99), "qps": float(qps),
            "n_requests": len(latencies), "wall_s": float(wall),
            "request_path_compiles": int(request_compiles),
            "startup_compiles": int(startup_compiles)}


def serve_lm(args) -> dict:
    """LM serving driver: batched autoregressive decode with a KV/SSM
    cache, prefilling token-by-token through the decode path (exercises
    the cache; a production server would run the batched prefill
    forward), then timing ``--gen-len`` decode steps.

    With ``--prompt-len 0`` generation starts from a fixed BOS-like
    token (id 0) — there are no prompt logits to argmax.  The timed loop
    accumulates DEVICE arrays and transfers to host only after the final
    ``block_until_ready``, so the tok/s figure measures decode, not one
    forced host sync per token."""
    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_config(cfg)
    api = zoo.build(cfg)
    if api.decode is None:
        raise SystemExit(f"{args.arch} has no decode path")
    params = api.init(jax.random.PRNGKey(args.seed))
    total = args.prompt_len + args.gen_len
    cache = api.init_cache(args.batch, total)
    decode = jax.jit(api.decode)

    rng = np.random.default_rng(args.seed)
    prompt = rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len),
                          dtype=np.int32)
    logits = None
    for p in range(args.prompt_len):
        logits, cache = decode(params, cache, jnp.asarray(prompt[:, p:p+1]),
                               jnp.int32(p))
    pos = args.prompt_len
    if logits is None:
        # zero-trip prefill: nothing to argmax — start from a fixed token
        tok = jnp.zeros((args.batch, 1), jnp.int32)
    else:
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    out = []
    jax.block_until_ready(tok)          # the clock starts on settled inputs
    t0 = time.perf_counter()
    for _ in range(args.gen_len):
        out.append(tok)                 # device array — no host sync here
        logits, cache = decode(params, cache, tok, jnp.int32(pos))
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        pos += 1
    jax.block_until_ready(tok)
    dt = time.perf_counter() - t0
    toks = args.gen_len * args.batch
    print(f"generated {toks} tokens in {dt:.2f}s ({toks/dt:.1f} tok/s batched)")
    gen = (np.concatenate([np.asarray(t) for t in out], axis=1)
           if out else np.zeros((args.batch, 0), np.int32))
    if gen.size:
        print("sample token ids:", gen[0][:16])
    return {"tok_s": toks / dt, "tokens": gen}


def main() -> None:
    """CLI entry: dispatch on the arch family — ``gcn`` archs get the
    graph-serving tier, zoo archs the LM decode driver."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    # --- LM decode flags -------------------------------------------------
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=16)
    # --- graph-serving flags ---------------------------------------------
    ap.add_argument("--workers", type=int, default=1)
    ap.add_argument("--nodes", type=int, default=20_000)
    ap.add_argument("--avg-degree", type=float, default=10.0)
    ap.add_argument("--buckets", default="8,16,32",
                    help="request-shape ladder: per-worker seed slots, "
                         "comma-separated ascending (compiled at startup)")
    ap.add_argument("--requests", type=int, default=256,
                    help="synthetic requests to serve")
    ap.add_argument("--queue-depth", type=int, default=32,
                    help="bounded request-queue size (backpressure)")
    ap.add_argument("--warmup-sweeps", type=int, default=8,
                    help="mutable-generator sweeps over the Zipf head "
                         "before freezing the cache")
    ap.add_argument("--warmup-head", type=int, default=0,
                    help="head population size for the warmup sweep "
                         "(0 = the cache's row count)")
    ap.add_argument("--warm-from", default=None,
                    help="restore params + warm cache from a serving "
                         "checkpoint dir (train.py --export-serve) "
                         "instead of sweeping")
    args = ap.parse_args()
    if get_config(args.arch).family == "gcn":
        serve_gcn(args)
    else:
        serve_lm(args)


if __name__ == "__main__":
    main()
