"""Serving driver: batched autoregressive decoding with a KV/SSM cache.

    PYTHONPATH=src python -m repro.launch.serve --arch mamba2-1.3b --smoke \
        --batch 4 --prompt-len 16 --gen-len 16
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config, smoke_config
from ..models import zoo


def serve(args) -> dict:
    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_config(cfg)
    api = zoo.build(cfg)
    if api.decode is None:
        raise SystemExit(f"{args.arch} has no decode path")
    params = api.init(jax.random.PRNGKey(args.seed))
    total = args.prompt_len + args.gen_len
    cache = api.init_cache(args.batch, total)
    decode = jax.jit(api.decode)

    rng = np.random.default_rng(args.seed)
    prompt = rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len),
                          dtype=np.int32)
    # prefill token-by-token through the decode path (exercises the cache);
    # a production server would run the batched prefill forward instead.
    tok = jnp.asarray(prompt[:, :1])
    for p in range(args.prompt_len):
        logits, cache = decode(params, cache, jnp.asarray(prompt[:, p:p+1]),
                               jnp.int32(p))
    out = []
    t0 = time.perf_counter()
    pos = args.prompt_len
    tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    for _ in range(args.gen_len):
        out.append(np.asarray(tok))
        logits, cache = decode(params, cache, tok, jnp.int32(pos))
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        pos += 1
    jax.block_until_ready(logits)
    dt = time.perf_counter() - t0
    toks = args.gen_len * args.batch
    print(f"generated {toks} tokens in {dt:.2f}s ({toks/dt:.1f} tok/s batched)")
    gen = np.concatenate(out, axis=1)
    print("sample token ids:", gen[0][:16])
    return {"tok_s": toks / dt, "tokens": gen}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    serve(ap.parse_args())


if __name__ == "__main__":
    main()
