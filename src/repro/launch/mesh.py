"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
importing this module touches no jax device state; the dry-run launcher
sets ``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any
jax import and then calls it.

Mesh layout (TPU v5e pods, 256 chips each):
  single-pod:  (data=16, model=16)
  multi-pod:   (pod=2, data=16, model=16)   — "pod" maps to the DCN axis;
               gradient AllReduce crosses it once per step, everything else
               stays intra-pod on ICI.
"""
from __future__ import annotations

import numpy as np

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_mesh(shape, axes) -> Mesh:
    """Build a mesh from the first prod(shape) devices (the forced-host
    device pool holds 512; the single-pod mesh uses 256 of them)."""
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {tuple(shape)}, have {len(devices)} — "
            "dryrun.py must set XLA_FLAGS=--xla_force_host_platform_device_count"
        )
    grid = np.asarray(devices[:n]).reshape(tuple(shape))
    return Mesh(grid, tuple(axes))


def make_local_mesh(n_data: int = 1, n_model: int = 1) -> Mesh:
    """Small mesh over (possibly forced-host) devices — tests/examples."""
    return make_mesh((n_data, n_model), ("data", "model"))
