"""Profile-driven unified autotuner: trace once, fit, replay offline.

The calibration story this module replaces is four *separate* ladders
(capacity slack, compact-wire hit cap, warm recalibration, and the
fanout sweep) that each probe the live generator — serial device runs,
re-paid per knob.  The autotuner pays for ONE short instrumented window
of the real pipelined loop and then searches the joint knob space
offline against a cost model fit from that window:

1. **Trace** (:func:`record_trace`): run ``--autotune-steps`` batches of
   the ``collect_stats=True`` generator and record, per step, the
   summed-over-workers ``FetchStats``/``CacheStats`` telemetry — L1 /
   local / shard / L3 hit counts, probe-round and host-gather bytes,
   demotions — plus per-step wall time.  The cache-tier conservation
   identities are the trace's internal consistency check
   (:meth:`Trace.violations`): tier hits must sum to total hits, the
   requester-side miss/stage counts must agree between the two stat
   blocks, and the measured wire bytes must equal the static formulas
   the compiled exchange actually shipped.

2. **Fit** (:meth:`CostModel.fit`): anchor a log-linear hit-rate curve
   (vs effective cache capacity ``rows x assoc-utilization``, per tier)
   at the traced point, over the PR-3 warm window (cold half excluded).
   The model is EXACT at the anchor by construction: evaluating the
   traced candidate reproduces the warm-window hit counts and the
   measured static wire bytes bit-for-bit, and predicts the traced mean
   step time exactly (the differential-test contract).

3. **Replay** (:func:`candidate_grid` + :func:`search`): evaluate every
   candidate ``(fanouts, cache_rows, l1_rows, assoc, hit_cap,
   capacity_slack)`` with :meth:`CostModel.predict` — static wire bytes
   from the same formulas ``fetch_rows`` uses (``probe_round_capacity``,
   ``probe_hit_cap``, ``hit_bitmap_words``: imported, never
   reimplemented), occupancy-scaled owner-exchange bytes, and a
   roofline-term ratio (:func:`repro.launch.roofline.roofline_terms`)
   transferring the traced wall time to the candidate.  No device work.

4. **Validate** (:func:`autotune_gcn`): the top-ranked candidates are
   re-jitted (``ModelConfig.with_candidate`` + :func:`candidate_cache_cfg`)
   and measured live for a few probes each; the first that drops no
   requests, demotes no hits, and lands within ``VALIDATOR_RATIO`` of
   ``max(predicted, traced)`` step time wins.  When every tried pick
   fails — or the trace is too short / inconsistent to fit — the
   caller falls back to the calibration ladders, which are thereby
   demoted from tuners to fallback validators.

Everything from the trace records down to the prediction is pure-python
ints/floats: identical trace + identical candidate => bit-identical
:class:`Prediction` (the replay-determinism contract; no wall clocks,
no RNG inside the model).
"""
from __future__ import annotations

import itertools
import math
import time
from typing import NamedTuple, Optional, Tuple

from ..core.config import VALID_CACHE_ASSOC, TuneCandidate
from .roofline import roofline_terms

#: live-measurement acceptance bound: the validator rejects the model's
#: pick when its measured warm step time exceeds this multiple of
#: max(predicted, traced) — wide enough for CPU-emulation jitter, tight
#: enough to catch a mis-fit model picking a config that thrashes
VALIDATOR_RATIO = 3.0

#: fewest trace steps the fit accepts: the PR-3 cold-half exclusion
#: leaves half the window, and one warm step has no averaging at all
MIN_TRACE_STEPS = 4

#: approximate conflict-miss utilization of an assoc-way cache relative
#: to fully-associative — the only empirically-shaped constant in the
#: model (direct-mapped caches waste capacity to conflict evictions)
ASSOC_UTILIZATION = {1: 0.66, 2: 0.85, 4: 1.0}

#: compact-wire hit-cap fractions the grid probes (mirrors the
#: calibration ladder ``repro.launch.train.HIT_CAP_LADDER`` — kept as a
#: literal here because train imports this module)
HIT_CAP_FRACTIONS = (0.125, 0.25, 0.5)

#: capacity-slack rungs the grid probes (subset of
#: ``repro.launch.train.SLACK_LADDER``; 0.25 is omitted — the model has
#: no drop term, so the live validator would pay for most 0.25 picks)
SLACK_RUNGS = (0.5, 1.0, 1.5, 2.0)


class TraceTooShort(ValueError):
    """The trace's warm window is too short to fit a model from."""


class TraceInconsistent(ValueError):
    """A trace record violated the cache-tier conservation identities."""


class TraceRecord(NamedTuple):
    """One instrumented step's telemetry, summed over workers.

    Every count is a python int (the sum of the per-worker
    ``FetchStats``/``CacheStats`` scalars for that step) except
    ``probe_hit_peak`` (the max over workers) and ``wall_time_s``.
    """
    n_requests: int         # request slots presented (incl. duplicates)
    n_unique: int           # ids routed to owners (device) / staged (host)
    n_dropped: int          # request slots zero-filled by capacity bounds
    probe_round_bytes: int  # measured shard-probe (+ host-admit) bytes
    host_gather_bytes: int  # measured L3 staging-round PCIe bytes
    n_hits: int             # distinct ids served by ANY cache tier
    n_misses: int           # distinct ids routed to the owner exchange
    n_l1_hits: int          # subset of hits served by the replicated L1
    n_local_hits: int       # subset served by THIS worker's main tier
    n_shard_hits: int       # subset served by a remote cache shard
    n_l3_hits: int          # distinct ids staged for the L3 host gather
    n_probe_demoted: int    # hits demoted to misses by the hit_cap bound
    probe_hit_peak: int     # max per-destination probe hits (over workers)
    wall_time_s: float      # wall time of the step, measured at the host

    def n_distinct(self) -> int:
        """Distinct ids the step resolved — the conservation total
        ``l1 + local + shard + l3 + misses`` every id routes through
        exactly once."""
        return (self.n_l1_hits + self.n_local_hits + self.n_shard_hits
                + self.n_l3_hits + self.n_misses)


class TracedConfig(NamedTuple):
    """The static facts of the configuration a trace was recorded under.

    Pure-python — everything the cost model needs to replay the wire
    formulas without touching jax: the generation shape
    (``fanouts``/``batch_per_worker``/``n_workers``), the feature row
    (``feat_dim`` x ``itemsize`` bytes), and the cache policy knobs.
    ``mode is None`` records an uncached trace.
    """
    fanouts: Tuple[int, ...]
    n_workers: int
    batch_per_worker: int
    feat_dim: int
    itemsize: int = 4
    mode: Optional[str] = None
    cache_rows: int = 0
    l1_rows: int = 0
    assoc: int = 1
    wire: str = "compact"
    hit_cap: int = 0
    capacity_slack: float = 2.0
    store: str = "device"

    def candidate(self) -> TuneCandidate:
        """The traced point expressed as a search candidate — the anchor
        every prediction is exact at."""
        return TuneCandidate(
            fanouts=tuple(self.fanouts), cache_rows=self.cache_rows,
            l1_rows=self.l1_rows, assoc=self.assoc, hit_cap=self.hit_cap,
            capacity_slack=self.capacity_slack)


def _requests_per_worker(fanouts: Tuple[int, ...],
                         batch_per_worker: int) -> int:
    """Feature-fetch request slots per worker per step: every padded
    node slot of the sampled trees (``b * slots_per_seed``)."""
    from ..graph.subgraph import slots_per_seed
    return batch_per_worker * slots_per_seed(tuple(fanouts))


def static_wire_bytes(tc: TracedConfig,
                      cand: TuneCandidate) -> Tuple[int, int, int]:
    """Per-worker static wire bytes of one step at candidate ``cand``.

    Returns ``(probe_bytes, gather_bytes, admit_bytes)`` — the byte
    sizes of the shard-probe round, the L3 host-staging round trip, and
    the deferred host-admission round, computed from the SAME sizing
    functions the compiled fetch uses (``probe_round_capacity``,
    ``probe_hit_cap``, ``hit_bitmap_words``), so the model's byte
    predictions equal the measured ``FetchStats`` values exactly.
    """
    from ..core.feature_cache import CacheConfig, hit_bitmap_words
    from ..core.generation import probe_hit_cap, probe_round_capacity

    w, d, item = tc.n_workers, tc.feat_dim, tc.itemsize
    r_pw = _requests_per_worker(cand.fanouts, tc.batch_per_worker)
    cached = tc.mode is not None and cand.cache_rows > 0
    host = tc.store == "host"
    probe = 0
    if cached and w > 1 and tc.mode != "replicated":
        cap = probe_round_capacity(r_pw, w, cand.capacity_slack)
        probe = w * cap * 4                                   # ids up
        if tc.wire == "compact":
            hc = probe_hit_cap(
                CacheConfig(n_rows=max(cand.cache_rows, 1),
                            hit_cap=cand.hit_cap), cap)
            probe += w * hit_bitmap_words(cap) * 4 + w * hc * d * item
        else:
            probe += w * cap * 1 + w * cap * d * item
    gather = admit = 0
    if host:
        s = max(int(probe_round_capacity(r_pw, 1, cand.capacity_slack)), 1)
        gather = s * (4 + d * item)
        if cached and w > 1 and tc.mode != "replicated":
            admit = w * s * (4 + d * item)
    return probe, gather, admit


class Trace(NamedTuple):
    """An instrumented window of the real loop: config + per-step records."""
    config: TracedConfig
    records: Tuple[TraceRecord, ...]

    def warm_records(self) -> Tuple[TraceRecord, ...]:
        """The warm half of the window — the PR-3 cold-half exclusion:
        the first ``max(n // 2, 1)`` steps carry the cold-start miss
        burst (and step 0 the jit compile), so only the second half
        feeds the fit.  Empty when the window has fewer than 2 steps."""
        n = len(self.records)
        return self.records[max(n // 2, 1):]

    def violations(self) -> Tuple[str, ...]:
        """Conservation-identity violations, one message per breach.

        Per record: counts non-negative and wall time positive/finite;
        tier hits sum to total hits; the requester-side
        ``FetchStats.n_unique`` equals the owner-routed misses (device
        store) or the L3-staged count (host store); the measured
        probe-round and host-gather bytes equal the static wire
        formulas (host admission may also ride the 1-slot
        ``empty_admit`` prologue buffer on early steps).  An empty
        tuple means the trace is internally consistent.
        """
        tc = self.config
        out = []
        probe, gather, admit = static_wire_bytes(tc, tc.candidate())
        w, d, item = tc.n_workers, tc.feat_dim, tc.itemsize
        admit0 = w * 1 * (4 + d * item) if admit else 0
        r_all = w * _requests_per_worker(tc.fanouts, tc.batch_per_worker)
        for t, r in enumerate(self.records):
            for f, v in zip(r._fields, r):
                if v < 0:
                    out.append(f"step {t}: {f} negative ({v})")
            if not (r.wall_time_s > 0.0 and math.isfinite(r.wall_time_s)):
                out.append(f"step {t}: wall_time_s not positive/finite "
                           f"({r.wall_time_s})")
            tiers = r.n_l1_hits + r.n_local_hits + r.n_shard_hits
            if r.n_hits != tiers:
                out.append(f"step {t}: tier hits {tiers} != n_hits "
                           f"{r.n_hits}")
            routed = r.n_l3_hits if tc.store == "host" else r.n_misses
            if r.n_unique != routed:
                out.append(f"step {t}: n_unique {r.n_unique} != "
                           f"routed/staged {routed}")
            if r.n_requests != r_all:
                out.append(f"step {t}: n_requests {r.n_requests} != "
                           f"{r_all} (= W * b * slots_per_seed)")
            if r.n_distinct() > r.n_requests:
                out.append(f"step {t}: distinct {r.n_distinct()} > "
                           f"requests {r.n_requests}")
            want = {w * (probe + admit), w * (probe + admit0)}
            if r.probe_round_bytes not in want:
                out.append(f"step {t}: probe_round_bytes "
                           f"{r.probe_round_bytes} not in {sorted(want)}")
            if r.host_gather_bytes != w * gather:
                out.append(f"step {t}: host_gather_bytes "
                           f"{r.host_gather_bytes} != {w * gather}")
        return tuple(out)

    def validate(self) -> None:
        """Raise :class:`TraceInconsistent` listing every conservation
        violation; return silently when the trace is consistent."""
        bad = self.violations()
        if bad:
            raise TraceInconsistent("; ".join(bad))


class Prediction(NamedTuple):
    """One offline replay of a candidate — scalars only, so two replays
    of the same (trace, candidate) compare bit-identically with ``==``.

    Counts are predicted WARM-WINDOW totals summed over workers (the
    same aggregation the trace records use); byte fields are the static
    per-worker sizes of one round (the values ``FetchStats`` measures).
    """
    candidate: TuneCandidate
    step_time_s: float      # predicted mean step wall time
    probe_round_bytes: int  # static per-worker shard-probe (+admit) bytes
    host_gather_bytes: int  # static per-worker L3 staging bytes
    n_distinct: float       # predicted distinct ids over the warm window
    n_hits: float           # predicted cache-tier hits (all tiers)
    n_l1_hits: float        # predicted replicated-L1 subset
    n_l3_hits: float        # predicted L3-staged ids (host store)
    n_misses: float         # predicted owner-routed misses
    wire_bytes: float       # per-worker per-step interconnect bytes
    cost_s: float           # summed roofline terms of one step


def _effective_capacity(tc: TracedConfig, rows: int, assoc: int) -> float:
    """Distinct-id capacity of the main cache tier at ``rows`` x
    ``assoc``: sharded/tiered modes pool all W shards; conflict-miss
    utilization scales by ``ASSOC_UTILIZATION``."""
    pooled = rows * (tc.n_workers if tc.mode in ("sharded", "tiered")
                     else 1)
    return pooled * ASSOC_UTILIZATION[assoc]


class CostModel(NamedTuple):
    """Warm-window anchor sums + the traced config: the fitted model.

    All fields are python ints/floats, so :meth:`predict` is a pure
    deterministic function — the replay-determinism contract.  The hit
    curve is count-space log-linear, anchored EXACTLY at the traced
    point: ``hits(c) = clip(H0 + B * (log2 eff(c) - log2 eff(c0)), 0,
    D)`` with ``B = H0 / log2 eff(c0)`` — the one-point fit that passes
    through both the anchor and the hits->0 limit of a vanishing cache.
    """
    traced: TracedConfig
    steps: int              # warm-window length (records)
    distinct_sum: int       # sum of n_distinct over the warm window
    hit_sum: int            # sum of n_hits
    l1_sum: int             # sum of n_l1_hits
    l3_sum: int             # sum of n_l3_hits
    miss_sum: int           # sum of n_misses
    wall_mean_s: float      # mean warm-window step wall time

    @classmethod
    def fit(cls, trace: Trace, strict: bool = True) -> "CostModel":
        """Fit the model from a trace's warm window.

        Raises :class:`TraceTooShort` when the window is shorter than
        ``MIN_TRACE_STEPS`` or its warm half is empty, and (unless
        ``strict=False``) :class:`TraceInconsistent` when the records
        breach the conservation identities — a corrupted trace must not
        silently become a confident model.
        """
        if strict:
            trace.validate()
        warm = trace.warm_records()
        if len(trace.records) < MIN_TRACE_STEPS or not warm:
            raise TraceTooShort(
                f"trace has {len(trace.records)} steps "
                f"({len(warm)} warm); need >= {MIN_TRACE_STEPS}")
        return cls(
            traced=trace.config,
            steps=len(warm),
            distinct_sum=sum(r.n_distinct() for r in warm),
            hit_sum=sum(r.n_hits for r in warm),
            l1_sum=sum(r.n_l1_hits for r in warm),
            l3_sum=sum(r.n_l3_hits for r in warm),
            miss_sum=sum(r.n_misses for r in warm),
            wall_mean_s=sum(r.wall_time_s for r in warm) / len(warm),
        )

    def _counts(self, cand: TuneCandidate):
        """Predicted warm-window (distinct, hits, l1, l3, misses)."""
        tc = self.traced
        work0 = _requests_per_worker(tc.fanouts, tc.batch_per_worker)
        work = _requests_per_worker(cand.fanouts, tc.batch_per_worker)
        distinct = self.distinct_sum * (work / work0)
        cached = tc.mode is not None and cand.cache_rows > 0
        if not cached:
            hits = 0.0
        else:
            e0 = _effective_capacity(tc, tc.cache_rows, tc.assoc)
            e = _effective_capacity(tc, cand.cache_rows, cand.assoc)
            if e <= 0.0 or e0 <= 0.0:
                hits = 0.0
            else:
                slope = self.hit_sum / math.log2(max(e0, 2.0))
                hits = self.hit_sum + slope * (math.log2(e)
                                               - math.log2(e0))
                hits = min(max(hits, 0.0), distinct)
        if tc.mode == "tiered" and cand.l1_rows > 0 and hits > 0.0:
            l1_0 = max(tc.l1_rows, 1)
            slope1 = self.l1_sum / math.log2(max(l1_0, 2.0))
            l1 = self.l1_sum + slope1 * (math.log2(max(cand.l1_rows, 1))
                                         - math.log2(l1_0))
            l1 = min(max(l1, 0.0), hits)
        else:
            l1 = 0.0
        rest = distinct - hits
        if tc.store == "host":
            l3, misses = rest, 0.0
        else:
            l3, misses = 0.0, rest
        return distinct, hits, l1, l3, misses

    def _cost(self, cand: TuneCandidate, misses: float) -> Tuple[float,
                                                                 float]:
        """Summed per-step roofline terms and the wire-bytes component."""
        tc = self.traced
        probe, gather, admit = static_wire_bytes(tc, cand)
        d, item, w = tc.feat_dim, tc.itemsize, tc.n_workers
        # owner-exchange occupancy: each routed distinct id ships its id
        # up and its feature row back (per worker per step)
        miss_pw = misses / (self.steps * w)
        wire = probe + admit + miss_pw * (4 + d * item)
        # HBM traffic: every padded node slot's feature row moves ~3x
        # (gather, mask-multiply, layer input) — the constant cancels in
        # the anchored ratio and only shapes cross-fanout comparisons
        hbm = 3.0 * _requests_per_worker(cand.fanouts,
                                         tc.batch_per_worker) * d * item
        terms = roofline_terms(0.0, hbm, wire, gather)
        return sum(terms.values()), wire

    def predict(self, cand: TuneCandidate) -> Prediction:
        """Replay one candidate offline: counts from the anchored hit
        curve, bytes from the static wire formulas, step time from the
        roofline-term ratio against the traced point.  Evaluating the
        traced candidate returns the trace's own warm-window sums and
        measured wall time exactly."""
        cand = TuneCandidate(tuple(cand.fanouts), int(cand.cache_rows),
                             int(cand.l1_rows), int(cand.assoc),
                             int(cand.hit_cap),
                             float(cand.capacity_slack))
        distinct, hits, l1, l3, misses = self._counts(cand)
        cost, wire = self._cost(cand, misses)
        cost0, _ = self._cost(self.traced.candidate(),
                              self._counts(self.traced.candidate())[4])
        probe, gather, _ = static_wire_bytes(self.traced, cand)
        return Prediction(
            candidate=cand,
            step_time_s=self.wall_mean_s * (cost / cost0),
            probe_round_bytes=probe,
            host_gather_bytes=gather,
            n_distinct=distinct, n_hits=hits, n_l1_hits=l1,
            n_l3_hits=l3, n_misses=misses,
            wire_bytes=wire, cost_s=cost)


def candidate_cache_cfg(base, cand: TuneCandidate):
    """The candidate applied to a ``CacheConfig`` — the cache half of
    the re-jit seam (``ModelConfig.with_candidate`` is the model half).
    Keeps the traced policy fields (mode, admit, wire, store) and swaps
    the sizing knobs the search explored."""
    return base._replace(n_rows=cand.cache_rows, l1_rows=cand.l1_rows,
                         assoc=cand.assoc, hit_cap=cand.hit_cap)


def observed_floors(trace: Trace) -> dict:
    """Demotion-safety floors the trace's own evidence implies.

    The cost model has no demotion term — demotions are per-destination
    SKEW events, not averages — so the grid must not offer compact-wire
    hit caps the traced workload already exceeded.  ``hit_peak`` is the
    largest per-destination probe-hit count any holder observed: a
    ``hit_cap`` below it would have demoted hits on this very trace
    (and :func:`candidate_grid` scales it up for candidates with MORE
    effective cache capacity than the traced point, whose hit peaks
    will grow with the hit count).  Drops get no floor on purpose:
    request drops depend on per-destination occupancy at capacities the
    trace never ran, which no offline margin can honestly bound — the
    live validator in :func:`autotune_gcn` is the drop check, exactly
    the evidence the calibration ladders use.
    """
    return {
        "hit_peak": max((r.probe_hit_peak for r in trace.records),
                        default=0),
    }


def candidate_grid(tc: TracedConfig, base_cache_cfg=None, floors=None):
    """The joint search space around a traced point.

    Fanout variants preserve the sampled tree exactly up to hop order
    (permutations of the traced tuple — same receptive field, different
    slot counts); cache rows sweep two power-of-two octaves either way;
    assoc spans ``VALID_CACHE_ASSOC``; L1 rows sweep an octave (tiered
    mode only); hit caps take the ladder fractions of each candidate's
    probe capacity (plus the never-demoting full-capacity cap); slack
    takes ``SLACK_RUNGS`` plus the traced value.  Candidates whose
    ``CacheConfig`` would not validate are filtered (``base_cache_cfg``
    supplies the policy fields; omit it for an uncached trace).  With
    ``floors`` (:func:`observed_floors`), hit caps below the traced
    per-destination hit peak — scaled by the candidate's effective-
    capacity growth over the traced point, since hit peaks grow with
    the hit count — are filtered: the trace's own evidence says they
    would demote.  Deterministically ordered and deduplicated.
    """
    from ..core.generation import probe_round_capacity

    fanout_opts = sorted(set(itertools.permutations(tc.fanouts)))[:6]
    cached = tc.mode is not None and tc.cache_rows > 0
    if cached:
        r0 = tc.cache_rows
        row_opts = sorted({max(r0 >> 2, 1), max(r0 >> 1, 1), r0,
                           r0 << 1, r0 << 2})
        assoc_opts = tuple(VALID_CACHE_ASSOC)
        if tc.mode == "tiered":
            l0 = max(tc.l1_rows, 1)
            l1_opts = sorted({max(l0 >> 1, 1), l0, l0 << 1})
        else:
            l1_opts = [tc.l1_rows]
    else:
        row_opts, assoc_opts, l1_opts = [tc.cache_rows], [tc.assoc], [0]
    slack_opts = sorted(set(SLACK_RUNGS) | {tc.capacity_slack})
    probe_wire = (cached and tc.n_workers > 1 and tc.mode != "replicated"
                  and tc.wire == "compact")
    out = []
    seen = set()
    for fo, rows, assoc, l1, slack in itertools.product(
            fanout_opts, row_opts, assoc_opts, l1_opts, slack_opts):
        cap = probe_round_capacity(
            _requests_per_worker(fo, tc.batch_per_worker),
            tc.n_workers, slack)
        if probe_wire:
            hc_opts = sorted({0, cap} | {max(int(cap * f), 1)
                                         for f in HIT_CAP_FRACTIONS})
            if floors is not None:
                # scale the traced demotion floor with the candidate's
                # capacity growth (clamped to cap: a full-capacity
                # payload can never demote, so it always survives)
                e0 = max(_effective_capacity(tc, tc.cache_rows, tc.assoc),
                         1.0)
                e = _effective_capacity(tc, rows, assoc)
                hp = min(int(math.ceil(floors["hit_peak"]
                                       * max(e / e0, 1.0))), cap)
                hc_opts = [h for h in hc_opts
                           if min(cap // 2 if h == 0 else h, cap) >= hp]
        else:
            hc_opts = [tc.hit_cap]
        for hc in hc_opts:
            cand = TuneCandidate(fo, rows, l1, assoc, hc, slack)
            if cand in seen:
                continue
            seen.add(cand)
            if cached and base_cache_cfg is not None:
                try:
                    candidate_cache_cfg(base_cache_cfg, cand).validated()
                except ValueError:
                    continue
            out.append(cand)
    return out


def search(model: CostModel, grid=None):
    """Replay the grid offline and rank it: returns ``(best, ranked)``
    where ``ranked`` is every prediction sorted by predicted step time
    (candidate tuple as the deterministic tie-break)."""
    if grid is None:
        grid = candidate_grid(model.traced)
    ranked = sorted((model.predict(c) for c in grid),
                    key=lambda p: (p.step_time_s, p.candidate))
    if not ranked:
        raise ValueError("empty candidate grid — nothing to search")
    return ranked[0], ranked


def _sum_stats(stats) -> dict:
    """Host-side reduction of one step's stacked ``(FetchStats,
    CacheStats)`` pytree: sum every per-worker counter (max for the
    probe-hit peak) into python ints."""
    import numpy as np
    fs, cs = stats
    out = {f: int(np.asarray(v).sum()) for f, v in zip(fs._fields, fs)}
    for f, v in zip(cs._fields, cs):
        out[f] = (int(np.asarray(v).max()) if f == "probe_hit_peak"
                  else int(np.asarray(v).sum()))
    return out


def record_trace(gen_fn, device_args, probes, traced: TracedConfig, *,
                 cache=None, store=None) -> Trace:
    """Run the instrumented window and build the :class:`Trace`.

    ``gen_fn`` must be the ``collect_stats=True`` generator for the
    configuration ``traced`` describes; ``probes`` is a list of
    ``(seeds, rng)`` batches (the same shape the calibration ladders
    use).  Host-store traces drive the real split dispatch — issue the
    L3 gather, land it, admit the landed rows next step — so
    ``host_gather_bytes`` enters the records.  A step whose telemetry
    already breaches a conservation identity ends the window early
    (the truncated trace then fails :meth:`CostModel.fit` loudly
    instead of anchoring a model on garbage); every issued gather is
    drained before returning, early exit included.
    """
    import jax

    host = traced.store == "host"
    if host and store is None:
        raise ValueError('record_trace on a store="host" trace needs the '
                         'HostFeatureStore to drive the gather pipeline')
    records = []
    pending = None
    prev_req = None
    for seeds, rng in probes:
        t0 = time.perf_counter()
        if host and cache is not None:
            if pending is None:
                from ..core.host_store import empty_admit
                adm_ids, adm_rows = empty_admit(traced.n_workers,
                                                traced.feat_dim)
            else:
                adm_ids, adm_rows = prev_req.ids, pending.rows()
            batch, cache, req, stats = gen_fn(device_args, seeds, rng,
                                              cache, adm_ids, adm_rows)
            pending = store.issue(req.ids)
            prev_req = req
        elif host:
            batch, req, stats = gen_fn(device_args, seeds, rng)
            if pending is not None:
                pending.rows()          # land the previous round first
            pending = store.issue(req.ids)
        elif cache is not None:
            batch, cache, stats = gen_fn(device_args, seeds, rng, cache)
        else:
            batch, stats = gen_fn(device_args, seeds, rng)
        jax.block_until_ready(stats)
        wall = time.perf_counter() - t0
        s = _sum_stats(stats)
        rec = TraceRecord(
            n_requests=s["n_requests"], n_unique=s["n_unique"],
            n_dropped=s["n_dropped"],
            probe_round_bytes=s["probe_round_bytes"],
            host_gather_bytes=s["host_gather_bytes"],
            n_hits=s["n_hits"], n_misses=s["n_misses"],
            n_l1_hits=s["n_l1_hits"], n_local_hits=s["n_local_hits"],
            n_shard_hits=s["n_shard_hits"], n_l3_hits=s["n_l3_hits"],
            n_probe_demoted=s["n_probe_demoted"],
            probe_hit_peak=s["probe_hit_peak"], wall_time_s=wall)
        records.append(rec)
        if rec.n_hits != (rec.n_l1_hits + rec.n_local_hits
                          + rec.n_shard_hits):
            break                       # early exit: telemetry is broken
    if pending is not None:
        pending.rows()                  # drain the in-flight L3 gather
    return Trace(config=traced, records=tuple(records))


class AutotuneResult(NamedTuple):
    """What :func:`autotune_gcn` hands the launcher.

    ``accepted=False`` means the caller must fall back to the
    calibration ladders (``reason`` says why: short/inconsistent trace,
    or the live validator rejected the pick)."""
    accepted: bool
    reason: str
    candidate: Optional[TuneCandidate] = None
    prediction: Optional[Prediction] = None
    trace: Optional[Trace] = None
    measured_step_s: float = 0.0


def _traced_config(fanouts, w, b, feat_dim, cache_cfg, slack,
                   feature_store) -> TracedConfig:
    """Build the :class:`TracedConfig` for a launcher configuration."""
    cached = cache_cfg is not None and cache_cfg.n_rows > 0
    return TracedConfig(
        fanouts=tuple(fanouts), n_workers=w, batch_per_worker=b,
        feat_dim=feat_dim, itemsize=4,
        mode=cache_cfg.mode if cached else None,
        cache_rows=cache_cfg.n_rows if cached else 0,
        l1_rows=cache_cfg.l1_rows if cached else 0,
        assoc=cache_cfg.assoc if cached else 1,
        wire=cache_cfg.wire if cached else "compact",
        hit_cap=cache_cfg.hit_cap if cached else 0,
        capacity_slack=float(slack), store=feature_store)


def _instrumented_run(mesh, part, feats, labels, tc: TracedConfig,
                      cache_cfg, probes) -> Trace:
    """Place the data, build the ``collect_stats`` generator for ``tc``,
    and record one trace window over ``probes`` (cold cache)."""
    from ..core.generation import make_distributed_generator

    cached = tc.mode is not None and tc.cache_rows > 0
    out = make_distributed_generator(
        mesh, part, feats, labels, fanouts=tc.fanouts,
        capacity_slack=tc.capacity_slack,
        cache_cfg=cache_cfg if cached else None,
        feature_store=tc.store, collect_stats=True)
    store = cache = None
    if tc.store == "host" and cached:
        gen_fn, device_args, store, cache = out
    elif tc.store == "host":
        gen_fn, device_args, store = out
    elif cached:
        gen_fn, device_args, cache = out
    else:
        gen_fn, device_args = out
    return record_trace(gen_fn, device_args, probes, tc,
                        cache=cache, store=store)


def autotune_gcn(mesh, part, feats, labels, *, fanouts, cache_cfg,
                 feature_store, batch_per_worker, seeds_for, rngs,
                 steps: int = 8, slack: float = 2.0,
                 validator_ratio: float = VALIDATOR_RATIO,
                 validator_probes: int = 3,
                 validator_picks: int = 3) -> AutotuneResult:
    """The full trace -> fit -> search -> validate pass for the GCN run.

    Records a ``steps``-long instrumented window at the configured
    point, fits :class:`CostModel`, searches :func:`candidate_grid`,
    then walks the ranking: up to ``validator_picks`` of the best
    predicted candidates are re-jitted and measured live for
    ``validator_probes`` batches each, and the FIRST one whose live run
    drops no requests, demotes no hits, and lands within
    ``validator_ratio`` of ``max(predicted, traced)`` step time is
    accepted.  The model deliberately has no drop term (drops are
    per-destination skew events at capacities the trace never ran), so
    the validator is where aggressive capacity picks earn their keep —
    the same drop evidence the calibration ladders use, paid for a few
    ranked picks instead of every ladder rung.  When every tried pick
    fails — or the trace is too short / inconsistent to fit — the
    result says to fall back to the calibration ladders.
    """
    w = mesh.shape["data"]
    feat_dim = int(feats.shape[1])
    tc = _traced_config(fanouts, w, batch_per_worker, feat_dim,
                        cache_cfg, slack, feature_store)
    probes = [(seeds_for(t), rngs[t]) for t in range(steps)]
    trace = _instrumented_run(mesh, part, feats, labels, tc, cache_cfg,
                              probes)
    try:
        model = CostModel.fit(trace)
    except (TraceTooShort, TraceInconsistent) as e:
        return AutotuneResult(False, f"{type(e).__name__}: {e}",
                              trace=trace)
    grid = candidate_grid(tc, cache_cfg, floors=observed_floors(trace))
    if not grid:
        return AutotuneResult(False, "empty candidate grid after the "
                                     "demotion-floor and validity filters",
                              trace=trace)
    best, ranked = search(model, grid)
    print(f"autotune: searched {len(ranked)} candidates offline; best "
          f"predicted {best.step_time_s * 1e3:.1f} ms/step vs traced "
          f"{model.wall_mean_s * 1e3:.1f}")
    # --- live validation: the ladders' acceptance rules, walked down
    # the ranking until a pick earns them --------------------------------
    vprobes = [(seeds_for(t), rngs[t]) for t in range(validator_probes)]
    last_reason = "empty ranking"
    for pred in ranked[:max(validator_picks, 1)]:
        cand = pred.candidate
        print(f"autotune: validating fanouts={cand.fanouts} "
              f"rows={cand.cache_rows} l1={cand.l1_rows} "
              f"assoc={cand.assoc} hit_cap={cand.hit_cap} "
              f"slack={cand.capacity_slack} "
              f"(predicted {pred.step_time_s * 1e3:.1f} ms/step)")
        cand_tc = tc._replace(
            fanouts=cand.fanouts, cache_rows=cand.cache_rows,
            l1_rows=cand.l1_rows, assoc=cand.assoc, hit_cap=cand.hit_cap,
            capacity_slack=cand.capacity_slack)
        cand_cfg = (candidate_cache_cfg(cache_cfg, cand)
                    if cand_tc.mode is not None else cache_cfg)
        vtrace = _instrumented_run(mesh, part, feats, labels, cand_tc,
                                   cand_cfg, vprobes)
        vwarm = vtrace.warm_records() or vtrace.records
        dropped = sum(r.n_dropped for r in vtrace.records)
        demoted = sum(r.n_probe_demoted for r in vtrace.records)
        measured = sum(r.wall_time_s for r in vwarm) / len(vwarm)
        bound = validator_ratio * max(pred.step_time_s, model.wall_mean_s)
        if not dropped and not demoted and measured <= bound:
            return AutotuneResult(True, "accepted", candidate=cand,
                                  prediction=pred, trace=trace,
                                  measured_step_s=measured)
        last_reason = (
            f"dropped={dropped} demoted={demoted} "
            f"measured={measured * 1e3:.1f} ms > bound "
            f"{bound * 1e3:.1f} ms" if measured > bound else
            f"dropped={dropped} demoted={demoted}")
        print(f"autotune: validator rejected the pick ({last_reason})")
    return AutotuneResult(
        False,
        f"validator rejected {min(max(validator_picks, 1), len(ranked))} "
        f"ranked pick(s); last: {last_reason}",
        candidate=best.candidate, prediction=best, trace=trace,
        measured_step_s=measured)
