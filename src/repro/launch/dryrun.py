"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell and
extract the roofline terms from the compiled artifact.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-135m \
        --shape train_4k [--multi-pod] [--attn chunked] [--remat full] \
        [--variant name] [--out results.jsonl]

This proves the distribution config is coherent without hardware: the
sharded program must partition (no sharding mismatches), compile (no
unsupported collectives), and fit (memory_analysis).
"""
# The VERY FIRST lines, before ANY other import (jax locks the device count
# on first init):
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse     # noqa: E402
import dataclasses  # noqa: E402
import json         # noqa: E402
import sys          # noqa: E402
import time         # noqa: E402

import jax          # noqa: E402
import jax.numpy as jnp  # noqa: E402

from ..configs import REGISTRY, SUBQUADRATIC, get_config   # noqa: E402
from ..core.config import SHAPES, TrainConfig              # noqa: E402
from ..models import layers as L                           # noqa: E402
from ..models import zoo                                   # noqa: E402
from ..train.train_loop import init_state, make_train_step # noqa: E402
from .hlo_analysis import collective_bytes, trip_weighted_cost, xla_cost  # noqa: E402
from .mesh import make_production_mesh                     # noqa: E402


def _artifact_stats(compiled, chips: int, t_lower: float, t_compile: float) -> dict:
    cost = xla_cost(compiled)
    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    out = dict(
        chips=chips,
        flops_per_device=trip_weighted_cost(hlo)["flops"],
        bytes_per_device=trip_weighted_cost(hlo)["bytes"],
        xla_cost_flops=float(cost.get("flops", 0.0)),
        xla_cost_bytes=float(cost.get("bytes accessed", 0.0)),
        collective_bytes_per_device=collective_bytes(hlo),
        t_lower_s=round(t_lower, 1),
        t_compile_s=round(t_compile, 1),
    )
    try:
        out["memory"] = {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "generated_code_bytes": mem.generated_code_size_in_bytes,
        }
    except Exception:
        out["memory"] = str(mem)
    return out


def lower_gcn_cell(rec: dict, arch: str, multi_pod: bool,
                   merge_mode: str = "butterfly",
                   cache_rows: int = None, cache_mode: str = None,
                   l1_rows: int = None, probe_wire: str = None,
                   feature_store: str = None,
                   collect_stats: bool = False) -> dict:
    """The paper's own workload at production scale: one synchronized
    generation+training step on a 530M-node / 5B-edge graph (the paper's
    evaluation graph).  The sampling depth comes from the arch config —
    2-hop (40, 20) for the paper cell, 1-hop for graphgen-sage, 3-hop for
    graphgen-gcn-deep (~1.7M padded nodes per iteration at (40, 20)).
    Generation shards over 'data' (the worker axis); the small GCN
    replicates over 'model'.  When the config enables the hot-node feature
    cache, its per-worker state rides in the pipelined carry —
    ``(params, opt, batch, cache)`` — and must partition/compile too.

    With ``feature_store="host"`` the cell lowers the L3 path: the
    feature table never appears among the device args (it lives in host
    RAM), the carry grows the in-flight ``HostMissRequest``, and the
    step takes the landed ``[W, S, D]`` gather buffer — proving the
    issue/collect split partitions and compiles at production scale
    WITHOUT materializing a 530M-row device table spec.

    With ``collect_stats=True`` the cell lowers the autotuner's trace
    recorder instead: the instrumented generator alone (the recorder
    times it outside the train step), whose output grows the stacked
    per-worker ``(FetchStats, CacheStats)`` tail — proving the
    telemetry seam partitions and compiles at production scale too."""
    from ..core.feature_cache import CacheConfig, cache_state_specs
    from ..core.generation import make_generator_fn, probe_round_capacity
    from ..core.host_store import HostMissRequest
    from ..core.pipeline import make_host_consume_step, make_pipelined_step
    from ..graph.subgraph import batch_specs, slots_per_seed
    from ..models import gcn as gcn_mod
    from ..train.optimizer import adam_update, init_adam

    mesh = make_production_mesh(multi_pod=multi_pod)
    axis = "data"
    w = mesh.shape[axis]
    cfg = dataclasses.replace(get_config(arch), gcn_in_dim=128,
                              gcn_hidden=256, n_classes=64)
    if cache_rows is not None:
        cfg = dataclasses.replace(cfg, cache_rows=cache_rows)
    if cache_mode is not None:
        cfg = dataclasses.replace(cfg, cache_mode=cache_mode)
    if l1_rows is not None:
        cfg = dataclasses.replace(cfg, cache_l1_rows=l1_rows)
    if probe_wire is not None:
        cfg = dataclasses.replace(cfg, cache_wire=probe_wire)
    if feature_store is not None:
        cfg = dataclasses.replace(cfg, feature_store=feature_store)
    host = cfg.feature_store == "host"
    cache_cfg = CacheConfig.from_model(cfg)
    cached = cache_cfg is not None
    fanouts = cfg.fanouts
    n_nodes = 530_000_000
    n_edges = 5_000_000_000
    b = 128                                  # seeds per worker
    rows = -(-n_nodes // w)
    e_pad = -(-n_edges // w)
    s = jax.ShapeDtypeStruct
    i32, f32 = jnp.int32, jnp.float32
    seeds = s((w, b), i32)
    rng = jax.eval_shape(lambda: jax.random.PRNGKey(0))
    slack = cfg.capacity_slack if cfg.capacity_slack is not None else 2.0
    gen_fn = make_generator_fn(mesh, fanouts=fanouts, axis_name=axis,
                               merge_mode=merge_mode,
                               capacity_slack=slack,
                               cache_cfg=cache_cfg,
                               feature_store=cfg.feature_store,
                               feat_dim=cfg.gcn_in_dim if host else None,
                               collect_stats=collect_stats)
    tcfg = TrainConfig()

    def train_fn(params, opt, batch):
        loss, grads = jax.value_and_grad(gcn_mod.gcn_loss)(params, batch)
        params, opt, _ = adam_update(tcfg, params, grads, opt)
        return params, opt, loss

    params = jax.eval_shape(lambda: gcn_mod.init_gcn(cfg, jax.random.PRNGKey(0)))
    opt = jax.eval_shape(lambda: init_adam(params))
    batch0 = batch_specs(w * b, fanouts, cfg.gcn_in_dim, n_workers=w)
    if collect_stats:
        # trace-recorder cell: the instrumented generator alone (the
        # recorder drives it outside the train step, see
        # repro.launch.autotune.record_trace)
        if host:
            device_args = (s((w, n_nodes + 1), i32), s((w, e_pad), i32),
                           s((w * rows, 1), f32))
        else:
            device_args = (s((w, n_nodes + 1), i32), s((w, e_pad), i32),
                           s((w * rows, cfg.gcn_in_dim), f32),
                           s((w * rows, 1), f32))
        gen_args = [device_args, seeds, rng]
        if cached:
            gen_args.append(cache_state_specs(cache_cfg, cfg.gcn_in_dim,
                                              n_workers=w))
        if host and cached:
            r = b * slots_per_seed(fanouts)
            stage = max(int(probe_round_capacity(r, 1, slack)), 1)
            gen_args += [s((w, stage), i32),
                         s((w, stage, cfg.gcn_in_dim), f32)]
        t0 = time.time()
        lowered = jax.jit(gen_fn).lower(*gen_args)
        t_lower = time.time() - t0
    elif host:
        # the runtime loop dispatches gen and patch+train as SEPARATE
        # programs (the gather must ride between them — see
        # pipeline.pipelined_loop); for the cost view, lower one
        # iteration's worth of device work as a single composite
        consume = make_host_consume_step(train_fn)

        if cached:
            def step(carry, device_args, seeds, rng, landed):
                params, opt, batch, req, cache = carry
                nb, cache, nreq = gen_fn(device_args, seeds, rng, cache,
                                         req.ids, landed)
                params, opt, loss = consume(params, opt, batch, req, landed)
                return (params, opt, nb, nreq, cache), loss
        else:
            def step(carry, device_args, seeds, rng, landed):
                params, opt, batch, req = carry
                nb, nreq = gen_fn(device_args, seeds, rng)
                params, opt, loss = consume(params, opt, batch, req, landed)
                return (params, opt, nb, nreq), loss
        # no device feature table; per-worker staging size from the SAME
        # formula the compiled fetch uses (_host_fetch)
        device_args = (
            s((w, n_nodes + 1), i32),
            s((w, e_pad), i32),
            s((w * rows, 1), f32),
        )
        r = b * slots_per_seed(fanouts)
        stage = max(int(probe_round_capacity(r, 1, slack)), 1)
        req0 = HostMissRequest(ids=s((w, stage), i32),
                               slot=s((w, r), i32),
                               patch=s((w, r), jnp.bool_))
        landed = s((w, stage, cfg.gcn_in_dim), f32)
        if cached:
            cache0 = cache_state_specs(cache_cfg, cfg.gcn_in_dim,
                                       n_workers=w)
            carry0 = (params, opt, batch0, req0, cache0)
        else:
            carry0 = (params, opt, batch0, req0)
        t0 = time.time()
        lowered = jax.jit(step).lower(carry0, device_args, seeds, rng,
                                      landed)
        t_lower = time.time() - t0
    else:
        step = make_pipelined_step(gen_fn, train_fn, cached=cached)
        device_args = (
            s((w, n_nodes + 1), i32),
            s((w, e_pad), i32),
            s((w * rows, cfg.gcn_in_dim), f32),
            s((w * rows, 1), f32),
        )
        if cached:
            cache0 = cache_state_specs(cache_cfg, cfg.gcn_in_dim,
                                       n_workers=w)
            carry0 = (params, opt, batch0, cache0)
        else:
            carry0 = (params, opt, batch0)
        t0 = time.time()
        lowered = jax.jit(step).lower(carry0, device_args, seeds, rng)
        t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    rec.update(_artifact_stats(compiled, mesh.size, t_lower, time.time() - t0))
    rec.update(
        status="ok",
        params=cfg.param_count(),
        active_params=cfg.param_count(),
        cache_rows=cfg.cache_rows,
        cache_mode=cfg.cache_mode if cached else None,
        cache_l1_rows=cache_cfg.l1_rows if cached else 0,
        feature_store=cfg.feature_store,
        collect_stats=collect_stats,
        tokens=w * b * slots_per_seed(fanouts),   # padded node slots per iter
    )
    return rec


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               attn: str = "naive", remat: str = "keep",
               variant: str = "baseline", shard_heads: bool = False,
               gen_merge: str = "butterfly", moe_impl: str = "gather",
               seq_parallel: bool = False, compress: bool = False,
               cache_rows: int = None, cache_mode: str = None,
               l1_rows: int = None, probe_wire: str = None,
               feature_store: str = None,
               collect_stats: bool = False) -> dict:
    cfg = get_config(arch)
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "variant": variant,
    }
    if cfg.family == "gcn":
        rec["kind"] = "train"
        return lower_gcn_cell(rec, arch, multi_pod, merge_mode=gen_merge,
                              cache_rows=cache_rows, cache_mode=cache_mode,
                              l1_rows=l1_rows, probe_wire=probe_wire,
                              feature_store=feature_store,
                              collect_stats=collect_stats)
    shape = SHAPES[shape_name]
    rec["kind"] = shape.kind
    if shape_name == "long_500k" and arch not in SUBQUADRATIC:
        rec["status"] = "skipped"
        rec["reason"] = ("quadratic-attention arch; long_500k runs on "
                         "SSM/hybrid only (DESIGN.md §4)")
        return rec
    if attn != "naive":
        L.set_attn_impl(attn)
    if shard_heads:
        L.set_shard_heads(True)
    if seq_parallel:
        L.set_seq_parallel(True)
    if moe_impl != "gather":
        from ..models import moe as moe_mod
        moe_mod.set_moe_impl(moe_impl)
    if remat != "keep":
        cfg = dataclasses.replace(cfg, remat=remat)

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    L.set_mesh(mesh)
    api = zoo.build(cfg)
    t0 = time.time()

    if shape.kind == "train":
        tcfg = TrainConfig(compress_grads=compress)
        params_shape = jax.eval_shape(api.init, jax.random.key(0))
        state_shape = jax.eval_shape(lambda p: init_state(p, tcfg), params_shape)
        pspecs = zoo.param_pspecs(cfg, params_shape, mesh)
        state_specs = type(state_shape)(
            params=pspecs,
            opt=type(state_shape.opt)(
                step=jax.sharding.PartitionSpec(), m=pspecs, v=pspecs
            ),
            error=pspecs if compress else None,
        )
        batch_shape = zoo.input_specs(cfg, shape)
        batch_specs = zoo.batch_pspecs(cfg, batch_shape, mesh)
        step = make_train_step(api.loss, tcfg, mesh)
        jitted = jax.jit(
            step,
            in_shardings=(zoo.to_shardings(mesh, state_specs),
                          zoo.to_shardings(mesh, batch_specs)),
            out_shardings=(zoo.to_shardings(mesh, state_specs), None),
            donate_argnums=(0,),
        )
        lowered = jitted.lower(state_shape, batch_shape)
    elif shape.kind == "prefill":
        params_shape = jax.eval_shape(api.init, jax.random.key(0))
        pspecs = zoo.param_pspecs(cfg, params_shape, mesh)
        batch_shape = zoo.prefill_specs(cfg, shape)
        batch_specs = zoo.batch_pspecs(cfg, batch_shape, mesh)
        fwd = lambda p, b: zoo.forward_logits(cfg, p, b)
        jitted = jax.jit(
            fwd,
            in_shardings=(zoo.to_shardings(mesh, pspecs),
                          zoo.to_shardings(mesh, batch_specs)),
        )
        lowered = jitted.lower(params_shape, batch_shape)
    else:  # decode
        params_shape = jax.eval_shape(api.init, jax.random.key(0))
        pspecs = zoo.param_pspecs(cfg, params_shape, mesh)
        cache_shape = jax.eval_shape(
            lambda: api.init_cache(shape.global_batch, shape.seq_len)
        )
        cache_specs = zoo.cache_pspecs(cfg, cache_shape, mesh)
        batch_shape = zoo.input_specs(cfg, shape)
        batch_specs = zoo.batch_pspecs(cfg, batch_shape, mesh)
        pos_shape = jax.ShapeDtypeStruct((), jnp.int32)

        def serve_step(params, cache, batch, pos):
            return api.decode(params, cache, batch["tokens"], pos)

        jitted = jax.jit(
            serve_step,
            in_shardings=(
                zoo.to_shardings(mesh, pspecs),
                zoo.to_shardings(mesh, cache_specs),
                zoo.to_shardings(mesh, batch_specs),
                None,
            ),
            out_shardings=(None, zoo.to_shardings(mesh, cache_specs)),
            donate_argnums=(1,),
        )
        lowered = jitted.lower(params_shape, cache_shape, batch_shape, pos_shape)

    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    rec.update(_artifact_stats(compiled, chips, t_lower, time.time() - t0))
    rec.update(
        status="ok",
        params=cfg.param_count(),
        active_params=cfg.active_param_count(),
        tokens=shape.global_batch
        * (shape.seq_len if shape.kind in ("train", "prefill") else 1),
    )
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(REGISTRY))
    ap.add_argument("--shape", required=True, choices=sorted(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--attn", default="naive", choices=["naive", "chunked"])
    ap.add_argument("--remat", default="keep",
                    choices=["keep", "none", "full", "dots"])
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--shard-heads", action="store_true")
    ap.add_argument("--gen-merge", default="butterfly",
                    choices=["butterfly", "reduce_scatter"])
    ap.add_argument("--moe", default="gather", choices=["gather", "ep_a2a"])
    ap.add_argument("--seq-parallel", action="store_true")
    ap.add_argument("--compress", action="store_true",
                    help="int8 error-feedback gradient compression")
    ap.add_argument("--cache-rows", type=int, default=None,
                    help="GCN cells: hot-node feature cache rows/worker "
                         "(0 disables; default from the arch config)")
    ap.add_argument("--cache-mode", default=None,
                    choices=["replicated", "sharded", "tiered"],
                    help="GCN cells: cache placement override")
    ap.add_argument("--l1-rows", type=int, default=None,
                    help="GCN cells, tiered mode: replicated L1 "
                         "rows/worker (0 auto-sizes to cache_rows/8)")
    ap.add_argument("--probe-wire", default=None,
                    choices=["dense", "compact"],
                    help="GCN cells: shard-probe response wire format "
                         "override (sharded/tiered modes)")
    ap.add_argument("--feature-store", default=None,
                    choices=["device", "host"],
                    help="GCN cells: feature-table placement override — "
                         "host lowers the L3 issue/collect path with NO "
                         "device feature table in the arg specs")
    ap.add_argument("--collect-stats", action="store_true",
                    help="GCN cells: lower the autotuner's instrumented "
                         "trace-recorder generator (the stacked "
                         "FetchStats/CacheStats telemetry tail) instead "
                         "of the train step")
    ap.add_argument("--out", default=None, help="append JSONL here")
    args = ap.parse_args()
    rec = lower_cell(args.arch, args.shape, args.multi_pod,
                     attn=args.attn, remat=args.remat, variant=args.variant,
                     shard_heads=args.shard_heads, gen_merge=args.gen_merge,
                     moe_impl=args.moe, seq_parallel=args.seq_parallel,
                     compress=args.compress, cache_rows=args.cache_rows,
                     cache_mode=args.cache_mode, l1_rows=args.l1_rows,
                     probe_wire=args.probe_wire,
                     feature_store=args.feature_store,
                     collect_stats=args.collect_stats)
    line = json.dumps(rec)
    print(line)
    if args.out:
        with open(args.out, "a") as f:
            f.write(line + "\n")
    if rec.get("status") not in ("ok", "skipped"):
        sys.exit(1)


if __name__ == "__main__":
    main()
