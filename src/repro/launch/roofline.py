"""Roofline analysis over dry-run results.

Reads the sweep JSONL and derives, per (arch x shape x mesh):

    compute term    = HLO_FLOPs_per_device / peak_FLOP/s          (197 TF bf16)
    memory term     = HLO_bytes_per_device / HBM_bw               (819 GB/s)
    collective term = collective_bytes_per_device / link_bw       (50 GB/s ICI)

plus MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE), the useful-compute
ratio MODEL_FLOPS / HLO_FLOPs, the dominant term, and a step-time lower
bound max(terms) (perfect overlap assumption).  Emits the markdown tables
for EXPERIMENTS.md.

    PYTHONPATH=src python -m repro.launch.roofline --in dryrun_results.jsonl
"""
from __future__ import annotations

import argparse
import json
from collections import defaultdict

from ..core.config import HBM_BW, ICI_BW, PCIE_BW, PEAK_FLOPS_BF16

# one decode step generates 1 token/sequence; 6*N_active*tokens is the
# model-flops floor for train (fwd+bwd); 2*N_active for forward-only.
_FWD_BWD = {"train": 6.0, "prefill": 2.0, "decode": 2.0}


def roofline_terms(flops_per_device: float, hbm_bytes_per_device: float,
                   wire_bytes_per_device: float,
                   host_gather_bytes: float = 0.0) -> dict:
    """Per-device roofline time terms (seconds) for one step.

    The shared seam between the dry-run sweep analysis and the
    autotuner's offline cost model: compute against ``PEAK_FLOPS_BF16``,
    HBM traffic against ``HBM_BW``, collective wire bytes against
    ``ICI_BW``, and the L3 host-gather term against ``PCIE_BW``.  Any
    count may be zero; every term is non-negative."""
    return {
        "compute": max(float(flops_per_device), 0.0) / PEAK_FLOPS_BF16,
        "memory": max(float(hbm_bytes_per_device), 0.0) / HBM_BW,
        "collective": max(float(wire_bytes_per_device), 0.0) / ICI_BW,
        "host": max(float(host_gather_bytes), 0.0) / PCIE_BW,
    }


def step_lower_bound(terms: dict) -> float:
    """Step-time lower bound from roofline terms: ``max`` over terms —
    the perfect-overlap assumption the sweep tables already use."""
    return max(terms.values()) if terms else 0.0


def analyse(rec: dict) -> dict | None:
    if rec.get("status") != "ok":
        return None
    chips = rec["chips"]
    terms = roofline_terms(rec["flops_per_device"], rec["bytes_per_device"],
                           rec["collective_bytes_per_device"]["total"])
    comp, mem, coll = terms["compute"], terms["memory"], terms["collective"]
    terms = {"compute": comp, "memory": mem, "collective": coll}
    dominant = max(terms, key=terms.get)
    model_flops = (
        _FWD_BWD[rec["kind"]] * rec["active_params"] * rec["tokens"]
    )
    hlo_global = rec["flops_per_device"] * chips
    useful = model_flops / hlo_global if hlo_global else 0.0
    bound = step_lower_bound(terms)
    mfu_bound = (model_flops / chips / PEAK_FLOPS_BF16) / bound if bound else 0.0
    return {
        **rec,
        "compute_s": comp,
        "memory_s": mem,
        "collective_s": coll,
        "dominant": dominant,
        "model_flops": model_flops,
        "useful_ratio": useful,
        "step_bound_s": bound,
        "mfu_bound": mfu_bound,
    }


def _fmt(x: float) -> str:
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x*1e6:.0f}us"
    if x < 1:
        return f"{x*1e3:.1f}ms"
    return f"{x:.2f}s"


def markdown_table(rows: list[dict], mesh: str) -> str:
    out = [
        f"### Mesh {mesh}",
        "",
        "| arch | shape | compute | memory | collective | dominant | "
        "model/HLO FLOPs | MFU bound | HBM/dev |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r.get("status") == "skipped":
            out.append(
                f"| {r['arch']} | {r['shape']} | — | — | — | skipped | — | — | — |"
            )
            continue
        hbm = ""
        if isinstance(r.get("memory"), dict):
            tot = (r["memory"]["argument_bytes"] + r["memory"]["temp_bytes"]
                   + r["memory"]["output_bytes"])
            hbm = f"{tot/2**30:.1f}GiB"
        out.append(
            f"| {r['arch']} | {r['shape']} | {_fmt(r['compute_s'])} | "
            f"{_fmt(r['memory_s'])} | {_fmt(r['collective_s'])} | "
            f"**{r['dominant']}** | {r['useful_ratio']:.2f} | "
            f"{r['mfu_bound']*100:.1f}% | {hbm} |"
        )
    return "\n".join(out)


def load(path: str) -> dict:
    cells = {}
    with open(path) as f:
        for line in f:
            r = json.loads(line)
            key = (r["arch"], r["shape"], r["mesh"])
            cells[key] = r          # later lines win (re-runs)
    return cells


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--in", dest="inp", default="dryrun_results.jsonl")
    ap.add_argument("--md", default=None, help="write markdown here")
    args = ap.parse_args()
    cells = load(args.inp)
    by_mesh = defaultdict(list)
    for (arch, shape, mesh), r in sorted(cells.items()):
        a = analyse(r) or r
        by_mesh[mesh].append(a)
    md = []
    for mesh in sorted(by_mesh):
        md.append(markdown_table(by_mesh[mesh], mesh))
        md.append("")
    text = "\n".join(md)
    print(text)
    if args.md:
        with open(args.md, "w") as f:
            f.write(text)


if __name__ == "__main__":
    main()
