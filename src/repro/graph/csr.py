"""CSR graph representation.

The global graph lives host-side as numpy arrays (the paper's graphs are far
larger than device memory); device-resident *partitions* of it are built by
``repro.core.partition``.  All ids are int32.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class CSRGraph:
    """Compressed sparse row adjacency: ``indices[indptr[v]:indptr[v+1]]``
    are the out-neighbors of ``v``."""

    indptr: np.ndarray   # [n_nodes + 1] int32 (int64 if E overflows)
    indices: np.ndarray  # [n_edges] int32

    @property
    def n_nodes(self) -> int:
        return len(self.indptr) - 1

    @property
    def n_edges(self) -> int:
        return len(self.indices)

    def degrees(self) -> np.ndarray:
        return np.diff(self.indptr)

    @staticmethod
    def from_edges(src: np.ndarray, dst: np.ndarray, n_nodes: int) -> "CSRGraph":
        """Build CSR from an edge list (src -> dst)."""
        order = np.argsort(src, kind="stable")
        src_sorted = src[order]
        dst_sorted = dst[order].astype(np.int32)
        counts = np.bincount(src_sorted, minlength=n_nodes)
        indptr = np.zeros(n_nodes + 1, dtype=np.int64)
        np.cumsum(counts, out=indptr[1:])
        if indptr[-1] < np.iinfo(np.int32).max:
            indptr = indptr.astype(np.int32)
        return CSRGraph(indptr=indptr, indices=dst_sorted)

    def edge_list(self) -> tuple[np.ndarray, np.ndarray]:
        src = np.repeat(np.arange(self.n_nodes, dtype=np.int32), self.degrees())
        return src, self.indices.copy()
