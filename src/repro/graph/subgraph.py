"""Fixed-fanout padded subgraph batches.

MapReduce GraphGen+ emits ragged subgraphs; XLA needs static shapes, so we
adopt the paper's own sampling configuration — 2-hop expansion with fanout
(40, 20) — as a *fixed-fanout padded tree* with validity masks (DESIGN.md §2,
"changed assumptions").

A batch of B seeds with fanouts (k1, k2) is:
    seeds   [B]          int32
    hop1    [B, k1]      int32 sampled 1-hop neighbor ids
    mask1   [B, k1]      bool
    hop2    [B, k1, k2]  int32 sampled 2-hop neighbor ids
    mask2   [B, k1, k2]  bool
    x_seed  [B, D]       float  features (collected during generation —
    x_hop1  [B, k1, D]          the paper routes subgraph *data*, not ids,
    x_hop2  [B, k1, k2, D]      through the tree reduction)
    labels  [B]          int32
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class SubgraphBatch(NamedTuple):
    seeds: jax.Array
    hop1: jax.Array
    mask1: jax.Array
    hop2: jax.Array
    mask2: jax.Array
    x_seed: jax.Array
    x_hop1: jax.Array
    x_hop2: jax.Array
    labels: jax.Array

    @property
    def batch_size(self) -> int:
        return self.seeds.shape[0]

    def nodes_per_iteration(self) -> int:
        """Total (padded) node slots materialized per iteration — the paper's
        '1M nodes per iteration' metric counts these."""
        b, k1 = self.hop1.shape
        k2 = self.hop2.shape[-1]
        return b * (1 + k1 + k1 * k2)


def batch_specs(batch: int, k1: int, k2: int, dim: int):
    """ShapeDtypeStruct stand-ins for a SubgraphBatch (dry-run input)."""
    f32, i32 = jnp.float32, jnp.int32
    s = jax.ShapeDtypeStruct
    return SubgraphBatch(
        seeds=s((batch,), i32),
        hop1=s((batch, k1), i32),
        mask1=s((batch, k1), jnp.bool_),
        hop2=s((batch, k1, k2), i32),
        mask2=s((batch, k1, k2), jnp.bool_),
        x_seed=s((batch, dim), f32),
        x_hop1=s((batch, k1, dim), f32),
        x_hop2=s((batch, k1, k2, dim), f32),
        labels=s((batch,), i32),
    )
