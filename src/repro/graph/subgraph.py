"""Fixed-fanout padded subgraph batches of arbitrary depth.

MapReduce GraphGen+ emits ragged subgraphs; XLA needs static shapes, so we
represent an L-hop expansion with fanouts ``(k_1, ..., k_L)`` as a
*fixed-fanout padded tree* with validity masks (DESIGN.md §2, "changed
assumptions").  The paper's benchmark configuration is the 2-hop special
case ``(40, 20)``; the layout below is depth-generic so 1-hop
(GraphSAGE-style) and deep (3+ hop) sampling share the same engine.

A batch of B seeds with fanouts ``(k_1, ..., k_L)`` is:
    seeds      [B]                      int32
    hops[l]    [B, k_1, ..., k_{l+1}]   int32 sampled hop-(l+1) neighbor ids
    masks[l]   [B, k_1, ..., k_{l+1}]   bool, chained: a padded parent's
                                        whole subtree is masked out
    x_seed     [B, D]                   float features (collected during
    x_hops[l]  [B, k_1, .., k_{l+1}, D] generation — the paper routes
                                        subgraph *data*, not ids, through
                                        the tree reduction); padded slots
                                        are zeroed
    labels     [B]                      int32
    n_dropped  [W]                      int32 per-worker count of feature-
                                        shuffle requests dropped by the
                                        capacity bound (0 in healthy runs)
    n_cache_hits   [W]                  int32 per-worker unique feature
                                        requests served by the hot-node
                                        cache (0 when the cache is off)
    n_cache_misses [W]                  int32 per-worker unique feature
                                        requests routed over the wire
    n_probe_demoted [W]                 int32 per-worker (holder-side)
                                        probe hits demoted to misses by
                                        the compact wire's hit_cap bound
                                        (0 on the dense wire / no cache;
                                        a lost hit opportunity, never a
                                        correctness loss — the launcher
                                        calibrates hit_cap against it)
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class SubgraphBatch(NamedTuple):
    seeds: jax.Array
    hops: Tuple[jax.Array, ...]
    masks: Tuple[jax.Array, ...]
    x_seed: jax.Array
    x_hops: Tuple[jax.Array, ...]
    labels: jax.Array
    n_dropped: jax.Array
    n_cache_hits: Optional[jax.Array] = None
    n_cache_misses: Optional[jax.Array] = None
    n_probe_demoted: Optional[jax.Array] = None

    def cache_hit_rate(self) -> float:
        """Fraction of unique feature requests served device-locally."""
        if self.n_cache_hits is None or self.n_cache_misses is None:
            return 0.0
        hits = float(jnp.sum(self.n_cache_hits))
        total = hits + float(jnp.sum(self.n_cache_misses))
        return hits / total if total else 0.0

    @property
    def batch_size(self) -> int:
        """Seeds in the batch (``B``, the leading axis of every field)."""
        return self.seeds.shape[0]

    @property
    def depth(self) -> int:
        """Sampled hop count ``L`` (``len(hops)``)."""
        return len(self.hops)

    @property
    def fanouts(self) -> Tuple[int, ...]:
        """Per-hop fanouts ``(k_1, ..., k_L)`` recovered from the shapes."""
        return tuple(h.shape[-1] for h in self.hops)

    # ---- 2-hop conveniences (the paper's benchmark layout) ----------------
    @property
    def hop1(self) -> jax.Array:
        """First-hop neighbor ids ``hops[0]`` ([B, k_1]; 2-hop shorthand)."""
        return self.hops[0]

    @property
    def mask1(self) -> jax.Array:
        """First-hop validity mask ``masks[0]`` ([B, k_1] bool)."""
        return self.masks[0]

    @property
    def x_hop1(self) -> jax.Array:
        """First-hop features ``x_hops[0]`` ([B, k_1, D]; padded rows 0)."""
        return self.x_hops[0]

    @property
    def hop2(self) -> jax.Array:
        """Second-hop neighbor ids ``hops[1]`` ([B, k_1, k_2])."""
        return self.hops[1]

    @property
    def mask2(self) -> jax.Array:
        """Second-hop validity mask ``masks[1]`` ([B, k_1, k_2] bool)."""
        return self.masks[1]

    @property
    def x_hop2(self) -> jax.Array:
        """Second-hop features ``x_hops[1]`` ([B, k_1, k_2, D])."""
        return self.x_hops[1]

    def nodes_per_iteration(self) -> int:
        """Total (padded) node slots materialized per iteration — the paper's
        '1M nodes per iteration' metric counts these."""
        return self.batch_size * slots_per_seed(self.fanouts)


def slots_per_seed(fanouts: Tuple[int, ...]) -> int:
    """Padded node slots per seed: 1 + k1 + k1*k2 + ... (tree size)."""
    total, level = 1, 1
    for k in fanouts:
        level *= k
        total += level
    return total


def batch_specs(batch: int, fanouts: Tuple[int, ...], dim: int,
                n_workers: int = 1):
    """ShapeDtypeStruct stand-ins for a SubgraphBatch (dry-run input)."""
    f32, i32 = jnp.float32, jnp.int32
    s = jax.ShapeDtypeStruct
    shape = (batch,)
    hops, masks, x_hops = [], [], []
    for k in fanouts:
        shape = shape + (k,)
        hops.append(s(shape, i32))
        masks.append(s(shape, jnp.bool_))
        x_hops.append(s(shape + (dim,), f32))
    return SubgraphBatch(
        seeds=s((batch,), i32),
        hops=tuple(hops),
        masks=tuple(masks),
        x_seed=s((batch, dim), f32),
        x_hops=tuple(x_hops),
        labels=s((batch,), i32),
        n_dropped=s((n_workers,), i32),
        n_cache_hits=s((n_workers,), i32),
        n_cache_misses=s((n_workers,), i32),
        n_probe_demoted=s((n_workers,), i32),
    )
