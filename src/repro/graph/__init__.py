from .csr import CSRGraph
from .synthetic import powerlaw_graph, node_features, node_labels
from .subgraph import SubgraphBatch, batch_specs, slots_per_seed
