"""Synthetic industrial-graph generators.

The paper evaluates on a 530M-node / 5B-edge production graph with a heavy
power-law degree distribution (hot nodes are the motivating problem for the
tree-reduction strategy).  We generate scale-down analogues with the same
statistical shape: a Zipf-distributed out-degree sequence realized with a
configuration model, plus optional planted "hot" nodes.
"""
from __future__ import annotations

import numpy as np

from .csr import CSRGraph


def powerlaw_graph(
    n_nodes: int,
    avg_degree: float = 10.0,
    alpha: float = 2.1,
    n_hot: int = 0,
    hot_degree: int = 0,
    seed: int = 0,
) -> CSRGraph:
    """Directed power-law graph via a configuration model.

    ``n_hot`` nodes are planted with out-degree ``hot_degree`` to stress the
    hot-node aggregation path (paper §2 step 3).
    """
    rng = np.random.default_rng(seed)
    # Zipf-ish degrees clipped so the expected mean is ~avg_degree.
    raw = rng.zipf(alpha, size=n_nodes).astype(np.float64)
    raw = np.minimum(raw, n_nodes // 2)
    deg = np.maximum((raw * (avg_degree / raw.mean())).astype(np.int64), 1)
    if n_hot > 0:
        hot_ids = rng.choice(n_nodes, size=n_hot, replace=False)
        deg[hot_ids] = hot_degree or max(int(deg.max() * 10), 100)
    src = np.repeat(np.arange(n_nodes, dtype=np.int32), deg)
    dst = rng.integers(0, n_nodes, size=len(src), dtype=np.int32)
    return CSRGraph.from_edges(src, dst, n_nodes)


def node_features(n_nodes: int, dim: int, seed: int = 0, *,
                  features_on_host: bool = False,
                  chunk_rows: int = 1 << 16) -> np.ndarray:
    """Synthetic [n_nodes, dim] float32 feature table.

    With ``features_on_host=True`` the table is built for the L3 host
    store (``core/host_store.py``): generated in ``chunk_rows``-row
    chunks into one preallocated host array, so peak memory is the table
    itself plus ONE chunk — the default path's full-size ``* 0.1``
    temporary would double the footprint, which is exactly what a
    table sized beyond aggregate device memory cannot afford.  Both
    paths are bit-identical: sequential ``standard_normal`` chunk draws
    consume the Generator stream exactly like one full-size draw, and
    the in-place ``*= 0.1`` is the same float32 multiply.
    """
    rng = np.random.default_rng(seed + 1)
    if not features_on_host:
        return rng.standard_normal((n_nodes, dim), dtype=np.float32) * 0.1
    out = np.empty((n_nodes, dim), np.float32)
    for lo in range(0, n_nodes, chunk_rows):
        hi = min(lo + chunk_rows, n_nodes)
        out[lo:hi] = rng.standard_normal((hi - lo, dim), dtype=np.float32)
    out *= np.float32(0.1)
    return out


def node_labels(n_nodes: int, n_classes: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed + 2)
    return rng.integers(0, n_classes, size=n_nodes, dtype=np.int32)
