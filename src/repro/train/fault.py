"""Node-failure and straggler handling for the generation+training fleet.

MapReduce (the paper's substrate) re-executes failed tasks transparently;
an SPMD TPU job cannot — a lost worker means the job restarts on the
surviving topology from the last checkpoint.  This module provides the
orchestration for that story:

  * ``FailureInjector``     — deterministic fault simulation for tests and
    benchmarks (worker death at step k, transient slowdowns).
  * ``recover_assignment``  — re-runs Algorithm 1's balance table over the
    survivors so every remaining worker gets an equal seed share.
  * ``run_with_recovery``   — the supervision loop: run -> on failure,
    rebalance + restore latest checkpoint -> continue.  Paired with
    ``checkpoint.py``'s elastic reshard, this covers shrink (node loss)
    and grow (node return) without re-partitioning the graph.

Straggler mitigation for *generation* is speculative re-execution in
``data.loader.PrefetchLoader``; for the jitted SPMD step, stragglers are
a hardware concern (there is no per-step reassignment inside a collective)
— the knobs here are checkpoint cadence and backup pods.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import numpy as np

from ..core.balance import BalanceTable, balance_table


class WorkerFailure(RuntimeError):
    def __init__(self, worker: int, step: int):
        super().__init__(f"worker {worker} failed at step {step}")
        self.worker = worker
        self.step = step


@dataclasses.dataclass
class FailureInjector:
    fail_worker: Optional[int] = None
    fail_at_step: Optional[int] = None
    _tripped: bool = False

    def check(self, step: int) -> None:
        if (
            not self._tripped
            and self.fail_at_step is not None
            and step >= self.fail_at_step
        ):
            self._tripped = True
            raise WorkerFailure(self.fail_worker or 0, step)


def recover_assignment(
    table: BalanceTable, failed: list[int], seed: int = 1
) -> BalanceTable:
    """Rebuild the balance table over survivors (Algorithm 1 with |W|-f)."""
    survivors = [w for w in range(table.n_workers) if w not in set(failed)]
    if not survivors:
        raise RuntimeError("no surviving workers")
    pool = table.per_worker.reshape(-1)
    return balance_table(pool, len(survivors), seed=seed)


def run_with_recovery(
    run_steps: Callable[[int, int, BalanceTable], int],
    table: BalanceTable,
    total_steps: int,
    restore_step: Callable[[], int],
    max_failures: int = 3,
):
    """Supervision loop.  ``run_steps(start, end, table)`` trains and may
    raise WorkerFailure; ``restore_step()`` returns the last durable step.
    Returns (completed_steps, failures_handled, final_table)."""
    failures = 0
    step = 0
    while step < total_steps:
        try:
            step = run_steps(step, total_steps, table)
        except WorkerFailure as f:
            failures += 1
            if failures > max_failures:
                raise
            table = recover_assignment(table, [f.worker], seed=failures)
            step = restore_step()
    return step, failures, table
