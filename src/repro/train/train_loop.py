"""Train-step builders.

``make_train_step`` produces the jitted SPMD step for any zoo architecture:
loss -> grad (with optional microbatch accumulation via lax.scan) -> AdamW.
Under jit with sharded batches, the data-parallel gradient AllReduce is
inserted by the SPMD partitioner; ``grad_sync='tree'`` instead routes the
sync through the explicit butterfly ``tree_psum`` inside a shard_map (the
paper's tree-reduction applied to step-4 gradient synchronization), and
``compress_grads=True`` applies int8 error-feedback compression to the
cross-pod leg.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from ..core.config import TrainConfig
from ..core.tree_reduce import tree_psum
from . import compression
from .optimizer import AdamState, adam_update, init_adam


class TrainState(NamedTuple):
    params: Any
    opt: AdamState
    error: Any   # error-feedback residual (None unless compressing)


def init_state(params, cfg: TrainConfig) -> TrainState:
    err = compression.init_error(params) if cfg.compress_grads else None
    return TrainState(params=params, opt=init_adam(params), error=err)


def _microbatch_grads(loss_fn, params, batch, n_micro: int):
    if n_micro <= 1:
        return jax.value_and_grad(loss_fn)(params, batch)

    def reshape(x):
        return x.reshape((n_micro, x.shape[0] // n_micro) + x.shape[1:])

    micro = jax.tree.map(reshape, batch)

    def body(carry, mb):
        loss_acc, grad_acc = carry
        loss, grads = jax.value_and_grad(loss_fn)(params, mb)
        return (
            loss_acc + loss / n_micro,
            jax.tree.map(lambda a, g: a + g / n_micro, grad_acc, grads),
        ), None

    zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    (loss, grads), _ = jax.lax.scan(body, (jnp.zeros((), jnp.float32), zero), micro)
    return loss, grads


def make_train_step(
    loss_fn: Callable[[Any, Any], jax.Array],
    tcfg: TrainConfig,
    mesh: Mesh | None = None,
):
    """Returns step(state, batch) -> (state, metrics).  jit it with the
    in/out shardings the launcher derives from zoo.param_pspecs."""

    def step(state: TrainState, batch):
        loss, grads = _microbatch_grads(
            loss_fn, state.params, batch, tcfg.microbatches
        )
        error = state.error
        if tcfg.compress_grads:
            packed, error = compression.compress_grads(grads, error)
            grads = compression.decompress_grads(packed)
        params, opt, gnorm = adam_update(tcfg, state.params, grads, state.opt)
        metrics = {"loss": loss, "grad_norm": gnorm, "step": opt.step}
        return TrainState(params=params, opt=opt, error=error), metrics

    return step


def make_shardmap_grad_sync(mesh: Mesh, axis_name: str = "data"):
    """Explicit tree-reduction gradient AllReduce (--grad-sync tree).

    For use around a per-worker grad computation inside shard_map: grads
    replicated on `axis_name` after a butterfly of ppermute+add — the
    paper's step-3 hierarchy applied to step-4 sync."""

    def sync(grads):
        def inner(g):
            summed = tree_psum(g, axis_name)
            return jax.tree.map(lambda x: x / mesh.shape[axis_name], summed)

        specs = jax.tree.map(lambda _: P(), grads)
        return shard_map(
            inner, mesh=mesh, in_specs=(specs,), out_specs=specs, check_rep=False
        )(grads)

    return sync


def nan_guard(state: TrainState, new_state: TrainState, metrics) -> TrainState:
    """Straggler/blow-up resilience: skip the update when loss goes NaN
    (keeps the replica fleet consistent instead of desyncing)."""
    ok = jnp.isfinite(metrics["loss"])
    return jax.tree.map(
        lambda old, new: jnp.where(ok, new, old), state, new_state
    )
