"""Int8 gradient compression with error feedback — for the cross-pod (DCN)
AllReduce, where link bandwidth is ~20x below ICI.

Per-tensor symmetric quantization; the residual (quantization error) is
carried in f32 on the local worker and added back before the next step's
quantization (error feedback guarantees the compression bias telescopes
rather than accumulates — Karimireddy et al. 2019).

Wire format cost: 1 byte/param + 1 f32 scale per tensor -> 4x less DCN
traffic than f32, 2x less than bf16.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def quantize(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def init_error(params) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_grads(grads, error):
    """-> (quantized tree of (q, scale) pairs, new error-feedback tree)."""
    flat, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(error)
    out_q, out_e = [], []
    for g, e in zip(flat, flat_e):
        gf = g.astype(jnp.float32) + e
        q, s = quantize(gf)
        out_q.append((q, s))
        out_e.append(gf - dequantize(q, s))
    return jax.tree.unflatten(treedef, out_q), jax.tree.unflatten(treedef, out_e)


def decompress_grads(packed):
    return jax.tree.map(
        lambda t: dequantize(*t),
        packed,
        is_leaf=lambda t: isinstance(t, tuple) and len(t) == 2,
    )
