"""Hand-rolled AdamW with linear-warmup cosine decay and global-norm clip.

State is a pytree mirroring params (so every optimizer leaf inherits the
param's sharding spec — ZeRO-style sharded optimizer state for free).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from ..core.config import TrainConfig


class AdamState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


def init_adam(params) -> AdamState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return AdamState(step=jnp.zeros((), jnp.int32), m=zeros,
                     v=jax.tree.map(jnp.copy, zeros))


def lr_schedule(cfg: TrainConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    return cfg.learning_rate * warm * (0.1 + 0.9 * cos)


def global_norm(tree) -> jax.Array:
    sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


def adam_update(cfg: TrainConfig, params, grads, state: AdamState):
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    lr = lr_schedule(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    new_m = jax.tree.map(
        lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32), state.m, grads
    )
    new_v = jax.tree.map(
        lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
        state.v, grads,
    )

    def upd(p, m, v):
        mh = m / bc1
        vh = v / bc2
        new_p = p.astype(jnp.float32) - lr * (
            mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        )
        return new_p.astype(p.dtype)

    new_params = jax.tree.map(upd, params, new_m, new_v)
    return new_params, AdamState(step=step, m=new_m, v=new_v), gnorm
