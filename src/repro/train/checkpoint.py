"""Checkpoint/restart for fault tolerance at scale.

Design (DESIGN.md §6):
  * atomic commits  — write to ``<dir>/tmp.<step>`` then ``os.rename`` (a
    torn write can never be mistaken for a valid checkpoint);
  * keep-last-k     — bounded disk usage under failure/restart churn;
  * elastic reshard — leaves are saved as full LOGICAL arrays (gathered),
    so a checkpoint taken on a (16,16) mesh restores onto (2,16,16), (4,)
    or a single device: restore takes the TARGET shardings and
    ``device_put``s each leaf.  This is what lets a 1000-node job resume
    on 900 survivors.
  * self-describing — tree structure + dtypes + step in meta.json.

On a real multi-host pod this becomes per-host shard files + a commit
barrier; the single-process container collapses that to one writer, but
the atomicity/retention/reshard logic is identical.
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any, Optional

import jax
import ml_dtypes
import numpy as np

# numpy's savez can't serialize the ml_dtypes extended types; round-trip
# them through a same-width integer view, tagged in meta.json.
_EXT_DTYPES = {"bfloat16": (ml_dtypes.bfloat16, np.uint16)}


def _flatten(tree) -> tuple[dict[str, np.ndarray], dict[str, str]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    dtypes = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        arr = np.asarray(leaf)
        for name, (ext, view) in _EXT_DTYPES.items():
            if arr.dtype == ext:
                dtypes[key] = name
                arr = arr.view(view)
                break
        out[key] = arr
    return out, dtypes


def save(ckpt_dir: str, step: int, tree: Any, *, keep: int = 3, extra: Optional[dict] = None) -> str:
    """Atomically commit ``tree`` as ``<ckpt_dir>/step_<step>`` (npz +
    meta.json), retaining only the newest ``keep`` checkpoints; ``extra``
    is recorded verbatim in the metadata.  Returns the committed path."""
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = os.path.join(ckpt_dir, f"tmp.{step}")
    final = os.path.join(ckpt_dir, f"step_{step:010d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    arrays, ext_dtypes = _flatten(tree)
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    meta = {"step": int(step), "keys": sorted(arrays),
            "ext_dtypes": ext_dtypes, "extra": extra or {}}
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)                      # atomic commit
    _retain(ckpt_dir, keep)
    return final


def _retain(ckpt_dir: str, keep: int) -> None:
    steps = sorted(
        d for d in os.listdir(ckpt_dir) if d.startswith("step_")
    )
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d))


def latest_step(ckpt_dir: str) -> Optional[int]:
    """Newest committed checkpoint step under ``ckpt_dir`` (None if the
    directory is missing or holds no ``step_*`` entries)."""
    if not os.path.isdir(ckpt_dir):
        return None
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_"))
    return int(steps[-1].split("_")[1]) if steps else None


def save_serving_state(ckpt_dir: str, step: int, params: Any, cache: Any,
                       *, keep: int = 3,
                       cache_cfg: Any = None) -> str:
    """Checkpoint the frozen-serving bundle: model params + warm cache.

    The serving tier restores this instead of re-warming from scratch —
    the trained params and the training run's steady-state cache state
    travel together, so a server comes up with the Zipf head already
    resident.  ``cache_cfg`` (a ``CacheConfig``) is recorded in the
    checkpoint metadata; ``restore_serving_state`` refuses a state whose
    recorded policy disagrees with the one the server was built under
    (the slot layout is a property of the policy — probing a state under
    the wrong layout silently yields a near-zero hit rate, not an
    error)."""
    extra = {"kind": "serving"}
    if cache_cfg is not None:
        extra["cache_cfg"] = dict(cache_cfg._asdict())
    return save(ckpt_dir, step, {"params": params, "cache": cache},
                keep=keep, extra=extra)


def restore_serving_state(ckpt_dir: str, params_like: Any, cache_like: Any,
                          *, step: Optional[int] = None,
                          shardings: Any = None,
                          expect_cache_cfg: Any = None) -> tuple:
    """Restore ``(params, cache)`` saved by :func:`save_serving_state`.

    ``params_like``/``cache_like`` supply the target structure and leaf
    dtypes (e.g. a fresh ``init_gcn`` tree and an empty
    ``init_cache_state``); ``shardings``, when given, is a matching
    ``{"params": ..., "cache": ...}`` pytree of shardings for the
    elastic-reshard placement path.  ``step=None`` selects the latest
    checkpoint.  ``expect_cache_cfg`` (a ``CacheConfig``) cross-checks
    the policy recorded at save time — a layout mismatch raises instead
    of silently probing cold."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(
                f"no serving checkpoint under {ckpt_dir!r}")
    if expect_cache_cfg is not None:
        meta_path = os.path.join(ckpt_dir, f"step_{step:010d}", "meta.json")
        with open(meta_path) as f:
            saved = json.load(f).get("extra", {}).get("cache_cfg")
        if saved is not None:
            now = {k: v for k, v in expect_cache_cfg._asdict().items()}
            # the serve view flips frozen/store without changing layout —
            # compare the layout-bearing fields only
            layout = ("n_rows", "assoc", "mode", "l1_rows")
            diff = {k: (saved.get(k), now.get(k))
                    for k in layout if saved.get(k) != now.get(k)}
            if diff:
                raise ValueError(
                    f"serving checkpoint cache layout mismatch: {diff} "
                    f"(saved vs serving CacheConfig) — the cache state "
                    f"only probes correctly under the layout it was "
                    f"warmed with")
    tree = restore(ckpt_dir, step,
                   {"params": params_like, "cache": cache_like},
                   shardings=shardings)
    return tree["params"], tree["cache"]


def restore(ckpt_dir: str, step: int, like: Any, shardings: Any = None) -> Any:
    """Restore into the structure of ``like`` (values replaced); if
    ``shardings`` (matching pytree of NamedSharding) is given, each leaf is
    placed with it — the elastic-reshard path."""
    path = os.path.join(ckpt_dir, f"step_{step:010d}")
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    with np.load(os.path.join(path, "arrays.npz")) as z:
        arrays = {k: z[k] for k in z.files}
    for key, name in meta.get("ext_dtypes", {}).items():
        ext, _ = _EXT_DTYPES[name]
        arrays[key] = arrays[key].view(ext)
    flat = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    shard_leaves = jax.tree.leaves(shardings) if shardings is not None else None
    for i, (p, leaf) in enumerate(flat[0]):
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in p)
        arr = arrays[key]
        if hasattr(leaf, "dtype"):
            arr = arr.astype(leaf.dtype)
        if shard_leaves is not None:
            leaves.append(jax.device_put(arr, shard_leaves[i]))
        else:
            leaves.append(jax.numpy.asarray(arr))
    return jax.tree.unflatten(flat[1], leaves)
