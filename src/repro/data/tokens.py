"""Token data pipeline for the assigned LM-family architectures.

Synthetic token streams (no corpus ships with the container) sharded with
the SAME balance-table discipline as subgraph seeds (DESIGN.md §4): document
ids are shuffled, dealt round-robin to data-parallel workers, and the
remainder is discarded — so every worker sees an identical batch count.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..core.balance import balance_table
from ..core.config import ModelConfig, ShapeConfig


def synthetic_token_batch(
    cfg: ModelConfig, shape: ShapeConfig, seed: int = 0
) -> dict:
    """A host-materialized batch (smoke tests; dry-runs use input_specs)."""
    rng = np.random.default_rng(seed)
    b, s = shape.global_batch, shape.seq_len
    tokens = rng.integers(0, cfg.vocab_size, size=(b, s), dtype=np.int32)
    batch = {"tokens": jnp.asarray(tokens)}
    batch["labels"] = jnp.asarray(
        np.concatenate([tokens[:, 1:], tokens[:, :1]], axis=1)
    )
    return batch


def token_shard_schedule(
    n_documents: int, n_workers: int, steps: int, per_step: int, seed: int = 0
) -> np.ndarray:
    """Balance-table document assignment -> [steps, W, per_step] schedule."""
    table = balance_table(np.arange(n_documents, dtype=np.int32), n_workers, seed)
    per_w = table.per_worker  # [W, S/W]
    need = steps * per_step
    reps = -(-need // per_w.shape[1])
    tiled = np.tile(per_w, (1, reps))[:, :need]          # [W, steps*per_step]
    return tiled.reshape(n_workers, steps, per_step).transpose(1, 0, 2)
