"""Host-side producer/consumer pipeline (GraphGen+ step 4, generalized).

The on-device double buffer (``core.pipeline``) overlaps one step of
generation; this loader generalizes the same idea across the host boundary
for producers that are not pure-JAX (tokenized text shards, file readers):
a bounded queue of prefetched batches, produced by worker threads that own
balance-table shards, with MapReduce-style **speculative execution** for
straggler mitigation: when a shard's production time exceeds
``straggler_factor x`` the running median, the same shard is re-issued to an
idle thread and whichever copy finishes first wins.
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Callable, Iterator, Optional


class PrefetchLoader:
    def __init__(
        self,
        produce: Callable[[int], object],   # shard_index -> batch
        n_shards: int,
        depth: int = 2,
        n_threads: int = 2,
        straggler_factor: float = 4.0,
        max_backups: int = 8,
    ) -> None:
        self._produce = produce
        self._n_shards = n_shards
        self._q: "queue.Queue[tuple[int, object]]" = queue.Queue(maxsize=depth)
        self._pending: "queue.Queue[int]" = queue.Queue()
        self._done: dict[int, object] = {}
        self._done_lock = threading.Lock()
        self._times: list[float] = []
        self._stop = threading.Event()
        self._straggler_factor = straggler_factor
        self._backups_issued = 0
        self._max_backups = max_backups
        self._inflight: dict[int, float] = {}   # shard -> start time
        for s in range(n_shards):
            self._pending.put(s)
        self._threads = [
            threading.Thread(target=self._worker, daemon=True)
            for _ in range(n_threads)
        ]
        self._watchdog = threading.Thread(target=self._watch, daemon=True)

    # -- internals ---------------------------------------------------------
    def _worker(self) -> None:
        while not self._stop.is_set():
            try:
                shard = self._pending.get(timeout=0.05)
            except queue.Empty:
                if self._all_done():
                    return
                continue
            with self._done_lock:
                if shard in self._done:      # a backup already finished it
                    continue
                self._inflight[shard] = time.perf_counter()
            t0 = time.perf_counter()
            batch = self._produce(shard)
            dt = time.perf_counter() - t0
            with self._done_lock:
                if shard in self._done:
                    continue                 # lost the race to a backup
                self._done[shard] = batch
                self._inflight.pop(shard, None)
                self._times.append(dt)
            # bounded put that keeps observing the stop flag — a plain
            # blocking put() would deadlock a producer forever if the
            # consumer goes away while the queue is full
            while not self._stop.is_set():
                try:
                    self._q.put((shard, batch), timeout=0.05)
                    break
                except queue.Full:
                    continue

    def _watch(self) -> None:
        """Speculative re-execution of stragglers."""
        while not self._stop.is_set() and not self._all_done():
            time.sleep(0.01)
            with self._done_lock:
                if len(self._times) < 3 or self._backups_issued >= self._max_backups:
                    continue
                med = sorted(self._times)[len(self._times) // 2]
                now = time.perf_counter()
                for shard, t0 in list(self._inflight.items()):
                    if now - t0 > self._straggler_factor * max(med, 1e-4):
                        self._pending.put(shard)        # re-issue
                        self._inflight.pop(shard)
                        self._backups_issued += 1

    def _all_done(self) -> bool:
        with self._done_lock:
            return len(self._done) >= self._n_shards

    # -- public ------------------------------------------------------------
    def __iter__(self) -> Iterator[object]:
        for t in self._threads:
            t.start()
        self._watchdog.start()
        served = 0
        try:
            while served < self._n_shards:
                shard, batch = self._q.get()
                served += 1
                yield batch
        finally:
            # normal exhaustion AND early generator close both land here
            self.stop()

    @property
    def backups_issued(self) -> int:
        return self._backups_issued

    def stop(self, join_timeout: float = 2.0) -> None:
        """Shut down producers and the watchdog.

        Drains the bounded queue so any producer blocked on a full queue can
        observe the stop flag, then joins all threads.  Idempotent; safe to
        call before iteration started (threads never started -> no join)."""
        self._stop.set()
        while True:
            try:
                self._q.get_nowait()
            except queue.Empty:
                break
        me = threading.current_thread()
        for t in self._threads + [self._watchdog]:
            if t is not me and t.is_alive():
                t.join(timeout=join_timeout)

    def live_threads(self) -> list[threading.Thread]:
        """Worker/watchdog threads still running (diagnostics + tests)."""
        return [t for t in self._threads + [self._watchdog] if t.is_alive()]
