from .loader import PrefetchLoader
from .tokens import synthetic_token_batch, token_shard_schedule
