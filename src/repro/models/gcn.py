"""GCN (Kipf & Welling) over fixed-fanout padded subgraph trees — the
paper's training model (§3: mini-batch GCN, benchmarked at 2-hop (40, 20)).

Depth-generic bottom-up aggregation: an L-hop batch is consumed by L graph
convolutions.  Layer ``i`` (1-based) updates every tree level that still
matters (levels ``0 .. L-i``) from its own representation plus the masked
mean of its children — so the seed level gets the SAME self+neighbor
treatment as interior levels at every layer (the seed repo dropped the
neighbor term at the seed's first layer).  After layer L only the seed
level remains.

Aggregation on a padded fanout tree is a masked mean over the fanout axis
followed by a dense transform — the masked mean is the `gather_reduce`
Pallas kernel's job on TPU (kernels/gather_reduce.py); here we route through
``kernels.ops.fanout_mean`` which picks kernel vs reference implementation.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from ..core.config import ModelConfig
from ..graph.subgraph import SubgraphBatch
from ..kernels import ops as kops


class GCNLayerParams(NamedTuple):
    w_self: jax.Array
    w_nbr: jax.Array
    b: jax.Array


class GCNParams(NamedTuple):
    layers: Tuple[GCNLayerParams, ...]   # one per hop, deepest first applied
    w_out: jax.Array
    b_out: jax.Array


def init_gcn(cfg: ModelConfig, rng: jax.Array) -> GCNParams:
    d, h, c = cfg.gcn_in_dim, cfg.gcn_hidden, cfg.n_classes
    depth = max(len(cfg.fanouts), 1)
    ks = jax.random.split(rng, 2 * depth + 1)
    gl = jax.nn.initializers.glorot_uniform()
    layers = []
    din = d
    for i in range(depth):
        layers.append(GCNLayerParams(
            w_self=gl(ks[2 * i], (din, h)),
            w_nbr=gl(ks[2 * i + 1], (din, h)),
            b=jnp.zeros((h,)),
        ))
        din = h
    return GCNParams(layers=tuple(layers), w_out=gl(ks[-1], (h, c)),
                     b_out=jnp.zeros((c,)))


def _child_mean(child: jax.Array, mask: jax.Array, use_kernel: bool) -> jax.Array:
    """Masked mean over the last fanout axis: [..., k, D] -> [..., D]."""
    k, d = child.shape[-2], child.shape[-1]
    agg = kops.fanout_mean(
        child.reshape(-1, k, d), mask.reshape(-1, k), use_kernel=use_kernel
    )
    return agg.reshape(child.shape[:-2] + (d,))


def gcn_forward(params: GCNParams, batch: SubgraphBatch, use_kernel: bool = False):
    """Bottom-up tree aggregation over an L-hop batch: hop L -> ... -> seed."""
    depth = batch.depth
    assert len(params.layers) == depth, (
        f"params built for {len(params.layers)} hops, batch has {depth}")
    # reps[v] = current representation of tree level v (0 = seeds)
    reps = [batch.x_seed] + list(batch.x_hops)
    for i, lyr in enumerate(params.layers):
        new_reps = []
        for v in range(depth - i):
            agg = _child_mean(reps[v + 1], batch.masks[v], use_kernel)
            new_reps.append(jax.nn.relu(
                reps[v] @ lyr.w_self + agg @ lyr.w_nbr + lyr.b))
        reps = new_reps
    return reps[0] @ params.w_out + params.b_out  # [b, n_classes]


def gcn_loss(params: GCNParams, batch: SubgraphBatch, use_kernel: bool = False):
    logits = gcn_forward(params, batch, use_kernel=use_kernel)
    logp = jax.nn.log_softmax(logits)
    nll = -jnp.take_along_axis(logp, batch.labels[:, None], axis=1)[:, 0]
    return nll.mean()
