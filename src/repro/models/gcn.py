"""GCN (Kipf & Welling) over fixed-fanout padded subgraph trees — the
paper's training model (§3: mini-batch GCN on 2-hop (40, 20) subgraphs).

Aggregation on a padded fanout tree is a masked mean over the fanout axis
followed by a dense transform — the masked mean is the `gather_reduce`
Pallas kernel's job on TPU (kernels/gather_reduce.py); here we route through
``kernels.ops.fanout_mean`` which picks kernel vs reference implementation.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..core.config import ModelConfig
from ..graph.subgraph import SubgraphBatch
from ..kernels import ops as kops


class GCNParams(NamedTuple):
    w1_self: jax.Array
    w1_nbr: jax.Array
    b1: jax.Array
    w2_self: jax.Array
    w2_nbr: jax.Array
    b2: jax.Array
    w_out: jax.Array
    b_out: jax.Array


def init_gcn(cfg: ModelConfig, rng: jax.Array) -> GCNParams:
    d, h, c = cfg.gcn_in_dim, cfg.gcn_hidden, cfg.n_classes
    ks = jax.random.split(rng, 5)
    gl = jax.nn.initializers.glorot_uniform()
    return GCNParams(
        w1_self=gl(ks[0], (d, h)),
        w1_nbr=gl(ks[1], (d, h)),
        b1=jnp.zeros((h,)),
        w2_self=gl(ks[2], (h, h)),
        w2_nbr=gl(ks[3], (h, h)),
        b2=jnp.zeros((h,)),
        w_out=gl(ks[4], (h, c)),
        b_out=jnp.zeros((c,)),
    )


def gcn_forward(params: GCNParams, batch: SubgraphBatch, use_kernel: bool = False):
    """Bottom-up tree aggregation: hop2 -> hop1 -> seed."""
    b, k1 = batch.hop1.shape
    k2 = batch.hop2.shape[-1]
    # layer 1 at hop-1 nodes: aggregate their (hop-2) neighbors
    agg1 = kops.fanout_mean(
        batch.x_hop2.reshape(b * k1, k2, -1),
        batch.mask2.reshape(b * k1, k2),
        use_kernel=use_kernel,
    ).reshape(b, k1, -1)
    h1 = jax.nn.relu(
        batch.x_hop1 @ params.w1_self + agg1 @ params.w1_nbr + params.b1
    )  # [b, k1, h]
    # layer 2 at seeds: aggregate hop-1 hidden states
    agg0 = kops.fanout_mean(h1, batch.mask1, use_kernel=use_kernel)  # [b, h]
    h0_self = jax.nn.relu(
        (batch.x_seed @ params.w1_self + params.b1)
    )
    h0 = jax.nn.relu(h0_self @ params.w2_self + agg0 @ params.w2_nbr + params.b2)
    return h0 @ params.w_out + params.b_out  # [b, n_classes]


def gcn_loss(params: GCNParams, batch: SubgraphBatch, use_kernel: bool = False):
    logits = gcn_forward(params, batch, use_kernel=use_kernel)
    logp = jax.nn.log_softmax(logits)
    nll = -jnp.take_along_axis(logp, batch.labels[:, None], axis=1)[:, 0]
    return nll.mean()
