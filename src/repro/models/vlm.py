"""Llama-3.2-Vision-11B text backbone: 40 decoder layers with a gated
cross-attention layer inserted every ``cross_attn_every`` layers (8 sites).

The modality frontend is a STUB per the assignment: ``input_specs()``
supplies precomputed patch embeddings [B, n_vision_tokens, d_vision]; this
module only projects them and cross-attends.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from ..core.config import ModelConfig
from . import layers as L
from .transformer import init_cache as _self_cache


def _n_sites(cfg: ModelConfig) -> int:
    return cfg.n_layers // cfg.cross_attn_every


def init_vlm(cfg: ModelConfig, key: jax.Array) -> dict:
    ks = jax.random.split(key, 6)
    n, sites = cfg.n_layers, _n_sites(cfg)
    return {
        "embed": L.init_embed(ks[0], cfg),
        "vproj": L.dense_init(ks[1], (cfg.d_vision, cfg.d_model)),
        "layers": {
            "attn": L.init_attn_stack(ks[2], cfg, n),
            "mlp": L.init_mlp_stack(ks[3], n, cfg.d_model, cfg.d_ff),
            "ln1": jnp.ones((n, cfg.d_model), jnp.float32),
            "ln2": jnp.ones((n, cfg.d_model), jnp.float32),
        },
        "cross": {
            "attn": L.init_attn_stack(ks[4], cfg, sites),
            "ln": jnp.ones((sites, cfg.d_model), jnp.float32),
            "gate": jnp.zeros((sites, 1), jnp.float32),   # tanh-gated, init 0
        },
    }


def _self_block(cfg, x, layer, pos, cache=None, cache_pos=None):
    h, new_cache = L.attn_forward(
        layer["attn"], L.rmsnorm(layer["ln1"], x, cfg.norm_eps), cfg,
        pos=pos, cache=cache, cache_pos=cache_pos,
    )
    x = x + h
    x = x + L.mlp_forward(layer["mlp"], L.rmsnorm(layer["ln2"], x, cfg.norm_eps))
    return L.shard_batch(x), new_cache


def _cross_block(cfg, x, cross_layer, vis, pos):
    h, _ = L.attn_forward(
        cross_layer["attn"], L.rmsnorm(cross_layer["ln"], x, cfg.norm_eps), cfg,
        pos=pos, causal=False, rope=False, kv_x=vis,
    )
    return x + jnp.tanh(cross_layer["gate"]).astype(x.dtype) * h


def forward_train(
    cfg: ModelConfig, params: dict, tokens: jax.Array, vision: jax.Array
) -> jax.Array:
    b, s = tokens.shape
    x = L.embed_tokens(params["embed"], tokens)
    vis = (vision.astype(x.dtype) @ params["vproj"].astype(x.dtype))
    vis = L.shard_batch(vis)
    pos = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    sites, ce = _n_sites(cfg), cfg.cross_attn_every
    grouped = jax.tree.map(
        lambda a: a.reshape((sites, ce) + a.shape[1:]), params["layers"]
    )

    def self_body(x, layer):
        out, _ = _self_block(cfg, x, layer, pos)
        return out, None

    self_body = L.maybe_remat(self_body, cfg)

    def group_body(x, xs):
        group, cross_layer = xs
        x, _ = lax.scan(self_body, x, group)
        x = _cross_block(cfg, x, cross_layer, vis, pos)
        return x, None

    x, _ = lax.scan(group_body, x, (grouped, params["cross"]))
    return L.lm_head(params["embed"], x, cfg)


def loss_fn(cfg, params, batch):
    logits = forward_train(cfg, params, batch["tokens"], batch["vision"])
    return L.lm_loss(logits, batch["labels"])


def init_cache(cfg: ModelConfig, batch: int, seq: int) -> dict:
    """Self KV caches for all 40 layers + per-site precomputed vision K/V
    (cross-attn keys are static per request)."""
    cache = _self_cache(cfg, batch, seq)
    sites = _n_sites(cfg)
    kvd = cfg.n_kv_heads * cfg.resolved_head_dim
    cache["vis_k"] = jnp.zeros((sites, batch, cfg.n_vision_tokens, kvd), jnp.bfloat16)
    cache["vis_v"] = jnp.zeros((sites, batch, cfg.n_vision_tokens, kvd), jnp.bfloat16)
    return cache


def forward_decode(cfg, params, cache, tokens, pos):
    b = tokens.shape[0]
    hd = cfg.resolved_head_dim
    x = L.embed_tokens(params["embed"], tokens)
    qpos = jnp.broadcast_to(pos[None, None], (b, 1))
    sites, ce = _n_sites(cfg), cfg.cross_attn_every
    grouped = jax.tree.map(
        lambda a: a.reshape((sites, ce) + a.shape[1:]), params["layers"]
    )
    kc = cache["k"].reshape((sites, ce) + cache["k"].shape[1:])
    vc = cache["v"].reshape((sites, ce) + cache["v"].shape[1:])

    def self_step(x, xs):
        layer, k1, v1 = xs
        out, ncache = _self_block(cfg, x, layer, qpos, cache=(k1, v1), cache_pos=pos)
        return out, ncache

    def group_body(x, xs):
        group, k_g, v_g, cross_layer, vk, vv = xs
        x, (k_n, v_n) = lax.scan(self_step, x, (group, k_g, v_g))
        # cross-attn against precomputed vision kv
        z = L.rmsnorm(cross_layer["ln"], x, cfg.norm_eps)
        q = (z @ cross_layer["attn"]["wq"].astype(x.dtype)).reshape(
            b, 1, cfg.n_heads, hd
        )
        kv = vk.reshape(b, -1, cfg.n_kv_heads, hd).astype(x.dtype)
        vv_ = vv.reshape(b, -1, cfg.n_kv_heads, hd).astype(x.dtype)
        att = L.gqa_attention(q, kv, vv_, causal=False)
        att = att.reshape(b, 1, -1) @ cross_layer["attn"]["wo"].astype(x.dtype)
        x = x + jnp.tanh(cross_layer["gate"]).astype(x.dtype) * att
        return x, (k_n, v_n)

    x, (k_new, v_new) = lax.scan(
        group_body, x,
        (grouped, kc, vc, params["cross"], cache["vis_k"], cache["vis_v"]),
    )
    new_cache = dict(cache)
    new_cache["k"] = k_new.reshape(cache["k"].shape)
    new_cache["v"] = v_new.reshape(cache["v"].shape)
    return L.lm_head(params["embed"], x, cfg)[:, 0], new_cache
