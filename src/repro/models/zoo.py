"""Architecture zoo: uniform build/init/loss/decode API over all assigned
architectures + the paper's GCN, plus the sharding-spec rules that map any
param/cache pytree onto the production mesh.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..core.config import ModelConfig, ShapeConfig
from . import deepseek, gcn, hybrid, moe, ssm, transformer, vlm, whisper
from . import layers as L


class ModelAPI(NamedTuple):
    cfg: ModelConfig
    init: Callable[[jax.Array], Any]
    loss: Callable[[Any, Any], jax.Array]
    decode: Optional[Callable]        # (params, cache, tokens, pos) -> (logits, cache)
    init_cache: Optional[Callable]    # (batch, seq) -> cache


_FAMILIES = {
    "dense": (transformer.init_lm, transformer.loss_fn,
              transformer.forward_decode, transformer.init_cache),
    "moe_qwen": (moe.init_qwen3_moe, moe.loss_fn,
                 moe.forward_decode, transformer.init_cache),
    "moe_deepseek": (deepseek.init_deepseek, deepseek.loss_fn,
                     deepseek.forward_decode, deepseek.init_cache),
    "ssm": (ssm.init_mamba2, ssm.loss_fn, ssm.forward_decode, ssm.init_cache),
    "hybrid": (hybrid.init_zamba2, hybrid.loss_fn,
               hybrid.forward_decode, hybrid.init_cache),
    "vlm": (vlm.init_vlm, vlm.loss_fn, vlm.forward_decode, vlm.init_cache),
    "audio": (whisper.init_whisper, whisper.loss_fn,
              whisper.forward_decode, whisper.init_cache),
}


def _family_key(cfg: ModelConfig) -> str:
    if cfg.family == "moe":
        return "moe_deepseek" if cfg.kv_lora_rank else "moe_qwen"
    return cfg.family


def build(cfg: ModelConfig) -> ModelAPI:
    if cfg.family == "gcn":
        return ModelAPI(
            cfg=cfg,
            init=lambda key: gcn.init_gcn(cfg, key),
            loss=lambda p, b: gcn.gcn_loss(p, b),
            decode=None,
            init_cache=None,
        )
    init_f, loss_f, dec_f, cache_f = _FAMILIES[_family_key(cfg)]
    return ModelAPI(
        cfg=cfg,
        init=lambda key: init_f(cfg, key),
        loss=lambda p, b: loss_f(cfg, p, b),
        decode=lambda p, c, t, pos: dec_f(cfg, p, c, t, pos),
        init_cache=lambda batch, seq: cache_f(cfg, batch, seq),
    )


# ------------------------------------------------------------ input specs -
def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """ShapeDtypeStruct stand-ins for every model input (dry-run contract:
    weak-type-correct, shardable, no device allocation)."""
    s = jax.ShapeDtypeStruct
    b = shape.global_batch
    if shape.kind == "train":
        out = {
            "tokens": s((b, shape.seq_len), jnp.int32),
            "labels": s((b, shape.seq_len), jnp.int32),
        }
        if cfg.family == "vlm":
            out["vision"] = s((b, cfg.n_vision_tokens, cfg.d_vision), jnp.float32)
        if cfg.family == "audio":
            out["frames"] = s((b, cfg.n_audio_frames, cfg.d_audio), jnp.float32)
        return out
    # decode / prefill-as-decode: one new token against a seq_len cache
    return {"tokens": s((b, 1), jnp.int32)}


def prefill_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """prefill_* shapes lower the full-sequence forward (no labels)."""
    s = jax.ShapeDtypeStruct
    b = shape.global_batch
    out = {"tokens": s((b, shape.seq_len), jnp.int32)}
    if cfg.family == "vlm":
        out["vision"] = s((b, cfg.n_vision_tokens, cfg.d_vision), jnp.float32)
    if cfg.family == "audio":
        out["frames"] = s((b, cfg.n_audio_frames, cfg.d_audio), jnp.float32)
    return out


def forward_logits(cfg: ModelConfig, params, batch: dict) -> jax.Array:
    """Full-sequence forward (prefill).  Dispatches per family."""
    fam = _family_key(cfg)
    if fam == "dense":
        return transformer.forward_train(cfg, params, batch["tokens"])
    if fam == "moe_qwen":
        return moe.forward_train(cfg, params, batch["tokens"])
    if fam == "moe_deepseek":
        return deepseek.forward_train(cfg, params, batch["tokens"])
    if fam == "ssm":
        return ssm.forward_train(cfg, params, batch["tokens"])
    if fam == "hybrid":
        return hybrid.forward_train(cfg, params, batch["tokens"])
    if fam == "vlm":
        return vlm.forward_train(cfg, params, batch["tokens"], batch["vision"])
    if fam == "audio":
        return whisper.forward_train(cfg, params, batch["tokens"], batch["frames"])
    raise ValueError(fam)


# --------------------------------------------------------- sharding rules -
def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1


def _dp_names(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def _dp_size(mesh: Mesh) -> int:
    n = 1
    for a in _dp_names(mesh):
        n *= _axis_size(mesh, a)
    return n


def param_pspec(path: str, shape: tuple, mesh: Mesh, fsdp: bool = True) -> P:
    """Sharding rule for one parameter leaf.

    * expert stacks [L?, E, D, F] -> E over 'model' (expert parallelism),
      D over 'data' (FSDP).
    * matrices [..., in, out]     -> out over 'model' (tensor parallelism),
      in over 'data' (FSDP / ZeRO-3).
    * vectors (norms, gates)      -> replicated.
    Params never shard over 'pod' (cross-pod = pure data parallelism; the
    gradient AllReduce is the only DCN traffic)."""
    m, d = _axis_size(mesh, "model"), _axis_size(mesh, "data")
    dims = [None] * len(shape)
    if len(shape) < 2:
        return P(*dims)
    is_expert = any(k in path for k in ("wg", "wu", "wd")) and "moe" in path and len(shape) >= 3
    if is_expert and shape[-3] % m == 0:
        dims[-3] = "model"
        if fsdp and shape[-2] % d == 0:
            dims[-2] = "data"
        return P(*dims)
    if shape[-1] % m == 0:
        dims[-1] = "model"
    if fsdp and shape[-2] % d == 0:
        dims[-2] = "data"
    return P(*dims)


def param_pspecs(cfg: ModelConfig, params_shape, mesh: Mesh):
    """Map a (possibly abstract) param tree to PartitionSpecs."""
    flat = jax.tree_util.tree_flatten_with_path(params_shape)[0]
    treedef = jax.tree.structure(params_shape)
    specs = []
    for path, leaf in flat:
        pstr = "/".join(str(getattr(k, "key", k)) for k in path)
        specs.append(param_pspec(pstr, leaf.shape, mesh, fsdp=cfg.fsdp_params))
    return jax.tree.unflatten(treedef, specs)


def cache_pspec(path: str, shape: tuple, mesh: Mesh, batch_axis: int) -> P:
    """KV/SSM-cache rule: shard batch over the dp axes when divisible;
    otherwise (long_500k, batch=1) shard the sequence axis over 'data'.
    The trailing feature axis shards over 'model' when divisible."""
    m = _axis_size(mesh, "model")
    dp = _dp_size(mesh)
    dims: list = [None] * len(shape)
    if shape[-1] % m == 0:
        dims[-1] = "model"
    if batch_axis < len(shape) and shape[batch_axis] % dp == 0 and shape[batch_axis] > 1:
        dims[batch_axis] = _dp_names(mesh)
    elif len(shape) >= 3:
        seq_axis = batch_axis + 1
        d = _axis_size(mesh, "data")
        if dims[seq_axis] is None and shape[seq_axis] % d == 0 and shape[seq_axis] >= d:
            dims[seq_axis] = "data"
    return P(*dims)


def cache_pspecs(cfg: ModelConfig, cache_shape, mesh: Mesh):
    flat = jax.tree_util.tree_flatten_with_path(cache_shape)[0]
    treedef = jax.tree.structure(cache_shape)
    specs = []
    for path, leaf in flat:
        pstr = "/".join(str(getattr(k, "key", k)) for k in path)
        batch_axis = 0 if pstr == "enc" else 1   # whisper enc cache is [B, T, D]
        specs.append(cache_pspec(pstr, leaf.shape, mesh, batch_axis))
    return jax.tree.unflatten(treedef, specs)


def batch_pspecs(cfg: ModelConfig, batch_shape, mesh: Mesh):
    """Inputs shard over the dp axes on their leading (batch) dim, unless
    batch == 1 (long_500k), which replicates."""
    dp = _dp_size(mesh)

    def one(leaf):
        dims = [None] * len(leaf.shape)
        if leaf.shape[0] % dp == 0 and leaf.shape[0] > 1:
            dims[0] = _dp_names(mesh)
        return P(*dims)

    return jax.tree.map(one, batch_shape)


def to_shardings(mesh: Mesh, pspecs):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        pspecs,
        is_leaf=lambda x: isinstance(x, P),
    )
