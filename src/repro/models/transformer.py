"""Dense decoder-only LM (llama family: smollm-135m/360m, stablelm-12b,
llama3-405b).  Layer params are stacked [L, ...] and scanned."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from ..core.config import ModelConfig
from . import layers as L


def init_lm(cfg: ModelConfig, key: jax.Array) -> dict:
    ks = jax.random.split(key, 4)
    n = cfg.n_layers
    return {
        "embed": L.init_embed(ks[0], cfg),
        "layers": {
            "attn": L.init_attn_stack(ks[1], cfg, n),
            "mlp": L.init_mlp_stack(ks[2], n, cfg.d_model, cfg.d_ff),
            "ln1": jnp.ones((n, cfg.d_model), jnp.float32),
            "ln2": jnp.ones((n, cfg.d_model), jnp.float32),
        },
    }


def _block(cfg: ModelConfig, x, layer, pos, cache=None, cache_pos=None):
    h, new_cache = L.attn_forward(
        layer["attn"], L.rmsnorm(layer["ln1"], x, cfg.norm_eps), cfg,
        pos=pos, cache=cache, cache_pos=cache_pos,
    )
    x = x + h
    x = x + L.mlp_forward(layer["mlp"], L.rmsnorm(layer["ln2"], x, cfg.norm_eps))
    return L.shard_batch(x), new_cache


def forward_train(cfg: ModelConfig, params: dict, tokens: jax.Array) -> jax.Array:
    b, s = tokens.shape
    x = L.embed_tokens(params["embed"], tokens)
    pos = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))

    def body(x, layer):
        out, _ = _block(cfg, x, layer, pos)
        return out, None

    body = L.maybe_remat(body, cfg)
    if cfg.scan_layers:
        x, _ = lax.scan(body, x, params["layers"])
    else:
        for i in range(cfg.n_layers):
            layer = jax.tree.map(lambda a: a[i], params["layers"])
            x, _ = body(x, layer)
    return L.lm_head(params["embed"], x, cfg)


def loss_fn(cfg: ModelConfig, params: dict, batch: dict) -> jax.Array:
    logits = forward_train(cfg, params, batch["tokens"])
    return L.lm_loss(logits, batch["labels"])


def init_cache(cfg: ModelConfig, batch: int, seq: int) -> dict:
    kvd = cfg.n_kv_heads * cfg.resolved_head_dim
    shape = (cfg.n_layers, batch, seq, kvd)
    return {
        "k": jnp.zeros(shape, jnp.bfloat16),
        "v": jnp.zeros(shape, jnp.bfloat16),
    }


def forward_decode(
    cfg: ModelConfig, params: dict, cache: dict, tokens: jax.Array, pos: jax.Array
):
    """One decode step.  tokens [B, 1]; pos scalar (current length).
    Returns (logits [B, V], new_cache)."""
    b = tokens.shape[0]
    x = L.embed_tokens(params["embed"], tokens)
    qpos = jnp.broadcast_to(pos[None, None], (b, 1))

    def body(x, xs):
        layer, kc, vc = xs
        out, new_cache = _block(cfg, x, layer, qpos, cache=(kc, vc), cache_pos=pos)
        return out, new_cache

    if cfg.scan_layers:
        x, (k_new, v_new) = lax.scan(body, x, (params["layers"], cache["k"], cache["v"]))
    else:
        ks, vs = [], []
        for i in range(cfg.n_layers):
            xs = jax.tree.map(lambda a: a[i], (params["layers"], cache["k"], cache["v"]))
            x, (kn, vn) = body(x, xs)
            ks.append(kn); vs.append(vn)
        k_new, v_new = jnp.stack(ks), jnp.stack(vs)
    logits = L.lm_head(params["embed"], x, cfg)[:, 0]
    return logits, {"k": k_new, "v": v_new}
