"""Whisper-small backbone: 12-layer encoder over audio frames + 12-layer
decoder with cross-attention.  The conv frontend is a STUB per the
assignment — ``input_specs()`` supplies precomputed frame embeddings
[B, n_audio_frames, d_audio]."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from ..core.config import ModelConfig
from . import layers as L


def init_whisper(cfg: ModelConfig, key: jax.Array) -> dict:
    ks = jax.random.split(key, 8)
    ne, nd = cfg.n_encoder_layers, cfg.n_layers
    d = cfg.d_model
    return {
        "embed": L.init_embed(ks[0], cfg),
        "aproj": L.dense_init(ks[1], (cfg.d_audio, d)),
        "encoder": {
            "attn": L.init_attn_stack(ks[2], cfg, ne),
            "mlp": L.init_mlp_stack(ks[3], ne, d, cfg.d_ff),
            "ln1": jnp.ones((ne, d), jnp.float32),
            "ln2": jnp.ones((ne, d), jnp.float32),
        },
        "decoder": {
            "attn": L.init_attn_stack(ks[4], cfg, nd),
            "xattn": L.init_attn_stack(ks[5], cfg, nd),
            "mlp": L.init_mlp_stack(ks[6], nd, d, cfg.d_ff),
            "ln1": jnp.ones((nd, d), jnp.float32),
            "lnx": jnp.ones((nd, d), jnp.float32),
            "ln2": jnp.ones((nd, d), jnp.float32),
        },
    }


def encode(cfg: ModelConfig, params: dict, frames: jax.Array) -> jax.Array:
    """frames [B, T_a, d_audio] -> encoder states [B, T_a, d_model]."""
    x = frames.astype(L.COMPUTE_DTYPE) @ params["aproj"].astype(L.COMPUTE_DTYPE)
    x = L.shard_batch(x)
    b, t, _ = x.shape
    pos = jnp.broadcast_to(jnp.arange(t)[None, :], (b, t))

    def body(x, layer):
        h, _ = L.attn_forward(
            layer["attn"], L.rmsnorm(layer["ln1"], x, cfg.norm_eps), cfg,
            pos=pos, causal=False,
        )
        x = x + h
        x = x + L.mlp_forward(layer["mlp"], L.rmsnorm(layer["ln2"], x, cfg.norm_eps))
        return L.shard_batch(x), None

    body = L.maybe_remat(body, cfg)
    x, _ = lax.scan(body, x, params["encoder"])
    return x


def _dec_block(cfg, x, layer, enc, pos, cache=None, cache_pos=None):
    h, new_cache = L.attn_forward(
        layer["attn"], L.rmsnorm(layer["ln1"], x, cfg.norm_eps), cfg,
        pos=pos, cache=cache, cache_pos=cache_pos,
    )
    x = x + h
    h, _ = L.attn_forward(
        layer["xattn"], L.rmsnorm(layer["lnx"], x, cfg.norm_eps), cfg,
        pos=pos, causal=False, rope=False, kv_x=enc,
    )
    x = x + h
    x = x + L.mlp_forward(layer["mlp"], L.rmsnorm(layer["ln2"], x, cfg.norm_eps))
    return L.shard_batch(x), new_cache


def forward_train(
    cfg: ModelConfig, params: dict, tokens: jax.Array, frames: jax.Array
) -> jax.Array:
    enc = encode(cfg, params, frames)
    b, s = tokens.shape
    x = L.embed_tokens(params["embed"], tokens)
    pos = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))

    def body(x, layer):
        out, _ = _dec_block(cfg, x, layer, enc, pos)
        return out, None

    body = L.maybe_remat(body, cfg)
    x, _ = lax.scan(body, x, params["decoder"])
    return L.lm_head(params["embed"], x, cfg)


def loss_fn(cfg, params, batch):
    logits = forward_train(cfg, params, batch["tokens"], batch["frames"])
    return L.lm_loss(logits, batch["labels"])


def init_cache(cfg: ModelConfig, batch: int, seq: int) -> dict:
    kvd = cfg.n_kv_heads * cfg.resolved_head_dim
    nd = cfg.n_layers
    return {
        "k": jnp.zeros((nd, batch, seq, kvd), jnp.bfloat16),
        "v": jnp.zeros((nd, batch, seq, kvd), jnp.bfloat16),
        # encoder output is fixed per request; decode cross-attends to it
        "enc": jnp.zeros((batch, cfg.n_audio_frames, cfg.d_model), jnp.bfloat16),
    }


def forward_decode(cfg, params, cache, tokens, pos):
    b = tokens.shape[0]
    x = L.embed_tokens(params["embed"], tokens)
    qpos = jnp.broadcast_to(pos[None, None], (b, 1))
    enc = cache["enc"].astype(x.dtype)

    def body(x, xs):
        layer, kc, vc = xs
        out, ncache = _dec_block(
            cfg, x, layer, enc, qpos, cache=(kc, vc), cache_pos=pos
        )
        return out, ncache

    x, (k_new, v_new) = lax.scan(
        body, x, (params["decoder"], cache["k"], cache["v"])
    )
    logits = L.lm_head(params["embed"], x, cfg)[:, 0]
    return logits, {"k": k_new, "v": v_new, "enc": cache["enc"]}
