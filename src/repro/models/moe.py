"""Mixture-of-Experts layer (sort-based capacity dispatch) and the
qwen3-moe-30b-a3b model (48L all-MoE, 128 experts top-8, GQA attention).

Dispatch is the production-standard capacity-factor scheme (GShard/Switch
lineage): token->expert assignments are sorted by expert, each token takes
its rank within its expert's queue, ranks beyond capacity are dropped, and
the [E, C, D] buffer is processed with batched per-expert matmuls (einsum
on the expert-sharded axis — expert parallelism over the mesh 'model'
axis).  Static shapes throughout; drop rate is a benchmark metric.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from ..core.config import ModelConfig
from . import layers as L
from .transformer import init_cache  # same cache layout (GQA)

CAPACITY_FACTOR = 1.25

MOE_IMPL = "gather"   # "gather" (jit-level scatter) | "ep_a2a" (shard_map EP)


def set_moe_impl(impl: str) -> None:
    global MOE_IMPL
    MOE_IMPL = impl


def init_moe_mlp(key, cfg: ModelConfig, n: int) -> dict:
    e, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff_expert
    ks = jax.random.split(key, 5)
    p = {
        "router": L.stack_init(ks[0], n, (d, e), scale=0.006),
        "wg": L.stack_init(ks[1], n, (e, d, f)),
        "wu": L.stack_init(ks[2], n, (e, d, f)),
        "wd": L.stack_init(ks[3], n, (e, f, d)),
    }
    if cfg.n_shared_experts:
        p["shared"] = L.init_mlp_stack(
            ks[4], n, d, cfg.n_shared_experts * cfg.d_ff_expert
        )
    return p


def moe_forward_ep(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Expert-parallel MoE with explicit all-to-all dispatch (shard_map).

    Beyond-paper optimization (EXPERIMENTS.md §Perf): the jit-level scatter
    formulation makes the SPMD partitioner all-gather the full token set
    onto every expert shard (collective-dominated cells).  Here each device
    routes ONLY ITS OWN tokens to the owning expert shard along the 'model'
    axis — two all_to_alls of [T_local*K, D] replace per-layer full-token
    all-gathers (~model_axis x less ICI traffic).

    Per-device protocol (classic GShard EP, same machinery as the
    generation layer's `fetch_rows` shuffle):
      1. route:   top-k experts per local token; destination shard =
                  expert // E_local.
      2. a2a out: slot tokens into per-destination send buffers
                  (capacity-bounded, drops counted like `moe_forward`).
      3. compute: sort received tokens by local expert, batched per-expert
                  einsum [E_loc, C, D] x [E_loc, D, F].
      4. a2a back + weighted combine.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    mesh = L.get_mesh()
    assert mesh is not None and "model" in mesh.axis_names
    b, s, d = x.shape
    m = mesh.shape["model"]
    e, k = cfg.n_experts, cfg.top_k
    e_loc = e // m
    dpa = L.dp_axes()

    def body(wr, wg, wu, wd, xb):
        # xb [b_loc, s_loc, D] — tokens of this device; experts e_loc mine
        bl, sl, _ = xb.shape
        tl = bl * sl
        xf = xb.reshape(tl, d)
        logits = (xf @ wr.astype(xf.dtype)).astype(jnp.float32)      # [Tl, E]
        probs = jax.nn.softmax(logits, axis=-1)
        topv, topi = lax.top_k(probs, k)
        topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)
        fe = topi.reshape(-1)                                        # [Tl*K]
        fw = topv.reshape(-1).astype(xf.dtype)
        ftok = jnp.arange(tl * k, dtype=jnp.int32) // k
        dest = fe // e_loc                                           # [Tl*K]
        cap = max(int(tl * k / m * 2.0) + 8, 8)
        order = jnp.argsort(dest)
        sd = dest[order]
        first = jnp.searchsorted(sd, sd, side="left")
        slot = jnp.arange(tl * k, dtype=jnp.int32) - first
        ok = slot < cap
        # overflow slots are pushed OUT OF BOUNDS so mode="drop" discards
        # them (clipping would overwrite a valid slot)
        slot_c = jnp.where(ok, slot, cap)
        send_x = jnp.zeros((m, cap, d), xf.dtype).at[sd, slot_c].set(
            xf[ftok[order]], mode="drop")
        send_e = jnp.zeros((m, cap), jnp.int32).at[sd, slot_c].set(
            fe[order] % e_loc, mode="drop")
        send_m = jnp.zeros((m, cap), xf.dtype).at[sd, slot_c].set(
            jnp.ones((), xf.dtype), mode="drop")
        a2a = lambda t: lax.all_to_all(t, "model", split_axis=0,
                                       concat_axis=0, tiled=True)
        rx = a2a(send_x).reshape(m * cap, d)      # tokens sent to my experts
        re_ = a2a(send_e).reshape(m * cap)
        rm = a2a(send_m).reshape(m * cap)
        # sort by local expert (invalid slots keyed AFTER all experts so the
        # sort key stays monotone — searchsorted needs a sorted array)
        c2 = max(int(m * cap / e_loc * 2.0) + 8, 8)
        key2 = re_ + (1 - rm.astype(jnp.int32)) * e_loc
        order2 = jnp.argsort(key2)
        sk2 = key2[order2]                           # sorted, invalid == e_loc
        first2 = jnp.searchsorted(sk2, sk2, side="left")
        slot2 = jnp.arange(m * cap, dtype=jnp.int32) - first2
        ok2 = jnp.logical_and(slot2 < c2, sk2 < e_loc)
        slot2c = jnp.where(ok2, slot2, c2)
        se2 = jnp.clip(sk2, 0, e_loc - 1)
        buf = jnp.zeros((e_loc, c2, d), xf.dtype).at[se2, slot2c].set(
            rx[order2], mode="drop")
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wg.astype(xf.dtype)))
        h = h * jnp.einsum("ecd,edf->ecf", buf, wu.astype(xf.dtype))
        out = jnp.einsum("ecf,efd->ecd", h, wd.astype(xf.dtype))
        # un-bucket back to recv order, a2a home, combine
        back = jnp.zeros((m * cap, d), xf.dtype).at[order2].set(
            out[se2, jnp.clip(slot2c, 0, c2 - 1)]
            * ok2.astype(xf.dtype)[:, None])
        home = a2a(back.reshape(m, cap, d)).reshape(m, cap, d)
        got = (home[sd, jnp.clip(slot_c, 0, cap - 1)]
               * ok.astype(xf.dtype)[:, None])        # sorted order
        contrib = jnp.zeros((tl * k, d), xf.dtype).at[order].set(got)
        y = jnp.zeros((tl, d), xf.dtype).at[ftok].add(
            contrib * fw[:, None])
        return y.reshape(bl, sl, d)

    y = shard_map(
        body,
        mesh=mesh,
        in_specs=(P(), P("model", None, None), P("model", None, None),
                  P("model", None, None), P(dpa, "model", None)),
        out_specs=P(dpa, "model", None),
        check_rep=False,
    )(p["router"], p["wg"], p["wu"], p["wd"], x)
    if "shared" in p:
        y = y + L.mlp_forward(p["shared"], x.reshape(b * s, d)).reshape(b, s, d)
    return y


def moe_forward(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    mesh = L.get_mesh()
    if (MOE_IMPL == "ep_a2a" and mesh is not None
            and "model" in mesh.axis_names
            and cfg.n_experts % mesh.shape["model"] == 0
            and x.shape[1] % mesh.shape["model"] == 0):
        return moe_forward_ep(p, x, cfg)
    b, s, d = x.shape
    t = b * s
    k = cfg.top_k
    e = cfg.n_experts
    cap = max(int(t * k / e * CAPACITY_FACTOR), 1)
    xf = x.reshape(t, d)

    logits = (xf @ p["router"].astype(x.dtype)).astype(jnp.float32)   # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = lax.top_k(probs, k)                                   # [T, K]
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)

    flat_e = topi.reshape(-1)                       # [T*K]
    flat_w = topv.reshape(-1)
    flat_tok = jnp.arange(t * k, dtype=jnp.int32) // k
    order = jnp.argsort(flat_e)
    se = flat_e[order]
    first = jnp.searchsorted(se, se, side="left")
    rank = jnp.arange(t * k, dtype=jnp.int32) - first
    keep = rank < cap
    rank_c = jnp.clip(rank, 0, cap - 1)
    src = xf[flat_tok[order]] * keep[:, None].astype(x.dtype)
    buf = jnp.zeros((e, cap, d), x.dtype).at[se, rank_c].set(src, mode="drop")
    buf = L.shard(buf, "model", None, None)          # expert parallelism

    wg = p["wg"].astype(x.dtype)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wg))
    h = h * jnp.einsum("ecd,edf->ecf", buf, p["wu"].astype(x.dtype))
    out = jnp.einsum("ecf,efd->ecd", h, p["wd"].astype(x.dtype))
    out = L.shard(out, "model", None, None)

    contrib = out[se, rank_c] * (flat_w[order] * keep).astype(x.dtype)[:, None]
    y = jnp.zeros((t, d), x.dtype).at[flat_tok[order]].add(contrib)
    if "shared" in p:
        y = y + L.mlp_forward(p["shared"], xf)
    return y.reshape(b, s, d)


def moe_drop_rate(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Fraction of assignments dropped by capacity (benchmark metric)."""
    b, s, d = x.shape
    t = b * s
    cap = max(int(t * cfg.top_k / cfg.n_experts * CAPACITY_FACTOR), 1)
    logits = (x.reshape(t, d) @ p["router"].astype(x.dtype)).astype(jnp.float32)
    _, topi = lax.top_k(jax.nn.softmax(logits, -1), cfg.top_k)
    flat_e = topi.reshape(-1)
    se = jnp.sort(flat_e)
    rank = jnp.arange(t * cfg.top_k) - jnp.searchsorted(se, se, side="left")
    return (rank >= cap).mean()


# ------------------------------------------------------- qwen3-moe model --
def init_qwen3_moe(cfg: ModelConfig, key: jax.Array) -> dict:
    ks = jax.random.split(key, 4)
    n = cfg.n_layers
    return {
        "embed": L.init_embed(ks[0], cfg),
        "layers": {
            "attn": L.init_attn_stack(ks[1], cfg, n),
            "moe": init_moe_mlp(ks[2], cfg, n),
            "ln1": jnp.ones((n, cfg.d_model), jnp.float32),
            "ln2": jnp.ones((n, cfg.d_model), jnp.float32),
        },
    }


def _block(cfg, x, layer, pos, cache=None, cache_pos=None):
    h, new_cache = L.attn_forward(
        layer["attn"], L.rmsnorm(layer["ln1"], x, cfg.norm_eps), cfg,
        pos=pos, cache=cache, cache_pos=cache_pos,
    )
    x = x + h
    x = x + moe_forward(layer["moe"], L.rmsnorm(layer["ln2"], x, cfg.norm_eps), cfg)
    return L.shard_batch(x), new_cache


def forward_train(cfg: ModelConfig, params: dict, tokens: jax.Array) -> jax.Array:
    b, s = tokens.shape
    x = L.embed_tokens(params["embed"], tokens)
    pos = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))

    def body(x, layer):
        out, _ = _block(cfg, x, layer, pos)
        return out, None

    body = L.maybe_remat(body, cfg)
    x, _ = lax.scan(body, x, params["layers"])
    return L.lm_head(params["embed"], x, cfg)


def loss_fn(cfg: ModelConfig, params: dict, batch: dict) -> jax.Array:
    return L.lm_loss(forward_train(cfg, params, batch["tokens"]), batch["labels"])


def forward_decode(cfg, params, cache, tokens, pos):
    b = tokens.shape[0]
    x = L.embed_tokens(params["embed"], tokens)
    qpos = jnp.broadcast_to(pos[None, None], (b, 1))

    def body(x, xs):
        layer, kc, vc = xs
        out, new_cache = _block(cfg, x, layer, qpos, cache=(kc, vc), cache_pos=pos)
        return out, new_cache

    x, (k_new, v_new) = lax.scan(body, x, (params["layers"], cache["k"], cache["v"]))
    return L.lm_head(params["embed"], x, cfg)[:, 0], {"k": k_new, "v": v_new}
