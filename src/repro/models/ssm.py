"""Mamba-2 (SSD — state-space duality) backbone: mamba2-1.3b, and the block
reused by the zamba2 hybrid.

Train path uses the CHUNKED SSD form in pure jnp (XLA-visible FLOPs, shards
over the mesh; the Pallas `ssd_scan` kernel is the TPU hot-path variant,
selected with cfg.use_flash_attention? no — with use_kernel at the op site).
Decode path is the O(1)-state recurrence — this is why mamba2/zamba2 are the
two archs that RUN long_500k (DESIGN.md §4).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from ..core.config import ModelConfig
from . import layers as L


def _dims(cfg: ModelConfig):
    d_in = cfg.ssm_expand * cfg.d_model
    p = cfg.ssm_head_dim or 64
    h = cfg.ssm_heads or d_in // p
    return d_in, h, p, cfg.ssm_state


def init_mamba_stack(key, cfg: ModelConfig, n: int) -> dict:
    d = cfg.d_model
    d_in, h, p, nstate = _dims(cfg)
    ch = d_in + 2 * nstate
    ks = jax.random.split(key, 6)
    return {
        "w_in": L.stack_init(ks[0], n, (d, 2 * d_in + 2 * nstate + h)),
        "conv_k": L.stack_init(ks[1], n, (cfg.conv_width, ch), scale=0.5),
        "a_log": jnp.zeros((n, h), jnp.float32),          # a = -exp(a_log) = -1
        "d_skip": jnp.ones((n, h), jnp.float32),
        "dt_bias": jnp.zeros((n, h), jnp.float32),
        "w_out": L.stack_init(ks[2], n, (d_in, d)),
        "ln": jnp.ones((n, d), jnp.float32),
    }


def ssd_chunked(x, dt, a, bm, cm, chunk: int):
    """Chunked SSD, pure jnp (same math as kernels/ssd_scan.py).

    x [B,L,H,P], dt [B,L,H] (>0), a [H] (<0), bm/cm [B,L,N] -> y [B,L,H,P]."""
    bsz, l, h, p = x.shape
    n = bm.shape[-1]
    q = min(chunk, l)
    assert l % q == 0
    nc = l // q
    xr = x.reshape(bsz, nc, q, h, p)
    dtr = dt.reshape(bsz, nc, q, h)
    br = bm.reshape(bsz, nc, q, n)
    cr = cm.reshape(bsz, nc, q, n)

    adt = a[None, None, None, :] * dtr                     # [B,NC,Q,H]
    cum = jnp.cumsum(adt, axis=2)
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]    # [B,NC,Q,Q,H]
    ii = jnp.arange(q)
    tri = (ii[:, None] >= ii[None, :])[None, None, :, :, None]
    l_mat = jnp.where(tri, jnp.exp(seg) * dtr[:, :, None, :, :], 0.0)
    scores = jnp.einsum("bnqc,bnkc->bnqk", cr, br)[..., None] * l_mat
    y = jnp.einsum("bnqkh,bnkhp->bnqhp", scores, xr)
    # chunk state summaries and inter-chunk associative scan
    w = dtr * jnp.exp(cum[:, :, -1:, :] - cum)             # [B,NC,Q,H]
    s_c = jnp.einsum("bnqhp,bnqk,bnqh->bnhpk", xr, br, w)  # [B,NC,H,P,N]
    total = jnp.exp(cum[:, :, -1, :])                      # [B,NC,H]

    def compose(u, v):
        (t1, s1), (t2, s2) = u, v
        return t1 * t2, s1 * t2[..., None, None] + s2

    _, st_sc = lax.associative_scan(compose, (total, s_c), axis=1)
    # state BEFORE chunk c = scan result of chunk c-1 (exclusive shift)
    st_prev = jnp.concatenate(
        [jnp.zeros_like(st_sc[:, :1]), st_sc[:, :-1]], axis=1
    )
    y = y + jnp.einsum(
        "bnqk,bnqh,bnhpk->bnqhp", cr, jnp.exp(cum), st_prev
    )
    return y.reshape(bsz, l, h, p)


def _causal_conv(x: jax.Array, k: jax.Array) -> jax.Array:
    """Depthwise causal conv: x [B,L,C], k [W,C]."""
    w = k.shape[0]
    out = x * k[-1]
    for i in range(1, w):
        shifted = jnp.pad(x, ((0, 0), (i, 0), (0, 0)))[:, : x.shape[1]]
        out = out + shifted * k[-1 - i]
    return out


def mamba_train(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """One mamba2 block over a full sequence.  x [B,L,D]."""
    bsz, l, d = x.shape
    d_in, h, pdim, n = _dims(cfg)
    z_all = x @ p["w_in"].astype(x.dtype)                   # [B,L,2d_in+2N+H]
    z, xc, bmat, cmat, dt = jnp.split(
        z_all, [d_in, 2 * d_in, 2 * d_in + n, 2 * d_in + 2 * n], axis=-1
    )
    conv_in = jnp.concatenate([xc, bmat, cmat], axis=-1)
    conv_out = jax.nn.silu(_causal_conv(conv_in, p["conv_k"].astype(x.dtype)))
    xc, bmat, cmat = jnp.split(conv_out, [d_in, d_in + n], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])
    xh = xc.reshape(bsz, l, h, pdim)
    y = ssd_chunked(
        xh.astype(jnp.float32), dt, a,
        bmat.astype(jnp.float32), cmat.astype(jnp.float32), cfg.ssm_chunk,
    ).astype(x.dtype)
    y = y + xh * p["d_skip"].astype(x.dtype)[None, None, :, None]
    y = (y.reshape(bsz, l, d_in) * jax.nn.silu(z))
    return y @ p["w_out"].astype(x.dtype)


def mamba_decode(p: dict, x: jax.Array, cfg: ModelConfig, state: dict):
    """One-token recurrent step.  x [B,1,D]; state = {"ssm" [B,H,P,N],
    "conv" [B,W-1,C]}.  Cost independent of history length."""
    bsz, _, d = x.shape
    d_in, h, pdim, n = _dims(cfg)
    z_all = (x[:, 0] @ p["w_in"].astype(x.dtype))
    z, xc, bmat, cmat, dt = jnp.split(
        z_all, [d_in, 2 * d_in, 2 * d_in + n, 2 * d_in + 2 * n], axis=-1
    )
    conv_in = jnp.concatenate([xc, bmat, cmat], axis=-1)     # [B, C]
    hist = jnp.concatenate([state["conv"], conv_in[:, None]], axis=1)  # [B,W,C]
    k = p["conv_k"].astype(x.dtype)
    conv_out = jax.nn.silu(jnp.einsum("bwc,wc->bc", hist, k))
    new_conv = hist[:, 1:]
    xc, bmat, cmat = jnp.split(conv_out, [d_in, d_in + n], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])          # [B,H]
    a = -jnp.exp(p["a_log"])
    xh = xc.reshape(bsz, h, pdim).astype(jnp.float32)
    decay = jnp.exp(a[None] * dt)                                        # [B,H]
    upd = dt[..., None, None] * (xh[..., None] * bmat.astype(jnp.float32)[:, None, None, :])
    ssm = state["ssm"] * decay[..., None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", ssm, cmat.astype(jnp.float32)).astype(x.dtype)
    y = y + xh.astype(x.dtype) * p["d_skip"].astype(x.dtype)[None, :, None]
    y = (y.reshape(bsz, d_in) * jax.nn.silu(z)) @ p["w_out"].astype(x.dtype)
    return y[:, None], {"ssm": ssm, "conv": new_conv}


# ---------------------------------------------------------------- model ---
def init_mamba2(cfg: ModelConfig, key: jax.Array) -> dict:
    ks = jax.random.split(key, 2)
    return {
        "embed": L.init_embed(ks[0], cfg),
        "layers": init_mamba_stack(ks[1], cfg, cfg.n_layers),
    }


def forward_train(cfg: ModelConfig, params: dict, tokens: jax.Array) -> jax.Array:
    x = L.embed_tokens(params["embed"], tokens)

    def body(x, layer):
        out = x + mamba_train(layer, L.rmsnorm(layer["ln"], x, cfg.norm_eps), cfg)
        return L.shard_batch(out), None

    body = L.maybe_remat(body, cfg)
    x, _ = lax.scan(body, x, params["layers"])
    return L.lm_head(params["embed"], x, cfg)


def loss_fn(cfg, params, batch):
    return L.lm_loss(forward_train(cfg, params, batch["tokens"]), batch["labels"])


def init_cache(cfg: ModelConfig, batch: int, seq: int) -> dict:
    """Recurrent state: O(1) in seq — `seq` is accepted for interface parity
    and ignored (the long_500k story)."""
    d_in, h, p, n = _dims(cfg)
    ch = d_in + 2 * n
    return {
        "ssm": jnp.zeros((cfg.n_layers, batch, h, p, n), jnp.float32),
        "conv": jnp.zeros((cfg.n_layers, batch, cfg.conv_width - 1, ch), jnp.bfloat16),
    }


def forward_decode(cfg, params, cache, tokens, pos):
    x = L.embed_tokens(params["embed"], tokens)

    def body(x, xs):
        layer, ssm, conv = xs
        h, new = mamba_decode(
            layer, L.rmsnorm(layer["ln"], x, cfg.norm_eps), cfg,
            {"ssm": ssm, "conv": conv.astype(x.dtype)},
        )
        return x + h, (new["ssm"], new["conv"].astype(jnp.bfloat16))

    x, (ssm_n, conv_n) = lax.scan(body, x, (params["layers"], cache["ssm"], cache["conv"]))
    return L.lm_head(params["embed"], x, cfg)[:, 0], {"ssm": ssm_n, "conv": conv_n}
