"""Shared building blocks for the architecture zoo.

Conventions:
  * params are plain nested dicts of jnp arrays (pytrees) — no framework.
  * per-layer params are STACKED on a leading [L] axis and consumed with
    ``lax.scan`` (keeps HLO size O(1) in depth; MaxText-style).
  * compute runs in bf16 (TPU MXU native), accumulation and softmax in f32;
    master params stay f32.
  * ``shard(x, spec)`` applies a sharding constraint when a mesh context is
    installed (launch code calls ``set_mesh``); it is a no-op in unit tests.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import NamedSharding, PartitionSpec as P

from ..core.config import ModelConfig
from ..kernels import ops as kops

_MESH = None
COMPUTE_DTYPE = jnp.bfloat16


def set_mesh(mesh) -> None:
    global _MESH
    _MESH = mesh


def get_mesh():
    return _MESH


def dp_axes():
    """Data-parallel axes: ('pod', 'data') on a multi-pod mesh."""
    if _MESH is not None and "pod" in _MESH.axis_names:
        return ("pod", "data")
    return ("data",)


def shard(x: jax.Array, *spec) -> jax.Array:
    if _MESH is None:
        return x
    return lax.with_sharding_constraint(x, NamedSharding(_MESH, P(*spec)))


SEQ_PARALLEL = False   # shard the residual stream's seq axis over 'model'


def set_seq_parallel(on: bool) -> None:
    global SEQ_PARALLEL
    SEQ_PARALLEL = on


def shard_batch(x: jax.Array) -> jax.Array:
    """Constrain leading axis to the data-parallel axes; with sequence
    parallelism on (Megatron-SP style, perf variant) the sequence axis of
    the [B, S, D] residual stream additionally shards over 'model', turning
    per-block activation all-gathers into reduce-scatter/all-gather pairs
    of 1/model_axis the volume."""
    if _MESH is None:
        return x
    if (SEQ_PARALLEL and x.ndim >= 3
            and x.shape[1] % _MESH.shape.get("model", 1) == 0):
        return shard(x, dp_axes(), "model", *(None,) * (x.ndim - 2))
    rest = (None,) * (x.ndim - 1)
    return shard(x, dp_axes(), *rest)


SHARD_HEADS = False   # tensor-parallel attention activations (perf variant)


def set_shard_heads(on: bool) -> None:
    global SHARD_HEADS
    SHARD_HEADS = on


def shard_heads(x: jax.Array, head_axis: int = 2) -> jax.Array:
    """Megatron-style TP: keep [B, S, H, Dh] activations sharded on the
    head axis over 'model' so per-head attention runs without gathering the
    full head dimension on every device.  No-op when heads don't divide the
    model axis or the variant is off."""
    if _MESH is None or not SHARD_HEADS:
        return x
    m = _MESH.shape.get("model", 1)
    if x.shape[head_axis] % m:
        return x
    spec = [None] * x.ndim
    spec[0] = dp_axes()
    spec[head_axis] = "model"
    return shard(x, *spec)


# ---------------------------------------------------------------- init ----
def dense_init(key, shape, scale: Optional[float] = None):
    scale = scale if scale is not None else 0.02
    return (jax.random.normal(key, shape) * scale).astype(jnp.float32)


def stack_init(key, n: int, shape, scale=None):
    return dense_init(key, (n,) + tuple(shape), scale)


# ------------------------------------------------------------- norm/rope --
def rmsnorm(w: jax.Array, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (w * (xf * lax.rsqrt(var + eps))).astype(x.dtype)


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, pos: jax.Array, theta: float) -> jax.Array:
    """x [..., L, H, Dh]; pos [..., L] (broadcastable int positions)."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                     # [Dh/2]
    angles = pos[..., None].astype(jnp.float32) * freqs  # [..., L, Dh/2]
    cos = jnp.cos(angles)[..., None, :]               # [..., L, 1, Dh/2]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# -------------------------------------------------------------- attention -
def init_attn(key, cfg: ModelConfig, cross: bool = False) -> dict:
    hd = cfg.resolved_head_dim
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    kv_src = cfg.d_audio if (cross and cfg.family == "audio") else d
    return {
        "wq": dense_init(ks[0], (d, cfg.n_heads * hd)),
        "wk": dense_init(ks[1], (kv_src if cross else d, cfg.n_kv_heads * hd)),
        "wv": dense_init(ks[2], (kv_src if cross else d, cfg.n_kv_heads * hd)),
        "wo": dense_init(ks[3], (cfg.n_heads * hd, d), scale=0.02 / max(cfg.n_layers, 1) ** 0.5),
    }


def init_attn_stack(key, cfg: ModelConfig, n: int) -> dict:
    hd = cfg.resolved_head_dim
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    return {
        "wq": stack_init(ks[0], n, (d, cfg.n_heads * hd)),
        "wk": stack_init(ks[1], n, (d, cfg.n_kv_heads * hd)),
        "wv": stack_init(ks[2], n, (d, cfg.n_kv_heads * hd)),
        "wo": stack_init(ks[3], n, (cfg.n_heads * hd, d)),
    }


ATTN_IMPL = "naive"   # "naive" | "chunked" — set by perf configs / dryrun


def set_attn_impl(impl: str) -> None:
    global ATTN_IMPL
    ATTN_IMPL = impl


def chunked_attention(
    q: jax.Array, k: jax.Array, v: jax.Array, causal: bool,
    block: int = 512,
) -> jax.Array:
    """Flash-style attention in pure jnp: iterate over query blocks so the
    [B, H, Lq, Lk] score matrix never materializes (peak activation
    [B, H, block, Lk] — Lq/block x smaller).  XLA-visible FLOPs, shards
    like the naive path; the Pallas `flash_attention` kernel is the TPU
    hot-path twin.  The loop body is rematerialized in the backward pass."""
    b, lq, hq, dh = q.shape
    hkv, lk = k.shape[2], k.shape[1]
    group = hq // hkv
    blk = min(block, lq)
    if lq % blk:
        blk = lq  # fallback: irregular sizes use one block
    nb = lq // blk
    qb = q.reshape(b, nb, blk, hkv, group, dh)
    scale = 1.0 / (dh ** 0.5)

    @jax.checkpoint
    def one_block(args):
        qi, start = args
        logits = jnp.einsum("bqhgd,bkhd->bhgqk", qi, k).astype(jnp.float32)
        logits *= scale
        if causal:
            rows = start + jnp.arange(blk)[:, None] + (lk - lq)
            cols = jnp.arange(lk)[None, :]
            logits = jnp.where(rows >= cols, logits, -1e30)
        p = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
        return jnp.einsum("bhgqk,bkhd->bqhgd", p, v)

    starts = jnp.arange(nb) * blk
    out = lax.map(one_block, (jnp.moveaxis(qb, 1, 0), starts))  # [nb, b, blk, ...]
    out = jnp.moveaxis(out, 0, 1).reshape(b, lq, hq, v.shape[-1])
    return out


def gqa_attention(
    q: jax.Array,   # [B, Lq, Hq, Dh]
    k: jax.Array,   # [B, Lk, Hkv, Dh]
    v: jax.Array,
    causal: bool,
    use_flash: bool = False,
    kv_valid_len: Optional[jax.Array] = None,   # decode: valid cache length
) -> jax.Array:
    b, lq, hq, dh = q.shape
    hkv = k.shape[2]
    if use_flash and kv_valid_len is None and lq % 128 == 0 and k.shape[1] % 128 == 0:
        out = kops.flash_attention(
            q.swapaxes(1, 2), k.swapaxes(1, 2), v.swapaxes(1, 2),
            causal=causal, use_kernel=True,
        )
        return out.swapaxes(1, 2)
    if ATTN_IMPL == "chunked" and kv_valid_len is None and lq > 512:
        return chunked_attention(q, k, v, causal)
    group = hq // hkv
    qg = q.reshape(b, lq, hkv, group, dh)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k).astype(jnp.float32)
    logits *= 1.0 / (dh ** 0.5)
    lk = k.shape[1]
    if causal and lq > 1:
        qi = jnp.arange(lq)[:, None] + (lk - lq)
        ki = jnp.arange(lk)[None, :]
        logits = jnp.where(qi >= ki, logits, -1e30)
    if kv_valid_len is not None:
        ki = jnp.arange(lk)
        mask = ki[None, :] < kv_valid_len
        logits = jnp.where(mask[:, None, None, None, :], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, v)
    return out.reshape(b, lq, hq, v.shape[-1])


def attn_forward(
    p: dict, x: jax.Array, cfg: ModelConfig, *,
    pos: jax.Array, causal: bool = True, rope: bool = True,
    kv_x: Optional[jax.Array] = None,
    cache: Optional[tuple] = None,         # (k_cache, v_cache) [B, S, Hkv*Dh]
    cache_pos: Optional[jax.Array] = None, # scalar write position
):
    """Self- or cross-attention with optional KV cache (decode).

    Returns (out, new_cache)."""
    b, l, d = x.shape
    hd = cfg.resolved_head_dim
    src = x if kv_x is None else kv_x
    q = shard_heads((x @ p["wq"].astype(x.dtype)).reshape(b, l, cfg.n_heads, hd))
    k = shard_heads((src @ p["wk"].astype(x.dtype)).reshape(b, src.shape[1], cfg.n_kv_heads, hd))
    v = shard_heads((src @ p["wv"].astype(x.dtype)).reshape(b, src.shape[1], cfg.n_kv_heads, hd))
    if rope:
        q = apply_rope(q, pos, cfg.rope_theta)
        kpos = pos if cache is None else cache_pos[None, None]
        k = apply_rope(k, jnp.broadcast_to(kpos, (b, k.shape[1])), cfg.rope_theta)
    new_cache = None
    kv_valid = None
    if cache is not None:
        kc, vc = cache                                  # [B, S, Hkv*Dh]
        s = kc.shape[1]
        kc = lax.dynamic_update_slice_in_dim(
            kc, k.reshape(b, l, -1).astype(kc.dtype), cache_pos, axis=1
        )
        vc = lax.dynamic_update_slice_in_dim(
            vc, v.reshape(b, l, -1).astype(vc.dtype), cache_pos, axis=1
        )
        new_cache = (kc, vc)
        k = kc.reshape(b, s, cfg.n_kv_heads, hd).astype(x.dtype)
        v = vc.reshape(b, s, cfg.n_kv_heads, hd).astype(x.dtype)
        kv_valid = cache_pos + l
    out = gqa_attention(
        q, k, v, causal=causal and cache is None,
        use_flash=cfg.use_flash_attention, kv_valid_len=kv_valid,
    )
    out = out.reshape(b, l, cfg.n_heads * hd) @ p["wo"].astype(x.dtype)
    return out, new_cache


# ------------------------------------------------------------------ mlp ---
def init_mlp(key, d: int, f: int) -> dict:
    ks = jax.random.split(key, 3)
    return {
        "wg": dense_init(ks[0], (d, f)),
        "wu": dense_init(ks[1], (d, f)),
        "wd": dense_init(ks[2], (f, d)),
    }


def init_mlp_stack(key, n: int, d: int, f: int) -> dict:
    ks = jax.random.split(key, 3)
    return {
        "wg": stack_init(ks[0], n, (d, f)),
        "wu": stack_init(ks[1], n, (d, f)),
        "wd": stack_init(ks[2], n, (f, d)),
    }


def mlp_forward(p: dict, x: jax.Array) -> jax.Array:
    h = jax.nn.silu(x @ p["wg"].astype(x.dtype)) * (x @ p["wu"].astype(x.dtype))
    return h @ p["wd"].astype(x.dtype)


# ------------------------------------------------------------- embedding --
def padded_vocab(cfg: ModelConfig, multiple: int = 256) -> int:
    return -(-cfg.vocab_size // multiple) * multiple


def init_embed(key, cfg: ModelConfig) -> dict:
    v = padded_vocab(cfg)
    ks = jax.random.split(key, 3)
    out = {
        "tok": dense_init(ks[0], (v, cfg.d_model), scale=0.01),
        "norm_f": jnp.ones((cfg.d_model,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        out["head"] = dense_init(ks[1], (cfg.d_model, v), scale=0.01)
    return out


def embed_tokens(params: dict, tokens: jax.Array) -> jax.Array:
    x = params["tok"].astype(COMPUTE_DTYPE)[tokens]
    return shard_batch(x)


def lm_head(params: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    x = rmsnorm(params["norm_f"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = x @ params["tok"].astype(x.dtype).T
    else:
        logits = x @ params["head"].astype(x.dtype)
    rest = (None,) * (logits.ndim - 2)
    return shard(logits.astype(jnp.float32), dp_axes(), *rest, "model")


def lm_loss(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Cross entropy over the PADDED vocab (labels are < true vocab)."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return nll.mean()


def maybe_remat(fn, cfg: ModelConfig):
    if cfg.remat == "full":
        return jax.checkpoint(fn)
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        )
    return fn
