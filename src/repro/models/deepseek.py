"""DeepSeek-V2-236B: Multi-head Latent Attention (MLA, kv_lora=512) +
fine-grained MoE (2 shared + 160 routed experts, top-6).

MLA stores only the compressed latent (c_kv [.., 512] and the decoupled
RoPE key [.., 64]) in the decode cache — the 'absorbed' serving form
(q projected into latent space; values reconstructed after attention),
which is what makes decode_32k at batch 128 feasible.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from ..core.config import ModelConfig
from . import layers as L
from .moe import init_moe_mlp, moe_forward


def init_mla_stack(key, cfg: ModelConfig, n: int) -> dict:
    d = cfg.d_model
    h = cfg.n_heads
    nope, rope = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    vd = cfg.v_head_dim
    ks = jax.random.split(key, 7)
    return {
        "wdq": L.stack_init(ks[0], n, (d, cfg.q_lora_rank)),
        "wuq": L.stack_init(ks[1], n, (cfg.q_lora_rank, h * (nope + rope))),
        "wdkv": L.stack_init(ks[2], n, (d, cfg.kv_lora_rank)),
        "wkr": L.stack_init(ks[3], n, (d, rope)),
        "wukv": L.stack_init(ks[4], n, (cfg.kv_lora_rank, h * (nope + vd))),
        "wo": L.stack_init(ks[5], n, (h * vd, d)),
        "lnq": jnp.ones((n, cfg.q_lora_rank), jnp.float32),
        "lnkv": jnp.ones((n, cfg.kv_lora_rank), jnp.float32),
    }


def mla_train(p: dict, x: jax.Array, cfg: ModelConfig, pos: jax.Array) -> jax.Array:
    b, s, d = x.shape
    h = cfg.n_heads
    nope, rope, vd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    cq = L.rmsnorm(p["lnq"], x @ p["wdq"].astype(x.dtype), cfg.norm_eps)
    q = L.shard_heads((cq @ p["wuq"].astype(x.dtype)).reshape(b, s, h, nope + rope))
    qn, qr = q[..., :nope], q[..., nope:]
    qr = L.apply_rope(qr, pos, cfg.rope_theta)
    ckv = L.rmsnorm(p["lnkv"], x @ p["wdkv"].astype(x.dtype), cfg.norm_eps)
    kr = L.apply_rope(
        (x @ p["wkr"].astype(x.dtype))[:, :, None, :], pos, cfg.rope_theta
    )                                                     # [B,S,1,rope]
    kv = L.shard_heads((ckv @ p["wukv"].astype(x.dtype)).reshape(b, s, h, nope + vd))
    kn, v = kv[..., :nope], kv[..., nope:]
    k = jnp.concatenate([kn, jnp.broadcast_to(kr, (b, s, h, rope))], axis=-1)
    q_full = jnp.concatenate([qn, qr], axis=-1)
    out = L.gqa_attention(q_full, k, v, causal=True,
                          use_flash=cfg.use_flash_attention)
    return out.reshape(b, s, h * vd) @ p["wo"].astype(x.dtype)


def mla_decode(p, x, cfg, cache, pos):
    """Absorbed MLA decode.  cache = (ckv [B,S,lora], kr [B,S,rope])."""
    b, l, d = x.shape  # l == 1
    h = cfg.n_heads
    nope, rope, vd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    lora = cfg.kv_lora_rank
    cq = L.rmsnorm(p["lnq"], x @ p["wdq"].astype(x.dtype), cfg.norm_eps)
    q = (cq @ p["wuq"].astype(x.dtype)).reshape(b, h, nope + rope)
    qn, qr = q[..., :nope], q[..., nope:]
    qr = L.apply_rope(
        qr[:, None], jnp.broadcast_to(pos[None, None], (b, 1)), cfg.rope_theta
    )[:, 0]
    # update cache
    ckv_t = L.rmsnorm(p["lnkv"], x @ p["wdkv"].astype(x.dtype), cfg.norm_eps)
    kr_t = L.apply_rope(
        (x @ p["wkr"].astype(x.dtype))[:, :, None, :],
        jnp.broadcast_to(pos[None, None], (b, 1)), cfg.rope_theta,
    )[:, :, 0, :]
    ckv_c, kr_c = cache
    ckv_c = lax.dynamic_update_slice_in_dim(ckv_c, ckv_t.astype(ckv_c.dtype), pos, 1)
    kr_c = lax.dynamic_update_slice_in_dim(kr_c, kr_t.astype(kr_c.dtype), pos, 1)
    s = ckv_c.shape[1]
    # absorb: q_nope into latent space via w_uk
    wukv = p["wukv"].astype(x.dtype).reshape(lora, h, nope + vd)
    wuk = wukv[..., :nope]                               # [lora, H, nope]
    wuv = wukv[..., nope:]                               # [lora, H, vd]
    q_lat = jnp.einsum("bhn,lhn->bhl", qn, wuk)          # [B, H, lora]
    scores = (
        jnp.einsum("bhl,bsl->bhs", q_lat, ckv_c.astype(x.dtype))
        + jnp.einsum("bhr,bsr->bhs", qr, kr_c.astype(x.dtype))
    ).astype(jnp.float32) / ((nope + rope) ** 0.5)
    valid = jnp.arange(s)[None, None, :] < (pos + 1)
    scores = jnp.where(valid, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    o_lat = jnp.einsum("bhs,bsl->bhl", w, ckv_c.astype(x.dtype))
    out = jnp.einsum("bhl,lhv->bhv", o_lat, wuv).reshape(b, h * vd)
    out = out[:, None, :] @ p["wo"].astype(x.dtype)
    return out, (ckv_c, kr_c)


# ---------------------------------------------------------------- model ---
def init_deepseek(cfg: ModelConfig, key: jax.Array) -> dict:
    ks = jax.random.split(key, 5)
    n_moe = cfg.n_layers - cfg.first_dense_layers
    params = {
        "embed": L.init_embed(ks[0], cfg),
        "dense": {
            "attn": init_mla_stack(ks[1], cfg, cfg.first_dense_layers),
            "mlp": L.init_mlp_stack(ks[2], cfg.first_dense_layers,
                                    cfg.d_model, cfg.d_ff),
            "ln1": jnp.ones((cfg.first_dense_layers, cfg.d_model), jnp.float32),
            "ln2": jnp.ones((cfg.first_dense_layers, cfg.d_model), jnp.float32),
        },
        "layers": {
            "attn": init_mla_stack(ks[3], cfg, n_moe),
            "moe": init_moe_mlp(ks[4], cfg, n_moe),
            "ln1": jnp.ones((n_moe, cfg.d_model), jnp.float32),
            "ln2": jnp.ones((n_moe, cfg.d_model), jnp.float32),
        },
    }
    return params


def _block_train(cfg, x, layer, pos, moe: bool):
    h = mla_train(layer["attn"], L.rmsnorm(layer["ln1"], x, cfg.norm_eps), cfg, pos)
    x = x + h
    z = L.rmsnorm(layer["ln2"], x, cfg.norm_eps)
    if moe:
        x = x + moe_forward(layer["moe"], z, cfg)
    else:
        x = x + L.mlp_forward(layer["mlp"], z)
    return L.shard_batch(x)


def forward_train(cfg: ModelConfig, params: dict, tokens: jax.Array) -> jax.Array:
    b, s = tokens.shape
    x = L.embed_tokens(params["embed"], tokens)
    pos = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))

    def dense_body(x, layer):
        return L.maybe_remat(
            lambda x, l: _block_train(cfg, x, l, pos, moe=False), cfg
        )(x, layer), None

    def moe_body(x, layer):
        return L.maybe_remat(
            lambda x, l: _block_train(cfg, x, l, pos, moe=True), cfg
        )(x, layer), None

    x, _ = lax.scan(dense_body, x, params["dense"])
    x, _ = lax.scan(moe_body, x, params["layers"])
    return L.lm_head(params["embed"], x, cfg)


def loss_fn(cfg, params, batch):
    return L.lm_loss(forward_train(cfg, params, batch["tokens"]), batch["labels"])


def init_cache(cfg: ModelConfig, batch: int, seq: int) -> dict:
    return {
        "ckv_dense": jnp.zeros(
            (cfg.first_dense_layers, batch, seq, cfg.kv_lora_rank), jnp.bfloat16),
        "kr_dense": jnp.zeros(
            (cfg.first_dense_layers, batch, seq, cfg.qk_rope_head_dim), jnp.bfloat16),
        "ckv": jnp.zeros(
            (cfg.n_layers - cfg.first_dense_layers, batch, seq, cfg.kv_lora_rank),
            jnp.bfloat16),
        "kr": jnp.zeros(
            (cfg.n_layers - cfg.first_dense_layers, batch, seq, cfg.qk_rope_head_dim),
            jnp.bfloat16),
    }


def forward_decode(cfg, params, cache, tokens, pos):
    b = tokens.shape[0]
    x = L.embed_tokens(params["embed"], tokens)

    def make_body(moe: bool):
        def body(x, xs):
            layer, ckv, kr = xs
            h, (ckv, kr) = mla_decode(
                layer["attn"], L.rmsnorm(layer["ln1"], x, cfg.norm_eps),
                cfg, (ckv, kr), pos,
            )
            x = x + h
            z = L.rmsnorm(layer["ln2"], x, cfg.norm_eps)
            if moe:
                x = x + moe_forward(layer["moe"], z, cfg)
            else:
                x = x + L.mlp_forward(layer["mlp"], z)
            return x, (ckv, kr)
        return body

    x, (ckv_d, kr_d) = lax.scan(
        make_body(False), x, (params["dense"], cache["ckv_dense"], cache["kr_dense"])
    )
    x, (ckv_m, kr_m) = lax.scan(
        make_body(True), x, (params["layers"], cache["ckv"], cache["kr"])
    )
    logits = L.lm_head(params["embed"], x, cfg)[:, 0]
    return logits, {"ckv_dense": ckv_d, "kr_dense": kr_d, "ckv": ckv_m, "kr": kr_m}
