"""Zamba2-1.2b hybrid: Mamba2 backbone (38 layers) + ONE shared GQA
attention block (arXiv:2411.15242) applied after every ``attn_every``-th
mamba layer — the same parameters at every application site (6 sites here),
each site with its own KV cache.

Train path: lax.scan over 6 groups of (6 mamba layers + shared attn), plus
the 2 tail mamba layers.  The shared block's params are closure captures of
the scan body — scanned-over xs carry only the mamba stacks.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from ..core.config import ModelConfig
from . import layers as L
from .ssm import init_mamba_stack, mamba_train, mamba_decode, _dims


def _n_sites(cfg: ModelConfig) -> int:
    return cfg.n_layers // cfg.attn_every


def _grouped(cfg: ModelConfig):
    sites = _n_sites(cfg)
    return sites, cfg.n_layers - sites * cfg.attn_every


def init_zamba2(cfg: ModelConfig, key: jax.Array) -> dict:
    ks = jax.random.split(key, 5)
    return {
        "embed": L.init_embed(ks[0], cfg),
        "mamba": init_mamba_stack(ks[1], cfg, cfg.n_layers),
        "shared": {
            "attn": L.init_attn(ks[2], cfg),
            "mlp": L.init_mlp(ks[3], cfg.d_model, cfg.d_ff),
            "ln1": jnp.ones((cfg.d_model,), jnp.float32),
            "ln2": jnp.ones((cfg.d_model,), jnp.float32),
        },
    }


def _shared_attn_train(cfg, shared, x, pos):
    h, _ = L.attn_forward(
        shared["attn"], L.rmsnorm(shared["ln1"], x, cfg.norm_eps), cfg, pos=pos
    )
    x = x + h
    x = x + L.mlp_forward(shared["mlp"], L.rmsnorm(shared["ln2"], x, cfg.norm_eps))
    return L.shard_batch(x)


def forward_train(cfg: ModelConfig, params: dict, tokens: jax.Array) -> jax.Array:
    b, s = tokens.shape
    x = L.embed_tokens(params["embed"], tokens)
    pos = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    sites, tail = _grouped(cfg)
    ae = cfg.attn_every
    head = jax.tree.map(
        lambda a: a[: sites * ae].reshape((sites, ae) + a.shape[1:]), params["mamba"]
    )
    tail_p = jax.tree.map(lambda a: a[sites * ae:], params["mamba"])
    shared = params["shared"]

    def mamba_body(x, layer):
        out = x + mamba_train(layer, L.rmsnorm(layer["ln"], x, cfg.norm_eps), cfg)
        return L.shard_batch(out), None

    mamba_body = L.maybe_remat(mamba_body, cfg)

    def group_body(x, group):
        x, _ = lax.scan(mamba_body, x, group)
        x = _shared_attn_train(cfg, shared, x, pos)
        return x, None

    x, _ = lax.scan(group_body, x, head)
    x, _ = lax.scan(mamba_body, x, tail_p)
    return L.lm_head(params["embed"], x, cfg)


def loss_fn(cfg, params, batch):
    return L.lm_loss(forward_train(cfg, params, batch["tokens"]), batch["labels"])


def init_cache(cfg: ModelConfig, batch: int, seq: int) -> dict:
    """Mamba states are O(1); the shared attn sites keep per-site KV caches
    of length ``seq`` (this is the part that scales with long_500k)."""
    d_in, h, p, n = _dims(cfg)
    ch = d_in + 2 * n
    sites = _n_sites(cfg)
    kvd = cfg.n_kv_heads * cfg.resolved_head_dim
    return {
        "ssm": jnp.zeros((cfg.n_layers, batch, h, p, n), jnp.float32),
        "conv": jnp.zeros((cfg.n_layers, batch, cfg.conv_width - 1, ch), jnp.bfloat16),
        "k": jnp.zeros((sites, batch, seq, kvd), jnp.bfloat16),
        "v": jnp.zeros((sites, batch, seq, kvd), jnp.bfloat16),
    }


def forward_decode(cfg, params, cache, tokens, pos):
    b = tokens.shape[0]
    x = L.embed_tokens(params["embed"], tokens)
    qpos = jnp.broadcast_to(pos[None, None], (b, 1))
    sites, tail = _grouped(cfg)
    ae = cfg.attn_every
    shared = params["shared"]

    def mamba_step(x, xs):
        layer, ssm, conv = xs
        h, new = mamba_decode(
            layer, L.rmsnorm(layer["ln"], x, cfg.norm_eps), cfg,
            {"ssm": ssm, "conv": conv.astype(x.dtype)},
        )
        return x + h, (new["ssm"], new["conv"].astype(jnp.bfloat16))

    def group_body(x, xs):
        group, ssm, conv, kc, vc = xs
        x, (ssm_n, conv_n) = lax.scan(mamba_step, x, (group, ssm, conv))
        h, (kc, vc) = L.attn_forward(
            shared["attn"], L.rmsnorm(shared["ln1"], x, cfg.norm_eps), cfg,
            pos=qpos, cache=(kc, vc), cache_pos=pos,
        )
        x = x + h
        x = x + L.mlp_forward(shared["mlp"], L.rmsnorm(shared["ln2"], x, cfg.norm_eps))
        return x, (ssm_n, conv_n, kc, vc)

    grp = lambda a: a[: sites * ae].reshape((sites, ae) + a.shape[1:])
    head = jax.tree.map(grp, params["mamba"])
    ssm_h, conv_h = grp(cache["ssm"]), grp(cache["conv"])
    x, (ssm_n, conv_n, k_n, v_n) = lax.scan(
        group_body, x, (head, ssm_h, conv_h, cache["k"], cache["v"])
    )
    tail_p = jax.tree.map(lambda a: a[sites * ae:], params["mamba"])
    x, (ssm_t, conv_t) = lax.scan(
        mamba_step, x, (tail_p, cache["ssm"][sites * ae:], cache["conv"][sites * ae:])
    )
    new_cache = {
        "ssm": jnp.concatenate([ssm_n.reshape((-1,) + ssm_n.shape[2:]), ssm_t]),
        "conv": jnp.concatenate([conv_n.reshape((-1,) + conv_n.shape[2:]), conv_t]),
        "k": k_n,
        "v": v_n,
    }
    return L.lm_head(params["embed"], x, cfg)[:, 0], new_cache
