"""Train any assigned architecture (reduced config) end to end:

    PYTHONPATH=src python examples/train_lm_smoke.py [arch]

Uses the same substrate as the production launcher: balance-table token
sharding, AdamW with warmup+cosine, grad clipping, microbatch accumulation,
checkpointing, and the host prefetch loader (the GraphGen+ pipeline
generalized to token streams — DESIGN.md §4)."""
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import REGISTRY, smoke_config
from repro.core.config import TrainConfig
from repro.data.loader import PrefetchLoader
from repro.models import zoo
from repro.train.train_loop import init_state, make_train_step

arch = sys.argv[1] if len(sys.argv) > 1 else "zamba2-1.2b"
cfg = smoke_config(REGISTRY[arch])
api = zoo.build(cfg)
tcfg = TrainConfig(learning_rate=2e-3, warmup_steps=5, total_steps=30,
                   microbatches=2)
state = init_state(api.init(jax.random.PRNGKey(0)), tcfg)
step = jax.jit(make_train_step(api.loss, tcfg))

STEPS, B, S = 30, 4, 32
rng = np.random.default_rng(0)


def produce(shard: int):
    """Host-side batch producer — runs in the prefetch loader's worker
    threads, overlapping with device compute."""
    r = np.random.default_rng(shard)
    toks = r.integers(0, cfg.vocab_size, (B, S), dtype=np.int32)
    batch = {"tokens": jnp.asarray(toks),
             "labels": jnp.asarray(np.roll(toks, -1, 1))}
    if cfg.family == "vlm":
        batch["vision"] = jnp.asarray(r.standard_normal(
            (B, cfg.n_vision_tokens, cfg.d_vision), dtype=np.float32))
    if cfg.family == "audio":
        batch["frames"] = jnp.asarray(r.standard_normal(
            (B, cfg.n_audio_frames, cfg.d_audio), dtype=np.float32))
    return batch


loader = PrefetchLoader(produce, n_shards=STEPS, depth=2, n_threads=2)
print(f"training {arch} (reduced) for {STEPS} steps...")
for i, batch in enumerate(loader):
    state, m = step(state, batch)
    if (i + 1) % 5 == 0:
        print(f"step {i+1:3d}  loss {float(m['loss']):.4f}  "
              f"gnorm {float(m['grad_norm']):.3f}")
print("done;", f"{loader.backups_issued} straggler backups issued")
